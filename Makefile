# ARI build entry points.
#
# The rust workspace is fully self-contained (offline, no artifacts
# needed) with default features; `make artifacts` runs the python
# build layer to train + AOT-lower the real models for the PJRT path.

CARGO ?= cargo
PYTHON ?= python3

.PHONY: build test lint verify doc fmt bench bench-json bench-serve serve-smoke chaos-smoke artifacts artifacts-quick clean

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

# Repo-native static analysis (docs/LINTS.md): the ari-lint tool walks
# rust/src + rust/tests and enforces the serving core's concurrency,
# clock, poison, hot-path-allocation, unsafe-audit and fault-registry
# contracts.  Escape hatch for experiments: ARI_LINT_SKIP=1 make lint
# (CI always runs it for real).
lint:
ifdef ARI_LINT_SKIP
	@echo "ari-lint: skipped (ARI_LINT_SKIP set)"
else
	$(CARGO) run --release -p ari-lint -- --root .
endif

# The one-stop local gate: what CI's build-test + lint legs enforce.
verify: build test lint

doc:
	$(CARGO) doc --no-deps

fmt:
	$(CARGO) fmt --check

bench:
	$(CARGO) bench

# Machine-readable perf record: short smoke iterations of the mlp /
# runtime / quant / cascade benches, each emitting an `ari-bench v1`
# JSON document, concatenated into BENCH_native.json (one document per
# line).  The mlp and runtime benches run twice — once on the
# auto-detected SIMD dispatch and once forced scalar (`ARI_SIMD=0`) —
# so the artifact records the SIMD delta per commit (each document's
# header carries its `simd` path); bench_quant pairs prepared against
# unprepared quantisation.  CI uploads the result as an artifact so the
# perf trajectory accumulates per commit; see docs/PERF.md.
bench-json:
	ARI_BENCH_SMOKE=1 ARI_BENCH_JSON=$(abspath BENCH_native.bench_mlp.json) $(CARGO) bench --bench bench_mlp
	ARI_SIMD=0 ARI_BENCH_SMOKE=1 ARI_BENCH_JSON=$(abspath BENCH_native.bench_mlp_scalar.json) $(CARGO) bench --bench bench_mlp
	ARI_BENCH_SMOKE=1 ARI_BENCH_JSON=$(abspath BENCH_native.bench_runtime.json) $(CARGO) bench --bench bench_runtime
	ARI_SIMD=0 ARI_BENCH_SMOKE=1 ARI_BENCH_JSON=$(abspath BENCH_native.bench_runtime_scalar.json) $(CARGO) bench --bench bench_runtime
	ARI_BENCH_SMOKE=1 ARI_BENCH_JSON=$(abspath BENCH_native.bench_quant.json) $(CARGO) bench --bench bench_quant
	ARI_BENCH_SMOKE=1 ARI_BENCH_JSON=$(abspath BENCH_native.bench_cascade.json) $(CARGO) bench --bench bench_cascade
	cat BENCH_native.bench_mlp.json BENCH_native.bench_mlp_scalar.json \
	    BENCH_native.bench_runtime.json BENCH_native.bench_runtime_scalar.json \
	    BENCH_native.bench_quant.json BENCH_native.bench_cascade.json > BENCH_native.json
	rm -f BENCH_native.bench_mlp.json BENCH_native.bench_mlp_scalar.json \
	    BENCH_native.bench_runtime.json BENCH_native.bench_runtime_scalar.json \
	    BENCH_native.bench_quant.json BENCH_native.bench_cascade.json
	@echo "wrote BENCH_native.json"

# Machine-readable serving perf record: short smoke sessions of the
# open-loop bench_serve harness (Poisson rates x escalation policy x
# ladder depth, plus closed-loop ceilings and the graceful-degradation
# frontier under injected overload) into BENCH_serve.json —
# p50/p95/p99 latency, queue wait, completions/sec, accuracy and
# robustness counters per session.  CI
# uploads it next to BENCH_native.json so the serving trajectory
# accumulates per commit; see docs/PERF.md for the record format.
bench-serve:
	ARI_BENCH_SMOKE=1 ARI_BENCH_JSON=$(abspath BENCH_serve.json) $(CARGO) bench --bench bench_serve
	@echo "wrote BENCH_serve.json"

# Short deferred-policy serving session on the synthetic fixtures, in
# two legs.  Leg 1: the in-process generator — a 3-level FP ladder
# under open-loop load, exercising the shutdown drain and per-stage
# escalation-flush paths end to end (the paths the PR 3 batcher/SC-key
# fixes cover).  Leg 2: the same session over loopback TCP — `ari serve
# --listen` in the background driven by the real `ari-client` load
# generator (length-prefixed wire protocol, docs/PROTOCOL.md),
# exercising accept/decode/admission, write backpressure and the
# network drain path.  If the client fails the server is killed so the
# target cannot hang; otherwise the server's exit status is the
# verdict (its in-process conservation ledger).
serve-smoke:
	$(CARGO) run --release --bin ari -- serve --deferred --backend native \
		"levels=[8,12,16]" server.requests=512 server.batch_size=32 server.arrival_rate=6000
	$(CARGO) build --release --bin ari --bin ari-client
	$(CARGO) run --release --bin ari -- serve --deferred --backend native \
		"levels=[8,12,16]" dataset=fashion_syn server.requests=512 server.batch_size=32 \
		--listen 127.0.0.1:7171 & srv=$$!; \
	if $(CARGO) run --release --bin ari-client -- --connect 127.0.0.1:7171 \
		--dataset fashion_syn --requests 512 --seed 42 --reconnects 64; then \
		wait $$srv; \
	else \
		kill $$srv 2>/dev/null; wait $$srv; exit 1; \
	fi

# The serve-smoke session under a seeded random fault schedule
# (docs/ROBUSTNESS.md): ARI_FAULTS defaults to seed 1 locally — a bare
# seed arms util::fault's canonical chaos spec (injected backend
# errors/panics, latency spikes, queue stalls, worker death, plus the
# five wire faults: conn-drop, frame-trunc, frame-corrupt, write-split,
# accept-stall); the CI chaos job seeds it from the run id instead.
# Leg 1 (in-process) must survive via retries, pool supervision and
# graceful degradation (server.overload_queue) with every request
# completing exactly once — enforced in-process — and the armed spec is
# echoed for exact replay.  Leg 2 runs the same schedule over loopback
# TCP: the client reconnects through dropped connections and truncated
# streams, and the server's wire conservation ledger
# (responses + dropped = admitted + shed) is enforced in-process.
# Leg 3 is the drift leg: every staged row perturbed (drift-shift) plus
# exec-delay latency spikes, with the closed-loop controller fully
# enabled (per-class + load-adaptive + drift recalibration,
# docs/ROBUSTNESS.md section *Control loop*) — the controller must
# detect the shifted margin distribution, recalibrate online and finish
# the session with every request completing exactly once; the batching
# watchdog (server.watchdog_stall_us default) bounds any stall from the
# inside, the CI job timeout from the outside.  Fixed seed: the drift
# leg pins one reproducible schedule rather than following the CI run
# id.
chaos-smoke:
	ARI_FAULTS=$${ARI_FAULTS:-1} $(CARGO) run --release --bin ari -- serve --deferred --backend native \
		"levels=[8,12,16]" server.requests=512 server.batch_size=32 server.arrival_rate=6000 \
		server.overload_queue=64
	$(CARGO) build --release --bin ari --bin ari-client
	ARI_FAULTS=$${ARI_FAULTS:-1} $(CARGO) run --release --bin ari -- serve --deferred --backend native \
		"levels=[8,12,16]" dataset=fashion_syn server.requests=512 server.batch_size=32 \
		server.overload_queue=64 --listen 127.0.0.1:7272 & srv=$$!; \
	if $(CARGO) run --release --bin ari-client -- --connect 127.0.0.1:7272 \
		--dataset fashion_syn --requests 512 --seed 42 --reconnects 64; then \
		wait $$srv; \
	else \
		kill $$srv 2>/dev/null; wait $$srv; exit 1; \
	fi
	$(CARGO) run --release --bin ari -- serve --deferred --backend native \
		--faults "drift-shift:1.0,exec-delay:0.2@7" \
		"levels=[8,12,16]" server.requests=512 server.batch_size=32 server.arrival_rate=6000 \
		control.per_class=true control.load_adaptive=true control.drift=true \
		control.queue_high=64 control.queue_low=8 \
		control.drift_window=128 control.drift_tolerance=0.05 control.recal_min=32

# Train the MLPs and AOT-lower every resolution variant to HLO text
# (L1/L2 python layer; needs jax).  Output: ./artifacts/
artifacts:
	cd python && $(PYTHON) -m compile.aot --out ../artifacts

# Tiny artifacts for smoke tests (one dataset, two FP levels).
artifacts-quick:
	cd python && $(PYTHON) -m compile.aot --out ../artifacts --quick

clean:
	$(CARGO) clean
	rm -rf artifacts
	rm -f BENCH_native.json BENCH_native.bench_*.json BENCH_serve.json
