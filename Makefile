# ARI build entry points.
#
# The rust workspace is fully self-contained (offline, no artifacts
# needed) with default features; `make artifacts` runs the python
# build layer to train + AOT-lower the real models for the PJRT path.

CARGO ?= cargo
PYTHON ?= python3

.PHONY: build test doc fmt bench artifacts artifacts-quick clean

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

doc:
	$(CARGO) doc --no-deps

fmt:
	$(CARGO) fmt --check

bench:
	$(CARGO) bench

# Train the MLPs and AOT-lower every resolution variant to HLO text
# (L1/L2 python layer; needs jax).  Output: ./artifacts/
artifacts:
	cd python && $(PYTHON) -m compile.aot --out ../artifacts

# Tiny artifacts for smoke tests (one dataset, two FP levels).
artifacts-quick:
	cd python && $(PYTHON) -m compile.aot --out ../artifacts --quick

clean:
	$(CARGO) clean
	rm -rf artifacts
