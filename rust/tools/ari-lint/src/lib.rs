//! `ari-lint` — repo-native static analysis for the ARI serving core.
//!
//! PRs 5–7 built the serving runtime around contracts that existed only
//! by convention; this crate turns them into machine-checked lints
//! (full rationale and the suppression grammar live in docs/LINTS.md):
//!
//! * **sim-discipline** — no raw `std::sync::{Mutex, Condvar, mpsc}` or
//!   `std::thread::spawn` outside `util::sim`, so model checking sees
//!   every scheduling point.
//! * **clock-discipline** — no `Instant::now()` / `SystemTime::now()`
//!   in `server` / `coordinator` outside the `ServeClock` plumbing.
//! * **poison-tolerance** — no `.lock()` / `.wait()` / `.wait_timeout()`
//!   result consumed by `.unwrap()` / `.expect()` in non-test source.
//! * **no-alloc-hot-path** — functions listed in the checked-in
//!   manifest (`hotpath.txt`) may not contain allocation tokens.
//! * **unsafe-audit** — every `unsafe` block / fn / impl carries a
//!   `// SAFETY:` comment or `# Safety` doc section.
//! * **fault-registry** — `util::fault::POINTS` matches the taxonomy
//!   table in docs/ROBUSTNESS.md and every point is armed by a test.
//!
//! Suppression is per-site: `// ari-lint: allow(<lint>): <justification>`
//! on the flagged line or a comment/attribute line directly above it.
//! A malformed suppression is itself a finding (**allow-syntax**), and
//! every well-formed one is listed in the report so nothing is waived
//! silently.
//!
//! The crate is dependency-free (the repo builds offline with vendored
//! crates only), so the Rust "parser" is a small hand-written lexer
//! that blanks comments, strings and char literals while preserving
//! line structure; the lints scan the blanked code text.  That keeps
//! them honest about what they are — lexical contract checks, not type
//! analysis — which is exactly enough for the conventions above.

/// Lint name: raw `std::sync` primitives / `std::thread::spawn`.
pub const SIM_DISCIPLINE: &str = "sim-discipline";
/// Lint name: raw clock reads in `server` / `coordinator`.
pub const CLOCK_DISCIPLINE: &str = "clock-discipline";
/// Lint name: lock/wait results consumed by `.unwrap()` / `.expect()`.
pub const POISON_TOLERANCE: &str = "poison-tolerance";
/// Lint name: allocation tokens in manifest-listed hot-path functions.
pub const NO_ALLOC_HOT_PATH: &str = "no-alloc-hot-path";
/// Lint name: `unsafe` without a `SAFETY:` justification.
pub const UNSAFE_AUDIT: &str = "unsafe-audit";
/// Lint name: fault points out of sync with docs or never armed.
pub const FAULT_REGISTRY: &str = "fault-registry";
/// Lint name: malformed `ari-lint: allow(...)` comments.
pub const ALLOW_SYNTAX: &str = "allow-syntax";

/// Every lint this tool knows, in reporting order.
pub const LINTS: &[&str] = &[
    SIM_DISCIPLINE,
    CLOCK_DISCIPLINE,
    POISON_TOLERANCE,
    NO_ALLOC_HOT_PATH,
    UNSAFE_AUDIT,
    FAULT_REGISTRY,
    ALLOW_SYNTAX,
];

/// One violation: `file:line: lint: msg`.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Repo-relative path.
    pub file: String,
    /// 1-indexed line.
    pub line: usize,
    /// One of [`LINTS`].
    pub lint: &'static str,
    /// Human-readable message.
    pub msg: String,
}

/// One well-formed `ari-lint: allow(...)` comment (whether or not it
/// suppressed a finding this run — stale allows stay visible).
#[derive(Debug, Clone)]
pub struct Suppression {
    /// Repo-relative path.
    pub file: String,
    /// 1-indexed line of the allow comment.
    pub line: usize,
    /// The lint being allowed.
    pub lint: String,
    /// The required justification text.
    pub justification: String,
}

/// The result of linting a tree.
#[derive(Debug, Default)]
pub struct Report {
    /// Unsuppressed violations.
    pub findings: Vec<Finding>,
    /// Every well-formed allow comment in the tree.
    pub suppressions: Vec<Suppression>,
    /// Number of `.rs` files scanned.
    pub files: usize,
}

/// One hot-path manifest entry: `file::func`.
#[derive(Debug, Clone)]
pub struct ManifestEntry {
    /// Repo-relative path of the file defining the function.
    pub file: String,
    /// The function name (definition, not call sites).
    pub func: String,
}

/// Everything the linter consumes, decoupled from the filesystem so
/// the self-tests can lint fixture and mutated sources in memory.
#[derive(Debug, Default)]
pub struct Input {
    /// `(repo-relative path, content)` for every `.rs` file to scan.
    pub files: Vec<(String, String)>,
    /// `(path, content)` of docs/ROBUSTNESS.md, when present.
    pub robustness_md: Option<(String, String)>,
    /// Hot-path manifest entries.
    pub manifest: Vec<ManifestEntry>,
}

/// Parse the `hotpath.txt` manifest: one `path::func` per line, `#`
/// comments and blank lines ignored.
pub fn parse_manifest(text: &str) -> Result<Vec<ManifestEntry>, String> {
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let Some((file, func)) = line.rsplit_once("::") else {
            return Err(format!("hotpath.txt line {}: expected `path::func`, got {:?}", i + 1, line));
        };
        if file.is_empty() || func.is_empty() || !func.chars().all(is_ident_char) {
            return Err(format!("hotpath.txt line {}: malformed entry {:?}", i + 1, line));
        }
        out.push(ManifestEntry { file: file.to_string(), func: func.to_string() });
    }
    Ok(out)
}

fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Does a raw (or byte-raw) string literal open at `chars[i]`?
/// Returns `(hashes, index just past the opening quote)`.
fn raw_string_open(chars: &[char], i: usize) -> Option<(u32, usize)> {
    let mut j = i;
    if chars.get(j) == Some(&'b') {
        j += 1;
    }
    if chars.get(j) != Some(&'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0u32;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if chars.get(j) == Some(&'"') {
        Some((hashes, j + 1))
    } else {
        None
    }
}

/// A lexed source file: comments, strings and char literals blanked out
/// of `code` (line structure preserved), with the comment and
/// string-literal text kept per line for the SAFETY / allow / armed-by
/// checks.
pub struct Lexed {
    /// Blanked code, all lines joined by `\n`.
    code: String,
    /// Byte offset of each line start within `code`.
    line_start: Vec<usize>,
    /// Blanked code per line.
    code_lines: Vec<String>,
    /// Comment text per line (`//`, `///`, `//!`, `/* */` contents).
    comment_lines: Vec<String>,
    /// String-literal contents per line.
    string_lines: Vec<String>,
    /// Lines inside a `#[cfg(test)]` item.
    is_test: Vec<bool>,
    /// Lints allowed per line by well-formed allow comments.
    allows: Vec<Vec<String>>,
    /// Malformed allow comments (reported as `allow-syntax`).
    bad_allows: Vec<(usize, String)>,
    /// Well-formed allow comments: `(line0, lint, justification)`.
    good_allows: Vec<(usize, String, String)>,
}

enum LexState {
    Code,
    LineComment,
    BlockComment(u32),
    Str,
    RawStr(u32),
    CharLit,
}

impl Lexed {
    /// Lex `src` (state machine over chars; no allocation surprises,
    /// no real parsing).
    pub fn new(src: &str) -> Lexed {
        let chars: Vec<char> = src.chars().collect();
        let mut code_lines: Vec<String> = Vec::new();
        let mut comment_lines: Vec<String> = Vec::new();
        let mut string_lines: Vec<String> = Vec::new();
        let mut code = String::new();
        let mut comment = String::new();
        let mut stringv = String::new();
        let mut st = LexState::Code;
        let mut i = 0usize;
        while i < chars.len() {
            let c = chars[i];
            if c == '\n' {
                if matches!(st, LexState::LineComment) {
                    st = LexState::Code;
                }
                code_lines.push(std::mem::take(&mut code));
                comment_lines.push(std::mem::take(&mut comment));
                string_lines.push(std::mem::take(&mut stringv));
                i += 1;
                continue;
            }
            match st {
                LexState::Code => {
                    let next = chars.get(i + 1).copied();
                    if c == '/' && next == Some('/') {
                        st = LexState::LineComment;
                        code.push_str("  ");
                        i += 2;
                    } else if c == '/' && next == Some('*') {
                        st = LexState::BlockComment(1);
                        code.push_str("  ");
                        i += 2;
                    } else if c == '"' {
                        st = LexState::Str;
                        code.push(' ');
                        i += 1;
                    } else if (c == 'r' || c == 'b') && !(i > 0 && is_ident_char(chars[i - 1])) {
                        if let Some((hashes, after)) = raw_string_open(&chars, i) {
                            for _ in i..after {
                                code.push(' ');
                            }
                            st = LexState::RawStr(hashes);
                            i = after;
                        } else if c == 'b' && next == Some('"') {
                            // Byte string: same escape rules as Str.
                            code.push_str("  ");
                            st = LexState::Str;
                            i += 2;
                        } else {
                            code.push(c); // plain ident starting with r/b
                            i += 1;
                        }
                    } else if c == '\'' {
                        let is_char = match chars.get(i + 1) {
                            Some('\\') => true,
                            Some(&x) if x != '\'' => chars.get(i + 2) == Some(&'\''),
                            _ => false,
                        };
                        if is_char {
                            st = LexState::CharLit;
                            code.push(' ');
                        } else {
                            code.push('\''); // lifetime or loop label
                        }
                        i += 1;
                    } else {
                        code.push(c);
                        i += 1;
                    }
                }
                LexState::LineComment => {
                    comment.push(c);
                    code.push(' ');
                    i += 1;
                }
                LexState::BlockComment(depth) => {
                    let next = chars.get(i + 1).copied();
                    if c == '*' && next == Some('/') {
                        st = if depth == 1 { LexState::Code } else { LexState::BlockComment(depth - 1) };
                        code.push_str("  ");
                        i += 2;
                    } else if c == '/' && next == Some('*') {
                        st = LexState::BlockComment(depth + 1);
                        code.push_str("  ");
                        i += 2;
                    } else {
                        comment.push(c);
                        code.push(' ');
                        i += 1;
                    }
                }
                LexState::Str => {
                    if c == '\\' {
                        code.push(' ');
                        if chars.get(i + 1).is_some_and(|&n| n != '\n') {
                            code.push(' ');
                            stringv.push(' ');
                            i += 2;
                        } else {
                            i += 1;
                        }
                    } else if c == '"' {
                        st = LexState::Code;
                        code.push(' ');
                        i += 1;
                    } else {
                        stringv.push(c);
                        code.push(' ');
                        i += 1;
                    }
                }
                LexState::RawStr(hashes) => {
                    let mut closes = c == '"';
                    for h in 0..hashes as usize {
                        closes = closes && chars.get(i + 1 + h) == Some(&'#');
                    }
                    if closes {
                        for _ in 0..=hashes {
                            code.push(' ');
                        }
                        st = LexState::Code;
                        i += 1 + hashes as usize;
                    } else {
                        stringv.push(c);
                        code.push(' ');
                        i += 1;
                    }
                }
                LexState::CharLit => {
                    if c == '\\' {
                        code.push(' ');
                        if chars.get(i + 1).is_some_and(|&n| n != '\n') {
                            code.push(' ');
                            i += 2;
                        } else {
                            i += 1;
                        }
                    } else {
                        if c == '\'' {
                            st = LexState::Code;
                        }
                        code.push(' ');
                        i += 1;
                    }
                }
            }
        }
        code_lines.push(code);
        comment_lines.push(comment);
        string_lines.push(stringv);

        let mut all = String::new();
        let mut line_start = Vec::with_capacity(code_lines.len());
        for (i, l) in code_lines.iter().enumerate() {
            line_start.push(all.len());
            all.push_str(l);
            if i + 1 < code_lines.len() {
                all.push('\n');
            }
        }
        let is_test = compute_test_regions(&all, &line_start, code_lines.len());
        let mut lexed = Lexed {
            code: all,
            line_start,
            code_lines,
            comment_lines,
            string_lines,
            is_test,
            allows: Vec::new(),
            bad_allows: Vec::new(),
            good_allows: Vec::new(),
        };
        lexed.parse_allows();
        lexed
    }

    /// 0-indexed line of a byte offset into `code`.
    fn line_of(&self, offset: usize) -> usize {
        match self.line_start.binary_search(&offset) {
            Ok(l) => l,
            Err(ins) => ins - 1,
        }
    }

    fn parse_allows(&mut self) {
        self.allows = vec![Vec::new(); self.comment_lines.len()];
        let marker = "ari-lint: allow(";
        for i in 0..self.comment_lines.len() {
            let text = self.comment_lines[i].clone();
            let mut from = 0usize;
            while let Some(rel) = text[from..].find(marker) {
                let after = from + rel + marker.len();
                from = after;
                let Some(close) = text[after..].find(')') else {
                    self.bad_allows.push((i, "unclosed `ari-lint: allow(`".to_string()));
                    break;
                };
                let name = text[after..after + close].trim().to_string();
                let rest = &text[after + close + 1..];
                if !LINTS.contains(&name.as_str()) {
                    self.bad_allows.push((i, format!("unknown lint {name:?} in allow")));
                    continue;
                }
                let Some(just) = rest.strip_prefix(':') else {
                    let m = format!("allow({name}) is missing its `: <justification>` — say why");
                    self.bad_allows.push((i, m));
                    continue;
                };
                let just = just.trim();
                // The justification ends at the next allow marker, if
                // several share one line (they never should).
                let just = just.split("ari-lint: allow(").next().unwrap_or("").trim();
                if just.is_empty() {
                    let m = format!("allow({name}) has an empty justification — say why");
                    self.bad_allows.push((i, m));
                    continue;
                }
                self.allows[i].push(name.clone());
                self.good_allows.push((i, name, just.to_string()));
            }
        }
    }

    /// True when line `l0` (0-indexed) is covered by a comment matching
    /// `pred` — on the same line, or on contiguous comment-only /
    /// attribute-only lines directly above (the SAFETY / allow walk).
    fn covered_by(&self, l0: usize, pred: &dyn Fn(&Lexed, usize) -> bool) -> bool {
        if pred(self, l0) {
            return true;
        }
        let mut l = l0;
        for _ in 0..50 {
            if l == 0 {
                return false;
            }
            l -= 1;
            let code = self.code_lines[l].trim();
            let has_comment = !self.comment_lines[l].trim().is_empty();
            if code.is_empty() && !has_comment {
                return false; // fully blank line ends the walk
            }
            if code.is_empty() || code.starts_with("#[") || code.starts_with("#![") {
                if has_comment && pred(self, l) {
                    return true;
                }
                continue; // comment-only or attribute line: keep walking
            }
            return false; // real code ends the walk
        }
        false
    }

    fn allowed(&self, l0: usize, lint: &str) -> bool {
        let pred = move |lex: &Lexed, l: usize| lex.allows[l].iter().any(|a| a == lint);
        self.covered_by(l0, &pred)
    }

    fn has_safety_comment(&self, l0: usize) -> bool {
        fn pred(lex: &Lexed, l: usize) -> bool {
            lex.comment_lines[l].contains("SAFETY:") || lex.comment_lines[l].contains("# Safety")
        }
        self.covered_by(l0, &pred)
    }
}

/// Mark every line belonging to a `#[cfg(test)]` item (in this repo:
/// always a `mod tests { ... }` block; a non-mod item falls back to
/// marking the single following item line).
fn compute_test_regions(code: &str, line_start: &[usize], n_lines: usize) -> Vec<bool> {
    let mut t = vec![false; n_lines];
    let bytes = code.as_bytes();
    let mut from = 0usize;
    while let Some(rel) = code[from..].find("#[cfg(test)]") {
        let attr_at = from + rel;
        from = attr_at + 1;
        let attr_line = line_of_in(line_start, attr_at);
        // Look for a `mod` keyword within the next few hundred bytes.
        let mut window_end = (attr_at + 400).min(code.len());
        while !code.is_char_boundary(window_end) {
            window_end -= 1;
        }
        let window = &code[attr_at..window_end];
        let mut mod_at = None;
        let mut wfrom = 0usize;
        while let Some(mrel) = window[wfrom..].find("mod") {
            let abs = attr_at + wfrom + mrel;
            wfrom += mrel + 3;
            let before_ok = abs == 0 || !is_ident_byte(bytes[abs - 1]);
            let after_ok = abs + 3 >= bytes.len() || !is_ident_byte(bytes[abs + 3]);
            if before_ok && after_ok {
                mod_at = Some(abs);
                break;
            }
        }
        let marked = mod_at
            .and_then(|m| code[m..].find('{').map(|b| m + b))
            .and_then(|open| match_delim(bytes, open, b'{', b'}'))
            .map(|close| line_of_in(line_start, close));
        match marked {
            Some(close_line) => {
                for l in attr_line..=close_line.min(n_lines - 1) {
                    t[l] = true;
                }
            }
            None => {
                // Attribute on a non-mod item (or an unclosed mod):
                // conservatively mark the attribute line and the next
                // non-blank code line.
                t[attr_line] = true;
                for (l, flag) in t.iter_mut().enumerate().take(n_lines).skip(attr_line + 1) {
                    let ls = line_start[l];
                    let le = if l + 1 < line_start.len() { line_start[l + 1] } else { code.len() };
                    if !code[ls..le].trim().is_empty() {
                        *flag = true;
                        break;
                    }
                }
            }
        }
    }
    t
}

fn line_of_in(line_start: &[usize], offset: usize) -> usize {
    match line_start.binary_search(&offset) {
        Ok(l) => l,
        Err(ins) => ins - 1,
    }
}

/// Find the matching close delimiter for the open delimiter at `open`.
fn match_delim(bytes: &[u8], open: usize, o: u8, c: u8) -> Option<usize> {
    let mut depth = 0i64;
    for (i, &b) in bytes.iter().enumerate().skip(open) {
        if b == o {
            depth += 1;
        } else if b == c {
            depth -= 1;
            if depth == 0 {
                return Some(i);
            }
        }
    }
    None
}

/// Whole-ident occurrences of `needle` in `hay` (byte offsets).
fn find_ident_occurrences(hay: &str, needle: &str) -> Vec<usize> {
    let bytes = hay.as_bytes();
    let mut out = Vec::new();
    let mut from = 0usize;
    while let Some(rel) = hay[from..].find(needle) {
        let at = from + rel;
        from = at + 1;
        let before_ok = at == 0 || !is_ident_byte(bytes[at - 1]);
        let end = at + needle.len();
        let after_ok = end >= bytes.len() || !is_ident_byte(bytes[end]);
        if before_ok && after_ok {
            out.push(at);
        }
    }
    out
}

/// Leading identifier of `s`.
fn leading_ident(s: &str) -> &str {
    let end = s.find(|c: char| !is_ident_char(c)).unwrap_or(s.len());
    &s[..end]
}

fn is_test_file(path: &str) -> bool {
    path.starts_with("rust/tests/") || path.contains("/tests/")
}

fn is_sim_file(path: &str) -> bool {
    path.ends_with("util/sim.rs")
}

// ---------------------------------------------------------------------
// The lints
// ---------------------------------------------------------------------

fn lint_sim_discipline(path: &str, lex: &Lexed, out: &mut Vec<Finding>) {
    if is_sim_file(path) {
        return;
    }
    for at in find_ident_occurrences(&lex.code, "std::thread::spawn") {
        out.push(Finding {
            file: path.to_string(),
            line: lex.line_of(at) + 1,
            lint: SIM_DISCIPLINE,
            msg: "raw `std::thread::spawn` — use `sim::spawn` so model checking sees the thread".to_string(),
        });
    }
    let banned = ["Mutex", "Condvar", "mpsc"];
    let bytes = lex.code.as_bytes();
    let mut from = 0usize;
    while let Some(rel) = lex.code[from..].find("std::sync::") {
        let at = from + rel;
        from = at + 1;
        if at > 0 && is_ident_byte(bytes[at - 1]) {
            continue;
        }
        let rest = &lex.code[at + "std::sync::".len()..];
        if rest.starts_with('{') {
            // `use std::sync::{...}` group, possibly multi-line.
            let open = at + "std::sync::".len();
            let Some(close) = match_delim(bytes, open, b'{', b'}') else { continue };
            let group = &lex.code[open..close];
            for b in banned {
                for grel in find_ident_occurrences(group, b) {
                    out.push(Finding {
                        file: path.to_string(),
                        line: lex.line_of(open + grel) + 1,
                        lint: SIM_DISCIPLINE,
                        msg: format!("raw `std::sync::{b}` — use the `util::sim` wrapper (docs/LINTS.md)"),
                    });
                }
            }
        } else {
            let ident = leading_ident(rest);
            if banned.contains(&ident) {
                out.push(Finding {
                    file: path.to_string(),
                    line: lex.line_of(at) + 1,
                    lint: SIM_DISCIPLINE,
                    msg: format!("raw `std::sync::{ident}` — use the `util::sim` wrapper (docs/LINTS.md)"),
                });
            }
        }
    }
}

fn lint_clock_discipline(path: &str, lex: &Lexed, out: &mut Vec<Finding>) {
    if !(path.contains("src/server/") || path.contains("src/coordinator/")) {
        return;
    }
    for needle in ["Instant::now", "SystemTime::now"] {
        for at in find_ident_occurrences(&lex.code, needle) {
            let l0 = lex.line_of(at);
            if lex.is_test[l0] {
                continue;
            }
            out.push(Finding {
                file: path.to_string(),
                line: l0 + 1,
                lint: CLOCK_DISCIPLINE,
                msg: format!("`{needle}()` in the serving core — thread time through `ServeClock`"),
            });
        }
    }
}

fn lint_poison_tolerance(path: &str, lex: &Lexed, out: &mut Vec<Finding>) {
    if is_sim_file(path) || is_test_file(path) {
        return;
    }
    let bytes = lex.code.as_bytes();
    for needle in [".lock(", ".wait(", ".wait_timeout("] {
        let mut from = 0usize;
        while let Some(rel) = lex.code[from..].find(needle) {
            let at = from + rel;
            from = at + 1;
            let l0 = lex.line_of(at);
            if lex.is_test[l0] {
                continue;
            }
            let open = at + needle.len() - 1;
            let Some(close) = match_delim(bytes, open, b'(', b')') else { continue };
            let mut j = close + 1;
            while j < bytes.len() && (bytes[j] as char).is_whitespace() {
                j += 1;
            }
            if j >= bytes.len() || bytes[j] != b'.' {
                continue;
            }
            let method = leading_ident(&lex.code[j + 1..]);
            if method == "unwrap" || method == "expect" {
                let m = needle.trim_start_matches('.').trim_end_matches('(');
                out.push(Finding {
                    file: path.to_string(),
                    line: l0 + 1,
                    lint: POISON_TOLERANCE,
                    msg: format!("`.{m}(..).{method}()` panics on poison — use `unwrap_or_else(|e| e.into_inner())`"),
                });
            }
        }
    }
}

/// Allocation tokens banned inside hot-path manifest functions.
const ALLOC_TOKENS: &[&str] = &[
    "Vec::new",
    "vec!",
    "Box::new",
    "format!",
    "String::new",
    "String::from",
    "with_capacity",
    ".to_vec",
    ".to_string",
    ".to_owned",
    ".clone",
    ".collect",
];

fn lint_no_alloc(entry: &ManifestEntry, lexeds: &[(String, Lexed)], out: &mut Vec<Finding>) {
    let Some((path, lex)) = lexeds.iter().find(|(p, _)| *p == entry.file) else {
        out.push(Finding {
            file: entry.file.clone(),
            line: 1,
            lint: NO_ALLOC_HOT_PATH,
            msg: format!("hot-path manifest names `{}` but the file was not scanned", entry.func),
        });
        return;
    };
    let needle = format!("fn {}", entry.func);
    let bytes = lex.code.as_bytes();
    let def = find_ident_occurrences(&lex.code, &needle).into_iter().find(|&at| !lex.is_test[lex.line_of(at)]);
    let Some(def) = def else {
        out.push(Finding {
            file: path.clone(),
            line: 1,
            lint: NO_ALLOC_HOT_PATH,
            msg: format!("hot-path manifest names `{}` but no such fn is defined here", entry.func),
        });
        return;
    };
    // First `{` at paren depth 0 after the signature opens the body.
    let mut depth = 0i64;
    let mut open = None;
    for (i, &b) in bytes.iter().enumerate().skip(def) {
        match b {
            b'(' => depth += 1,
            b')' => depth -= 1,
            b'{' if depth == 0 => {
                open = Some(i);
                break;
            }
            _ => {}
        }
    }
    let Some(open) = open else { return };
    let Some(close) = match_delim(bytes, open, b'{', b'}') else { return };
    let body = &lex.code[open..close];
    for token in ALLOC_TOKENS {
        // Method tokens match ident-bounded after a `.`, so `.clone()`
        // and `.collect::<..>()` hit but `.clone_from(..)` does not.
        let hits: Vec<usize> = if let Some(m) = token.strip_prefix('.') {
            find_ident_occurrences(body, m)
                .into_iter()
                .filter(|&at| at > 0 && body.as_bytes()[at - 1] == b'.')
                .map(|at| at - 1)
                .collect()
        } else {
            find_ident_occurrences(body, token.trim_end_matches('!'))
                .into_iter()
                .filter(|&at| !token.ends_with('!') || body[at + token.len() - 1..].starts_with('!'))
                .collect()
        };
        for at in hits {
            out.push(Finding {
                file: path.clone(),
                line: lex.line_of(open + at) + 1,
                lint: NO_ALLOC_HOT_PATH,
                msg: format!("allocation token `{token}` in hot-path fn `{}` (hotpath.txt)", entry.func),
            });
        }
    }
}

fn lint_unsafe_audit(path: &str, lex: &Lexed, out: &mut Vec<Finding>) {
    let bytes = lex.code.as_bytes();
    for at in find_ident_occurrences(&lex.code, "unsafe") {
        let mut j = at + "unsafe".len();
        while j < bytes.len() && (bytes[j] as char).is_whitespace() {
            j += 1;
        }
        if lex.code[j..].starts_with("fn") {
            let mut k = j + 2;
            while k < bytes.len() && (bytes[k] as char).is_whitespace() {
                k += 1;
            }
            if k < bytes.len() && bytes[k] == b'(' {
                continue; // `unsafe fn(..)` function-pointer type, not a declaration
            }
        }
        let l0 = lex.line_of(at);
        if !lex.has_safety_comment(l0) {
            out.push(Finding {
                file: path.to_string(),
                line: l0 + 1,
                lint: UNSAFE_AUDIT,
                msg: "`unsafe` without a `// SAFETY:` comment (or `# Safety` doc section) above".to_string(),
            });
        }
    }
}

fn lint_fault_registry(input: &Input, lexeds: &[(String, Lexed)], out: &mut Vec<Finding>) {
    let Some((fault_path, fault_lex)) = lexeds.iter().find(|(p, _)| p.ends_with("util/fault.rs")) else {
        return; // tree without a fault registry (fixture runs): nothing to check
    };
    // `pub const NAME: &str = "value";` — values live in string
    // literals, so parse names from code and values from string text.
    let mut consts: Vec<(String, String, usize)> = Vec::new();
    for (i, code) in fault_lex.code_lines.iter().enumerate() {
        let t = code.trim_start();
        let Some(rest) = t.strip_prefix("pub const ") else { continue };
        let name = leading_ident(rest);
        if name.is_empty() || !rest[name.len()..].trim_start().starts_with(": &str") {
            continue;
        }
        let value = fault_lex.string_lines[i].trim().to_string();
        if !value.is_empty() {
            consts.push((name.to_string(), value, i));
        }
    }
    // `pub const POINTS: &[&str] = &[A, B, ...];`
    let mut points: Vec<(String, usize)> = Vec::new();
    let mut points_line = 1usize;
    if let Some(at) = fault_lex.code.find("const POINTS") {
        points_line = fault_lex.line_of(at) + 1;
        // The `[` we want is the initialiser's, after the `=` — not the
        // one in the `&[&str]` type annotation.
        let eq = fault_lex.code[at..].find('=').map(|e| at + e).unwrap_or(at);
        if let Some(bo) = fault_lex.code[eq..].find('[') {
            let open = eq + bo;
            if let Some(close) = match_delim(fault_lex.code.as_bytes(), open, b'[', b']') {
                for ident in fault_lex.code[open + 1..close].split(',') {
                    let ident = ident.trim();
                    if ident.is_empty() {
                        continue;
                    }
                    match consts.iter().find(|(n, _, _)| n.as_str() == ident) {
                        Some((_, value, line0)) => points.push((value.clone(), line0 + 1)),
                        None => out.push(Finding {
                            file: fault_path.clone(),
                            line: points_line,
                            lint: FAULT_REGISTRY,
                            msg: format!("POINTS entry `{ident}` has no `pub const .. : &str` here"),
                        }),
                    }
                }
            }
        }
    } else {
        out.push(Finding {
            file: fault_path.clone(),
            line: 1,
            lint: FAULT_REGISTRY,
            msg: "no `const POINTS` table found in util/fault.rs".to_string(),
        });
        return;
    }
    // The taxonomy table in docs/ROBUSTNESS.md.
    let Some((md_path, md)) = &input.robustness_md else {
        out.push(Finding {
            file: fault_path.clone(),
            line: points_line,
            lint: FAULT_REGISTRY,
            msg: "docs/ROBUSTNESS.md not found — the fault-point taxonomy table must document every point".to_string(),
        });
        return;
    };
    let mut doc_points: Vec<(String, usize)> = Vec::new();
    let mut in_section = false;
    for (i, line) in md.lines().enumerate() {
        let t = line.trim();
        if t.starts_with("###") {
            in_section = t.contains("Fault points");
            continue;
        }
        if in_section && t.starts_with('#') {
            in_section = false;
        }
        if in_section && t.starts_with('|') {
            let mut back = t.split('`');
            if let (Some(_), Some(name)) = (back.next(), back.next()) {
                doc_points.push((name.to_string(), i + 1));
            }
        }
    }
    for (p, line) in &points {
        if !doc_points.iter().any(|(d, _)| d == p) {
            out.push(Finding {
                file: md_path.clone(),
                line: 1,
                lint: FAULT_REGISTRY,
                msg: format!("fault point `{p}` (util/fault.rs:{line}) missing from the taxonomy table"),
            });
        }
    }
    for (d, line) in &doc_points {
        if !points.iter().any(|(p, _)| p == d) {
            out.push(Finding {
                file: md_path.clone(),
                line: *line,
                lint: FAULT_REGISTRY,
                msg: format!("documented fault point `{d}` is not defined in util::fault::POINTS"),
            });
        }
    }
    // Every point must be armed by at least one test (a string literal
    // containing the point name inside test code).
    for (p, line) in &points {
        let armed = lexeds.iter().any(|(path, lex)| {
            lex.string_lines
                .iter()
                .enumerate()
                .any(|(l, s)| (is_test_file(path) || lex.is_test[l]) && s.contains(p.as_str()))
        });
        if !armed {
            out.push(Finding {
                file: fault_path.clone(),
                line: *line,
                lint: FAULT_REGISTRY,
                msg: format!("fault point `{p}` is never armed by any test (`ArmGuard::arm`)"),
            });
        }
    }
}

// ---------------------------------------------------------------------
// Driver
// ---------------------------------------------------------------------

/// Lint a tree.  Findings covered by a well-formed allow comment are
/// suppressed; every allow comment (used or not) is reported.
pub fn run(input: &Input) -> Report {
    let lexeds: Vec<(String, Lexed)> = input.files.iter().map(|(p, s)| (p.clone(), Lexed::new(s))).collect();
    let mut raw: Vec<Finding> = Vec::new();
    for (path, lex) in &lexeds {
        lint_sim_discipline(path, lex, &mut raw);
        lint_clock_discipline(path, lex, &mut raw);
        lint_poison_tolerance(path, lex, &mut raw);
        lint_unsafe_audit(path, lex, &mut raw);
        for (l0, msg) in &lex.bad_allows {
            raw.push(Finding { file: path.clone(), line: l0 + 1, lint: ALLOW_SYNTAX, msg: msg.clone() });
        }
    }
    for entry in &input.manifest {
        lint_no_alloc(entry, &lexeds, &mut raw);
    }
    lint_fault_registry(input, &lexeds, &mut raw);

    let mut findings = Vec::new();
    for f in raw {
        let suppressed = f.lint != ALLOW_SYNTAX
            && lexeds.iter().any(|(p, lex)| *p == f.file && f.line >= 1 && lex.allowed(f.line - 1, f.lint));
        if !suppressed {
            findings.push(f);
        }
    }
    findings.sort_by(|a, b| (&a.file, a.line, a.lint).cmp(&(&b.file, b.line, b.lint)));

    let mut suppressions = Vec::new();
    for (path, lex) in &lexeds {
        for (l0, lint, just) in &lex.good_allows {
            suppressions.push(Suppression {
                file: path.clone(),
                line: l0 + 1,
                lint: lint.clone(),
                justification: just.clone(),
            });
        }
    }
    suppressions.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));

    Report { findings, suppressions, files: lexeds.len() }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn one(path: &str, src: &str) -> Report {
        run(&Input { files: vec![(path.to_string(), src.to_string())], robustness_md: None, manifest: Vec::new() })
    }

    #[test]
    fn lexer_blanks_comments_strings_chars_and_keeps_lifetimes() {
        let src = "let s = \"std::sync::Mutex\"; // std::sync::Mutex\nlet l: &'static str = x;\n";
        let lex = Lexed::new(src);
        assert!(!lex.code.contains("std::sync::Mutex"), "strings and comments must be blanked");
        assert!(lex.comment_lines[0].contains("std::sync::Mutex"));
        assert!(lex.string_lines[0].contains("std::sync::Mutex"));
        assert!(lex.code.contains("&'static str"), "lifetimes survive blanking");
        let lex2 = Lexed::new("let c = 'x'; let e = '\\n';\n");
        assert!(!lex2.code.contains("'x'"), "char literals are blanked");
    }

    #[test]
    fn lexer_handles_raw_strings() {
        let lex = Lexed::new("let s = r#\"a \"quoted\" std::sync::Mutex\"#;\nlet t = 1;\n");
        assert!(!lex.code.contains("Mutex"));
        assert!(lex.code.contains("let t = 1;"));
    }

    #[test]
    fn test_regions_cover_cfg_test_mods() {
        let src = "fn a() {}\n#[cfg(test)]\nmod tests {\n    fn b() {}\n}\nfn c() {}\n";
        let lex = Lexed::new(src);
        assert!(!lex.is_test[0]);
        assert!(lex.is_test[1] && lex.is_test[2] && lex.is_test[3] && lex.is_test[4]);
        assert!(!lex.is_test[5]);
    }

    #[test]
    fn sim_discipline_flags_paths_and_use_groups() {
        let src = "use std::sync::{Arc, Mutex as M, Condvar};\nfn f() { std::thread::spawn(|| {}); }\n";
        let r = one("rust/src/x.rs", src);
        let lints: Vec<_> = r.findings.iter().map(|f| f.lint).collect();
        assert_eq!(lints.iter().filter(|&&l| l == SIM_DISCIPLINE).count(), 3, "{:?}", r.findings);
        assert!(r.findings.iter().any(|f| f.msg.contains("Mutex")));
        assert!(r.findings.iter().any(|f| f.msg.contains("Condvar")));
        assert!(r.findings.iter().any(|f| f.msg.contains("spawn")));
        assert!(!r.findings.iter().any(|f| f.msg.contains("Arc")), "Arc is allowed");
    }

    #[test]
    fn allow_suppresses_and_is_reported() {
        let src = "// ari-lint: allow(sim-discipline): fixture reason.\nuse std::sync::Mutex;\n";
        let r = one("rust/src/x.rs", src);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
        assert_eq!(r.suppressions.len(), 1);
        assert_eq!(r.suppressions[0].justification, "fixture reason.");
    }

    #[test]
    fn allow_without_justification_is_a_finding() {
        let src = "// ari-lint: allow(sim-discipline)\nuse std::sync::Mutex;\n";
        let r = one("rust/src/x.rs", src);
        assert!(r.findings.iter().any(|f| f.lint == ALLOW_SYNTAX), "{:?}", r.findings);
        assert!(r.findings.iter().any(|f| f.lint == SIM_DISCIPLINE), "a malformed allow must not suppress");
    }

    #[test]
    fn manifest_parses_and_rejects_garbage() {
        let m = parse_manifest("# c\nrust/src/a.rs::f\n\nrust/src/b.rs::g\n").unwrap();
        assert_eq!(m.len(), 2);
        assert_eq!(m[1].func, "g");
        assert!(parse_manifest("no-separator\n").is_err());
    }
}
