//! CLI driver for `ari-lint`: walk `rust/src` + `rust/tests`, lint,
//! print `file:line: lint: message` findings plus the suppression
//! summary, and exit non-zero when anything fires.  `make lint` runs
//! this; see docs/LINTS.md.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use ari_lint::{parse_manifest, run, Input};

fn main() -> ExitCode {
    let mut root = PathBuf::from(".");
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--root" => match args.next() {
                Some(r) => root = PathBuf::from(r),
                None => {
                    eprintln!("ari-lint: --root needs a path");
                    return ExitCode::from(2);
                }
            },
            "--help" | "-h" => {
                println!("usage: ari-lint [--root <repo-root>]");
                println!("Lints rust/src and rust/tests against the serving-core contracts (docs/LINTS.md).");
                return ExitCode::SUCCESS;
            }
            other => {
                eprintln!("ari-lint: unknown argument {other:?} (try --help)");
                return ExitCode::from(2);
            }
        }
    }

    let mut files: Vec<PathBuf> = Vec::new();
    for dir in ["rust/src", "rust/tests"] {
        let abs = root.join(dir);
        if !abs.is_dir() {
            eprintln!("ari-lint: {} not found — is --root pointing at the repo root?", abs.display());
            return ExitCode::from(2);
        }
        collect_rs(&abs, &mut files);
    }
    files.sort();

    let mut input = Input::default();
    for path in &files {
        match std::fs::read_to_string(path) {
            Ok(src) => input.files.push((rel(path, &root), src)),
            Err(e) => {
                eprintln!("ari-lint: cannot read {}: {e}", path.display());
                return ExitCode::from(2);
            }
        }
    }
    let md_path = root.join("docs/ROBUSTNESS.md");
    if let Ok(md) = std::fs::read_to_string(&md_path) {
        input.robustness_md = Some((rel(&md_path, &root), md));
    }
    input.manifest = match parse_manifest(include_str!("../hotpath.txt")) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("ari-lint: {e}");
            return ExitCode::from(2);
        }
    };

    let report = run(&input);
    for f in &report.findings {
        println!("{}:{}: {}: {}", f.file, f.line, f.lint, f.msg);
    }
    if !report.suppressions.is_empty() {
        println!("suppressions ({}):", report.suppressions.len());
        for s in &report.suppressions {
            println!("  {}:{}: allow({}): {}", s.file, s.line, s.lint, s.justification);
        }
    }
    println!(
        "ari-lint: {} finding(s), {} suppression(s), {} file(s) scanned",
        report.findings.len(),
        report.suppressions.len(),
        report.files
    );
    if report.findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.is_dir() {
            // Skip the vendored crates: they are third-party code.
            if path.file_name().is_some_and(|n| n == "vendor") {
                continue;
            }
            collect_rs(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

fn rel(path: &Path, root: &Path) -> String {
    path.strip_prefix(root).unwrap_or(path).to_string_lossy().replace('\\', "/")
}
