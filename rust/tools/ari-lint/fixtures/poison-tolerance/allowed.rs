//! ari-lint fixture: poison-tolerant recovery passes, and a justified
//! allow suppresses the strict site.  Lexed as
//! `rust/src/util/counter.rs` by the self-test; never compiled.

use crate::util::sim::Mutex;

pub fn bump(m: &Mutex<u32>) -> u32 {
    let mut g = m.lock().unwrap_or_else(|e| e.into_inner());
    *g += 1;
    *g
}

pub fn strict(m: &Mutex<u32>) -> u32 {
    // ari-lint: allow(poison-tolerance): fixture — panic-on-poison is the intended abort here.
    *m.lock().unwrap()
}
