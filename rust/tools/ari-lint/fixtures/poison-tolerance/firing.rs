//! ari-lint fixture: lock/wait results consumed by unwrap/expect must
//! fire poison-tolerance.  Lexed as `rust/src/util/counter.rs` by the
//! self-test; never compiled.

use crate::util::sim::{Condvar, Mutex};

pub fn bump(m: &Mutex<u32>) -> u32 {
    let mut g = m.lock().unwrap();
    *g += 1;
    *g
}

pub fn wait_ready(m: &Mutex<bool>, cv: &Condvar) {
    let g = m.lock().unwrap_or_else(|e| e.into_inner());
    let _g = cv.wait(g).expect("ready");
}
