//! ari-lint fixture: arms every fixture fault point.  Lexed as
//! `rust/tests/fault_arm.rs` by the self-test; never compiled.

#[test]
fn arms_every_point() {
    let _a = "exec-error:1.0:2";
    let _b = "queue-stall:1.0:4";
}
