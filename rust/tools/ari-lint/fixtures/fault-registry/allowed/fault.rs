//! ari-lint fixture: a fault registry consistent with its taxonomy
//! table, every point armed.  Lexed as `rust/src/util/fault.rs` by the
//! self-test; never compiled.

/// Fault point: the backend returns a typed error.
pub const EXEC_ERROR: &str = "exec-error";
/// Fault point: a queue operation sleeps before taking the lock.
pub const QUEUE_STALL: &str = "queue-stall";

/// Every fault point this fixture defines.
pub const POINTS: &[&str] = &[EXEC_ERROR, QUEUE_STALL];
