//! ari-lint fixture: a fault registry that drifted from its taxonomy
//! table — `worker-death` is undocumented AND unarmed, and the doc
//! table lists a phantom `exec-haunt`.  Lexed as
//! `rust/src/util/fault.rs` by the self-test; never compiled.

/// Fault point: the backend returns a typed error.
pub const EXEC_ERROR: &str = "exec-error";
/// Fault point: a queue operation sleeps before taking the lock.
pub const QUEUE_STALL: &str = "queue-stall";
/// Fault point: a pool worker exits as if its thread died.
pub const WORKER_DEATH: &str = "worker-death";

/// Every fault point this fixture defines.
pub const POINTS: &[&str] = &[EXEC_ERROR, QUEUE_STALL, WORKER_DEATH];
