//! ari-lint fixture: arms only two of the three fixture fault points —
//! `worker-death` stays unarmed.  Lexed as `rust/tests/fault_arm.rs` by
//! the self-test; never compiled.

#[test]
fn arms_some_points() {
    let _a = "exec-error:1.0:2";
    let _b = "queue-stall:1.0:4";
}
