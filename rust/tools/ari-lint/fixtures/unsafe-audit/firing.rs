//! ari-lint fixture: `unsafe` without a SAFETY justification must fire
//! unsafe-audit.  Lexed as `rust/src/tensor/fixture.rs` by the
//! self-test; never compiled.

pub fn read_first(v: &[u8]) -> u8 {
    unsafe { *v.as_ptr() }
}

pub unsafe fn raw_add(p: *mut u32) {
    *p += 1;
}
