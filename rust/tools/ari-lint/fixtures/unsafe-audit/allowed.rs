//! ari-lint fixture: SAFETY comments and `# Safety` doc sections
//! satisfy unsafe-audit, and `unsafe fn(..)` pointer types are exempt.
//! Lexed as `rust/src/tensor/fixture.rs` by the self-test; never
//! compiled.

/// Increment through a raw pointer.
///
/// # Safety
/// `p` must be non-null, properly aligned, and valid for reads and
/// writes.
pub unsafe fn raw_add(p: *mut u32) {
    *p += 1;
}

pub fn read_first(v: &[u8]) -> u8 {
    assert!(!v.is_empty());
    // SAFETY: the assert above guarantees the slice is non-empty, so
    // reading the first element is in bounds.
    unsafe { *v.as_ptr() }
}

/// An erased hook — the `unsafe fn` here is a pointer *type*, not a
/// declaration, and needs no SAFETY comment of its own.
pub type ExecHook = unsafe fn(*mut ()) -> u32;
