//! ari-lint fixture: every raw concurrency primitive here must fire
//! sim-discipline.  Lexed as `rust/src/util/worker.rs` by the
//! self-test; never compiled.

use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};

pub fn start(shared: Arc<Mutex<u32>>, cv: Condvar, tx: mpsc::Sender<u32>) {
    let _h = std::thread::spawn(move || {
        let _ = (shared, cv, tx);
    });
}
