//! ari-lint fixture: justified allows suppress sim-discipline.
//! Lexed as `rust/src/util/worker.rs` by the self-test; never compiled.

// ari-lint: allow(sim-discipline): fixture — a const-initialised registry needs the std Mutex.
use std::sync::Mutex;

static REGISTRY: Mutex<Vec<u32>> = Mutex::new(Vec::new());

pub fn start() {
    // ari-lint: allow(sim-discipline): fixture — real-thread stress leg outside the model.
    let h = std::thread::spawn(|| {
        REGISTRY.lock().unwrap_or_else(|e| e.into_inner()).push(1);
    });
    let _ = h.join();
}
