//! ari-lint fixture: allocation tokens inside a manifest-listed fn must
//! fire no-alloc-hot-path; unlisted fns may allocate freely.  Lexed as
//! `rust/src/coordinator/hot.rs` by the self-test (manifest lists only
//! `hot_fn`); never compiled.

pub fn hot_fn(out: &mut Vec<u32>) {
    let scratch = Vec::new();
    out.extend(scratch);
    let boxed = Box::new(0u32);
    out.push(*boxed);
}

pub fn cold_fn() -> Vec<String> {
    vec![format!("cold code may allocate")]
}
