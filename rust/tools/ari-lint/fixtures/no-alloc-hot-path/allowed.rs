//! ari-lint fixture: a clean scratch-reuse hot fn passes, and a
//! justified allow suppresses the one allocating line.  Lexed as
//! `rust/src/coordinator/hot.rs` by the self-test (manifest lists
//! `hot_fn` and `hot_fn_logged`); never compiled.

pub fn hot_fn(out: &mut Vec<u32>, scratch: &mut Vec<u32>) {
    scratch.clear();
    out.extend(scratch.drain(..));
}

pub fn hot_fn_logged(out: &mut Vec<u32>) -> String {
    out.clear();
    // ari-lint: allow(no-alloc-hot-path): fixture — the error path allocates only on failure.
    format!("drained {}", out.len())
}
