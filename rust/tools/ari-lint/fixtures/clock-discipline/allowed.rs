//! ari-lint fixture: a justified allow suppresses clock-discipline, and
//! `#[cfg(test)]` code is exempt.  Lexed as
//! `rust/src/server/clockfix.rs` by the self-test; never compiled.

use std::time::Instant;

pub fn stamp() -> Instant {
    // ari-lint: allow(clock-discipline): fixture — the ServeClock impl itself reads the real clock.
    Instant::now()
}

#[cfg(test)]
mod tests {
    #[test]
    fn test_code_reads_the_clock_freely() {
        let _ = std::time::Instant::now();
    }
}
