//! ari-lint fixture: raw clock reads in the serving core must fire
//! clock-discipline.  Lexed as `rust/src/server/clockfix.rs` by the
//! self-test; never compiled.

use std::time::{Instant, SystemTime};

pub fn poll() -> Instant {
    let _wall = SystemTime::now();
    Instant::now()
}
