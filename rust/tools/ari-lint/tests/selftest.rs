//! ari-lint self-tests, in the spirit of PR 6's self-checked invariant
//! machinery: the fixture corpus proves every lint both fires and is
//! suppressible via a justified allow; the mutation tests prove the
//! linter guards the *real* tree (deleting a SAFETY comment or
//! re-introducing a raw `Mutex` produces findings, i.e. fails `make
//! lint`); the staleness test pins `hotpath.txt` to actual function
//! definitions so renames cannot silently drop hot-path coverage.

use ari_lint::{
    parse_manifest, run, Input, ManifestEntry, Report, CLOCK_DISCIPLINE, FAULT_REGISTRY, NO_ALLOC_HOT_PATH,
    POISON_TOLERANCE, SIM_DISCIPLINE, UNSAFE_AUDIT,
};

/// Repo root, resolved from this crate's manifest dir.
const ROOT: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../../..");

fn read_repo(rel: &str) -> String {
    let path = format!("{ROOT}/{rel}");
    std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("read {path}: {e}"))
}

fn lint_one(path: &str, src: &str) -> Report {
    let files = vec![(path.to_string(), src.to_string())];
    run(&Input { files, robustness_md: None, manifest: Vec::new() })
}

fn count(r: &Report, lint: &str) -> usize {
    r.findings.iter().filter(|f| f.lint == lint).count()
}

fn entry(file: &str, func: &str) -> ManifestEntry {
    ManifestEntry { file: file.to_string(), func: func.to_string() }
}

/// Lint a fault-registry fixture tree: a fault.rs, an arming test file,
/// and a ROBUSTNESS.md, at their real repo-relative paths.
fn lint_fault_tree(fault: &str, arm: &str, md: &str) -> Report {
    let input = Input {
        files: vec![
            ("rust/src/util/fault.rs".to_string(), fault.to_string()),
            ("rust/tests/fault_arm.rs".to_string(), arm.to_string()),
        ],
        robustness_md: Some(("docs/ROBUSTNESS.md".to_string(), md.to_string())),
        manifest: Vec::new(),
    };
    run(&input)
}

// ------------------------------------------------------------------
// Fixture corpus: one firing and one allowed snippet per lint.
// ------------------------------------------------------------------

#[test]
fn sim_discipline_fixture_fires() {
    let r = lint_one("rust/src/util/worker.rs", include_str!("../fixtures/sim-discipline/firing.rs"));
    assert_eq!(count(&r, SIM_DISCIPLINE), 4, "{:?}", r.findings);
    assert_eq!(r.findings.len(), 4, "{:?}", r.findings);
    assert!(r.suppressions.is_empty());
}

#[test]
fn sim_discipline_fixture_allowed() {
    let r = lint_one("rust/src/util/worker.rs", include_str!("../fixtures/sim-discipline/allowed.rs"));
    assert!(r.findings.is_empty(), "{:?}", r.findings);
    assert_eq!(r.suppressions.len(), 2, "{:?}", r.suppressions);
    assert!(r.suppressions.iter().all(|s| s.lint == SIM_DISCIPLINE && !s.justification.is_empty()));
}

#[test]
fn clock_discipline_fixture_fires() {
    let r = lint_one("rust/src/server/clockfix.rs", include_str!("../fixtures/clock-discipline/firing.rs"));
    assert_eq!(count(&r, CLOCK_DISCIPLINE), 2, "{:?}", r.findings);
    assert_eq!(r.findings.len(), 2, "{:?}", r.findings);
}

#[test]
fn clock_discipline_fixture_allowed() {
    let r = lint_one("rust/src/server/clockfix.rs", include_str!("../fixtures/clock-discipline/allowed.rs"));
    assert!(r.findings.is_empty(), "{:?}", r.findings);
    assert_eq!(r.suppressions.len(), 1, "{:?}", r.suppressions);
}

#[test]
fn clock_discipline_ignores_files_outside_the_serving_core() {
    // The same raw clock reads are fine in, say, util or benches.
    let r = lint_one("rust/src/util/clockfix.rs", include_str!("../fixtures/clock-discipline/firing.rs"));
    assert!(r.findings.is_empty(), "{:?}", r.findings);
}

#[test]
fn poison_tolerance_fixture_fires() {
    let r = lint_one("rust/src/util/counter.rs", include_str!("../fixtures/poison-tolerance/firing.rs"));
    assert_eq!(count(&r, POISON_TOLERANCE), 2, "{:?}", r.findings);
    assert_eq!(r.findings.len(), 2, "{:?}", r.findings);
}

#[test]
fn poison_tolerance_fixture_allowed() {
    let r = lint_one("rust/src/util/counter.rs", include_str!("../fixtures/poison-tolerance/allowed.rs"));
    assert!(r.findings.is_empty(), "{:?}", r.findings);
    assert_eq!(r.suppressions.len(), 1, "{:?}", r.suppressions);
}

#[test]
fn no_alloc_fixture_fires() {
    let src = include_str!("../fixtures/no-alloc-hot-path/firing.rs");
    let input = Input {
        files: vec![("rust/src/coordinator/hot.rs".to_string(), src.to_string())],
        robustness_md: None,
        manifest: vec![entry("rust/src/coordinator/hot.rs", "hot_fn")],
    };
    let r = run(&input);
    // `hot_fn` allocates twice (Vec::new, Box::new); the unlisted
    // `cold_fn` allocates too and must NOT be flagged.
    assert_eq!(count(&r, NO_ALLOC_HOT_PATH), 2, "{:?}", r.findings);
    assert_eq!(r.findings.len(), 2, "{:?}", r.findings);
}

#[test]
fn no_alloc_fixture_allowed() {
    let src = include_str!("../fixtures/no-alloc-hot-path/allowed.rs");
    let input = Input {
        files: vec![("rust/src/coordinator/hot.rs".to_string(), src.to_string())],
        robustness_md: None,
        manifest: vec![
            entry("rust/src/coordinator/hot.rs", "hot_fn"),
            entry("rust/src/coordinator/hot.rs", "hot_fn_logged"),
        ],
    };
    let r = run(&input);
    assert!(r.findings.is_empty(), "{:?}", r.findings);
    assert_eq!(r.suppressions.len(), 1, "{:?}", r.suppressions);
}

#[test]
fn unsafe_audit_fixture_fires() {
    let r = lint_one("rust/src/tensor/fixture.rs", include_str!("../fixtures/unsafe-audit/firing.rs"));
    assert_eq!(count(&r, UNSAFE_AUDIT), 2, "{:?}", r.findings);
    assert_eq!(r.findings.len(), 2, "{:?}", r.findings);
}

#[test]
fn unsafe_audit_fixture_allowed() {
    let r = lint_one("rust/src/tensor/fixture.rs", include_str!("../fixtures/unsafe-audit/allowed.rs"));
    assert!(r.findings.is_empty(), "{:?}", r.findings);
}

#[test]
fn fault_registry_fixture_fires() {
    let fault = include_str!("../fixtures/fault-registry/firing/fault.rs");
    let arm = include_str!("../fixtures/fault-registry/firing/arm_test.rs");
    let md = include_str!("../fixtures/fault-registry/firing/ROBUSTNESS.md");
    let r = lint_fault_tree(fault, arm, md);
    // Drifted three ways: `worker-death` is undocumented AND unarmed,
    // and the doc table lists a phantom `exec-haunt`.
    assert_eq!(count(&r, FAULT_REGISTRY), 3, "{:?}", r.findings);
    assert_eq!(r.findings.iter().filter(|f| f.msg.contains("worker-death")).count(), 2, "{:?}", r.findings);
    assert_eq!(r.findings.iter().filter(|f| f.msg.contains("exec-haunt")).count(), 1, "{:?}", r.findings);
}

#[test]
fn fault_registry_fixture_allowed() {
    let fault = include_str!("../fixtures/fault-registry/allowed/fault.rs");
    let arm = include_str!("../fixtures/fault-registry/allowed/arm_test.rs");
    let md = include_str!("../fixtures/fault-registry/allowed/ROBUSTNESS.md");
    let r = lint_fault_tree(fault, arm, md);
    assert!(r.findings.is_empty(), "{:?}", r.findings);
}

// ------------------------------------------------------------------
// Mutation tests against the real tree: the contracts the issue names
// must actually be guarded, not just demonstrable on fixtures.
// ------------------------------------------------------------------

#[test]
fn mutation_deleting_safety_comments_fails_the_lint() {
    let rel = "rust/src/tensor/mod.rs";
    let src = read_repo(rel);
    let base = count(&lint_one(rel, &src), UNSAFE_AUDIT);
    let mutated = src.replace("SAFETY:", "NOTE:").replace("# Safety", "# Notes");
    assert_ne!(src, mutated, "tensor/mod.rs has no SAFETY comments left to mutate");
    let after = count(&lint_one(rel, &mutated), UNSAFE_AUDIT);
    assert!(after > base, "deleting SAFETY comments must add unsafe-audit findings (got {base} -> {after})");
    assert!(after > 0);
}

#[test]
fn mutation_reintroducing_a_raw_mutex_fails_the_lint() {
    let rel = "rust/src/util/queue.rs";
    let src = read_repo(rel);
    let base = count(&lint_one(rel, &src), SIM_DISCIPLINE);
    let mutated = format!("use std::sync::Mutex as Sneaky;\n{src}");
    let after = count(&lint_one(rel, &mutated), SIM_DISCIPLINE);
    assert_eq!(after, base + 1, "a re-introduced raw Mutex must add exactly one sim-discipline finding");
}

// ------------------------------------------------------------------
// Manifest staleness: every hotpath.txt entry must resolve to a real
// function definition in the current tree.
// ------------------------------------------------------------------

#[test]
fn hotpath_manifest_resolves_against_the_real_tree() {
    let manifest = parse_manifest(include_str!("../hotpath.txt")).expect("hotpath.txt parses");
    assert!(!manifest.is_empty(), "hotpath.txt lists no functions");
    let mut input = Input::default();
    for e in &manifest {
        if !input.files.iter().any(|(p, _)| p == &e.file) {
            input.files.push((e.file.clone(), read_repo(&e.file)));
        }
    }
    input.manifest = manifest;
    let r = run(&input);
    let stale: Vec<_> = r.findings.iter().filter(|f| f.msg.contains("manifest names")).collect();
    assert!(stale.is_empty(), "stale hotpath.txt entries: {stale:?}");
}
