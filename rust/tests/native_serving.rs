//! Native-backend ports of the server integration suite: the threaded
//! request loop end to end over the synthetic fixture, under both
//! escalation policies and both arrival modes.  Always runs — no
//! artifacts, no PJRT.

use ari::config::{AriConfig, Mode, ThresholdPolicy};
use ari::coordinator::{Cascade, CascadeSpec, EscalationPolicy};
use ari::runtime::{Backend, NativeBackend};
use ari::server::{run_serving, ServeOptions};

fn base_cfg() -> AriConfig {
    let mut cfg = AriConfig::default();
    cfg.dataset = "fashion_syn".into();
    cfg.mode = Mode::Fp;
    cfg.reduced_level = 10;
    cfg.threshold = ThresholdPolicy::MMax;
    cfg.batch_size = 32;
    cfg.requests = 256;
    cfg.batch_timeout_us = 1000;
    cfg
}

fn serve_with(cfg: &AriConfig, opts: ServeOptions) -> ari::server::ServeReport {
    let mut engine = NativeBackend::synthetic();
    let data = engine.eval_data(&cfg.dataset).unwrap();
    let n_calib = data.n / 2;
    let cascade = Cascade::calibrate(&mut engine, CascadeSpec::from_config(cfg), &data, n_calib).unwrap();
    run_serving(&mut engine, &cascade, cfg, &data, None, opts).unwrap()
}

#[test]
fn closed_loop_serves_every_request_exactly_once() {
    let cfg = base_cfg();
    let report = serve_with(&cfg, ServeOptions::default());
    assert_eq!(report.completions.len(), cfg.requests);
    let mut ids: Vec<u64> = report.completions.iter().map(|c| c.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), cfg.requests, "duplicate or missing request ids");
    assert!(report.accuracy > 0.7, "accuracy {} too low", report.accuracy);
    assert!(report.savings() > 0.2, "savings {} too low", report.savings());
}

#[test]
fn open_loop_poisson_also_completes() {
    let mut cfg = base_cfg();
    cfg.requests = 96;
    cfg.arrival_rate = 3000.0;
    let report = serve_with(&cfg, ServeOptions::default());
    assert_eq!(report.completions.len(), cfg.requests);
    // Open loop with a sane rate: mean latency should be bounded (batches
    // fire on deadline, 1 ms).
    assert!(report.mean_latency < std::time::Duration::from_secs(2));
}

#[test]
fn deferred_escalation_preserves_results() {
    let cfg = base_cfg();
    let imm = serve_with(&cfg, ServeOptions { escalation: EscalationPolicy::Immediate });
    let def = serve_with(&cfg, ServeOptions { escalation: EscalationPolicy::Deferred });
    assert_eq!(imm.completions.len(), def.completions.len());
    // Same rows escalate under both policies (same threshold, same data,
    // deterministic FP path) -> same escalation fraction and accuracy.
    assert!((imm.escalation_fraction - def.escalation_fraction).abs() < 1e-9);
    assert!((imm.accuracy - def.accuracy).abs() < 1e-9);
    // And the modelled energy agrees (per-inference accounting; the
    // metrics store energy as integer nanojoules, so each add_energy_uj
    // call truncates <1 nJ — the two policies make different numbers of
    // accounting calls, hence the small tolerance).
    assert!((imm.energy_uj - def.energy_uj).abs() < 0.1, "imm {} vs def {}", imm.energy_uj, def.energy_uj);
}

/// Regression: queue-wait metrics used to be recorded only on the
/// Immediate path, making `MetricsRegistry::report()` incomparable
/// across escalation policies.  Both policies must record exactly one
/// queue-wait sample per dispatched request — and, since the ingress
/// wait (submission → batcher enqueue) was split out of it, exactly one
/// net-wait sample too.
#[test]
fn queue_wait_recorded_under_both_policies() {
    let cfg = base_cfg();
    for esc in [EscalationPolicy::Immediate, EscalationPolicy::Deferred] {
        let report = serve_with(&cfg, ServeOptions { escalation: esc });
        assert_eq!(
            report.queue_wait_samples,
            cfg.requests as u64,
            "{esc:?} must record one queue-wait sample per request"
        );
        assert_eq!(
            report.net_wait_samples,
            cfg.requests as u64,
            "{esc:?} must record one ingress-wait sample per request"
        );
    }
}

/// Regression for the lossy shutdown check: the old serving loop's
/// `received >= n_requests && rx.try_recv().is_err()` exit *consumed* —
/// and silently dropped — any request `try_recv` happened to return, so
/// a flooded channel near shutdown could lose a request.  The
/// restructured loop pushes everything `try_recv` returns; flood the
/// channel (closed loop, no pacing, deadline-heavy batching) repeatedly
/// and assert conservation every time.
#[test]
fn shutdown_flood_never_drops_requests() {
    for round in 0..3u64 {
        let mut cfg = base_cfg();
        cfg.requests = 512;
        cfg.arrival_rate = 0.0;
        cfg.batch_timeout_us = 100;
        cfg.seed = 1000 + round;
        let report = serve_with(&cfg, ServeOptions::default());
        assert_eq!(report.completions.len(), cfg.requests, "round {round} lost requests");
        let mut ids: Vec<u64> = report.completions.iter().map(|c| c.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), cfg.requests, "round {round} duplicated or dropped ids");
    }
}

/// The padding counter covers every dispatch shape: with the compiled
/// batch at 32 and deadline-fired partial batches, padded_slots must be
/// consistent with what was served (n_batches * 32 - requests for a
/// 2-level Immediate session where only first-stage batches pad —
/// escalation chunks inside `infer_batch` are internal to the ladder).
#[test]
fn padded_slots_reported() {
    let mut cfg = base_cfg();
    cfg.requests = 40; // not a multiple of 32: the drain pads
    let report = serve_with(&cfg, ServeOptions::default());
    assert_eq!(report.completions.len(), 40);
    assert!(report.padded_slots > 0, "a 40-request session must pad at least one batch");
    assert_eq!(report.padded_slots % 8, 0, "padding is a whole number of empty slots: 32k - 40");
}

#[test]
fn tiny_batch_timeout_works() {
    let mut cfg = base_cfg();
    cfg.requests = 8;
    cfg.batch_size = 32; // compiled size; the batcher may fire partial batches
    cfg.batch_timeout_us = 1; // force per-request batches
    let report = serve_with(&cfg, ServeOptions::default());
    assert_eq!(report.completions.len(), 8);
}

#[test]
fn parity_with_full_reported_when_baseline_given() {
    let cfg = base_cfg();
    let mut engine = NativeBackend::synthetic();
    let data = engine.eval_data(&cfg.dataset).unwrap();
    let cascade = Cascade::calibrate(&mut engine, CascadeSpec::from_config(&cfg), &data, data.n / 2).unwrap();
    let full_v = engine
        .manifest()
        .variant(&cfg.dataset, cfg.mode.kind(), cfg.full_level, cfg.batch_size)
        .unwrap()
        .clone();
    let full = engine.run_dataset(&full_v, &data, cfg.seed as u32).unwrap();
    let report =
        run_serving(&mut engine, &cascade, &cfg, &data, Some(&full.pred), ServeOptions::default()).unwrap();
    let parity = report.full_parity.expect("parity must be reported");
    // Mmax guarantees parity on the calibration half; the serve half can
    // drift only on unseen low-margin rows.
    assert!(parity > 0.9, "full-model parity {parity} too low");
}
