//! Deterministic-schedule model checking for
//! `ari::util::queue::BoundedQueue` — the close contract pinned in the
//! queue's module docs, verified under **every** interleaving at small
//! bounds (2–3 threads, capacity 1–2, ≤6 ops) and under seeded random
//! schedules at larger ones.  Failing random schedules print a one-line
//! `ARI_REPLAY=<seed>` reproduction string.
//!
//! Compiled only when the sim harness is (dev/test builds or
//! `--features sim`); the suite also carries real-thread property tests
//! so the queue is exercised under genuine preemption, not just the
//! model scheduler.
#![cfg(any(debug_assertions, feature = "sim"))]

use std::sync::Arc;
// ari-lint: allow(sim-discipline): the stress legs below use real OS threads on
// purpose (genuine preemption); a plain std Mutex collects their results.
use std::sync::Mutex as PlainMutex;
use std::time::Duration;

use ari::util::proptest::{run, Config};
use ari::util::queue::BoundedQueue;
use ari::util::sim;

// ---------------------------------------------------------------------
// Exhaustive small-bound models (every interleaving, `complete`
// asserted).  A plain std mutex is safe for recording inside sim
// threads as long as it is never held across a scheduling point.
// ---------------------------------------------------------------------

/// Items enqueued before `close` are always delivered, FIFO, then
/// `None` — under every schedule of a cap-1 queue.
#[test]
fn exhaustive_items_before_close_always_delivered_fifo() {
    let report = sim::check_exhaustive(100_000, || {
        let q = Arc::new(BoundedQueue::new(1));
        let q2 = Arc::clone(&q);
        let producer = sim::spawn(move || {
            q2.push(1u32).unwrap();
            q2.push(2).unwrap();
            q2.close();
        });
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None, "closed and drained queue must report None");
        producer.join().unwrap();
    });
    assert!(report.complete, "state space must enumerate fully ({} schedules)", report.schedules);
}

/// A push racing `close` either delivers the item exactly once or hands
/// the exact item back — never both, never neither.
#[test]
fn exhaustive_close_racing_push_never_loses_or_duplicates() {
    let report = sim::check_exhaustive(100_000, || {
        let q = Arc::new(BoundedQueue::new(1));
        let result: Arc<PlainMutex<Option<Result<(), u32>>>> = Arc::new(PlainMutex::new(None));
        let q2 = Arc::clone(&q);
        let r2 = Arc::clone(&result);
        let pusher = sim::spawn(move || {
            let r = q2.push(7u32);
            *r2.lock().unwrap() = Some(r);
        });
        q.close();
        let mut popped = Vec::new();
        while let Some(v) = q.pop() {
            popped.push(v);
        }
        pusher.join().unwrap();
        match result.lock().unwrap().take().unwrap() {
            Ok(()) => assert_eq!(popped, vec![7], "accepted item must be delivered exactly once"),
            Err(item) => {
                assert_eq!(item, 7, "rejected push must hand the exact item back");
                assert!(popped.is_empty(), "an item must never be both returned and delivered");
            }
        }
    });
    assert!(report.complete, "state space must enumerate fully ({} schedules)", report.schedules);
}

/// A pusher blocked on a full queue always wakes on `close` and gets
/// its item back; the queued item is still delivered.
#[test]
fn exhaustive_close_wakes_blocked_pusher() {
    let report = sim::check_exhaustive(100_000, || {
        let q = Arc::new(BoundedQueue::new(1));
        q.push(5u32).unwrap();
        let q2 = Arc::clone(&q);
        let pusher = sim::spawn(move || {
            assert_eq!(q2.push(9), Err(9), "push against a full-then-closed queue must wake and reject");
        });
        q.close();
        assert_eq!(q.pop(), Some(5), "close never discards queued items");
        assert_eq!(q.pop(), None);
        pusher.join().unwrap();
    });
    assert!(report.complete, "state space must enumerate fully ({} schedules)", report.schedules);
}

/// A popper blocked on an empty queue always wakes: first on the push
/// (delivering the item), then on `close` (reporting `None`).  No
/// wakeup is lost under any schedule.
#[test]
fn exhaustive_close_wakes_blocked_popper() {
    let report = sim::check_exhaustive(100_000, || {
        let q = Arc::new(BoundedQueue::<u32>::new(1));
        let q2 = Arc::clone(&q);
        let popper = sim::spawn(move || {
            assert_eq!(q2.pop(), Some(3), "blocked pop must wake on push");
            assert_eq!(q2.pop(), None, "blocked pop must wake on close");
        });
        q.push(3).unwrap();
        q.close();
        popper.join().unwrap();
    });
    assert!(report.complete, "state space must enumerate fully ({} schedules)", report.schedules);
}

// ---------------------------------------------------------------------
// Random-schedule model at a larger bound (3 spawned threads).
// ---------------------------------------------------------------------

/// Two producers, a racing closer and a draining root: every item is
/// either delivered once or handed back once, delivered items keep
/// per-producer FIFO order.  `ARI_MODEL_SCHEDULES` raises the budget
/// in CI; failures print `ARI_REPLAY=<seed>`.
#[test]
fn random_schedules_conserve_items_across_close() {
    sim::check_random(sim::schedule_budget(300), 0xA5E1_D00D, || {
        let q = Arc::new(BoundedQueue::new(2));
        let rejected: Arc<PlainMutex<Vec<u32>>> = Arc::new(PlainMutex::new(Vec::new()));
        let mut producers = Vec::new();
        for p in 0..2u32 {
            let q2 = Arc::clone(&q);
            let rej = Arc::clone(&rejected);
            producers.push(sim::spawn(move || {
                for k in 0..2u32 {
                    if let Err(item) = q2.push(p * 10 + k) {
                        rej.lock().unwrap().push(item);
                    }
                }
            }));
        }
        let qc = Arc::clone(&q);
        let closer = sim::spawn(move || qc.close());
        let mut delivered = Vec::new();
        while let Some(v) = q.pop() {
            delivered.push(v);
        }
        for t in producers {
            t.join().unwrap();
        }
        closer.join().unwrap();
        let rejected = rejected.lock().unwrap();
        let mut all: Vec<u32> = delivered.iter().chain(rejected.iter()).copied().collect();
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 10, 11], "delivered {delivered:?} + rejected {rejected:?} must cover every item once");
        for base in [0u32, 10] {
            let seq: Vec<u32> = delivered.iter().copied().filter(|v| v / 10 == base / 10).collect();
            assert!(seq.windows(2).all(|w| w[0] < w[1]), "per-producer FIFO violated: {delivered:?}");
        }
    });
}

// ---------------------------------------------------------------------
// Real-thread property tests (satellite): genuine preemption, no sim
// schedule.
// ---------------------------------------------------------------------

/// Linearisability smoke under real threads: 3 producers × 50 items
/// through a cap-4 queue into 2 consumers; every item arrives exactly
/// once.
#[test]
fn real_threads_linearisability_smoke() {
    let q = Arc::new(BoundedQueue::new(4));
    let got: Arc<PlainMutex<Vec<u32>>> = Arc::new(PlainMutex::new(Vec::new()));
    let mut producers = Vec::new();
    for p in 0..3u32 {
        let q2 = Arc::clone(&q);
        // ari-lint: allow(sim-discipline): real-thread stress leg under genuine preemption.
        producers.push(std::thread::spawn(move || {
            for k in 0..50u32 {
                q2.push(p * 1000 + k).unwrap();
            }
        }));
    }
    let mut consumers = Vec::new();
    for _ in 0..2 {
        let q2 = Arc::clone(&q);
        let got2 = Arc::clone(&got);
        // ari-lint: allow(sim-discipline): real-thread stress leg under genuine preemption.
        consumers.push(std::thread::spawn(move || {
            while let Some(v) = q2.pop() {
                got2.lock().unwrap().push(v);
            }
        }));
    }
    for h in producers {
        h.join().unwrap();
    }
    q.close();
    for h in consumers {
        h.join().unwrap();
    }
    let mut all = got.lock().unwrap().clone();
    all.sort_unstable();
    let want: Vec<u32> = (0..3).flat_map(|p| (0..50).map(move |k| p * 1000 + k)).collect();
    assert_eq!(all, want);
}

/// Close-while-full under real threads: every pusher blocked on a full
/// queue wakes and gets its own item back; the resident item survives.
#[test]
fn real_threads_close_while_full_wakes_every_pusher() {
    let q = Arc::new(BoundedQueue::new(1));
    q.push(0u32).unwrap();
    let mut pushers = Vec::new();
    for i in 1..=4u32 {
        let q2 = Arc::clone(&q);
        // ari-lint: allow(sim-discipline): real-thread stress leg under genuine preemption.
        pushers.push(std::thread::spawn(move || q2.push(i)));
    }
    // Give the pushers time to genuinely block on the full queue.
    std::thread::sleep(Duration::from_millis(30));
    q.close();
    let mut rejected: Vec<u32> = pushers.into_iter().map(|h| h.join().unwrap().unwrap_err()).collect();
    rejected.sort_unstable();
    assert_eq!(rejected, vec![1, 2, 3, 4]);
    assert_eq!(q.pop(), Some(0));
    assert_eq!(q.pop(), None);
}

/// Randomised close-mid-stream property under real threads: the
/// delivered ids form a prefix, the rejected ids the exact suffix, and
/// together they cover the sequence once.  Failures print an
/// `ARI_REPLAY=<seed>/<stream>` reproduction string.
#[test]
fn real_threads_property_close_splits_prefix_suffix() {
    run(Config::cases(8), |rng| {
        let cap = 1 + rng.below(2) as usize;
        let n_items = 1 + rng.below(40) as u32;
        let cut = rng.below(n_items as u64 + 1) as usize;
        let q = Arc::new(BoundedQueue::new(cap));
        let q2 = Arc::clone(&q);
        // ari-lint: allow(sim-discipline): real-thread stress leg under genuine preemption.
        let producer = std::thread::spawn(move || {
            let mut rejected = Vec::new();
            for k in 0..n_items {
                if let Err(item) = q2.push(k) {
                    rejected.push(item);
                }
            }
            rejected
        });
        let mut delivered = Vec::new();
        for _ in 0..cut {
            match q.pop() {
                Some(v) => delivered.push(v),
                None => break,
            }
        }
        q.close();
        while let Some(v) = q.pop() {
            delivered.push(v);
        }
        let rejected = producer.join().unwrap();
        let m = delivered.len() as u32;
        assert_eq!(delivered, (0..m).collect::<Vec<_>>(), "delivered ids must be the FIFO prefix");
        assert_eq!(rejected, (m..n_items).collect::<Vec<_>>(), "rejected ids must be the exact suffix");
    });
}
