//! Mutation testing for the model suites: each test re-introduces one
//! historical serving-core bug as a test-only fault
//! (`ari::util::sim::fault`) and proves the *same* invariant check the
//! model suites run (`tests/model_common/mod.rs`) fails against it —
//! so a regression in the checks themselves cannot go unnoticed.
//!
//! The faults, and the bugs they re-encode:
//!
//! * `lossy-shutdown-drain` — the batching loop's shutdown paths used
//!   to exit without flushing, dropping in-flight requests;
//! * `sc-key-reuse` — escalation flushes used to share one SC chunk
//!   key instead of drawing fresh ones;
//! * `padded-slots-first-stage-only` — `padded_slots` used to count
//!   first-stage padding only, missing escalation flushes;
//! * `unchunked-drain` — the batcher's shutdown drain used to return
//!   arbitrarily large batches, exceeding the compiled batch size;
//! * `lost-completion` — a batch that exhausted its execute retries
//!   used to vanish without completions, silently losing its requests
//!   instead of accounting them as `Failed`.
//!
//! Every test holds a `FaultGuard`, which serialises fault-injection
//! through a process-wide lock; this suite is its own test binary so
//! the guards cannot interfere with the clean model suites.  Expect
//! `ARI_REPLAY=...` lines in this suite's stderr: they come from the
//! *deliberately failing* model runs.
#![cfg(any(debug_assertions, feature = "sim"))]

mod model_common;

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::Duration;

use ari::runtime::NativeBackend;
use ari::util::sim;
use model_common::{
    assert_conservation_under_execute_failure, assert_drain_chunked, assert_padding_double_entry,
    assert_sc_keys_unique, escalate_all_fixture, run_sim_serving_model,
};

/// True when `f` panics (i.e. the invariant check fired).
fn check_fails(f: impl FnOnce()) -> bool {
    catch_unwind(AssertUnwindSafe(f)).is_err()
}

/// The conservation model must fail when the shutdown flush is lost:
/// 5 requests at batch 4 always leave one request in the batcher at
/// shutdown, and the faulted loop drops it on every schedule.
#[test]
fn conservation_model_catches_lossy_shutdown_drain() {
    let mut engine = NativeBackend::synthetic();
    let data = engine.eval_data("fashion_syn").unwrap();
    let model = |schedules: u64| {
        sim::check_random(schedules, 0x10ad_bea7, || {
            run_sim_serving_model(&data, 5, 4, Duration::from_millis(10), false);
        });
    };
    model(3); // sanity: the model passes while the fault is off
    let _fault = sim::FaultGuard::enable("lossy-shutdown-drain");
    assert!(check_fails(|| model(3)), "conservation model must catch the lossy shutdown drain");
}

/// The SC-key uniqueness model must fail when escalation flushes pin
/// their key instead of drawing fresh chunk ids.
#[test]
fn sc_key_model_catches_key_reuse() {
    let mut engine = NativeBackend::synthetic();
    let (ladder, data) = escalate_all_fixture(&mut engine);
    assert_sc_keys_unique(&mut engine, &ladder, &data); // sanity: passes clean
    let _fault = sim::FaultGuard::enable("sc-key-reuse");
    assert!(
        check_fails(|| assert_sc_keys_unique(&mut engine, &ladder, &data)),
        "SC-key model must catch flush-key reuse"
    );
}

/// The padding double-entry model must fail when flush-side padding
/// goes uncounted (the pre-fix first-stage-only accounting).
#[test]
fn padding_model_catches_first_stage_only_accounting() {
    let mut engine = NativeBackend::synthetic();
    let (ladder, data) = escalate_all_fixture(&mut engine);
    assert_padding_double_entry(&mut engine, &ladder, &data); // sanity: passes clean
    let _fault = sim::FaultGuard::enable("padded-slots-first-stage-only");
    assert!(
        check_fails(|| assert_padding_double_entry(&mut engine, &ladder, &data)),
        "padding model must catch first-stage-only accounting"
    );
}

/// The drain-chunking model must fail when the shutdown drain returns
/// one oversized batch.
#[test]
fn drain_model_catches_unchunked_drain() {
    assert_drain_chunked(2, 5); // sanity: passes clean
    let _fault = sim::FaultGuard::enable("unchunked-drain");
    assert!(check_fails(|| assert_drain_chunked(2, 5)), "drain model must catch the unchunked shutdown drain");
}

/// The exactly-one-completion model must fail when a batch that
/// exhausted its retries drops its completion records instead of
/// accounting every request as `Failed`.  Failing execute call 0 puts
/// the whole first batch on the `fail_batch` path, so the faulted run
/// loses 20 completions.
#[test]
fn conservation_model_catches_lost_completions() {
    assert_conservation_under_execute_failure(0); // sanity: passes clean
    let _fault = sim::FaultGuard::enable("lost-completion");
    assert!(
        check_fails(|| assert_conservation_under_execute_failure(0)),
        "completion-conservation model must catch dropped Failed completions"
    );
}
