//! Shared fixtures and invariant checks for the model suites
//! (`model_queue` / `model_pool` / `model_server` / `model_mutations`).
//!
//! Each invariant lives here exactly once so the mutation suite can
//! prove that the *same* check the model suites run fails when a
//! historical bug is re-introduced via `sim::fault`.
#![allow(dead_code)] // each test crate uses a subset of these helpers

use std::sync::Arc;
// ari-lint: allow(sim-discipline): invariant checkers collect results from real
// stress threads; a plain std Mutex keeps them independent of the sim scheduler.
use std::sync::Mutex as PlainMutex;
use std::time::{Duration, Instant};

use ari::config::{Mode, ThresholdPolicy};
use ari::coordinator::{Batcher, BatcherPolicy, ControlPolicy, Ladder, LadderSpec};
use ari::data::EvalData;
use ari::metrics::ControlEvent;
use ari::runtime::{Backend, FlakyBackend, NativeBackend};
use ari::server::model::{drive_deferred, drive_deferred_controlled, drive_deferred_with};
use ari::server::{batching_loop, CompletionOutcome, Heartbeat, Request, RobustnessPolicy, ServeClock, StagedBatch};
use ari::util::queue::BoundedQueue;
use ari::util::sim;

/// Virtual clock for driving [`batching_loop`] under the sim harness:
/// `now` is a fixed origin plus the scheduler's virtual nanoseconds, so
/// batcher deadlines fire deterministically.
pub struct VClock {
    pub t0: Instant,
}

impl ServeClock for VClock {
    fn now(&self) -> Instant {
        self.t0 + Duration::from_nanos(sim::vnow())
    }
}

/// Drive the *real* [`batching_loop`] under the sim scheduler — sim
/// channel for arrivals, virtual clock for deadlines, a sim generator
/// thread and a sim consumer thread around the root running the loop —
/// and assert the serving pipeline's core invariants:
///
/// * **conservation**: every generated request is staged exactly once,
///   in arrival order (no request dropped at shutdown, none duplicated);
/// * **chunk bound**: every staged batch holds `1..=max_batch` items
///   (shutdown drains included);
/// * **staging**: each batch's row buffer is exactly
///   `items.len() * input_dim` floats.
///
/// Must be called from inside a schedule body ([`sim::check_random`] /
/// [`sim::check_exhaustive`]).
pub fn run_sim_serving_model(
    data: &EvalData,
    n_requests: u64,
    max_batch: usize,
    max_wait: Duration,
    paced: bool,
) {
    let t0 = Instant::now();
    let staged: Arc<BoundedQueue<StagedBatch>> = Arc::new(BoundedQueue::new(2));
    let empties: Arc<BoundedQueue<StagedBatch>> = Arc::new(BoundedQueue::new(2));
    for _ in 0..2 {
        let _ = empties.push(StagedBatch::default());
    }
    let (tx, rx) = sim::sim_channel::<Request>();
    let n_rows = data.n;
    let input_dim = data.input_dim;

    let gen = sim::spawn(move || {
        for id in 0..n_requests {
            if paced {
                sim::sleep(Duration::from_micros(700));
            }
            let submitted = t0 + Duration::from_nanos(sim::vnow());
            tx.send(Request { id, row: id as usize % n_rows, submitted, deadline: None });
        }
        // tx drops here: the loop sees Disconnected once drained.
    });

    let staged_c = Arc::clone(&staged);
    let empties_c = Arc::clone(&empties);
    let seen: Arc<PlainMutex<Vec<u64>>> = Arc::new(PlainMutex::new(Vec::new()));
    let seen_c = Arc::clone(&seen);
    let consumer = sim::spawn(move || {
        while let Some(mut batch) = staged_c.pop() {
            assert!(!batch.items.is_empty(), "empty batch staged");
            assert!(
                batch.items.len() <= max_batch,
                "staged batch of {} exceeds max_batch {max_batch}",
                batch.items.len()
            );
            assert_eq!(batch.x.len(), batch.items.len() * input_dim, "staged rows out of step with items");
            {
                let mut s = seen_c.lock().unwrap();
                s.extend(batch.items.iter().map(|p| p.payload.id));
            }
            batch.items.clear();
            batch.x.clear();
            let _ = empties_c.push(batch);
        }
    });

    let policy = BatcherPolicy::new(max_batch, max_wait);
    let hb = Heartbeat::default();
    batching_loop(rx, &VClock { t0 }, policy, n_requests as usize, data, &staged, &empties, &hb);
    assert!(hb.count() > 0, "batching loop must heartbeat");
    gen.join().unwrap();
    consumer.join().unwrap();

    let seen = seen.lock().unwrap();
    assert_eq!(
        seen.len(),
        n_requests as usize,
        "request lost or duplicated at shutdown: staged ids {:?}",
        &*seen
    );
    for (i, &id) in seen.iter().enumerate() {
        assert_eq!(id, i as u64, "arrival order violated: staged ids {:?}", &*seen);
    }
}

/// The batcher's shutdown-drain contract: every chunk yielded by
/// `drain_into` holds `1..=max_batch` items and the concatenation is
/// FIFO-complete.  The serving pipeline relies on the bound — a larger
/// chunk would exceed the ladder's compiled batch (`run_padded`'s
/// `n <= batch` contract) and underflow the padding accounting.
pub fn assert_drain_chunked(max_batch: usize, n_items: u32) {
    let mut batcher: Batcher<u32> = Batcher::new(BatcherPolicy::new(max_batch, Duration::from_millis(1)));
    for i in 0..n_items {
        batcher.push(i);
    }
    let mut out = Vec::new();
    let mut drained = Vec::new();
    while batcher.drain_into(&mut out).is_some() {
        assert!(!out.is_empty(), "drain_into fired an empty chunk");
        assert!(out.len() <= max_batch, "drained chunk of {} exceeds max_batch {max_batch}", out.len());
        drained.extend(out.iter().map(|p| p.payload));
    }
    assert_eq!(drained, (0..n_items).collect::<Vec<_>>(), "drain must conserve items in FIFO order");
}

/// A 3-level ladder whose fixed threshold (2.0) exceeds the margin
/// ceiling (top1−top2 of L2-normalised scores never tops sqrt(2)), so
/// every request escalates to the final stage — the shape that
/// exercises escalation flushes both mid-session and at shutdown.
pub fn escalate_all_fixture(engine: &mut NativeBackend) -> (Ladder, EvalData) {
    let data = engine.eval_data("fashion_syn").unwrap();
    let spec = LadderSpec {
        dataset: "fashion_syn".into(),
        mode: Mode::Fp,
        levels: vec![8, 12, 16],
        batch: 32,
        threshold: ThresholdPolicy::Fixed(2.0),
        seed: 7,
    };
    let ladder = Ladder::calibrate(engine, spec, &data, 64).unwrap();
    (ladder, data)
}

/// No SC batch key is ever reused: across first-stage dispatches and
/// escalation flushes (in-dispatch *and* shutdown), every key drawn
/// from the dispatcher's chunk counter is distinct.
pub fn assert_sc_keys_unique(engine: &mut dyn Backend, ladder: &Ladder, data: &EvalData) {
    // Three batches of 20 escalate-all rows: queue depth crosses the
    // compiled batch (32), forcing flushes inside dispatch as well as
    // the shutdown drain.
    let batches: Vec<Vec<usize>> = (0..3).map(|b| (0..20).map(|k| (b * 20 + k) % data.n).collect()).collect();
    let session = drive_deferred(engine, ladder, data, &batches).unwrap();
    assert!(session.flushes.len() >= 2, "fixture must exercise escalation flushes: {:?}", session.flushes);
    let mut keys = session.sc_keys.clone();
    keys.sort_unstable();
    let n = keys.len();
    keys.dedup();
    assert_eq!(keys.len(), n, "SC batch key reused: keys in draw order {:?}", session.sc_keys);
}

/// `padded_slots` double-entry: the metric must equal the padding
/// recomputed independently from the probe stream — `Σ (B₀ − n)` over
/// first-stage dispatches plus `Σ (B_s − take)` over escalation
/// flushes.  Catches both under- and over-counting on either path.
pub fn assert_padding_double_entry(engine: &mut dyn Backend, ladder: &Ladder, data: &EvalData) {
    // One 5-row escalate-all batch: pads 27 slots at the first stage
    // and 27 more at each of the two shutdown flushes.
    let session = drive_deferred(engine, ladder, data, &[(0..5).collect::<Vec<usize>>()]).unwrap();
    assert!(session.flushes.len() >= 2, "fixture must exercise escalation flushes: {:?}", session.flushes);
    let dispatch_pad: u64 = session.dispatches.iter().map(|&(n, b)| b - n).sum();
    let flush_pad: u64 =
        session.flushes.iter().map(|&(stage, take)| ladder.stages[stage as usize].variant.batch as u64 - take).sum();
    assert_eq!(
        session.padded_slots,
        dispatch_pad + flush_pad,
        "padded_slots out of double-entry balance (dispatch {dispatch_pad} + flush {flush_pad})"
    );
    assert_eq!(session.completions.len(), 5, "escalate-all session must still serve every request");
}

/// Exactly-one-completion conservation while the closed-loop
/// controller moves thresholds *mid-session*: an aggressive
/// load-adaptive policy (tighten on a single queued escalation, no
/// hold, queue signal only so the schedule is deterministic) steps the
/// tighten level between batches of an MMax ladder, so the accept
/// thresholds queued rows will be flushed under differ from the ones
/// they were staged under — and every submitted request must still
/// yield exactly one completion.
pub fn assert_conservation_under_threshold_churn() {
    let mut engine = NativeBackend::synthetic();
    let data = engine.eval_data("fashion_syn").unwrap();
    let spec = LadderSpec {
        dataset: "fashion_syn".into(),
        mode: Mode::Fp,
        levels: vec![8, 12, 16],
        batch: 32,
        threshold: ThresholdPolicy::MMax,
        seed: 7,
    };
    let ladder = Ladder::calibrate(&mut engine, spec, &data, 64).unwrap();
    let control = ControlPolicy {
        load_adaptive: true,
        queue_high: 1,
        queue_low: 0,
        p95_high_us: 0,
        hold: 1,
        step: 0.2,
        max_steps: 4,
        ..ControlPolicy::default()
    };
    let batches: Vec<Vec<usize>> = (0..6).map(|b| (0..10).map(|k| (b * 10 + k) % data.n).collect()).collect();
    let session = drive_deferred_controlled(
        &mut engine,
        &ladder,
        &data,
        &batches,
        RobustnessPolicy::default(),
        Some(control),
    )
    .unwrap();
    assert!(
        session.control_events.iter().any(|e| matches!(e, ControlEvent::Tighten { .. })),
        "fixture must actually move thresholds mid-session: {:?}",
        session.control_events
    );
    assert_eq!(session.completions.len(), 60, "every request needs exactly one completion under threshold churn");
    let mut ids: Vec<u64> = session.completions.iter().map(|c| c.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 60, "duplicate completion ids under threshold churn");
    assert!(
        session.completions.iter().all(|c| c.outcome != CompletionOutcome::Failed && c.pred >= 0),
        "no fault armed: every completion is a served prediction"
    );
}

/// Exactly-one-typed-completion under a mid-session execute failure:
/// run two 20-row escalate-all batches through the deferred dispatcher
/// over a [`FlakyBackend`] whose `execute` call `fail_call` errors
/// (with no retry budget, so the failing batch fails as a unit), and
/// assert that every submitted request still yields exactly one typed
/// completion — served or `Failed`, never lost, never duplicated.
/// The `lost-completion` mutation (see `model_mutations.rs`) drops the
/// failed batch's records and must make this check fail.
pub fn assert_conservation_under_execute_failure(fail_call: u64) {
    let mut native = NativeBackend::synthetic();
    let (ladder, data) = escalate_all_fixture(&mut native);
    let mut flaky = FlakyBackend::new(native).fail_on_call(fail_call);
    let batches: Vec<Vec<usize>> = (0..2).map(|b| (0..20).map(|k| (b * 20 + k) % data.n).collect()).collect();
    let session =
        drive_deferred_with(&mut flaky, &ladder, &data, &batches, RobustnessPolicy::default()).unwrap();
    assert_eq!(session.completions.len(), 40, "fail@{fail_call}: every request needs exactly one completion");
    let mut ids: Vec<u64> = session.completions.iter().map(|c| c.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 40, "fail@{fail_call}: duplicate completion ids");
    for c in &session.completions {
        match c.outcome {
            CompletionOutcome::Failed => assert_eq!(c.pred, -1, "fail@{fail_call}: failed completions are typed"),
            _ => assert!(c.pred >= 0, "fail@{fail_call}: served completions carry a prediction"),
        }
    }
    if fail_call < flaky.calls() {
        assert!(
            session.completions.iter().any(|c| c.outcome == CompletionOutcome::Failed),
            "fail@{fail_call}: the injected failure must surface as Failed completions"
        );
    }
}
