//! N-level ladder integration suite on the pure-rust backend — no
//! artifacts, no PJRT, runs in every checkout.
//!
//! Pins the two contracts the ladder generalisation must keep:
//!
//! 1. the 2-level configuration reproduces the original cascade's
//!    outputs **bit-identically** (same calibration seeds, same SC key
//!    salts, same gather/scatter chunking), and
//! 2. a 3-level FP ladder runs end to end — calibrate → infer_dataset →
//!    serving under both escalation policies — with coherent per-stage
//!    escalation fractions and `E = Σ_i f_i · E_i` energy accounting.
//!
//! Plus the serving-loop fixes that ride along: distinct SC keys for
//! distinct escalation flushes, and deterministic SC serving output for
//! a fixed seed.

use ari::config::{AriConfig, Mode, ThresholdPolicy};
use ari::coordinator::{Cascade, CascadeSpec, EscalationPolicy, Ladder, LadderBatch, LadderScratch, LadderSpec};
use ari::data::{EvalData, VariantRef};
use ari::margin::{accepts, Calibration};
use ari::runtime::{Backend, NativeBackend};
use ari::server::{run_serving_ladder, ServeOptions};

fn spec(dataset: &str, mode: Mode, levels: Vec<usize>, threshold: ThresholdPolicy) -> LadderSpec {
    LadderSpec { dataset: dataset.into(), mode, levels, batch: 32, threshold, seed: 0xA41 }
}

/// The original (PR 2) two-tier cascade dataset pass, reimplemented
/// verbatim as the bit-identity reference: chunk by the serving batch,
/// reduced pass keyed `[seed, chunk]`, escalated rows gathered in
/// full-batch chunks keyed `[seed ^ 0x5A5A_5A5A, chunk]`.
fn pr2_reference_dataset(
    engine: &mut dyn Backend,
    reduced: &VariantRef,
    full: &VariantRef,
    threshold: f64,
    data: &EvalData,
    seed: u32,
    sc: bool,
    batch: usize,
) -> (Vec<i32>, Vec<f32>) {
    let mut pred = Vec::with_capacity(data.n);
    let mut margin = Vec::with_capacity(data.n);
    let mut chunkid = 0u32;
    let mut lo = 0;
    while lo < data.n {
        let hi = (lo + batch).min(data.n);
        let n = hi - lo;
        let x = data.rows(lo, hi);
        let key = if sc { Some([seed, chunkid]) } else { None };
        let (red, _) = engine.run_padded(reduced, x, n, key).unwrap();
        let mut p = red.pred.clone();
        let mut m = red.margin.clone();
        let esc_rows: Vec<usize> = (0..n).filter(|&i| !accepts(red.margin[i], threshold)).collect();
        for chunk in esc_rows.chunks(full.batch) {
            let mut gathered = Vec::with_capacity(chunk.len() * data.input_dim);
            for &i in chunk {
                gathered.extend_from_slice(&x[i * data.input_dim..(i + 1) * data.input_dim]);
            }
            let fkey = key.map(|[a, b]| [a ^ 0x5A5A_5A5A, b]);
            let (fout, _) = engine.run_padded(full, &gathered, chunk.len(), fkey).unwrap();
            for (j, &i) in chunk.iter().enumerate() {
                p[i] = fout.pred[j];
                m[i] = fout.margin[j];
            }
        }
        pred.extend(p);
        margin.extend(m);
        lo = hi;
        chunkid += 1;
    }
    (pred, margin)
}

#[test]
fn three_level_fp_ladder_end_to_end() {
    let mut engine = NativeBackend::synthetic();
    let data = engine.eval_data("fashion_syn").unwrap();
    let ladder = Ladder::calibrate(
        &mut engine,
        spec("fashion_syn", Mode::Fp, vec![8, 12, 16], ThresholdPolicy::MMax),
        &data,
        data.n / 2,
    )
    .unwrap();
    assert_eq!(ladder.n_stages(), 3);
    // Stage energies ascend with resolution; only non-final stages carry
    // a calibration.
    assert!(ladder.stages[0].energy_uj < ladder.stages[1].energy_uj);
    assert!(ladder.stages[1].energy_uj < ladder.stages[2].energy_uj);
    assert!(ladder.stages[0].calibration.is_some());
    assert!(ladder.stages[1].calibration.is_some());
    assert!(ladder.stages[2].calibration.is_none());

    let (out, outputs) = ladder.infer_dataset(&mut engine, &data).unwrap();
    assert_eq!(out.pred.len(), data.n);
    assert_eq!(outputs.pred, out.pred);
    // Every row executes stage 0; deeper stages shrink monotonically.
    assert_eq!(out.stage_counts[0], data.n);
    assert!(out.stage_counts[1] <= data.n);
    assert!(out.stage_counts[2] <= out.stage_counts[1]);
    assert!(out.stage_counts[1] > 0, "FP8 must escalate some rows on the fixture");
    // stage_counts[s] == rows whose final stage is >= s.
    for s in 0..3 {
        let rows_at = out.stage.iter().filter(|&&st| st >= s).count();
        assert_eq!(rows_at, out.stage_counts[s], "stage {s} bookkeeping");
    }
    // Energy identity: E = Σ_i stage_counts[i] · E_i.
    let expect: f64 =
        out.stage_counts.iter().zip(&ladder.stages).map(|(&c, st)| c as f64 * st.energy_uj).sum();
    assert!((out.energy_uj - expect).abs() < 1e-9);
    // Paying reduced energy for most rows must beat always-full.
    assert!(ladder.realised_savings(&out) > 0.2, "savings {}", ladder.realised_savings(&out));
    // Mmax calibration against the final stage keeps accuracy at the
    // full model's level on the (deterministic FP) fixture.
    let acc = out.pred.iter().zip(&data.y).filter(|(a, b)| a == b).count() as f64 / data.n as f64;
    assert!(acc > 0.7, "ladder accuracy {acc} too low");
    // The per-stage report mentions every stage.
    let report = ladder.calibration_report();
    assert!(report.contains("stage 0 (FP8)"), "{report}");
    assert!(report.contains("stage 1 (FP12)"), "{report}");
    assert!(report.contains("stage 2 (FP16): final"), "{report}");
}

#[test]
fn three_level_ladder_serves_under_both_policies() {
    let mut engine = NativeBackend::synthetic();
    let data = engine.eval_data("fashion_syn").unwrap();
    let ladder = Ladder::calibrate(
        &mut engine,
        spec("fashion_syn", Mode::Fp, vec![8, 12, 16], ThresholdPolicy::MMax),
        &data,
        data.n / 2,
    )
    .unwrap();
    let mut cfg = AriConfig::default();
    cfg.levels = vec![8, 12, 16];
    cfg.reduced_level = 8;
    cfg.requests = 192;
    cfg.batch_timeout_us = 1000;
    let mut fractions = Vec::new();
    for esc in [EscalationPolicy::Immediate, EscalationPolicy::Deferred] {
        let report =
            run_serving_ladder(&mut engine, &ladder, &cfg, &data, None, ServeOptions { escalation: esc })
                .unwrap();
        assert_eq!(report.completions.len(), cfg.requests, "{esc:?} lost requests");
        assert_eq!(report.stage_fractions.len(), 3, "{esc:?} must report all stages");
        let sum: f64 = report.stage_fractions.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9, "{esc:?} stage fractions sum to {sum}");
        assert!(report.savings() > 0.0, "{esc:?} savings {}", report.savings());
        // Completion stage bookkeeping matches the escalated flag.
        for c in &report.completions {
            assert_eq!(c.escalated, c.stage > 0);
            assert!(c.stage < 3);
        }
        fractions.push(report.stage_fractions.clone());
    }
    // FP serving is deterministic: both policies route the same rows to
    // the same final stages.
    assert_eq!(fractions[0], fractions[1]);
}

/// The serving hot path's scratch/reuse variants must be bit-identical
/// to the allocating paths: `infer_batch_into` (recycled result +
/// gather scratch, output recycling through the engine) against
/// `infer_batch`, and `run_stage_scratch` (scratch-staged padding)
/// against `run_stage` — FP and SC, across reused-buffer batches.
#[test]
fn scratch_serving_path_bit_identical_to_allocating_path() {
    let mut engine = NativeBackend::synthetic();
    let data = engine.eval_data("fashion_syn").unwrap();
    for (mode, levels) in [(Mode::Fp, vec![8usize, 12, 16]), (Mode::Sc, vec![128, 512])] {
        let ladder = Ladder::calibrate(
            &mut engine,
            spec("fashion_syn", mode, levels, ThresholdPolicy::MMax),
            &data,
            128,
        )
        .unwrap();
        let mut scratch = LadderScratch::new();
        let mut reused = LadderBatch::empty();
        for (chunk, lo) in [(1u32, 0usize), (2, 32), (3, 64)] {
            let n = 32;
            let x = data.rows(lo, lo + n);
            let want = ladder.infer_batch(&mut engine, x, n, chunk).unwrap();
            ladder.infer_batch_into(&mut engine, x, n, chunk, &mut scratch, &mut reused).unwrap();
            assert_eq!(reused.pred, want.pred, "{mode:?} chunk={chunk}");
            assert_eq!(reused.margin, want.margin, "{mode:?} chunk={chunk}");
            assert_eq!(reused.stage, want.stage, "{mode:?} chunk={chunk}");
            assert_eq!(reused.stage_counts, want.stage_counts, "{mode:?} chunk={chunk}");
            assert_eq!(reused.first_pred, want.first_pred, "{mode:?} chunk={chunk}");
            assert_eq!(reused.energy_uj.to_bits(), want.energy_uj.to_bits(), "{mode:?} chunk={chunk}");
        }
        // Partial batch through the scratch stage runner: same zero
        // padding, same key, same truncation as run_stage/run_padded.
        let x = data.rows(0, 20);
        let (a, waste) = ladder.run_stage_scratch(&mut engine, 1, x, 20, 9, &mut scratch).unwrap();
        let b = ladder.run_stage(&mut engine, 1, x, 20, 9).unwrap();
        assert_eq!(waste, 12, "{mode:?}");
        assert_eq!(a.scores, b.scores, "{mode:?}");
        assert_eq!(a.pred, b.pred, "{mode:?}");
        assert_eq!(a.margin, b.margin, "{mode:?}");
        assert_eq!(a.batch, 20);
    }
}

#[test]
fn two_level_fp_ladder_bit_identical_to_pr2_cascade() {
    let mut engine = NativeBackend::synthetic();
    let data = engine.eval_data("fashion_syn").unwrap();
    let ladder = Ladder::calibrate(
        &mut engine,
        spec("fashion_syn", Mode::Fp, vec![8, 16], ThresholdPolicy::MMax),
        &data,
        256,
    )
    .unwrap();
    // Calibration reference: the original cascade ran the full model
    // with `seed` and the reduced model with `seed + 1`.
    let calib = EvalData {
        x: data.rows(0, 256).to_vec(),
        y: data.y[..256].to_vec(),
        n: 256,
        input_dim: data.input_dim,
    };
    let full_out = engine.run_dataset(&ladder.stages[1].variant, &calib, 0xA41).unwrap();
    let red_out = engine.run_dataset(&ladder.stages[0].variant, &calib, 0xA41 + 1).unwrap();
    let reference = Calibration::from_pairs(&full_out.pred, &red_out.pred, &red_out.margin);
    assert_eq!(ladder.stages[0].threshold.to_bits(), reference.threshold(ThresholdPolicy::MMax).to_bits());

    let (out, _) = ladder.infer_dataset(&mut engine, &data).unwrap();
    let (ref_pred, ref_margin) = pr2_reference_dataset(
        &mut engine,
        &ladder.stages[0].variant,
        &ladder.stages[1].variant,
        ladder.stages[0].threshold,
        &data,
        0xA41,
        false,
        32,
    );
    assert_eq!(out.pred, ref_pred, "2-level FP ladder must match the PR 2 cascade bit-identically");
    assert_eq!(out.margin, ref_margin);
}

#[test]
fn two_level_sc_ladder_bit_identical_to_pr2_cascade() {
    let mut engine = NativeBackend::synthetic();
    let data = engine.eval_data("fashion_syn").unwrap();
    let ladder = Ladder::calibrate(
        &mut engine,
        spec("fashion_syn", Mode::Sc, vec![128, 512], ThresholdPolicy::MMax),
        &data,
        256,
    )
    .unwrap();
    let (out, _) = ladder.infer_dataset(&mut engine, &data).unwrap();
    let (ref_pred, ref_margin) = pr2_reference_dataset(
        &mut engine,
        &ladder.stages[0].variant,
        &ladder.stages[1].variant,
        ladder.stages[0].threshold,
        &data,
        0xA41,
        true,
        32,
    );
    assert_eq!(out.pred, ref_pred, "2-level SC ladder must reuse the cascade's exact key schedule");
    assert_eq!(out.margin, ref_margin);
}

#[test]
fn cascade_wrapper_delegates_to_its_ladder() {
    let mut engine = NativeBackend::synthetic();
    let data = engine.eval_data("fashion_syn").unwrap();
    let mut cfg = AriConfig::default();
    cfg.reduced_level = 8;
    let cascade =
        Cascade::calibrate(&mut engine, CascadeSpec::from_config(&cfg), &data, 256).unwrap();
    assert_eq!(cascade.ladder.n_stages(), 2);
    assert_eq!(cascade.threshold.to_bits(), cascade.ladder.stages[0].threshold.to_bits());
    assert_eq!(cascade.e_reduced, cascade.ladder.stages[0].energy_uj);
    assert_eq!(cascade.e_full, cascade.ladder.stages[1].energy_uj);
    let (cb, _) = cascade.infer_dataset(&mut engine, &data).unwrap();
    let (lb, _) = cascade.ladder.infer_dataset(&mut engine, &data).unwrap();
    assert_eq!(cb.pred, lb.pred);
    assert_eq!(cb.margin, lb.margin);
    assert_eq!(cb.reduced_pred, lb.first_pred);
    assert_eq!(cb.energy_uj.to_bits(), lb.energy_uj.to_bits());
    let escalated: Vec<bool> = lb.stage.iter().map(|&s| s > 0).collect();
    assert_eq!(cb.escalated, escalated);
}

/// Regression for the SC key-reuse bug: the serving loop's final
/// deferred-escalation drain passed one chunk id to every flush, so
/// distinct full-model batches shared a stochastic-computing key and
/// produced *identical* noise streams.  Distinct flush ids must yield
/// distinct streams; the same id must stay reproducible.
#[test]
fn distinct_flush_keys_give_distinct_sc_streams() {
    let mut engine = NativeBackend::synthetic();
    let data = engine.eval_data("fashion_syn").unwrap();
    let ladder = Ladder::calibrate(
        &mut engine,
        spec("fashion_syn", Mode::Sc, vec![128, 512], ThresholdPolicy::MMax),
        &data,
        128,
    )
    .unwrap();
    let x = data.rows(0, 32).to_vec();
    let a = ladder.run_stage(&mut engine, 1, &x, 32, 7).unwrap();
    let b = ladder.run_stage(&mut engine, 1, &x, 32, 7).unwrap();
    assert_eq!(a.scores, b.scores, "same flush id must reproduce the same stream");
    let c = ladder.run_stage(&mut engine, 1, &x, 32, 8).unwrap();
    assert_ne!(a.scores, c.scores, "two flushes with fresh ids must not share a noise stream");
}

/// SC deferred serving is deterministic for a fixed seed: with a closed
/// loop and a deadline far beyond the test's runtime, every batch fires
/// on size, so batch composition — and therefore the chunk-id (SC key)
/// schedule, including the shutdown drain's per-flush ids — is exactly
/// reproducible across runs.  Combined with `kernel_parity.rs` (SC
/// forwards are bit-identical for any worker-pool size), this makes the
/// served output deterministic across pool sizes too.
#[test]
fn sc_deferred_serving_is_deterministic_for_fixed_seed() {
    let mut cfg = AriConfig::default();
    cfg.dataset = "fashion_syn".into();
    cfg.mode = Mode::Sc;
    cfg.reduced_level = 64;
    cfg.full_level = 512;
    cfg.batch_size = 32;
    cfg.requests = 160;
    cfg.batch_timeout_us = 5_000_000; // far beyond the test runtime
    cfg.arrival_rate = 0.0;
    let run = || {
        let mut engine = NativeBackend::synthetic();
        let data = engine.eval_data(&cfg.dataset).unwrap();
        let ladder =
            Ladder::calibrate(&mut engine, LadderSpec::from_config(&cfg), &data, data.n / 2).unwrap();
        let mut report = run_serving_ladder(
            &mut engine,
            &ladder,
            &cfg,
            &data,
            None,
            ServeOptions { escalation: EscalationPolicy::Deferred },
        )
        .unwrap();
        report.completions.sort_by_key(|c| c.id);
        report
    };
    let r1 = run();
    let r2 = run();
    assert_eq!(r1.completions.len(), cfg.requests);
    assert!(r1.escalation_fraction > 0.0, "L=64 must escalate some rows on the fixture");
    let key = |r: &ari::server::ServeReport| {
        r.completions.iter().map(|c| (c.id, c.row, c.pred, c.stage)).collect::<Vec<_>>()
    };
    assert_eq!(key(&r1), key(&r2), "SC deferred serving must be deterministic for a fixed seed");
}
