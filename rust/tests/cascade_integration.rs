//! Cascade-level integration on the pure-rust backend: calibration +
//! cascaded inference over the deterministic synthetic fixture suite —
//! no artifacts, no PJRT, runs in every checkout.  The key ARI
//! invariant — T = Mmax reproduces the full model's predictions on the
//! calibration set exactly — is checked here end to end.

use ari::config::{AriConfig, Mode, ThresholdPolicy};
use ari::coordinator::{Cascade, CascadeSpec};
use ari::data::VariantKind;
use ari::runtime::{Backend, NativeBackend};

fn spec(dataset: &str, mode: Mode, reduced: usize, threshold: ThresholdPolicy) -> CascadeSpec {
    let mut cfg = AriConfig::default();
    cfg.dataset = dataset.into();
    cfg.mode = mode;
    cfg.reduced_level = reduced;
    cfg.full_level = if mode == Mode::Sc { 4096 } else { 16 };
    cfg.threshold = threshold;
    cfg.batch_size = 32;
    CascadeSpec::from_config(&cfg)
}

#[test]
fn mmax_gives_exact_full_parity_on_calibration_set() {
    let mut engine = NativeBackend::synthetic();
    let data = engine.eval_data("fashion_syn").unwrap();
    let n_calib = 256;
    let cascade = Cascade::calibrate(
        &mut engine,
        spec("fashion_syn", Mode::Fp, 8, ThresholdPolicy::MMax),
        &data,
        n_calib,
    )
    .unwrap();
    // Run the cascade over the calibration rows and compare to the full
    // model run directly (the FP path is deterministic, so parity at
    // Mmax is exact by the paper's construction).
    let calib = ari::data::EvalData {
        x: data.rows(0, n_calib).to_vec(),
        y: data.y[..n_calib].to_vec(),
        n: n_calib,
        input_dim: data.input_dim,
    };
    let (served, _) = cascade.infer_dataset(&mut engine, &calib).unwrap();
    let full_v = engine.manifest().variant("fashion_syn", VariantKind::Fp, 16, 32).unwrap().clone();
    let full = engine.run_dataset(&full_v, &calib, 0).unwrap();
    assert_eq!(served.pred, full.pred, "ARI@Mmax must equal the full model on the calibration set");
}

#[test]
fn escalation_fraction_reasonable_and_energy_accounted() {
    let mut engine = NativeBackend::synthetic();
    let data = engine.eval_data("fashion_syn").unwrap();
    // FP8 over the whole eval split guarantees a non-empty
    // changed-element set on the fixture (FP10's change rate can be a
    // handful of rows).
    let n = data.n;
    let cascade =
        Cascade::calibrate(&mut engine, spec("fashion_syn", Mode::Fp, 8, ThresholdPolicy::MMax), &data, n)
            .unwrap();
    assert!(
        !cascade.calibration.changed_margins.is_empty(),
        "fixture must produce changed elements at FP8"
    );
    let (served, _) = cascade.infer_dataset(&mut engine, &data).unwrap();
    let f = Cascade::escalation_fraction(&served);
    assert!(f > 0.0 && f < 0.5, "escalation fraction {f} outside sane band");
    // Energy accounting identity: E = n*e_r + n_esc*e_f.
    let n = data.n as f64;
    let n_esc = served.escalated.iter().filter(|&&e| e).count() as f64;
    let expect = n * cascade.e_reduced + n_esc * cascade.e_full;
    assert!((served.energy_uj - expect).abs() < 1e-6);
    // Savings must be positive at this operating point (the numpy design
    // study puts it near 0.5; assert a generous floor).
    assert!(cascade.realised_savings(&served) > 0.2);
}

#[test]
fn lower_threshold_escalates_less() {
    let mut engine = NativeBackend::synthetic();
    let data = engine.eval_data("fashion_syn").unwrap();
    let mut fractions = Vec::new();
    for policy in [ThresholdPolicy::MMax, ThresholdPolicy::M99, ThresholdPolicy::M95] {
        let cascade =
            Cascade::calibrate(&mut engine, spec("fashion_syn", Mode::Fp, 8, policy), &data, 256).unwrap();
        let (served, _) = cascade.infer_dataset(&mut engine, &data).unwrap();
        fractions.push(Cascade::escalation_fraction(&served));
    }
    assert!(fractions[0] >= fractions[1] && fractions[1] >= fractions[2], "{fractions:?}");
}

#[test]
fn sc_cascade_works_and_accuracy_close_to_full() {
    let mut engine = NativeBackend::synthetic();
    let data = engine.eval_data("fashion_syn").unwrap();
    let cascade =
        Cascade::calibrate(&mut engine, spec("fashion_syn", Mode::Sc, 512, ThresholdPolicy::MMax), &data, 256)
            .unwrap();
    let (served, _) = cascade.infer_dataset(&mut engine, &data).unwrap();
    let acc: f64 = served.pred.iter().zip(&data.y).filter(|(a, b)| a == b).count() as f64 / data.n as f64;
    let full_v = engine.manifest().variant("fashion_syn", VariantKind::Sc, 4096, 256).unwrap().clone();
    let full = engine.run_dataset(&full_v, &data, 512).unwrap();
    let acc_full = full.accuracy(&data.y);
    assert!((acc - acc_full).abs() < 0.05, "SC cascade accuracy {acc} vs full {acc_full}");
}

#[test]
fn fixed_threshold_zero_never_escalates() {
    let mut engine = NativeBackend::synthetic();
    let data = engine.eval_data("fashion_syn").unwrap();
    // T = 0 accepts everything with margin > 0 (ties are escalated).
    let cascade = Cascade::calibrate(
        &mut engine,
        spec("fashion_syn", Mode::Fp, 10, ThresholdPolicy::Fixed(0.0)),
        &data,
        256,
    )
    .unwrap();
    let small = ari::data::EvalData {
        x: data.rows(0, 128).to_vec(),
        y: data.y[..128].to_vec(),
        n: 128,
        input_dim: data.input_dim,
    };
    let (served, _) = cascade.infer_dataset(&mut engine, &small).unwrap();
    let f = Cascade::escalation_fraction(&served);
    assert!(f < 0.05, "T=0 should accept almost everything, got F={f}");
    // And energy ≈ n * e_reduced.
    assert!(served.energy_uj <= 128.0 * cascade.e_reduced + 8.0 * cascade.e_full);
}

#[test]
fn cascade_calibrates_on_every_fixture_dataset() {
    let mut engine = NativeBackend::synthetic();
    for ds in ["fashion_syn", "svhn_syn", "cifar10_syn"] {
        let data = engine.eval_data(ds).unwrap();
        let cascade =
            Cascade::calibrate(&mut engine, spec(ds, Mode::Fp, 10, ThresholdPolicy::MMax), &data, 256).unwrap();
        assert!(cascade.e_reduced < cascade.e_full, "{ds}: reduced model must be cheaper");
        assert!(cascade.threshold >= 0.0);
    }
}

#[test]
fn infer_dataset_reports_backend_class_count() {
    // Regression: n_classes used to be hardcoded to 10 in
    // Cascade::infer_dataset; a 6-class fixture must report 6.
    use ari::runtime::fixture::FixtureSpec;
    let mut fx = FixtureSpec::small("six", "Six", 20, 400);
    fx.n_classes = 6;
    let mut engine = NativeBackend::from_fixtures(&[fx]);
    let data = engine.eval_data("six").unwrap();
    assert!(data.y.iter().all(|&y| (0..6).contains(&y)));
    let cascade =
        Cascade::calibrate(&mut engine, spec("six", Mode::Fp, 8, ThresholdPolicy::MMax), &data, 128).unwrap();
    let (batch, outputs) = cascade.infer_dataset(&mut engine, &data).unwrap();
    assert_eq!(outputs.n_classes, 6);
    assert_eq!(batch.n_classes, 6);
    assert_eq!(outputs.pred.len(), data.n);
}
