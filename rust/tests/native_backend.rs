//! Native-backend ports of the runtime parity suite: the [`Backend`]
//! contract (execute/run_padded/run_dataset semantics, determinism,
//! margin consistency) exercised on the pure-rust engine over the
//! deterministic fixture suite.  Always runs — no artifacts, no PJRT.
//!
//! The cross-language golden checks against jax live in
//! `runtime_parity.rs` (behind the `pjrt` feature); here the golden is
//! the in-process [`ari::mlp`] engine the backend is built from, which
//! must agree *bit-for-bit*.

use ari::data::VariantKind;
use ari::mlp::{FpEngine, ScNoiseEngine};
use ari::quant::FpFormat;
use ari::runtime::{Backend, NativeBackend};
use ari::sc::ScConfig;

fn backend() -> NativeBackend {
    NativeBackend::synthetic()
}

const DS: &str = "fashion_syn";

#[test]
fn fp_variants_match_mlp_engine_exactly() {
    let mut engine = backend();
    engine.load_dataset(DS).unwrap();
    let eval = engine.eval_data(DS).unwrap();
    let x = eval.rows(0, 32).to_vec();
    for bits in [16usize, 12, 10, 8] {
        let v = engine.manifest().variant(DS, VariantKind::Fp, bits, 32).unwrap().clone();
        let out = engine.execute(&v, &x, None).unwrap();
        let weights = engine.weights(DS).unwrap();
        let golden = FpEngine::new(weights, FpFormat::fp(bits as u32)).forward(&x, 32);
        assert_eq!(out.pred, golden.pred, "FP{bits} predictions");
        assert_eq!(out.scores, golden.scores.data, "FP{bits} scores");
        assert_eq!(out.margin, golden.margin, "FP{bits} margins");
    }
}

#[test]
fn sc_variant_matches_noise_engine_with_same_key() {
    let mut engine = backend();
    engine.load_dataset(DS).unwrap();
    let eval = engine.eval_data(DS).unwrap();
    let x = eval.rows(0, 32).to_vec();
    let key = [5u32, 9u32];
    let v = engine.manifest().variant(DS, VariantKind::Sc, 512, 32).unwrap().clone();
    let out = engine.execute(&v, &x, Some(key)).unwrap();
    let weights = engine.weights(DS).unwrap();
    let seed = ((key[0] as u64) << 32) | key[1] as u64;
    let golden = ScNoiseEngine::new(weights, ScConfig::new(512)).forward(&x, 32, seed);
    assert_eq!(out.pred, golden.pred);
    assert_eq!(out.scores, golden.scores.data);
}

#[test]
fn margins_are_top2_gaps_of_scores() {
    let mut engine = backend();
    let eval = engine.eval_data(DS).unwrap();
    let v = engine.manifest().variant(DS, VariantKind::Fp, 16, 32).unwrap().clone();
    let out = engine.execute(&v, eval.rows(0, 32), None).unwrap();
    for i in 0..32 {
        let row = out.score_row(i);
        let mut sorted: Vec<f32> = row.to_vec();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        assert!((out.margin[i] - (sorted[0] - sorted[1])).abs() < 1e-6, "row {i}");
        assert_eq!(out.pred[i] as usize, (0..row.len()).max_by(|&a, &b| row[a].partial_cmp(&row[b]).unwrap()).unwrap());
    }
}

#[test]
fn run_dataset_chunking_consistent() {
    // Chunked full-dataset run must equal a manual single-batch run on
    // the first rows (FP is deterministic).
    let mut engine = backend();
    let eval = engine.eval_data(DS).unwrap();
    let small = ari::data::EvalData {
        x: eval.rows(0, 40).to_vec(),
        y: eval.y[..40].to_vec(),
        n: 40,
        input_dim: eval.input_dim,
    };
    let v = engine.manifest().variant(DS, VariantKind::Fp, 10, 32).unwrap().clone();
    let all = engine.run_dataset(&v, &small, 0).unwrap();
    assert_eq!(all.pred.len(), 40);
    let first = engine.execute(&v, eval.rows(0, 32), None).unwrap();
    assert_eq!(&all.pred[..32], &first.pred[..]);
    assert_eq!(&all.margin[..32], &first.margin[..]);
}

#[test]
fn padding_does_not_change_results() {
    let mut engine = backend();
    let eval = engine.eval_data(DS).unwrap();
    let v = engine.manifest().variant(DS, VariantKind::Fp, 10, 32).unwrap().clone();
    let full = engine.execute(&v, eval.rows(0, 32), None).unwrap();
    let (padded, waste) = engine.run_padded(&v, eval.rows(0, 7), 7, None).unwrap();
    assert_eq!(waste, 25);
    assert_eq!(&padded.pred[..], &full.pred[..7]);
    assert_eq!(&padded.margin[..], &full.margin[..7]);
}

#[test]
fn full_model_is_accurate_on_fixture() {
    // The fixture's embedded-prototype classifier must be well above
    // chance at FP16 (design target ~0.9; see runtime::fixture docs).
    let mut engine = backend();
    let eval = engine.eval_data(DS).unwrap();
    let v = engine.manifest().variant(DS, VariantKind::Fp, 16, 256).unwrap().clone();
    let out = engine.run_dataset(&v, &eval, 0).unwrap();
    assert!(out.accuracy(&eval.y) > 0.6, "accuracy {}", out.accuracy(&eval.y));
}

#[test]
fn artifacts_dir_and_synthetic_agree() {
    // Writing the fixture suite to disk and loading it back must give
    // the same outputs as the in-memory backend (the two construction
    // paths share one generator).
    let dir = std::env::temp_dir().join(format!("ari-native-rt-{}", std::process::id()));
    ari::runtime::fixture::write_artifacts(&dir, &ari::runtime::fixture::default_specs()).unwrap();
    let mut from_disk = NativeBackend::from_artifacts(&dir).unwrap();
    let mut in_memory = backend();
    let eval = in_memory.eval_data(DS).unwrap();
    let v = in_memory.manifest().variant(DS, VariantKind::Fp, 10, 32).unwrap().clone();
    let a = in_memory.execute(&v, eval.rows(0, 32), None).unwrap();
    let b = from_disk.execute(&v, eval.rows(0, 32), None).unwrap();
    assert_eq!(a.pred, b.pred);
    assert_eq!(a.scores, b.scores);
    std::fs::remove_dir_all(dir).ok();
}
