//! End-to-end suite for the closed-loop threshold controller
//! (`docs/ROBUSTNESS.md`, "Control loop").
//!
//! Four contracts are pinned here:
//!
//! 1. **Pass-through bit-identity** — with every `[control]` knob off,
//!    a session that carries a controller (armed only for its sliding
//!    latency window) serves bit-identical pred/stage/margin to a
//!    session with no controller at all.
//! 2. **Deterministic adaptation under drift** — over a harshly
//!    drifted eval stream the single-threaded dispatcher driver flags
//!    drift, recalibrates within the clamp, serves accuracy within
//!    epsilon of the full model on the same drifted rows, and does all
//!    of it identically across runs.
//! 3. **The pipelined session survives drift + overload** — the real
//!    threaded serving loop with the controller fully on stays
//!    accurate, bounded in latency, and conserves every request.
//! 4. **The `drift-shift` fault point** composes with the controller:
//!    an armed session completes and accounts every request.
//!
//! Hysteresis convergence and no-flapping under constant load are
//! pinned at the controller level in `coordinator::control` unit tests;
//! here the same policy runs through the real dispatch path.
#![cfg(any(debug_assertions, feature = "sim"))]

use ari::config::{AriConfig, Mode, ThresholdPolicy};
use ari::coordinator::{ControlPolicy, EscalationPolicy, Ladder, LadderSpec};
use ari::data::{EvalData, VariantKind};
use ari::metrics::ControlEvent;
use ari::runtime::fixture::{drift_eval, DriftSpec};
use ari::runtime::{Backend, NativeBackend};
use ari::server::model::drive_deferred_controlled;
use ari::server::{run_serving_ladder, RobustnessPolicy, ServeOptions};
use ari::util::fault;

/// A drift harsh enough that the stage-0 margin distribution must move
/// visibly (the per-test guard asserts it does): the acceptance gate
/// was calibrated on a clean stream and goes stale.
fn harsh_drift() -> DriftSpec {
    DriftSpec { scale: 1.5, shift: 0.4, noise: 0.2, seed: 0xD21F }
}

fn clean_ladder(engine: &mut NativeBackend) -> (Ladder, EvalData) {
    let data = engine.eval_data("fashion_syn").unwrap();
    let spec = LadderSpec {
        dataset: "fashion_syn".into(),
        mode: Mode::Fp,
        levels: vec![8, 12, 16],
        batch: 32,
        threshold: ThresholdPolicy::MMax,
        seed: 7,
    };
    let ladder = Ladder::calibrate(engine, spec, &data, 64).unwrap();
    (ladder, data)
}

/// Accuracy of `pred[row]` against labels over the rows a session used.
fn accuracy_over(rows: &[usize], pred: &[i32], y: &[i32]) -> f64 {
    let hit = rows.iter().filter(|&&r| pred[r] == y[r]).count();
    hit as f64 / rows.len().max(1) as f64
}

/// With `[control]` unset, the pass-through controller (kept alive only
/// to feed the overload detector's sliding window) must serve the exact
/// same bits as a session with no controller: same preds, same stages,
/// same margins, request for request.
#[test]
fn passthrough_controller_is_bit_identical_to_none() {
    let mut engine = NativeBackend::synthetic();
    let data = engine.eval_data("fashion_syn").unwrap();
    let spec = LadderSpec {
        dataset: "fashion_syn".into(),
        mode: Mode::Fp,
        levels: vec![8, 16],
        batch: 32,
        threshold: ThresholdPolicy::MMax,
        seed: 7,
    };
    let ladder = Ladder::calibrate(&mut engine, spec, &data, 64).unwrap();
    let mut cfg = AriConfig::default();
    cfg.dataset = "fashion_syn".into();
    cfg.requests = 128;
    cfg.batch_size = 16;
    cfg.batch_timeout_us = 200;
    let bare = run_serving_ladder(&mut engine, &ladder, &cfg, &data, None, ServeOptions::default()).unwrap();
    // An overload threshold far above anything loopback latencies can
    // reach: the controller exists (sliding window armed) but every
    // threshold it answers is the calibrated one.
    cfg.overload_p95_us = 600_000_000;
    let with_ctl = run_serving_ladder(&mut engine, &ladder, &cfg, &data, None, ServeOptions::default()).unwrap();
    assert!(with_ctl.control_events.is_empty(), "pass-through mode must adapt nothing");
    assert_eq!(bare.completions.len(), with_ctl.completions.len());
    let mut a = bare.completions.clone();
    let mut b = with_ctl.completions.clone();
    a.sort_by_key(|c| c.id);
    b.sort_by_key(|c| c.id);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.id, y.id);
        assert_eq!(x.pred, y.pred, "request {}", x.id);
        assert_eq!(x.stage, y.stage, "request {}", x.id);
        assert_eq!(x.margin.to_bits(), y.margin.to_bits(), "request {}", x.id);
        assert_eq!(x.outcome, y.outcome, "request {}", x.id);
    }
}

/// The pinned adaptive-under-drift run: deterministic single-threaded
/// dispatch over a harshly drifted stream.  The controller must flag
/// drift, recalibrate stage 0 within the clamp, keep accuracy within
/// epsilon of the *full model on the same drifted rows*, restore a
/// bounded escalation load — and reproduce all of it bit-for-bit on a
/// second run.
#[test]
fn drifted_stream_is_detected_recalibrated_and_served_within_epsilon() {
    let mut engine = NativeBackend::synthetic();
    let (ladder, data) = clean_ladder(&mut engine);
    let mut drifted = data.clone();
    drift_eval(&mut drifted, &harsh_drift());

    // Guard: the fixture drift must actually move the stage-0 margin
    // distribution past the detector's tolerance, or the whole scenario
    // is vacuous.  Computed from the reduced model directly so a
    // too-weak drift fails here with a diagnosable message.
    let reduced = engine.manifest().variant("fashion_syn", VariantKind::Fp, 8, 256).unwrap().clone();
    let red_out = engine.run_dataset(&reduced, &drifted, 7).unwrap();
    let t_cal = ladder.stages[0].threshold;
    let frac = red_out.margin.iter().filter(|&&m| (m as f64) <= t_cal).count() as f64 / drifted.n as f64;
    let baseline = ladder.stages[0].base_escalation;
    assert!(
        (frac - baseline).abs() > 0.05,
        "fixture drift too weak to test the monitor: drifted escalation {frac:.3} vs baseline {baseline:.3}"
    );

    // Full-model accuracy on the same drifted rows: the static-full
    // baseline the adaptive ladder must stay within epsilon of.
    let full = engine.manifest().variant("fashion_syn", VariantKind::Fp, 16, 256).unwrap().clone();
    let full_out = engine.run_dataset(&full, &drifted, 7).unwrap();

    let control = ControlPolicy {
        drift: true,
        drift_window: 128,
        drift_tolerance: 0.05,
        recal_min: 32,
        recal_clamp: 0.5,
        ..ControlPolicy::default()
    };
    let batches: Vec<Vec<usize>> = (0..16).map(|b| (0..32).map(|k| (b * 32 + k) % drifted.n).collect()).collect();
    let rows: Vec<usize> = batches.iter().flatten().copied().collect();
    let run = |engine: &mut NativeBackend| {
        drive_deferred_controlled(
            engine,
            &ladder,
            &drifted,
            &batches,
            RobustnessPolicy::default(),
            Some(control.clone()),
        )
        .unwrap()
    };
    let session = run(&mut engine);
    assert_eq!(session.completions.len(), rows.len(), "every drifted request completes exactly once");
    assert!(
        session.control_events.iter().any(|e| matches!(e, ControlEvent::Drift { stage: 0, .. })),
        "drift must be flagged: {:?}",
        session.control_events
    );
    assert!(
        session.control_events.iter().any(|e| matches!(e, ControlEvent::Recalibrated { .. })),
        "drift must trigger an online recalibration: {:?}",
        session.control_events
    );
    // Recalibration is bounded: every new threshold stays within the
    // clamp of the offline calibration and never goes negative.
    for e in &session.control_events {
        if let ControlEvent::Recalibrated { to, .. } = e {
            assert!(*to >= 0.0 && (*to - t_cal).abs() <= control.recal_clamp + 1e-12, "unbounded recal: {e:?}");
        }
    }
    let full_acc = accuracy_over(&rows, &full_out.pred, &drifted.y);
    let adaptive_hits =
        session.completions.iter().filter(|c| c.pred == drifted.y[c.row]).count();
    let adaptive_acc = adaptive_hits as f64 / session.completions.len() as f64;
    assert!(
        adaptive_acc >= full_acc - 0.05,
        "adaptive accuracy {adaptive_acc:.4} fell more than epsilon below the full model {full_acc:.4}"
    );

    // Deterministic: an identical second session reproduces the same
    // predictions, stages and control trajectory bit for bit.
    let mut engine2 = NativeBackend::synthetic();
    let again = run(&mut engine2);
    assert_eq!(again.completions.len(), session.completions.len());
    for (a, b) in session.completions.iter().zip(&again.completions) {
        assert_eq!((a.id, a.pred, a.stage, a.margin.to_bits()), (b.id, b.pred, b.stage, b.margin.to_bits()));
    }
    assert_eq!(format!("{:?}", session.control_events), format!("{:?}", again.control_events));
}

/// The real pipelined serving loop, controller fully on (per-class +
/// load-adaptive + drift, queue signal only), over a harshly drifted
/// stream: the session must conserve every request, flag the drift,
/// stay within epsilon of the full model on the same rows, and keep
/// the observed p95 under a generous wall-clock bound.
#[test]
fn pipelined_session_adapts_under_drift_and_load() {
    let mut engine = NativeBackend::synthetic();
    let (ladder, data) = clean_ladder(&mut engine);
    let mut drifted = data.clone();
    drift_eval(&mut drifted, &harsh_drift());
    let full = engine.manifest().variant("fashion_syn", VariantKind::Fp, 16, 256).unwrap().clone();
    let full_out = engine.run_dataset(&full, &drifted, 7).unwrap();

    let mut cfg = AriConfig::default();
    cfg.dataset = "fashion_syn".into();
    cfg.requests = 512;
    cfg.batch_size = 32;
    cfg.batch_timeout_us = 500;
    cfg.control_per_class = true;
    cfg.control_load_adaptive = true;
    cfg.control_drift = true;
    // Queue signal only: latency bands off so the adaptation trajectory
    // depends on backlog, not wall-clock noise.
    cfg.control_p95_high_us = 0;
    cfg.control_p95_low_us = 0;
    cfg.control_queue_high = 64;
    cfg.control_queue_low = 8;
    cfg.control_step = 0.02;
    cfg.control_max_steps = 2;
    cfg.control_drift_window = 128;
    cfg.control_drift_tolerance = 0.05;
    cfg.control_recal_min = 32;
    let opts = ServeOptions { escalation: EscalationPolicy::Deferred };
    let report = run_serving_ladder(&mut engine, &ladder, &cfg, &drifted, Some(&full_out.pred), opts).unwrap();

    assert_eq!(report.completions.len(), 512, "drift must not cost a single completion");
    let mut ids: Vec<u64> = report.completions.iter().map(|c| c.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 512, "duplicate completions under the adaptive session");
    assert!(
        report.control_events.iter().any(|e| matches!(e, ControlEvent::Drift { .. })),
        "the pipelined session must flag the drifted stream: {:?}",
        report.control_events
    );
    let rows: Vec<usize> = report.completions.iter().map(|c| c.row).collect();
    let full_acc = accuracy_over(&rows, &full_out.pred, &drifted.y);
    assert!(
        report.accuracy >= full_acc - 0.05,
        "adaptive accuracy {:.4} fell more than epsilon below the full model {full_acc:.4}",
        report.accuracy
    );
    // Generous latency ceiling: the point is that recalibration happens
    // inline without stalling serving, not a tight SLO.
    assert!(report.p95 < std::time::Duration::from_secs(2), "p95 {:?} implies the loop stalled", report.p95);
}

/// The `drift-shift` fault point (inputs perturbed at the staging
/// boundary) composes with the controller: an armed in-process session
/// still serves every request exactly once.
#[test]
fn drift_shift_fault_session_conserves_requests() {
    let _g = fault::ArmGuard::arm("drift-shift:1.0");
    let mut engine = NativeBackend::synthetic();
    let (ladder, data) = clean_ladder(&mut engine);
    let mut cfg = AriConfig::default();
    cfg.dataset = "fashion_syn".into();
    cfg.requests = 96;
    cfg.batch_size = 16;
    cfg.batch_timeout_us = 200;
    cfg.control_drift = true;
    cfg.control_drift_window = 32;
    cfg.control_recal_min = 16;
    let report = run_serving_ladder(&mut engine, &ladder, &cfg, &data, None, ServeOptions::default()).unwrap();
    assert_eq!(report.completions.len(), 96);
    let mut ids: Vec<u64> = report.completions.iter().map(|c| c.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), 96, "every shifted request completes exactly once");
}
