//! Loopback integration suite for the TCP serving tier: a real
//! `run_net_serving` session on an ephemeral port, driven by the real
//! `run_client` load generator over 127.0.0.1.
//!
//! Three contracts are pinned here:
//!
//! 1. **Parity** — wire serving is a transport, not a model change: for
//!    a fixed seed, every TCP response carries bit-identical
//!    pred/stage/margin to the same request served by the in-process
//!    [`run_serving`] loop (FP mode, where per-row results are
//!    independent of batch composition).
//! 2. **Exactly-one-completion under faults** — each network fault
//!    point, armed alone, still yields exactly one typed completion per
//!    request on both sides of the wire: the server's conservation
//!    ledger balances and the client accounts every sent request as
//!    received or lost.
//! 3. **Chaos** — the canonical `chaos_spec` schedule (all recoverable
//!    points, the five net points included) over loopback TCP completes
//!    under the watchdog with both ledgers balanced.

use std::collections::HashMap;

use ari::config::{AriConfig, Mode, ThresholdPolicy};
use ari::coordinator::{Cascade, CascadeSpec};
use ari::runtime::{Backend, NativeBackend};
use ari::server::net::client::{fetch_stats, run_client, ClientConfig, ClientReport};
use ari::server::net::{run_net_serving, NetServeReport};
use ari::server::{run_serving, ServeOptions};
use ari::util::fault;

fn base_cfg() -> AriConfig {
    let mut cfg = AriConfig::default();
    cfg.dataset = "fashion_syn".into();
    cfg.mode = Mode::Fp;
    cfg.reduced_level = 10;
    cfg.threshold = ThresholdPolicy::MMax;
    cfg.batch_size = 32;
    cfg.requests = 192;
    cfg.batch_timeout_us = 1000;
    // Bound every shutdown path the tests can hit: idle-linger drain,
    // write-stuck drop, and the slow-loris read deadline.
    cfg.net_linger_us = 100_000;
    cfg.net_read_deadline_us = 200_000;
    cfg
}

/// Run one loopback session: server on this thread, client on its own.
fn serve_loopback(cfg: &AriConfig, tune: impl FnOnce(&mut ClientConfig)) -> (NetServeReport, ClientReport) {
    let mut engine = NativeBackend::synthetic();
    let data = engine.eval_data(&cfg.dataset).unwrap();
    let cascade = Cascade::calibrate(&mut engine, CascadeSpec::from_config(cfg), &data, data.n / 2).unwrap();
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let mut ccfg = ClientConfig::default();
    ccfg.addr = listener.local_addr().unwrap().to_string();
    ccfg.seed = cfg.seed;
    ccfg.requests = cfg.requests;
    ccfg.rate = cfg.arrival_rate;
    // Keep loss detection well under the test timeout.
    ccfg.timeout = std::time::Duration::from_secs(1);
    tune(&mut ccfg);
    let cdata = data.clone();
    // ari-lint: allow(sim-discipline): the loopback client models the outside world
    // on a real thread over a real socket; the sim scheduler cannot (and should not)
    // interleave kernel TCP.
    let client = std::thread::spawn(move || run_client(&ccfg, &cdata));
    let report = run_net_serving(&mut engine, &cascade.ladder, cfg, data.input_dim, ServeOptions::default(), listener)
        .expect("net serving session failed");
    let creport = client.join().expect("client thread panicked").expect("client session failed");
    (report, creport)
}

/// The exactly-one-completion ledger, asserted on both ends of the wire.
fn assert_conservation(report: &NetServeReport, creport: &ClientReport) {
    assert_eq!(
        report.responses_sent + report.dropped_dead,
        report.admitted + report.shed,
        "server response conservation broken"
    );
    assert_eq!(creport.received + creport.lost, creport.sent, "client conservation broken");
    assert!(
        creport.received <= report.responses_sent,
        "client received {} > server sent {}",
        creport.received,
        report.responses_sent
    );
}

/// Fault-free loopback serving must be a pure transport: every request
/// answered, and every answer bit-identical to the in-process server's
/// completion for the same seed (same rows, same ladder, FP mode).
#[test]
fn loopback_scores_match_in_process_serving() {
    // Probability-0 arm: holds the fault registry's serial lock so a
    // concurrently-running fault test in this binary cannot inject into
    // the parity session, while injecting nothing itself.
    let _quiesce = fault::ArmGuard::arm("conn-drop:0.0");
    let cfg = base_cfg();

    // In-process reference session, same seed and fixture.
    let mut engine = NativeBackend::synthetic();
    let data = engine.eval_data(&cfg.dataset).unwrap();
    let cascade = Cascade::calibrate(&mut engine, CascadeSpec::from_config(&cfg), &data, data.n / 2).unwrap();
    let inproc = run_serving(&mut engine, &cascade, &cfg, &data, None, ServeOptions::default()).unwrap();
    let by_id: HashMap<u64, (i32, u8, u32)> = inproc
        .completions
        .iter()
        .map(|c| (c.id, (c.pred, c.stage as u8, c.margin.to_bits())))
        .collect();
    assert_eq!(by_id.len(), cfg.requests);

    let (report, creport) = serve_loopback(&cfg, |_| {});
    assert_conservation(&report, &creport);
    assert_eq!(report.admitted, cfg.requests as u64, "nothing may be shed in a fault-free session");
    assert_eq!(report.shed, 0);
    assert_eq!(report.protocol_errors, 0);
    assert_eq!(report.responses_sent, cfg.requests as u64);
    assert_eq!(creport.received, cfg.requests as u64);
    assert_eq!(creport.lost, 0);
    assert_eq!(creport.wire_errors, 0);
    assert_eq!(creport.outcomes, [cfg.requests as u64, 0, 0, 0], "defaults-off serving must be all Ok");

    // One ingress-wait and one queue-wait sample per dispatched request.
    assert_eq!(report.net_wait_samples, cfg.requests as u64);
    assert_eq!(report.queue_wait_samples, cfg.requests as u64);

    assert_eq!(creport.responses.len(), cfg.requests);
    for r in &creport.responses {
        let (pred, stage, margin_bits) = by_id[&r.id];
        assert_eq!(r.pred, pred, "pred mismatch for request {}", r.id);
        assert_eq!(r.stage, stage, "stage mismatch for request {}", r.id);
        assert_eq!(r.margin.to_bits(), margin_bits, "margin bits mismatch for request {}", r.id);
    }
}

/// `conn-drop`: the server abruptly closes an accepted connection.  The
/// client reconnects with backoff; every request still resolves to
/// exactly one completion or one counted loss.
#[test]
fn conn_drop_conserves_every_request() {
    let _g = fault::ArmGuard::arm("conn-drop:1.0:1");
    let (report, creport) = serve_loopback(&base_cfg(), |_| {});
    assert_conservation(&report, &creport);
    assert_eq!(creport.sent, 192, "the client must still send its whole schedule");
}

/// `frame-trunc`: a response stream is cut mid-frame.  The client sees
/// a truncated stream (dead connection), reconnects, and both ledgers
/// still balance — the half-written response is counted dropped, never
/// delivered twice and never lost silently.
#[test]
fn frame_trunc_conserves_every_request() {
    let _g = fault::ArmGuard::arm("frame-trunc:1.0:1");
    let (report, creport) = serve_loopback(&base_cfg(), |_| {});
    assert_conservation(&report, &creport);
    assert_eq!(creport.sent, 192);
}

/// `frame-corrupt`: one inbound byte is flipped before decoding.  The
/// decoder must produce a typed protocol error (or an honestly
/// different valid frame) — and whatever it produces, conservation
/// holds on both sides.
#[test]
fn frame_corrupt_conserves_every_request() {
    let _g = fault::ArmGuard::arm("frame-corrupt:1.0:1");
    let (report, creport) = serve_loopback(&base_cfg(), |_| {});
    assert_conservation(&report, &creport);
    assert_eq!(creport.sent, 192);
}

/// `write-split`: outbound flushes are chopped to a few bytes.  Purely
/// a pacing fault — nothing may be lost, every response reassembles.
#[test]
fn write_split_loses_nothing() {
    let _g = fault::ArmGuard::arm("write-split:0.4");
    let (report, creport) = serve_loopback(&base_cfg(), |_| {});
    assert_conservation(&report, &creport);
    assert_eq!(creport.lost, 0, "split writes must only delay frames, not lose them");
    assert_eq!(creport.received, 192);
    assert_eq!(report.responses_sent, 192);
}

/// `accept-stall`: connection setup stalls.  The client's
/// connect-with-backoff absorbs it; nothing is lost.
#[test]
fn accept_stall_loses_nothing() {
    let _g = fault::ArmGuard::arm("accept-stall:1.0:2");
    let (report, creport) = serve_loopback(&base_cfg(), |_| {});
    assert_conservation(&report, &creport);
    assert_eq!(creport.lost, 0);
    assert_eq!(creport.received, 192);
}

/// `Stats` frames are served live, mid-session, without consuming any
/// of the serving budget: after half the workload, a stats snapshot
/// reports the counters, per-stage served totals and effective
/// thresholds so far, and the second half still serves in full.
#[test]
fn stats_frames_report_live_control_state() {
    // Probability-0 arm: serialises against fault tests in this binary.
    let _quiesce = fault::ArmGuard::arm("conn-drop:0.0");
    let cfg = base_cfg();
    let mut engine = NativeBackend::synthetic();
    let data = engine.eval_data(&cfg.dataset).unwrap();
    let cascade = Cascade::calibrate(&mut engine, CascadeSpec::from_config(&cfg), &data, data.n / 2).unwrap();
    let n_stages = cascade.ladder.stages.len();
    let t0 = cascade.ladder.stages[0].threshold;
    let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let cdata = data.clone();
    let half = (cfg.requests / 2) as u64;
    // ari-lint: allow(sim-discipline): loopback client on a real thread over a
    // real socket, same as serve_loopback.
    let client = std::thread::spawn(move || {
        let mut ccfg = ClientConfig::default();
        ccfg.addr = addr.clone();
        ccfg.requests = half as usize;
        ccfg.timeout = std::time::Duration::from_secs(1);
        let r1 = run_client(&ccfg, &cdata).expect("first half failed");
        let stats = fetch_stats(&addr, std::time::Duration::from_secs(2)).expect("stats fetch failed");
        let r2 = run_client(&ccfg, &cdata).expect("second half failed");
        (r1, stats, r2)
    });
    let report =
        run_net_serving(&mut engine, &cascade.ladder, &cfg, data.input_dim, ServeOptions::default(), listener)
            .expect("net serving session failed");
    let (r1, stats, r2) = client.join().expect("client thread panicked");
    // The mid-session snapshot accounts exactly the first half.
    assert_eq!(r1.received, half);
    assert_eq!(stats.admitted, half, "stats frames must not consume serving budget");
    assert_eq!(stats.shed, 0);
    assert_eq!(stats.responses_sent, half);
    assert_eq!(stats.completed, half);
    assert_eq!(stats.rejected + stats.failed + stats.degraded, 0);
    assert_eq!(stats.stages.len(), n_stages);
    assert_eq!(stats.stages.iter().map(|s| s.served).sum::<u64>(), half, "per-stage served totals balance");
    assert_eq!(stats.stages[0].threshold.to_bits(), t0.to_bits(), "calibrated threshold reported exactly");
    assert_eq!(stats.stages[n_stages - 1].threshold, f64::NEG_INFINITY, "final stage accepts everything");
    // No [control] knob is on: the loop reports its quiescent state.
    assert_eq!((stats.level, stats.drifted, stats.recals), (0, false, 0));
    // The second half still served in full — the session's budget was
    // untouched by the stats exchange.
    assert_eq!(r2.received, half);
    assert_eq!(report.admitted, 2 * half);
    assert_eq!(report.responses_sent + report.dropped_dead, report.admitted + report.shed);
}

/// The canonical chaos schedule — every recoverable fault point, the
/// five wire points included — over real loopback TCP, with the
/// watchdog armed: the session must complete (not hang, not bail) with
/// both conservation ledgers balanced and at least some requests
/// actually served.
#[test]
fn chaos_session_over_loopback_conserves_and_terminates() {
    let spec = fault::chaos_spec(7);
    for p in ["conn-drop", "frame-trunc", "frame-corrupt", "write-split", "accept-stall", "drift-shift"] {
        assert!(spec.contains(p), "canonical chaos spec must cover the {p} point");
    }
    let _g = fault::ArmGuard::arm(&spec);
    let mut cfg = base_cfg();
    // Survive the exec-error/exec-panic legs of the schedule, and let
    // the watchdog bound any stuck drain.
    cfg.retries = 3;
    cfg.retry_backoff_us = 100;
    cfg.watchdog_stall_us = 2_000_000;
    let (report, creport) = serve_loopback(&cfg, |c| {
        c.max_reconnects = 16;
    });
    assert_conservation(&report, &creport);
    assert!(creport.received > 0, "a chaos session must still serve some requests");
    assert_eq!(creport.sent, creport.received + creport.lost);
}
