//! Cross-language parity: the PJRT runtime executing the AOT-lowered HLO
//! must reproduce the jax-side golden outputs recorded at export time.
//!
//! This is THE correctness signal of the whole bridge: L1 pallas kernel →
//! L2 jax model → HLO text → xla-crate parse → PJRT compile → execute.
//!
//! Requires the `pjrt` cargo feature AND `make artifacts` (skips
//! gracefully when artifacts are absent so `cargo test --features pjrt`
//! works in a fresh checkout; the whole file is compiled out of the
//! default feature set).  The native-backend ports of these assertions
//! live in `native_backend.rs` and always run.

#![cfg(feature = "pjrt")]

use std::path::{Path, PathBuf};

use ari::data::{TensorFile, VariantKind};
use ari::runtime::{Backend, Engine};

fn artifacts() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if p.join("manifest.txt").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: no artifacts/ — run `make artifacts`");
        None
    }
}

/// A PJRT engine over the artifacts, or None (with a SKIP note) when no
/// PJRT client can be constructed — e.g. the compile-only xla stub is
/// linked instead of the real crate.
fn engine() -> Option<Engine> {
    let root = artifacts()?;
    match Engine::new(&root) {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("SKIP: PJRT client unavailable ({e})");
            None
        }
    }
}

struct GoldenCfg {
    fp_bits: Vec<usize>,
    sc_len: usize,
    key: [u32; 2],
    batch: usize,
}

fn read_golden_cfg(dir: &Path) -> GoldenCfg {
    let text = std::fs::read_to_string(dir.join("golden.cfg")).unwrap();
    let mut fp_bits = Vec::new();
    let mut sc_len = 0;
    let mut key = [0u32; 2];
    let mut batch = 0;
    for line in text.lines() {
        let parts: Vec<&str> = line.split_whitespace().collect();
        match parts.first() {
            Some(&"fp_bits") => fp_bits = parts[1..].iter().map(|p| p.parse().unwrap()).collect(),
            Some(&"sc_len") => sc_len = parts[1].parse().unwrap(),
            Some(&"key") => key = [parts[1].parse().unwrap(), parts[2].parse().unwrap()],
            Some(&"batch") => batch = parts[1].parse().unwrap(),
            _ => {}
        }
    }
    GoldenCfg { fp_bits, sc_len, key, batch }
}

/// Tolerances: the artifacts are executed here by xla_extension 0.5.1,
/// while the goldens were produced by jax 0.8's bundled XLA.  The two
/// accumulate dot products in different orders, and the quantising
/// epilogue turns a 1-ULP pre-rounding difference into a full grid step
/// (~2^-m relative), which then propagates through 5 layers + softmax.
/// So: small mean deviation, bounded worst-case deviation, and identical
/// predictions wherever the margin is not razor-thin.
fn assert_close(a: &[f32], b: &[f32], atol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    let mut worst = 0.0f32;
    let mut sum = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        worst = worst.max((x - y).abs());
        sum += (x - y).abs() as f64;
    }
    let mean = sum / a.len() as f64;
    assert!(worst <= atol, "{what}: worst |diff| = {worst} > {atol}");
    assert!(mean <= atol as f64 / 4.0, "{what}: mean |diff| = {mean} too high");
}

#[test]
fn fp_variants_match_jax_golden() {
    let Some(mut engine) = engine() else { return };
    let root = engine.manifest.root.clone();
    for ds in engine.manifest.dataset_names().iter().map(|s| s.to_string()).collect::<Vec<_>>() {
        let dir = root.join(&ds);
        let cfg = read_golden_cfg(&dir);
        let golden = TensorFile::open(&dir.join("golden")).unwrap();
        let eval = engine.eval_data(&ds).unwrap();
        let x = eval.rows(0, cfg.batch).to_vec();
        for &bits in &cfg.fp_bits {
            let v = engine.manifest.variant(&ds, VariantKind::Fp, bits, cfg.batch).unwrap().clone();
            let out = engine.execute(&v, &x, None).unwrap();
            let g_scores = golden.get(&format!("fp{bits}.scores")).unwrap().as_f32().unwrap();
            let g_pred = golden.get(&format!("fp{bits}.pred")).unwrap().as_i32().unwrap();
            let g_margin = golden.get(&format!("fp{bits}.margin")).unwrap().as_f32().unwrap();
            assert_close(&out.scores, &g_scores, 2e-2, &format!("{ds}/fp{bits} scores"));
            assert_close(&out.margin, &g_margin, 4e-2, &format!("{ds}/fp{bits} margin"));
            // predictions may only differ where the margin is razor-thin
            let mism = out
                .pred
                .iter()
                .zip(&g_pred)
                .enumerate()
                .filter(|(i, (a, b))| a != b && g_margin[*i] > 5e-2)
                .count();
            assert_eq!(mism, 0, "{ds}/fp{bits}: solid-margin prediction mismatches");
        }
    }
}

#[test]
fn sc_variant_matches_jax_golden() {
    let Some(mut engine) = engine() else { return };
    let root = engine.manifest.root.clone();
    for ds in engine.manifest.dataset_names().iter().map(|s| s.to_string()).collect::<Vec<_>>() {
        let dir = root.join(&ds);
        let cfg = read_golden_cfg(&dir);
        let golden = TensorFile::open(&dir.join("golden")).unwrap();
        let eval = engine.eval_data(&ds).unwrap();
        let x = eval.rows(0, cfg.batch).to_vec();
        let l = cfg.sc_len;
        let v = engine.manifest.variant(&ds, VariantKind::Sc, l, cfg.batch).unwrap().clone();
        let out = engine.execute(&v, &x, Some(cfg.key)).unwrap();
        let g_scores = golden.get(&format!("sc{l}.scores")).unwrap().as_f32().unwrap();
        let g_margin = golden.get(&format!("sc{l}.margin")).unwrap().as_f32().unwrap();
        // Same key -> same threefry stream -> same noise; tolerance covers
        // XLA-version float differences only.
        assert_close(&out.scores, &g_scores, 2e-2, &format!("{ds}/sc{l} scores"));
        assert_close(&out.margin, &g_margin, 4e-2, &format!("{ds}/sc{l} margin"));
    }
}

#[test]
fn pjrt_matches_pure_rust_engine_fp16() {
    // Independent implementation cross-check: the pure-rust FpEngine and
    // the PJRT executable must agree on FP16 (both emulate the same
    // datapath; tolerance covers accumulation-order ULPs through softmax).
    let Some(mut engine) = engine() else { return };
    let ds = "fashion_syn";
    engine.load_dataset(ds).unwrap();
    let eval = engine.eval_data(ds).unwrap();
    let n = 32;
    let x = eval.rows(0, n).to_vec();
    let v = engine.manifest.variant(ds, VariantKind::Fp, 16, 32).unwrap().clone();
    let pjrt = engine.execute(&v, &x, None).unwrap();
    let weights = engine.weights(ds).unwrap();
    let rust = ari::mlp::FpEngine::new(weights, ari::quant::FpFormat::FP16).forward(&x, n);
    let mut agree = 0;
    for i in 0..n {
        if pjrt.pred[i] == rust.pred[i] {
            agree += 1;
        }
    }
    assert!(agree >= n - 1, "pure-rust vs PJRT FP16: only {agree}/{n} prediction agreement");
    assert_close(&pjrt.scores, &rust.scores.data, 5e-3, "fp16 scores rust-vs-pjrt");
}

#[test]
fn run_dataset_chunking_consistent() {
    // Chunked full-dataset run must equal a manual single-batch run on
    // the first rows (FP is deterministic).
    let Some(mut engine) = engine() else { return };
    let ds = "fashion_syn";
    let eval = engine.eval_data(ds).unwrap();
    let small = ari::data::EvalData {
        x: eval.rows(0, 40).to_vec(),
        y: eval.y[..40].to_vec(),
        n: 40,
        input_dim: eval.input_dim,
    };
    let v = engine.manifest.variant(ds, VariantKind::Fp, 10, 32).unwrap().clone();
    let all = engine.run_dataset(&v, &small, 0).unwrap();
    assert_eq!(all.pred.len(), 40);
    let first = engine.execute(&v, eval.rows(0, 32), None).unwrap();
    assert_eq!(&all.pred[..32], &first.pred[..]);
    assert_close(&all.margin[..32], &first.margin, 1e-6, "chunk margins");
}

#[test]
fn padding_does_not_change_results() {
    let Some(mut engine) = engine() else { return };
    let ds = "fashion_syn";
    let eval = engine.eval_data(ds).unwrap();
    let v = engine.manifest.variant(ds, VariantKind::Fp, 10, 32).unwrap().clone();
    let full = engine.execute(&v, eval.rows(0, 32), None).unwrap();
    let (padded, waste) = engine.run_padded(&v, eval.rows(0, 7), 7, None).unwrap();
    assert_eq!(waste, 25);
    assert_eq!(&padded.pred[..], &full.pred[..7]);
    assert_close(&padded.margin, &full.margin[..7], 1e-6, "padded margins");
}
