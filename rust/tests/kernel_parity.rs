//! Kernel-equivalence suite: the tiled/parallel prepared-plan path must
//! be bit-identical to the naive reference implementations, and — under
//! the per-row SC noise keying — invariant to the worker-pool size.
//!
//! This is the contract that makes the perf work safe: any blocking,
//! padding or sharding change that alters a single output bit fails
//! here before it can silently shift the ARI escalation statistics.

use ari::data::VariantKind;
use ari::mlp::{FpEngine, FpPlan, ScNoiseEngine, ScPlan, Scratch};
use ari::quant::FpFormat;
use ari::runtime::fixture::{self, FixtureSpec};
use ari::runtime::{Backend, NativeBackend};
use ari::sc::ScConfig;
use ari::tensor::Matrix;
use ari::util::Pcg64;

/// Shapes that straddle the kernel's MR×NR tile edges.
const SHAPES: [(usize, usize, usize); 7] =
    [(1, 1, 1), (2, 3, 5), (4, 8, 8), (5, 9, 17), (7, 33, 10), (32, 24, 32), (256, 24, 40)];

#[test]
fn tiled_matmul_bit_identical_to_naive_reference() {
    let mut rng = Pcg64::seeded(101);
    for (m, k, n) in SHAPES {
        let a = Matrix::from_fn(m, k, |_, _| (rng.next_f32() - 0.5) * 4.0);
        let b = Matrix::from_fn(k, n, |_, _| (rng.next_f32() - 0.5) * 4.0);
        let tiled = a.matmul(&b);
        let naive = a.matmul_naive(&b);
        assert_eq!(tiled.data, naive.data, "m={m} k={k} n={n}");
    }
}

fn fixture_backend() -> (NativeBackend, ari::data::EvalData) {
    let b = NativeBackend::from_fixtures(&[FixtureSpec::small("par", "Par", 24, 2024)]);
    let eval = b.eval_data("par").unwrap();
    (b, eval)
}

#[test]
fn fp_plan_matches_unprepared_forward_every_level() {
    // The prepared plan (pre-quantised padded weights, tiled kernel,
    // fused epilogue) against the textbook per-call path: quantise
    // operands, naive matmul, quantised epilogue — per layer, per call.
    let (mut backend, eval) = fixture_backend();
    backend.load_dataset("par").unwrap();
    let weights = backend.weights("par").unwrap().clone();
    let batch = 32;
    let x = eval.rows(0, batch).to_vec();
    for bits in fixture::FP_LEVELS {
        let fmt = FpFormat::fp(bits as u32);
        // Unprepared reference forward on the naive kernel.
        let mut h = Matrix::from_vec(batch, eval.input_dim, x.clone());
        let n = weights.layers.len();
        for (i, l) in weights.layers.iter().enumerate() {
            let mut xq = h.clone();
            fmt.quantize_slice(&mut xq.data);
            let mut wq = Matrix::from_vec(l.in_dim, l.out_dim, l.w.clone());
            fmt.quantize_slice(&mut wq.data);
            let mut out = xq.matmul_naive(&wq);
            let bq: Vec<f32> = l.b.iter().map(|&v| fmt.quantize(v)).collect();
            out.add_row(&bq);
            fmt.quantize_slice(&mut out.data);
            if i + 1 < n {
                out.prelu(l.alpha);
                fmt.quantize_slice(&mut out.data);
            }
            h = out;
        }
        h.l2_normalize_rows();

        let plan = FpPlan::new(&weights, fmt);
        for threads in [1usize, 2, 4] {
            let got = plan.forward(&x, batch, &mut Scratch::new(), threads);
            assert_eq!(got.scores.data, h.data, "FP{bits} threads={threads}");
        }
        // And the engine wrapper agrees with the plan.
        let eng = FpEngine::new(&weights, fmt).forward(&x, batch);
        assert_eq!(eng.scores.data, h.data, "FP{bits} engine wrapper");
    }
}

#[test]
fn fp_outputs_invariant_to_worker_pool_size() {
    let (mut backend, eval) = fixture_backend();
    backend.load_dataset("par").unwrap();
    let weights = backend.weights("par").unwrap().clone();
    let batch = 256;
    let x = eval.rows(0, batch).to_vec();
    let plan = FpPlan::new(&weights, FpFormat::fp(10));
    let base = plan.forward(&x, batch, &mut Scratch::new(), 1);
    for threads in [2usize, 3, 4, 7] {
        let got = plan.forward(&x, batch, &mut Scratch::new(), threads);
        assert_eq!(got.scores.data, base.scores.data, "threads={threads}");
        assert_eq!(got.pred, base.pred, "threads={threads}");
        assert_eq!(got.margin, base.margin, "threads={threads}");
    }
}

#[test]
fn sc_outputs_invariant_to_worker_pool_size() {
    // The per-row (key, row_index) PCG keying is what makes this hold:
    // every row's noise stream is independent of which worker runs it.
    let (mut backend, eval) = fixture_backend();
    backend.load_dataset("par").unwrap();
    let weights = backend.weights("par").unwrap().clone();
    let batch = 32;
    let x = eval.rows(0, batch).to_vec();
    for level in [64usize, 512] {
        let plan = ScPlan::new(&weights, ScConfig::new(level));
        let base = plan.forward(&x, batch, 99, &mut Scratch::new(), 1);
        for threads in [2usize, 4] {
            let got = plan.forward(&x, batch, 99, &mut Scratch::new(), threads);
            assert_eq!(got.scores.data, base.scores.data, "L={level} threads={threads}");
            assert_eq!(got.pred, base.pred);
            assert_eq!(got.margin, base.margin);
        }
        // Engine wrapper (auto thread count) must agree too.
        let eng = ScNoiseEngine::new(&weights, ScConfig::new(level)).forward(&x, batch, 99);
        assert_eq!(eng.scores.data, base.scores.data, "L={level} engine wrapper");
    }
}

#[test]
fn sc_rows_have_independent_streams() {
    // Same rows in a different batch composition keep their noise: row r
    // alone must equal row r inside a batch (per-row keying, per-row
    // operand scale).
    let (mut backend, eval) = fixture_backend();
    backend.load_dataset("par").unwrap();
    let weights = backend.weights("par").unwrap().clone();
    let plan = ScPlan::new(&weights, ScConfig::new(256));
    let batch = 8;
    let x = eval.rows(0, batch).to_vec();
    let all = plan.forward(&x, batch, 7, &mut Scratch::new(), 2);
    // Row 0 on its own: same (seed, row_index=0) stream.
    let solo = plan.forward(eval.rows(0, 1), 1, 7, &mut Scratch::new(), 1);
    assert_eq!(solo.scores.data, all.scores.data[..solo.scores.cols].to_vec());
}

#[test]
fn backend_execute_matches_plan_outputs() {
    // The served path (prepared-variant cache + scratch reuse) equals a
    // fresh plan — executing twice also exercises scratch reuse.
    let (mut backend, eval) = fixture_backend();
    let x = eval.rows(0, 32).to_vec();
    let v = backend.manifest().variant("par", VariantKind::Fp, 8, 32).unwrap().clone();
    let a = backend.execute(&v, &x, None).unwrap();
    let b = backend.execute(&v, &x, None).unwrap();
    assert_eq!(a.scores, b.scores, "scratch reuse must not change results");
    let weights = backend.weights("par").unwrap();
    let plan = FpPlan::new(weights, FpFormat::fp(8));
    let fresh = plan.forward(&x, 32, &mut Scratch::new(), 1);
    assert_eq!(a.scores, fresh.scores.data);
    assert_eq!(a.pred, fresh.pred);

    let sv = backend.manifest().variant("par", VariantKind::Sc, 512, 32).unwrap().clone();
    let key = [11u32, 13u32];
    let sa = backend.execute(&sv, &x, Some(key)).unwrap();
    let weights = backend.weights("par").unwrap();
    let seed = ((key[0] as u64) << 32) | key[1] as u64;
    let splan = ScPlan::new(weights, ScConfig::new(512));
    let sfresh = splan.forward(&x, 32, seed, &mut Scratch::new(), 3);
    assert_eq!(sa.scores, sfresh.scores.data);
}

#[test]
fn full_mantissa_fp_level_usable_end_to_end() {
    // m_bits = 23 (the former shift-underflow panic) through the whole
    // plan path: FpFormat::new(23, 5) must forward cleanly.
    let (mut backend, eval) = fixture_backend();
    backend.load_dataset("par").unwrap();
    let weights = backend.weights("par").unwrap().clone();
    let fmt = FpFormat::new(23, 5);
    let x = eval.rows(0, 32).to_vec();
    let out = FpPlan::new(&weights, fmt).forward(&x, 32, &mut Scratch::new(), 2);
    assert_eq!(out.pred.len(), 32);
    assert!(out.scores.data.iter().all(|v| v.is_finite()));
}
