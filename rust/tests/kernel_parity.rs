//! Kernel-equivalence suite: the tiled/parallel prepared-plan path must
//! be bit-identical to the naive reference implementations — on **every
//! SIMD dispatch path this host can run** — and, under the per-row SC
//! noise keying, invariant to the worker-pool size.
//!
//! This is the contract that makes the perf work safe: any blocking,
//! padding, SIMD or sharding change that alters a single output bit
//! fails here before it can silently shift the ARI escalation
//! statistics.  CI additionally runs this whole suite under
//! `ARI_SIMD=0 ARI_THREADS=1` (forced scalar dispatch, serial pool), so
//! every dispatch × thread combination gets pinned across the two runs.

use ari::data::VariantKind;
use ari::mlp::plan::SC_ROW_STREAM;
use ari::mlp::{FpEngine, FpPlan, ScNoiseEngine, ScPlan, Scratch, SC_LFSR_K, SC_NOISE_C};
use ari::quant::FpFormat;
use ari::runtime::fixture::{self, FixtureSpec};
use ari::runtime::{Backend, NativeBackend};
use ari::sc::ScConfig;
use ari::tensor::{available_backends, matmul_strided_with, Matrix, SimdBackend};
use ari::util::{pool, Pcg64};

/// Shapes that straddle the kernel's MR×NR tile edges.
const SHAPES: [(usize, usize, usize); 8] =
    [(1, 1, 1), (2, 3, 5), (4, 8, 8), (5, 9, 17), (7, 33, 10), (32, 24, 32), (256, 24, 40), (13, 24, 48)];

#[test]
fn tiled_matmul_bit_identical_to_naive_reference() {
    // The active dispatch path (whatever ARI_SIMD / detection picked)
    // and every other available path, against the naive triple loop.
    let mut rng = Pcg64::seeded(101);
    for (m, k, n) in SHAPES {
        let a = Matrix::from_fn(m, k, |_, _| (rng.next_f32() - 0.5) * 4.0);
        let b = Matrix::from_fn(k, n, |_, _| (rng.next_f32() - 0.5) * 4.0);
        let tiled = a.matmul(&b);
        let naive = a.matmul_naive(&b);
        assert_eq!(tiled.data, naive.data, "m={m} k={k} n={n}");
        for backend in available_backends() {
            let mut out = Matrix::zeros(m, n);
            matmul_strided_with(backend, &a.data, k, &b.data, k, &mut out.data, n, m, n);
            assert_eq!(out.data, naive.data, "{} m={m} k={k} n={n}", backend.name());
        }
    }
}

#[test]
fn simd_dispatch_honours_ari_simd_override() {
    // When ARI_SIMD names an available path, the process-wide dispatch
    // must have picked it (this is what makes CI's forced-scalar leg a
    // real scalar run); otherwise it must have picked something runnable.
    let want = match std::env::var("ARI_SIMD").ok().as_deref().map(str::trim) {
        Some("0") | Some("scalar") | Some("off") => Some(SimdBackend::Scalar),
        Some("sse2") => Some(SimdBackend::Sse2),
        Some("avx2") => Some(SimdBackend::Avx2),
        _ => None,
    };
    let active = ari::tensor::active_backend();
    assert!(active.is_available());
    if let Some(want) = want {
        if want.is_available() {
            assert_eq!(active, want, "ARI_SIMD override not honoured");
        }
    }
}

#[test]
fn simd_paths_agree_on_strided_plan_shaped_buffers() {
    // The exact buffer shape the prepared plans use: rows embedded at a
    // stride wider than the matrix, padded widths a KERNEL_NR multiple.
    let mut rng = Pcg64::seeded(103);
    let (m, k, n) = (9usize, 40usize, 32usize);
    let stride = 56usize;
    let mut a = vec![0.0f32; m * stride];
    for r in 0..m {
        for p in 0..k {
            a[r * stride + p] = (rng.next_f32() - 0.5) * 2.0;
        }
    }
    let b = Matrix::from_fn(k, n, |_, _| (rng.next_f32() - 0.5) * 2.0);
    let mut want = vec![0.0f32; m * stride];
    matmul_strided_with(SimdBackend::Scalar, &a, stride, &b.data, k, &mut want, stride, m, n);
    for backend in available_backends() {
        let mut out = vec![0.0f32; m * stride];
        matmul_strided_with(backend, &a, stride, &b.data, k, &mut out, stride, m, n);
        assert_eq!(out, want, "{}", backend.name());
    }
}

fn fixture_backend() -> (NativeBackend, ari::data::EvalData) {
    let b = NativeBackend::from_fixtures(&[FixtureSpec::small("par", "Par", 24, 2024)]);
    let eval = b.eval_data("par").unwrap();
    (b, eval)
}

#[test]
fn fp_plan_matches_unprepared_forward_every_level() {
    // The prepared plan (pre-quantised padded weights, tiled kernel,
    // fused epilogue) against the textbook per-call path: quantise
    // operands, naive matmul, quantised epilogue — per layer, per call.
    let (mut backend, eval) = fixture_backend();
    backend.load_dataset("par").unwrap();
    let weights = backend.weights("par").unwrap().clone();
    let batch = 32;
    let x = eval.rows(0, batch).to_vec();
    for bits in fixture::FP_LEVELS {
        let fmt = FpFormat::fp(bits as u32);
        // Unprepared reference forward on the naive kernel.
        let mut h = Matrix::from_vec(batch, eval.input_dim, x.clone());
        let n = weights.layers.len();
        for (i, l) in weights.layers.iter().enumerate() {
            let mut xq = h.clone();
            fmt.quantize_slice(&mut xq.data);
            let mut wq = Matrix::from_vec(l.in_dim, l.out_dim, l.w.clone());
            fmt.quantize_slice(&mut wq.data);
            let mut out = xq.matmul_naive(&wq);
            let bq: Vec<f32> = l.b.iter().map(|&v| fmt.quantize(v)).collect();
            out.add_row(&bq);
            fmt.quantize_slice(&mut out.data);
            if i + 1 < n {
                out.prelu(l.alpha);
                fmt.quantize_slice(&mut out.data);
            }
            h = out;
        }
        h.l2_normalize_rows();

        let plan = FpPlan::new(&weights, fmt);
        for threads in [1usize, 2, 4] {
            let got = plan.forward(&x, batch, &mut Scratch::new(), threads);
            assert_eq!(got.scores.data, h.data, "FP{bits} threads={threads}");
        }
        // And the engine wrapper agrees with the plan.
        let eng = FpEngine::new(&weights, fmt).forward(&x, batch);
        assert_eq!(eng.scores.data, h.data, "FP{bits} engine wrapper");
    }
}

#[test]
fn fp_outputs_invariant_to_worker_pool_size() {
    let (mut backend, eval) = fixture_backend();
    backend.load_dataset("par").unwrap();
    let weights = backend.weights("par").unwrap().clone();
    let batch = 256;
    let x = eval.rows(0, batch).to_vec();
    let plan = FpPlan::new(&weights, FpFormat::fp(10));
    let base = plan.forward(&x, batch, &mut Scratch::new(), 1);
    for threads in [2usize, 3, 4, 7, 8] {
        let got = plan.forward(&x, batch, &mut Scratch::new(), threads);
        assert_eq!(got.scores.data, base.scores.data, "threads={threads}");
        assert_eq!(got.pred, base.pred, "threads={threads}");
        assert_eq!(got.margin, base.margin, "threads={threads}");
    }
}

#[test]
fn sc_outputs_invariant_to_worker_pool_size() {
    // The per-row (key, row_index) PCG keying is what makes this hold:
    // every row's noise stream is independent of which worker runs it.
    let (mut backend, eval) = fixture_backend();
    backend.load_dataset("par").unwrap();
    let weights = backend.weights("par").unwrap().clone();
    let batch = 32;
    let x = eval.rows(0, batch).to_vec();
    for level in [64usize, 512] {
        let plan = ScPlan::new(&weights, ScConfig::new(level));
        let base = plan.forward(&x, batch, 99, &mut Scratch::new(), 1);
        for threads in [2usize, 4, 8] {
            let got = plan.forward(&x, batch, 99, &mut Scratch::new(), threads);
            assert_eq!(got.scores.data, base.scores.data, "L={level} threads={threads}");
            assert_eq!(got.pred, base.pred);
            assert_eq!(got.margin, base.margin);
        }
        // Engine wrapper (auto thread count) must agree too.
        let eng = ScNoiseEngine::new(&weights, ScConfig::new(level)).forward(&x, batch, 99);
        assert_eq!(eng.scores.data, base.scores.data, "L={level} engine wrapper");
    }
}

#[test]
fn sc_rows_have_independent_streams() {
    // Same rows in a different batch composition keep their noise: row r
    // alone must equal row r inside a batch (per-row keying, per-row
    // operand scale).
    let (mut backend, eval) = fixture_backend();
    backend.load_dataset("par").unwrap();
    let weights = backend.weights("par").unwrap().clone();
    let plan = ScPlan::new(&weights, ScConfig::new(256));
    let batch = 8;
    let x = eval.rows(0, batch).to_vec();
    let all = plan.forward(&x, batch, 7, &mut Scratch::new(), 2);
    // Row 0 on its own: same (seed, row_index=0) stream.
    let solo = plan.forward(eval.rows(0, 1), 1, 7, &mut Scratch::new(), 1);
    assert_eq!(solo.scores.data, all.scores.data[..solo.scores.cols].to_vec());
}

#[test]
fn backend_execute_matches_plan_outputs() {
    // The served path (prepared-variant cache + scratch reuse) equals a
    // fresh plan — executing twice also exercises scratch reuse.
    let (mut backend, eval) = fixture_backend();
    let x = eval.rows(0, 32).to_vec();
    let v = backend.manifest().variant("par", VariantKind::Fp, 8, 32).unwrap().clone();
    let a = backend.execute(&v, &x, None).unwrap();
    let b = backend.execute(&v, &x, None).unwrap();
    assert_eq!(a.scores, b.scores, "scratch reuse must not change results");
    let weights = backend.weights("par").unwrap();
    let plan = FpPlan::new(weights, FpFormat::fp(8));
    let fresh = plan.forward(&x, 32, &mut Scratch::new(), 1);
    assert_eq!(a.scores, fresh.scores.data);
    assert_eq!(a.pred, fresh.pred);

    let sv = backend.manifest().variant("par", VariantKind::Sc, 512, 32).unwrap().clone();
    let key = [11u32, 13u32];
    let sa = backend.execute(&sv, &x, Some(key)).unwrap();
    let weights = backend.weights("par").unwrap();
    let seed = ((key[0] as u64) << 32) | key[1] as u64;
    let splan = ScPlan::new(weights, ScConfig::new(512));
    let sfresh = splan.forward(&x, 32, seed, &mut Scratch::new(), 3);
    assert_eq!(sa.scores, sfresh.scores.data);
}

/// The old row-major SC walk, reimplemented verbatim on the naive
/// kernel and unpadded weights: per row, per layer, an `m = 1` matmul,
/// then the noise epilogue, with one persistent per-row PCG stream.
/// This is the reference `ScPlan`'s layer-major restructure is pinned
/// against — same seed, same draws, same bits.
fn sc_row_major_reference(weights: &ari::data::Weights, x: &[f32], batch: usize, cfg: ScConfig, seed: u64) -> Matrix {
    let n_layers = weights.layers.len();
    let input_dim = weights.layers[0].in_dim;
    let n_classes = weights.layers.last().unwrap().out_dim;
    let mut scores = Matrix::zeros(batch, n_classes);
    for r in 0..batch {
        let mut rng = Pcg64::new(seed, SC_ROW_STREAM + r as u64);
        let mut h: Vec<f32> = x[r * input_dim..(r + 1) * input_dim].to_vec();
        for (li, l) in weights.layers.iter().enumerate() {
            let last = li + 1 == n_layers;
            let xmax = h.iter().fold(1e-6f32, |a, &v| a.max(v.abs())) as f64;
            let wmax = l.w.iter().fold(1e-6f32, |a, &v| a.max(v.abs())) as f64;
            let scale = xmax * wmax;
            let sigma = SC_NOISE_C / SC_LFSR_K * (l.in_dim as f64 / cfg.seq_len as f64).sqrt() * scale;
            let step = cfg.grid_step() * scale;
            let xm = Matrix::from_vec(1, l.in_dim, h.clone());
            let wm = Matrix::from_vec(l.in_dim, l.out_dim, l.w.clone());
            let mut out = xm.matmul_naive(&wm);
            for j in 0..l.out_dim {
                let v = out.data[j] + l.b[j];
                let noisy = v as f64 + sigma * rng.normal();
                let mut v = ((noisy / step).round() * step) as f32;
                if !last && v < 0.0 {
                    v *= l.alpha;
                }
                out.data[j] = v;
            }
            h = out.data;
        }
        scores.row_mut(r).copy_from_slice(&h);
    }
    // The plan's readout: L2-normalised scores snapped to the bipolar
    // 2/L counter grid.
    scores.l2_normalize_rows();
    let half = cfg.seq_len as f32 / 2.0;
    scores.map_inplace(|v| (v * half).round() / half);
    scores
}

#[test]
fn sc_layer_major_forward_bit_identical_to_row_major_reference() {
    // The layer-major restructure (one whole-shard matmul per layer)
    // must not move a single bit relative to the row-major walk: the
    // per-row PRNGs persist across layers, so each row's draw order is
    // unchanged, and the kernel's per-element accumulation order is
    // blocking-independent.
    let (mut backend, eval) = fixture_backend();
    backend.load_dataset("par").unwrap();
    let weights = backend.weights("par").unwrap().clone();
    let batch = 19; // straddles shard boundaries at every pool size
    let x = eval.rows(0, batch).to_vec();
    for level in [64usize, 512] {
        let cfg = ScConfig::new(level);
        let want = sc_row_major_reference(&weights, &x, batch, cfg, 1234);
        let plan = ScPlan::new(&weights, cfg);
        for threads in [1usize, 2, 4] {
            let got = plan.forward(&x, batch, 1234, &mut Scratch::new(), threads);
            assert_eq!(got.scores.data, want.data, "L={level} threads={threads}");
        }
    }
}

/// Persistent-pool pin: many forwards through the process-global
/// parked pool — across pool sizes (1/2/4/8), batch sizes and plan
/// kinds, interleaved — every one bit-identical to the serial path,
/// and the pool neither grows nor loses workers.
#[test]
fn persistent_pool_reuse_is_bit_identical_across_sizes() {
    let (mut backend, eval) = fixture_backend();
    backend.load_dataset("par").unwrap();
    let weights = backend.weights("par").unwrap().clone();
    let fp = FpPlan::new(&weights, FpFormat::fp(10));
    let sc = ScPlan::new(&weights, ScConfig::new(256));
    let fp_base = fp.forward(eval.rows(0, 256), 256, &mut Scratch::new(), 1);
    let sc_base = sc.forward(eval.rows(0, 32), 32, 77, &mut Scratch::new(), 1);
    let workers_before = pool::global().live_workers();
    let mut fp_scratch = Scratch::new();
    let mut sc_scratch = Scratch::new();
    for round in 0..6 {
        for threads in [1usize, 2, 4, 8] {
            let got = fp.forward(eval.rows(0, 256), 256, &mut fp_scratch, threads);
            assert_eq!(got.scores.data, fp_base.scores.data, "FP round={round} threads={threads}");
            let got = sc.forward(eval.rows(0, 32), 32, 77, &mut sc_scratch, threads);
            assert_eq!(got.scores.data, sc_base.scores.data, "SC round={round} threads={threads}");
        }
        // Interleave a different batch size through the same scratch
        // (FP rows are independent, so the first 32 rows' scores match
        // the big-batch forward exactly).
        let got = fp.forward(eval.rows(0, 32), 32, &mut fp_scratch, 4);
        let cols = fp_base.scores.cols;
        assert_eq!(got.scores.data, &fp_base.scores.data[..32 * cols], "FP small round={round}");
    }
    assert_eq!(pool::global().live_workers(), workers_before, "pool reuse must not spawn or lose threads");
}

/// Backends share the process-global parked pool: creating, executing
/// on and dropping many backends spawns no threads beyond the fixed
/// pool (the old scoped implementation spawned and joined per call).
#[test]
fn backend_create_drop_does_not_leak_threads() {
    let workers = pool::global().live_workers();
    assert_eq!(workers, pool::global().worker_count());
    assert!(pool::global().worker_count() <= pool::max_threads());
    let reference = {
        let (mut backend, eval) = fixture_backend();
        let v = backend.manifest().variant("par", VariantKind::Fp, 10, 32).unwrap().clone();
        backend.execute(&v, eval.rows(0, 32), None).unwrap().scores
    };
    for round in 0..8 {
        let (mut backend, eval) = fixture_backend();
        let v = backend.manifest().variant("par", VariantKind::Fp, 10, 32).unwrap().clone();
        let out = backend.execute(&v, eval.rows(0, 32), None).unwrap();
        assert_eq!(out.scores, reference, "round {round}");
        // backend drops here; the global pool must be unaffected.
        assert_eq!(pool::global().live_workers(), workers, "round {round}");
    }
}

#[test]
fn full_mantissa_fp_level_usable_end_to_end() {
    // m_bits = 23 (the former shift-underflow panic) through the whole
    // plan path: FpFormat::new(23, 5) must forward cleanly.
    let (mut backend, eval) = fixture_backend();
    backend.load_dataset("par").unwrap();
    let weights = backend.weights("par").unwrap().clone();
    let fmt = FpFormat::new(23, 5);
    let x = eval.rows(0, 32).to_vec();
    let out = FpPlan::new(&weights, fmt).forward(&x, 32, &mut Scratch::new(), 2);
    assert_eq!(out.pred.len(), 32);
    assert!(out.scores.data.iter().all(|v| v.is_finite()));
}
