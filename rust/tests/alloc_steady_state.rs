//! Allocation-counting hook for the zero-steady-state-allocation
//! contract (docs/PERF.md): after a short warm-up in which every
//! reusable buffer reaches its steady capacity — ladder scratch,
//! recycled ladder result, the backend's recycled output storage, plan
//! ping-pong scratch — the Immediate dispatch path from batch input to
//! filled result must perform **zero heap allocations**.
//!
//! The counting `#[global_allocator]` lives in its own test binary with
//! a single `#[test]`, so no concurrent test can allocate inside the
//! counting window.  Fixture-sized models run on the serial path (the
//! pool's work gate), which is exactly the configuration this pins; the
//! threaded path adds two small bounded per-call Vecs (documented in
//! PERF.md, not covered here).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};

use ari::config::{Mode, ThresholdPolicy};
use ari::coordinator::{Ladder, LadderBatch, LadderScratch, LadderSpec};
use ari::runtime::{Backend, NativeBackend};

struct CountingAlloc;

static COUNTING: AtomicBool = AtomicBool::new(false);
static ALLOCS: AtomicU64 = AtomicU64::new(0);

// SAFETY: a pure pass-through to `System` plus a relaxed atomic bump — every
// GlobalAlloc contract obligation is discharged by `System` itself.
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: caller obligations are exactly `System::alloc`'s; we add no state.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        // SAFETY: `layout` is forwarded unchanged from our own caller.
        unsafe { System.alloc(layout) }
    }

    // SAFETY: caller obligations are exactly `System::alloc_zeroed`'s.
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        // SAFETY: `layout` is forwarded unchanged from our own caller.
        unsafe { System.alloc_zeroed(layout) }
    }

    // SAFETY: caller obligations are exactly `System::realloc`'s.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        if COUNTING.load(Ordering::Relaxed) {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
        }
        // SAFETY: `ptr`/`layout`/`new_size` are forwarded unchanged.
        unsafe { System.realloc(ptr, layout, new_size) }
    }

    // SAFETY: caller obligations are exactly `System::dealloc`'s.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        // SAFETY: `ptr` was allocated by this allocator, i.e. by `System`.
        unsafe { System.dealloc(ptr, layout) }
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn ladder_for(engine: &mut NativeBackend, data: &ari::data::EvalData, threshold: ThresholdPolicy) -> Ladder {
    let spec = LadderSpec {
        dataset: "fashion_syn".into(),
        mode: Mode::Fp,
        levels: vec![8, 16],
        batch: 32,
        threshold,
        seed: 3,
    };
    Ladder::calibrate(engine, spec, data, 64).unwrap()
}

/// Warm four batches, then assert the next eight identical batches
/// allocate nothing and keep identical predictions.
fn assert_steady_state_allocation_free(
    engine: &mut NativeBackend,
    ladder: &Ladder,
    x: &[f32],
    n: usize,
    label: &str,
) {
    let mut scratch = LadderScratch::new();
    let mut out = LadderBatch::empty();
    // Warm-up: scratch/result/recycle-pool capacities stabilise (the
    // FP path is chunk-independent, so every round does identical work
    // and sizes).
    for chunk in 1..5u32 {
        ladder.infer_batch_into(engine, x, n, chunk, &mut scratch, &mut out).unwrap();
    }
    let want_pred = out.pred.clone();

    ALLOCS.store(0, Ordering::SeqCst);
    COUNTING.store(true, Ordering::SeqCst);
    for chunk in 5..13u32 {
        ladder.infer_batch_into(engine, x, n, chunk, &mut scratch, &mut out).unwrap();
    }
    COUNTING.store(false, Ordering::SeqCst);

    assert_eq!(out.pred, want_pred, "{label}: steady-state results must stay identical");
    assert_eq!(
        ALLOCS.load(Ordering::SeqCst),
        0,
        "{label}: steady-state Immediate dispatch (batch in -> ladder result) must not allocate"
    );
}

#[test]
fn steady_state_immediate_dispatch_is_allocation_free() {
    // Build and warm everything OUTSIDE the counting windows.
    let mut engine = NativeBackend::synthetic();
    let data = engine.eval_data("fashion_syn").unwrap();

    // Calibrated threshold, full compiled batch: the common serving
    // shape (whatever mix of accepts/escalations MMax yields).
    let mmax = ladder_for(&mut engine, &data, ThresholdPolicy::MMax);
    let x = data.rows(0, 32).to_vec();
    assert_steady_state_allocation_free(&mut engine, &mmax, &x, 32, "MMax full batch");

    // Margins never exceed sqrt(2), so T=2 escalates every row: the
    // gather path definitely runs; n=20 < 32 also exercises the padded
    // staging on both the first stage and the escalation chunk.
    let escalate_all = ladder_for(&mut engine, &data, ThresholdPolicy::Fixed(2.0));
    let x20 = data.rows(0, 20).to_vec();
    let mut probe = LadderBatch::empty();
    escalate_all
        .infer_batch_into(&mut engine, &x20, 20, 0, &mut LadderScratch::new(), &mut probe)
        .unwrap();
    assert_eq!(probe.stage_counts[1], 20, "T=2 must escalate every row");
    assert_steady_state_allocation_free(&mut engine, &escalate_all, &x20, 20, "escalate-all partial batch");
}
