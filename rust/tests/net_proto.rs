//! Property/fuzz tests for the wire-protocol decoder: the
//! [`FrameBuf`] contract is *totality* — arbitrary bytes, delivered at
//! arbitrary split boundaries, either decode, ask for more input, or
//! produce a typed [`ProtoError`]; never a panic, never a hang.  Valid
//! streams must round-trip bit-exactly (including NaN feature
//! payloads) regardless of how the bytes are chunked.

use ari::server::net::proto::{
    encode_error, encode_request, encode_response, Frame, FrameBuf, ProtoError, ResponseFrame, MAX_FRAME_LEN,
};
use ari::server::CompletionOutcome;
use ari::util::proptest::{run, Config};
use ari::util::Pcg64;

/// An owned, bit-exact record of a decoded frame (frames borrow the
/// decode buffer, so they cannot be held across `next_frame` calls).
#[derive(Debug, PartialEq, Eq)]
enum Rec {
    Req { id: u64, send_us: u64, feat_bits: Vec<u32> },
    Resp { id: u64, send_us: u64, outcome: CompletionOutcome, stage: u8, pred: i32, margin_bits: u32 },
    Err { code: u8, detail: u32 },
}

fn record(f: Frame<'_>) -> Rec {
    match f {
        Frame::Request(r) => Rec::Req {
            id: r.id,
            send_us: r.send_us,
            feat_bits: r.features().map(f32::to_bits).collect(),
        },
        Frame::Response(r) => Rec::Resp {
            id: r.id,
            send_us: r.send_us,
            outcome: r.outcome,
            stage: r.stage,
            pred: r.pred,
            margin_bits: r.margin.to_bits(),
        },
        Frame::Error(e) => Rec::Err { code: e.code, detail: e.detail },
    }
}

/// Encode a random valid frame onto `wire`, returning its record.
/// Feature rows and margins use arbitrary `u32` bit patterns (NaNs and
/// infinities included) so round-trip comparison is at the bit level.
fn push_random_frame(rng: &mut Pcg64, wire: &mut Vec<u8>) -> Rec {
    match rng.below(3) {
        0 => {
            let n = rng.below(48) as usize;
            let bits: Vec<u32> = (0..n).map(|_| rng.next_u32()).collect();
            let row: Vec<f32> = bits.iter().map(|&b| f32::from_bits(b)).collect();
            let (id, send_us) = (rng.next_u64(), rng.next_u64());
            encode_request(wire, id, send_us, &row);
            Rec::Req { id, send_us, feat_bits: bits }
        }
        1 => {
            let outcome = match rng.below(4) {
                0 => CompletionOutcome::Ok,
                1 => CompletionOutcome::Degraded,
                2 => CompletionOutcome::Rejected,
                _ => CompletionOutcome::Failed,
            };
            let r = ResponseFrame {
                id: rng.next_u64(),
                send_us: rng.next_u64(),
                outcome,
                stage: rng.below(8) as u8,
                pred: rng.next_u32() as i32,
                margin: f32::from_bits(rng.next_u32()),
            };
            encode_response(wire, &r);
            Rec::Resp {
                id: r.id,
                send_us: r.send_us,
                outcome,
                stage: r.stage,
                pred: r.pred,
                margin_bits: r.margin.to_bits(),
            }
        }
        _ => {
            let (code, detail) = (rng.below(256) as u8, rng.next_u32());
            encode_error(wire, code, detail);
            Rec::Err { code, detail }
        }
    }
}

/// Feed `wire` into a fresh decoder in random-sized chunks, draining
/// completely after each chunk.  Returns the decoded records and the
/// typed error that ended the stream, if any.
fn decode_chunked(rng: &mut Pcg64, wire: &[u8], max_chunk: u64) -> (Vec<Rec>, Option<ProtoError>) {
    let mut fb = FrameBuf::new();
    let mut got = Vec::new();
    let mut off = 0;
    while off < wire.len() {
        let chunk = (1 + rng.below(max_chunk) as usize).min(wire.len() - off);
        fb.extend(&wire[off..off + chunk]);
        off += chunk;
        loop {
            match fb.next_frame() {
                Ok(Some(f)) => got.push(record(f)),
                Ok(None) => break,
                Err(e) => return (got, Some(e)),
            }
        }
        fb.compact();
    }
    (got, None)
}

/// Totality over garbage: random bytes at random split boundaries must
/// never panic (the proptest harness catches panics), and the decode
/// loop must terminate with a bounded frame count — every yielded
/// frame consumes at least 5 wire bytes (4-byte length + 1 payload
/// byte).
#[test]
fn arbitrary_bytes_never_panic_and_terminate() {
    run(Config::cases(256), |rng| {
        let n = rng.below(600) as usize;
        let wire: Vec<u8> = (0..n).map(|_| rng.next_u32() as u8).collect();
        let (got, err) = decode_chunked(rng, &wire, 64);
        assert!(got.len() <= wire.len() / 5, "{} frames from {} bytes", got.len(), wire.len());
        if let Some(e) = err {
            // The error is typed: it has a wire code in the documented
            // taxonomy (docs/PROTOCOL.md) and a detail value.
            assert!((1..=7).contains(&e.code()), "unexpected error code {} for {e:?}", e.code());
            let _ = e.detail();
        }
    });
}

/// Valid streams round-trip bit-exactly at every split granularity —
/// byte-at-a-time up to whole-stream — with no error and no partial
/// residue.
#[test]
fn valid_streams_round_trip_bit_exact_across_splits() {
    run(Config::cases(128), |rng| {
        let n_frames = 1 + rng.below(8) as usize;
        let mut wire = Vec::new();
        let expect: Vec<Rec> = (0..n_frames).map(|_| push_random_frame(rng, &mut wire)).collect();
        let max_chunk = 1 + rng.below(wire.len() as u64 + 1);
        let (got, err) = decode_chunked(rng, &wire, max_chunk);
        assert_eq!(err, None, "valid stream must not error");
        assert_eq!(got, expect, "round trip must be bit-exact");
    });
}

/// One flipped byte in an otherwise valid stream: the decoder yields a
/// prefix of intact frames, then either a typed error or frames that
/// are merely *different* (a flipped feature bit is still a valid
/// frame) — never a panic, never more frames than the stream carried
/// bytes for.
#[test]
fn single_byte_corruption_is_typed_or_survivable() {
    run(Config::cases(192), |rng| {
        let n_frames = 1 + rng.below(6) as usize;
        let mut wire = Vec::new();
        for _ in 0..n_frames {
            push_random_frame(rng, &mut wire);
        }
        let pos = rng.below(wire.len() as u64) as usize;
        let flip = 1u8 << rng.below(8);
        wire[pos] ^= flip;
        let (got, err) = decode_chunked(rng, &wire, 32);
        assert!(got.len() <= wire.len() / 5);
        if let Some(e) = err {
            assert!((1..=7).contains(&e.code()));
        }
    });
}

/// The `Truncated` contract: any *proper* prefix of a single valid
/// frame decodes to nothing and leaves a partial buffered — the signal
/// the connection layer converts into [`ProtoError::Truncated`] on EOF
/// (the length prefix itself never errors on valid frames).
#[test]
fn every_proper_prefix_is_partial_not_error() {
    run(Config::cases(64), |rng| {
        let mut wire = Vec::new();
        push_random_frame(rng, &mut wire);
        let cut = 1 + rng.below(wire.len() as u64 - 1) as usize;
        let mut fb = FrameBuf::new();
        fb.extend(&wire[..cut]);
        match fb.next_frame() {
            Ok(None) => assert!(fb.has_partial(), "a proper prefix must leave a partial frame"),
            Ok(Some(_)) => panic!("a proper prefix must not decode to a frame"),
            Err(e) => panic!("a proper prefix of a valid frame must not error: {e:?}"),
        }
    });
}

/// A length prefix past [`MAX_FRAME_LEN`] is rejected *immediately* —
/// the decoder must not wait for (or allocate) the claimed payload.
#[test]
fn oversized_length_rejected_before_buffering_payload() {
    run(Config::cases(64), |rng| {
        let len = MAX_FRAME_LEN + 1 + rng.next_u32() % 1_000_000;
        let mut fb = FrameBuf::new();
        fb.extend(&len.to_le_bytes());
        assert_eq!(fb.next_frame().unwrap_err(), ProtoError::BadLength { len });
    });
}
