//! `PreparedQuantizer` vs scalar `FpFormat::quantize` equivalence suite:
//! the branchless bit-pattern kernel the prepared plans run must match
//! the scalar reference **bit for bit** over every constructible
//! `(m_bits, e_bits)` format — random values across the full dynamic
//! range, plus the adversarial edges (NaN payloads, ±0, subnormals,
//! halfway round-to-nearest-even ties, format bounds, infinities).
//!
//! The scalar path stays the semantic golden (it is itself pinned
//! against the python `quantize_fp`); this suite is what lets the hot
//! path swap in the prepared kernel without re-litigating quantisation
//! semantics anywhere else.

use ari::quant::FpFormat;
use ari::util::Pcg64;

fn assert_match(fmt: FpFormat, bits: u32) {
    let x = f32::from_bits(bits);
    let scalar = fmt.quantize(x);
    let prepared = fmt.prepare().quantize(x);
    assert_eq!(
        scalar.to_bits(),
        prepared.to_bits(),
        "m={} e={} bits={bits:#010x} x={x:e}: scalar {scalar:e} != prepared {prepared:e}",
        fmt.m_bits,
        fmt.e_bits
    );
}

/// Every constructible format: `m_bits` 1..=23 × `e_bits` 2..=8.
fn all_formats() -> Vec<FpFormat> {
    let mut out = Vec::new();
    for m in 1..=23u32 {
        for e in 2..=8u32 {
            out.push(FpFormat::new(m, e));
        }
    }
    out
}

#[test]
fn random_bit_patterns_every_constructible_format() {
    // Raw u64-derived bit patterns: uniform over the whole f32 space,
    // so every binade, subnormals, infs and NaNs all occur.
    let mut rng = Pcg64::seeded(0xE9);
    for fmt in all_formats() {
        for _ in 0..4_000 {
            assert_match(fmt, rng.next_u32());
        }
    }
}

#[test]
fn random_values_every_constructible_format() {
    // Value-space randoms concentrated where inference actually lives:
    // magnitudes spanning 1e-8..1e8 around each format's range.
    let mut rng = Pcg64::seeded(0xEA);
    for fmt in all_formats() {
        for _ in 0..2_000 {
            let x = (rng.next_f32() - 0.5) * 2.0 * rng.range_f64(1e-8, 1e8) as f32;
            assert_match(fmt, x.to_bits());
        }
    }
}

#[test]
fn curated_edges_every_constructible_format() {
    for fmt in all_formats() {
        let shift = 23 - fmt.m_bits;
        let mut patterns: Vec<u32> = vec![
            0x0000_0000, // +0
            0x8000_0000, // -0
            0x0000_0001, // smallest positive subnormal
            0x8000_0001,
            0x007F_FFFF, // largest subnormal
            0x0080_0000, // smallest f32 normal
            0x3F80_0000, // 1.0
            0xBF80_0000, // -1.0
            0x7F7F_FFFF, // f32::MAX
            0xFF7F_FFFF, // f32::MIN
            0x7F80_0000, // +inf
            0xFF80_0000, // -inf
            0x7FC0_0000, // canonical quiet NaN
            0x7FFF_FFFF, // NaN, max payload
            0xFFC0_0123, // negative quiet NaN with payload
            0x7F80_0001, // signalling NaN
        ];
        // The format's own bounds and their bit-neighbours.
        for base in [fmt.max_value().to_bits(), fmt.min_normal().to_bits()] {
            for delta in -3i64..=3 {
                let b = (base as i64 + delta) as u32;
                patterns.push(b);
                patterns.push(b | 0x8000_0000);
            }
        }
        // Halfway RNE ties (even and odd mantissa neighbours) in several
        // binades, when any mantissa bits are dropped.
        if shift > 0 {
            let keep = !((1u32 << shift) - 1);
            for g in [0x3F80_0000u32, 0x4000_0000, 0x3F00_0000, 0x4150_0000, 0x0080_0000] {
                let even = g & keep;
                let odd = even | (1 << shift);
                for grid in [even, odd] {
                    let tie = grid + (1 << (shift - 1));
                    patterns.push(tie);
                    patterns.push(tie | 0x8000_0000);
                    // One ULP either side of the tie breaks it.
                    patterns.push(tie - 1);
                    patterns.push(tie + 1);
                }
            }
        }
        for bits in patterns {
            assert_match(fmt, bits);
        }
    }
}

#[test]
fn prepared_idempotent_and_on_grid() {
    // Quantised output must be a fixed point of both implementations.
    let mut rng = Pcg64::seeded(0xEB);
    for fmt in [FpFormat::fp(8), FpFormat::fp(12), FpFormat::FP16, FpFormat::new(23, 5)] {
        let pq = fmt.prepare();
        for _ in 0..2_000 {
            let x = (rng.next_f32() - 0.5) * rng.range_f64(1e-4, 1e4) as f32;
            let q = pq.quantize(x);
            assert_eq!(pq.quantize(q).to_bits(), q.to_bits(), "prepared idempotency x={x}");
            assert_eq!(fmt.quantize(q).to_bits(), q.to_bits(), "cross idempotency x={x}");
        }
    }
}
