//! Property-based tests on coordinator invariants (routing, batching,
//! state) via the in-crate proptest harness — no PJRT needed, so these
//! run in any checkout.

use std::time::{Duration, Instant};

use ari::coordinator::{Batcher, BatcherPolicy};
use ari::margin::{accepts, Calibration};
use ari::util::proptest::{run, Config};
use ari::util::stats::margin_threshold;

/// Batching: any interleaving of pushes and fires conserves requests and
/// preserves FIFO order, and no fired batch ever exceeds max_batch.
#[test]
fn batcher_conservation_and_bounds() {
    run(Config::cases(128), |rng| {
        let cap = 1 + rng.below(16) as usize;
        let mut b = Batcher::new(BatcherPolicy::new(cap, Duration::from_micros(rng.below(5000))));
        let total = rng.below(300) as usize;
        let t0 = Instant::now();
        let mut out = Vec::new();
        let mut pushed = 0;
        while pushed < total || !b.is_empty() {
            if pushed < total && rng.next_f64() < 0.7 {
                b.push_at(pushed, t0 + Duration::from_micros(pushed as u64));
                pushed += 1;
            } else if let Some(batch) = b.try_fire(t0 + Duration::from_secs(3600)) {
                assert!(batch.items.len() <= cap, "batch exceeded cap");
                out.extend(batch.items.iter().map(|p| p.payload));
            }
        }
        assert_eq!(out.len(), total);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i, "FIFO violated");
        }
    });
}

/// Routing: the accept/escalate decision is a threshold function — for
/// any margins and any T, the set of accepted margins is exactly
/// {m : m > T}, and escalation_fraction is its complement's measure.
#[test]
fn routing_partition_property() {
    run(Config::cases(256), |rng| {
        let n = 1 + rng.below(500) as usize;
        let margins: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
        let t = rng.next_f64();
        let accepted = margins.iter().filter(|&&m| accepts(m, t)).count();
        let f = Calibration::escalation_fraction(&margins, t);
        assert!((f - (n - accepted) as f64 / n as f64).abs() < 1e-12);
    });
}

/// Calibration state: thresholds are monotone in coverage, and Mmax
/// dominates every changed margin.
#[test]
fn threshold_monotone_in_coverage() {
    run(Config::cases(256), |rng| {
        let n = 1 + rng.below(300) as usize;
        let margins: Vec<f64> = (0..n).map(|_| rng.next_f64()).collect();
        let mut last = f64::NEG_INFINITY;
        for cov in [0.5, 0.9, 0.95, 0.99, 1.0] {
            let t = margin_threshold(&margins, cov);
            assert!(t >= last - 1e-12, "threshold not monotone in coverage");
            last = t;
        }
        let mmax = margin_threshold(&margins, 1.0);
        for &m in &margins {
            assert!(m <= mmax + 1e-12);
        }
    });
}

/// Calibration bookkeeping: agree + changed == n, and every margin kept
/// comes from a changed element.
#[test]
fn calibration_bookkeeping() {
    run(Config::cases(256), |rng| {
        let n = rng.below(400) as usize;
        let full: Vec<i32> = (0..n).map(|_| rng.below(10) as i32).collect();
        let red: Vec<i32> = full
            .iter()
            .map(|&p| if rng.next_f64() < 0.1 { (p + 1) % 10 } else { p })
            .collect();
        let margins: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
        let cal = Calibration::from_pairs(&full, &red, &margins);
        assert_eq!(cal.agree + cal.changed_margins.len(), n);
        let expected_changed = full.iter().zip(&red).filter(|(a, b)| a != b).count();
        assert_eq!(cal.changed_margins.len(), expected_changed);
    });
}

/// The ARI acceptance rule at T = Mmax can never accept an element that
/// the calibration saw change class (soundness of the paper's rule).
#[test]
fn mmax_soundness_property() {
    run(Config::cases(256), |rng| {
        let n = 1 + rng.below(300) as usize;
        let full: Vec<i32> = (0..n).map(|_| rng.below(10) as i32).collect();
        let red: Vec<i32> = full
            .iter()
            .map(|&p| if rng.next_f64() < 0.2 { (p + 1 + rng.below(8) as i32) % 10 } else { p })
            .collect();
        let margins: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
        let cal = Calibration::from_pairs(&full, &red, &margins);
        let t = cal.threshold(ari::config::ThresholdPolicy::MMax);
        for i in 0..n {
            if full[i] != red[i] {
                assert!(!accepts(margins[i], t), "changed element {i} accepted at Mmax");
            }
        }
    });
}

/// Energy equations: E_ARI is monotone in F and in E_R; savings is the
/// exact complement of E_ARI/E_F (eq. 1 vs eq. 2 consistency).
#[test]
fn energy_equation_properties() {
    use ari::energy::EnergyModel;
    run(Config::cases(256), |rng| {
        let e_f = rng.range_f64(0.1, 5.0);
        let e_r = rng.range_f64(0.001, e_f);
        let f1 = rng.next_f64();
        let f2 = rng.next_f64();
        let (lo, hi) = if f1 < f2 { (f1, f2) } else { (f2, f1) };
        assert!(EnergyModel::ari_energy(e_r, e_f, lo) <= EnergyModel::ari_energy(e_r, e_f, hi));
        let s = EnergyModel::ari_savings(e_r, e_f, lo);
        let e = EnergyModel::ari_energy(e_r, e_f, lo);
        assert!((s - (1.0 - e / e_f)).abs() < 1e-12);
    });
}
