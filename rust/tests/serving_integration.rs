//! Server-level integration: the threaded request loop end to end
//! against real artifacts, under both escalation policies and both
//! arrival modes.
//!
//! Requires the `pjrt` cargo feature (compiled out of the default
//! feature set); the native-backend ports of these assertions live in
//! `native_serving.rs` and always run.

#![cfg(feature = "pjrt")]

use std::path::PathBuf;

use ari::config::{AriConfig, Mode, ThresholdPolicy};
use ari::coordinator::{Cascade, CascadeSpec, EscalationPolicy};
use ari::runtime::Engine;
use ari::server::{run_serving, ServeOptions};

fn artifacts() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if p.join("manifest.txt").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: no artifacts/ — run `make artifacts`");
        None
    }
}

/// A PJRT engine over the artifacts, or None (with a SKIP note) when no
/// PJRT client can be constructed — e.g. the compile-only xla stub is
/// linked instead of the real crate.
fn engine() -> Option<Engine> {
    let root = artifacts()?;
    match Engine::new(&root) {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!("SKIP: PJRT client unavailable ({e})");
            None
        }
    }
}

fn base_cfg() -> AriConfig {
    let mut cfg = AriConfig::default();
    cfg.dataset = "fashion_syn".into();
    cfg.mode = Mode::Fp;
    cfg.reduced_level = 10;
    cfg.threshold = ThresholdPolicy::MMax;
    cfg.batch_size = 32;
    cfg.requests = 256;
    cfg.batch_timeout_us = 1000;
    cfg
}

fn serve_with(cfg: &AriConfig, opts: ServeOptions) -> Option<ari::server::ServeReport> {
    let mut engine = engine()?;
    let data = engine.eval_data(&cfg.dataset).unwrap();
    let cascade = Cascade::calibrate(&mut engine, CascadeSpec::from_config(cfg), &data, 2048).unwrap();
    Some(run_serving(&mut engine, &cascade, cfg, &data, None, opts).unwrap())
}

#[test]
fn closed_loop_serves_every_request_exactly_once() {
    let cfg = base_cfg();
    let Some(report) = serve_with(&cfg, ServeOptions::default()) else { return };
    assert_eq!(report.completions.len(), cfg.requests);
    let mut ids: Vec<u64> = report.completions.iter().map(|c| c.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), cfg.requests, "duplicate or missing request ids");
    assert!(report.accuracy > 0.7, "accuracy {} too low", report.accuracy);
    assert!(report.savings() > 0.2, "savings {} too low", report.savings());
}

#[test]
fn open_loop_poisson_also_completes() {
    let mut cfg = base_cfg();
    cfg.requests = 96;
    cfg.arrival_rate = 3000.0;
    let Some(report) = serve_with(&cfg, ServeOptions::default()) else { return };
    assert_eq!(report.completions.len(), cfg.requests);
    // Open loop with a sane rate: mean latency should be bounded (batches
    // fire on deadline, 1 ms).
    assert!(report.mean_latency < std::time::Duration::from_secs(2));
}

#[test]
fn deferred_escalation_preserves_results_and_reduces_full_batches() {
    let cfg = base_cfg();
    let Some(imm) = serve_with(&cfg, ServeOptions { escalation: EscalationPolicy::Immediate }) else { return };
    let Some(def) = serve_with(&cfg, ServeOptions { escalation: EscalationPolicy::Deferred }) else { return };
    assert_eq!(imm.completions.len(), def.completions.len());
    // Same rows escalate under both policies (same threshold, same data,
    // deterministic FP path) -> same escalation fraction and accuracy.
    assert!((imm.escalation_fraction - def.escalation_fraction).abs() < 1e-9);
    assert!((imm.accuracy - def.accuracy).abs() < 1e-9);
    // And the modelled energy agrees (per-inference accounting; the
    // metrics store energy as integer nanojoules, so each add_energy_uj
    // call truncates <1 nJ — the two policies make different numbers of
    // accounting calls, hence the small tolerance).
    assert!((imm.energy_uj - def.energy_uj).abs() < 0.1, "imm {} vs def {}", imm.energy_uj, def.energy_uj);
}

#[test]
fn tiny_batch_size_one_works() {
    let mut cfg = base_cfg();
    cfg.requests = 8;
    cfg.batch_size = 32; // compiled size; the batcher may fire partial batches
    cfg.batch_timeout_us = 1; // force per-request batches
    let Some(report) = serve_with(&cfg, ServeOptions::default()) else { return };
    assert_eq!(report.completions.len(), 8);
}
