//! Deterministic-schedule model checking for the serving core: the
//! *real* `batching_loop` driven under the sim scheduler (sim channel,
//! virtual-time deadlines, sim consumer), plus single-threaded
//! dispatcher models for the SC-key and padding invariants.
//!
//! Invariants pinned here (the mutation suite proves each check really
//! fires — see `tests/model_mutations.rs`):
//!
//! * no request is dropped or duplicated at shutdown, and staging
//!   preserves arrival order;
//! * every staged batch holds `1..=max_batch` requests (shutdown
//!   drains chunk correctly);
//! * no SC batch key is ever reused across first-stage dispatches and
//!   escalation flushes;
//! * `padded_slots` balances against an independent recomputation over
//!   first-stage **and** escalation-flush padding;
//! * under an execute failure at *any* call position, every submitted
//!   request still yields exactly one typed completion;
//! * while the closed-loop controller moves accept thresholds
//!   mid-session, every submitted request still completes exactly once.
//!
//! Compiled only when the sim harness is (dev/test builds or
//! `--features sim`).
#![cfg(any(debug_assertions, feature = "sim"))]

mod model_common;

use std::time::Duration;

use ari::runtime::NativeBackend;
use ari::util::sim;
use model_common::{
    assert_conservation_under_execute_failure, assert_conservation_under_threshold_churn, assert_drain_chunked,
    assert_padding_double_entry, assert_sc_keys_unique, escalate_all_fixture, run_sim_serving_model,
};

/// Closed-loop burst through the pipelined arrival loop under random
/// schedules: 7 requests, batch 3, so size-fired batches, a partial
/// shutdown flush and channel-tail draining all occur.  Failures print
/// a one-line `ARI_REPLAY=<seed>` reproduction string.
#[test]
fn random_schedules_burst_session_conserves_requests() {
    let mut engine = NativeBackend::synthetic();
    let data = engine.eval_data("fashion_syn").unwrap();
    sim::check_random(sim::schedule_budget(250), 0x5E7_ED15, || {
        run_sim_serving_model(&data, 7, 3, Duration::from_millis(5), false);
    });
}

/// Paced arrivals against a short batcher deadline under random
/// schedules: batches fire by *virtual-time* deadline rather than
/// size, exercising `next_deadline` / `recv_timeout` / restamping.
#[test]
fn random_schedules_paced_session_fires_deadlines() {
    let mut engine = NativeBackend::synthetic();
    let data = engine.eval_data("fashion_syn").unwrap();
    sim::check_random(sim::schedule_budget(250), 0xDEAD_115E, || {
        run_sim_serving_model(&data, 5, 4, Duration::from_micros(300), true);
    });
}

/// Bounded-exhaustive pass over the smallest pipeline (2 requests,
/// batch 1, generator + loop + consumer): enumerates the leading
/// interleavings of channel, batcher and staging-queue operations.
#[test]
fn exhaustive_prefix_tiny_session_conserves_requests() {
    let mut engine = NativeBackend::synthetic();
    let data = engine.eval_data("fashion_syn").unwrap();
    sim::check_exhaustive(10_000, || {
        run_sim_serving_model(&data, 2, 1, Duration::from_millis(5), false);
    });
}

/// Shutdown drains chunk at `max_batch`: direct model of the batcher's
/// `drain_into` contract the pipeline relies on.
#[test]
fn drained_chunks_respect_max_batch() {
    assert_drain_chunked(2, 5);
    assert_drain_chunked(3, 9);
    assert_drain_chunked(4, 1);
}

/// No SC batch key reused across dispatches and escalation flushes
/// (in-dispatch and shutdown).
#[test]
fn deferred_sc_keys_are_never_reused() {
    let mut engine = NativeBackend::synthetic();
    let (ladder, data) = escalate_all_fixture(&mut engine);
    assert_sc_keys_unique(&mut engine, &ladder, &data);
}

/// `padded_slots` is exact across first-stage batches and escalation
/// flushes (double-entry against the probe stream).
#[test]
fn deferred_padded_slots_balance_double_entry() {
    let mut engine = NativeBackend::synthetic();
    let (ladder, data) = escalate_all_fixture(&mut engine);
    assert_padding_double_entry(&mut engine, &ladder, &data);
}

/// Execute fails mid-session at *every* call position in turn —
/// first-stage dispatches, in-dispatch escalation flushes and shutdown
/// flushes alike — and every submitted request still completes exactly
/// once, with the failing batch surfacing as typed `Failed`
/// completions.  Position 8 is past the session's last execute, which
/// doubles as the clean-run sanity case.
#[test]
fn execute_failure_at_every_position_conserves_completions() {
    for fail_call in 0..=8 {
        assert_conservation_under_execute_failure(fail_call);
    }
}

/// The closed-loop controller tightens thresholds between batches, so
/// queued escalations flush under different accept thresholds than
/// they were staged under — and conservation must hold regardless.
#[test]
fn threshold_churn_mid_session_conserves_completions() {
    assert_conservation_under_threshold_churn();
}
