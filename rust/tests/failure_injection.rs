//! Failure injection: every loader/runtime error path must fail loudly
//! with a useful message — naming the offending file, so a corrupt
//! artifact directory is diagnosable from the error alone — never panic
//! or silently mis-serve.  Runs entirely offline: artifact directories
//! are produced on the fly by the deterministic fixture writer, then
//! corrupted (truncated blobs, garbage metadata, malformed manifests)
//! before loading through `NativeBackend`.

use std::path::{Path, PathBuf};

use ari::data::{EvalData, Manifest, VariantKind, Weights};
use ari::runtime::fixture::{write_artifacts, FixtureSpec};
use ari::runtime::{Backend, NativeBackend};

fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("ari-fail-{name}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Write a one-dataset synthetic artifacts dir and return its path.
fn fixture_artifacts(name: &str) -> PathBuf {
    let dir = scratch(name);
    write_artifacts(&dir, &[FixtureSpec::small("tiny", "Tiny", 12, 77)]).unwrap();
    dir
}

#[test]
fn missing_manifest_is_a_clear_error() {
    let dir = scratch("nomanifest");
    let err = match NativeBackend::from_artifacts(&dir) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("expected an error"),
    };
    assert!(err.contains("make artifacts"), "unhelpful error: {err}");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn truncated_weights_blob_rejected() {
    let dir = fixture_artifacts("truncw");
    let ds = dir.join("tiny");
    let blob = std::fs::read(ds.join("weights.bin")).unwrap();
    std::fs::write(ds.join("weights.bin"), &blob[..blob.len() / 2]).unwrap();
    let err = Weights::load(&ds).unwrap_err().to_string();
    assert!(err.contains("overruns"), "{err}");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn corrupt_weights_surface_through_the_backend() {
    let dir = fixture_artifacts("backendtrunc");
    let ds = dir.join("tiny");
    let blob = std::fs::read(ds.join("weights.bin")).unwrap();
    std::fs::write(ds.join("weights.bin"), &blob[..blob.len() / 2]).unwrap();
    let mut backend = NativeBackend::from_artifacts(&dir).unwrap();
    let err = backend.load_dataset("tiny").unwrap_err().to_string();
    assert!(err.contains("overruns"), "{err}");
    std::fs::remove_dir_all(dir).ok();
}

/// `load_dataset` over a truncated weights blob: the error must name
/// the dataset and the offending file pair, not just the decode
/// failure.
#[test]
fn backend_error_names_corrupt_weights_file() {
    let dir = fixture_artifacts("namedweights");
    let ds = dir.join("tiny");
    let blob = std::fs::read(ds.join("weights.bin")).unwrap();
    std::fs::write(ds.join("weights.bin"), &blob[..blob.len() / 2]).unwrap();
    let mut backend = NativeBackend::from_artifacts(&dir).unwrap();
    let err = backend.load_dataset("tiny").unwrap_err().to_string();
    assert!(err.contains("dataset tiny"), "error must name the dataset: {err}");
    assert!(err.contains("weights.bin"), "error must name the file: {err}");
    std::fs::remove_dir_all(dir).ok();
}

/// `load_dataset` over garbage `weights.meta`: still a typed error
/// naming the file pair — never a panic.
#[test]
fn backend_error_names_malformed_weights_meta() {
    let dir = fixture_artifacts("namedmeta");
    std::fs::write(dir.join("tiny").join("weights.meta"), "this is not ari-meta\n").unwrap();
    let mut backend = NativeBackend::from_artifacts(&dir).unwrap();
    let err = backend.load_dataset("tiny").unwrap_err().to_string();
    assert!(err.contains("dataset tiny"), "error must name the dataset: {err}");
    assert!(err.contains("weights.bin/.meta"), "error must name the file pair: {err}");
    std::fs::remove_dir_all(dir).ok();
}

/// `load_dataset` over a truncated eval blob: the error names the eval
/// file pair, distinguishing it from a weights corruption.
#[test]
fn backend_error_names_truncated_eval_file() {
    let dir = fixture_artifacts("namedeval");
    let ds = dir.join("tiny");
    let blob = std::fs::read(ds.join("eval.bin")).unwrap();
    std::fs::write(ds.join("eval.bin"), &blob[..blob.len() / 2]).unwrap();
    let mut backend = NativeBackend::from_artifacts(&dir).unwrap();
    let err = backend.load_dataset("tiny").unwrap_err().to_string();
    assert!(err.contains("dataset tiny"), "error must name the dataset: {err}");
    assert!(err.contains("eval.bin"), "error must name the file: {err}");
    std::fs::remove_dir_all(dir).ok();
}

/// A malformed `manifest.txt` (bad magic, or a bad entry) fails at
/// backend open with an error naming the manifest file.
#[test]
fn malformed_manifest_error_names_the_manifest_file() {
    for (tag, text) in [
        ("magic", "not-a-manifest v9\n"),
        ("kind", "ari-manifest v1\nvariant tiny kind=quantum level=1 batch=1 file=x.hlo.txt\n"),
    ] {
        let dir = fixture_artifacts(&format!("badmanifest-{tag}"));
        std::fs::write(dir.join("manifest.txt"), text).unwrap();
        let err = match NativeBackend::from_artifacts(&dir) {
            Err(e) => e.to_string(),
            Ok(_) => panic!("malformed manifest ({tag}) must not open"),
        };
        assert!(err.contains("manifest.txt"), "error must name the manifest ({tag}): {err}");
        std::fs::remove_dir_all(dir).ok();
    }
}

#[test]
fn eval_label_count_mismatch_rejected() {
    let dir = scratch("badlabels");
    // x: (2, 3) f32, y: (3,) i32 — count mismatch.
    let mut bin = Vec::new();
    for v in [0f32; 6] {
        bin.extend_from_slice(&v.to_le_bytes());
    }
    for v in [0i32; 3] {
        bin.extend_from_slice(&v.to_le_bytes());
    }
    std::fs::write(dir.join("eval.bin"), &bin).unwrap();
    std::fs::write(
        dir.join("eval.meta"),
        "ari-meta v1\ntensor x f32 2 2 3 0 24\ntensor y i32 1 3 24 12\n",
    )
    .unwrap();
    let err = EvalData::load(&dir).unwrap_err().to_string();
    assert!(err.contains("label count"), "{err}");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn wrong_input_length_rejected_before_execution() {
    let mut engine = NativeBackend::synthetic();
    let v = engine.manifest().variant("fashion_syn", VariantKind::Fp, 16, 32).unwrap().clone();
    let err = engine.execute(&v, &[0.0f32; 10], None).unwrap_err().to_string();
    assert!(err.contains("input length"), "{err}");
}

#[test]
fn sc_variant_without_key_rejected() {
    let mut engine = NativeBackend::synthetic();
    let v = engine.manifest().variant("fashion_syn", VariantKind::Sc, 512, 32).unwrap().clone();
    let input_dim = engine.manifest().dataset("fashion_syn").unwrap().input_dim;
    let x = vec![0.0f32; 32 * input_dim];
    let err = engine.execute(&v, &x, None).unwrap_err().to_string();
    assert!(err.contains("key"), "{err}");
}

#[test]
fn padded_run_bounds_checked() {
    let mut engine = NativeBackend::synthetic();
    let v = engine.manifest().variant("fashion_syn", VariantKind::Fp, 16, 32).unwrap().clone();
    let input_dim = engine.manifest().dataset("fashion_syn").unwrap().input_dim;
    // n = 0 and n > batch both rejected
    assert!(engine.run_padded(&v, &[], 0, None).is_err());
    let x = vec![0.0f32; 33 * input_dim];
    assert!(engine.run_padded(&v, &x, 33, None).is_err());
}

#[test]
fn manifest_rejects_unknown_kind_and_bad_lines() {
    let bad = "ari-manifest v1\n\
               dataset d paper=P input_dim=4 n_classes=2 n_eval=1 train_acc=0.5\n\
               variant d kind=quantum level=1 batch=1 file=x.hlo.txt\n";
    assert!(Manifest::parse(Path::new("/tmp"), bad).is_err());
}

#[cfg(feature = "pjrt")]
mod pjrt_failures {
    //! PJRT-specific error paths (need the `pjrt` feature; skip without
    //! real artifacts — the HLO compile path needs a weights/eval pair
    //! to exist, which the fixture writer provides).

    use super::*;
    use ari::runtime::Engine;
    use std::io::Write as _;

    #[test]
    fn corrupt_hlo_file_fails_at_compile_not_at_execute() {
        let dir = super::fixture_artifacts("badhlo");
        let ds = dir.join("tiny");
        std::fs::File::create(ds.join("bad.hlo.txt")).unwrap().write_all(b"this is not HLO").unwrap();
        std::fs::write(
            dir.join("manifest.txt"),
            "ari-manifest v1\n\
             dataset tiny paper=Tiny input_dim=12 n_classes=10 n_eval=512 train_acc=0.9\n\
             variant tiny kind=fp level=16 batch=32 file=bad.hlo.txt\n",
        )
        .unwrap();
        // Engine::new only needs the manifest; if no PJRT client is
        // available in this build (stub), that is also an acceptable
        // loud failure.
        let Ok(mut engine) = Engine::new(&dir) else {
            std::fs::remove_dir_all(dir).ok();
            return;
        };
        let v = engine.manifest.variant("tiny", VariantKind::Fp, 16, 32).unwrap().clone();
        let err = engine.ensure_compiled(&v).unwrap_err().to_string();
        assert!(err.contains("bad.hlo.txt") || err.contains("parsing"), "{err}");
        std::fs::remove_dir_all(dir).ok();
    }
}
