//! Failure injection: every loader/runtime error path must fail loudly
//! with a useful message, never panic or silently mis-serve.

use std::io::Write as _;
use std::path::{Path, PathBuf};

use ari::data::{EvalData, Manifest, VariantKind, Weights};
use ari::runtime::Engine;

fn artifacts() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if p.join("manifest.txt").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: no artifacts/ — run `make artifacts`");
        None
    }
}

fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("ari-fail-{name}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn missing_manifest_is_a_clear_error() {
    let dir = scratch("nomanifest");
    let err = match Engine::new(&dir) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("expected an error"),
    };
    assert!(err.contains("make artifacts"), "unhelpful error: {err}");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn corrupt_hlo_file_fails_at_compile_not_at_execute() {
    let Some(root) = artifacts() else { return };
    // Build a scratch artifact dir with a valid manifest + weights but a
    // garbage HLO file.
    let dir = scratch("badhlo");
    let ds = dir.join("fashion_syn");
    std::fs::create_dir_all(&ds).unwrap();
    for f in ["weights.bin", "weights.meta", "eval.bin", "eval.meta"] {
        std::fs::copy(root.join("fashion_syn").join(f), ds.join(f)).unwrap();
    }
    std::fs::File::create(ds.join("bad.hlo.txt")).unwrap().write_all(b"this is not HLO").unwrap();
    std::fs::write(
        dir.join("manifest.txt"),
        "ari-manifest v1\n\
         dataset fashion_syn paper=F input_dim=784 n_classes=10 n_eval=4096 train_acc=0.9\n\
         variant fashion_syn kind=fp level=16 batch=32 file=bad.hlo.txt\n",
    )
    .unwrap();
    let mut engine = Engine::new(&dir).unwrap();
    let v = engine.manifest.variant("fashion_syn", VariantKind::Fp, 16, 32).unwrap().clone();
    let err = engine.ensure_compiled(&v).unwrap_err().to_string();
    assert!(err.contains("bad.hlo.txt") || err.contains("parsing"), "{err}");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn truncated_weights_blob_rejected() {
    let Some(root) = artifacts() else { return };
    let dir = scratch("truncw");
    let src = root.join("fashion_syn");
    let blob = std::fs::read(src.join("weights.bin")).unwrap();
    std::fs::write(dir.join("weights.bin"), &blob[..blob.len() / 2]).unwrap();
    std::fs::copy(src.join("weights.meta"), dir.join("weights.meta")).unwrap();
    let err = Weights::load(&dir).unwrap_err().to_string();
    assert!(err.contains("overruns"), "{err}");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn eval_label_count_mismatch_rejected() {
    let dir = scratch("badlabels");
    // x: (2, 3) f32, y: (3,) i32 — count mismatch.
    let mut bin = Vec::new();
    for v in [0f32; 6] {
        bin.extend_from_slice(&v.to_le_bytes());
    }
    for v in [0i32; 3] {
        bin.extend_from_slice(&v.to_le_bytes());
    }
    std::fs::write(dir.join("eval.bin"), &bin).unwrap();
    std::fs::write(
        dir.join("eval.meta"),
        "ari-meta v1\ntensor x f32 2 2 3 0 24\ntensor y i32 1 3 24 12\n",
    )
    .unwrap();
    let err = EvalData::load(&dir).unwrap_err().to_string();
    assert!(err.contains("label count"), "{err}");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn wrong_input_length_rejected_before_reaching_pjrt() {
    let Some(root) = artifacts() else { return };
    let mut engine = Engine::new(&root).unwrap();
    let v = engine.manifest.variant("fashion_syn", VariantKind::Fp, 16, 32).unwrap().clone();
    let err = engine.execute(&v, &[0.0f32; 10], None).unwrap_err().to_string();
    assert!(err.contains("input length"), "{err}");
}

#[test]
fn sc_variant_without_key_rejected() {
    let Some(root) = artifacts() else { return };
    let mut engine = Engine::new(&root).unwrap();
    let v = engine.manifest.variant("fashion_syn", VariantKind::Sc, 512, 32).unwrap().clone();
    let x = vec![0.0f32; 32 * 784];
    let err = engine.execute(&v, &x, None).unwrap_err().to_string();
    assert!(err.contains("key"), "{err}");
}

#[test]
fn padded_run_bounds_checked() {
    let Some(root) = artifacts() else { return };
    let mut engine = Engine::new(&root).unwrap();
    let v = engine.manifest.variant("fashion_syn", VariantKind::Fp, 16, 32).unwrap().clone();
    // n = 0 and n > batch both rejected
    assert!(engine.run_padded(&v, &[], 0, None).is_err());
    let x = vec![0.0f32; 33 * 784];
    assert!(engine.run_padded(&v, &x, 33, None).is_err());
}

#[test]
fn manifest_rejects_unknown_kind_and_bad_lines() {
    let bad = "ari-manifest v1\n\
               dataset d paper=P input_dim=4 n_classes=2 n_eval=1 train_acc=0.5\n\
               variant d kind=quantum level=1 batch=1 file=x.hlo.txt\n";
    assert!(Manifest::parse(Path::new("/tmp"), bad).is_err());
}
