//! Deterministic-schedule model checking for
//! `ari::util::pool::WorkerPool` — claim-loop races between the
//! submitter and the workers, batch drain, panic containment and
//! shutdown, under the sim scheduler.  Model tests build **dedicated**
//! pool instances; the process-global pool is never driven under a
//! schedule.
//!
//! Compiled only when the sim harness is (dev/test builds or
//! `--features sim`).
#![cfg(any(debug_assertions, feature = "sim"))]

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};

use ari::util::pool::WorkerPool;
use ari::util::sim;

/// Every job runs exactly once per batch, across two batches on the
/// same pool (worker reuse), under random schedules of the
/// submitter-vs-worker claim race.  Pool drop (shutdown + join) must
/// terminate under every schedule — a lost shutdown wakeup shows up as
/// a deadlock abort.
#[test]
fn random_schedules_every_job_runs_exactly_once() {
    sim::check_random(sim::schedule_budget(200), 0x9001_CAFE, || {
        let pool = WorkerPool::new(2);
        for _round in 0..2 {
            let hits: Vec<AtomicUsize> = (0..5).map(|_| AtomicUsize::new(0)).collect();
            let jobs: Vec<_> = hits
                .iter()
                .map(|h| {
                    move || {
                        h.fetch_add(1, Ordering::SeqCst);
                    }
                })
                .collect();
            pool.run(jobs);
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), 1, "job {i} ran a wrong number of times");
            }
        }
        drop(pool);
    });
}

/// Bounded-exhaustive pass over the smallest interesting pool (one
/// worker, one three-job batch): enumerates the leading interleavings
/// of the claim race and shutdown.  The full space is too large to
/// assert completeness (that is what the queue suite's tiny scenarios
/// are for); every explored schedule must still drain exactly once.
#[test]
fn exhaustive_prefix_single_worker_batch_drains() {
    sim::check_exhaustive(10_000, || {
        let pool = WorkerPool::new(1);
        let hits: Vec<AtomicUsize> = (0..3).map(|_| AtomicUsize::new(0)).collect();
        let jobs: Vec<_> = hits
            .iter()
            .map(|h| {
                move || {
                    h.fetch_add(1, Ordering::SeqCst);
                }
            })
            .collect();
        pool.run(jobs);
        for h in &hits {
            assert_eq!(h.load(Ordering::SeqCst), 1);
        }
        drop(pool);
    });
}

/// A panicking job must not poison the batch: the panic propagates to
/// the submitter *after* the batch fully drains (every other job still
/// runs exactly once), and the pool survives for the next batch —
/// under every random schedule, whichever thread claims the bad job.
#[test]
fn random_schedules_batch_drains_after_job_panic() {
    sim::check_random(sim::schedule_budget(150), 0xBAD_0B07, || {
        let pool = WorkerPool::new(2);
        let hits: Vec<AtomicUsize> = (0..5).map(|_| AtomicUsize::new(0)).collect();
        let jobs: Vec<_> = hits
            .iter()
            .enumerate()
            .map(|(i, h)| {
                move || {
                    if i == 2 {
                        panic!("job 2 exploded");
                    }
                    h.fetch_add(1, Ordering::SeqCst);
                }
            })
            .collect();
        let result = catch_unwind(AssertUnwindSafe(|| pool.run(jobs)));
        assert!(result.is_err(), "a job panic must propagate to the submitter");
        for (i, h) in hits.iter().enumerate() {
            let want = usize::from(i != 2);
            assert_eq!(h.load(Ordering::SeqCst), want, "job {i} must still run exactly once");
        }
        // The pool survives: the next batch runs normally.
        let after = AtomicUsize::new(0);
        let bump = || {
            after.fetch_add(1, Ordering::SeqCst);
        };
        pool.run(vec![bump, bump]);
        assert_eq!(after.load(Ordering::SeqCst), 2, "pool must keep working after a panicking batch");
        drop(pool);
    });
}
