//! Pure-rust MLP engine vs the backend execute path on the same weights —
//! the cross-check baseline's cost next to whatever substrate is active
//! (native in the default build, PJRT with `--features pjrt` + real
//! artifacts: XLA's fused matmuls win at batch).
//!
//! Runs against `artifacts/` when present, else the synthetic fixture.

use std::path::PathBuf;

use ari::data::VariantKind;
use ari::mlp::{FpEngine, ScNoiseEngine};
use ari::quant::FpFormat;
use ari::runtime::{open_backend, Backend, BackendKind};
use ari::sc::ScConfig;
use ari::util::benchkit::{bench, section};

fn main() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut engine = open_backend(&root, BackendKind::Auto).unwrap();
    let ds = engine.manifest().datasets[0].name.clone();
    engine.load_dataset(&ds).unwrap();
    let data = engine.eval_data(&ds).unwrap();

    section(&format!("pure-rust engines, batch 32 ({ds} topology)"));
    let x = data.rows(0, 32).to_vec();
    {
        let weights = engine.weights(&ds).unwrap();
        for bits in [16u32, 8] {
            let eng = FpEngine::new(weights, FpFormat::fp(bits));
            bench(&format!("rust FpEngine FP{bits}"), 1, 5, || {
                std::hint::black_box(eng.forward(&x, 32));
            })
            .report(Some((32, "samples")));
        }
        let sc = ScNoiseEngine::new(weights, ScConfig::new(512));
        bench("rust ScNoiseEngine L=512", 1, 5, || {
            std::hint::black_box(sc.forward(&x, 32, 7));
        })
        .report(Some((32, "samples")));
    }

    section(&format!("backend execute path ({}), batch 32 (same model)", engine.name()));
    for (kind, level, key) in
        [(VariantKind::Fp, 16usize, None), (VariantKind::Fp, 8, None), (VariantKind::Sc, 512, Some([1u32, 2u32]))]
    {
        let v = engine.manifest().variant(&ds, kind, level, 32).unwrap().clone();
        engine.execute(&v, &x, key).unwrap(); // warm compile
        bench(&format!("{} {:?} level={level}", engine.name(), kind), 2, 10, || {
            std::hint::black_box(engine.execute(&v, &x, key).unwrap());
        })
        .report(Some((32, "samples")));
    }
}
