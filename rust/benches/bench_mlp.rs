//! Pure-rust MLP engine vs the backend execute path on the same weights —
//! the cross-check baseline's cost next to whatever substrate is active
//! (native in the default build, PJRT with `--features pjrt` + real
//! artifacts: XLA's fused matmuls win at batch).
//!
//! Runs against `artifacts/` when present, else the synthetic fixture.
//!
//! With `ARI_BENCH_JSON=path` every case is also written as a machine-
//! readable `ari-bench v1` document (ns/sample and samples/s per
//! engine/variant) — `make bench-json` uses this to record the perf
//! trajectory in `BENCH_native.json`.  `ARI_BENCH_SMOKE=1` shrinks the
//! iteration counts for CI.

use std::path::PathBuf;

use ari::data::VariantKind;
use ari::mlp::{FpEngine, ScNoiseEngine};
use ari::quant::FpFormat;
use ari::runtime::{open_backend, Backend, BackendKind};
use ari::sc::ScConfig;
use ari::util::benchkit::{bench, iters, section, JsonReport};

fn main() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut engine = open_backend(&root, BackendKind::Auto).unwrap();
    let ds = engine.manifest().datasets[0].name.clone();
    engine.load_dataset(&ds).unwrap();
    let data = engine.eval_data(&ds).unwrap();
    let mut json = JsonReport::new("bench_mlp");

    section(&format!(
        "pure-rust engines, batch 32 ({ds} topology, SIMD dispatch: {})",
        ari::tensor::active_backend().name()
    ));
    let x = data.rows(0, 32).to_vec();
    {
        let weights = engine.weights(&ds).unwrap();
        let (w, n) = iters(1, 5);
        for bits in [16u32, 8] {
            let eng = FpEngine::new(weights, FpFormat::fp(bits));
            let r = bench(&format!("rust FpEngine FP{bits} b=32"), w, n, || {
                std::hint::black_box(eng.forward(&x, 32));
            });
            json.record(&r, Some((32, "samples")));
        }
        let sc = ScNoiseEngine::new(weights, ScConfig::new(512));
        let r = bench("rust ScNoiseEngine L=512 b=32", w, n, || {
            std::hint::black_box(sc.forward(&x, 32, 7));
        });
        json.record(&r, Some((32, "samples")));
    }

    for batch in [32usize, 256] {
        section(&format!(
            "backend execute path ({}), batch {batch} (prepared plans, same model)",
            engine.name()
        ));
        let xb = data.rows(0, batch).to_vec();
        let (w, n) = iters(2, 10);
        for (kind, level, key) in
            [(VariantKind::Fp, 16usize, None), (VariantKind::Fp, 8, None), (VariantKind::Sc, 512, Some([1u32, 2u32]))]
        {
            let v = engine.manifest().variant(&ds, kind, level, batch).unwrap().clone();
            engine.execute(&v, &xb, key).unwrap(); // warm compile / plan build
            let r = bench(&format!("{} {:?} level={level} b={batch}", engine.name(), kind), w, n, || {
                std::hint::black_box(engine.execute(&v, &xb, key).unwrap());
            });
            json.record(&r, Some((batch as u64, "samples")));
        }
    }

    section("per-variant accounting (backend variant_stats)");
    for s in engine.variant_stats() {
        println!(
            "{:<28} prepared in {:>8.1} µs, {:>4} executes, {:>9.0} ns/sample, {:>12.0} samples/s",
            s.key,
            s.prepare_ns as f64 / 1e3,
            s.executes,
            s.ns_per_sample(),
            s.samples_per_sec(),
        );
    }

    json.write_if_requested();
}
