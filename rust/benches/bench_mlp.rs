//! Pure-rust MLP engine vs the PJRT path on the same weights — the
//! cross-check baseline's cost, and the justification for serving through
//! PJRT (XLA's fused matmuls win at batch).
//!
//! Requires `make artifacts`; skips gracefully otherwise.

use std::path::PathBuf;

use ari::data::VariantKind;
use ari::mlp::{FpEngine, ScNoiseEngine};
use ari::quant::FpFormat;
use ari::runtime::Engine;
use ari::sc::ScConfig;
use ari::util::benchkit::{bench, section};

fn main() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !root.join("manifest.txt").exists() {
        eprintln!("SKIP bench_mlp: run `make artifacts` first");
        return;
    }
    let mut engine = Engine::new(&root).unwrap();
    let ds = "fashion_syn";
    engine.load_dataset(ds).unwrap();
    let data = engine.eval_data(ds).unwrap();

    section("pure-rust engines, batch 32 (fashion topology)");
    let x = data.rows(0, 32).to_vec();
    {
        let weights = engine.weights(ds).unwrap();
        for bits in [16u32, 8] {
            let eng = FpEngine::new(weights, FpFormat::fp(bits));
            bench(&format!("rust FpEngine FP{bits}"), 1, 5, || {
                std::hint::black_box(eng.forward(&x, 32));
            })
            .report(Some((32, "samples")));
        }
        let sc = ScNoiseEngine::new(weights, ScConfig::new(512));
        bench("rust ScNoiseEngine L=512", 1, 5, || {
            std::hint::black_box(sc.forward(&x, 32, 7));
        })
        .report(Some((32, "samples")));
    }

    section("PJRT path, batch 32 (same model)");
    for (kind, level, key) in
        [(VariantKind::Fp, 16usize, None), (VariantKind::Fp, 8, None), (VariantKind::Sc, 512, Some([1u32, 2u32]))]
    {
        let v = engine.manifest.variant(ds, kind, level, 32).unwrap().clone();
        engine.execute(&v, &x, key).unwrap(); // warm compile
        bench(&format!("pjrt {:?} level={level}", kind), 2, 10, || {
            std::hint::black_box(engine.execute(&v, &x, key).unwrap());
        })
        .report(Some((32, "samples")));
    }
}
