//! Quantisation substrate benchmarks: FpFormat::quantize throughput and
//! the pure-rust reduced-precision layer (the rust twin of the L1 Pallas
//! kernel's epilogue).  Hot on the SC-exact and cross-check paths.

use ari::quant::{quant_layer, FpFormat};
use ari::tensor::Matrix;
use ari::util::benchkit::{bench, section};
use ari::util::Pcg64;

fn main() {
    section("FpFormat::quantize scalar throughput");
    let mut rng = Pcg64::seeded(1);
    let xs: Vec<f32> = (0..65536).map(|_| rng.next_f32() * 100.0 - 50.0).collect();
    for bits in [8u32, 10, 12, 16] {
        let fmt = FpFormat::fp(bits);
        let mut acc = 0.0f32;
        bench(&format!("quantize 64k values, FP{bits}"), 3, 20, || {
            let mut local = 0.0f32;
            for &x in &xs {
                local += fmt.quantize(x);
            }
            acc += local;
        })
        .report(Some((xs.len() as u64, "vals")));
        std::hint::black_box(acc);
    }

    section("quant_layer (batch 32) — rust twin of the L1 kernel");
    let mut rng = Pcg64::seeded(2);
    for (k, n) in [(784usize, 1024usize), (1024, 512), (256, 10)] {
        let x = Matrix::from_fn(32, k, |_, _| rng.next_f32() - 0.5);
        let w = Matrix::from_fn(k, n, |_, _| (rng.next_f32() - 0.5) * 0.1);
        let b = vec![0.01f32; n];
        for bits in [8u32, 16] {
            let fmt = FpFormat::fp(bits);
            bench(&format!("layer {k}x{n}, FP{bits}"), 2, 10, || {
                std::hint::black_box(quant_layer(&x, &w, &b, 0.25, fmt, true));
            })
            .report(Some(((32 * k * n) as u64, "MAC")));
        }
    }
}
