//! Quantisation substrate benchmarks: the scalar `FpFormat::quantize`
//! reference next to the prepared paths serving actually runs — the
//! branchless `PreparedQuantizer` slice kernel and the prepared
//! `FpPlan` forward (pre-quantised packed weights, prepared epilogue) —
//! plus the historical unprepared `quant_layer` for the delta.
//!
//! With `ARI_BENCH_JSON=path` every case is recorded in the `ari-bench
//! v1` document, so `make bench-json` captures the prepared/unprepared
//! quantisation delta per commit alongside the SIMD pairs.

use ari::data::{LayerWeights, Weights};
use ari::mlp::{FpPlan, Scratch};
use ari::quant::{quant_layer, FpFormat};
use ari::tensor::Matrix;
use ari::util::benchkit::{bench, iters, section, JsonReport};
use ari::util::Pcg64;

fn main() {
    let mut json = JsonReport::new("bench_quant");

    section("FpFormat::quantize scalar vs PreparedQuantizer (64k values)");
    let mut rng = Pcg64::seeded(1);
    let xs: Vec<f32> = (0..65536).map(|_| rng.next_f32() * 100.0 - 50.0).collect();
    let (w, n) = iters(3, 20);
    for bits in [8u32, 10, 12, 16] {
        let fmt = FpFormat::fp(bits);
        let mut acc = 0.0f32;
        let r = bench(&format!("scalar quantize 64k values, FP{bits}"), w, n, || {
            let mut local = 0.0f32;
            for &x in &xs {
                local += fmt.quantize(x);
            }
            acc += local;
        });
        json.record(&r, Some((xs.len() as u64, "vals")));
        std::hint::black_box(acc);

        let pq = fmt.prepare();
        let mut buf = xs.clone();
        let r = bench(&format!("prepared quantize 64k values, FP{bits}"), w, n, || {
            buf.copy_from_slice(&xs);
            pq.quantize_slice(&mut buf);
            std::hint::black_box(&buf);
        });
        json.record(&r, Some((xs.len() as u64, "vals")));
    }

    section("quant_layer (unprepared, batch 32) vs prepared FpPlan forward");
    let mut rng = Pcg64::seeded(2);
    let (w, n) = iters(2, 10);
    for (k, nn) in [(784usize, 1024usize), (1024, 512), (256, 10)] {
        let x = Matrix::from_fn(32, k, |_, _| rng.next_f32() - 0.5);
        let wm = Matrix::from_fn(k, nn, |_, _| (rng.next_f32() - 0.5) * 0.1);
        let b = vec![0.01f32; nn];
        let weights = Weights {
            layers: vec![LayerWeights { w: wm.data.clone(), in_dim: k, out_dim: nn, b: b.clone(), alpha: 0.25 }],
        };
        for bits in [8u32, 16] {
            let fmt = FpFormat::fp(bits);
            let r = bench(&format!("unprepared quant_layer {k}x{nn}, FP{bits}"), w, n, || {
                std::hint::black_box(quant_layer(&x, &wm, &b, 0.25, fmt, true));
            });
            json.record(&r, Some(((32 * k * nn) as u64, "MAC")));

            // What serving runs: weights pre-quantised/packed once, the
            // prepared-quantiser epilogue, reusable scratch.  Pinned to
            // one worker so this pair isolates the preparation effect —
            // quant_layer above is single-threaded too; the threaded
            // delta is bench_mlp/bench_runtime territory.
            let plan = FpPlan::new(&weights, fmt);
            let mut scratch = Scratch::new();
            let r = bench(&format!("prepared FpPlan {k}x{nn}, FP{bits} b=32"), w, n, || {
                std::hint::black_box(plan.forward(&x.data, 32, &mut scratch, 1));
            });
            json.record(&r, Some(((32 * k * nn) as u64, "MAC")));
        }
    }

    json.write_if_requested();
}
