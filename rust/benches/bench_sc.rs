//! Stochastic-computing substrate benchmarks: LFSR state generation,
//! SNG packing, XNOR+popcount multiply, exact dot product, and the
//! stanh FSM ablation (readout-domain vs stochastic-domain activation).

use ari::sc::fsm::StanhFsm;
use ari::sc::sng::{count_ones, Sng};
use ari::sc::{sc_dot, Lfsr, ScConfig};
use ari::util::benchkit::{bench, section};

fn main() {
    section("LFSR state generation");
    for width in [10u32, 16] {
        bench(&format!("lfsr width={width}, 65536 states"), 2, 20, || {
            let mut l = Lfsr::new(width, 0xACE1);
            let mut acc = 0u32;
            for _ in 0..65536 {
                acc ^= l.next_state();
            }
            std::hint::black_box(acc);
        })
        .report(Some((65536, "states")));
    }

    section("SNG packing (bits -> u64 words)");
    for l in [1024usize, 4096] {
        bench(&format!("sng pack L={l}"), 2, 50, || {
            let mut s = Sng::bipolar(0.37, 16, 12345);
            std::hint::black_box(s.bits_packed(l));
        })
        .report(Some((l as u64, "bits")));
    }

    section("bitstream multiply-accumulate (XNOR + popcount)");
    for l in [1024usize, 4096] {
        let mut a = Sng::bipolar(0.5, 16, 1);
        let mut b = Sng::bipolar(-0.3, 16, 99);
        let pa = a.bits_packed(l);
        let pb = b.bits_packed(l);
        bench(&format!("xnor+popcount L={l}"), 5, 200, || {
            std::hint::black_box(ari::sc::ops::product_ones(&pa, &pb, l));
        })
        .report(Some((l as u64, "bits")));
    }

    section("exact SC dot product (fan_in=128, n_out=8)");
    let x: Vec<f32> = (0..128).map(|i| ((i % 17) as f32 / 17.0) - 0.5).collect();
    let w: Vec<f32> = (0..128 * 8).map(|i| ((i % 23) as f32 / 23.0) - 0.5).collect();
    for l in [256usize, 1024, 4096] {
        bench(&format!("sc_dot L={l}"), 1, 5, || {
            std::hint::black_box(sc_dot(&x, &w, 8, ScConfig::new(l), 7));
        })
        .report(Some(((128 * 8 * l) as u64, "bitops")));
    }

    section("activation ablation: stanh FSM vs readout PReLU");
    let mut s = Sng::bipolar(0.3, 16, 5);
    let stream = s.bits_packed(4096);
    bench("stanh FSM over L=4096", 5, 100, || {
        let mut fsm = StanhFsm::new(8);
        std::hint::black_box(fsm.run_packed(&stream, 4096));
    })
    .report(Some((4096, "bits")));
    bench("readout PReLU (decode + compare)", 5, 100, || {
        let v = 2.0 * count_ones(&stream, 4096) as f64 / 4096.0 - 1.0;
        std::hint::black_box(if v < 0.0 { 0.25 * v } else { v });
    })
    .report(None);
}
