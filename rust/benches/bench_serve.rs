//! Open-loop serving bench — the request-to-completion pipeline under
//! load: Poisson arrivals at several rates, Immediate and Deferred
//! escalation, 2- and 3-level FP ladders, plus a closed-loop
//! throughput-ceiling point per ladder.
//!
//! Per session it reports p50/p95/p99 latency, mean queue wait and
//! completions/sec; with `ARI_BENCH_JSON` set every session becomes a
//! group of `ari-bench v1` entries (see docs/PERF.md for the record
//! format) — `make bench-serve` drives this into `BENCH_serve.json`, so
//! the serving trajectory is tracked per commit alongside the kernel
//! benches in `BENCH_native.json`.  Every session entry also carries
//! the robustness counters (`accuracy`, `degraded`, `rejected`,
//! `failed`, `retries`), and a final section records the
//! accuracy-vs-latency frontier of ladder-native graceful degradation
//! under injected overload (`exec-delay` faults; see
//! docs/ROBUSTNESS.md).  `ARI_BENCH_SMOKE=1` shrinks the request
//! counts for CI.

use ari::config::{AriConfig, Mode, ThresholdPolicy};
use ari::coordinator::{EscalationPolicy, Ladder, LadderSpec};
use ari::runtime::fixture::{drift_eval, DriftSpec};
use ari::runtime::{Backend, NativeBackend};
use ari::server::net::client::{run_client, ClientConfig};
use ari::server::net::run_net_serving;
use ari::server::{run_serving_ladder, ServeOptions, ServeReport};
use ari::util::benchkit::{section, smoke, BenchResult, JsonReport};
use ari::util::fault;

/// Shrink a request count for smoke runs.
fn req(n: usize) -> usize {
    if smoke() {
        n / 8
    } else {
        n
    }
}

/// Record one serving session: a wall-time entry whose `items_per_sec`
/// is completions/sec — carrying the session's accuracy and robustness
/// counters as extra fields — plus one entry per latency quantile and
/// the mean queue wait (their `mean_ns` carries the metric; no item
/// counts).
fn record(json: &mut JsonReport, name: &str, r: &ServeReport) {
    json.add_extra(
        &BenchResult { name: name.to_string(), mean_ns: r.wall.as_nanos() as f64, std_ns: 0.0, iters: 1 },
        Some(r.completions.len() as u64),
        &[
            ("accuracy", r.accuracy),
            ("degraded", r.degraded as f64),
            ("rejected", r.rejected as f64),
            ("failed", r.failed as f64),
            ("retries", r.retries as f64),
        ],
    );
    for (suffix, d) in
        [("p50", r.p50), ("p95", r.p95), ("p99", r.p99), ("queue_wait", r.queue_wait_mean)]
    {
        json.add(
            &BenchResult {
                name: format!("{name} {suffix}"),
                mean_ns: d.as_nanos() as f64,
                std_ns: 0.0,
                iters: 1,
            },
            None,
        );
    }
}

/// Run one serving session.  `faults` (a `util::fault` spec) is armed
/// *after* calibration, so injected faults hit only the serving
/// pipeline — the same placement the `ari serve --faults` flag uses;
/// `tweak` applies config overrides (e.g. an overload threshold) on top
/// of the bench defaults.
fn session_with(
    levels: &[usize],
    rate: f64,
    requests: usize,
    policy: EscalationPolicy,
    faults: Option<&str>,
    tweak: impl FnOnce(&mut AriConfig),
) -> ServeReport {
    let mut engine = NativeBackend::synthetic();
    let data = engine.eval_data("fashion_syn").unwrap();
    let mut cfg = AriConfig::default();
    cfg.dataset = "fashion_syn".into();
    cfg.mode = Mode::Fp;
    cfg.batch_size = 32;
    cfg.requests = requests;
    cfg.arrival_rate = rate;
    cfg.batch_timeout_us = 500;
    tweak(&mut cfg);
    let spec = LadderSpec {
        dataset: cfg.dataset.clone(),
        mode: Mode::Fp,
        levels: levels.to_vec(),
        batch: cfg.batch_size,
        threshold: ThresholdPolicy::MMax,
        seed: cfg.seed as u32,
    };
    let ladder = Ladder::calibrate(&mut engine, spec, &data, data.n / 2).unwrap();
    let _armed = faults.map(fault::ArmGuard::arm);
    run_serving_ladder(&mut engine, &ladder, &cfg, &data, None, ServeOptions { escalation: policy })
        .unwrap()
}

fn session(levels: &[usize], rate: f64, requests: usize, policy: EscalationPolicy) -> ServeReport {
    session_with(levels, rate, requests, policy, None, |_| {})
}

/// One drifted serving session for the control frontier: the 3-level
/// ladder is calibrated on the *clean* eval split, the request stream
/// is drawn from a drifted copy (the deterministic fixture transform),
/// and `exec-delay` spikes load the pipeline.  `adaptive` flips every
/// `[control]` mode on (with bands sized for the bench's scale);
/// `false` serves the same stream on static calibrated thresholds.
fn drift_session(adaptive: bool) -> ServeReport {
    let mut engine = NativeBackend::synthetic();
    let data = engine.eval_data("fashion_syn").unwrap();
    let mut cfg = AriConfig::default();
    cfg.dataset = "fashion_syn".into();
    cfg.mode = Mode::Fp;
    cfg.batch_size = 32;
    cfg.requests = req(512);
    cfg.arrival_rate = 8000.0;
    cfg.batch_timeout_us = 500;
    if adaptive {
        cfg.control_per_class = true;
        cfg.control_load_adaptive = true;
        cfg.control_drift = true;
        cfg.control_queue_high = 64;
        cfg.control_queue_low = 8;
        cfg.control_p95_high_us = 0; // queue signal only: rate-independent
        cfg.control_drift_window = 128;
        cfg.control_drift_tolerance = 0.05;
        cfg.control_recal_min = 32;
    }
    let spec = LadderSpec {
        dataset: cfg.dataset.clone(),
        mode: Mode::Fp,
        levels: vec![8, 12, 16],
        batch: cfg.batch_size,
        threshold: ThresholdPolicy::MMax,
        seed: cfg.seed as u32,
    };
    let ladder = Ladder::calibrate(&mut engine, spec, &data, data.n / 2).unwrap();
    let mut drifted = data.clone();
    drift_eval(&mut drifted, &DriftSpec::default());
    let _armed = fault::ArmGuard::arm("exec-delay:0.5@7");
    run_serving_ladder(
        &mut engine,
        &ladder,
        &cfg,
        &drifted,
        None,
        ServeOptions { escalation: EscalationPolicy::Deferred },
    )
    .unwrap()
}

fn main() {
    let mut json = JsonReport::new("bench_serve");

    section("pipelined serving: open-loop Poisson x escalation policy x ladder depth (FP @ Mmax)");
    println!(
        "{:<40} {:>9} {:>10} {:>10} {:>10} {:>11}",
        "case", "req/s", "p50", "p95", "p99", "queue wait"
    );
    for levels in [&[8usize, 16][..], &[8, 12, 16][..]] {
        for rate in [2000.0f64, 8000.0] {
            for (pname, policy) in
                [("imm", EscalationPolicy::Immediate), ("def", EscalationPolicy::Deferred)]
            {
                let r = session(levels, rate, req(768), policy);
                let name = format!("{}L {pname} rate={rate:.0}", levels.len());
                record(&mut json, &name, &r);
                println!(
                    "{:<40} {:>9.0} {:>10.1?} {:>10.1?} {:>10.1?} {:>11.1?}",
                    name, r.throughput_rps, r.p50, r.p95, r.p99, r.queue_wait_mean
                );
            }
        }
    }

    section("closed-loop throughput ceiling (no pacing)");
    for levels in [&[8usize, 16][..], &[8, 12, 16][..]] {
        let r = session(levels, 0.0, req(1024), EscalationPolicy::Immediate);
        let name = format!("{}L imm closed-loop", levels.len());
        record(&mut json, &name, &r);
        println!(
            "{:<40} {:>9.0} {:>10.1?} {:>10.1?} {:>10.1?} {:>11.1?}",
            name, r.throughput_rps, r.p50, r.p95, r.p99, r.queue_wait_mean
        );
    }

    // Wire tier: the same pipeline behind the length-prefixed TCP
    // front-end, driven by the real load generator over loopback.  The
    // client's echoed send stamps measure true round-trip wire latency
    // (both directions plus full server residency); the server entry
    // splits pre-dispatch wait into ingress (net) and batcher (queue)
    // components.
    section("loopback TCP serving: round-trip wire latency over 127.0.0.1 (closed loop x 8)");
    println!("{:<40} {:>9} {:>10} {:>10} {:>10} {:>11}", "case", "req/s", "p50", "p95", "p99", "net wait");
    {
        let mut engine = NativeBackend::synthetic();
        let data = engine.eval_data("fashion_syn").unwrap();
        let mut cfg = AriConfig::default();
        cfg.dataset = "fashion_syn".into();
        cfg.mode = Mode::Fp;
        cfg.batch_size = 32;
        cfg.requests = req(768);
        cfg.batch_timeout_us = 500;
        cfg.net_linger_us = 100_000;
        let spec = LadderSpec {
            dataset: cfg.dataset.clone(),
            mode: Mode::Fp,
            levels: vec![8, 16],
            batch: cfg.batch_size,
            threshold: ThresholdPolicy::MMax,
            seed: cfg.seed as u32,
        };
        let ladder = Ladder::calibrate(&mut engine, spec, &data, data.n / 2).unwrap();
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let mut ccfg = ClientConfig::default();
        ccfg.addr = listener.local_addr().unwrap().to_string();
        ccfg.requests = cfg.requests;
        ccfg.seed = cfg.seed;
        let cdata = data.clone();
        // ari-lint: allow(sim-discipline): the bench client models the outside world
        // on a real thread over a real socket — kernel TCP cannot run under the sim
        // scheduler.
        let client = std::thread::spawn(move || run_client(&ccfg, &cdata));
        let r = run_net_serving(&mut engine, &ladder, &cfg, data.input_dim, ServeOptions::default(), listener)
            .unwrap();
        let c = client.join().expect("bench client panicked").unwrap();
        let name = "2L imm tcp closed-loop";
        json.add_extra(
            &BenchResult { name: name.to_string(), mean_ns: c.wall.as_nanos() as f64, std_ns: 0.0, iters: 1 },
            Some(c.received),
            &[
                ("sent", c.sent as f64),
                ("lost", c.lost as f64),
                ("reconnects", c.reconnects as f64),
                ("shed", r.shed as f64),
            ],
        );
        for (suffix, d) in [
            ("wire p50", c.p50),
            ("wire p95", c.p95),
            ("wire p99", c.p99),
            ("net_wait", r.net_wait_mean),
            ("queue_wait", r.queue_wait_mean),
        ] {
            json.add(
                &BenchResult {
                    name: format!("{name} {suffix}"),
                    mean_ns: d.as_nanos() as f64,
                    std_ns: 0.0,
                    iters: 1,
                },
                None,
            );
        }
        println!(
            "{:<40} {:>9.0} {:>10.1?} {:>10.1?} {:>10.1?} {:>11.1?}",
            name, r.throughput_rps, c.p50, c.p95, c.p99, r.net_wait_mean
        );
    }

    // Graceful-degradation frontier: the same overloaded session
    // (injected exec-delay latency spikes under open-loop pressure) at
    // tightening overload thresholds.  As the threshold drops, more
    // batches are served the reduced-stage answer: p95 falls, accuracy
    // gives a little — the accuracy-vs-latency tradeoff the degradation
    // policy buys under overload.  Deterministic fault seed, so the
    // frontier is comparable across commits.
    section("graceful degradation: accuracy vs latency under injected overload (exec-delay:0.5@7)");
    println!(
        "{:<40} {:>9} {:>10} {:>9} {:>9} {:>9}",
        "case", "req/s", "p95", "accuracy", "degraded", "retries"
    );
    for (cname, overload_queue) in [("off", 0usize), ("depth=64", 64), ("depth=32", 32)] {
        let r = session_with(
            &[8, 12, 16],
            8000.0,
            req(512),
            EscalationPolicy::Deferred,
            Some("exec-delay:0.5@7"),
            |cfg| cfg.overload_queue = overload_queue,
        );
        let name = format!("3L def overloaded {cname}");
        record(&mut json, &name, &r);
        println!(
            "{:<40} {:>9.0} {:>10.1?} {:>9.4} {:>9} {:>9}",
            name, r.throughput_rps, r.p95, r.accuracy, r.degraded, r.retries
        );
    }

    // Self-stabilizing control frontier: calibrate on the clean split,
    // then serve a *drifted* request stream (the deterministic fixture
    // drift transform) under the same injected overload — once with
    // static calibrated thresholds and once with the closed-loop
    // controller fully enabled (per-class + load-adaptive + drift
    // recalibration).  The frontier tracked per commit is
    // accuracy vs modelled energy vs p95 (see docs/ROBUSTNESS.md,
    // section *Control loop*).
    section("closed-loop control: adaptive vs static thresholds under input drift (exec-delay:0.5@7)");
    println!(
        "{:<40} {:>9} {:>10} {:>9} {:>11} {:>7}",
        "case", "req/s", "p95", "accuracy", "energy/inf", "events"
    );
    for (cname, adaptive) in [("static", false), ("adaptive", true)] {
        let r = drift_session(adaptive);
        let name = format!("3L def drifted {cname}");
        record(&mut json, &name, &r);
        let per_inf = r.energy_uj / r.completions.len().max(1) as f64;
        json.add_extra(
            &BenchResult {
                name: format!("{name} energy"),
                mean_ns: r.energy_uj,
                std_ns: 0.0,
                iters: 1,
            },
            None,
            &[("energy_full_uj", r.energy_full_uj), ("energy_per_inf_uj", per_inf)],
        );
        println!(
            "{:<40} {:>9.0} {:>10.1?} {:>9.4} {:>11.3} {:>7}",
            name,
            r.throughput_rps,
            r.p95,
            r.accuracy,
            per_inf,
            r.control_events.len()
        );
    }

    json.write_if_requested();
}
