//! End-to-end ARI serving bench — the paper's headline, as a serving
//! system: throughput, latency and energy savings of the cascade vs the
//! always-full baseline, plus the batching-policy ablation (batch size ×
//! escalation policy) called out in DESIGN.md §8.
//!
//! Runs against `artifacts/` when present (PJRT with `--features pjrt`),
//! else the synthetic fixture on the native backend.  `ARI_BENCH_JSON`
//! additionally records each serving session (ns/request, req/s) in the
//! machine-readable `ari-bench v1` document; `ARI_BENCH_SMOKE=1` shrinks
//! the request counts.

use std::path::PathBuf;

use ari::config::{AriConfig, Mode, ThresholdPolicy};
use ari::coordinator::{Cascade, CascadeSpec, EscalationPolicy};
use ari::runtime::{open_backend, Backend, BackendKind};
use ari::server::{run_serving, ServeOptions, ServeReport};
use ari::util::benchkit::{section, smoke, BenchResult, JsonReport};

/// Shrink a request count for smoke runs.
fn req(n: usize) -> usize {
    if smoke() {
        n / 8
    } else {
        n
    }
}

/// Record one serving session as a bench entry: one "iteration" of
/// `completions` items, so ns_per_item is ns/request and items_per_sec
/// is req/s.
fn record(json: &mut JsonReport, name: &str, r: &ServeReport) {
    let result = BenchResult {
        name: name.to_string(),
        mean_ns: r.wall.as_nanos() as f64,
        std_ns: 0.0,
        iters: 1,
    };
    json.add(&result, Some(r.completions.len() as u64));
}

fn main() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut json = JsonReport::new("bench_cascade");

    section("ARI cascade vs always-full, fashion_syn FP10 (closed loop, 1024 req)");
    println!(
        "{:<34} {:>10} {:>9} {:>9} {:>10} {:>8}",
        "case", "req/s", "p50", "p99", "energy µJ", "savings"
    );
    for (name, reduced, threshold) in [
        // baseline: reduced IS the full model and nothing ever escalates
        // (T = -1 accepts every margin) -> exactly one full-cost pass.
        ("always-full (reduced=full)", 16usize, ThresholdPolicy::Fixed(-1.0)),
        ("ARI @ Mmax", 10, ThresholdPolicy::MMax),
        ("ARI @ M99", 10, ThresholdPolicy::M99),
        ("ARI @ M95", 10, ThresholdPolicy::M95),
    ] {
        let mut cfg = AriConfig::default();
        cfg.artifacts = root.clone();
        cfg.dataset = "fashion_syn".into();
        cfg.mode = Mode::Fp;
        cfg.reduced_level = reduced;
        cfg.threshold = threshold;
        cfg.batch_size = 32;
        cfg.requests = req(1024);
        let mut engine = open_backend(&root, BackendKind::Auto).unwrap();
        let data = engine.eval_data(&cfg.dataset).unwrap();
        let n_calib = data.n / 2;
        let cascade = Cascade::calibrate(engine.as_mut(), CascadeSpec::from_config(&cfg), &data, n_calib).unwrap();
        let r = run_serving(engine.as_mut(), &cascade, &cfg, &data, None, ServeOptions::default()).unwrap();
        record(&mut json, name, &r);
        println!(
            "{:<34} {:>10.0} {:>9.1?} {:>9.1?} {:>10.1} {:>7.1}%",
            name,
            r.throughput_rps,
            r.p50,
            r.p99,
            r.energy_uj,
            100.0 * r.savings()
        );
    }

    section("batching ablation: batch size x escalation policy (FP10 @ Mmax, 512 req)");
    println!("{:<34} {:>10} {:>9} {:>9}", "case", "req/s", "p50", "p99");
    for batch in [32usize, 256] {
        for (pname, policy) in [("immediate", EscalationPolicy::Immediate), ("deferred", EscalationPolicy::Deferred)] {
            let mut cfg = AriConfig::default();
            cfg.artifacts = root.clone();
            cfg.dataset = "fashion_syn".into();
            cfg.reduced_level = 10;
            cfg.batch_size = batch;
            cfg.requests = req(512);
            let mut engine = open_backend(&root, BackendKind::Auto).unwrap();
            let data = engine.eval_data(&cfg.dataset).unwrap();
            let n_calib = data.n / 2;
            let cascade = Cascade::calibrate(engine.as_mut(), CascadeSpec::from_config(&cfg), &data, n_calib).unwrap();
            let r = run_serving(engine.as_mut(), &cascade, &cfg, &data, None, ServeOptions { escalation: policy }).unwrap();
            record(&mut json, &format!("b={batch} {pname}"), &r);
            println!("{:<34} {:>10.0} {:>9.1?} {:>9.1?}", format!("b={batch} {pname}"), r.throughput_rps, r.p50, r.p99);
        }
    }

    section("SC cascade, fashion_syn L=512 @ Mmax (512 req)");
    let mut cfg = AriConfig::default();
    cfg.artifacts = root.clone();
    cfg.dataset = "fashion_syn".into();
    cfg.mode = Mode::Sc;
    cfg.reduced_level = 512;
    cfg.full_level = 4096;
    cfg.batch_size = 32;
    cfg.requests = req(512);
    let mut engine = open_backend(&root, BackendKind::Auto).unwrap();
    let data = engine.eval_data(&cfg.dataset).unwrap();
    let n_calib = data.n / 2;
    let cascade = Cascade::calibrate(engine.as_mut(), CascadeSpec::from_config(&cfg), &data, n_calib).unwrap();
    let r = run_serving(engine.as_mut(), &cascade, &cfg, &data, None, ServeOptions::default()).unwrap();
    record(&mut json, "SC L=512 @ Mmax", &r);
    println!("{}", r.summary());
    json.write_if_requested();
}
