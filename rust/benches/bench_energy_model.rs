//! Tables I & II regeneration bench: evaluates the calibrated energy
//! model across the paper's grid and times it (the model sits on the
//! serving hot path — one call per request).

use ari::energy::{self, EnergyModel};
use ari::quant::FpFormat;
use ari::sc::ScConfig;
use ari::util::benchkit::{bench, section};

fn main() {
    section("Table I / Table II regeneration (see `ari experiment table1|table2`)");
    let fp_model = EnergyModel::for_input_dim(784);
    for (bits, paper) in energy::TABLE_I {
        let got = fp_model.fp_energy(FpFormat::fp(bits));
        println!("FP{bits:<3} paper {paper:.2} µJ  model {got:.3} µJ");
    }
    let sc_model = EnergyModel { macs: energy::table_ii_reference_macs() };
    for (l, paper) in energy::TABLE_II {
        let got = sc_model.sc_energy(ScConfig::new(l));
        println!("L={l:<5} paper {paper:.2} µJ  model {got:.3} µJ");
    }

    section("model evaluation cost (hot path: one per request)");
    bench("fp_energy", 10, 1000, || {
        std::hint::black_box(fp_model.fp_energy(FpFormat::fp(10)));
    })
    .report(None);
    bench("sc_energy", 10, 1000, || {
        std::hint::black_box(sc_model.sc_energy(ScConfig::new(512)));
    })
    .report(None);
    bench("ari_savings (eq. 2)", 10, 1000, || {
        std::hint::black_box(EnergyModel::ari_savings(0.25, 1.0, 0.2));
    })
    .report(None);
}
