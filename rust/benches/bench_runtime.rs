//! PJRT execute-path bench: per-batch and per-sample cost across
//! resolution variants and batch sizes — the serving-side analogue of the
//! paper's Tables I/II cost axes (here wall time on the CPU PJRT client;
//! energy comes from the calibrated model, see bench_energy_model).
//!
//! Requires `make artifacts`; skips gracefully otherwise.

use std::path::PathBuf;

use ari::data::VariantKind;
use ari::runtime::Engine;
use ari::util::benchkit::{bench, section};

fn main() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !root.join("manifest.txt").exists() {
        eprintln!("SKIP bench_runtime: run `make artifacts` first");
        return;
    }
    let mut engine = Engine::new(&root).unwrap();
    let ds = "fashion_syn";
    let data = engine.eval_data(ds).unwrap();

    for batch in [32usize, 256] {
        section(&format!("execute, batch {batch} (fashion_syn)"));
        let x = data.rows(0, batch).to_vec();
        for (kind, levels) in [(VariantKind::Fp, vec![16usize, 12, 8]), (VariantKind::Sc, vec![4096, 512, 64])] {
            for level in levels {
                let v = engine.manifest.variant(ds, kind, level, batch).unwrap().clone();
                let key = match kind {
                    VariantKind::Sc => Some([1u32, 2u32]),
                    VariantKind::Fp => None,
                };
                engine.execute(&v, &x, key).unwrap(); // warm compile
                bench(&format!("{:?} level={level} b={batch}", kind), 1, 8, || {
                    std::hint::black_box(engine.execute(&v, &x, key).unwrap());
                })
                .report(Some((batch as u64, "samples")));
            }
        }
    }

    section("host->device + padding overhead (batch 32, n=5)");
    let v = engine.manifest.variant(ds, VariantKind::Fp, 16, 32).unwrap().clone();
    let x5 = data.rows(0, 5).to_vec();
    bench("run_padded n=5 into b=32", 1, 8, || {
        std::hint::black_box(engine.run_padded(&v, &x5, 5, None).unwrap());
    })
    .report(Some((5, "samples")));

    println!(
        "\nengine totals: {} compiles / {} ms, {} executes, mean {:.0} µs/execute",
        engine.stats.compiles,
        engine.stats.compile_ms,
        engine.stats.executes,
        engine.mean_execute_us()
    );
}
