//! Backend execute-path bench: per-batch and per-sample cost across
//! resolution variants and batch sizes — the serving-side analogue of the
//! paper's Tables I/II cost axes (wall time on the active backend;
//! energy comes from the calibrated model, see bench_energy_model).
//!
//! Runs against `artifacts/` when present (PJRT with `--features pjrt`),
//! else the synthetic fixture on the native backend.  `ARI_BENCH_JSON`
//! additionally writes the machine-readable `ari-bench v1` document;
//! `ARI_BENCH_SMOKE=1` shrinks iterations.

use std::path::PathBuf;

use ari::data::VariantKind;
use ari::runtime::{open_backend, Backend, BackendKind};
use ari::util::benchkit::{bench, iters, section, JsonReport};

fn main() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut engine = open_backend(&root, BackendKind::Auto).unwrap();
    let ds = engine.manifest().datasets[0].name.clone();
    let data = engine.eval_data(&ds).unwrap();
    let mut json = JsonReport::new("bench_runtime");
    let (warm, timed) = iters(1, 8);

    for batch in [32usize, 256] {
        section(&format!("execute, batch {batch} ({ds}, backend {})", engine.name()));
        let x = data.rows(0, batch).to_vec();
        for (kind, levels) in [(VariantKind::Fp, vec![16usize, 12, 8]), (VariantKind::Sc, vec![4096, 512, 64])] {
            for level in levels {
                let v = engine.manifest().variant(&ds, kind, level, batch).unwrap().clone();
                let key = match kind {
                    VariantKind::Sc => Some([1u32, 2u32]),
                    VariantKind::Fp => None,
                };
                engine.execute(&v, &x, key).unwrap(); // warm compile
                let r = bench(&format!("{:?} level={level} b={batch}", kind), warm, timed, || {
                    std::hint::black_box(engine.execute(&v, &x, key).unwrap());
                });
                json.record(&r, Some((batch as u64, "samples")));
            }
        }
    }

    section("padding overhead (batch 32, n=5)");
    let v = engine.manifest().variant(&ds, VariantKind::Fp, 16, 32).unwrap().clone();
    let x5 = data.rows(0, 5).to_vec();
    let r = bench("run_padded n=5 into b=32", warm, timed, || {
        std::hint::black_box(engine.run_padded(&v, &x5, 5, None).unwrap());
    });
    json.record(&r, Some((5, "samples")));

    let stats = engine.stats();
    println!(
        "\nengine totals: {} compiles / {} ms, {} executes, mean {:.0} µs/execute",
        stats.compiles,
        stats.compile_ms,
        stats.executes,
        engine.mean_execute_us()
    );
    json.write_if_requested();
}
