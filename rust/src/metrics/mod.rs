//! Serving metrics: counters and latency histograms.
//!
//! Lock-free atomic counters for the hot path; histograms are merged at
//! report time.  A [`MetricsRegistry`] is shared by the coordinator and
//! the server threads.

use std::sync::atomic::{AtomicU64, Ordering};
// ari-lint: allow(sim-discipline): guards only the report-time `extra` map, written
// after the serving threads join — never part of a model-checked protocol.
use std::sync::Mutex;
use std::time::Duration;

/// Fixed log-spaced latency histogram: 1 µs .. ~100 s.
const LAT_BUCKETS: usize = 64;

fn bucket_of(d: Duration) -> usize {
    let us = d.as_micros().max(1) as f64;
    // 64 log buckets over [1 µs, 1e8 µs): ~3.45 buckets per decade.
    let idx = (us.log10() * 8.0) as usize;
    idx.min(LAT_BUCKETS - 1)
}

fn bucket_upper_us(idx: usize) -> f64 {
    10f64.powf((idx as f64 + 1.0) / 8.0)
}

/// A latency histogram (log-spaced buckets) with exact count/sum.
pub struct LatencyHist {
    buckets: [AtomicU64; LAT_BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl Default for LatencyHist {
    fn default() -> Self {
        Self {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
        }
    }
}

impl LatencyHist {
    /// Record one latency sample.
    pub fn record(&self, d: Duration) {
        self.buckets[bucket_of(d)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(d.as_micros() as u64, Ordering::Relaxed);
    }

    /// Samples recorded.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Exact mean latency.
    pub fn mean(&self) -> Duration {
        let c = self.count().max(1);
        Duration::from_micros(self.sum_us.load(Ordering::Relaxed) / c)
    }

    /// Approximate quantile (bucket upper bound).
    pub fn quantile(&self, q: f64) -> Duration {
        let total = self.count();
        if total == 0 {
            return Duration::ZERO;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut acc = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return Duration::from_micros(bucket_upper_us(i) as u64);
            }
        }
        Duration::from_micros(bucket_upper_us(LAT_BUCKETS - 1) as u64)
    }
}

/// Shared registry of everything the server reports.
#[derive(Default)]
pub struct MetricsRegistry {
    /// Requests fully served.
    pub completed: AtomicU64,
    /// Requests that ran the full model (escalations).
    pub escalated: AtomicU64,
    /// Batches dispatched to the reduced model.
    pub reduced_batches: AtomicU64,
    /// Batches dispatched to the full model.
    pub full_batches: AtomicU64,
    /// Padding waste: slots in dispatched batches not carrying a request.
    pub padded_slots: AtomicU64,
    /// Modelled energy spent, in nano-joules (µJ * 1000 for integer atomics).
    pub energy_nj: AtomicU64,
    /// End-to-end request latency.
    pub latency: LatencyHist,
    /// Queue wait: batcher enqueue → dispatch (batch formation plus
    /// staged-queue residency).
    pub queue_wait: LatencyHist,
    /// Network/ingress wait: request submission (wire ingress for TCP
    /// sessions, generator hand-off in-process) → batcher enqueue.
    /// Separating this from [`Self::queue_wait`] is what lets a serving
    /// report tell a slow wire from a congested batcher.
    pub net_wait: LatencyHist,
    /// Requests served a reduced-stage answer under overload
    /// (escalation suppressed — [`crate::server::CompletionOutcome::Degraded`]).
    pub degraded: AtomicU64,
    /// Requests rejected unserved (deadline already expired at dispatch).
    pub rejected: AtomicU64,
    /// Requests whose batch exhausted its backend retries.
    pub failed: AtomicU64,
    /// Backend `execute` retries after transient errors/panics.
    pub retries: AtomicU64,
    /// Named counters for anything else (failure injection, retries…).
    extra: Mutex<std::collections::BTreeMap<String, u64>>,
    /// Ordered log of every adaptation step the threshold controller
    /// took (see [`ControlEvent`]) — the control loop's replayable
    /// observability trail.
    control_events: Mutex<Vec<ControlEvent>>,
}

/// One adaptation step taken by the closed-loop threshold controller
/// (`coordinator::control`).  Recorded in order into the
/// [`MetricsRegistry`] so a session's control trajectory is observable
/// and replayable after the fact.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ControlEvent {
    /// Load pressure held for the hysteresis window: thresholds moved
    /// one step down (fewer escalations).  Carries the new tighten
    /// level.
    Tighten {
        /// Tighten level after this step (1..=max_steps).
        level: u32,
    },
    /// Load stayed below the relax band: thresholds moved one step back
    /// toward calibration.  Carries the new tighten level.
    Relax {
        /// Tighten level after this step (0..max_steps).
        level: u32,
    },
    /// The windowed escalation fraction at a stage deviated from the
    /// calibration-time baseline past the configured tolerance.
    Drift {
        /// Ladder stage whose margin statistics drifted.
        stage: usize,
        /// Escalation fraction observed over the sliding window.
        observed: f64,
        /// Calibration-time baseline escalation fraction.
        baseline: f64,
    },
    /// Online recalibration refreshed a stage's base threshold from the
    /// sliding margin window (clamped to the configured distance from
    /// the offline calibration).
    Recalibrated {
        /// Ladder stage recalibrated.
        stage: usize,
        /// Base threshold before the refresh.
        from: f64,
        /// Base threshold after the refresh.
        to: f64,
    },
}

impl MetricsRegistry {
    /// Fresh registry with all counters at zero.
    pub fn new() -> Self {
        Self::default()
    }

    /// Increment a named ad-hoc counter.  Recovers a poisoned guard:
    /// the map is plain data, and losing ad-hoc counters to an
    /// unrelated panic would hide the very incident being counted.
    pub fn bump(&self, name: &str, by: u64) {
        *self.extra.lock().unwrap_or_else(|e| e.into_inner()).entry(name.to_string()).or_insert(0) += by;
    }

    /// Append one typed control-loop adaptation step.  Recovers a
    /// poisoned guard for the same reason [`MetricsRegistry::bump`]
    /// does: the log is plain data and must survive unrelated panics.
    pub fn record_control(&self, event: ControlEvent) {
        self.control_events.lock().unwrap_or_else(|e| e.into_inner()).push(event);
    }

    /// Snapshot of the control-event log, in recording order.
    pub fn control_events(&self) -> Vec<ControlEvent> {
        self.control_events.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }

    /// Account modelled energy (µJ, stored as integer nJ).
    ///
    /// Rounds to the nearest nanojoule: the old truncating cast lost up
    /// to 1 nJ per call, biasing long accumulations of small per-request
    /// energies systematically down.  Negative inputs are clamped to
    /// zero (the counter is monotone) rather than wrapping.
    pub fn add_energy_uj(&self, uj: f64) {
        let nj = (uj * 1000.0).round().max(0.0) as u64;
        self.energy_nj.fetch_add(nj, Ordering::Relaxed);
    }

    /// Total modelled energy spent (µJ).
    pub fn energy_uj(&self) -> f64 {
        self.energy_nj.load(Ordering::Relaxed) as f64 / 1000.0
    }

    /// Escalated / completed so far.
    pub fn escalation_fraction(&self) -> f64 {
        let done = self.completed.load(Ordering::Relaxed);
        if done == 0 {
            return 0.0;
        }
        self.escalated.load(Ordering::Relaxed) as f64 / done as f64
    }

    /// Multi-line human report.
    pub fn report(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "requests: {} (escalated {} = {:.2}%)\n",
            self.completed.load(Ordering::Relaxed),
            self.escalated.load(Ordering::Relaxed),
            100.0 * self.escalation_fraction()
        ));
        s.push_str(&format!(
            "batches: reduced {} full {} padded_slots {}\n",
            self.reduced_batches.load(Ordering::Relaxed),
            self.full_batches.load(Ordering::Relaxed),
            self.padded_slots.load(Ordering::Relaxed)
        ));
        s.push_str(&format!(
            "latency: mean {:?} p50 {:?} p99 {:?}\n",
            self.latency.mean(),
            self.latency.quantile(0.50),
            self.latency.quantile(0.99)
        ));
        s.push_str(&format!("modelled energy: {:.2} µJ\n", self.energy_uj()));
        s.push_str(&format!(
            "outcomes: degraded {} rejected {} failed {} after {} retries\n",
            self.degraded.load(Ordering::Relaxed),
            self.rejected.load(Ordering::Relaxed),
            self.failed.load(Ordering::Relaxed),
            self.retries.load(Ordering::Relaxed)
        ));
        let events = self.control_events.lock().unwrap_or_else(|e| e.into_inner());
        if !events.is_empty() {
            let (mut tighten, mut relax, mut drift, mut recal) = (0u64, 0u64, 0u64, 0u64);
            for e in events.iter() {
                match e {
                    ControlEvent::Tighten { .. } => tighten += 1,
                    ControlEvent::Relax { .. } => relax += 1,
                    ControlEvent::Drift { .. } => drift += 1,
                    ControlEvent::Recalibrated { .. } => recal += 1,
                }
            }
            s.push_str(&format!(
                "control: tighten {tighten} relax {relax} drift {drift} recalibrated {recal}\n"
            ));
        }
        drop(events);
        for (k, v) in self.extra.lock().unwrap_or_else(|e| e.into_inner()).iter() {
            s.push_str(&format!("{k}: {v}\n"));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_ordered() {
        let h = LatencyHist::default();
        for us in [10u64, 20, 30, 40, 50, 1000] {
            h.record(Duration::from_micros(us));
        }
        assert_eq!(h.count(), 6);
        assert!(h.quantile(0.5) <= h.quantile(0.99));
        assert!(h.quantile(0.99) >= Duration::from_micros(500));
    }

    #[test]
    fn histogram_empty() {
        let h = LatencyHist::default();
        assert_eq!(h.quantile(0.5), Duration::ZERO);
        assert_eq!(h.mean(), Duration::ZERO);
    }

    #[test]
    fn mean_exact() {
        let h = LatencyHist::default();
        h.record(Duration::from_micros(100));
        h.record(Duration::from_micros(300));
        assert_eq!(h.mean(), Duration::from_micros(200));
    }

    #[test]
    fn registry_energy_and_fraction() {
        let m = MetricsRegistry::new();
        m.completed.store(10, Ordering::Relaxed);
        m.escalated.store(3, Ordering::Relaxed);
        m.add_energy_uj(1.5);
        m.add_energy_uj(0.25);
        assert!((m.escalation_fraction() - 0.3).abs() < 1e-12);
        assert!((m.energy_uj() - 1.75).abs() < 1e-3);
    }

    /// Regression: accumulating many small per-request energies must
    /// round per call, not truncate (1.9 nJ truncated to 1 nJ lost 47%
    /// of the total), and negative inputs are clamped, not wrapped.
    #[test]
    fn energy_rounds_instead_of_truncating() {
        let m = MetricsRegistry::new();
        for _ in 0..1000 {
            m.add_energy_uj(0.0019); // 1.9 nJ per request
        }
        // Rounding keeps the total within ±0.5 nJ/call of the true
        // 1.9 µJ; truncation would report 1.0 µJ.
        assert!((m.energy_uj() - 1.9).abs() < 0.11, "got {} µJ", m.energy_uj());
        let before = m.energy_uj();
        m.add_energy_uj(-4.0);
        assert_eq!(m.energy_uj(), before, "negative energy must be clamped, not wrapped");
    }

    #[test]
    fn extra_counters_in_report() {
        let m = MetricsRegistry::new();
        m.bump("retries", 2);
        m.bump("retries", 1);
        assert!(m.report().contains("retries: 3"));
    }

    #[test]
    fn outcome_counters_in_report() {
        let m = MetricsRegistry::new();
        m.degraded.store(4, Ordering::Relaxed);
        m.rejected.store(2, Ordering::Relaxed);
        m.failed.store(1, Ordering::Relaxed);
        m.retries.store(7, Ordering::Relaxed);
        assert!(m.report().contains("outcomes: degraded 4 rejected 2 failed 1 after 7 retries"));
    }

    #[test]
    fn bump_survives_a_poisoned_map() {
        let m = std::sync::Arc::new(MetricsRegistry::new());
        let mc = std::sync::Arc::clone(&m);
        // Poison `extra` by panicking while holding its guard.
        // ari-lint: allow(sim-discipline): poisoning requires a real panicking thread;
        // sim threads abort the whole schedule on panic instead of poisoning locks.
        let _ = std::thread::spawn(move || {
            let _guard = mc.extra.lock().unwrap();
            panic!("poison the metrics map");
        })
        .join();
        m.bump("after-poison", 1);
        assert!(m.report().contains("after-poison: 1"));
    }

    /// Control events are recorded in order, survive snapshotting, and
    /// surface as one summary line in the report — absent entirely when
    /// the controller never acted (the default-off configuration).
    #[test]
    fn control_events_recorded_in_order() {
        let m = MetricsRegistry::new();
        assert!(!m.report().contains("control:"), "quiet sessions must not mention control");
        m.record_control(ControlEvent::Tighten { level: 1 });
        m.record_control(ControlEvent::Drift { stage: 0, observed: 0.6, baseline: 0.2 });
        m.record_control(ControlEvent::Recalibrated { stage: 0, from: 0.4, to: 0.55 });
        m.record_control(ControlEvent::Relax { level: 0 });
        let events = m.control_events();
        assert_eq!(events.len(), 4);
        assert_eq!(events[0], ControlEvent::Tighten { level: 1 });
        assert_eq!(events[3], ControlEvent::Relax { level: 0 });
        assert!(m.report().contains("control: tighten 1 relax 1 drift 1 recalibrated 1"));
    }

    #[test]
    fn bucket_monotone() {
        let mut last = 0;
        for us in [1u64, 10, 100, 1000, 10_000, 100_000, 1_000_000] {
            let b = bucket_of(Duration::from_micros(us));
            assert!(b >= last);
            last = b;
        }
    }
}
