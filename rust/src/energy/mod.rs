//! Per-inference energy model, calibrated to the paper's synthesis data.
//!
//! The paper measures energy on a 32 nm Cadence Genus flow (unavailable
//! here — DESIGN.md §2); its published numbers are used as the model's
//! calibration points, which is all the ARI analysis consumes (the
//! energy scalars E_R, E_F in eq. 1/2):
//!
//! * **Table I** (floating point, Fashion-MNIST topology): FP16 0.70 µJ,
//!   FP14 0.57, FP12 0.46, FP10 0.36, FP8 0.25 — linear in the bit width
//!   to excellent approximation (the MAC array's switched capacitance
//!   scales with mantissa width; cycle count is precision-independent in
//!   the paper's design, so energy ∝ area).
//! * **Table II** (stochastic computing, 784-100-200-10 topology):
//!   energy halves with sequence length from 2.15 µJ at L=4096 down to
//!   0.07 µJ at L=128 — linear in L (same circuit, L cycles).
//!
//! Energies for other topologies scale by MAC count: the paper's FP
//! design runs a fixed 64-PE bank, so cycles (and hence energy at equal
//! precision) are proportional to the number of MACs; the SC design is
//! fully parallel, so per-inference energy is proportional to active
//! gates × L, again ∝ MACs × L.

use crate::quant::FpFormat;
use crate::sc::ScConfig;

/// Table I calibration points: (total bits, µJ per inference) for the
/// paper's Fashion-MNIST MLP (784-1024-512-256-256-10).
pub const TABLE_I: [(u32, f64); 5] = [(16, 0.70), (14, 0.57), (12, 0.46), (10, 0.36), (8, 0.25)];

/// Table II calibration points: (sequence length, µJ per inference) for
/// the paper's SC MLP (784-100-200-10).
pub const TABLE_II: [(usize, f64); 6] =
    [(4096, 2.15), (2048, 1.08), (1024, 0.54), (512, 0.27), (256, 0.14), (128, 0.07)];

/// Table II latency points: (sequence length, µs per inference).
pub const TABLE_II_LATENCY: [(usize, f64); 6] =
    [(4096, 4.10), (2048, 2.05), (1024, 1.03), (512, 0.52), (256, 0.26), (128, 0.13)];

/// MAC count of an MLP given its layer widths.
pub fn mac_count(dims: &[usize]) -> u64 {
    dims.windows(2).map(|w| (w[0] * w[1]) as u64).sum()
}

/// MACs of the Table I reference topology (input 784).
pub fn table_i_reference_macs() -> u64 {
    mac_count(&[784, 1024, 512, 256, 256, 10])
}

/// MACs of the Table II reference topology.
pub fn table_ii_reference_macs() -> u64 {
    mac_count(&[784, 100, 200, 10])
}

/// The calibrated energy model.
#[derive(Clone, Debug)]
pub struct EnergyModel {
    /// MACs of the topology being modelled.
    pub macs: u64,
}

impl EnergyModel {
    /// Model for an MLP with the given layer widths (input first).
    pub fn for_dims(dims: &[usize]) -> Self {
        Self { macs: mac_count(dims) }
    }

    /// Model for the paper's 5-layer topology with `input_dim` inputs.
    pub fn for_input_dim(input_dim: usize) -> Self {
        Self::for_dims(&[input_dim, 1024, 512, 256, 256, 10])
    }

    /// Energy per inference (µJ) of the floating-point design at `fmt`.
    ///
    /// Least-squares line through Table I (E = a + b·bits, fit below),
    /// scaled by MAC count relative to the Table I topology.
    pub fn fp_energy(&self, fmt: FpFormat) -> f64 {
        let bits = fmt.total_bits() as f64;
        // Least-squares fit over Table I: E ≈ -0.198 + 0.0555 * bits
        // (R² > 0.999; worst point error 1.7%).
        let base = -0.198 + 0.0555 * bits;
        base * self.macs as f64 / table_i_reference_macs() as f64
    }

    /// Energy per inference (µJ) of the SC design at sequence length `L`.
    ///
    /// Linear in L through Table II (E ≈ L · 2.15/4096), scaled by MACs.
    pub fn sc_energy(&self, cfg: ScConfig) -> f64 {
        let per_bit = 2.15 / 4096.0;
        per_bit * cfg.seq_len as f64 * self.macs as f64 / table_ii_reference_macs() as f64
    }

    /// SC latency per inference (µs): one cycle per stream bit.
    pub fn sc_latency_us(&self, cfg: ScConfig) -> f64 {
        (4.10 / 4096.0) * cfg.seq_len as f64
    }

    /// The paper's eq. (1): average ARI energy per inference given the
    /// reduced/full energies and the escalation fraction F.
    pub fn ari_energy(e_reduced: f64, e_full: f64, escalation_fraction: f64) -> f64 {
        e_reduced + escalation_fraction * e_full
    }

    /// The paper's eq. (2): relative savings of ARI vs always-full.
    /// `1 - E_ARI/E_F = (1 - F) - E_R/E_F`.
    pub fn ari_savings(e_reduced: f64, e_full: f64, escalation_fraction: f64) -> f64 {
        (1.0 - escalation_fraction) - e_reduced / e_full
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The model must reproduce Table I within 3% at every calibration
    /// point (it is a least-squares line, not an interpolator).
    #[test]
    fn reproduces_table_i() {
        let m = EnergyModel::for_input_dim(784);
        for (bits, uj) in TABLE_I {
            let got = m.fp_energy(FpFormat::fp(bits));
            let rel = (got - uj).abs() / uj;
            assert!(rel < 0.03, "FP{bits}: got {got:.4} expected {uj} ({rel:.3})");
        }
    }

    /// The model must reproduce Table II exactly at L=4096 and within 5%
    /// everywhere (the paper itself calls its numbers "almost linear";
    /// the worst deviation from the L∝E line is L=256 at 4.0%).
    #[test]
    fn reproduces_table_ii() {
        let m = EnergyModel { macs: table_ii_reference_macs() };
        for (l, uj) in TABLE_II {
            let got = m.sc_energy(ScConfig::new(l));
            let rel = (got - uj).abs() / uj;
            assert!(rel < 0.05, "L={l}: got {got:.4} expected {uj} ({rel:.3})");
        }
        // exact at the calibration anchor
        assert!((m.sc_energy(ScConfig::new(4096)) - 2.15).abs() < 1e-9);
    }

    #[test]
    fn reproduces_table_ii_latency() {
        let m = EnergyModel { macs: table_ii_reference_macs() };
        for (l, us) in TABLE_II_LATENCY {
            let got = m.sc_latency_us(ScConfig::new(l));
            assert!((got - us).abs() / us < 0.02, "L={l}: {got} vs {us}");
        }
    }

    #[test]
    fn mac_counts() {
        assert_eq!(mac_count(&[784, 10]), 7840);
        assert_eq!(table_ii_reference_macs(), 784 * 100 + 100 * 200 + 200 * 10);
    }

    #[test]
    fn energy_scales_with_topology() {
        let small = EnergyModel::for_input_dim(784);
        let big = EnergyModel::for_input_dim(3072);
        assert!(big.fp_energy(FpFormat::FP16) > small.fp_energy(FpFormat::FP16));
        let ratio = big.fp_energy(FpFormat::FP16) / small.fp_energy(FpFormat::FP16);
        let expect = big.macs as f64 / small.macs as f64;
        assert!((ratio - expect).abs() < 1e-9);
    }

    #[test]
    fn fp_energy_monotone_in_bits() {
        let m = EnergyModel::for_input_dim(784);
        let mut last = 0.0;
        for bits in [8u32, 9, 10, 12, 14, 16] {
            let e = m.fp_energy(FpFormat::fp(bits));
            assert!(e > last);
            last = e;
        }
    }

    #[test]
    fn ari_equations_match_paper_example() {
        // Paper §III-D: F = 0.2, E_R = 0.25, E_F = 1 -> E_ARI = 0.45.
        let e = EnergyModel::ari_energy(0.25, 1.0, 0.2);
        assert!((e - 0.45).abs() < 1e-12);
        let s = EnergyModel::ari_savings(0.25, 1.0, 0.2);
        assert!((s - 0.55).abs() < 1e-12);
    }

    #[test]
    fn savings_equation_consistent_with_energy() {
        // 1 - E_ARI/E_F must equal eq. (2) for random inputs.
        let mut rng = crate::util::Pcg64::seeded(31);
        for _ in 0..100 {
            let ef = rng.range_f64(0.5, 3.0);
            let er = rng.range_f64(0.01, ef);
            let f = rng.next_f64();
            let lhs = 1.0 - EnergyModel::ari_energy(er, ef, f) / ef;
            let rhs = EnergyModel::ari_savings(er, ef, f);
            assert!((lhs - rhs).abs() < 1e-12);
        }
    }
}
