//! # ARI — Adaptive Resolution Inference
//!
//! A production-shaped reproduction of *"Adaptive Resolution Inference
//! (ARI): Energy-Efficient Machine Learning for Internet of Things"*
//! (IEEE IoT Journal 2024, DOI 10.1109/JIOT.2023.3339623) as a
//! three-layer rust + JAX + Pallas stack:
//!
//! * **L1/L2 (build time, python)** — the paper's MLP and its
//!   reduced-resolution variants (truncated-mantissa floating point and
//!   stochastic-computing noise model) are authored in JAX + Pallas and
//!   AOT-lowered to HLO text (`make artifacts`).
//! * **L3 (this crate)** — the serving system: a pluggable inference
//!   [`runtime`] (pure-rust [`runtime::NativeBackend`] by default, a
//!   PJRT engine for the lowered executables behind the `pjrt` cargo
//!   feature) and the ARI ladder coordinator that runs every request on
//!   the lowest-resolution model first, checks the score margin against
//!   a per-stage calibrated threshold, and escalates only low-margin
//!   requests down an N-level resolution ladder (paper Fig. 7b is the
//!   2-level special case).
//!
//! Python never runs on the request path.  With default features the
//! crate is fully self-contained: no `artifacts/` directory, no native
//! libraries — the [`runtime::fixture`] module synthesises deterministic
//! datasets so every test, bench and example runs offline.
//!
//! ## Module map
//!
//! | module | role |
//! |---|---|
//! | [`util`] | PRNG, stats, histograms, mini-TOML, worker pool, fault-injection registry ([`util::fault`]), bench kit, property-test + deterministic-schedule ([`util::sim`]) harnesses |
//! | [`config`] | experiment / server configuration |
//! | [`data`] | `.bin`/`.meta` tensor loader, manifest, datasets |
//! | [`tensor`] | f32 matrix substrate with the tiled matmul kernel |
//! | [`quant`] | truncated-mantissa FP emulation (rust twin of the L1 kernel) |
//! | [`sc`] | exact bitstream stochastic-computing simulator (LFSR → SNG → XNOR → APC) |
//! | [`mlp`] | pure-rust MLP engines + prepared execution plans over [`quant`]/[`sc`] |
//! | [`energy`] | per-inference energy model calibrated to the paper's Tables I & II |
//! | [`margin`] | margin statistics + threshold calibration (Mmax / M99 / M95) |
//! | [`runtime`] | the [`runtime::Backend`] trait, native + PJRT backends, fixtures |
//! | [`coordinator`] | the ARI N-level ladder (+ 2-level cascade wrapper): batcher, per-stage escalation, energy accounting |
//! | [`server`] | threaded request loop + workload generators; TCP front-end ([`server::net`]) speaking the length-prefixed wire protocol (`docs/PROTOCOL.md`) |
//! | [`metrics`] | counters + latency histograms |
//! | [`experiments`] | regeneration drivers for every paper table & figure |

#![warn(missing_docs)]

pub mod config;
pub mod coordinator;
pub mod data;
pub mod energy;
pub mod experiments;
pub mod margin;
pub mod metrics;
pub mod mlp;
pub mod quant;
pub mod runtime;
pub mod sc;
pub mod server;
pub mod tensor;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
