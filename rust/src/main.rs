//! `ari` — the ARI serving and experiment CLI.
//!
//! ```text
//! ari info       [--artifacts DIR] [--backend B]
//! ari calibrate  [--artifacts DIR] [--backend B] [overrides…]   per-stage threshold table
//! ari serve      [--artifacts DIR] [--backend B] [--config FILE] [--deferred] [--listen ADDR] [overrides…]
//! ari sweep      [--artifacts DIR] [--backend B] [--ladder] [--drift] [overrides…]   tradeoff tables
//! ari experiment <id|all> [--artifacts DIR] [--backend B] [--out DIR]
//! ari bench-exec [--artifacts DIR] [--backend B] [overrides…]   raw execute timing
//! ari fixture    --out DIR                                      write synthetic artifacts
//! ```
//!
//! `calibrate` and `serve` run the N-level ladder described by the
//! config (`levels = [8, 12, 16]`, or the classic 2-level
//! reduced/full pair when no ladder is configured); `sweep` tabulates
//! every candidate ladder's energy/accuracy tradeoff (`--ladder` adds
//! multi-level ladders to the 2-level pairs).
//!
//! `--backend` selects the inference substrate: `auto` (default; PJRT
//! when compiled in and artifacts exist, else native), `native`
//! (pure rust; falls back to the deterministic synthetic fixture suite
//! when there is no artifacts directory), or `pjrt` (requires building
//! with `--features pjrt`).
//!
//! Overrides are `key=value` / `section.key=value` pairs applied on top of
//! the config file (hand-rolled arg parsing — clap is not in the sandbox's
//! vendored crate set).  See `docs/CONFIG.md` for the full schema.

use std::path::PathBuf;

use ari::config::AriConfig;
use ari::coordinator::{EscalationPolicy, Ladder, LadderSpec};
use ari::runtime::{open_backend, Backend, BackendKind};
use ari::server::{run_serving_ladder, ServeOptions};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

struct Cli {
    artifacts: PathBuf,
    backend: BackendKind,
    config: Option<PathBuf>,
    out: Option<PathBuf>,
    deferred: bool,
    ladder: bool,
    drift: bool,
    faults: Option<String>,
    listen: Option<String>,
    positional: Vec<String>,
    overrides: Vec<String>,
}

fn parse_cli(args: &[String]) -> ari::Result<Cli> {
    let mut cli = Cli {
        artifacts: PathBuf::from("artifacts"),
        backend: BackendKind::Auto,
        config: None,
        out: None,
        deferred: false,
        ladder: false,
        drift: false,
        faults: None,
        listen: None,
        positional: Vec::new(),
        overrides: Vec::new(),
    };
    let mut it = args.iter().peekable();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--artifacts" => cli.artifacts = PathBuf::from(next_val(&mut it, "--artifacts")?),
            "--backend" => cli.backend = BackendKind::parse(next_val(&mut it, "--backend")?)?,
            "--config" => cli.config = Some(PathBuf::from(next_val(&mut it, "--config")?)),
            "--out" => cli.out = Some(PathBuf::from(next_val(&mut it, "--out")?)),
            "--deferred" => cli.deferred = true,
            "--ladder" => cli.ladder = true,
            "--drift" => cli.drift = true,
            "--faults" => cli.faults = Some(next_val(&mut it, "--faults")?.to_string()),
            "--listen" => cli.listen = Some(next_val(&mut it, "--listen")?.to_string()),
            "--help" | "-h" => {
                println!("{}", HELP);
                std::process::exit(0);
            }
            s if s.contains('=') => cli.overrides.push(s.to_string()),
            s => cli.positional.push(s.to_string()),
        }
    }
    Ok(cli)
}

fn next_val<'a>(it: &mut std::iter::Peekable<std::slice::Iter<'a, String>>, flag: &str) -> ari::Result<&'a str> {
    it.next().map(|s| s.as_str()).ok_or_else(|| anyhow::anyhow!("{flag} needs a value"))
}

const HELP: &str = "ari — Adaptive Resolution Inference\n\
commands:\n  info | calibrate | serve | sweep | experiment <id|all> | bench-exec | fixture\n\
flags: --artifacts DIR  --backend auto|native|pjrt  --config FILE  --out DIR  --deferred  --ladder\n  \
--drift        sweep the configured ladder over progressively drifted eval streams (static\n  \
               thresholds; shows the staleness the [control] loop corrects — docs/ROBUSTNESS.md)\n  \
--faults SPEC  arm fault injection for serve (point[:prob[:count]],…[@seed] or a bare chaos seed;\n  \
               also read from ARI_FAULTS; see docs/ROBUSTNESS.md)\n  \
--listen ADDR  serve over TCP (length-prefixed wire protocol, see docs/PROTOCOL.md) instead of\n  \
               the in-process generator; overrides net.listen (drive it with ari-client)\n\
overrides: dataset=… mode=fp|sc reduced_level=… levels=[8,12,16] threshold=mmax|m99|m95|<f> server.batch_size=… server.requests=… server.arrival_rate=… net.listen=…";

fn load_config(cli: &Cli) -> ari::Result<AriConfig> {
    let mut cfg = match &cli.config {
        Some(p) => AriConfig::from_file(p)?,
        None => AriConfig::default(),
    };
    cfg.artifacts = cli.artifacts.clone();
    cfg.apply_overrides(&cli.overrides)?;
    Ok(cfg)
}

fn build_ladder(engine: &mut dyn Backend, cfg: &AriConfig) -> ari::Result<(Ladder, ari::data::EvalData, usize)> {
    let data = engine.eval_data(&cfg.dataset)?;
    let n_calib = ((data.n as f64) * cfg.calib_fraction) as usize;
    let spec = LadderSpec::from_config(cfg);
    let ladder = Ladder::calibrate(engine, spec, &data, n_calib.max(1))?;
    Ok((ladder, data, n_calib))
}

fn dispatch(args: &[String]) -> ari::Result<()> {
    let cli = parse_cli(args)?;
    let cmd = cli.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "help" => println!("{HELP}"),
        "info" => {
            let engine = open_backend(&cli.artifacts, cli.backend)?;
            println!("artifacts: {:?} (backend: {})", cli.artifacts, engine.name());
            for d in &engine.manifest().datasets {
                println!(
                    "dataset {} (stand-in for {}): input_dim={} n_eval={} train_acc={:.4}",
                    d.name, d.paper_name, d.input_dim, d.n_eval, d.train_acc
                );
            }
            println!("variants: {}", engine.manifest().variants.len());
        }
        "calibrate" => {
            let cfg = load_config(&cli)?;
            let mut engine = open_backend(&cfg.artifacts, cli.backend)?;
            let (ladder, _, n_calib) = build_ladder(engine.as_mut(), &cfg)?;
            println!(
                "ladder {}/{:?} levels={:?} ({}) calibrated on {n_calib} rows, backend {}",
                cfg.dataset,
                cfg.mode,
                ladder.spec.levels,
                cfg.threshold,
                engine.name()
            );
            print!("{}", ladder.calibration_report());
            for (i, stage) in ladder.stages.iter().enumerate() {
                if let Some(cal) = &stage.calibration {
                    for p in
                        [ari::config::ThresholdPolicy::MMax, ari::config::ThresholdPolicy::M99, ari::config::ThresholdPolicy::M95]
                    {
                        println!("  stage {i} T({p}) = {:.4}", cal.threshold(p));
                    }
                }
            }
        }
        "serve" => {
            let mut cfg = load_config(&cli)?;
            if let Some(l) = &cli.listen {
                // The CLI flag wins over `[net] listen` from the file.
                cfg.listen = l.clone();
            }
            let mut engine = open_backend(&cfg.artifacts, cli.backend)?;
            let (ladder, data, n_calib) = build_ladder(engine.as_mut(), &cfg)?;
            let opts = ServeOptions {
                escalation: if cli.deferred { EscalationPolicy::Deferred } else { EscalationPolicy::Immediate },
            };
            println!(
                "serving {}: {:?} levels={:?} ({}) calib_rows={n_calib} backend={}",
                cfg.dataset,
                cfg.mode,
                ladder.spec.levels,
                cfg.threshold,
                engine.name()
            );
            print!("{}", ladder.calibration_report());
            if cfg.listen.is_empty() {
                // In-process serving: baseline full-model predictions
                // for parity reporting.
                let kind = cfg.mode.kind();
                let full_level = *ladder.spec.levels.last().unwrap();
                let full_v = engine.manifest().variant(&cfg.dataset, kind, full_level, cfg.batch_size)?.clone();
                let full_out = engine.run_dataset(&full_v, &data, cfg.seed as u32)?;
                // Arm fault injection last, so chaos hits the serving
                // session rather than calibration or the baseline pass
                // (neither has a retry path).  `--faults` wins over the
                // `ARI_FAULTS` environment variable; the normalised spec
                // is echoed so a failing run can be replayed exactly.
                let armed_spec = match &cli.faults {
                    Some(v) => Some(ari::util::fault::arm_value(v)?),
                    None => ari::util::fault::arm_from_env()?,
                };
                if let Some(spec) = &armed_spec {
                    println!("faults armed: {spec}");
                }
                let report = run_serving_ladder(engine.as_mut(), &ladder, &cfg, &data, Some(&full_out.pred), opts)?;
                ari::util::fault::disarm_all();
                println!("{}", report.summary());
            } else {
                // TCP serving tier: bind first so the client side of a
                // smoke script can start polling, then arm faults so
                // chaos hits the wire + serving session only.
                let listener = std::net::TcpListener::bind(&cfg.listen)?;
                println!("listening on {} (wire protocol: docs/PROTOCOL.md; drive with ari-client)", listener.local_addr()?);
                let armed_spec = match &cli.faults {
                    Some(v) => Some(ari::util::fault::arm_value(v)?),
                    None => ari::util::fault::arm_from_env()?,
                };
                if let Some(spec) = &armed_spec {
                    println!("faults armed: {spec}");
                }
                let report =
                    ari::server::net::run_net_serving(engine.as_mut(), &ladder, &cfg, data.input_dim, opts, listener)?;
                ari::util::fault::disarm_all();
                println!("{}", report.summary());
            }
        }
        "sweep" => {
            let cfg = load_config(&cli)?;
            let mut engine = open_backend(&cfg.artifacts, cli.backend)?;
            let kind = cfg.mode.kind();
            if cli.drift {
                // Drift axis instead of the ladder axis: one ladder,
                // static thresholds, progressively drifted streams.
                let levels = if cfg.levels.is_empty() {
                    vec![cfg.reduced_level, ari::experiments::sweep::Sweep::full_level(kind)]
                } else {
                    cfg.levels.clone()
                };
                let table = ari::experiments::sweep::drift_table(
                    engine.as_mut(),
                    &cfg.dataset,
                    cfg.mode,
                    &levels,
                    cfg.threshold,
                    cfg.calib_fraction,
                    cfg.batch_size,
                    cfg.seed as u32,
                )?;
                print!("{table}");
                return Ok(());
            }
            let mut ladders =
                ari::experiments::sweep::candidate_ladders(engine.as_ref(), &cfg.dataset, kind, cli.ladder);
            if !cfg.levels.is_empty() {
                // The explicitly configured ladder leads the table
                // (deduplicated — each ladder runs a full eval pass).
                ladders.retain(|l| *l != cfg.levels);
                ladders.insert(0, cfg.levels.clone());
            }
            let table = ari::experiments::sweep::ladder_table(
                engine.as_mut(),
                &cfg.dataset,
                cfg.mode,
                &ladders,
                cfg.threshold,
                cfg.calib_fraction,
                cfg.batch_size,
                cfg.seed as u32,
            )?;
            print!("{table}");
        }
        "experiment" => {
            let id = cli.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
            let mut engine = open_backend(&cli.artifacts, cli.backend)?;
            let ids: Vec<&str> = if id == "all" { ari::experiments::ALL.to_vec() } else { vec![id] };
            for id in ids {
                eprintln!("[experiment {id}] running…");
                let t0 = std::time::Instant::now();
                let report = ari::experiments::run_experiment(engine.as_mut(), id)?;
                eprintln!("[experiment {id}] done in {:.1?}", t0.elapsed());
                match &cli.out {
                    Some(dir) => {
                        std::fs::create_dir_all(dir)?;
                        let path = dir.join(format!("{id}.txt"));
                        std::fs::write(&path, &report)?;
                        println!("wrote {path:?}");
                    }
                    None => println!("{report}"),
                }
            }
        }
        "bench-exec" => {
            let cfg = load_config(&cli)?;
            let mut engine = open_backend(&cfg.artifacts, cli.backend)?;
            let data = engine.eval_data(&cfg.dataset)?;
            let kind = cfg.mode.kind();
            let v = engine.manifest().variant(&cfg.dataset, kind, cfg.reduced_level, cfg.batch_size)?.clone();
            let x = data.rows(0, cfg.batch_size.min(data.n)).to_vec();
            let key = match cfg.mode {
                ari::config::Mode::Sc => Some([1u32, 2u32]),
                ari::config::Mode::Fp => None,
            };
            engine.execute(&v, &x, key)?; // warm (compile)
            let iters = 20;
            let t0 = std::time::Instant::now();
            for _ in 0..iters {
                engine.execute(&v, &x, key)?;
            }
            let dt = t0.elapsed() / iters;
            println!(
                "{} batch={} ({}): {:?}/batch = {:.1} µs/sample (compile {} ms)",
                v.key(),
                cfg.batch_size,
                engine.name(),
                dt,
                dt.as_micros() as f64 / cfg.batch_size as f64,
                engine.stats().compile_ms
            );
        }
        "fixture" => {
            let out = cli.out.clone().ok_or_else(|| anyhow::anyhow!("fixture needs --out DIR"))?;
            ari::runtime::fixture::write_artifacts(&out, &ari::runtime::fixture::default_specs())?;
            println!("wrote synthetic artifacts to {out:?}");
        }
        other => anyhow::bail!("unknown command {other:?}\n{HELP}"),
    }
    Ok(())
}
