//! Wire-hardened TCP serving tier: a length-prefixed binary front-end
//! for the pipelined ladder server.
//!
//! Layout:
//!
//! - [`proto`] — the frame grammar (see `docs/PROTOCOL.md`): incremental
//!   allocation-reusing decoder, typed [`proto::ProtoError`] taxonomy,
//!   append-style encoders.
//! - [`client`] — the load-generator used by `ari-client` and the
//!   loopback test/bench suites (open-, partial-open- and closed-loop).
//! - this module — [`run_net_serving`]: a **std-only non-blocking**
//!   accept/read/write loop feeding the exact same bounded-queue
//!   pipeline and [`super::Dispatcher`] as the in-process
//!   [`super::run_serving_ladder`].
//!
//! Threading model (mirrors the in-process server, with the network
//! front-end replacing the workload generator *and* batching thread):
//!
//! 1. the **net thread** owns the listener, every connection, and the
//!    batcher.  One readiness sweep per iteration: accept new
//!    connections, read + decode frames, admit or shed requests, fire
//!    due batches into the staged queue, route completions back to
//!    their connection, and flush write buffers — all non-blocking, one
//!    real-clock read per iteration;
//! 2. the **calling thread** runs ladder inference exactly as
//!    in-process, pushing each [`Completion`] into a third bounded
//!    queue the net thread drains;
//! 3. an optional **watchdog** thread (same heartbeat protocol as the
//!    in-process server) converts a stuck net loop *or* a stuck drain
//!    into a diagnostic `Err` by closing all three queues — a stalled
//!    shutdown never hangs the caller.
//!
//! Connection supervision (see `docs/PROTOCOL.md` for the client-visible
//! contract): a read deadline bounds how long a peer may dangle a
//! partial frame (slow-loris); per-connection in-flight and write-buffer
//! caps shed excess load with typed `Rejected` responses instead of
//! queueing unboundedly; a peer that stops reading its responses is
//! dropped after `linger` without write progress.  Shutdown drains the
//! batcher, flushes every socket, and only then closes — connections
//! that cannot be flushed are force-dropped after a bounded grace
//! period, with every undelivered response counted.
//!
//! **Conservation**: every admitted request produces exactly one typed
//! [`Completion`] (the dispatcher's invariant), and every completion is
//! routed exactly once — delivered to its (still-live) connection or
//! counted against a dead one.  [`run_net_serving`] `ensure!`s both
//! sums before reporting, under every network fault the [`fault`]
//! registry can inject (`conn-drop`, `frame-trunc`, `frame-corrupt`,
//! `write-split`, `accept-stall`).

use std::collections::VecDeque;
use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
// ari-lint: allow(sim-discipline): the net watchdog's stop signal runs on real
// primitives by design, exactly like the in-process serving watchdog — it measures
// real time and is never part of a model-checked protocol.
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

pub mod client;
pub mod proto;

use crate::config::AriConfig;
use crate::coordinator::{Batcher, BatcherPolicy, Ladder};
use crate::metrics::MetricsRegistry;
use crate::runtime::Backend;
use crate::util::fault;
use crate::util::queue::BoundedQueue;

use super::{
    panic_msg, Completion, CompletionOutcome, ControlStats, Dispatcher, Heartbeat, Request, RobustnessPolicy,
    RowSource, ServeOptions, StagedBatch, PIPELINE_DEPTH,
};
use crate::coordinator::ControlPolicy;

/// Completions in flight between the inference loop and the net
/// thread.  Deep enough that routing never backpressures dispatch in
/// the steady state; bounded so a dead net loop cannot hide an
/// unbounded completion pile.
const COMP_QUEUE_DEPTH: usize = 256;

/// Per-connection read chunk (stack buffer).
const READ_CHUNK: usize = 4096;

/// Net-loop sleep when a full sweep made no progress (no accepts, no
/// bytes, no completions).  Short enough to keep loopback latency in
/// the sub-millisecond range; long enough not to spin a core.
const IDLE_SLEEP: Duration = Duration::from_micros(500);

/// One real-clock read per net-loop iteration.  The front-end schedules
/// against real socket readiness and real wall time and is exercised
/// over real loopback TCP, never under the sim scheduler — so unlike
/// the in-process arrival loop there is no virtual clock to thread
/// through it.
fn net_now() -> Instant {
    // ari-lint: allow(clock-discipline): the TCP front-end is driven by real socket
    // readiness; it is never model-checked under the sim scheduler (see the doc
    // comment above and docs/TESTING.md).
    Instant::now()
}

/// Connection-supervision knobs, derived from the `[net]` config
/// section (see `docs/CONFIG.md`).
struct NetPolicy {
    /// Accepted-connection cap; excess accepts are closed immediately.
    max_conns: usize,
    /// How long a peer may dangle a partial frame before the connection
    /// is closed with a typed [`proto::ProtoError::Stalled`] error
    /// (slow-loris defence).  `None` disables.
    read_deadline: Option<Duration>,
    /// Per-connection admitted-but-unanswered cap; excess requests are
    /// shed with typed `Rejected` responses.
    max_in_flight: usize,
    /// Per-connection encoded-but-unflushed byte cap; past it new
    /// requests are shed and responses stay queued until the socket
    /// drains.
    write_buf_cap: usize,
    /// Grace period: a connection with pending bytes but no write
    /// progress for this long is dropped, and an idle listener with no
    /// remaining connections for this long begins shutdown.
    linger: Duration,
}

impl NetPolicy {
    fn from_config(cfg: &AriConfig) -> Self {
        Self {
            max_conns: cfg.net_max_conns,
            read_deadline: (cfg.net_read_deadline_us > 0).then(|| Duration::from_micros(cfg.net_read_deadline_us)),
            max_in_flight: cfg.net_max_in_flight,
            write_buf_cap: cfg.net_write_buf_cap,
            linger: Duration::from_micros(cfg.net_linger_us),
        }
    }
}

/// Routing record for one admitted request: which connection slot (and
/// which incarnation of it) receives the response, plus the client's
/// echo fields.  `Request::row` indexes the ticket table, so the
/// dispatcher needs no wire knowledge at all.
#[derive(Clone, Copy)]
struct Ticket {
    /// Client-chosen request id, echoed verbatim in the response.
    id: u64,
    /// Client send stamp (µs), echoed verbatim in the response.
    send_us: u64,
    /// Connection slab slot.
    conn: u32,
    /// Slot generation at admission; a mismatch at routing time means
    /// the connection died and was (possibly) replaced.
    gen: u32,
}

/// One live connection's state: reusable read/write buffers, the
/// response queue, and the supervision counters.
struct Conn {
    stream: TcpStream,
    /// Slot generation this connection was created under.
    gen: u32,
    /// Incremental frame decoder (reusable allocation).
    rbuf: proto::FrameBuf,
    /// When the currently-buffered partial frame started arriving;
    /// `None` when the decoder sits on a frame boundary.  Doubles as
    /// the ingress stamp of the next completed frame (net-wait metric)
    /// and as the slow-loris deadline anchor.
    partial_since: Option<Instant>,
    /// Completed responses not yet encoded into `wbuf`.
    pending: VecDeque<proto::ResponseFrame>,
    /// Encoded-but-possibly-unflushed output bytes.
    wbuf: Vec<u8>,
    /// Flushed prefix of `wbuf`.
    wsent: usize,
    /// End offset in `wbuf` of each encoded *response* frame (error
    /// frames are not tracked — they are diagnostics, not responses).
    /// Popped as the flush cursor passes them to count deliveries.
    frame_ends: VecDeque<usize>,
    /// Admitted-but-unanswered requests on this connection.
    in_flight: usize,
    /// Last instant `wsent` advanced (or the accept instant).
    last_write_progress: Instant,
    /// Stop reading; close once everything queued has been flushed
    /// (set on protocol errors).
    close_after_flush: bool,
    /// Peer closed its write half (EOF seen).
    read_closed: bool,
}

impl Conn {
    fn new(stream: TcpStream, gen: u32, now: Instant) -> Self {
        Self {
            stream,
            gen,
            rbuf: proto::FrameBuf::new(),
            partial_since: None,
            pending: VecDeque::new(),
            wbuf: Vec::new(),
            wsent: 0,
            frame_ends: VecDeque::new(),
            in_flight: 0,
            last_write_progress: now,
            close_after_flush: false,
            read_closed: false,
        }
    }
}

/// The net thread's accounting, returned to the caller when the loop
/// exits and `ensure!`d against the dispatcher's completion count.
#[derive(Default)]
struct NetStats {
    conns_accepted: u64,
    conns_refused: u64,
    protocol_errors: u64,
    frames_in: u64,
    admitted: u64,
    shed: u64,
    /// Completions drained from the pipeline and routed (== `admitted`
    /// on every successful session).
    routed: u64,
    /// Response frames fully flushed to a socket.
    responses_sent: u64,
    /// Responses owed to a connection that died first (routed to a
    /// dead slot, or queued/encoded on a connection that was dropped).
    dropped_dead: u64,
    /// Routed completions by [`proto::outcome_tag`] (Ok, Degraded,
    /// Rejected, Failed).
    outcomes: [u64; 4],
}

/// Gather the rows of the batcher's just-fired FIFO prefix out of the
/// ingress row ring into the staged batch's reusable buffer.  Hot path
/// (see `hotpath.txt`): the ring and the buffer both reach steady-state
/// capacity after the first few batches.
fn stage_net_rows(rows: &mut VecDeque<f32>, dim: usize, buf: &mut StagedBatch) {
    buf.x.clear();
    let n = buf.items.len();
    buf.x.extend(rows.drain(..n * dim));
    if fault::inject(fault::DRIFT_SHIFT) {
        fault::drift_rows(&mut buf.x);
    }
}

/// Flush a connection's pending output bytes into its socket.  Returns
/// whether any byte moved; `Err` means the connection must be dropped.
/// Hosts the `write-split` (short writes, forcing client-side
/// reassembly) and `frame-trunc` (emit a partial frame, then die)
/// fault points.
fn flush_conn(c: &mut Conn, now: Instant) -> Result<bool, ()> {
    let mut progress = false;
    while c.wsent < c.wbuf.len() {
        let mut limit = c.wbuf.len() - c.wsent;
        if fault::inject(fault::WRITE_SPLIT) {
            limit = limit.min(3);
        }
        let trunc = fault::inject(fault::FRAME_TRUNC);
        if trunc {
            limit = (limit + 1) / 2;
        }
        match c.stream.write(&c.wbuf[c.wsent..c.wsent + limit]) {
            Ok(0) => return Err(()),
            Ok(n) => {
                c.wsent += n;
                c.last_write_progress = now;
                progress = true;
                if trunc {
                    return Err(());
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(_) => return Err(()),
        }
    }
    Ok(progress)
}

/// Reclaim the flushed prefix of a connection's write buffer, keeping
/// the tracked frame-end offsets valid.
fn compact_wbuf(c: &mut Conn) {
    if c.wsent == 0 {
        return;
    }
    let sent = c.wsent;
    c.wbuf.copy_within(sent.., 0);
    c.wbuf.truncate(c.wbuf.len() - sent);
    for e in &mut c.frame_ends {
        *e -= sent;
    }
    c.wsent = 0;
}

/// Net-loop phase.
enum Phase {
    /// Accepting connections, reading, admitting, serving.
    Accepting,
    /// Request budget reached (or clients gone): no more reads; flush
    /// the batcher's tail into the pipeline.
    Draining,
    /// Batcher empty, staged queue closed: route the last completions
    /// and flush every socket.
    Flushing,
}

/// The network front-end: listener, connection slab, ingress batcher,
/// and the queue endpoints it shares with the inference loop.  Runs on
/// its own scoped thread via [`NetFront::run`].
struct NetFront<'q> {
    listener: TcpListener,
    policy: NetPolicy,
    /// Features per request row (requests with any other count are shed).
    dim: usize,
    /// Per-request completion deadline (the pipeline's, not the wire's).
    deadline: Option<Duration>,
    /// Session request budget: after this many admitted + shed the
    /// session drains (loopback suites size it to the client's load).
    budget: usize,
    batcher: Batcher<Request>,
    staged: &'q BoundedQueue<StagedBatch>,
    empties: &'q BoundedQueue<StagedBatch>,
    comps: &'q BoundedQueue<Completion>,
    hb: &'q Heartbeat,
    conns: Vec<Option<Conn>>,
    /// Per-slot generation counters (bumped on every close, clean or
    /// not, so stale tickets can never route to a slot's next tenant).
    gens: Vec<u32>,
    tickets: Vec<Ticket>,
    /// Free ticket indices (tickets are recycled like every other
    /// steady-state buffer).
    free: Vec<u32>,
    /// Ingress row ring, FIFO-parallel to the batcher's queue.
    rows: VecDeque<f32>,
    /// Pipeline-internal request id counter.
    seq: u64,
    ever_accepted: bool,
    stats: NetStats,
    /// Shared metrics registry — read-only here, for answering stats
    /// requests (the dispatcher owns the writes).
    metrics: &'q MetricsRegistry,
    /// The dispatcher's published control-loop snapshot (see
    /// [`ControlStats`]), read when answering stats requests.
    ctl_stats: &'q ControlStats,
}

impl<'q> NetFront<'q> {
    #[allow(clippy::too_many_arguments)]
    fn new(
        listener: TcpListener,
        policy: NetPolicy,
        dim: usize,
        deadline: Option<Duration>,
        budget: usize,
        batcher_policy: BatcherPolicy,
        staged: &'q BoundedQueue<StagedBatch>,
        empties: &'q BoundedQueue<StagedBatch>,
        comps: &'q BoundedQueue<Completion>,
        hb: &'q Heartbeat,
        metrics: &'q MetricsRegistry,
        ctl_stats: &'q ControlStats,
    ) -> Self {
        Self {
            listener,
            policy,
            dim,
            deadline,
            budget,
            batcher: Batcher::new(batcher_policy),
            staged,
            empties,
            comps,
            hb,
            conns: Vec::new(),
            gens: Vec::new(),
            tickets: Vec::new(),
            free: Vec::new(),
            rows: VecDeque::new(),
            seq: 0,
            ever_accepted: false,
            stats: NetStats::default(),
            metrics,
            ctl_stats,
        }
    }

    /// Answer one stats request: assemble a [`proto::StatsReply`] from
    /// the wire ledger, the metrics registry and the dispatcher's
    /// published control snapshot, and encode it straight into the
    /// connection's write buffer.  Stats frames are diagnostics —
    /// deliberately *not* recorded in `frame_ends` (the
    /// response-conservation ledger) and never counted against the
    /// session's request budget.
    fn answer_stats(&self, c: &mut Conn) {
        let reply = proto::StatsReply {
            admitted: self.stats.admitted,
            shed: self.stats.shed,
            responses_sent: self.stats.responses_sent,
            completed: self.metrics.completed.load(Ordering::Relaxed),
            degraded: self.metrics.degraded.load(Ordering::Relaxed),
            rejected: self.metrics.rejected.load(Ordering::Relaxed),
            failed: self.metrics.failed.load(Ordering::Relaxed),
            level: self.ctl_stats.level.load(Ordering::Relaxed) as u32,
            drifted: self.ctl_stats.drifted.load(Ordering::Relaxed) != 0,
            recals: self.ctl_stats.recals.load(Ordering::Relaxed) as u32,
            stages: self
                .ctl_stats
                .stage_served
                .iter()
                .zip(&self.ctl_stats.thresholds)
                .take(proto::MAX_STAGES as usize)
                .map(|(served, t)| proto::StageStat {
                    served: served.load(Ordering::Relaxed),
                    threshold: f64::from_bits(t.load(Ordering::Relaxed)),
                })
                .collect(),
        };
        proto::encode_stats(&mut c.wbuf, &reply);
    }

    fn live_conns(&self) -> usize {
        self.conns.iter().filter(|c| c.is_some()).count()
    }

    /// Requests answered one way or the other so far.
    fn handled(&self) -> u64 {
        self.stats.admitted + self.stats.shed
    }

    /// Account a dying connection's undeliverable responses.
    fn drop_conn_state(&mut self, c: &Conn) {
        self.stats.dropped_dead += c.pending.len() as u64 + c.frame_ends.len() as u64;
    }

    /// Drop every remaining connection (error/stuck-shutdown path);
    /// their queued responses are counted, not lost silently.
    fn abandon(&mut self) {
        for slot in 0..self.conns.len() {
            if let Some(c) = self.conns[slot].take() {
                self.drop_conn_state(&c);
                self.gens[slot] = self.gens[slot].wrapping_add(1);
            }
        }
    }

    /// Close every remaining (fully flushed) connection cleanly.
    fn close_all(&mut self) {
        for slot in 0..self.conns.len() {
            if self.conns[slot].take().is_some() {
                self.gens[slot] = self.gens[slot].wrapping_add(1);
            }
        }
    }

    /// Accept every waiting connection (non-blocking).  Hosts the
    /// `accept-stall` fault point (a stalled accept loop — new peers
    /// wait, existing ones are unaffected).
    fn accept_new(&mut self, now: Instant) -> bool {
        if fault::inject(fault::ACCEPT_STALL) {
            std::thread::sleep(fault::STALL);
        }
        let mut progress = false;
        loop {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    progress = true;
                    if self.live_conns() >= self.policy.max_conns || stream.set_nonblocking(true).is_err() {
                        // Refusal is the backpressure of last resort:
                        // the peer sees an immediate close and may
                        // retry with backoff.
                        self.stats.conns_refused += 1;
                        continue;
                    }
                    stream.set_nodelay(true).ok();
                    let slot = match self.conns.iter().position(Option::is_none) {
                        Some(s) => s,
                        None => {
                            self.conns.push(None);
                            self.gens.push(0);
                            self.conns.len() - 1
                        }
                    };
                    self.conns[slot] = Some(Conn::new(stream, self.gens[slot], now));
                    self.stats.conns_accepted += 1;
                    self.ever_accepted = true;
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                // Transient accept errors (per-connection resets
                // surfacing here): skip this sweep, try again next.
                Err(_) => break,
            }
        }
        progress
    }

    /// Record a protocol violation: queue a typed error frame for the
    /// peer, stop reading, and close once the error has been flushed.
    fn proto_violation(&mut self, c: &mut Conn, e: proto::ProtoError) {
        proto::encode_error(&mut c.wbuf, e.code(), e.detail());
        c.close_after_flush = true;
        c.read_closed = true;
        c.rbuf.clear();
        c.partial_since = None;
        self.stats.protocol_errors += 1;
    }

    /// Admit one decoded request into the batching pipeline (hot path —
    /// see `hotpath.txt`; its row bytes were already appended to the
    /// ingress ring by the caller, and the recycled ticket table makes
    /// the steady state allocation-free).
    #[allow(clippy::too_many_arguments)]
    fn admit_request(
        &mut self,
        in_flight: &mut usize,
        gen: u32,
        slot: u32,
        id: u64,
        send_us: u64,
        ingress: Instant,
        now: Instant,
    ) {
        let t = Ticket { id, send_us, conn: slot, gen };
        let ticket = match self.free.pop() {
            Some(i) => {
                self.tickets[i as usize] = t;
                i as usize
            }
            None => {
                self.tickets.push(t);
                self.tickets.len() - 1
            }
        };
        let seq = self.seq;
        self.seq += 1;
        self.batcher.push_at(
            Request { id: seq, row: ticket, submitted: ingress, deadline: self.deadline.map(|d| ingress + d) },
            now,
        );
        *in_flight += 1;
        self.stats.admitted += 1;
    }

    /// Decode every complete frame buffered on `c`, admitting or
    /// shedding requests.  The first frame completed by this read
    /// inherits the partial-frame ingress stamp (its bytes started
    /// arriving earlier); later frames arrived wholly in this read.
    fn decode_frames(&mut self, c: &mut Conn, slot: usize, now: Instant) {
        let mut pending_ingress = c.partial_since.take();
        loop {
            match c.rbuf.next_frame() {
                Ok(Some(proto::Frame::Request(rf))) => {
                    self.stats.frames_in += 1;
                    let ingress = pending_ingress.take().unwrap_or(now);
                    let backlogged = c.wbuf.len() - c.wsent >= self.policy.write_buf_cap;
                    if rf.n_features() != self.dim
                        || c.in_flight >= self.policy.max_in_flight
                        || backlogged
                        || self.handled() >= self.budget as u64
                    {
                        // Shed: a typed Rejected response straight to
                        // the response queue — the pipeline never sees
                        // the request, the client gets an answer.
                        c.pending.push_back(proto::ResponseFrame {
                            id: rf.id,
                            send_us: rf.send_us,
                            outcome: CompletionOutcome::Rejected,
                            stage: 0,
                            pred: -1,
                            margin: 0.0,
                        });
                        self.stats.shed += 1;
                    } else {
                        self.rows.extend(rf.features());
                        self.admit_request(&mut c.in_flight, c.gen, slot as u32, rf.id, rf.send_us, ingress, now);
                    }
                }
                Ok(Some(proto::Frame::StatsRequest)) => {
                    self.answer_stats(c);
                }
                // Only clients send requests; a response, error or
                // stats frame arriving at the server is a protocol
                // violation.
                Ok(Some(proto::Frame::Response(_))) => {
                    self.proto_violation(c, proto::ProtoError::BadKind { kind: proto::KIND_RESPONSE });
                    return;
                }
                Ok(Some(proto::Frame::Error(_))) => {
                    self.proto_violation(c, proto::ProtoError::BadKind { kind: proto::KIND_ERROR });
                    return;
                }
                Ok(Some(proto::Frame::Stats(_))) => {
                    self.proto_violation(c, proto::ProtoError::BadKind { kind: proto::KIND_STATS });
                    return;
                }
                Ok(None) => break,
                Err(e) => {
                    self.proto_violation(c, e);
                    return;
                }
            }
        }
        if c.rbuf.has_partial() {
            c.partial_since = pending_ingress.or(Some(now));
        }
        c.rbuf.compact();
    }

    /// One supervision sweep over every connection: read + decode
    /// (while `read_allowed`), slow-loris check, response encode +
    /// flush, and the close/kill decisions.  Hosts the `conn-drop`
    /// (peer vanishes) and `frame-corrupt` (a read byte flips) fault
    /// points.
    fn pump_conns(&mut self, now: Instant, read_allowed: bool) -> bool {
        let mut progress = false;
        let mut chunk = [0u8; READ_CHUNK];
        for slot in 0..self.conns.len() {
            let Some(mut c) = self.conns[slot].take() else { continue };
            let mut kill = false;

            if fault::inject(fault::CONN_DROP) {
                self.drop_conn_state(&c);
                self.gens[slot] = self.gens[slot].wrapping_add(1);
                continue;
            }

            if read_allowed && !c.read_closed && !c.close_after_flush {
                match c.stream.read(&mut chunk) {
                    Ok(0) => {
                        progress = true;
                        c.read_closed = true;
                        if c.rbuf.has_partial() {
                            self.proto_violation(&mut c, proto::ProtoError::Truncated);
                        }
                    }
                    Ok(n) => {
                        progress = true;
                        if fault::inject(fault::FRAME_CORRUPT) {
                            chunk[0] ^= 0x40;
                        }
                        c.rbuf.extend(&chunk[..n]);
                        self.decode_frames(&mut c, slot, now);
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {}
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                    Err(_) => kill = true,
                }
            }

            // Slow-loris: a partial frame outliving the read deadline
            // closes the connection with a typed Stalled error.
            if !kill && !c.read_closed {
                if let (Some(dl), Some(t0)) = (self.policy.read_deadline, c.partial_since) {
                    if now.duration_since(t0) >= dl {
                        self.proto_violation(&mut c, proto::ProtoError::Stalled);
                    }
                }
            }

            if !kill {
                // Encode completed responses up to the write-buffer
                // cap, then flush as much as the socket accepts.
                while c.wbuf.len() - c.wsent < self.policy.write_buf_cap {
                    let Some(rf) = c.pending.pop_front() else { break };
                    proto::encode_response(&mut c.wbuf, &rf);
                    c.frame_ends.push_back(c.wbuf.len());
                }
                match flush_conn(&mut c, now) {
                    Ok(p) => {
                        progress |= p;
                        while c.frame_ends.front().is_some_and(|&e| e <= c.wsent) {
                            c.frame_ends.pop_front();
                            self.stats.responses_sent += 1;
                        }
                        compact_wbuf(&mut c);
                    }
                    Err(()) => kill = true,
                }
            }

            // A peer holding unflushed bytes without accepting a single
            // one for `linger` is gone in all but name.
            if !kill && c.wsent < c.wbuf.len() && now.duration_since(c.last_write_progress) >= self.policy.linger {
                kill = true;
            }

            if kill {
                self.drop_conn_state(&c);
                self.gens[slot] = self.gens[slot].wrapping_add(1);
                progress = true;
                continue;
            }

            let flushed = c.wsent == c.wbuf.len() && c.pending.is_empty();
            if (c.read_closed || c.close_after_flush) && c.in_flight == 0 && flushed {
                // Clean close: everything owed has been delivered.
                self.gens[slot] = self.gens[slot].wrapping_add(1);
                progress = true;
                continue;
            }
            self.conns[slot] = Some(c);
        }
        progress
    }

    /// Fire every due batch into the pipeline.  Buffers come from the
    /// `empties` queue non-blockingly — when both staging buffers are
    /// in flight the batcher simply holds the batch until the next
    /// sweep (the pipeline is the backpressure).  Returns `false` when
    /// the pipeline is closed.
    fn fire_ready(&mut self, now: Instant) -> bool {
        while self.batcher.ready(now) {
            let Some(mut buf) = self.empties.try_pop() else { break };
            if self.batcher.try_fire_into(now, &mut buf.items).is_none() {
                let _ = self.empties.try_push(buf);
                break;
            }
            stage_net_rows(&mut self.rows, self.dim, &mut buf);
            // Never blocks: a buffer just left the 2-deep circulation,
            // so the staged queue has a free slot.
            if self.staged.push(buf).is_err() {
                return false;
            }
        }
        true
    }

    /// Shutdown flush: drain the batcher's tail into the pipeline in
    /// `<= max_batch` chunks.  Returns `(progress, alive)`.
    fn flush_batcher(&mut self) -> (bool, bool) {
        let mut progress = false;
        while !self.batcher.is_empty() {
            let Some(mut buf) = self.empties.try_pop() else { break };
            if self.batcher.drain_into(&mut buf.items).is_none() {
                let _ = self.empties.try_push(buf);
                break;
            }
            stage_net_rows(&mut self.rows, self.dim, &mut buf);
            if self.staged.push(buf).is_err() {
                return (progress, false);
            }
            progress = true;
        }
        (progress, true)
    }

    /// Drain every completion the inference loop has produced, routing
    /// each to its connection (or counting it against a dead one).
    fn route_completions(&mut self) -> bool {
        let mut progress = false;
        while let Some(done) = self.comps.try_pop() {
            progress = true;
            self.stats.routed += 1;
            self.stats.outcomes[proto::outcome_tag(done.outcome) as usize] += 1;
            let ti = done.row;
            let t = self.tickets[ti];
            let live = self
                .conns
                .get_mut(t.conn as usize)
                .and_then(Option::as_mut)
                .filter(|conn| conn.gen == t.gen);
            match live {
                Some(conn) => {
                    conn.pending.push_back(proto::ResponseFrame {
                        id: t.id,
                        send_us: t.send_us,
                        outcome: done.outcome,
                        stage: done.stage as u8,
                        pred: done.pred,
                        margin: done.margin,
                    });
                    conn.in_flight = conn.in_flight.saturating_sub(1);
                }
                None => self.stats.dropped_dead += 1,
            }
            self.free.push(ti as u32);
        }
        progress
    }

    /// The net loop: accept → read/decode/admit → fire → route → flush,
    /// then the two-step shutdown (drain the batcher, flush the
    /// sockets).  Beats the watchdog heartbeat once per sweep while
    /// accepting/draining, but only on *progress* while flushing — a
    /// stuck drain therefore stops the heartbeat and lets the watchdog
    /// convert the hang into a diagnostic error.
    fn run(mut self) -> NetStats {
        let mut phase = Phase::Accepting;
        let mut idle_conns_since: Option<Instant> = None;
        let mut last_progress = net_now();
        // How long the flush phase tolerates zero progress before
        // force-dropping the stragglers (bounded shutdown even with the
        // watchdog disabled).
        let force_drop_after = self.policy.linger.max(Duration::from_millis(250));
        loop {
            let now = net_now();
            match phase {
                Phase::Accepting => {
                    self.hb.beat();
                    if self.staged.is_closed() {
                        // Watchdog or inference error: release everything.
                        self.abandon();
                        return self.stats;
                    }
                    let mut progress = self.accept_new(now);
                    progress |= self.pump_conns(now, true);
                    if !self.fire_ready(now) {
                        self.abandon();
                        return self.stats;
                    }
                    progress |= self.route_completions();
                    if self.handled() >= self.budget as u64 {
                        phase = Phase::Draining;
                    } else if self.ever_accepted && self.live_conns() == 0 {
                        // Clients came and went: linger briefly for a
                        // reconnect, then begin shutdown.
                        let since = *idle_conns_since.get_or_insert(now);
                        if now.duration_since(since) >= self.policy.linger {
                            phase = Phase::Draining;
                        }
                    } else {
                        idle_conns_since = None;
                    }
                    if !progress && matches!(phase, Phase::Accepting) {
                        std::thread::sleep(IDLE_SLEEP);
                    }
                }
                Phase::Draining => {
                    self.hb.beat();
                    if self.staged.is_closed() {
                        self.abandon();
                        return self.stats;
                    }
                    let (mut progress, alive) = self.flush_batcher();
                    if !alive {
                        self.abandon();
                        return self.stats;
                    }
                    progress |= self.route_completions();
                    progress |= self.pump_conns(now, false);
                    if self.batcher.is_empty() {
                        self.staged.close();
                        phase = Phase::Flushing;
                        last_progress = now;
                    } else if !progress {
                        std::thread::sleep(IDLE_SLEEP);
                    }
                }
                Phase::Flushing => {
                    let mut progress = self.route_completions();
                    progress |= self.pump_conns(now, false);
                    if progress {
                        self.hb.beat();
                        last_progress = now;
                    }
                    let comps_done = self.comps.is_closed() && self.comps.len() == 0;
                    let unflushed = self
                        .conns
                        .iter()
                        .flatten()
                        .any(|c| c.wsent < c.wbuf.len() || !c.pending.is_empty());
                    if comps_done && !unflushed {
                        self.close_all();
                        return self.stats;
                    }
                    if now.duration_since(last_progress) >= force_drop_after {
                        if comps_done {
                            // Only stuck sockets remain: force-drop
                            // them (counted) and finish.
                            self.abandon();
                            return self.stats;
                        }
                        // Inference is stuck: keep *not* beating so the
                        // watchdog closes the pipeline; the closed
                        // completion queue unblocks this loop above.
                    }
                    if !progress {
                        std::thread::sleep(IDLE_SLEEP);
                    }
                }
            }
        }
    }
}

/// Aggregated report of one network serving session: the wire-side
/// conservation ledger plus the same latency/energy metrics as the
/// in-process [`super::ServeReport`].
#[derive(Debug)]
pub struct NetServeReport {
    /// Connections accepted over the session.
    pub conns_accepted: u64,
    /// Connections refused (over the `max_conns` cap).
    pub conns_refused: u64,
    /// Connections closed for a protocol violation (each got a typed
    /// error frame; see `docs/PROTOCOL.md`).
    pub protocol_errors: u64,
    /// Request frames decoded.
    pub frames_in: u64,
    /// Requests admitted into the inference pipeline.
    pub admitted: u64,
    /// Requests shed at admission with a typed `Rejected` response
    /// (in-flight cap, write backpressure, dimension mismatch, or
    /// session budget).
    pub shed: u64,
    /// Response frames fully delivered to a socket.
    pub responses_sent: u64,
    /// Responses owed to connections that died first.  Always
    /// `responses_sent + dropped_dead == admitted + shed`.
    pub dropped_dead: u64,
    /// Routed pipeline completions by outcome tag (Ok, Degraded,
    /// Rejected, Failed).  Shed requests are *not* in here — they
    /// never reached the pipeline.
    pub outcomes: [u64; 4],
    /// Wall time of the whole session.
    pub wall: Duration,
    /// Admitted requests per second of wall time.
    pub throughput_rps: f64,
    /// Median server-side request latency (ingress → completion).
    pub p50: Duration,
    /// 95th-percentile server-side latency.
    pub p95: Duration,
    /// 99th-percentile server-side latency.
    pub p99: Duration,
    /// Mean server-side latency.
    pub mean_latency: Duration,
    /// Mean wire-ingress wait (frame start → batcher enqueue).
    pub net_wait_mean: Duration,
    /// Net-wait samples (one per dispatched request).
    pub net_wait_samples: u64,
    /// Mean batcher wait (enqueue → dispatch).
    pub queue_wait_mean: Duration,
    /// Queue-wait samples (one per dispatched request).
    pub queue_wait_samples: u64,
    /// Pipeline completions served reduced under overload.
    pub degraded: u64,
    /// Pipeline completions rejected past their deadline (distinct from
    /// [`Self::shed`], which never entered the pipeline).
    pub rejected: u64,
    /// Pipeline completions failed after exhausting execute retries.
    pub failed: u64,
    /// Backend execute retries across the session.
    pub retries: u64,
    /// Modelled energy spent (µJ).
    pub energy_uj: f64,
    /// Modelled energy an always-full policy would have spent on the
    /// served (Ok + Degraded) requests (µJ).
    pub energy_full_uj: f64,
    /// Fraction of pipeline completions that escalated.
    pub escalation_fraction: f64,
}

impl NetServeReport {
    /// Savings vs running every served request on the full model.
    pub fn savings(&self) -> f64 {
        if self.energy_full_uj == 0.0 {
            return 0.0;
        }
        1.0 - self.energy_uj / self.energy_full_uj
    }

    /// Human-readable summary block.
    pub fn summary(&self) -> String {
        format!(
            "net: {} conns accepted ({} refused, {} protocol errors), {} frames in\n\
             requests: {} admitted + {} shed -> {} responses sent, {} dropped to dead conns\n\
             outcomes: ok {} degraded {} rejected {} failed {}  escalation {:.2}%\n\
             served in {:.2?} ({:.0} req/s)\n\
             latency mean {:?} p50 {:?} p95 {:?} p99 {:?} (net wait mean {:?}, queue wait mean {:?})\n\
             robustness: degraded {} rejected {} failed {} retries {}\n\
             energy {:.1} µJ vs always-full {:.1} µJ -> savings {:.1}%",
            self.conns_accepted,
            self.conns_refused,
            self.protocol_errors,
            self.frames_in,
            self.admitted,
            self.shed,
            self.responses_sent,
            self.dropped_dead,
            self.outcomes[0],
            self.outcomes[1],
            self.outcomes[2],
            self.outcomes[3],
            100.0 * self.escalation_fraction,
            self.wall,
            self.throughput_rps,
            self.mean_latency,
            self.p50,
            self.p95,
            self.p99,
            self.net_wait_mean,
            self.queue_wait_mean,
            self.degraded,
            self.rejected,
            self.failed,
            self.retries,
            self.energy_uj,
            self.energy_full_uj,
            100.0 * self.savings(),
        )
    }
}

/// Closes all three pipeline queues on drop, so an inference error (or
/// panic) on the serving thread always releases the net thread.
struct CloseAllOnDrop<'q> {
    staged: &'q BoundedQueue<StagedBatch>,
    empties: &'q BoundedQueue<StagedBatch>,
    comps: &'q BoundedQueue<Completion>,
}

impl Drop for CloseAllOnDrop<'_> {
    fn drop(&mut self) {
        self.staged.close();
        self.empties.close();
        self.comps.close();
    }
}

/// Serve ladder inference over a length-prefixed TCP protocol (see
/// `docs/PROTOCOL.md`).  The caller binds the listener (tests use an
/// ephemeral port); requests arrive over the wire instead of from the
/// in-process generator, but flow through the *same* batcher, bounded
/// pipeline, dispatcher and robustness machinery as
/// [`super::run_serving_ladder`] — with `--listen` unset none of this
/// code runs and serving is bit-identical to the in-process path.
///
/// The session ends when `cfg.requests` requests have been admitted or
/// shed (the loopback suites' budget), or when every client has
/// disconnected and `linger` has passed; shutdown drains the batcher,
/// completes every admitted request, and flushes every socket.  On
/// success the report satisfies two conservation invariants, `ensure!`d
/// here: every admitted request was routed exactly once, and every
/// admitted-or-shed request's response was either delivered or counted
/// against a dead connection.
pub fn run_net_serving(
    engine: &mut dyn Backend,
    ladder: &Ladder,
    cfg: &AriConfig,
    input_dim: usize,
    opts: ServeOptions,
    listener: TcpListener,
) -> crate::Result<NetServeReport> {
    anyhow::ensure!(
        cfg.batch_size <= ladder.stages[0].variant.batch,
        "server batch_size {} exceeds the ladder's compiled batch {}",
        cfg.batch_size,
        ladder.stages[0].variant.batch
    );
    anyhow::ensure!(
        input_dim > 0 && input_dim <= proto::MAX_FEATURES as usize,
        "input_dim {} outside the wire protocol's 1..={} feature bound",
        input_dim,
        proto::MAX_FEATURES
    );
    listener.set_nonblocking(true)?;
    let robustness = RobustnessPolicy::from_config(cfg);
    let netpol = NetPolicy::from_config(cfg);
    let metrics = MetricsRegistry::new();
    let mut disp = Dispatcher::new(
        ladder,
        RowSource::Inline { dim: input_dim },
        &metrics,
        opts.escalation,
        robustness,
        cfg.requests,
    );
    let control = ControlPolicy::from_config(cfg);
    if control.enabled() {
        disp.set_control(control);
    }
    // Shared with the net thread so stats requests read a live (if
    // slightly stale) control snapshot without locking.
    let ctl_stats = ControlStats::new(ladder);
    let staged: BoundedQueue<StagedBatch> = BoundedQueue::new(PIPELINE_DEPTH);
    let empties: BoundedQueue<StagedBatch> = BoundedQueue::new(PIPELINE_DEPTH);
    for _ in 0..PIPELINE_DEPTH {
        let _ = empties.push(StagedBatch::default());
    }
    let comps: BoundedQueue<Completion> = BoundedQueue::new(COMP_QUEUE_DEPTH);
    let hb = Heartbeat::default();
    let stalled = AtomicBool::new(false);
    let wd_stop: (Mutex<bool>, Condvar) = (Mutex::new(false), Condvar::new());
    let t_start = net_now();
    let batch_size = cfg.batch_size;
    let batcher_policy = BatcherPolicy::new(cfg.batch_size, Duration::from_micros(cfg.batch_timeout_us));
    let (serve_result, stats): (crate::Result<()>, crate::Result<NetStats>) = std::thread::scope(|s| {
        let front = NetFront::new(
            listener,
            netpol,
            input_dim,
            robustness.deadline,
            cfg.requests,
            batcher_policy,
            &staged,
            &empties,
            &comps,
            &hb,
            &metrics,
            &ctl_stats,
        );
        let net = s.spawn(move || front.run());
        if let Some(stall_after) = robustness.watchdog_stall {
            let stalled_ref = &stalled;
            let wd_ref = &wd_stop;
            let hb_ref = &hb;
            let staged_ref = &staged;
            let empties_ref = &empties;
            let comps_ref = &comps;
            s.spawn(move || {
                let (lock, cv) = wd_ref;
                let mut last = hb_ref.count();
                // ari-lint: allow(clock-discipline): the watchdog measures *real* stall
                // time by design, exactly like the in-process serving watchdog.
                let mut last_change = Instant::now();
                let mut done = lock.lock().unwrap_or_else(|e| e.into_inner());
                loop {
                    let poll = Duration::from_millis(100).min(stall_after);
                    let (g, _) = cv.wait_timeout(done, poll).unwrap_or_else(|e| e.into_inner());
                    done = g;
                    if *done {
                        return;
                    }
                    let beats = hb_ref.count();
                    if beats != last {
                        last = beats;
                        // ari-lint: allow(clock-discipline): watchdog real-time restamp,
                        // same rationale as above.
                        last_change = Instant::now();
                        continue;
                    }
                    if last_change.elapsed() >= stall_after {
                        // A stuck net loop *or* a stuck drain: close
                        // every queue so both sides unblock, and turn
                        // the session into a diagnostic Err below.
                        stalled_ref.store(true, Ordering::SeqCst);
                        staged_ref.close();
                        empties_ref.close();
                        comps_ref.close();
                        return;
                    }
                }
            });
        }
        // Inference loop on the calling thread; the guard closes the
        // pipeline on every exit path so the net thread never blocks
        // forever.
        let _guard = CloseAllOnDrop { staged: &staged, empties: &empties, comps: &comps };
        let r: crate::Result<()> = (|| {
            while let Some(mut batch) = staged.pop() {
                disp.backlog_hint = staged.len() * batch_size;
                let n = batch.items.len();
                let r = disp.dispatch(engine, &batch.items, &batch.x[..n * input_dim]);
                batch.items.clear();
                batch.x.clear();
                let _ = empties.push(batch);
                r?;
                disp.publish_stats(&ctl_stats);
                for done in disp.completions.drain(..) {
                    anyhow::ensure!(comps.push(done).is_ok(), "completion queue closed mid-session (watchdog fired)");
                }
            }
            disp.finish(engine)?;
            disp.publish_stats(&ctl_stats);
            for done in disp.completions.drain(..) {
                anyhow::ensure!(comps.push(done).is_ok(), "completion queue closed during drain (watchdog fired)");
            }
            Ok(())
        })();
        if r.is_err() {
            // Release the net thread before joining it.
            staged.close();
            empties.close();
        }
        comps.close();
        let stats = net
            .join()
            .map_err(|p| anyhow::anyhow!("net front-end panicked: {}", panic_msg(p.as_ref())));
        *wd_stop.0.lock().unwrap_or_else(|e| e.into_inner()) = true;
        wd_stop.1.notify_all();
        (r, stats)
    });
    if stalled.load(Ordering::SeqCst) {
        anyhow::bail!(
            "net serving stalled: no front-end heartbeat for {:?} (accept loop stuck or shutdown drain wedged); \
             watchdog closed the pipeline",
            robustness.watchdog_stall.unwrap_or_default()
        );
    }
    serve_result?;
    let stats = stats?;
    let wall = t_start.elapsed();
    anyhow::ensure!(
        stats.routed == stats.admitted,
        "net serving lost completions: routed {} of {} admitted",
        stats.routed,
        stats.admitted
    );
    anyhow::ensure!(
        stats.responses_sent + stats.dropped_dead == stats.admitted + stats.shed,
        "net serving response conservation broken: {} sent + {} dropped != {} admitted + {} shed",
        stats.responses_sent,
        stats.dropped_dead,
        stats.admitted,
        stats.shed
    );
    let served = stats.outcomes[0] + stats.outcomes[1];
    Ok(NetServeReport {
        conns_accepted: stats.conns_accepted,
        conns_refused: stats.conns_refused,
        protocol_errors: stats.protocol_errors,
        frames_in: stats.frames_in,
        admitted: stats.admitted,
        shed: stats.shed,
        responses_sent: stats.responses_sent,
        dropped_dead: stats.dropped_dead,
        outcomes: stats.outcomes,
        throughput_rps: stats.admitted as f64 / wall.as_secs_f64().max(1e-9),
        p50: metrics.latency.quantile(0.5),
        p95: metrics.latency.quantile(0.95),
        p99: metrics.latency.quantile(0.99),
        mean_latency: metrics.latency.mean(),
        net_wait_mean: metrics.net_wait.mean(),
        net_wait_samples: metrics.net_wait.count(),
        queue_wait_mean: metrics.queue_wait.mean(),
        queue_wait_samples: metrics.queue_wait.count(),
        degraded: metrics.degraded.load(Ordering::Relaxed),
        rejected: metrics.rejected.load(Ordering::Relaxed),
        failed: metrics.failed.load(Ordering::Relaxed),
        retries: metrics.retries.load(Ordering::Relaxed),
        energy_uj: metrics.energy_uj(),
        energy_full_uj: served as f64 * ladder.e_full(),
        escalation_fraction: metrics.escalation_fraction(),
        wall,
    })
}
