//! The ARI wire protocol: length-prefixed binary frames.
//!
//! Every frame is a little-endian `u32` payload length followed by the
//! payload, whose first byte is the frame kind:
//!
//! ```text
//! request    (kind 1): id u64 | send_us u64 | n_features u32 | n × f32
//! response   (kind 2): id u64 | send_us u64 | outcome u8 | stage u8 | pred i32 | margin f32
//! error      (kind 3): code u8 | detail u32
//! stats-req  (kind 4): (kind byte only)
//! stats      (kind 5): admitted u64 | shed u64 | responses_sent u64 | completed u64
//!                      | degraded u64 | rejected u64 | failed u64
//!                      | level u32 | drifted u8 | recals u32
//!                      | n_stages u8 | n × (served u64 | threshold f64)
//! ```
//!
//! The stats pair is the observability side-channel: a client sends a
//! bare `stats-req` and gets back the server's live counters, per-stage
//! serving mix and the control loop's current state (effective
//! thresholds, tighten level, drift flag — see `docs/ROBUSTNESS.md`,
//! "Control loop").  Stats frames are *diagnostics*, not responses:
//! they never count against the session's request budget or the
//! response-conservation ledger.
//!
//! The decoder ([`FrameBuf::next_frame`]) is **total over arbitrary
//! bytes**: every input either yields a frame, asks for more bytes, or
//! returns a typed [`ProtoError`] — it never panics and never
//! allocates.  Malformed input is unrecoverable by design (a corrupted
//! length prefix desynchronises the stream), so the contract is "typed
//! error, then a clean connection close", mirrored on the peer by an
//! error frame when the socket still works.  See `docs/PROTOCOL.md`
//! for the full grammar and error taxonomy.

use crate::server::CompletionOutcome;

/// Frame kind tag: client → server inference request.
pub const KIND_REQUEST: u8 = 1;
/// Frame kind tag: server → client completion response.
pub const KIND_RESPONSE: u8 = 2;
/// Frame kind tag: a typed protocol error, sent before closing.
pub const KIND_ERROR: u8 = 3;
/// Frame kind tag: client → server stats request (kind byte only).
pub const KIND_STATS_REQ: u8 = 4;
/// Frame kind tag: server → client stats snapshot.
pub const KIND_STATS: u8 = 5;

/// Most ladder stages a stats frame may describe; bounds the frame and
/// matches any ladder the config layer can express.
pub const MAX_STAGES: u8 = 16;

/// Most features a request frame may carry; bounds the decode buffer a
/// malicious length prefix can demand.
pub const MAX_FEATURES: u32 = 4096;
/// Largest legal payload: a request frame carrying [`MAX_FEATURES`]
/// features (fixed header 21 bytes + 4 bytes per feature).
pub const MAX_FRAME_LEN: u32 = REQ_HEADER + 4 * MAX_FEATURES;

/// Request payload bytes before the feature data: kind + id + send_us
/// + n_features.
const REQ_HEADER: u32 = 1 + 8 + 8 + 4;
/// Response payload length: kind + id + send_us + outcome + stage +
/// pred + margin.
const RESP_LEN: u32 = 1 + 8 + 8 + 1 + 1 + 4 + 4;
/// Error payload length: kind + code + detail.
const ERR_LEN: u32 = 1 + 1 + 4;
/// Stats-request payload length: the kind byte alone.
const STATS_REQ_LEN: u32 = 1;
/// Stats payload bytes before the per-stage records: kind + 7 × u64
/// counters + level u32 + drifted u8 + recals u32 + n_stages u8.
const STATS_HEADER: u32 = 1 + 7 * 8 + 4 + 1 + 4 + 1;
/// Bytes per per-stage record: served u64 + threshold f64.
const STAGE_REC: u32 = 8 + 8;

/// Why a byte stream failed to decode.  One variant per way the wire
/// can lie; [`ProtoError::code`] gives the tag shipped in an error
/// frame so the peer learns *why* it is being closed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProtoError {
    /// The length prefix is zero or exceeds [`MAX_FRAME_LEN`].
    BadLength {
        /// The offending length prefix.
        len: u32,
    },
    /// The payload's first byte is not a known frame kind.
    BadKind {
        /// The offending kind byte.
        kind: u8,
    },
    /// The payload length contradicts its kind's wire size.
    SizeMismatch {
        /// The frame kind whose size was violated.
        kind: u8,
        /// The offending payload length.
        len: u32,
    },
    /// A response frame carries an unknown outcome tag.
    BadOutcome {
        /// The offending outcome tag.
        tag: u8,
    },
    /// A request frame claims more than [`MAX_FEATURES`] features.
    TooManyFeatures {
        /// The claimed feature count.
        n: u32,
    },
    /// The stream ended mid-frame (connection closed with a partial
    /// frame buffered).
    Truncated,
    /// The peer stopped mid-frame past the read deadline (slow-loris).
    Stalled,
}

impl ProtoError {
    /// Wire tag for an error frame's `code` field.
    pub fn code(&self) -> u8 {
        match self {
            ProtoError::BadLength { .. } => 1,
            ProtoError::BadKind { .. } => 2,
            ProtoError::SizeMismatch { .. } => 3,
            ProtoError::BadOutcome { .. } => 4,
            ProtoError::TooManyFeatures { .. } => 5,
            ProtoError::Truncated => 6,
            ProtoError::Stalled => 7,
        }
    }

    /// The detail value shipped alongside [`Self::code`] in an error
    /// frame (the offending length/kind/tag/count; 0 where the variant
    /// carries none).
    pub fn detail(&self) -> u32 {
        match *self {
            ProtoError::BadLength { len } => len,
            ProtoError::BadKind { kind } => kind as u32,
            ProtoError::SizeMismatch { len, .. } => len,
            ProtoError::BadOutcome { tag } => tag as u32,
            ProtoError::TooManyFeatures { n } => n,
            ProtoError::Truncated | ProtoError::Stalled => 0,
        }
    }
}

impl std::fmt::Display for ProtoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProtoError::BadLength { len } => write!(f, "bad frame length {len}"),
            ProtoError::BadKind { kind } => write!(f, "unknown frame kind {kind}"),
            ProtoError::SizeMismatch { kind, len } => write!(f, "payload length {len} wrong for kind {kind}"),
            ProtoError::BadOutcome { tag } => write!(f, "unknown outcome tag {tag}"),
            ProtoError::TooManyFeatures { n } => write!(f, "request claims {n} features (max {MAX_FEATURES})"),
            ProtoError::Truncated => write!(f, "stream truncated mid-frame"),
            ProtoError::Stalled => write!(f, "peer stalled mid-frame past the read deadline"),
        }
    }
}

impl std::error::Error for ProtoError {}

/// Map a completion outcome to its wire tag.
pub fn outcome_tag(o: CompletionOutcome) -> u8 {
    match o {
        CompletionOutcome::Ok => 0,
        CompletionOutcome::Degraded => 1,
        CompletionOutcome::Rejected => 2,
        CompletionOutcome::Failed => 3,
    }
}

/// Map a wire tag back to its completion outcome.
pub fn tag_outcome(tag: u8) -> Result<CompletionOutcome, ProtoError> {
    match tag {
        0 => Ok(CompletionOutcome::Ok),
        1 => Ok(CompletionOutcome::Degraded),
        2 => Ok(CompletionOutcome::Rejected),
        3 => Ok(CompletionOutcome::Failed),
        tag => Err(ProtoError::BadOutcome { tag }),
    }
}

/// A decoded inference request, borrowing its feature bytes from the
/// decode buffer (no copy until the server stages the row).
#[derive(Clone, Copy, Debug)]
pub struct RequestFrame<'a> {
    /// Client-chosen request id, echoed in the response.
    pub id: u64,
    /// Client send timestamp (µs since its session start), echoed in
    /// the response so the client can measure wire latency.
    pub send_us: u64,
    /// Raw little-endian feature bytes (`4 * n_features` of them).
    feat: &'a [u8],
}

impl RequestFrame<'_> {
    /// Features carried by this request.
    pub fn n_features(&self) -> usize {
        self.feat.len() / 4
    }

    /// Iterate the feature row without copying.
    pub fn features(&self) -> impl Iterator<Item = f32> + '_ {
        self.feat.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
    }
}

/// A decoded completion response.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ResponseFrame {
    /// The request id this answers.
    pub id: u64,
    /// The request's `send_us`, echoed verbatim.
    pub send_us: u64,
    /// How the completion was produced.
    pub outcome: CompletionOutcome,
    /// Ladder stage that served the prediction.
    pub stage: u8,
    /// Predicted class (`-1` when rejected or failed).
    pub pred: i32,
    /// Serving-stage margin (top-1 minus top-2 confidence).
    pub margin: f32,
}

/// A decoded error frame: the peer's parting diagnosis before close.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ErrorFrame {
    /// [`ProtoError::code`] of the error the peer hit.
    pub code: u8,
    /// [`ProtoError::detail`] of the error the peer hit.
    pub detail: u32,
}

/// One per-stage record of a stats frame.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StageStat {
    /// Requests served (completed `Ok`/`Degraded`) at this stage.
    pub served: u64,
    /// The stage's current effective accept threshold (the controller's
    /// view when the control loop is on; the calibrated value
    /// otherwise).
    pub threshold: f64,
}

/// A decoded stats snapshot, borrowing its per-stage records from the
/// decode buffer (same no-copy discipline as [`RequestFrame`]).
#[derive(Clone, Copy, Debug)]
pub struct StatsFrame<'a> {
    /// Requests admitted into the pipeline.
    pub admitted: u64,
    /// Requests shed at admission with typed `Rejected` responses.
    pub shed: u64,
    /// Response frames fully delivered so far.
    pub responses_sent: u64,
    /// Pipeline completions recorded.
    pub completed: u64,
    /// Completions served reduced under overload.
    pub degraded: u64,
    /// Completions rejected past their deadline.
    pub rejected: u64,
    /// Completions failed after exhausting execute retries.
    pub failed: u64,
    /// Control loop's current tighten level (0 = calibrated).
    pub level: u32,
    /// Whether the drift monitor currently holds a drift verdict.
    pub drifted: bool,
    /// Online recalibrations applied so far.
    pub recals: u32,
    /// Raw little-endian per-stage records (`16 * n_stages` bytes).
    raw_stages: &'a [u8],
}

impl StatsFrame<'_> {
    /// Ladder stages described.
    pub fn n_stages(&self) -> usize {
        self.raw_stages.len() / STAGE_REC as usize
    }

    /// Iterate the per-stage records without copying.
    pub fn stages(&self) -> impl Iterator<Item = StageStat> + '_ {
        self.raw_stages.chunks_exact(STAGE_REC as usize).map(|c| StageStat {
            served: u64_at(c, 0),
            threshold: f64::from_bits(u64_at(c, 8)),
        })
    }
}

/// One decoded frame, borrowing from the decode buffer.
#[derive(Clone, Copy, Debug)]
pub enum Frame<'a> {
    /// An inference request.
    Request(RequestFrame<'a>),
    /// A completion response.
    Response(ResponseFrame),
    /// A protocol-error notification.
    Error(ErrorFrame),
    /// A stats request (client → server, no payload).
    StatsRequest,
    /// A stats snapshot (server → client).
    Stats(StatsFrame<'a>),
}

/// Incremental, allocation-reusing frame decoder.  Feed it bytes as
/// they arrive ([`FrameBuf::extend`]); pull complete frames with
/// [`FrameBuf::next_frame`]; call [`FrameBuf::compact`] after draining
/// so consumed bytes are reclaimed instead of growing the buffer.
#[derive(Default)]
pub struct FrameBuf {
    buf: Vec<u8>,
    /// Consumed prefix: bytes before this offset belong to frames
    /// already returned.
    start: usize,
}

impl FrameBuf {
    /// Empty decoder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append freshly read bytes.
    pub fn extend(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Mutable view of the unconsumed bytes (the fault layer flips a
    /// bit here to simulate wire corruption).
    pub fn pending_mut(&mut self) -> &mut [u8] {
        &mut self.buf[self.start..]
    }

    /// Unconsumed bytes buffered.
    pub fn pending(&self) -> usize {
        self.buf.len() - self.start
    }

    /// Whether a partial frame is buffered — the slow-loris signal: a
    /// peer that leaves this true past the read deadline is stalled.
    pub fn has_partial(&self) -> bool {
        self.pending() > 0
    }

    /// Drop everything (connection reset).
    pub fn clear(&mut self) {
        self.buf.clear();
        self.start = 0;
    }

    /// Reclaim consumed bytes: slide the unconsumed tail to the front.
    /// Amortised O(pending); call once per read cycle, after the
    /// decode loop returns `Ok(None)`.
    pub fn compact(&mut self) {
        if self.start == 0 {
            return;
        }
        let n = self.buf.len() - self.start;
        self.buf.copy_within(self.start.., 0);
        self.buf.truncate(n);
        self.start = 0;
    }

    /// Decode the next complete frame, if one is buffered.
    ///
    /// Total over arbitrary input: `Ok(Some(frame))` consumes one
    /// frame, `Ok(None)` means "need more bytes", `Err` is a typed
    /// protocol error after which the stream is unrecoverable (the
    /// caller closes the connection).  Never panics, never allocates.
    pub fn next_frame(&mut self) -> Result<Option<Frame<'_>>, ProtoError> {
        let start = self.start;
        let avail = self.buf.len() - start;
        if avail < 4 {
            return Ok(None);
        }
        let len = u32::from_le_bytes([
            self.buf[start],
            self.buf[start + 1],
            self.buf[start + 2],
            self.buf[start + 3],
        ]);
        if len == 0 || len > MAX_FRAME_LEN {
            return Err(ProtoError::BadLength { len });
        }
        if avail < 4 + len as usize {
            return Ok(None);
        }
        // Consume before borrowing the payload for the return value.
        self.start = start + 4 + len as usize;
        let payload = &self.buf[start + 4..start + 4 + len as usize];
        parse_payload(payload, len)
    }
}

/// Parse one complete payload.  `payload.len() == len` and `len >= 1`
/// are guaranteed by the caller.
fn parse_payload(payload: &[u8], len: u32) -> Result<Option<Frame<'_>>, ProtoError> {
    match payload[0] {
        KIND_REQUEST => {
            if len < REQ_HEADER {
                return Err(ProtoError::SizeMismatch { kind: KIND_REQUEST, len });
            }
            let n = u32::from_le_bytes([payload[17], payload[18], payload[19], payload[20]]);
            if n > MAX_FEATURES {
                return Err(ProtoError::TooManyFeatures { n });
            }
            if len != REQ_HEADER + 4 * n {
                return Err(ProtoError::SizeMismatch { kind: KIND_REQUEST, len });
            }
            Ok(Some(Frame::Request(RequestFrame {
                id: u64_at(payload, 1),
                send_us: u64_at(payload, 9),
                feat: &payload[REQ_HEADER as usize..],
            })))
        }
        KIND_RESPONSE => {
            if len != RESP_LEN {
                return Err(ProtoError::SizeMismatch { kind: KIND_RESPONSE, len });
            }
            Ok(Some(Frame::Response(ResponseFrame {
                id: u64_at(payload, 1),
                send_us: u64_at(payload, 9),
                outcome: tag_outcome(payload[17])?,
                stage: payload[18],
                pred: i32::from_le_bytes([payload[19], payload[20], payload[21], payload[22]]),
                margin: f32::from_le_bytes([payload[23], payload[24], payload[25], payload[26]]),
            })))
        }
        KIND_ERROR => {
            if len != ERR_LEN {
                return Err(ProtoError::SizeMismatch { kind: KIND_ERROR, len });
            }
            Ok(Some(Frame::Error(ErrorFrame {
                code: payload[1],
                detail: u32::from_le_bytes([payload[2], payload[3], payload[4], payload[5]]),
            })))
        }
        KIND_STATS_REQ => {
            if len != STATS_REQ_LEN {
                return Err(ProtoError::SizeMismatch { kind: KIND_STATS_REQ, len });
            }
            Ok(Some(Frame::StatsRequest))
        }
        KIND_STATS => {
            if len < STATS_HEADER {
                return Err(ProtoError::SizeMismatch { kind: KIND_STATS, len });
            }
            let n = payload[STATS_HEADER as usize - 1];
            if n > MAX_STAGES || len != STATS_HEADER + STAGE_REC * n as u32 {
                return Err(ProtoError::SizeMismatch { kind: KIND_STATS, len });
            }
            Ok(Some(Frame::Stats(StatsFrame {
                admitted: u64_at(payload, 1),
                shed: u64_at(payload, 9),
                responses_sent: u64_at(payload, 17),
                completed: u64_at(payload, 25),
                degraded: u64_at(payload, 33),
                rejected: u64_at(payload, 41),
                failed: u64_at(payload, 49),
                level: u32::from_le_bytes([payload[57], payload[58], payload[59], payload[60]]),
                drifted: payload[61] != 0,
                recals: u32::from_le_bytes([payload[62], payload[63], payload[64], payload[65]]),
                raw_stages: &payload[STATS_HEADER as usize..],
            })))
        }
        kind => Err(ProtoError::BadKind { kind }),
    }
}

/// Read a little-endian `u64` at `off` (bounds checked by the caller's
/// size verification).
fn u64_at(b: &[u8], off: usize) -> u64 {
    u64::from_le_bytes([
        b[off],
        b[off + 1],
        b[off + 2],
        b[off + 3],
        b[off + 4],
        b[off + 5],
        b[off + 6],
        b[off + 7],
    ])
}

/// Append one encoded request frame to `out` (a reusable write
/// buffer — never cleared here).
pub fn encode_request(out: &mut Vec<u8>, id: u64, send_us: u64, row: &[f32]) {
    assert!(row.len() <= MAX_FEATURES as usize, "request row exceeds MAX_FEATURES");
    let len = REQ_HEADER + 4 * row.len() as u32;
    out.extend_from_slice(&len.to_le_bytes());
    out.push(KIND_REQUEST);
    out.extend_from_slice(&id.to_le_bytes());
    out.extend_from_slice(&send_us.to_le_bytes());
    out.extend_from_slice(&(row.len() as u32).to_le_bytes());
    for v in row {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Append one encoded response frame to `out`.
pub fn encode_response(out: &mut Vec<u8>, r: &ResponseFrame) {
    out.extend_from_slice(&RESP_LEN.to_le_bytes());
    out.push(KIND_RESPONSE);
    out.extend_from_slice(&r.id.to_le_bytes());
    out.extend_from_slice(&r.send_us.to_le_bytes());
    out.push(outcome_tag(r.outcome));
    out.push(r.stage);
    out.extend_from_slice(&r.pred.to_le_bytes());
    out.extend_from_slice(&r.margin.to_le_bytes());
}

/// Append one encoded error frame to `out`.
pub fn encode_error(out: &mut Vec<u8>, code: u8, detail: u32) {
    out.extend_from_slice(&ERR_LEN.to_le_bytes());
    out.push(KIND_ERROR);
    out.push(code);
    out.extend_from_slice(&detail.to_le_bytes());
}

/// Owned stats snapshot: what the server assembles to answer a stats
/// request, and what [`super::client::fetch_stats`] hands back.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StatsReply {
    /// Requests admitted into the pipeline.
    pub admitted: u64,
    /// Requests shed at admission.
    pub shed: u64,
    /// Response frames fully delivered so far.
    pub responses_sent: u64,
    /// Pipeline completions recorded.
    pub completed: u64,
    /// Completions served reduced under overload.
    pub degraded: u64,
    /// Completions rejected past their deadline.
    pub rejected: u64,
    /// Completions failed after exhausting execute retries.
    pub failed: u64,
    /// Control loop's current tighten level (0 = calibrated).
    pub level: u32,
    /// Whether the drift monitor currently holds a drift verdict.
    pub drifted: bool,
    /// Online recalibrations applied so far.
    pub recals: u32,
    /// Per-stage serving counts and effective thresholds.
    pub stages: Vec<StageStat>,
}

impl StatsFrame<'_> {
    /// Copy this borrowed frame into an owned [`StatsReply`].
    pub fn to_reply(&self) -> StatsReply {
        StatsReply {
            admitted: self.admitted,
            shed: self.shed,
            responses_sent: self.responses_sent,
            completed: self.completed,
            degraded: self.degraded,
            rejected: self.rejected,
            failed: self.failed,
            level: self.level,
            drifted: self.drifted,
            recals: self.recals,
            stages: self.stages().collect(),
        }
    }
}

/// Append one encoded stats-request frame to `out`.
pub fn encode_stats_request(out: &mut Vec<u8>) {
    out.extend_from_slice(&STATS_REQ_LEN.to_le_bytes());
    out.push(KIND_STATS_REQ);
}

/// Append one encoded stats frame to `out`.
pub fn encode_stats(out: &mut Vec<u8>, s: &StatsReply) {
    assert!(s.stages.len() <= MAX_STAGES as usize, "stats frame exceeds MAX_STAGES");
    let len = STATS_HEADER + STAGE_REC * s.stages.len() as u32;
    out.extend_from_slice(&len.to_le_bytes());
    out.push(KIND_STATS);
    for v in [s.admitted, s.shed, s.responses_sent, s.completed, s.degraded, s.rejected, s.failed] {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out.extend_from_slice(&s.level.to_le_bytes());
    out.push(s.drifted as u8);
    out.extend_from_slice(&s.recals.to_le_bytes());
    out.push(s.stages.len() as u8);
    for st in &s.stages {
        out.extend_from_slice(&st.served.to_le_bytes());
        out.extend_from_slice(&st.threshold.to_bits().to_le_bytes());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_round_trips() {
        let mut wire = Vec::new();
        let row = [0.5f32, -1.25, 3.0];
        encode_request(&mut wire, 42, 7_000, &row);
        let mut fb = FrameBuf::new();
        fb.extend(&wire);
        let Frame::Request(r) = fb.next_frame().unwrap().unwrap() else {
            panic!("expected a request frame");
        };
        assert_eq!(r.id, 42);
        assert_eq!(r.send_us, 7_000);
        assert_eq!(r.n_features(), 3);
        let got: Vec<f32> = r.features().collect();
        assert_eq!(got, row);
        assert!(matches!(fb.next_frame(), Ok(None)));
    }

    #[test]
    fn response_and_error_round_trip() {
        let resp = ResponseFrame {
            id: 9,
            send_us: 123,
            outcome: CompletionOutcome::Degraded,
            stage: 2,
            pred: -1,
            margin: 0.75,
        };
        let mut wire = Vec::new();
        encode_response(&mut wire, &resp);
        encode_error(&mut wire, ProtoError::Truncated.code(), 0);
        let mut fb = FrameBuf::new();
        fb.extend(&wire);
        let Frame::Response(got) = fb.next_frame().unwrap().unwrap() else {
            panic!("expected a response frame");
        };
        assert_eq!(got, resp);
        let Frame::Error(e) = fb.next_frame().unwrap().unwrap() else {
            panic!("expected an error frame");
        };
        assert_eq!(e.code, ProtoError::Truncated.code());
        assert_eq!(e.detail, 0);
        assert!(matches!(fb.next_frame(), Ok(None)));
    }

    #[test]
    fn partial_frames_wait_for_more_bytes() {
        let mut wire = Vec::new();
        encode_request(&mut wire, 1, 0, &[1.0, 2.0]);
        let mut fb = FrameBuf::new();
        for (i, b) in wire.iter().enumerate() {
            assert!(
                matches!(fb.next_frame(), Ok(None)),
                "no frame before byte {i} of {} arrived",
                wire.len()
            );
            fb.extend(std::slice::from_ref(b));
        }
        assert!(matches!(fb.next_frame(), Ok(Some(Frame::Request(_)))));
        assert!(!fb.has_partial());
    }

    #[test]
    fn zero_and_oversized_lengths_are_typed_errors() {
        let mut fb = FrameBuf::new();
        fb.extend(&0u32.to_le_bytes());
        assert_eq!(fb.next_frame().unwrap_err(), ProtoError::BadLength { len: 0 });
        fb.clear();
        fb.extend(&(MAX_FRAME_LEN + 1).to_le_bytes());
        assert_eq!(fb.next_frame().unwrap_err(), ProtoError::BadLength { len: MAX_FRAME_LEN + 1 });
    }

    #[test]
    fn bad_kind_size_and_outcome_are_typed_errors() {
        let mut fb = FrameBuf::new();
        fb.extend(&1u32.to_le_bytes());
        fb.extend(&[99u8]);
        assert_eq!(fb.next_frame().unwrap_err(), ProtoError::BadKind { kind: 99 });

        fb.clear();
        fb.extend(&2u32.to_le_bytes());
        fb.extend(&[KIND_ERROR, 0]);
        assert_eq!(fb.next_frame().unwrap_err(), ProtoError::SizeMismatch { kind: KIND_ERROR, len: 2 });

        // A response with an unknown outcome tag.
        let mut wire = Vec::new();
        encode_response(
            &mut wire,
            &ResponseFrame {
                id: 0,
                send_us: 0,
                outcome: CompletionOutcome::Ok,
                stage: 0,
                pred: 0,
                margin: 0.0,
            },
        );
        wire[4 + 17] = 200; // outcome byte
        fb.clear();
        fb.extend(&wire);
        assert_eq!(fb.next_frame().unwrap_err(), ProtoError::BadOutcome { tag: 200 });
    }

    #[test]
    fn feature_count_is_bounded_and_checked() {
        // Claimed n_features beyond the cap.
        let mut wire = Vec::new();
        let len = 21u32;
        wire.extend_from_slice(&len.to_le_bytes());
        wire.push(KIND_REQUEST);
        wire.extend_from_slice(&[0u8; 16]); // id + send_us
        wire.extend_from_slice(&(MAX_FEATURES + 1).to_le_bytes());
        let mut fb = FrameBuf::new();
        fb.extend(&wire);
        assert_eq!(fb.next_frame().unwrap_err(), ProtoError::TooManyFeatures { n: MAX_FEATURES + 1 });

        // Claimed n_features inconsistent with the payload length.
        let mut wire = Vec::new();
        encode_request(&mut wire, 0, 0, &[1.0, 2.0]);
        // Rewrite n_features to 3 without adding bytes.
        wire[4 + 17..4 + 21].copy_from_slice(&3u32.to_le_bytes());
        let mut fb = FrameBuf::new();
        fb.extend(&wire);
        assert_eq!(
            fb.next_frame().unwrap_err(),
            ProtoError::SizeMismatch { kind: KIND_REQUEST, len: 21 + 8 }
        );
    }

    #[test]
    fn compact_reclaims_consumed_bytes() {
        let mut fb = FrameBuf::new();
        let mut wire = Vec::new();
        encode_error(&mut wire, 1, 0);
        for _ in 0..100 {
            fb.extend(&wire);
            assert!(matches!(fb.next_frame(), Ok(Some(Frame::Error(_)))));
            fb.compact();
            assert_eq!(fb.pending(), 0);
        }
        // The buffer never grew past one frame.
        assert!(fb.buf.capacity() <= 4 * wire.len(), "compact must bound the buffer");
    }

    #[test]
    fn stats_round_trips() {
        let reply = StatsReply {
            admitted: 10,
            shed: 2,
            responses_sent: 11,
            completed: 10,
            degraded: 1,
            rejected: 0,
            failed: 3,
            level: 2,
            drifted: true,
            recals: 4,
            stages: vec![
                StageStat { served: 7, threshold: 0.25 },
                StageStat { served: 3, threshold: f64::NEG_INFINITY },
            ],
        };
        let mut wire = Vec::new();
        encode_stats_request(&mut wire);
        encode_stats(&mut wire, &reply);
        let mut fb = FrameBuf::new();
        fb.extend(&wire);
        assert!(matches!(fb.next_frame().unwrap().unwrap(), Frame::StatsRequest));
        let Frame::Stats(s) = fb.next_frame().unwrap().unwrap() else {
            panic!("expected a stats frame");
        };
        assert_eq!(s.n_stages(), 2);
        assert_eq!(s.to_reply(), reply);
        assert!(matches!(fb.next_frame(), Ok(None)));
    }

    #[test]
    fn stats_size_violations_are_typed_errors() {
        // A stats request carrying payload bytes.
        let mut fb = FrameBuf::new();
        fb.extend(&2u32.to_le_bytes());
        fb.extend(&[KIND_STATS_REQ, 0]);
        assert_eq!(fb.next_frame().unwrap_err(), ProtoError::SizeMismatch { kind: KIND_STATS_REQ, len: 2 });

        // A stats frame whose n_stages byte contradicts its length.
        let mut wire = Vec::new();
        let one_stage = StatsReply { stages: vec![StageStat { served: 0, threshold: 0.0 }], ..Default::default() };
        encode_stats(&mut wire, &one_stage);
        wire[4 + STATS_HEADER as usize - 1] = 2;
        fb.clear();
        fb.extend(&wire);
        assert!(matches!(fb.next_frame().unwrap_err(), ProtoError::SizeMismatch { kind: KIND_STATS, .. }));
    }

    #[test]
    fn outcome_tags_round_trip() {
        for o in [
            CompletionOutcome::Ok,
            CompletionOutcome::Degraded,
            CompletionOutcome::Rejected,
            CompletionOutcome::Failed,
        ] {
            assert_eq!(tag_outcome(outcome_tag(o)).unwrap(), o);
        }
        assert_eq!(tag_outcome(4).unwrap_err(), ProtoError::BadOutcome { tag: 4 });
    }
}
