//! The ARI wire protocol: length-prefixed binary frames.
//!
//! Every frame is a little-endian `u32` payload length followed by the
//! payload, whose first byte is the frame kind:
//!
//! ```text
//! request  (kind 1): id u64 | send_us u64 | n_features u32 | n × f32
//! response (kind 2): id u64 | send_us u64 | outcome u8 | stage u8 | pred i32 | margin f32
//! error    (kind 3): code u8 | detail u32
//! ```
//!
//! The decoder ([`FrameBuf::next_frame`]) is **total over arbitrary
//! bytes**: every input either yields a frame, asks for more bytes, or
//! returns a typed [`ProtoError`] — it never panics and never
//! allocates.  Malformed input is unrecoverable by design (a corrupted
//! length prefix desynchronises the stream), so the contract is "typed
//! error, then a clean connection close", mirrored on the peer by an
//! error frame when the socket still works.  See `docs/PROTOCOL.md`
//! for the full grammar and error taxonomy.

use crate::server::CompletionOutcome;

/// Frame kind tag: client → server inference request.
pub const KIND_REQUEST: u8 = 1;
/// Frame kind tag: server → client completion response.
pub const KIND_RESPONSE: u8 = 2;
/// Frame kind tag: a typed protocol error, sent before closing.
pub const KIND_ERROR: u8 = 3;

/// Most features a request frame may carry; bounds the decode buffer a
/// malicious length prefix can demand.
pub const MAX_FEATURES: u32 = 4096;
/// Largest legal payload: a request frame carrying [`MAX_FEATURES`]
/// features (fixed header 21 bytes + 4 bytes per feature).
pub const MAX_FRAME_LEN: u32 = REQ_HEADER + 4 * MAX_FEATURES;

/// Request payload bytes before the feature data: kind + id + send_us
/// + n_features.
const REQ_HEADER: u32 = 1 + 8 + 8 + 4;
/// Response payload length: kind + id + send_us + outcome + stage +
/// pred + margin.
const RESP_LEN: u32 = 1 + 8 + 8 + 1 + 1 + 4 + 4;
/// Error payload length: kind + code + detail.
const ERR_LEN: u32 = 1 + 1 + 4;

/// Why a byte stream failed to decode.  One variant per way the wire
/// can lie; [`ProtoError::code`] gives the tag shipped in an error
/// frame so the peer learns *why* it is being closed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ProtoError {
    /// The length prefix is zero or exceeds [`MAX_FRAME_LEN`].
    BadLength {
        /// The offending length prefix.
        len: u32,
    },
    /// The payload's first byte is not a known frame kind.
    BadKind {
        /// The offending kind byte.
        kind: u8,
    },
    /// The payload length contradicts its kind's wire size.
    SizeMismatch {
        /// The frame kind whose size was violated.
        kind: u8,
        /// The offending payload length.
        len: u32,
    },
    /// A response frame carries an unknown outcome tag.
    BadOutcome {
        /// The offending outcome tag.
        tag: u8,
    },
    /// A request frame claims more than [`MAX_FEATURES`] features.
    TooManyFeatures {
        /// The claimed feature count.
        n: u32,
    },
    /// The stream ended mid-frame (connection closed with a partial
    /// frame buffered).
    Truncated,
    /// The peer stopped mid-frame past the read deadline (slow-loris).
    Stalled,
}

impl ProtoError {
    /// Wire tag for an error frame's `code` field.
    pub fn code(&self) -> u8 {
        match self {
            ProtoError::BadLength { .. } => 1,
            ProtoError::BadKind { .. } => 2,
            ProtoError::SizeMismatch { .. } => 3,
            ProtoError::BadOutcome { .. } => 4,
            ProtoError::TooManyFeatures { .. } => 5,
            ProtoError::Truncated => 6,
            ProtoError::Stalled => 7,
        }
    }

    /// The detail value shipped alongside [`Self::code`] in an error
    /// frame (the offending length/kind/tag/count; 0 where the variant
    /// carries none).
    pub fn detail(&self) -> u32 {
        match *self {
            ProtoError::BadLength { len } => len,
            ProtoError::BadKind { kind } => kind as u32,
            ProtoError::SizeMismatch { len, .. } => len,
            ProtoError::BadOutcome { tag } => tag as u32,
            ProtoError::TooManyFeatures { n } => n,
            ProtoError::Truncated | ProtoError::Stalled => 0,
        }
    }
}

impl std::fmt::Display for ProtoError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProtoError::BadLength { len } => write!(f, "bad frame length {len}"),
            ProtoError::BadKind { kind } => write!(f, "unknown frame kind {kind}"),
            ProtoError::SizeMismatch { kind, len } => write!(f, "payload length {len} wrong for kind {kind}"),
            ProtoError::BadOutcome { tag } => write!(f, "unknown outcome tag {tag}"),
            ProtoError::TooManyFeatures { n } => write!(f, "request claims {n} features (max {MAX_FEATURES})"),
            ProtoError::Truncated => write!(f, "stream truncated mid-frame"),
            ProtoError::Stalled => write!(f, "peer stalled mid-frame past the read deadline"),
        }
    }
}

impl std::error::Error for ProtoError {}

/// Map a completion outcome to its wire tag.
pub fn outcome_tag(o: CompletionOutcome) -> u8 {
    match o {
        CompletionOutcome::Ok => 0,
        CompletionOutcome::Degraded => 1,
        CompletionOutcome::Rejected => 2,
        CompletionOutcome::Failed => 3,
    }
}

/// Map a wire tag back to its completion outcome.
pub fn tag_outcome(tag: u8) -> Result<CompletionOutcome, ProtoError> {
    match tag {
        0 => Ok(CompletionOutcome::Ok),
        1 => Ok(CompletionOutcome::Degraded),
        2 => Ok(CompletionOutcome::Rejected),
        3 => Ok(CompletionOutcome::Failed),
        tag => Err(ProtoError::BadOutcome { tag }),
    }
}

/// A decoded inference request, borrowing its feature bytes from the
/// decode buffer (no copy until the server stages the row).
#[derive(Clone, Copy, Debug)]
pub struct RequestFrame<'a> {
    /// Client-chosen request id, echoed in the response.
    pub id: u64,
    /// Client send timestamp (µs since its session start), echoed in
    /// the response so the client can measure wire latency.
    pub send_us: u64,
    /// Raw little-endian feature bytes (`4 * n_features` of them).
    feat: &'a [u8],
}

impl RequestFrame<'_> {
    /// Features carried by this request.
    pub fn n_features(&self) -> usize {
        self.feat.len() / 4
    }

    /// Iterate the feature row without copying.
    pub fn features(&self) -> impl Iterator<Item = f32> + '_ {
        self.feat.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
    }
}

/// A decoded completion response.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ResponseFrame {
    /// The request id this answers.
    pub id: u64,
    /// The request's `send_us`, echoed verbatim.
    pub send_us: u64,
    /// How the completion was produced.
    pub outcome: CompletionOutcome,
    /// Ladder stage that served the prediction.
    pub stage: u8,
    /// Predicted class (`-1` when rejected or failed).
    pub pred: i32,
    /// Serving-stage margin (top-1 minus top-2 confidence).
    pub margin: f32,
}

/// A decoded error frame: the peer's parting diagnosis before close.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ErrorFrame {
    /// [`ProtoError::code`] of the error the peer hit.
    pub code: u8,
    /// [`ProtoError::detail`] of the error the peer hit.
    pub detail: u32,
}

/// One decoded frame, borrowing from the decode buffer.
#[derive(Clone, Copy, Debug)]
pub enum Frame<'a> {
    /// An inference request.
    Request(RequestFrame<'a>),
    /// A completion response.
    Response(ResponseFrame),
    /// A protocol-error notification.
    Error(ErrorFrame),
}

/// Incremental, allocation-reusing frame decoder.  Feed it bytes as
/// they arrive ([`FrameBuf::extend`]); pull complete frames with
/// [`FrameBuf::next_frame`]; call [`FrameBuf::compact`] after draining
/// so consumed bytes are reclaimed instead of growing the buffer.
#[derive(Default)]
pub struct FrameBuf {
    buf: Vec<u8>,
    /// Consumed prefix: bytes before this offset belong to frames
    /// already returned.
    start: usize,
}

impl FrameBuf {
    /// Empty decoder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Append freshly read bytes.
    pub fn extend(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Mutable view of the unconsumed bytes (the fault layer flips a
    /// bit here to simulate wire corruption).
    pub fn pending_mut(&mut self) -> &mut [u8] {
        &mut self.buf[self.start..]
    }

    /// Unconsumed bytes buffered.
    pub fn pending(&self) -> usize {
        self.buf.len() - self.start
    }

    /// Whether a partial frame is buffered — the slow-loris signal: a
    /// peer that leaves this true past the read deadline is stalled.
    pub fn has_partial(&self) -> bool {
        self.pending() > 0
    }

    /// Drop everything (connection reset).
    pub fn clear(&mut self) {
        self.buf.clear();
        self.start = 0;
    }

    /// Reclaim consumed bytes: slide the unconsumed tail to the front.
    /// Amortised O(pending); call once per read cycle, after the
    /// decode loop returns `Ok(None)`.
    pub fn compact(&mut self) {
        if self.start == 0 {
            return;
        }
        let n = self.buf.len() - self.start;
        self.buf.copy_within(self.start.., 0);
        self.buf.truncate(n);
        self.start = 0;
    }

    /// Decode the next complete frame, if one is buffered.
    ///
    /// Total over arbitrary input: `Ok(Some(frame))` consumes one
    /// frame, `Ok(None)` means "need more bytes", `Err` is a typed
    /// protocol error after which the stream is unrecoverable (the
    /// caller closes the connection).  Never panics, never allocates.
    pub fn next_frame(&mut self) -> Result<Option<Frame<'_>>, ProtoError> {
        let start = self.start;
        let avail = self.buf.len() - start;
        if avail < 4 {
            return Ok(None);
        }
        let len = u32::from_le_bytes([
            self.buf[start],
            self.buf[start + 1],
            self.buf[start + 2],
            self.buf[start + 3],
        ]);
        if len == 0 || len > MAX_FRAME_LEN {
            return Err(ProtoError::BadLength { len });
        }
        if avail < 4 + len as usize {
            return Ok(None);
        }
        // Consume before borrowing the payload for the return value.
        self.start = start + 4 + len as usize;
        let payload = &self.buf[start + 4..start + 4 + len as usize];
        parse_payload(payload, len)
    }
}

/// Parse one complete payload.  `payload.len() == len` and `len >= 1`
/// are guaranteed by the caller.
fn parse_payload(payload: &[u8], len: u32) -> Result<Option<Frame<'_>>, ProtoError> {
    match payload[0] {
        KIND_REQUEST => {
            if len < REQ_HEADER {
                return Err(ProtoError::SizeMismatch { kind: KIND_REQUEST, len });
            }
            let n = u32::from_le_bytes([payload[17], payload[18], payload[19], payload[20]]);
            if n > MAX_FEATURES {
                return Err(ProtoError::TooManyFeatures { n });
            }
            if len != REQ_HEADER + 4 * n {
                return Err(ProtoError::SizeMismatch { kind: KIND_REQUEST, len });
            }
            Ok(Some(Frame::Request(RequestFrame {
                id: u64_at(payload, 1),
                send_us: u64_at(payload, 9),
                feat: &payload[REQ_HEADER as usize..],
            })))
        }
        KIND_RESPONSE => {
            if len != RESP_LEN {
                return Err(ProtoError::SizeMismatch { kind: KIND_RESPONSE, len });
            }
            Ok(Some(Frame::Response(ResponseFrame {
                id: u64_at(payload, 1),
                send_us: u64_at(payload, 9),
                outcome: tag_outcome(payload[17])?,
                stage: payload[18],
                pred: i32::from_le_bytes([payload[19], payload[20], payload[21], payload[22]]),
                margin: f32::from_le_bytes([payload[23], payload[24], payload[25], payload[26]]),
            })))
        }
        KIND_ERROR => {
            if len != ERR_LEN {
                return Err(ProtoError::SizeMismatch { kind: KIND_ERROR, len });
            }
            Ok(Some(Frame::Error(ErrorFrame {
                code: payload[1],
                detail: u32::from_le_bytes([payload[2], payload[3], payload[4], payload[5]]),
            })))
        }
        kind => Err(ProtoError::BadKind { kind }),
    }
}

/// Read a little-endian `u64` at `off` (bounds checked by the caller's
/// size verification).
fn u64_at(b: &[u8], off: usize) -> u64 {
    u64::from_le_bytes([
        b[off],
        b[off + 1],
        b[off + 2],
        b[off + 3],
        b[off + 4],
        b[off + 5],
        b[off + 6],
        b[off + 7],
    ])
}

/// Append one encoded request frame to `out` (a reusable write
/// buffer — never cleared here).
pub fn encode_request(out: &mut Vec<u8>, id: u64, send_us: u64, row: &[f32]) {
    assert!(row.len() <= MAX_FEATURES as usize, "request row exceeds MAX_FEATURES");
    let len = REQ_HEADER + 4 * row.len() as u32;
    out.extend_from_slice(&len.to_le_bytes());
    out.push(KIND_REQUEST);
    out.extend_from_slice(&id.to_le_bytes());
    out.extend_from_slice(&send_us.to_le_bytes());
    out.extend_from_slice(&(row.len() as u32).to_le_bytes());
    for v in row {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

/// Append one encoded response frame to `out`.
pub fn encode_response(out: &mut Vec<u8>, r: &ResponseFrame) {
    out.extend_from_slice(&RESP_LEN.to_le_bytes());
    out.push(KIND_RESPONSE);
    out.extend_from_slice(&r.id.to_le_bytes());
    out.extend_from_slice(&r.send_us.to_le_bytes());
    out.push(outcome_tag(r.outcome));
    out.push(r.stage);
    out.extend_from_slice(&r.pred.to_le_bytes());
    out.extend_from_slice(&r.margin.to_le_bytes());
}

/// Append one encoded error frame to `out`.
pub fn encode_error(out: &mut Vec<u8>, code: u8, detail: u32) {
    out.extend_from_slice(&ERR_LEN.to_le_bytes());
    out.push(KIND_ERROR);
    out.push(code);
    out.extend_from_slice(&detail.to_le_bytes());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_round_trips() {
        let mut wire = Vec::new();
        let row = [0.5f32, -1.25, 3.0];
        encode_request(&mut wire, 42, 7_000, &row);
        let mut fb = FrameBuf::new();
        fb.extend(&wire);
        let Frame::Request(r) = fb.next_frame().unwrap().unwrap() else {
            panic!("expected a request frame");
        };
        assert_eq!(r.id, 42);
        assert_eq!(r.send_us, 7_000);
        assert_eq!(r.n_features(), 3);
        let got: Vec<f32> = r.features().collect();
        assert_eq!(got, row);
        assert!(matches!(fb.next_frame(), Ok(None)));
    }

    #[test]
    fn response_and_error_round_trip() {
        let resp = ResponseFrame {
            id: 9,
            send_us: 123,
            outcome: CompletionOutcome::Degraded,
            stage: 2,
            pred: -1,
            margin: 0.75,
        };
        let mut wire = Vec::new();
        encode_response(&mut wire, &resp);
        encode_error(&mut wire, ProtoError::Truncated.code(), 0);
        let mut fb = FrameBuf::new();
        fb.extend(&wire);
        let Frame::Response(got) = fb.next_frame().unwrap().unwrap() else {
            panic!("expected a response frame");
        };
        assert_eq!(got, resp);
        let Frame::Error(e) = fb.next_frame().unwrap().unwrap() else {
            panic!("expected an error frame");
        };
        assert_eq!(e.code, ProtoError::Truncated.code());
        assert_eq!(e.detail, 0);
        assert!(matches!(fb.next_frame(), Ok(None)));
    }

    #[test]
    fn partial_frames_wait_for_more_bytes() {
        let mut wire = Vec::new();
        encode_request(&mut wire, 1, 0, &[1.0, 2.0]);
        let mut fb = FrameBuf::new();
        for (i, b) in wire.iter().enumerate() {
            assert!(
                matches!(fb.next_frame(), Ok(None)),
                "no frame before byte {i} of {} arrived",
                wire.len()
            );
            fb.extend(std::slice::from_ref(b));
        }
        assert!(matches!(fb.next_frame(), Ok(Some(Frame::Request(_)))));
        assert!(!fb.has_partial());
    }

    #[test]
    fn zero_and_oversized_lengths_are_typed_errors() {
        let mut fb = FrameBuf::new();
        fb.extend(&0u32.to_le_bytes());
        assert_eq!(fb.next_frame().unwrap_err(), ProtoError::BadLength { len: 0 });
        fb.clear();
        fb.extend(&(MAX_FRAME_LEN + 1).to_le_bytes());
        assert_eq!(fb.next_frame().unwrap_err(), ProtoError::BadLength { len: MAX_FRAME_LEN + 1 });
    }

    #[test]
    fn bad_kind_size_and_outcome_are_typed_errors() {
        let mut fb = FrameBuf::new();
        fb.extend(&1u32.to_le_bytes());
        fb.extend(&[99u8]);
        assert_eq!(fb.next_frame().unwrap_err(), ProtoError::BadKind { kind: 99 });

        fb.clear();
        fb.extend(&2u32.to_le_bytes());
        fb.extend(&[KIND_ERROR, 0]);
        assert_eq!(fb.next_frame().unwrap_err(), ProtoError::SizeMismatch { kind: KIND_ERROR, len: 2 });

        // A response with an unknown outcome tag.
        let mut wire = Vec::new();
        encode_response(
            &mut wire,
            &ResponseFrame {
                id: 0,
                send_us: 0,
                outcome: CompletionOutcome::Ok,
                stage: 0,
                pred: 0,
                margin: 0.0,
            },
        );
        wire[4 + 17] = 200; // outcome byte
        fb.clear();
        fb.extend(&wire);
        assert_eq!(fb.next_frame().unwrap_err(), ProtoError::BadOutcome { tag: 200 });
    }

    #[test]
    fn feature_count_is_bounded_and_checked() {
        // Claimed n_features beyond the cap.
        let mut wire = Vec::new();
        let len = 21u32;
        wire.extend_from_slice(&len.to_le_bytes());
        wire.push(KIND_REQUEST);
        wire.extend_from_slice(&[0u8; 16]); // id + send_us
        wire.extend_from_slice(&(MAX_FEATURES + 1).to_le_bytes());
        let mut fb = FrameBuf::new();
        fb.extend(&wire);
        assert_eq!(fb.next_frame().unwrap_err(), ProtoError::TooManyFeatures { n: MAX_FEATURES + 1 });

        // Claimed n_features inconsistent with the payload length.
        let mut wire = Vec::new();
        encode_request(&mut wire, 0, 0, &[1.0, 2.0]);
        // Rewrite n_features to 3 without adding bytes.
        wire[4 + 17..4 + 21].copy_from_slice(&3u32.to_le_bytes());
        let mut fb = FrameBuf::new();
        fb.extend(&wire);
        assert_eq!(
            fb.next_frame().unwrap_err(),
            ProtoError::SizeMismatch { kind: KIND_REQUEST, len: 21 + 8 }
        );
    }

    #[test]
    fn compact_reclaims_consumed_bytes() {
        let mut fb = FrameBuf::new();
        let mut wire = Vec::new();
        encode_error(&mut wire, 1, 0);
        for _ in 0..100 {
            fb.extend(&wire);
            assert!(matches!(fb.next_frame(), Ok(Some(Frame::Error(_)))));
            fb.compact();
            assert_eq!(fb.pending(), 0);
        }
        // The buffer never grew past one frame.
        assert!(fb.buf.capacity() <= 4 * wire.len(), "compact must bound the buffer");
    }

    #[test]
    fn outcome_tags_round_trip() {
        for o in [
            CompletionOutcome::Ok,
            CompletionOutcome::Degraded,
            CompletionOutcome::Rejected,
            CompletionOutcome::Failed,
        ] {
            assert_eq!(tag_outcome(outcome_tag(o)).unwrap(), o);
        }
        assert_eq!(tag_outcome(4).unwrap_err(), ProtoError::BadOutcome { tag: 4 });
    }
}
