//! Load-generator client for the TCP serving tier.
//!
//! Drives a [`run_net_serving`](super::run_net_serving) server over the
//! `docs/PROTOCOL.md` wire format in one of three load shapes:
//!
//! - **open loop** — requests fire on a Poisson schedule regardless of
//!   responses (the honest tail-latency measurement);
//! - **partial open loop** — the Poisson schedule, but capped at a
//!   maximum number of outstanding requests (open-loop pressure without
//!   unbounded client-side queueing);
//! - **closed loop** — a fixed concurrency window; each response admits
//!   the next request.
//!
//! The request schedule is drawn with the *same* RNG stream and draw
//! order as the in-process workload generator
//! (`Pcg64::new(seed, 99)`: optional exponential gap, then row index),
//! so a TCP session against a fixed-seed server is row-for-row
//! comparable with an in-process [`super::super::run_serving_ladder`]
//! session — the loopback parity suite relies on this.
//!
//! Connections are supervised from this side too: a failed connect or a
//! mid-session disconnect retries with exponential backoff (which also
//! absorbs the server's startup race in the smoke targets), and
//! requests outstanding on a dead connection are counted `lost`, never
//! silently forgotten: `sent == received + lost` holds on every exit
//! path.  Wire latency is measured from the client's own `send_us`
//! stamp echoed back by the server, so it includes both wire directions
//! and the full server residency.

use std::io::{self, Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use crate::data::EvalData;
use crate::metrics::LatencyHist;
use crate::server::net::proto::{self, Frame, FrameBuf, ResponseFrame};
use crate::util::Pcg64;

/// Real-clock read for the client loop.  The client is the outside
/// world: its stamps define the wire-latency measurement and are never
/// part of the (sim-checked) serving protocol.
fn client_now() -> Instant {
    // ari-lint: allow(clock-discipline): the load generator models the outside
    // world — its send stamps ARE the latency ground truth.
    Instant::now()
}

/// How the client paces its requests.
#[derive(Clone, Copy, Debug)]
pub enum LoadMode {
    /// Open loop: the Poisson schedule fires regardless of responses.
    Open,
    /// Open-loop schedule, but never more than this many outstanding.
    PartialOpen {
        /// Outstanding-request cap.
        max_outstanding: usize,
    },
    /// Closed loop: a fixed concurrency window.
    Closed {
        /// Concurrency window (requests in flight).
        concurrency: usize,
    },
}

/// Client configuration.
#[derive(Clone, Debug)]
pub struct ClientConfig {
    /// Server address, e.g. `127.0.0.1:7070`.
    pub addr: String,
    /// Load shape.
    pub mode: LoadMode,
    /// Poisson arrival rate (req/s) for the open-loop schedules;
    /// `0` sends back-to-back (matching the in-process closed loop).
    pub rate: f64,
    /// Requests to send.
    pub requests: usize,
    /// Workload seed — must match the server session's seed for
    /// row-for-row parity with an in-process run.
    pub seed: u64,
    /// Declare outstanding requests lost after this long without a
    /// single byte from the server.
    pub timeout: Duration,
    /// Connect / reconnect attempts before giving up.
    pub max_reconnects: u32,
    /// Base reconnect backoff (doubles per consecutive failure, capped
    /// at 250 ms — below the server's linger, so a reconnect lands
    /// before the server decides the client is gone).
    pub backoff: Duration,
}

impl Default for ClientConfig {
    fn default() -> Self {
        Self {
            addr: String::from("127.0.0.1:7070"),
            mode: LoadMode::Closed { concurrency: 8 },
            rate: 0.0,
            requests: 256,
            seed: 42,
            timeout: Duration::from_secs(5),
            max_reconnects: 8,
            backoff: Duration::from_millis(10),
        }
    }
}

/// What one client session observed.
#[derive(Debug)]
pub struct ClientReport {
    /// Requests actually written to a socket.
    pub sent: u64,
    /// Responses received.
    pub received: u64,
    /// Sent requests whose response never arrived (connection died or
    /// timed out).  `sent == received + lost` always.
    pub lost: u64,
    /// Typed error frames and decode failures observed.
    pub wire_errors: u64,
    /// Successful reconnects after a drop (the initial connect is not
    /// counted).
    pub reconnects: u64,
    /// Received responses by outcome tag (Ok, Degraded, Rejected,
    /// Failed).
    pub outcomes: [u64; 4],
    /// Median round-trip latency (send stamp → response in hand).
    pub p50: Duration,
    /// 95th-percentile round-trip latency.
    pub p95: Duration,
    /// 99th-percentile round-trip latency.
    pub p99: Duration,
    /// Mean round-trip latency.
    pub mean_latency: Duration,
    /// Wall time of the whole client session.
    pub wall: Duration,
    /// Every response frame, arrival order (the parity suite matches
    /// these against in-process completions by request id).
    pub responses: Vec<ResponseFrame>,
}

impl ClientReport {
    /// Human-readable summary block.
    pub fn summary(&self) -> String {
        format!(
            "client: sent {} -> received {} (lost {}, wire errors {}, reconnects {})\n\
             outcomes: ok {} degraded {} rejected {} failed {}\n\
             wire latency mean {:?} p50 {:?} p95 {:?} p99 {:?}  wall {:.2?}",
            self.sent,
            self.received,
            self.lost,
            self.wire_errors,
            self.reconnects,
            self.outcomes[0],
            self.outcomes[1],
            self.outcomes[2],
            self.outcomes[3],
            self.mean_latency,
            self.p50,
            self.p95,
            self.p99,
            self.wall,
        )
    }
}

/// One live client connection: the socket plus its reusable frame
/// buffers.
struct ClientConn {
    stream: TcpStream,
    rbuf: FrameBuf,
    wbuf: Vec<u8>,
    wsent: usize,
}

impl ClientConn {
    fn connect(addr: &str) -> io::Result<Self> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nonblocking(true)?;
        stream.set_nodelay(true).ok();
        Ok(Self { stream, rbuf: FrameBuf::new(), wbuf: Vec::new(), wsent: 0 })
    }

    /// Flush pending output; `Err` means the connection is dead.
    fn flush(&mut self) -> Result<(), ()> {
        while self.wsent < self.wbuf.len() {
            match self.stream.write(&self.wbuf[self.wsent..]) {
                Ok(0) => return Err(()),
                Ok(n) => self.wsent += n,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => return Err(()),
            }
        }
        if self.wsent == self.wbuf.len() {
            self.wbuf.clear();
            self.wsent = 0;
        }
        Ok(())
    }
}

/// Run one client session against a serving-tier address.
///
/// Rows are drawn from `data` with the in-process generator's RNG
/// stream (see the module docs).  Returns the session report; client
/// conservation (`sent == received + lost`) is `ensure!`d before
/// returning.  A session that exhausts its reconnect budget returns a
/// *partial* report (the caller sees `lost > 0` and `sent <
/// requests`), not an error — under chaos injection a bounded-loss
/// session is the expected outcome, and the caller decides what loss
/// budget is acceptable.
pub fn run_client(cfg: &ClientConfig, data: &EvalData) -> crate::Result<ClientReport> {
    // Pre-draw the schedule with the generator's exact draw order:
    // (optional exponential gap, then row index) per request.
    let mut rng = Pcg64::new(cfg.seed, 99);
    let mut sched: Vec<(Duration, usize)> = Vec::with_capacity(cfg.requests);
    let mut at = Duration::ZERO;
    for _ in 0..cfg.requests {
        if cfg.rate > 0.0 {
            at += Duration::from_secs_f64(rng.exponential(cfg.rate));
        }
        sched.push((at, rng.below(data.n as u64) as usize));
    }

    let epoch = client_now();
    let hist = LatencyHist::default();
    let mut responses: Vec<ResponseFrame> = Vec::with_capacity(cfg.requests);
    let mut outcomes = [0u64; 4];
    let (mut sent, mut received, mut lost, mut wire_errors, mut reconnects) = (0u64, 0u64, 0u64, 0u64, 0u64);
    let mut conn: Option<ClientConn> = None;
    let mut attempts = 0u32;
    let mut next_idx = 0usize;
    let mut outstanding = 0u64;
    let mut last_activity = epoch;
    let mut chunk = [0u8; 4096];

    loop {
        let now = client_now();
        if next_idx == cfg.requests && outstanding == 0 {
            break;
        }

        if conn.is_none() {
            if attempts > cfg.max_reconnects {
                // Reconnect budget exhausted: whatever is unanswered is
                // lost; unsent requests stay unsent (sent < requests).
                lost += outstanding;
                outstanding = 0;
                break;
            }
            if attempts > 0 {
                let backoff = (cfg.backoff * 2u32.saturating_pow(attempts - 1)).min(Duration::from_millis(250));
                std::thread::sleep(backoff);
            }
            match ClientConn::connect(&cfg.addr) {
                Ok(c) => {
                    if attempts > 0 && sent > 0 {
                        reconnects += 1;
                    }
                    conn = Some(c);
                    attempts = 0;
                    last_activity = client_now();
                }
                Err(_) => {
                    attempts += 1;
                }
            }
            continue;
        }

        let mut progress = false;
        let mut dead = false;
        if let Some(c) = conn.as_mut() {
            // Send every request the load shape says is due.
            while next_idx < cfg.requests {
                let (due_at, row) = sched[next_idx];
                let due = match cfg.mode {
                    LoadMode::Open => now.duration_since(epoch) >= due_at,
                    LoadMode::PartialOpen { max_outstanding } => {
                        now.duration_since(epoch) >= due_at && (outstanding as usize) < max_outstanding
                    }
                    LoadMode::Closed { concurrency } => (outstanding as usize) < concurrency,
                };
                if !due {
                    break;
                }
                let send_us = now.duration_since(epoch).as_micros() as u64;
                proto::encode_request(&mut c.wbuf, next_idx as u64, send_us, data.row(row));
                next_idx += 1;
                sent += 1;
                outstanding += 1;
                progress = true;
            }
            if c.flush().is_err() {
                dead = true;
            }

            // Read and decode whatever the server has for us.
            if !dead {
                match c.stream.read(&mut chunk) {
                    Ok(0) => dead = true,
                    Ok(n) => {
                        progress = true;
                        last_activity = now;
                        c.rbuf.extend(&chunk[..n]);
                        loop {
                            match c.rbuf.next_frame() {
                                Ok(Some(Frame::Response(r))) => {
                                    received += 1;
                                    outstanding = outstanding.saturating_sub(1);
                                    outcomes[proto::outcome_tag(r.outcome) as usize] += 1;
                                    let now_us = client_now().duration_since(epoch).as_micros() as u64;
                                    hist.record(Duration::from_micros(now_us.saturating_sub(r.send_us)));
                                    responses.push(r);
                                }
                                Ok(Some(Frame::Error(_))) => {
                                    // Typed rejection: the server told us
                                    // why and will close; our in-flight
                                    // requests on this conn are gone.
                                    wire_errors += 1;
                                    dead = true;
                                    break;
                                }
                                Ok(Some(Frame::Request(_)))
                                | Ok(Some(Frame::StatsRequest))
                                | Ok(Some(Frame::Stats(_))) => {
                                    // Servers never send requests or
                                    // stats traffic we didn't ask for.
                                    wire_errors += 1;
                                    dead = true;
                                    break;
                                }
                                Ok(None) => break,
                                Err(_) => {
                                    // Garbled stream (e.g. frame-corrupt /
                                    // frame-trunc injection upstream).
                                    wire_errors += 1;
                                    dead = true;
                                    break;
                                }
                            }
                        }
                        c.rbuf.compact();
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {}
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                    Err(_) => dead = true,
                }
            }
        }

        if dead {
            lost += outstanding;
            outstanding = 0;
            conn = None;
            attempts += 1;
            continue;
        }

        if outstanding > 0 && now.duration_since(last_activity) >= cfg.timeout {
            // The server went quiet on us: count the stragglers lost
            // and (if there is more to send) start a fresh connection.
            lost += outstanding;
            outstanding = 0;
            if next_idx == cfg.requests {
                break;
            }
            conn = None;
            attempts += 1;
            continue;
        }

        if !progress {
            std::thread::sleep(Duration::from_micros(200));
        }
    }

    anyhow::ensure!(
        received + lost == sent,
        "client conservation broken: {} received + {} lost != {} sent",
        received,
        lost,
        sent
    );
    Ok(ClientReport {
        sent,
        received,
        lost,
        wire_errors,
        reconnects,
        outcomes,
        p50: hist.quantile(0.5),
        p95: hist.quantile(0.95),
        p99: hist.quantile(0.99),
        mean_latency: hist.mean(),
        wall: epoch.elapsed(),
        responses,
    })
}

/// Fetch one stats snapshot from a serving-tier address: connect, send
/// a single stats request, and wait (bounded by `timeout`) for the
/// stats frame.  Used by `ari-client --stats`; a stats connection is
/// ordinary wire traffic to the server — it counts as a connection but
/// never against the request budget or response conservation.
pub fn fetch_stats(addr: &str, timeout: Duration) -> crate::Result<proto::StatsReply> {
    let deadline = client_now() + timeout;
    let mut stream = TcpStream::connect(addr)?;
    stream.set_nodelay(true).ok();
    let mut wire = Vec::new();
    proto::encode_stats_request(&mut wire);
    stream.write_all(&wire)?;
    let mut fb = FrameBuf::new();
    let mut chunk = [0u8; 4096];
    loop {
        let now = client_now();
        anyhow::ensure!(now < deadline, "stats request timed out after {timeout:?}");
        stream.set_read_timeout(Some(deadline - now))?;
        match stream.read(&mut chunk) {
            Ok(0) => anyhow::bail!("server closed the connection before answering the stats request"),
            Ok(n) => {
                fb.extend(&chunk[..n]);
                loop {
                    match fb.next_frame() {
                        Ok(Some(Frame::Stats(s))) => return Ok(s.to_reply()),
                        Ok(Some(Frame::Error(e))) => {
                            anyhow::bail!("server error frame: code {} detail {}", e.code, e.detail)
                        }
                        Ok(Some(_)) => anyhow::bail!("unexpected frame while waiting for the stats reply"),
                        Ok(None) => break,
                        Err(e) => anyhow::bail!("protocol error while waiting for the stats reply: {e}"),
                    }
                }
                fb.compact();
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock || e.kind() == io::ErrorKind::TimedOut => {}
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The client's schedule must replay the in-process generator's
    /// draw order exactly: optional gap first, then the row — per
    /// request, from the same `(seed, 99)` stream.
    #[test]
    fn schedule_matches_generator_draw_order() {
        let (seed, n_rows, n_req, rate) = (7u64, 50u64, 20usize, 800.0f64);
        let mut gen_rng = Pcg64::new(seed, 99);
        let mut expect = Vec::new();
        for _ in 0..n_req {
            let _gap = gen_rng.exponential(rate);
            expect.push(gen_rng.below(n_rows) as usize);
        }
        let mut cli_rng = Pcg64::new(seed, 99);
        let mut got = Vec::new();
        for _ in 0..n_req {
            let _gap = cli_rng.exponential(rate);
            got.push(cli_rng.below(n_rows) as usize);
        }
        assert_eq!(expect, got);
    }

    /// Rate 0 must skip the exponential draw entirely (the in-process
    /// closed loop does), or every row index shifts by one draw.
    #[test]
    fn zero_rate_skips_gap_draws() {
        let mut a = Pcg64::new(3, 99);
        let mut b = Pcg64::new(3, 99);
        let rows_a: Vec<u64> = (0..10).map(|_| a.below(17)).collect();
        let rows_b: Vec<u64> = (0..10).map(|_| b.below(17)).collect();
        assert_eq!(rows_a, rows_b);
    }
}
