//! The serving loop: workload generation, request queueing, ladder
//! dispatch and reporting.
//!
//! Threading model: backends may be thread-pinned (the PJRT client is
//! `Rc`-based, not `Send` — see [`crate::runtime`]), so the coordinator
//! loop — batcher + ladder + backend — runs on the calling thread,
//! while a generator thread produces timestamped requests into an
//! `mpsc` channel (open-loop Poisson or closed-loop).  This mirrors the
//! single-accelerator IoT deployment the paper targets: one device, one
//! inference queue.  Compute still scales with cores: the native
//! backend shards each batch's rows across its scoped worker pool
//! inside `execute` (see [`crate::mlp::plan`] and `docs/PERF.md`), so
//! the serving loop stays single-queue while forwards are parallel.
//!
//! Both escalation policies route through the N-level
//! [`crate::coordinator::Ladder`]: `Immediate` walks a batch down the
//! whole ladder in place; `Deferred` keeps one escalation queue per
//! non-first stage and flushes a stage when a full batch of escalations
//! is waiting (or at shutdown).  Every dispatched batch — reduced or
//! escalation flush — draws a fresh chunk id from one shared counter,
//! so no two SC batches ever share a stochastic-computing key.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::config::AriConfig;
use crate::coordinator::{Batcher, BatcherPolicy, Cascade, EscalationPolicy, Ladder};
use crate::data::EvalData;
use crate::metrics::MetricsRegistry;
use crate::runtime::Backend;
use crate::util::Pcg64;

/// One request: a row index into the workload dataset.
#[derive(Clone, Copy, Debug)]
pub struct Request {
    /// Unique request id (generation order).
    pub id: u64,
    /// Row index into the workload dataset.
    pub row: usize,
    /// When the generator produced the request.
    pub submitted: Instant,
}

/// Completed request with its outcome.
#[derive(Clone, Debug)]
pub struct Completion {
    /// The request's id.
    pub id: u64,
    /// The request's dataset row.
    pub row: usize,
    /// Predicted class served back.
    pub pred: i32,
    /// Ladder stage that produced the prediction (0 = reduced model).
    pub stage: usize,
    /// Whether any escalation stage ran for this request.
    pub escalated: bool,
    /// Submit-to-complete latency.
    pub latency: Duration,
}

/// Aggregated serving report.
#[derive(Debug)]
pub struct ServeReport {
    /// Every served request with its outcome.
    pub completions: Vec<Completion>,
    /// Wall time of the whole serving session.
    pub wall: Duration,
    /// Completions per second of wall time.
    pub throughput_rps: f64,
    /// Accuracy of the served predictions against labels.
    pub accuracy: f64,
    /// Agreement with the always-full baseline predictions, if provided.
    pub full_parity: Option<f64>,
    /// Fraction of requests that ran at least one escalation stage.
    pub escalation_fraction: f64,
    /// Fraction of completions *finishing* at each ladder stage
    /// (completion shares — sums to 1).  Not the executed-fraction `f_i`
    /// of the energy identity `E = Σ_i f_i · E_i`; that is
    /// [`crate::coordinator::LadderBatch::stage_fractions`], where every
    /// escalated row also counts toward the stages it passed through.
    pub stage_fractions: Vec<f64>,
    /// Modelled energy actually spent (µJ).
    pub energy_uj: f64,
    /// Modelled energy an always-full policy would have spent (µJ).
    pub energy_full_uj: f64,
    /// Median request latency.
    pub p50: Duration,
    /// 99th-percentile request latency.
    pub p99: Duration,
    /// Mean request latency.
    pub mean_latency: Duration,
    /// Mean wait in the batching queue before the first-stage pass
    /// (recorded under both escalation policies).
    pub queue_wait_mean: Duration,
    /// Queue-wait samples recorded (one per dispatched request).
    pub queue_wait_samples: u64,
}

/// Serving options beyond the config.
#[derive(Clone, Copy, Debug)]
pub struct ServeOptions {
    /// When escalated rows run on the deeper stages.
    pub escalation: EscalationPolicy,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self { escalation: EscalationPolicy::Immediate }
    }
}

/// Run a serving session through a calibrated two-tier cascade.
///
/// Kept as the stable entry point for the paper's reduced/full
/// configuration; it serves from the cascade's underlying 2-level
/// ladder via [`run_serving_ladder`].
pub fn run_serving(
    engine: &mut dyn Backend,
    cascade: &Cascade,
    cfg: &AriConfig,
    data: &EvalData,
    full_pred: Option<&[i32]>,
    opts: ServeOptions,
) -> crate::Result<ServeReport> {
    run_serving_ladder(engine, &cascade.ladder, cfg, data, full_pred, opts)
}

/// Run a serving session: `cfg.requests` requests drawn (with repetition
/// if needed) from `data`, at `cfg.arrival_rate` req/s Poisson (or
/// closed-loop when 0), through a calibrated N-level ladder.
pub fn run_serving_ladder(
    engine: &mut dyn Backend,
    ladder: &Ladder,
    cfg: &AriConfig,
    data: &EvalData,
    full_pred: Option<&[i32]>,
    opts: ServeOptions,
) -> crate::Result<ServeReport> {
    // The batcher may fire (and the shutdown path drain) batches of up
    // to cfg.batch_size rows; every one must fit the ladder's compiled
    // batch or the padding accounting and run_padded's n <= batch
    // contract break.
    anyhow::ensure!(
        cfg.batch_size <= ladder.stages[0].variant.batch,
        "server batch_size {} exceeds the ladder's compiled batch {}",
        cfg.batch_size,
        ladder.stages[0].variant.batch
    );
    let (tx, rx) = mpsc::channel::<Request>();
    let n_requests = cfg.requests;
    let n_rows = data.n;
    let rate = cfg.arrival_rate;
    let seed = cfg.seed;
    // Generator thread: open-loop Poisson arrivals (or back-to-back).
    let gen = std::thread::spawn(move || {
        let mut rng = Pcg64::new(seed, 99);
        for id in 0..n_requests as u64 {
            if rate > 0.0 {
                let gap = rng.exponential(rate);
                std::thread::sleep(Duration::from_secs_f64(gap));
            }
            let row = rng.below(n_rows as u64) as usize;
            if tx.send(Request { id, row, submitted: Instant::now() }).is_err() {
                return;
            }
        }
    });

    let metrics = MetricsRegistry::new();
    let policy = BatcherPolicy::new(cfg.batch_size, Duration::from_micros(cfg.batch_timeout_us));
    let mut batcher: Batcher<Request> = Batcher::new(policy);
    let n_stages = ladder.n_stages();
    // Deferred escalations: one queue of (request, gathered row) per
    // non-first stage (index 0 is unused).
    let mut esc_queues: Vec<Vec<(Request, Vec<f32>)>> = vec![Vec::new(); n_stages];
    let mut completions: Vec<Completion> = Vec::with_capacity(n_requests);
    let mut received = 0usize;
    // Every dispatched batch — first-stage or escalation flush — draws a
    // fresh id from this counter, so SC keys are never reused.
    let mut chunk = 0u32;
    let t_start = Instant::now();

    // Helper: dispatch one first-stage batch through the ladder.
    let dispatch = |batch: crate::coordinator::Batch<Request>,
                        engine: &mut dyn Backend,
                        esc_queues: &mut Vec<Vec<(Request, Vec<f32>)>>,
                        completions: &mut Vec<Completion>,
                        chunk: &mut u32|
     -> crate::Result<()> {
        let n = batch.items.len();
        let mut x = Vec::with_capacity(n * data.input_dim);
        for p in &batch.items {
            x.extend_from_slice(data.row(p.payload.row));
        }
        *chunk += 1;
        metrics.reduced_batches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        metrics
            .padded_slots
            .fetch_add((ladder.stages[0].variant.batch - n) as u64, std::sync::atomic::Ordering::Relaxed);
        match opts.escalation {
            EscalationPolicy::Immediate => {
                let out = ladder.infer_batch(engine, &x, n, *chunk)?;
                metrics.add_energy_uj(out.energy_uj);
                // full_batches counts batches that actually reached the
                // final (full) model; intermediate stages don't qualify.
                if *out.stage_counts.last().unwrap() > 0 {
                    metrics.full_batches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                }
                let now = Instant::now();
                for (i, p) in batch.items.iter().enumerate() {
                    let lat = now.duration_since(p.payload.submitted);
                    metrics.latency.record(lat);
                    metrics.queue_wait.record(p.enqueued.duration_since(p.payload.submitted));
                    metrics.completed.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if out.stage[i] > 0 {
                        metrics.escalated.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    }
                    completions.push(Completion {
                        id: p.payload.id,
                        row: p.payload.row,
                        pred: out.pred[i],
                        stage: out.stage[i],
                        escalated: out.stage[i] > 0,
                        latency: lat,
                    });
                }
            }
            EscalationPolicy::Deferred => {
                let red = ladder.run_stage(engine, 0, &x, n, *chunk)?;
                metrics.add_energy_uj(n as f64 * ladder.stages[0].energy_uj);
                let now = Instant::now();
                for (i, p) in batch.items.iter().enumerate() {
                    // Queue wait is recorded at dispatch under *both*
                    // policies, so MetricsRegistry::report() stays
                    // comparable across them.
                    metrics.queue_wait.record(p.enqueued.duration_since(p.payload.submitted));
                    if crate::margin::accepts(red.margin[i], ladder.stages[0].threshold) {
                        let lat = now.duration_since(p.payload.submitted);
                        metrics.latency.record(lat);
                        metrics.completed.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        completions.push(Completion {
                            id: p.payload.id,
                            row: p.payload.row,
                            pred: red.pred[i],
                            stage: 0,
                            escalated: false,
                            latency: lat,
                        });
                    } else {
                        esc_queues[1].push((p.payload, data.row(p.payload.row).to_vec()));
                    }
                }
                // Flush any stage whose queue holds a full batch; a
                // flush at stage s may refill queue s+1, so walk down.
                for s in 1..n_stages {
                    while esc_queues[s].len() >= ladder.stages[s].variant.batch {
                        let take = ladder.stages[s].variant.batch;
                        flush_stage(engine, ladder, esc_queues, s, take, &metrics, completions, chunk)?;
                    }
                }
            }
        }
        Ok(())
    };

    // Main loop: recv with deadline-aware timeout, fire batches.
    loop {
        let now = Instant::now();
        let timeout = batcher.next_deadline(now).unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(req) => {
                batcher.push_at(req, req.submitted.max(now));
                received += 1;
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                // Generator finished (or died): flush in ≤ max_batch
                // chunks and exit.
                while let Some(batch) = batcher.drain() {
                    dispatch(batch, engine, &mut esc_queues, &mut completions, &mut chunk)?;
                }
                break;
            }
        }
        let now = Instant::now();
        while let Some(batch) = batcher.try_fire(now) {
            dispatch(batch, engine, &mut esc_queues, &mut completions, &mut chunk)?;
        }
        if received >= n_requests && rx.try_recv().is_err() {
            // Drain the tail.
            while let Some(batch) = batcher.drain() {
                dispatch(batch, engine, &mut esc_queues, &mut completions, &mut chunk)?;
            }
            if batcher.is_empty() {
                break;
            }
        }
    }
    // Final drain: flush leftover escalations stage by stage (a flush at
    // stage s may push into queue s+1, which is visited next).  Each
    // flush draws a fresh chunk id — the old loop passed one id to every
    // flush, making distinct full-model batches share an SC key.
    for s in 1..n_stages {
        while !esc_queues[s].is_empty() {
            let take = esc_queues[s].len().min(ladder.stages[s].variant.batch);
            flush_stage(engine, ladder, &mut esc_queues, s, take, &metrics, &mut completions, &mut chunk)?;
        }
    }
    gen.join().ok();

    let wall = t_start.elapsed();
    let mut accuracy = 0.0;
    let mut parity_ok = 0usize;
    let mut stage_fractions = vec![0.0f64; n_stages];
    for c in &completions {
        if c.pred == data.y[c.row] {
            accuracy += 1.0;
        }
        if let Some(fp) = full_pred {
            if c.pred == fp[c.row] {
                parity_ok += 1;
            }
        }
        stage_fractions[c.stage] += 1.0;
    }
    accuracy /= completions.len().max(1) as f64;
    for f in &mut stage_fractions {
        *f /= completions.len().max(1) as f64;
    }
    let energy_uj = metrics.energy_uj();
    Ok(ServeReport {
        throughput_rps: completions.len() as f64 / wall.as_secs_f64(),
        accuracy,
        full_parity: full_pred.map(|_| parity_ok as f64 / completions.len().max(1) as f64),
        escalation_fraction: metrics.escalation_fraction(),
        stage_fractions,
        energy_uj,
        energy_full_uj: completions.len() as f64 * ladder.e_full(),
        p50: metrics.latency.quantile(0.5),
        p99: metrics.latency.quantile(0.99),
        mean_latency: metrics.latency.mean(),
        queue_wait_mean: metrics.queue_wait.mean(),
        queue_wait_samples: metrics.queue_wait.count(),
        completions,
        wall,
    })
}

/// Flush `take` queued escalations through ladder stage `stage`.
/// Completes rows accepted there (or at the final stage) and forwards
/// the rest to the next stage's queue.  Draws its own chunk id so every
/// flushed batch gets a distinct SC key.
#[allow(clippy::too_many_arguments)]
fn flush_stage(
    engine: &mut dyn Backend,
    ladder: &Ladder,
    esc_queues: &mut [Vec<(Request, Vec<f32>)>],
    stage: usize,
    take: usize,
    metrics: &MetricsRegistry,
    completions: &mut Vec<Completion>,
    chunk: &mut u32,
) -> crate::Result<()> {
    *chunk += 1;
    let key_seed = *chunk;
    let drained: Vec<_> = esc_queues[stage].drain(..take).collect();
    let mut x = Vec::with_capacity(take * drained[0].1.len());
    for (_, row) in &drained {
        x.extend_from_slice(row);
    }
    let out = ladder.run_stage(engine, stage, &x, take, key_seed)?;
    metrics.add_energy_uj(take as f64 * ladder.stages[stage].energy_uj);
    let last = stage + 1 == ladder.n_stages();
    // full_batches tracks full-model dispatches only; intermediate-stage
    // flushes get their own named counter so the report stays honest for
    // N-level ladders.
    if last {
        metrics.full_batches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    } else {
        metrics.bump(&format!("stage{stage}_flushes"), 1);
    }
    let now = Instant::now();
    for (i, (req, row)) in drained.into_iter().enumerate() {
        if last || crate::margin::accepts(out.margin[i], ladder.stages[stage].threshold) {
            let lat = now.duration_since(req.submitted);
            metrics.latency.record(lat);
            metrics.completed.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            metrics.escalated.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            completions.push(Completion {
                id: req.id,
                row: req.row,
                pred: out.pred[i],
                stage,
                escalated: true,
                latency: lat,
            });
        } else {
            esc_queues[stage + 1].push((req, row));
        }
    }
    Ok(())
}

impl ServeReport {
    /// Savings vs running every request on the full model (eq. 2 realised).
    pub fn savings(&self) -> f64 {
        if self.energy_full_uj == 0.0 {
            return 0.0;
        }
        1.0 - self.energy_uj / self.energy_full_uj
    }

    /// Human-readable summary block.
    pub fn summary(&self) -> String {
        let stages = self
            .stage_fractions
            .iter()
            .enumerate()
            .map(|(i, f)| format!("s{i} {:.1}%", 100.0 * f))
            .collect::<Vec<_>>()
            .join(" ");
        format!(
            "served {} requests in {:.2?} ({:.0} req/s)\n\
             accuracy {:.4}{}  escalation {:.2}%  stage mix: {stages}\n\
             latency mean {:?} p50 {:?} p99 {:?} (queue wait mean {:?})\n\
             energy {:.1} µJ vs always-full {:.1} µJ -> savings {:.1}%",
            self.completions.len(),
            self.wall,
            self.throughput_rps,
            self.accuracy,
            self.full_parity.map(|p| format!(" (parity with full: {p:.4})")).unwrap_or_default(),
            100.0 * self.escalation_fraction,
            self.mean_latency,
            self.p50,
            self.p99,
            self.queue_wait_mean,
            self.energy_uj,
            self.energy_full_uj,
            100.0 * self.savings(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_savings() {
        let r = ServeReport {
            completions: vec![],
            wall: Duration::from_secs(1),
            throughput_rps: 0.0,
            accuracy: 0.0,
            full_parity: None,
            escalation_fraction: 0.0,
            stage_fractions: vec![0.55, 0.3, 0.15],
            energy_uj: 45.0,
            energy_full_uj: 100.0,
            p50: Duration::ZERO,
            p99: Duration::ZERO,
            mean_latency: Duration::ZERO,
            queue_wait_mean: Duration::ZERO,
            queue_wait_samples: 0,
        };
        assert!((r.savings() - 0.55).abs() < 1e-12);
        assert!(r.summary().contains("55.0%"));
        assert!(r.summary().contains("s1 30.0%"));
    }
}
