//! The serving loop: workload generation, request queueing, pipelined
//! ladder dispatch and reporting.
//!
//! Threading model (three stages, pipelined):
//!
//! 1. a **generator** thread produces timestamped requests into an
//!    `mpsc` channel (open-loop Poisson or closed-loop);
//! 2. a **batching** thread runs the arrival loop — `recv_timeout`
//!    against the batcher's next deadline, one timestamp per iteration
//!    threaded through `push_at`/`try_fire_into` — and stages each
//!    fired batch's input rows into a recycled `StagedBatch` buffer;
//! 3. the **calling** thread runs ladder inference.  Backends may be
//!    thread-pinned (the PJRT client is `Rc`-based, not `Send` — see
//!    [`crate::runtime`]), so compute stays on the caller while
//!    batching/arrival overlaps it.
//!
//! Stages 2 and 3 exchange a fixed set of staging buffers through a
//! pair of bounded queues ([`crate::util::queue::BoundedQueue`]):
//! bounded for backpressure, preallocated so the steady-state dispatch
//! path — batch fire, input staging, ladder forward, completion
//! recording — performs **zero heap allocation** (buffers circulate;
//! the ladder reuses gather/padding scratch and a recycled result; the
//! native backend recycles output storage via
//! `Backend::recycle_outputs`).  Compute additionally scales with
//! cores: the native backend shards each batch's rows across the
//! persistent worker pool inside `execute` (see [`crate::mlp::plan`]
//! and `docs/PERF.md`).
//!
//! Both escalation policies route through the N-level
//! [`crate::coordinator::Ladder`]: `Immediate` walks a batch down the
//! whole ladder in place; `Deferred` keeps one escalation queue per
//! non-first stage (row indices only — inputs are re-gathered from the
//! dataset at flush time) and flushes a stage when a full batch of
//! escalations is waiting (or at shutdown).  Every dispatched batch —
//! reduced or escalation flush — draws a fresh chunk id from one
//! shared counter, so no two SC batches ever share a
//! stochastic-computing key.  Batches are staged and inferred strictly
//! in arrival order, so serving output for a fixed seed is as
//! deterministic as the pre-pipelined loop.
//!
//! **Fault tolerance** (see `docs/ROBUSTNESS.md`): every submitted
//! request yields exactly one typed [`Completion`] — served
//! ([`CompletionOutcome::Ok`]), served reduced under overload
//! ([`CompletionOutcome::Degraded`]), rejected past its deadline
//! ([`CompletionOutcome::Rejected`]), or failed after exhausting
//! execute retries ([`CompletionOutcome::Failed`]).  Transient backend
//! errors and panics are retried with linear backoff
//! ([`RobustnessPolicy`]); a stalled batching thread is detected by a
//! heartbeat watchdog that closes the pipeline and turns the hang into
//! a diagnostic error.  With every knob at its default-off setting the
//! dispatch path is bit-identical to the policy-free loop.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
// ari-lint: allow(sim-discipline): mpsc is the production RequestSource transport and the
// watchdog stop signal deliberately runs on real primitives even under the sim scheduler —
// both sit outside the model-checked dispatch protocol (see docs/TESTING.md).
use std::sync::{mpsc, Condvar, Mutex};
use std::time::{Duration, Instant};

pub mod net;

use crate::config::AriConfig;
use crate::coordinator::{
    Batcher, BatcherPolicy, Cascade, ControlPolicy, Controller, EscalationPolicy, Ladder, LadderBatch, LadderScratch,
    Pending,
};
use crate::data::EvalData;
use crate::metrics::MetricsRegistry;
use crate::runtime::Backend;
use crate::util::fault;
use crate::util::queue::BoundedQueue;
use crate::util::sim;
use crate::util::Pcg64;

/// Staged batches in flight between the batching thread and the
/// inference loop.  2 is enough to overlap staging with compute; more
/// would only let the queue hide latency the report should show.
const PIPELINE_DEPTH: usize = 2;

/// Arrival-loop poll interval when the batcher holds no deadline.
const IDLE_POLL: Duration = Duration::from_millis(50);

/// One request: a row index into the workload dataset.
#[derive(Clone, Copy, Debug)]
pub struct Request {
    /// Unique request id (generation order).
    pub id: u64,
    /// Row index into the workload dataset.
    pub row: usize,
    /// When the generator produced the request.
    pub submitted: Instant,
    /// Optional completion deadline.  A request still waiting for its
    /// first-stage dispatch past this instant is rejected instead of
    /// occupying a batch slot ([`CompletionOutcome::Rejected`]).
    pub deadline: Option<Instant>,
}

/// How a request's single accounted [`Completion`] came to be.  Every
/// submitted request gets exactly one, whatever faults the session
/// absorbed along the way.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CompletionOutcome {
    /// Served by the normal ladder walk (possibly escalated).
    Ok,
    /// Served the reduced-stage answer because the dispatcher was in
    /// overload and suppressed escalation; the prediction is real but
    /// below the configured confidence bar.
    Degraded,
    /// Deadline expired before first-stage dispatch; `pred` is `-1`
    /// and no inference ran for this request.
    Rejected,
    /// Backend execution failed after exhausting the retry budget;
    /// `pred` is `-1`.
    Failed,
}

/// Completed request with its outcome.
#[derive(Clone, Debug)]
pub struct Completion {
    /// The request's id.
    pub id: u64,
    /// The request's dataset row.
    pub row: usize,
    /// Predicted class served back (`-1` when rejected or failed).
    pub pred: i32,
    /// Ladder stage that produced the prediction (0 = reduced model).
    pub stage: usize,
    /// Margin (top-1 minus top-2 confidence) at the serving stage;
    /// `0.0` when no inference ran (rejected / failed).  Carried so the
    /// wire protocol can ship a confidence score with each response.
    pub margin: f32,
    /// Whether any escalation stage ran for this request.
    pub escalated: bool,
    /// Submit-to-complete latency.
    pub latency: Duration,
    /// How this completion was produced.
    pub outcome: CompletionOutcome,
}

/// Aggregated serving report.
#[derive(Debug)]
pub struct ServeReport {
    /// Every served request with its outcome.
    pub completions: Vec<Completion>,
    /// Wall time of the whole serving session.
    pub wall: Duration,
    /// Completions per second of wall time.
    pub throughput_rps: f64,
    /// Accuracy of the served predictions against labels.
    pub accuracy: f64,
    /// Agreement with the always-full baseline predictions, if provided.
    pub full_parity: Option<f64>,
    /// Fraction of requests that ran at least one escalation stage.
    pub escalation_fraction: f64,
    /// Fraction of completions *finishing* at each ladder stage
    /// (completion shares — sums to 1).  Not the executed-fraction `f_i`
    /// of the energy identity `E = Σ_i f_i · E_i`; that is
    /// [`crate::coordinator::LadderBatch::stage_fractions`], where every
    /// escalated row also counts toward the stages it passed through.
    pub stage_fractions: Vec<f64>,
    /// Modelled energy actually spent (µJ).
    pub energy_uj: f64,
    /// Modelled energy an always-full policy would have spent (µJ).
    pub energy_full_uj: f64,
    /// Median request latency.
    pub p50: Duration,
    /// 95th-percentile request latency.
    pub p95: Duration,
    /// 99th-percentile request latency.
    pub p99: Duration,
    /// Mean request latency.
    pub mean_latency: Duration,
    /// Mean wait in the batching queue before the first-stage pass:
    /// batcher enqueue → dispatch (recorded under both escalation
    /// policies).
    pub queue_wait_mean: Duration,
    /// Queue-wait samples recorded (one per dispatched request).
    pub queue_wait_samples: u64,
    /// Mean ingress wait before the batcher saw the request:
    /// submission → batcher enqueue.  Wire transit + decode + admission
    /// for TCP sessions; generator hand-off in-process.  Together with
    /// [`Self::queue_wait_mean`] this splits pre-dispatch latency into
    /// "the network was slow" vs "the batcher was congested".
    pub net_wait_mean: Duration,
    /// Net-wait samples recorded (one per dispatched request).
    pub net_wait_samples: u64,
    /// Batch slots dispatched without a request in them — first-stage
    /// batches **and** escalation-stage flushes (the latter were
    /// uncounted before this field existed).
    pub padded_slots: u64,
    /// Requests served the reduced-stage answer under overload.
    pub degraded: u64,
    /// Requests rejected because their deadline expired before dispatch.
    pub rejected: u64,
    /// Requests failed after exhausting the execute retry budget.
    pub failed: u64,
    /// Backend execute retries performed across the session.
    pub retries: u64,
    /// Every control-loop adaptation in emission order (empty with the
    /// `[control]` section off).  See
    /// [`crate::metrics::ControlEvent`].
    pub control_events: Vec<crate::metrics::ControlEvent>,
}

/// Serving options beyond the config.
#[derive(Clone, Copy, Debug)]
pub struct ServeOptions {
    /// When escalated rows run on the deeper stages.
    pub escalation: EscalationPolicy,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self { escalation: EscalationPolicy::Immediate }
    }
}

/// The serving loop's fault-handling knobs, derived from the
/// `[server]` config section (see `docs/CONFIG.md` and
/// `docs/ROBUSTNESS.md`).  [`RobustnessPolicy::default`] turns every
/// mechanism off, which keeps the dispatch path bit-identical to the
/// policy-free loop.
#[derive(Clone, Copy, Debug)]
pub struct RobustnessPolicy {
    /// Per-request deadline measured from submission; `None` disables
    /// deadline rejection.
    pub deadline: Option<Duration>,
    /// Extra execute attempts after the first failure (errors *and*
    /// panics are retried).  0 fails the batch on the first error.
    pub retries: u32,
    /// Backoff before retry `k` is `retry_backoff * k` (linear).
    pub retry_backoff: Duration,
    /// Queue-depth overload threshold in requests (staged backlog plus
    /// queued escalations); 0 disables.
    pub overload_queue: usize,
    /// Observed-p95-latency overload threshold; `None` disables.
    pub overload_p95: Option<Duration>,
    /// Declare the batching thread stalled after this long without a
    /// heartbeat; `None` disables the watchdog.
    pub watchdog_stall: Option<Duration>,
}

impl Default for RobustnessPolicy {
    fn default() -> Self {
        Self {
            deadline: None,
            retries: 0,
            retry_backoff: Duration::ZERO,
            overload_queue: 0,
            overload_p95: None,
            watchdog_stall: None,
        }
    }
}

impl RobustnessPolicy {
    /// Build the policy from the `[server]` config keys (a `0` /
    /// absent key disables the corresponding mechanism).
    pub fn from_config(cfg: &AriConfig) -> Self {
        Self {
            deadline: (cfg.deadline_us > 0).then(|| Duration::from_micros(cfg.deadline_us)),
            retries: cfg.retries,
            retry_backoff: Duration::from_micros(cfg.retry_backoff_us),
            overload_queue: cfg.overload_queue,
            overload_p95: (cfg.overload_p95_us > 0).then(|| Duration::from_micros(cfg.overload_p95_us)),
            watchdog_stall: (cfg.watchdog_stall_us > 0).then(|| Duration::from_micros(cfg.watchdog_stall_us)),
        }
    }
}

/// Liveness beacon the batching thread increments once per arrival
/// iteration; the serving watchdog declares a stall when it stops
/// advancing.  `doc(hidden)`-pub so the model suites can drive
/// [`batching_loop`] directly.
#[doc(hidden)]
#[derive(Debug, Default)]
pub struct Heartbeat(AtomicU64);

impl Heartbeat {
    /// Record one unit of batching-loop progress.
    pub fn beat(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    /// Beats recorded so far.
    pub fn count(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Render a caught panic payload for an error message.
fn panic_msg(p: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = p.downcast_ref::<&str>() {
        s
    } else if let Some(s) = p.downcast_ref::<String>() {
        s
    } else {
        "non-string panic payload"
    }
}

/// Run `f` with the policy's retry budget.  A panic inside `f` is
/// caught (through [`sim::catching`], so deliberate panics don't abort
/// a model schedule) and treated as one more transient failure.  Each
/// retry bumps `metrics.retries` and sleeps `retry_backoff * attempt`.
fn with_retry<T>(
    policy: &RobustnessPolicy,
    metrics: &MetricsRegistry,
    mut f: impl FnMut() -> crate::Result<T>,
) -> crate::Result<T> {
    let mut attempt = 0u32;
    loop {
        let err = match sim::catching(&mut f) {
            Ok(Ok(v)) => return Ok(v),
            Ok(Err(e)) => e,
            Err(p) => anyhow::anyhow!("backend panicked during execute: {}", panic_msg(p.as_ref())),
        };
        if attempt >= policy.retries {
            return Err(err);
        }
        attempt += 1;
        metrics.retries.fetch_add(1, Ordering::Relaxed);
        if !policy.retry_backoff.is_zero() {
            std::thread::sleep(policy.retry_backoff * attempt);
        }
    }
}

/// A batch staged for inference: the fired requests plus their input
/// rows gathered contiguously.  A fixed set of these circulates
/// between the batching thread and the inference loop, so the steady
/// state stages batches into already-sized buffers.  `doc(hidden)`-pub
/// so the model suites (`tests/model_server.rs`) can drive
/// [`batching_loop`] directly under the sim harness.
#[doc(hidden)]
#[derive(Default)]
pub struct StagedBatch {
    /// Requests fired into this batch, arrival order.
    pub items: Vec<Pending<Request>>,
    /// Their input rows, gathered contiguously.
    pub x: Vec<f32>,
}

/// What one arrival-loop receive produced.  Mirrors
/// `mpsc::RecvTimeoutError` so [`batching_loop`] can run against the
/// real channel or the sim harness's virtual-time channel.
#[doc(hidden)]
#[derive(Debug)]
pub enum SourceRecv {
    /// A request arrived.
    Req(Request),
    /// The timeout elapsed with no request.
    Timeout,
    /// Every sender is gone.
    Disconnected,
}

/// The arrival loop's view of the request channel.  The production
/// impl is `mpsc::Receiver<Request>`; dev/test builds also implement
/// it for [`sim::SimReceiver`] so model tests can enumerate arrival /
/// deadline / shutdown interleavings deterministically.
#[doc(hidden)]
pub trait RequestSource {
    /// Receive with a timeout.
    fn recv_timeout(&mut self, timeout: Duration) -> SourceRecv;
    /// Non-blocking receive; `None` for empty *or* disconnected (only
    /// used on the shutdown tail-drain path).
    fn try_recv(&mut self) -> Option<Request>;
}

impl RequestSource for mpsc::Receiver<Request> {
    fn recv_timeout(&mut self, timeout: Duration) -> SourceRecv {
        match mpsc::Receiver::recv_timeout(self, timeout) {
            Ok(req) => SourceRecv::Req(req),
            Err(mpsc::RecvTimeoutError::Timeout) => SourceRecv::Timeout,
            Err(mpsc::RecvTimeoutError::Disconnected) => SourceRecv::Disconnected,
        }
    }

    fn try_recv(&mut self) -> Option<Request> {
        mpsc::Receiver::try_recv(self).ok()
    }
}

#[cfg(any(debug_assertions, feature = "sim"))]
impl RequestSource for sim::SimReceiver<Request> {
    fn recv_timeout(&mut self, timeout: Duration) -> SourceRecv {
        match sim::SimReceiver::recv_timeout(self, timeout) {
            sim::SimRecv::Item(req) => SourceRecv::Req(req),
            sim::SimRecv::Timeout => SourceRecv::Timeout,
            sim::SimRecv::Disconnected => SourceRecv::Disconnected,
        }
    }

    fn try_recv(&mut self) -> Option<Request> {
        sim::SimReceiver::try_recv(self)
    }
}

/// Clock the arrival loop stamps enqueues and deadlines with.  The
/// production impl is [`StdClock`]; model tests substitute the sim
/// harness's virtual clock so batcher deadlines fire deterministically.
#[doc(hidden)]
pub trait ServeClock {
    /// Current time.
    fn now(&self) -> Instant;
}

/// The real clock: `Instant::now()`.
#[doc(hidden)]
#[derive(Clone, Copy, Debug, Default)]
pub struct StdClock;

impl ServeClock for StdClock {
    fn now(&self) -> Instant {
        // ari-lint: allow(clock-discipline): this IS the ServeClock plumbing — the one
        // place the serving loop is allowed to read the real clock.
        Instant::now()
    }
}

/// Real-clock completion stamp for the dispatcher threads.
///
/// The serving *loop* threads one `ServeClock` read per iteration
/// (PR 5's one-read rule), but the pipeline dispatcher stamps each
/// batch completion as it lands — those stamps feed latency metrics
/// only, never scheduling decisions, so they read the real clock
/// directly instead of threading a clock handle through the worker
/// pool.
fn stamp_now() -> Instant {
    // ari-lint: allow(clock-discipline): metrics-only completion stamps outside the
    // ServeClock-driven loop; see the doc comment above.
    Instant::now()
}

/// Gather the staged requests' input rows into the batch's reusable
/// buffer.  The `drift-shift` fault point perturbs the gathered rows in
/// place — injected input drift for the control loop's monitor.
fn stage_rows(data: &EvalData, buf: &mut StagedBatch) {
    buf.x.clear();
    for p in &buf.items {
        buf.x.extend_from_slice(data.row(p.payload.row));
    }
    if fault::inject(fault::DRIFT_SHIFT) {
        fault::drift_rows(&mut buf.x);
    }
}

/// Fire every due batch into the pipeline.  Returns `false` when the
/// pipeline is closed (inference errored) and the loop should stop.
/// `now` is restamped after each dispatched batch: the buffer pop and
/// pipeline push may block on backpressure, and a stale timestamp
/// would both mis-stamp later enqueues and stretch the next recv
/// deadline by up to a full `max_wait`.
fn fire_ready<C: ServeClock>(
    batcher: &mut Batcher<Request>,
    now: &mut Instant,
    clock: &C,
    data: &EvalData,
    staged: &BoundedQueue<StagedBatch>,
    empties: &BoundedQueue<StagedBatch>,
) -> bool {
    while batcher.ready(*now) {
        let Some(mut buf) = empties.pop() else { return false };
        if batcher.try_fire_into(*now, &mut buf.items).is_none() {
            let _ = empties.push(buf);
            break;
        }
        stage_rows(data, &mut buf);
        if staged.push(buf).is_err() {
            return false;
        }
        *now = clock.now();
    }
    true
}

/// Shutdown flush: drain the batcher in `<= max_batch` chunks into the
/// pipeline until empty (or the pipeline is closed).
fn flush_batcher(
    batcher: &mut Batcher<Request>,
    data: &EvalData,
    staged: &BoundedQueue<StagedBatch>,
    empties: &BoundedQueue<StagedBatch>,
) {
    loop {
        let Some(mut buf) = empties.pop() else { return };
        if batcher.drain_into(&mut buf.items).is_none() {
            let _ = empties.push(buf);
            return;
        }
        stage_rows(data, &mut buf);
        if staged.push(buf).is_err() {
            return;
        }
    }
}

/// The batching thread's arrival loop: receive requests, fire batches
/// by size/deadline, stage their rows, and hand them to the inference
/// loop.  One `clock.now()` per arrival iteration stamps the
/// enqueue and drives every deadline check (the old loop took several
/// per request), plus one restamp per dispatched batch — the pipeline
/// push can block on backpressure (see [`fire_ready`]).  On shutdown
/// no request is ever discarded: when the expected count has been
/// produced, the channel is drained with `try_recv` and every returned
/// request is *pushed* (the old check dropped one).
///
/// Generic over the request source and clock ([`RequestSource`],
/// [`ServeClock`]) so `tests/model_server.rs` can run the *same* loop
/// body against the sim harness's channel and virtual clock; the
/// production instantiation is `mpsc::Receiver<Request>` + [`StdClock`]
/// and monomorphises to exactly the old code.  The
/// `lossy-shutdown-drain` fault (dev/test builds only) re-introduces
/// the historical lossy shutdown exit for the mutation suite.
///
/// `hb` is beaten once per arrival iteration; the serving watchdog
/// reads it to tell a stalled loop from a slow one.  The
/// [`fault::BATCH_STALL`] injection point simulates a hard stall: the
/// loop stops beating and parks until something (normally the
/// watchdog) closes the pipeline.
#[doc(hidden)]
pub fn batching_loop<S: RequestSource, C: ServeClock>(
    mut rx: S,
    clock: &C,
    policy: BatcherPolicy,
    n_requests: usize,
    data: &EvalData,
    staged: &BoundedQueue<StagedBatch>,
    empties: &BoundedQueue<StagedBatch>,
    hb: &Heartbeat,
) {
    let mut batcher: Batcher<Request> = Batcher::new(policy);
    let mut received = 0usize;
    let mut now = clock.now();
    loop {
        hb.beat();
        if fault::inject(fault::BATCH_STALL) {
            while !staged.is_closed() {
                std::thread::sleep(Duration::from_millis(10));
            }
            break;
        }
        if staged.is_closed() {
            break;
        }
        let timeout = batcher.next_deadline(now).unwrap_or(IDLE_POLL);
        match rx.recv_timeout(timeout) {
            SourceRecv::Req(req) => {
                now = clock.now();
                batcher.push_at(req, now);
                received += 1;
            }
            SourceRecv::Timeout => now = clock.now(),
            SourceRecv::Disconnected => {
                // Generator finished (or died): flush in <= max_batch
                // chunks and exit.
                if !sim::fault("lossy-shutdown-drain") {
                    flush_batcher(&mut batcher, data, staged, empties);
                }
                break;
            }
        }
        if !fire_ready(&mut batcher, &mut now, clock, data, staged, empties) {
            break;
        }
        if received >= n_requests {
            // Every request was produced: drain the channel tail
            // without discarding anything, then flush and exit.  The
            // tail gets a fresh stamp — these requests were submitted
            // after the loop's `now`, and a stale stamp would record
            // zero queue wait (enqueued < submitted saturates).
            now = clock.now();
            while let Some(req) = rx.try_recv() {
                batcher.push_at(req, now);
                received += 1;
            }
            if !sim::fault("lossy-shutdown-drain") {
                flush_batcher(&mut batcher, data, staged, empties);
            }
            break;
        }
    }
    staged.close();
}

/// Closes both pipeline queues on drop, so an inference error (or
/// panic) on the serving thread always releases the batching thread.
struct CloseOnDrop<'q> {
    staged: &'q BoundedQueue<StagedBatch>,
    empties: &'q BoundedQueue<StagedBatch>,
}

impl Drop for CloseOnDrop<'_> {
    fn drop(&mut self) {
        self.staged.close();
        self.empties.close();
    }
}

/// Where the dispatcher finds a request's input row.
///
/// In-process serving indexes the workload dataset by `Request::row`
/// and re-gathers escalation rows from it at flush time.  Net serving
/// has no dataset — rows arrive over the wire and live in the staging
/// buffers only — so the dispatcher keeps its own per-stage escalation
/// row copies (`esc_rows`) instead.
enum RowSource<'a> {
    /// `Request::row` indexes this dataset.
    Dataset(&'a EvalData),
    /// Rows arrive inline with each staged batch (`Request::row` is an
    /// opaque ticket for the caller); escalations copy their row into
    /// the dispatcher's `esc_rows`.
    Inline {
        /// Features per row.
        dim: usize,
    },
}

impl RowSource<'_> {
    fn dim(&self) -> usize {
        match self {
            RowSource::Dataset(d) => d.input_dim,
            RowSource::Inline { dim } => *dim,
        }
    }
}

/// Lock-free snapshot of the dispatcher's control-loop state, shared
/// with the network front so a `Stats` frame can be answered without
/// touching the dispatch path.  All fields are relaxed atomics: the
/// dispatcher publishes after each batch via
/// [`Dispatcher::publish_stats`]; readers tolerate tearing across
/// fields (each field is individually consistent).
pub struct ControlStats {
    /// Requests completed per ladder stage (`Ok`/`Degraded` only).
    pub stage_served: Vec<AtomicU64>,
    /// Effective per-stage thresholds, stored as `f64::to_bits`.
    pub thresholds: Vec<AtomicU64>,
    /// Current load-adaptive tighten level (0 = calibrated).
    pub level: AtomicU64,
    /// 1 while the drift monitor holds an active drift verdict.
    pub drifted: AtomicU64,
    /// Online recalibrations applied so far.
    pub recals: AtomicU64,
}

impl ControlStats {
    /// Zeroed stats block shaped for `ladder`, thresholds seeded from
    /// its calibrated values.
    pub fn new(ladder: &Ladder) -> Self {
        Self {
            stage_served: (0..ladder.n_stages()).map(|_| AtomicU64::new(0)).collect(),
            thresholds: ladder.stages.iter().map(|s| AtomicU64::new(s.threshold.to_bits())).collect(),
            level: AtomicU64::new(0),
            drifted: AtomicU64::new(0),
            recals: AtomicU64::new(0),
        }
    }
}

/// The inference side of the serving loop: ladder dispatch, escalation
/// queues, completion recording.  Owns every reusable buffer of the
/// dispatch path (ladder scratch, recycled ladder result, escalation
/// gather), so the steady state allocates nothing per batch.
struct Dispatcher<'a> {
    ladder: &'a Ladder,
    rows: RowSource<'a>,
    metrics: &'a MetricsRegistry,
    escalation: EscalationPolicy,
    policy: RobustnessPolicy,
    /// Approximate requests waiting in the staging pipeline, refreshed
    /// by the serving loop before each dispatch; feeds the queue-depth
    /// overload signal together with the escalation queues.
    backlog_hint: usize,
    /// Deferred escalations: one queue of requests per non-first stage
    /// (index 0 unused).  With a [`RowSource::Dataset`] only the
    /// request is queued — input rows are re-gathered from the dataset
    /// at flush time, replacing the old per-escalation row copy.
    esc_queues: Vec<Vec<Request>>,
    /// Escalation row copies, parallel to `esc_queues`, used only with
    /// [`RowSource::Inline`] (queue `s` holds `esc_queues[s].len() *
    /// dim` floats).  Amortised like every other dispatch buffer.
    esc_rows: Vec<Vec<f32>>,
    completions: Vec<Completion>,
    /// Every dispatched batch — first-stage or escalation flush — draws
    /// a fresh id from this counter, so SC keys are never reused.
    chunk: u32,
    scratch: LadderScratch,
    /// Recycled result buffer for `Ladder::infer_batch_into`.
    ladder_out: LadderBatch,
    /// Gather buffer for escalation flushes.
    gather: Vec<f32>,
    /// Reused buffers for the deadline filter (requests still live
    /// after rejection, and their re-gathered rows).
    live_items: Vec<Pending<Request>>,
    live_x: Vec<f32>,
    /// Closed-loop threshold controller (`docs/ROBUSTNESS.md`,
    /// "Control loop").  `Some` whenever any `[control]` knob is on *or*
    /// `overload_p95` is set — the latter runs the controller in
    /// pass-through mode purely for its sliding latency window, which
    /// replaced the old whole-session p95 (that histogram never decays,
    /// so one early spike pinned degraded mode forever).
    ctl: Option<Controller>,
    /// Requests served (`Ok`/`Degraded`) per ladder stage.
    stage_served: Vec<u64>,
}

impl<'a> Dispatcher<'a> {
    fn new(
        ladder: &'a Ladder,
        rows: RowSource<'a>,
        metrics: &'a MetricsRegistry,
        escalation: EscalationPolicy,
        policy: RobustnessPolicy,
        expected: usize,
    ) -> Self {
        let ctl = policy.overload_p95.is_some().then(|| Controller::new(ControlPolicy::default(), ladder));
        Self {
            ladder,
            rows,
            metrics,
            escalation,
            policy,
            backlog_hint: 0,
            esc_queues: vec![Vec::new(); ladder.n_stages()],
            esc_rows: vec![Vec::new(); ladder.n_stages()],
            completions: Vec::with_capacity(expected),
            chunk: 0,
            scratch: LadderScratch::new(),
            ladder_out: LadderBatch::empty(),
            gather: Vec::new(),
            live_items: Vec::new(),
            live_x: Vec::new(),
            ctl,
            stage_served: vec![0; ladder.n_stages()],
        }
    }

    /// Install a control policy.  The controller is kept when any of
    /// its features is enabled or `overload_p95` still needs the
    /// sliding latency window; otherwise the dispatcher runs the exact
    /// calibrated thresholds with zero control overhead.
    fn set_control(&mut self, policy: ControlPolicy) {
        if policy.enabled() || self.policy.overload_p95.is_some() {
            self.ctl = Some(Controller::new(policy, self.ladder));
        } else {
            self.ctl = None;
        }
    }

    /// The effective accept threshold for `stage` given the reduced
    /// model's predicted class — the controller's view when present,
    /// the calibrated ladder value otherwise.
    #[inline]
    fn threshold_for(&self, stage: usize, pred: i32) -> f64 {
        match &self.ctl {
            Some(c) => c.threshold(stage, pred),
            None => self.ladder.stages[stage].threshold,
        }
    }

    /// Close one control-loop batch: feed the controller the current
    /// queue depth (staged backlog plus queued escalations) and let it
    /// adapt.  Called once per dispatched first-stage batch.
    fn end_control_batch(&mut self) {
        let depth = self.backlog_hint + self.esc_queues.iter().map(Vec::len).sum::<usize>();
        if let Some(ctl) = self.ctl.as_mut() {
            ctl.end_batch(depth, self.metrics);
        }
    }

    /// Publish the control-loop snapshot for external readers (the
    /// network front's `Stats` frame).  Relaxed stores only — readers
    /// tolerate tearing across fields.
    fn publish_stats(&self, out: &ControlStats) {
        for (slot, &served) in out.stage_served.iter().zip(&self.stage_served) {
            slot.store(served, Ordering::Relaxed);
        }
        for (s, slot) in out.thresholds.iter().enumerate() {
            let t = match &self.ctl {
                Some(c) => c.effective_threshold(s),
                None => self.ladder.stages[s].threshold,
            };
            slot.store(t.to_bits(), Ordering::Relaxed);
        }
        let (level, drifted, recals) = match &self.ctl {
            Some(c) => (c.tighten_level() as u64, c.drifted() as u64, c.recals()),
            None => (0, 0, 0),
        };
        out.level.store(level, Ordering::Relaxed);
        out.drifted.store(drifted, Ordering::Relaxed);
        out.recals.store(recals, Ordering::Relaxed);
    }

    /// Whether the dispatcher should serve reduced-stage answers
    /// instead of escalating: queue depth (staged backlog plus queued
    /// escalations) or observed p95 latency past the configured
    /// threshold.  Recovers automatically — the signal is re-evaluated
    /// per dispatched batch.
    fn overload_active(&self) -> bool {
        if self.policy.overload_queue > 0 {
            let depth = self.backlog_hint + self.esc_queues.iter().map(Vec::len).sum::<usize>();
            if depth >= self.policy.overload_queue {
                return true;
            }
        }
        // Sliding-window p95 from the controller, not the session
        // histogram: the histogram never decays, so an early latency
        // spike used to pin degraded mode for the rest of the session.
        // The window forgets old samples and the signal recovers.
        if let (Some(t), Some(ctl)) = (self.policy.overload_p95, self.ctl.as_ref()) {
            if ctl.window_warm() && Duration::from_micros(ctl.window_p95_us()) >= t {
                return true;
            }
        }
        false
    }

    /// Record a `Failed` completion for every request of a batch whose
    /// execution exhausted the retry budget.  The session keeps
    /// serving — a backend fault must cost the batch, not the run.
    /// The `lost-completion` fault (dev/test builds only) drops the
    /// completion records, re-introducing a lost-request bug for the
    /// mutation suite.
    fn fail_batch(&mut self, items: &[Pending<Request>], err: &anyhow::Error) {
        self.metrics.bump("execute_failures", 1);
        sim::probe("fail_batch", items.len() as u64, 0);
        let _ = err;
        let now = stamp_now();
        for p in items {
            self.metrics.failed.fetch_add(1, Ordering::Relaxed);
            self.metrics.completed.fetch_add(1, Ordering::Relaxed);
            if sim::fault("lost-completion") {
                continue;
            }
            self.completions.push(Completion {
                id: p.payload.id,
                row: p.payload.row,
                pred: -1,
                stage: 0,
                margin: 0.0,
                escalated: false,
                latency: now.duration_since(p.payload.submitted),
                outcome: CompletionOutcome::Failed,
            });
        }
    }

    /// Dispatch one first-stage batch: reject expired-deadline
    /// requests, then run the survivors through the ladder.  The
    /// deadline filter's fast path (no request carries a deadline) is
    /// a single scan, so sessions without deadlines pay nothing.
    fn dispatch(&mut self, engine: &mut dyn Backend, items: &[Pending<Request>], x: &[f32]) -> crate::Result<()> {
        if !items.iter().any(|p| p.payload.deadline.is_some()) {
            return self.dispatch_live(engine, items, x);
        }
        let mut live = std::mem::take(&mut self.live_items);
        let mut live_x = std::mem::take(&mut self.live_x);
        live.clear();
        live_x.clear();
        let dim = self.rows.dim();
        let now = stamp_now();
        for (i, p) in items.iter().enumerate() {
            if p.payload.deadline.is_some_and(|d| now >= d) {
                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                self.metrics.completed.fetch_add(1, Ordering::Relaxed);
                self.completions.push(Completion {
                    id: p.payload.id,
                    row: p.payload.row,
                    pred: -1,
                    stage: 0,
                    margin: 0.0,
                    escalated: false,
                    latency: now.duration_since(p.payload.submitted),
                    outcome: CompletionOutcome::Rejected,
                });
            } else {
                live.push(Pending { payload: p.payload, enqueued: p.enqueued });
                live_x.extend_from_slice(&x[i * dim..(i + 1) * dim]);
            }
        }
        let r = self.dispatch_live(engine, &live, &live_x);
        self.live_items = live;
        self.live_x = live_x;
        r
    }

    /// Dispatch the deadline-surviving requests through the ladder.
    fn dispatch_live(
        &mut self,
        engine: &mut dyn Backend,
        items: &[Pending<Request>],
        x: &[f32],
    ) -> crate::Result<()> {
        let n = items.len();
        if n == 0 {
            return Ok(());
        }
        // Dispatch-start stamp: closes each request's queue-wait
        // interval (enqueue → dispatch) before service time begins.
        let t_disp = stamp_now();
        self.chunk += 1;
        sim::probe("sc_key", self.chunk as u64, 0);
        sim::probe("dispatch", n as u64, self.ladder.stages[0].variant.batch as u64);
        self.metrics.reduced_batches.fetch_add(1, Ordering::Relaxed);
        self.metrics
            .padded_slots
            .fetch_add((self.ladder.stages[0].variant.batch - n) as u64, Ordering::Relaxed);
        if self.overload_active() {
            return self.dispatch_degraded(engine, items, x);
        }
        let policy = self.policy;
        let metrics = self.metrics;
        let ladder = self.ladder;
        let chunk = self.chunk;
        match self.escalation {
            EscalationPolicy::Immediate => {
                let scratch = &mut self.scratch;
                let out = &mut self.ladder_out;
                // The controller supplies effective thresholds when
                // present; `None` takes the calibrated-only entry point
                // so the default path stays bit-identical.
                let ctl = self.ctl.as_ref();
                let run = with_retry(&policy, metrics, || match ctl {
                    Some(c) => ladder
                        .infer_batch_with(engine, x, n, chunk, &mut *scratch, &mut *out, &|s, p| c.threshold(s, p)),
                    None => ladder.infer_batch_into(engine, x, n, chunk, &mut *scratch, &mut *out),
                });
                if let Err(e) = run {
                    self.fail_batch(items, &e);
                    return Ok(());
                }
                self.metrics.add_energy_uj(self.ladder_out.energy_uj);
                // full_batches counts batches that actually reached the
                // final (full) model; intermediate stages don't qualify.
                if *self.ladder_out.stage_counts.last().unwrap() > 0 {
                    self.metrics.full_batches.fetch_add(1, Ordering::Relaxed);
                }
                let now = stamp_now();
                for (i, p) in items.iter().enumerate() {
                    let lat = now.duration_since(p.payload.submitted);
                    self.metrics.latency.record(lat);
                    self.metrics.net_wait.record(p.enqueued.duration_since(p.payload.submitted));
                    self.metrics.queue_wait.record(t_disp.duration_since(p.enqueued));
                    self.metrics.completed.fetch_add(1, Ordering::Relaxed);
                    if self.ladder_out.stage[i] > 0 {
                        self.metrics.escalated.fetch_add(1, Ordering::Relaxed);
                    }
                    if let Some(ctl) = self.ctl.as_mut() {
                        ctl.record_latency_us(lat.as_micros() as u64);
                        ctl.observe_margin(0, self.ladder_out.first_margin[i]);
                    }
                    self.stage_served[self.ladder_out.stage[i]] += 1;
                    self.completions.push(Completion {
                        id: p.payload.id,
                        row: p.payload.row,
                        pred: self.ladder_out.pred[i],
                        stage: self.ladder_out.stage[i],
                        margin: self.ladder_out.margin[i],
                        escalated: self.ladder_out.stage[i] > 0,
                        latency: lat,
                        outcome: CompletionOutcome::Ok,
                    });
                }
            }
            EscalationPolicy::Deferred => {
                let scratch = &mut self.scratch;
                let run = with_retry(&policy, metrics, || {
                    ladder.run_stage_scratch(engine, 0, x, n, chunk, &mut *scratch).map(|(out, _)| out)
                });
                let red = match run {
                    Ok(red) => red,
                    Err(e) => {
                        self.fail_batch(items, &e);
                        return Ok(());
                    }
                };
                self.metrics.add_energy_uj(n as f64 * self.ladder.stages[0].energy_uj);
                let now = stamp_now();
                for (i, p) in items.iter().enumerate() {
                    // Both waits are recorded at first dispatch under
                    // *both* policies, so MetricsRegistry::report()
                    // stays comparable across them.
                    self.metrics.net_wait.record(p.enqueued.duration_since(p.payload.submitted));
                    self.metrics.queue_wait.record(t_disp.duration_since(p.enqueued));
                    if let Some(ctl) = self.ctl.as_mut() {
                        ctl.observe_margin(0, red.margin[i]);
                    }
                    if crate::margin::accepts(red.margin[i], self.threshold_for(0, red.pred[i])) {
                        let lat = now.duration_since(p.payload.submitted);
                        self.metrics.latency.record(lat);
                        self.metrics.completed.fetch_add(1, Ordering::Relaxed);
                        if let Some(ctl) = self.ctl.as_mut() {
                            ctl.record_latency_us(lat.as_micros() as u64);
                        }
                        self.stage_served[0] += 1;
                        self.completions.push(Completion {
                            id: p.payload.id,
                            row: p.payload.row,
                            pred: red.pred[i],
                            stage: 0,
                            margin: red.margin[i],
                            escalated: false,
                            latency: lat,
                            outcome: CompletionOutcome::Ok,
                        });
                    } else {
                        if let RowSource::Inline { dim } = self.rows {
                            self.esc_rows[1].extend_from_slice(&x[i * dim..(i + 1) * dim]);
                        }
                        self.esc_queues[1].push(p.payload);
                    }
                }
                engine.recycle_outputs(red);
                // Flush any stage whose queue holds a full batch; a
                // flush at stage s may refill queue s+1, so walk down.
                for s in 1..self.ladder.n_stages() {
                    while self.esc_queues[s].len() >= self.ladder.stages[s].variant.batch {
                        let take = self.ladder.stages[s].variant.batch;
                        self.flush_stage(engine, s, take)?;
                    }
                }
            }
        }
        self.end_control_batch();
        Ok(())
    }

    /// Overload path: run the reduced stage only and serve its answer
    /// for every request — margin-accepted rows complete `Ok` exactly
    /// as they would off-overload, the rest are served `Degraded`
    /// instead of escalating.  Escalation pressure therefore stops
    /// growing, and once the overload signal clears the normal path
    /// resumes on the next batch.
    fn dispatch_degraded(
        &mut self,
        engine: &mut dyn Backend,
        items: &[Pending<Request>],
        x: &[f32],
    ) -> crate::Result<()> {
        let n = items.len();
        sim::probe("degraded", n as u64, 0);
        let t_disp = stamp_now();
        let policy = self.policy;
        let metrics = self.metrics;
        let ladder = self.ladder;
        let chunk = self.chunk;
        let scratch = &mut self.scratch;
        let run = with_retry(&policy, metrics, || {
            ladder.run_stage_scratch(engine, 0, x, n, chunk, &mut *scratch).map(|(out, _)| out)
        });
        let red = match run {
            Ok(red) => red,
            Err(e) => {
                self.fail_batch(items, &e);
                return Ok(());
            }
        };
        self.metrics.add_energy_uj(n as f64 * self.ladder.stages[0].energy_uj);
        let now = stamp_now();
        for (i, p) in items.iter().enumerate() {
            self.metrics.net_wait.record(p.enqueued.duration_since(p.payload.submitted));
            self.metrics.queue_wait.record(t_disp.duration_since(p.enqueued));
            let lat = now.duration_since(p.payload.submitted);
            self.metrics.latency.record(lat);
            self.metrics.completed.fetch_add(1, Ordering::Relaxed);
            if let Some(ctl) = self.ctl.as_mut() {
                ctl.record_latency_us(lat.as_micros() as u64);
                ctl.observe_margin(0, red.margin[i]);
            }
            self.stage_served[0] += 1;
            let outcome = if crate::margin::accepts(red.margin[i], self.threshold_for(0, red.pred[i])) {
                CompletionOutcome::Ok
            } else {
                self.metrics.degraded.fetch_add(1, Ordering::Relaxed);
                CompletionOutcome::Degraded
            };
            self.completions.push(Completion {
                id: p.payload.id,
                row: p.payload.row,
                pred: red.pred[i],
                stage: 0,
                margin: red.margin[i],
                escalated: false,
                latency: lat,
                outcome,
            });
        }
        engine.recycle_outputs(red);
        self.end_control_batch();
        Ok(())
    }

    /// Flush `take` queued escalations through ladder stage `stage`.
    /// Completes rows accepted there (or at the final stage) and
    /// forwards the rest to the next stage's queue.  Draws its own
    /// chunk id so every flushed batch gets a distinct SC key; padding
    /// waste is counted (escalation flushes used to be missed by
    /// `padded_slots`).
    fn flush_stage(&mut self, engine: &mut dyn Backend, stage: usize, take: usize) -> crate::Result<()> {
        self.chunk += 1;
        // `sc-key-reuse` (dev/test builds only) pins every flush to key
        // 1, re-introducing the historical shared-SC-key bug for the
        // mutation suite.
        let key_seed = if sim::fault("sc-key-reuse") { 1 } else { self.chunk };
        sim::probe("sc_key", key_seed as u64, 1);
        sim::probe("flush", stage as u64, take as u64);
        let mut gather = std::mem::take(&mut self.gather);
        gather.clear();
        match self.rows {
            RowSource::Dataset(data) => {
                for i in 0..take {
                    gather.extend_from_slice(data.row(self.esc_queues[stage][i].row));
                }
            }
            // Inline rows were copied at escalation time; they sit at
            // the queue's front in arrival order.
            RowSource::Inline { dim } => gather.extend_from_slice(&self.esc_rows[stage][..take * dim]),
        }
        let policy = self.policy;
        let metrics = self.metrics;
        let ladder = self.ladder;
        let scratch = &mut self.scratch;
        let gather_ref = &gather;
        let result = with_retry(&policy, metrics, || {
            ladder.run_stage_scratch(engine, stage, gather_ref, take, key_seed, &mut *scratch)
        });
        self.gather = gather;
        let (out, waste) = match result {
            Ok(r) => r,
            Err(e) => {
                // The flush exhausted its retries: the `take` queued
                // escalations fail as a unit and leave the queue, so
                // the session keeps draining instead of aborting.
                let failed: Vec<Pending<Request>> = self.esc_queues[stage][..take]
                    .iter()
                    .map(|&req| Pending { payload: req, enqueued: req.submitted })
                    .collect();
                self.fail_batch(&failed, &e);
                self.esc_queues[stage].drain(..take);
                if let RowSource::Inline { dim } = self.rows {
                    self.esc_rows[stage].drain(..take * dim);
                }
                return Ok(());
            }
        };
        self.metrics.add_energy_uj(take as f64 * self.ladder.stages[stage].energy_uj);
        // `padded-slots-first-stage-only` (dev/test builds only) skips
        // the flush-side count, re-introducing the historical
        // first-stage-only accounting for the mutation suite.
        if !sim::fault("padded-slots-first-stage-only") {
            self.metrics.padded_slots.fetch_add(waste as u64, Ordering::Relaxed);
        }
        let last = stage + 1 == self.ladder.n_stages();
        // full_batches tracks full-model dispatches only;
        // intermediate-stage flushes get their own named counter so the
        // report stays honest for N-level ladders.
        if last {
            self.metrics.full_batches.fetch_add(1, Ordering::Relaxed);
        } else {
            self.metrics.bump(&format!("stage{stage}_flushes"), 1);
        }
        let now = stamp_now();
        for i in 0..take {
            let req = self.esc_queues[stage][i];
            if last || crate::margin::accepts(out.margin[i], self.threshold_for(stage, out.pred[i])) {
                let lat = now.duration_since(req.submitted);
                self.metrics.latency.record(lat);
                self.metrics.completed.fetch_add(1, Ordering::Relaxed);
                self.metrics.escalated.fetch_add(1, Ordering::Relaxed);
                if let Some(ctl) = self.ctl.as_mut() {
                    ctl.record_latency_us(lat.as_micros() as u64);
                }
                self.stage_served[stage] += 1;
                self.completions.push(Completion {
                    id: req.id,
                    row: req.row,
                    pred: out.pred[i],
                    stage,
                    margin: out.margin[i],
                    escalated: true,
                    latency: lat,
                    outcome: CompletionOutcome::Ok,
                });
            } else {
                if let RowSource::Inline { dim } = self.rows {
                    // The flushed rows live in `gather` (disjoint field
                    // from `esc_rows`, so the borrows don't collide).
                    self.esc_rows[stage + 1].extend_from_slice(&self.gather[i * dim..(i + 1) * dim]);
                }
                self.esc_queues[stage + 1].push(req);
            }
        }
        self.esc_queues[stage].drain(..take);
        if let RowSource::Inline { dim } = self.rows {
            self.esc_rows[stage].drain(..take * dim);
        }
        engine.recycle_outputs(out);
        Ok(())
    }

    /// Shutdown drain: flush leftover escalations stage by stage (a
    /// flush at stage s may push into queue s+1, which is visited
    /// next).  Each flush draws a fresh chunk id.
    fn finish(&mut self, engine: &mut dyn Backend) -> crate::Result<()> {
        for s in 1..self.ladder.n_stages() {
            while !self.esc_queues[s].is_empty() {
                let take = self.esc_queues[s].len().min(self.ladder.stages[s].variant.batch);
                self.flush_stage(engine, s, take)?;
            }
        }
        Ok(())
    }
}

/// Run a serving session through a calibrated two-tier cascade.
///
/// Kept as the stable entry point for the paper's reduced/full
/// configuration; it serves from the cascade's underlying 2-level
/// ladder via [`run_serving_ladder`].
pub fn run_serving(
    engine: &mut dyn Backend,
    cascade: &Cascade,
    cfg: &AriConfig,
    data: &EvalData,
    full_pred: Option<&[i32]>,
    opts: ServeOptions,
) -> crate::Result<ServeReport> {
    run_serving_ladder(engine, &cascade.ladder, cfg, data, full_pred, opts)
}

/// Run a serving session: `cfg.requests` requests drawn (with repetition
/// if needed) from `data`, at `cfg.arrival_rate` req/s Poisson (or
/// closed-loop when 0), through a calibrated N-level ladder — batching
/// on a dedicated thread, inference on the calling thread, overlapped
/// through a bounded pipeline.
pub fn run_serving_ladder(
    engine: &mut dyn Backend,
    ladder: &Ladder,
    cfg: &AriConfig,
    data: &EvalData,
    full_pred: Option<&[i32]>,
    opts: ServeOptions,
) -> crate::Result<ServeReport> {
    // The batcher may fire (and the shutdown path drain) batches of up
    // to cfg.batch_size rows; every one must fit the ladder's compiled
    // batch or the padding accounting and run_padded's n <= batch
    // contract break.
    anyhow::ensure!(
        cfg.batch_size <= ladder.stages[0].variant.batch,
        "server batch_size {} exceeds the ladder's compiled batch {}",
        cfg.batch_size,
        ladder.stages[0].variant.batch
    );
    let robustness = RobustnessPolicy::from_config(cfg);
    let (tx, rx) = mpsc::channel::<Request>();
    let n_requests = cfg.requests;
    let n_rows = data.n;
    let rate = cfg.arrival_rate;
    let seed = cfg.seed;
    let deadline = robustness.deadline;
    // Generator thread: open-loop Poisson arrivals (or back-to-back).
    // ari-lint: allow(sim-discipline): the load generator models the *outside world* —
    // real arrivals on a real thread, intentionally invisible to the sim scheduler.
    let gen = std::thread::spawn(move || {
        let mut rng = Pcg64::new(seed, 99);
        for id in 0..n_requests as u64 {
            if rate > 0.0 {
                let gap = rng.exponential(rate);
                std::thread::sleep(Duration::from_secs_f64(gap));
            }
            let row = rng.below(n_rows as u64) as usize;
            // ari-lint: allow(clock-discipline): arrival timestamps come from the outside
            // world (the generator thread), not from the ServeClock-driven loop.
            let submitted = Instant::now();
            let req = Request { id, row, submitted, deadline: deadline.map(|d| submitted + d) };
            if tx.send(req).is_err() {
                return;
            }
        }
    });

    let metrics = MetricsRegistry::new();
    let policy = BatcherPolicy::new(cfg.batch_size, Duration::from_micros(cfg.batch_timeout_us));
    let mut disp = Dispatcher::new(ladder, RowSource::Dataset(data), &metrics, opts.escalation, robustness, n_requests);
    let control = ControlPolicy::from_config(cfg);
    if control.enabled() {
        disp.set_control(control);
    }
    // The fixed set of staging buffers that circulates through the
    // pipeline for the whole session.
    let staged: BoundedQueue<StagedBatch> = BoundedQueue::new(PIPELINE_DEPTH);
    let empties: BoundedQueue<StagedBatch> = BoundedQueue::new(PIPELINE_DEPTH);
    for _ in 0..PIPELINE_DEPTH {
        let _ = empties.push(StagedBatch::default());
    }
    let hb = Heartbeat::default();
    let stalled = AtomicBool::new(false);
    // Watchdog stop signal: flipped (under the lock, then notified)
    // once the serving loop exits, so the watchdog never outlives the
    // scope.  Plain `std` primitives — the watchdog measures real time
    // even in dev/test builds.
    let wd_stop: (Mutex<bool>, Condvar) = (Mutex::new(false), Condvar::new());
    // ari-lint: allow(clock-discipline): wall-clock session start for the throughput
    // report only; the serving loop itself reads time through ServeClock.
    let t_start = Instant::now();
    let input_dim = data.input_dim;
    let batch_size = cfg.batch_size;
    let serve_result: crate::Result<()> = std::thread::scope(|s| {
        let staged_ref = &staged;
        let empties_ref = &empties;
        let hb_ref = &hb;
        let _batching = s.spawn(move || {
            batching_loop(rx, &StdClock, policy, n_requests, data, staged_ref, empties_ref, hb_ref)
        });
        if let Some(stall_after) = robustness.watchdog_stall {
            let stalled_ref = &stalled;
            let wd_ref = &wd_stop;
            s.spawn(move || {
                let (lock, cv) = wd_ref;
                let mut last = hb_ref.count();
                // ari-lint: allow(clock-discipline): the watchdog measures *real* stall
                // time by design, even under the sim scheduler (see wd_stop above).
                let mut last_change = Instant::now();
                let mut done = lock.lock().unwrap_or_else(|e| e.into_inner());
                loop {
                    let poll = Duration::from_millis(100).min(stall_after);
                    let (g, _) = cv.wait_timeout(done, poll).unwrap_or_else(|e| e.into_inner());
                    done = g;
                    if *done {
                        return;
                    }
                    let beats = hb_ref.count();
                    if beats != last {
                        last = beats;
                        // ari-lint: allow(clock-discipline): watchdog real-time restamp,
                        // same rationale as above.
                        last_change = Instant::now();
                        continue;
                    }
                    if last_change.elapsed() >= stall_after {
                        // Convert the hang into a diagnostic failure:
                        // closing both queues releases every pipeline
                        // thread, and the flag turns the session into
                        // an `Err` below.
                        stalled_ref.store(true, Ordering::SeqCst);
                        staged_ref.close();
                        empties_ref.close();
                        return;
                    }
                }
            });
        }
        // Inference loop on the calling thread; the guard closes the
        // pipeline on every exit path so the batching thread never
        // blocks forever.
        let _guard = CloseOnDrop { staged: &staged, empties: &empties };
        let r = (|| {
            while let Some(mut batch) = staged.pop() {
                // Refresh the overload signal's view of the staged
                // backlog (batches waiting x configured batch size —
                // an upper bound on queued requests).
                disp.backlog_hint = staged.len() * batch_size;
                let n = batch.items.len();
                let r = disp.dispatch(engine, &batch.items, &batch.x[..n * input_dim]);
                batch.items.clear();
                batch.x.clear();
                let _ = empties.push(batch);
                r?;
            }
            Ok(())
        })();
        *wd_stop.0.lock().unwrap_or_else(|e| e.into_inner()) = true;
        wd_stop.1.notify_all();
        r
    });
    if stalled.load(Ordering::SeqCst) {
        // The generator is left to notice the closed channel on its
        // next send; joining it here could wait on arrival sleeps.
        drop(gen);
        anyhow::bail!(
            "serving pipeline stalled: no batching heartbeat for {:?}; watchdog closed the pipeline",
            robustness.watchdog_stall.unwrap_or_default()
        );
    }
    serve_result?;
    disp.finish(engine)?;
    gen.join().ok();

    let wall = t_start.elapsed();
    let completions = std::mem::take(&mut disp.completions);
    anyhow::ensure!(
        completions.len() == n_requests,
        "serving session lost completions: {} accounted of {} submitted",
        completions.len(),
        n_requests
    );
    #[cfg(debug_assertions)]
    {
        let mut ids: Vec<u64> = completions.iter().map(|c| c.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), n_requests, "duplicate completion ids");
    }
    let n_stages = ladder.n_stages();
    // Accuracy, parity and the stage mix are computed over *served*
    // predictions only (Ok | Degraded) — rejected and failed requests
    // carry no prediction and would read as misses.
    let mut served = 0usize;
    let mut accuracy = 0.0;
    let mut parity_ok = 0usize;
    let mut stage_fractions = vec![0.0f64; n_stages];
    for c in &completions {
        if matches!(c.outcome, CompletionOutcome::Rejected | CompletionOutcome::Failed) {
            continue;
        }
        served += 1;
        if c.pred == data.y[c.row] {
            accuracy += 1.0;
        }
        if let Some(fp) = full_pred {
            if c.pred == fp[c.row] {
                parity_ok += 1;
            }
        }
        stage_fractions[c.stage] += 1.0;
    }
    accuracy /= served.max(1) as f64;
    for f in &mut stage_fractions {
        *f /= served.max(1) as f64;
    }
    let energy_uj = metrics.energy_uj();
    Ok(ServeReport {
        throughput_rps: completions.len() as f64 / wall.as_secs_f64(),
        accuracy,
        full_parity: full_pred.map(|_| parity_ok as f64 / served.max(1) as f64),
        escalation_fraction: metrics.escalation_fraction(),
        stage_fractions,
        energy_uj,
        energy_full_uj: served as f64 * ladder.e_full(),
        p50: metrics.latency.quantile(0.5),
        p95: metrics.latency.quantile(0.95),
        p99: metrics.latency.quantile(0.99),
        mean_latency: metrics.latency.mean(),
        queue_wait_mean: metrics.queue_wait.mean(),
        queue_wait_samples: metrics.queue_wait.count(),
        net_wait_mean: metrics.net_wait.mean(),
        net_wait_samples: metrics.net_wait.count(),
        padded_slots: metrics.padded_slots.load(Ordering::Relaxed),
        degraded: metrics.degraded.load(Ordering::Relaxed),
        rejected: metrics.rejected.load(Ordering::Relaxed),
        failed: metrics.failed.load(Ordering::Relaxed),
        retries: metrics.retries.load(Ordering::Relaxed),
        control_events: metrics.control_events(),
        completions,
        wall,
    })
}

impl ServeReport {
    /// Savings vs running every request on the full model (eq. 2 realised).
    pub fn savings(&self) -> f64 {
        if self.energy_full_uj == 0.0 {
            return 0.0;
        }
        1.0 - self.energy_uj / self.energy_full_uj
    }

    /// Human-readable summary block.
    pub fn summary(&self) -> String {
        let stages = self
            .stage_fractions
            .iter()
            .enumerate()
            .map(|(i, f)| format!("s{i} {:.1}%", 100.0 * f))
            .collect::<Vec<_>>()
            .join(" ");
        format!(
            "served {} requests in {:.2?} ({:.0} req/s)\n\
             accuracy {:.4}{}  escalation {:.2}%  stage mix: {stages}\n\
             latency mean {:?} p50 {:?} p95 {:?} p99 {:?} (net wait mean {:?}, queue wait mean {:?})\n\
             robustness: degraded {} rejected {} failed {} retries {}\n\
             energy {:.1} µJ vs always-full {:.1} µJ -> savings {:.1}%",
            self.completions.len(),
            self.wall,
            self.throughput_rps,
            self.accuracy,
            self.full_parity.map(|p| format!(" (parity with full: {p:.4})")).unwrap_or_default(),
            100.0 * self.escalation_fraction,
            self.mean_latency,
            self.p50,
            self.p95,
            self.p99,
            self.net_wait_mean,
            self.queue_wait_mean,
            self.degraded,
            self.rejected,
            self.failed,
            self.retries,
            self.energy_uj,
            self.energy_full_uj,
            100.0 * self.savings(),
        )
    }
}

/// Deterministic single-threaded drivers for the dispatcher, used by
/// the model suites (`tests/model_server.rs`, `tests/model_mutations.rs`)
/// to check SC-key uniqueness and padding exactness without running a
/// full pipelined session.  Dev/test builds only — compiled out of
/// release binaries alongside the sim harness.
#[cfg(any(debug_assertions, feature = "sim"))]
#[doc(hidden)]
pub mod model {
    use super::*;

    /// Everything a model test needs after a deferred-policy session:
    /// the completions plus the probe-derived dispatch bookkeeping.
    pub struct DeferredSession {
        /// Completions in completion order.
        pub completions: Vec<Completion>,
        /// Final `padded_slots` metric.
        pub padded_slots: u64,
        /// Every SC chunk key drawn, in draw order.
        pub sc_keys: Vec<u64>,
        /// `(stage, take)` per escalation flush.
        pub flushes: Vec<(u64, u64)>,
        /// `(n, compiled_batch)` per first-stage dispatch.
        pub dispatches: Vec<(u64, u64)>,
        /// Control-loop adaptation events, in emission order.
        pub control_events: Vec<crate::metrics::ControlEvent>,
    }

    /// Run `batches` (lists of dataset row indices) through a
    /// deferred-escalation dispatcher exactly as the serving loop
    /// would — same `dispatch`/`flush_stage`/`finish` code — then
    /// collect the probe stream.  Uses the default (all-off)
    /// robustness policy; see [`drive_deferred_with`].
    pub fn drive_deferred(
        engine: &mut dyn Backend,
        ladder: &Ladder,
        data: &EvalData,
        batches: &[Vec<usize>],
    ) -> crate::Result<DeferredSession> {
        drive_deferred_with(engine, ladder, data, batches, RobustnessPolicy::default())
    }

    /// [`drive_deferred`] with an explicit [`RobustnessPolicy`], so the
    /// model suites can schedule deadline / retry / overload behaviour
    /// deterministically.
    pub fn drive_deferred_with(
        engine: &mut dyn Backend,
        ladder: &Ladder,
        data: &EvalData,
        batches: &[Vec<usize>],
        policy: RobustnessPolicy,
    ) -> crate::Result<DeferredSession> {
        drive_deferred_controlled(engine, ladder, data, batches, policy, None)
    }

    /// [`drive_deferred_with`] plus an optional [`ControlPolicy`], so
    /// the model suites can assert the conservation invariants while
    /// the closed-loop controller moves thresholds mid-session.
    pub fn drive_deferred_controlled(
        engine: &mut dyn Backend,
        ladder: &Ladder,
        data: &EvalData,
        batches: &[Vec<usize>],
        policy: RobustnessPolicy,
        control: Option<ControlPolicy>,
    ) -> crate::Result<DeferredSession> {
        let metrics = MetricsRegistry::new();
        let mut disp = Dispatcher::new(ladder, RowSource::Dataset(data), &metrics, EscalationPolicy::Deferred, policy, 64);
        if let Some(c) = control {
            disp.set_control(c);
        }
        // ari-lint: allow(clock-discipline): model-check driver, not the serving loop —
        // the stamp only seeds synthetic request timestamps for the harness.
        let t0 = Instant::now();
        let mut next_id = 0u64;
        let mut x = Vec::new();
        sim::begin_probes();
        let run = (|| -> crate::Result<()> {
            for rows in batches {
                let items: Vec<Pending<Request>> = rows
                    .iter()
                    .map(|&row| {
                        let req = Request { id: next_id, row, submitted: t0, deadline: None };
                        next_id += 1;
                        Pending { payload: req, enqueued: t0 }
                    })
                    .collect();
                x.clear();
                for p in &items {
                    x.extend_from_slice(data.row(p.payload.row));
                }
                disp.dispatch(engine, &items, &x)?;
            }
            disp.finish(engine)
        })();
        let probes = sim::end_probes();
        run?;
        let mut sc_keys = Vec::new();
        let mut flushes = Vec::new();
        let mut dispatches = Vec::new();
        for p in &probes {
            match p.tag {
                "sc_key" => sc_keys.push(p.a),
                "flush" => flushes.push((p.a, p.b)),
                "dispatch" => dispatches.push((p.a, p.b)),
                _ => {}
            }
        }
        Ok(DeferredSession {
            completions: std::mem::take(&mut disp.completions),
            padded_slots: metrics.padded_slots.load(Ordering::Relaxed),
            sc_keys,
            flushes,
            dispatches,
            control_events: metrics.control_events(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Mode, ThresholdPolicy};
    use crate::coordinator::LadderSpec;
    use crate::runtime::NativeBackend;

    #[test]
    fn report_savings() {
        let r = ServeReport {
            completions: vec![],
            wall: Duration::from_secs(1),
            throughput_rps: 0.0,
            accuracy: 0.0,
            full_parity: None,
            escalation_fraction: 0.0,
            stage_fractions: vec![0.55, 0.3, 0.15],
            energy_uj: 45.0,
            energy_full_uj: 100.0,
            p50: Duration::ZERO,
            p95: Duration::ZERO,
            p99: Duration::ZERO,
            mean_latency: Duration::ZERO,
            queue_wait_mean: Duration::ZERO,
            queue_wait_samples: 0,
            net_wait_mean: Duration::ZERO,
            net_wait_samples: 0,
            padded_slots: 0,
            degraded: 2,
            rejected: 1,
            failed: 3,
            retries: 4,
            control_events: vec![],
        };
        assert!((r.savings() - 0.55).abs() < 1e-12);
        assert!(r.summary().contains("55.0%"));
        assert!(r.summary().contains("s1 30.0%"));
        assert!(r.summary().contains("degraded 2 rejected 1 failed 3 retries 4"), "{}", r.summary());
    }

    fn fixture_ladder(engine: &mut NativeBackend, threshold: ThresholdPolicy) -> (Ladder, EvalData) {
        let data = engine.eval_data("fashion_syn").unwrap();
        let spec = LadderSpec {
            dataset: "fashion_syn".into(),
            mode: Mode::Fp,
            levels: vec![8, 12, 16],
            batch: 32,
            threshold,
            seed: 7,
        };
        let ladder = Ladder::calibrate(engine, spec, &data, 64).unwrap();
        (ladder, data)
    }

    fn staged_items(data: &EvalData, n: usize) -> (Vec<Pending<Request>>, Vec<f32>) {
        let t0 = Instant::now();
        let items: Vec<Pending<Request>> = (0..n)
            .map(|i| Pending {
                payload: Request { id: i as u64, row: i, submitted: t0, deadline: None },
                enqueued: t0,
            })
            .collect();
        let mut x = Vec::new();
        for p in &items {
            x.extend_from_slice(data.row(p.payload.row));
        }
        (items, x)
    }

    /// Satellite regression: `padded_slots` must count the padding of
    /// escalation-stage flushes, not just first-stage batches.  With a
    /// fixed threshold above the margin ceiling every row escalates to
    /// the end of a 3-level deferred ladder, so a 5-request session
    /// pads 27 slots at each of the three dispatches.
    #[test]
    fn escalation_flush_padding_is_counted() {
        let mut engine = NativeBackend::synthetic();
        // Margins are top1-minus-top2 of L2-normalised scores, so they
        // never exceed sqrt(2): T=2 escalates everything.
        let (ladder, data) = fixture_ladder(&mut engine, ThresholdPolicy::Fixed(2.0));
        let metrics = MetricsRegistry::new();
        let mut disp = Dispatcher::new(
            &ladder,
            RowSource::Dataset(&data),
            &metrics,
            EscalationPolicy::Deferred,
            RobustnessPolicy::default(),
            8,
        );
        let (items, x) = staged_items(&data, 5);
        disp.dispatch(&mut engine, &items, &x).unwrap();
        assert_eq!(disp.completions.len(), 0, "nothing accepted at FP8 under T=2");
        assert_eq!(disp.esc_queues[1].len(), 5);
        assert_eq!(metrics.padded_slots.load(Ordering::Relaxed), 27, "first-stage padding");
        disp.finish(&mut engine).unwrap();
        assert_eq!(disp.completions.len(), 5);
        assert!(disp.completions.iter().all(|c| c.stage == 2 && c.escalated));
        // 27 first-stage + 27 at the stage-1 flush + 27 at the stage-2
        // flush — the two flush paddings were uncounted before.
        assert_eq!(metrics.padded_slots.load(Ordering::Relaxed), 81);
        assert_eq!(metrics.full_batches.load(Ordering::Relaxed), 1);
        assert!(metrics.report().contains("stage1_flushes: 1"), "{}", metrics.report());
    }

    /// The reusable-dispatch path must serve the same predictions as a
    /// direct `Ladder::infer_batch` on the same rows and chunk id.
    #[test]
    fn immediate_dispatch_matches_ladder_inference() {
        let mut engine = NativeBackend::synthetic();
        let (ladder, data) = fixture_ladder(&mut engine, ThresholdPolicy::MMax);
        let metrics = MetricsRegistry::new();
        let mut disp = Dispatcher::new(
            &ladder,
            RowSource::Dataset(&data),
            &metrics,
            EscalationPolicy::Immediate,
            RobustnessPolicy::default(),
            16,
        );
        let (items, x) = staged_items(&data, 16);
        disp.dispatch(&mut engine, &items, &x).unwrap();
        // Dispatch used chunk id 1.
        let want = ladder.infer_batch(&mut engine, &x, 16, 1).unwrap();
        assert_eq!(disp.completions.len(), 16);
        for (i, c) in disp.completions.iter().enumerate() {
            assert_eq!(c.pred, want.pred[i], "row {i}");
            assert_eq!(c.stage, want.stage[i], "row {i}");
        }
        // Dispatching a second, different-sized batch reuses the same
        // buffers and stays correct.
        let (items2, x2) = staged_items(&data, 7);
        disp.dispatch(&mut engine, &items2, &x2).unwrap();
        assert_eq!(disp.completions.len(), 16 + 7);
        let want2 = ladder.infer_batch(&mut engine, &x2, 7, 2).unwrap();
        for (i, c) in disp.completions[16..].iter().enumerate() {
            assert_eq!(c.pred, want2.pred[i], "row {i}");
        }
    }

    /// End-to-end pipelined session: every request generated is served
    /// exactly once (closed-loop flood, small batches — the shape that
    /// used to lose an in-flight request at shutdown).
    #[test]
    fn pipelined_session_serves_every_request() {
        let mut engine = NativeBackend::synthetic();
        let data = engine.eval_data("fashion_syn").unwrap();
        let mut cfg = AriConfig::default();
        cfg.dataset = "fashion_syn".into();
        cfg.reduced_level = 8;
        cfg.requests = 200;
        cfg.batch_size = 8;
        cfg.batch_timeout_us = 200;
        cfg.arrival_rate = 0.0;
        // Calibrate at a compiled batch size (the fixture manifest has
        // 32/256); serving at batch_size 8 pads into it.
        let spec = LadderSpec {
            dataset: cfg.dataset.clone(),
            mode: Mode::Fp,
            levels: vec![8, 16],
            batch: 32,
            threshold: ThresholdPolicy::MMax,
            seed: cfg.seed as u32,
        };
        let ladder = Ladder::calibrate(&mut engine, spec, &data, 64).unwrap();
        let report =
            run_serving_ladder(&mut engine, &ladder, &cfg, &data, None, ServeOptions::default()).unwrap();
        assert_eq!(report.completions.len(), 200);
        let mut ids: Vec<u64> = report.completions.iter().map(|c| c.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 200, "every id exactly once");
        assert!(report.p95 >= report.p50 && report.p99 >= report.p95);
        // With every robustness knob at its default and no faults
        // armed, nothing degrades, rejects, fails or retries.
        assert!(report.completions.iter().all(|c| c.outcome == CompletionOutcome::Ok));
        assert_eq!(report.degraded + report.rejected + report.failed + report.retries, 0);
    }

    /// Requests whose deadline already passed are rejected with one
    /// typed completion each; the surviving rows are served the same
    /// predictions a direct ladder call produces for them.
    #[test]
    fn expired_deadlines_reject_without_starving_live_requests() {
        let mut engine = NativeBackend::synthetic();
        let (ladder, data) = fixture_ladder(&mut engine, ThresholdPolicy::MMax);
        let metrics = MetricsRegistry::new();
        let mut disp = Dispatcher::new(
            &ladder,
            RowSource::Dataset(&data),
            &metrics,
            EscalationPolicy::Immediate,
            RobustnessPolicy::default(),
            8,
        );
        let t0 = Instant::now();
        let mut items = Vec::new();
        let mut x = Vec::new();
        for i in 0..6usize {
            // Even ids carry an already-expired deadline (t0 is in the
            // past by dispatch time); odd ids have none.
            let deadline = (i % 2 == 0).then_some(t0);
            items.push(Pending {
                payload: Request { id: i as u64, row: i, submitted: t0, deadline },
                enqueued: t0,
            });
            x.extend_from_slice(data.row(i));
        }
        disp.dispatch(&mut engine, &items, &x).unwrap();
        assert_eq!(disp.completions.len(), 6, "one completion per request, rejected included");
        for c in &disp.completions {
            if c.id % 2 == 0 {
                assert_eq!(c.outcome, CompletionOutcome::Rejected, "id {}", c.id);
                assert_eq!(c.pred, -1);
            } else {
                assert_eq!(c.outcome, CompletionOutcome::Ok, "id {}", c.id);
            }
        }
        assert_eq!(metrics.rejected.load(Ordering::Relaxed), 3);
        // The live rows (1, 3, 5) were dispatched as one 3-row batch
        // with chunk id 1 — exactly what a direct call produces.
        let mut live_x = Vec::new();
        for i in [1usize, 3, 5] {
            live_x.extend_from_slice(data.row(i));
        }
        let want = ladder.infer_batch(&mut engine, &live_x, 3, 1).unwrap();
        let live: Vec<&Completion> =
            disp.completions.iter().filter(|c| c.outcome == CompletionOutcome::Ok).collect();
        for (k, c) in live.iter().enumerate() {
            assert_eq!(c.pred, want.pred[k], "live row {k}");
        }
    }

    /// Under queue-depth overload the dispatcher serves the reduced
    /// answer flagged `Degraded` and queues no escalations; once the
    /// signal clears, the very next batch escalates normally again.
    #[test]
    fn overload_serves_degraded_and_recovers() {
        let mut engine = NativeBackend::synthetic();
        // T=2 escalates everything, so any non-degraded dispatch queues
        // all its rows.
        let (ladder, data) = fixture_ladder(&mut engine, ThresholdPolicy::Fixed(2.0));
        let metrics = MetricsRegistry::new();
        let policy = RobustnessPolicy { overload_queue: 4, ..RobustnessPolicy::default() };
        let mut disp = Dispatcher::new(&ladder, RowSource::Dataset(&data), &metrics, EscalationPolicy::Deferred, policy, 16);
        disp.backlog_hint = 8; // over the threshold of 4
        let (items, x) = staged_items(&data, 5);
        disp.dispatch(&mut engine, &items, &x).unwrap();
        assert_eq!(disp.completions.len(), 5, "overload serves immediately at stage 0");
        assert!(disp
            .completions
            .iter()
            .all(|c| c.stage == 0 && !c.escalated && c.outcome == CompletionOutcome::Degraded));
        assert!(disp.esc_queues.iter().all(Vec::is_empty), "escalation suppressed under overload");
        assert_eq!(metrics.degraded.load(Ordering::Relaxed), 5);
        // Load drops: the same dispatcher escalates again.
        disp.backlog_hint = 0;
        let (items2, x2) = staged_items(&data, 5);
        disp.dispatch(&mut engine, &items2, &x2).unwrap();
        assert_eq!(disp.completions.len(), 5, "T=2 accepts nothing at stage 0 off-overload");
        assert_eq!(disp.esc_queues[1].len(), 5);
        disp.finish(&mut engine).unwrap();
        assert_eq!(disp.completions.len(), 10);
        assert!(disp.completions[5..].iter().all(|c| c.escalated && c.outcome == CompletionOutcome::Ok));
    }

    /// Satellite regression (PR 7 bug): the p95 overload signal reads a
    /// *sliding window*, not the whole-session histogram.  The histogram
    /// never decays, so an early latency spike used to pin degraded mode
    /// for the rest of the session; with the window the spike scrolls
    /// out and the detector recovers.
    #[test]
    fn overload_p95_recovers_after_early_spike() {
        let mut engine = NativeBackend::synthetic();
        let (ladder, data) = fixture_ladder(&mut engine, ThresholdPolicy::MMax);
        let metrics = MetricsRegistry::new();
        let policy =
            RobustnessPolicy { overload_p95: Some(Duration::from_millis(10)), ..RobustnessPolicy::default() };
        let mut disp =
            Dispatcher::new(&ladder, RowSource::Dataset(&data), &metrics, EscalationPolicy::Deferred, policy, 16);
        assert!(!disp.overload_active(), "cold window never trips the detector");
        // An early spike: 16 samples (the warm-up gate) far past the
        // 10 ms threshold.
        {
            let ctl = disp.ctl.as_mut().unwrap();
            for _ in 0..16 {
                ctl.record_latency_us(50_000);
            }
        }
        disp.end_control_batch();
        assert!(disp.overload_active(), "sustained spike trips the detector");
        // A full window of fast samples displaces the spike entirely;
        // the session histogram this replaced would still report the
        // 50 ms spike at p95 here.
        let window = ControlPolicy::default().window;
        {
            let ctl = disp.ctl.as_mut().unwrap();
            for _ in 0..window {
                ctl.record_latency_us(200);
            }
        }
        disp.end_control_batch();
        assert!(!disp.overload_active(), "spike scrolled out of the window: the signal must recover");
        assert_eq!(disp.ctl.as_ref().unwrap().window_p95_us(), 200);
        // No control knob is on — the pass-through controller emitted
        // no adaptation events while feeding the overload signal.
        assert!(metrics.control_events().is_empty());
    }

    /// Transient execute faults — one typed error and one panic — are
    /// retried until the batch serves, and the served predictions are
    /// bit-identical to an undisturbed run of the same batch and chunk.
    #[test]
    fn transient_execute_failures_retry_to_identical_predictions() {
        let mut native = NativeBackend::synthetic();
        let (ladder, data) = fixture_ladder(&mut native, ThresholdPolicy::MMax);
        // Call 0 (first attempt, stage 0) errors; call 1 (the retried
        // stage-0 execute) panics; the third attempt runs clean.
        let mut flaky = crate::runtime::FlakyBackend::new(native).fail_on_call(0).panic_on_call(1);
        let metrics = MetricsRegistry::new();
        let policy = RobustnessPolicy { retries: 3, ..RobustnessPolicy::default() };
        let mut disp = Dispatcher::new(&ladder, RowSource::Dataset(&data), &metrics, EscalationPolicy::Immediate, policy, 8);
        let (items, x) = staged_items(&data, 8);
        disp.dispatch(&mut flaky, &items, &x).unwrap();
        assert_eq!(disp.completions.len(), 8);
        assert!(disp.completions.iter().all(|c| c.outcome == CompletionOutcome::Ok));
        assert!(metrics.retries.load(Ordering::Relaxed) >= 2, "error and panic both retried");
        // All scheduled faults are behind us: the same engine now
        // reproduces the served predictions for chunk 1.
        let want = ladder.infer_batch(&mut flaky, &x, 8, 1).unwrap();
        for (i, c) in disp.completions.iter().enumerate() {
            assert_eq!(c.pred, want.pred[i], "row {i}");
        }
    }

    /// When the retry budget runs out the batch fails as a unit —
    /// every request gets exactly one `Failed` completion — and the
    /// session keeps serving the next batch.
    #[test]
    fn exhausted_retries_fail_the_batch_not_the_session() {
        let mut native = NativeBackend::synthetic();
        let (ladder, data) = fixture_ladder(&mut native, ThresholdPolicy::MMax);
        let mut flaky = crate::runtime::FlakyBackend::new(native).fail_on_call(0).fail_on_call(1);
        let metrics = MetricsRegistry::new();
        let policy = RobustnessPolicy { retries: 1, ..RobustnessPolicy::default() };
        let mut disp = Dispatcher::new(&ladder, RowSource::Dataset(&data), &metrics, EscalationPolicy::Immediate, policy, 8);
        let (items, x) = staged_items(&data, 4);
        disp.dispatch(&mut flaky, &items, &x).unwrap();
        assert_eq!(disp.completions.len(), 4, "the failed batch still accounts every request");
        assert!(disp.completions.iter().all(|c| c.outcome == CompletionOutcome::Failed && c.pred == -1));
        assert_eq!(metrics.failed.load(Ordering::Relaxed), 4);
        assert_eq!(metrics.retries.load(Ordering::Relaxed), 1);
        // The next batch is untouched by the earlier failure.
        let (items2, x2) = staged_items(&data, 4);
        disp.dispatch(&mut flaky, &items2, &x2).unwrap();
        assert_eq!(disp.completions.len(), 8);
        assert!(disp.completions[4..].iter().all(|c| c.outcome == CompletionOutcome::Ok));
    }

    /// A batching thread that stops beating is detected by the
    /// watchdog, which closes the pipeline and turns the would-be hang
    /// into a diagnostic error.
    #[test]
    fn watchdog_turns_a_stalled_batching_thread_into_an_error() {
        let _g = fault::ArmGuard::arm("batch-stall:1.0:1");
        let mut engine = NativeBackend::synthetic();
        let data = engine.eval_data("fashion_syn").unwrap();
        let mut cfg = AriConfig::default();
        cfg.dataset = "fashion_syn".into();
        cfg.requests = 16;
        cfg.batch_size = 8;
        cfg.batch_timeout_us = 200;
        cfg.arrival_rate = 0.0;
        cfg.watchdog_stall_us = 50_000;
        let spec = LadderSpec {
            dataset: cfg.dataset.clone(),
            mode: Mode::Fp,
            levels: vec![8, 16],
            batch: 32,
            threshold: ThresholdPolicy::MMax,
            seed: cfg.seed as u32,
        };
        let ladder = Ladder::calibrate(&mut engine, spec, &data, 64).unwrap();
        let err = run_serving_ladder(&mut engine, &ladder, &cfg, &data, None, ServeOptions::default())
            .unwrap_err()
            .to_string();
        assert!(err.contains("stalled"), "{err}");
    }
}
