//! The serving loop: workload generation, request queueing, cascade
//! dispatch and reporting.
//!
//! Threading model: backends may be thread-pinned (the PJRT client is
//! `Rc`-based, not `Send` — see [`crate::runtime`]), so the coordinator
//! loop — batcher + cascade + backend — runs on the calling thread,
//! while a generator thread produces timestamped requests into an
//! `mpsc` channel (open-loop Poisson or closed-loop).  This mirrors the
//! single-accelerator IoT deployment the paper targets: one device, one
//! inference queue.  Compute still scales with cores: the native
//! backend shards each batch's rows across its scoped worker pool
//! inside `execute` (see [`crate::mlp::plan`] and `docs/PERF.md`), so
//! the serving loop stays single-queue while forwards are parallel.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::config::AriConfig;
use crate::coordinator::{Batcher, BatcherPolicy, Cascade, EscalationPolicy};
use crate::data::EvalData;
use crate::metrics::MetricsRegistry;
use crate::runtime::Backend;
use crate::util::Pcg64;

/// One request: a row index into the workload dataset.
#[derive(Clone, Copy, Debug)]
pub struct Request {
    /// Unique request id (generation order).
    pub id: u64,
    /// Row index into the workload dataset.
    pub row: usize,
    /// When the generator produced the request.
    pub submitted: Instant,
}

/// Completed request with its outcome.
#[derive(Clone, Debug)]
pub struct Completion {
    /// The request's id.
    pub id: u64,
    /// The request's dataset row.
    pub row: usize,
    /// Predicted class served back.
    pub pred: i32,
    /// Whether the full model ran for this request.
    pub escalated: bool,
    /// Submit-to-complete latency.
    pub latency: Duration,
}

/// Aggregated serving report.
#[derive(Debug)]
pub struct ServeReport {
    /// Every served request with its outcome.
    pub completions: Vec<Completion>,
    /// Wall time of the whole serving session.
    pub wall: Duration,
    /// Completions per second of wall time.
    pub throughput_rps: f64,
    /// Accuracy of the served predictions against labels.
    pub accuracy: f64,
    /// Agreement with the always-full baseline predictions, if provided.
    pub full_parity: Option<f64>,
    /// Fraction of requests that ran the full model.
    pub escalation_fraction: f64,
    /// Modelled energy actually spent (µJ).
    pub energy_uj: f64,
    /// Modelled energy an always-full policy would have spent (µJ).
    pub energy_full_uj: f64,
    /// Median request latency.
    pub p50: Duration,
    /// 99th-percentile request latency.
    pub p99: Duration,
    /// Mean request latency.
    pub mean_latency: Duration,
}

/// Serving options beyond the config.
#[derive(Clone, Copy, Debug)]
pub struct ServeOptions {
    /// When escalated rows run on the full model.
    pub escalation: EscalationPolicy,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self { escalation: EscalationPolicy::Immediate }
    }
}

/// Run a serving session: `cfg.requests` requests drawn (with repetition
/// if needed) from `data`, at `cfg.arrival_rate` req/s Poisson (or
/// closed-loop when 0), through the calibrated cascade.
pub fn run_serving(
    engine: &mut dyn Backend,
    cascade: &Cascade,
    cfg: &AriConfig,
    data: &EvalData,
    full_pred: Option<&[i32]>,
    opts: ServeOptions,
) -> crate::Result<ServeReport> {
    let (tx, rx) = mpsc::channel::<Request>();
    let n_requests = cfg.requests;
    let n_rows = data.n;
    let rate = cfg.arrival_rate;
    let seed = cfg.seed;
    // Generator thread: open-loop Poisson arrivals (or back-to-back).
    let gen = std::thread::spawn(move || {
        let mut rng = Pcg64::new(seed, 99);
        for id in 0..n_requests as u64 {
            if rate > 0.0 {
                let gap = rng.exponential(rate);
                std::thread::sleep(Duration::from_secs_f64(gap));
            }
            let row = rng.below(n_rows as u64) as usize;
            if tx.send(Request { id, row, submitted: Instant::now() }).is_err() {
                return;
            }
        }
    });

    let metrics = MetricsRegistry::new();
    let policy = BatcherPolicy::new(cfg.batch_size, Duration::from_micros(cfg.batch_timeout_us));
    let mut batcher: Batcher<Request> = Batcher::new(policy);
    // Deferred-escalation queue (row-gathered inputs + request meta).
    let mut esc_queue: Vec<(Request, Vec<f32>)> = Vec::new();
    let mut completions: Vec<Completion> = Vec::with_capacity(n_requests);
    let mut received = 0usize;
    let mut chunk = 0u32;
    let t_start = Instant::now();

    // Helper: dispatch one reduced batch through the cascade.
    let dispatch = |batch: crate::coordinator::Batch<Request>,
                        engine: &mut dyn Backend,
                        esc_queue: &mut Vec<(Request, Vec<f32>)>,
                        completions: &mut Vec<Completion>,
                        chunk: &mut u32|
     -> crate::Result<()> {
        let n = batch.items.len();
        let mut x = Vec::with_capacity(n * data.input_dim);
        for p in &batch.items {
            x.extend_from_slice(data.row(p.payload.row));
        }
        *chunk += 1;
        metrics.reduced_batches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        metrics.padded_slots.fetch_add((cascade.reduced.batch - n) as u64, std::sync::atomic::Ordering::Relaxed);
        match opts.escalation {
            EscalationPolicy::Immediate => {
                let out = cascade.infer_batch(engine, &x, n, *chunk)?;
                metrics.add_energy_uj(out.energy_uj);
                if out.escalated.iter().any(|&e| e) {
                    metrics.full_batches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                }
                let now = Instant::now();
                for (i, p) in batch.items.iter().enumerate() {
                    let lat = now.duration_since(p.payload.submitted);
                    metrics.latency.record(lat);
                    metrics.queue_wait.record(p.enqueued.duration_since(p.payload.submitted));
                    metrics.completed.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    if out.escalated[i] {
                        metrics.escalated.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    }
                    completions.push(Completion {
                        id: p.payload.id,
                        row: p.payload.row,
                        pred: out.pred[i],
                        escalated: out.escalated[i],
                        latency: lat,
                    });
                }
            }
            EscalationPolicy::Deferred => {
                let red = cascade.run_reduced(engine, &x, n, *chunk)?;
                metrics.add_energy_uj(n as f64 * cascade.e_reduced);
                let now = Instant::now();
                for (i, p) in batch.items.iter().enumerate() {
                    if crate::margin::accepts(red.margin[i], cascade.threshold) {
                        let lat = now.duration_since(p.payload.submitted);
                        metrics.latency.record(lat);
                        metrics.completed.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        completions.push(Completion {
                            id: p.payload.id,
                            row: p.payload.row,
                            pred: red.pred[i],
                            escalated: false,
                            latency: lat,
                        });
                    } else {
                        esc_queue.push((p.payload, data.row(p.payload.row).to_vec()));
                    }
                }
                // Flush the escalation queue when a full batch is ready.
                while esc_queue.len() >= cascade.full.batch {
                    flush_escalations(engine, cascade, esc_queue, cascade.full.batch, &metrics, completions, *chunk)?;
                }
            }
        }
        Ok(())
    };

    // Main loop: recv with deadline-aware timeout, fire batches.
    loop {
        let now = Instant::now();
        let timeout = batcher.next_deadline(now).unwrap_or(Duration::from_millis(50));
        match rx.recv_timeout(timeout) {
            Ok(req) => {
                batcher.push_at(req, req.submitted.max(now));
                received += 1;
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                // Generator finished (or died): flush and exit.
                if let Some(batch) = batcher.drain() {
                    dispatch(batch, engine, &mut esc_queue, &mut completions, &mut chunk)?;
                }
                break;
            }
        }
        let now = Instant::now();
        while let Some(batch) = batcher.try_fire(now) {
            dispatch(batch, engine, &mut esc_queue, &mut completions, &mut chunk)?;
        }
        if received >= n_requests && rx.try_recv().is_err() {
            // Drain the tail.
            if let Some(batch) = batcher.drain() {
                dispatch(batch, engine, &mut esc_queue, &mut completions, &mut chunk)?;
            }
            if batcher.is_empty() {
                break;
            }
        }
    }
    // Flush any deferred escalations left over.
    while !esc_queue.is_empty() {
        let take = esc_queue.len().min(cascade.full.batch);
        flush_escalations(engine, cascade, &mut esc_queue, take, &metrics, &mut completions, chunk)?;
    }
    gen.join().ok();

    let wall = t_start.elapsed();
    let mut accuracy = 0.0;
    let mut parity_ok = 0usize;
    for c in &completions {
        if c.pred == data.y[c.row] {
            accuracy += 1.0;
        }
        if let Some(fp) = full_pred {
            if c.pred == fp[c.row] {
                parity_ok += 1;
            }
        }
    }
    accuracy /= completions.len().max(1) as f64;
    let energy_uj = metrics.energy_uj();
    Ok(ServeReport {
        throughput_rps: completions.len() as f64 / wall.as_secs_f64(),
        accuracy,
        full_parity: full_pred.map(|_| parity_ok as f64 / completions.len().max(1) as f64),
        escalation_fraction: metrics.escalation_fraction(),
        energy_uj,
        energy_full_uj: completions.len() as f64 * cascade.e_full,
        p50: metrics.latency.quantile(0.5),
        p99: metrics.latency.quantile(0.99),
        mean_latency: metrics.latency.mean(),
        completions,
        wall,
    })
}

fn flush_escalations(
    engine: &mut dyn Backend,
    cascade: &Cascade,
    esc_queue: &mut Vec<(Request, Vec<f32>)>,
    take: usize,
    metrics: &MetricsRegistry,
    completions: &mut Vec<Completion>,
    chunk: u32,
) -> crate::Result<()> {
    let drained: Vec<_> = esc_queue.drain(..take).collect();
    let mut x = Vec::with_capacity(take * drained[0].1.len());
    for (_, row) in &drained {
        x.extend_from_slice(row);
    }
    let out = cascade.run_full(engine, &x, take, chunk ^ 0x8000_0000)?;
    metrics.add_energy_uj(take as f64 * cascade.e_full);
    metrics.full_batches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let now = Instant::now();
    for (i, (req, _)) in drained.iter().enumerate() {
        let lat = now.duration_since(req.submitted);
        metrics.latency.record(lat);
        metrics.completed.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        metrics.escalated.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        completions.push(Completion { id: req.id, row: req.row, pred: out.pred[i], escalated: true, latency: lat });
    }
    Ok(())
}

impl ServeReport {
    /// Savings vs running every request on the full model (eq. 2 realised).
    pub fn savings(&self) -> f64 {
        if self.energy_full_uj == 0.0 {
            return 0.0;
        }
        1.0 - self.energy_uj / self.energy_full_uj
    }

    /// Human-readable summary block.
    pub fn summary(&self) -> String {
        format!(
            "served {} requests in {:.2?} ({:.0} req/s)\n\
             accuracy {:.4}{}  escalation {:.2}%\n\
             latency mean {:?} p50 {:?} p99 {:?}\n\
             energy {:.1} µJ vs always-full {:.1} µJ -> savings {:.1}%",
            self.completions.len(),
            self.wall,
            self.throughput_rps,
            self.accuracy,
            self.full_parity.map(|p| format!(" (parity with full: {p:.4})")).unwrap_or_default(),
            100.0 * self.escalation_fraction,
            self.mean_latency,
            self.p50,
            self.p99,
            self.energy_uj,
            self.energy_full_uj,
            100.0 * self.savings(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_savings() {
        let r = ServeReport {
            completions: vec![],
            wall: Duration::from_secs(1),
            throughput_rps: 0.0,
            accuracy: 0.0,
            full_parity: None,
            escalation_fraction: 0.0,
            energy_uj: 45.0,
            energy_full_uj: 100.0,
            p50: Duration::ZERO,
            p99: Duration::ZERO,
            mean_latency: Duration::ZERO,
        };
        assert!((r.savings() - 0.55).abs() < 1e-12);
        assert!(r.summary().contains("55.0%"));
    }
}
