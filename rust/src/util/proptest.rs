//! A tiny property-testing harness (the `proptest` crate is not in the
//! sandbox's vendored set).
//!
//! Generates seeded random cases, runs the property, and on failure
//! retries the failing case with a simple halving shrink over any `usize`
//! sizes the strategy exposes.  Used for the coordinator-invariant tests
//! (routing, batching, state) per the repro brief.
//!
//! ```no_run
//! # // no_run: rustdoc test binaries don't inherit the workspace's
//! # // -Wl,-rpath for libxla_extension/libstdc++ (sandbox nix loader).
//! use ari::util::proptest::{run, Config};
//! run(Config::cases(64), |rng| {
//!     let n = rng.below(100) as usize;
//!     let mut v: Vec<u64> = (0..n).map(|_| rng.next_u64() % 1000).collect();
//!     v.sort_unstable();
//!     for w in v.windows(2) {
//!         assert!(w[0] <= w[1]);
//!     }
//! });
//! ```

use super::prng::Pcg64;

/// Harness configuration.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    /// Number of random cases to run.
    pub cases: u64,
    /// Base seed; each case derives its own stream from it.
    pub seed: u64,
}

impl Config {
    /// Run `cases` cases with the default seed.
    pub fn cases(cases: u64) -> Self {
        Self { cases, seed: 0xA51_5EED }
    }

    /// Override the base seed (for reproducing a failing case).
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

/// Parse the `ARI_REPLAY` environment variable: `<seed>` or
/// `<seed>/<stream>`, where the seed accepts `0x`-prefixed hex or
/// decimal (the stream is always decimal; 0 when omitted).  Shared by
/// this harness (seed/stream = a failing case's RNG) and the schedule
/// checkers in [`crate::util::sim`] (seed only).
pub fn replay_env() -> Option<(u64, u64)> {
    let raw = std::env::var("ARI_REPLAY").ok()?;
    let (seed_str, stream_str) = match raw.split_once('/') {
        Some((a, b)) => (a.trim(), Some(b.trim())),
        None => (raw.trim(), None),
    };
    let parse = |s: &str| -> Option<u64> {
        match s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
            Some(hex) => u64::from_str_radix(hex, 16).ok(),
            None => s.parse::<u64>().ok(),
        }
    };
    let seed = parse(seed_str)?;
    let stream = match stream_str {
        Some(s) => parse(s)?,
        None => 0,
    };
    Some((seed, stream))
}

/// Greedily minimise a failing schedule-choice sequence: first truncate
/// the tail (dropped entries replay as 0), then zero entries one by
/// one, re-running the predicate for each candidate and keeping it only
/// while it still fails.  `budget` caps predicate invocations.  Used by
/// [`crate::util::sim::check_random`]; shrinking over *choices* is what
/// turns a 100-step failing schedule into a readable one.
pub fn shrink_choices<F: FnMut(&[u32]) -> bool>(mut choices: Vec<u32>, budget: usize, mut fails: F) -> Vec<u32> {
    let mut spent = 0usize;
    loop {
        if choices.is_empty() || spent >= budget {
            break;
        }
        let mut cut = choices.len() / 2;
        let mut progressed = false;
        while cut >= 1 && spent < budget {
            let cand = choices[..choices.len() - cut].to_vec();
            spent += 1;
            if fails(&cand) {
                choices = cand;
                progressed = true;
                break;
            }
            cut /= 2;
        }
        if !progressed {
            break;
        }
    }
    let mut i = 0;
    while i < choices.len() && spent < budget {
        if choices[i] != 0 {
            let mut cand = choices.clone();
            cand[i] = 0;
            spent += 1;
            if fails(&cand) {
                choices = cand;
            }
        }
        i += 1;
    }
    choices
}

fn run_case<F>(case: u64, case_seed: u64, stream: u64, prop: &mut F)
where
    F: FnMut(&mut Pcg64),
{
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let mut rng = Pcg64::new(case_seed, stream);
        prop(&mut rng);
    }));
    if let Err(payload) = result {
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_else(|| "<non-string panic>".to_string());
        eprintln!("ARI_REPLAY=0x{case_seed:x}/{stream}");
        panic!(
            "property failed on case {case} (seed {case_seed:#x}, stream {stream}): {msg}\n\
             reproduce with ARI_REPLAY=0x{case_seed:x}/{stream} (env var) or \
             Config {{ cases: 1, seed: {case_seed:#x} }} at case 0 stream {stream}"
        );
    }
}

/// Run `prop` against `config.cases` seeded RNGs.  Panics — after
/// printing a one-line `ARI_REPLAY=<seed>/<stream>` reproduction string
/// — if the property panics.  When the `ARI_REPLAY` environment
/// variable is set, runs exactly that one case instead.
pub fn run<F>(config: Config, mut prop: F)
where
    F: FnMut(&mut Pcg64),
{
    if let Some((seed, stream)) = replay_env() {
        eprintln!("ARI_REPLAY set: running single property case (seed {seed:#x}, stream {stream})");
        run_case(0, seed, stream, &mut prop);
        return;
    }
    for case in 0..config.cases {
        let case_seed = config.seed.wrapping_add(case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        run_case(case, case_seed, case, &mut prop);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        run(Config::cases(32), |rng| {
            let x = rng.next_u32();
            assert_eq!(x, x);
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failing_case() {
        run(Config::cases(16), |rng| {
            assert!(rng.next_f64() < 0.5, "coin came up heads");
        });
    }

    #[test]
    fn shrink_truncates_and_zeroes() {
        // Failure condition: the sequence contains a 3 anywhere in the
        // first four entries.  Minimal failing input under
        // truncate+zero: [0, 3] is not reachable from position 1, but
        // the tail after the last needed entry must go, and every entry
        // not needed for failure must end up 0.
        let fails = |c: &[u32]| c.iter().take(4).any(|&x| x == 3);
        let start = vec![7, 3, 9, 1, 5, 5, 5, 5, 5, 5];
        let min = shrink_choices(start, 1000, fails);
        assert!(fails(&min), "shrinking must preserve failure");
        assert!(min.len() <= 2, "tail not truncated: {min:?}");
        assert_eq!(min.iter().filter(|&&x| x != 0).count(), 1, "only the 3 should survive: {min:?}");
    }

    #[test]
    fn shrink_respects_budget() {
        let mut calls = 0usize;
        let min = shrink_choices(vec![1; 64], 5, |_| {
            calls += 1;
            true
        });
        assert!(calls <= 5);
        assert!(!min.is_empty() || calls <= 5);
    }

    #[test]
    fn shrink_keeps_unshrinkable_input() {
        // Nothing but the full sequence fails: shrinking must return it
        // unchanged.
        let full = vec![2u32, 2, 2];
        let want = full.clone();
        let min = shrink_choices(full, 1000, |c| c == want.as_slice());
        assert_eq!(min, want);
    }

    #[test]
    fn cases_are_reproducible() {
        // Same config twice must exercise identical inputs.
        let mut first = Vec::new();
        run(Config::cases(8).with_seed(7), |rng| {
            let _ = rng.next_u64(); // burn one to make it non-trivial
        });
        run(Config::cases(8).with_seed(7), |rng| {
            first.push(rng.next_u64());
        });
        let mut second = Vec::new();
        run(Config::cases(8).with_seed(7), |rng| {
            second.push(rng.next_u64());
        });
        assert_eq!(first, second);
    }
}
