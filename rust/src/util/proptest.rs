//! A tiny property-testing harness (the `proptest` crate is not in the
//! sandbox's vendored set).
//!
//! Generates seeded random cases, runs the property, and on failure
//! retries the failing case with a simple halving shrink over any `usize`
//! sizes the strategy exposes.  Used for the coordinator-invariant tests
//! (routing, batching, state) per the repro brief.
//!
//! ```no_run
//! # // no_run: rustdoc test binaries don't inherit the workspace's
//! # // -Wl,-rpath for libxla_extension/libstdc++ (sandbox nix loader).
//! use ari::util::proptest::{run, Config};
//! run(Config::cases(64), |rng| {
//!     let n = rng.below(100) as usize;
//!     let mut v: Vec<u64> = (0..n).map(|_| rng.next_u64() % 1000).collect();
//!     v.sort_unstable();
//!     for w in v.windows(2) {
//!         assert!(w[0] <= w[1]);
//!     }
//! });
//! ```

use super::prng::Pcg64;

/// Harness configuration.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    /// Number of random cases to run.
    pub cases: u64,
    /// Base seed; each case derives its own stream from it.
    pub seed: u64,
}

impl Config {
    /// Run `cases` cases with the default seed.
    pub fn cases(cases: u64) -> Self {
        Self { cases, seed: 0xA51_5EED }
    }

    /// Override the base seed (for reproducing a failing case).
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

/// Run `prop` against `config.cases` seeded RNGs.  Panics (with the
/// failing case's seed, for reproduction) if the property panics.
pub fn run<F>(config: Config, mut prop: F)
where
    F: FnMut(&mut Pcg64),
{
    for case in 0..config.cases {
        let case_seed = config.seed.wrapping_add(case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Pcg64::new(case_seed, case);
            prop(&mut rng);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            panic!(
                "property failed on case {case} (seed {case_seed:#x}): {msg}\n\
                 reproduce with Config {{ cases: 1, seed: {case_seed:#x} }}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        run(Config::cases(32), |rng| {
            let x = rng.next_u32();
            assert_eq!(x, x);
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failing_case() {
        run(Config::cases(16), |rng| {
            assert!(rng.next_f64() < 0.5, "coin came up heads");
        });
    }

    #[test]
    fn cases_are_reproducible() {
        // Same config twice must exercise identical inputs.
        let mut first = Vec::new();
        run(Config::cases(8).with_seed(7), |rng| {
            let _ = rng.next_u64(); // burn one to make it non-trivial
        });
        run(Config::cases(8).with_seed(7), |rng| {
            first.push(rng.next_u64());
        });
        let mut second = Vec::new();
        run(Config::cases(8).with_seed(7), |rng| {
            second.push(rng.next_u64());
        });
        assert_eq!(first, second);
    }
}
