//! Descriptive statistics used throughout the experiment harness.

/// Summary statistics of a sample.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    /// Sample count.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Population standard deviation.
    pub std: f64,
    /// Smallest sample.
    pub min: f64,
    /// Largest sample.
    pub max: f64,
}

impl Summary {
    /// Compute over a slice (empty slices give a zeroed summary).
    pub fn of(xs: &[f64]) -> Self {
        if xs.is_empty() {
            return Self { n: 0, mean: 0.0, std: 0.0, min: 0.0, max: 0.0 };
        }
        let n = xs.len() as f64;
        let mean = xs.iter().sum::<f64>() / n;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        Self {
            n: xs.len(),
            mean,
            std: var.sqrt(),
            min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
            max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        }
    }

    /// Compute over an f32 slice (widened to f64).
    pub fn of_f32(xs: &[f32]) -> Self {
        Self::of(&xs.iter().map(|&x| x as f64).collect::<Vec<_>>())
    }
}

/// Percentile by linear interpolation on a *sorted* slice; `q` in [0, 1].
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of empty slice");
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Percentile of an unsorted slice (copies + sorts).
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, q)
}

/// The paper's threshold rule (§III-C): given the reduced-model margins of
/// the elements whose class *changed* between reduced and full model,
/// return the margin that covers fraction `coverage` of them.
/// `coverage = 1.0` is `M_max`, `0.99` is `M_99`, `0.95` is `M_95`.
pub fn margin_threshold(changed_margins: &[f64], coverage: f64) -> f64 {
    if changed_margins.is_empty() {
        // No element changes class: any threshold works; 0 accepts all.
        return 0.0;
    }
    percentile(changed_margins, coverage)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.std - (1.25f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_empty() {
        assert_eq!(Summary::of(&[]).n, 0);
    }

    #[test]
    fn percentile_endpoints() {
        let xs = [3.0, 1.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 3.0);
        assert_eq!(percentile(&xs, 0.5), 2.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile(&xs, 0.25) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn threshold_mmax_is_max() {
        let margins = [0.1, 0.5, 0.3];
        assert_eq!(margin_threshold(&margins, 1.0), 0.5);
    }

    #[test]
    fn threshold_percentiles_ordered() {
        let margins: Vec<f64> = (0..1000).map(|i| i as f64 / 1000.0).collect();
        let m95 = margin_threshold(&margins, 0.95);
        let m99 = margin_threshold(&margins, 0.99);
        let mmax = margin_threshold(&margins, 1.0);
        assert!(m95 < m99 && m99 < mmax);
        assert!((m95 - 0.949).abs() < 0.005);
    }

    #[test]
    fn threshold_empty_is_zero() {
        assert_eq!(margin_threshold(&[], 1.0), 0.0);
    }
}
