//! Small self-contained utilities the rest of the crate builds on.
//!
//! The sandbox's vendored crate set has no `rand`, `serde`, `toml` or
//! `proptest`, so this module carries minimal, well-tested replacements:
//! a PCG-family PRNG, descriptive statistics, a streaming histogram, a
//! line-oriented mini-TOML parser, a persistent parked worker pool, a
//! bounded blocking queue, a runtime fault-injection registry
//! ([`fault`]), a tiny property-testing harness and a
//! deterministic-interleaving scheduler ([`sim`]) the concurrency
//! primitives are checked under.

pub mod benchkit;
pub mod fault;
pub mod histogram;
pub mod minitoml;
pub mod pool;
pub mod prng;
pub mod proptest;
pub mod queue;
pub mod sim;
pub mod stats;

pub use histogram::Histogram;
pub use prng::Pcg64;
pub use stats::Summary;
