//! Runtime fault injection: a process-wide registry of injectable
//! faults, armed per *fault point* with a probability, an optional
//! remaining-shot count and a deterministic per-point PRNG stream.
//!
//! Unlike the test-only [`crate::util::sim::fault`] switches (which are
//! compiled out of release builds and re-introduce *specific historical
//! bugs*), this registry is always compiled and injects *generic
//! environmental* faults — backend errors, panics, latency spikes,
//! queue stalls, worker death, and wire faults (connection drops,
//! truncated/corrupted frames, split writes, accept stalls) — so the
//! serving pipeline's recovery paths (retry, supervision, degradation,
//! watchdog, protocol-error close) can be exercised from tests,
//! benches, chaos CI and the `ari serve --faults` flag.
//!
//! The disarmed fast path is a single relaxed atomic load ([`armed`]),
//! so instrumented hot paths cost nothing in normal operation.
//!
//! # Spec grammar
//!
//! ```text
//! spec    := point[:prob[:count]] ("," point[:prob[:count]])* ["@" seed]
//! point   := one of the names in [`POINTS`]
//! prob    := f64 in [0, 1]      (default 1.0)
//! count   := u64 max injections (default unlimited)
//! seed    := u64 PRNG seed      (default 0)
//! ```
//!
//! Example: `exec-error:0.05,worker-death:1.0:2@42` — 5% of backend
//! executions fail, and the first two worker-death draws kill their
//! worker, all decided by streams seeded from 42.
//!
//! `ARI_FAULTS` (see [`arm_from_env`]) accepts either a bare seed —
//! arming the canonical chaos schedule ([`chaos_spec`]) used by the CI
//! `chaos` job — or a full spec string.

use std::sync::atomic::{AtomicUsize, Ordering};
// ari-lint: allow(sim-discipline): the registry statics need const-init `Mutex::new`,
// which `sim::Mutex` does not provide; injection sites already run under the sim
// scheduler, so wrapping the registry would only add unmodelled scheduling points.
use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

use super::prng::Pcg64;
use crate::Result;
use anyhow::{bail, ensure};

/// Fault point: [`crate::runtime::NativeBackend::execute`] returns a
/// typed error (transient — the dispatcher retries it).
pub const EXEC_ERROR: &str = "exec-error";
/// Fault point: `execute` panics mid-batch (converted to a retryable
/// error by the dispatcher's panic shield).
pub const EXEC_PANIC: &str = "exec-panic";
/// Fault point: `execute` sleeps [`STALL`] before running — an
/// artificial latency spike that drives the overload detector.
pub const EXEC_DELAY: &str = "exec-delay";
/// Fault point: a [`crate::util::queue::BoundedQueue`] operation sleeps
/// [`STALL`] before taking the lock — a bounded pipeline hiccup.
pub const QUEUE_STALL: &str = "queue-stall";
/// Fault point: a parked [`crate::util::pool`] worker exits its loop as
/// if its thread died; the pool supervisor respawns it.
pub const WORKER_DEATH: &str = "worker-death";
/// Fault point: the server's batching loop stops staging work (a *true*
/// stall — only the watchdog can convert it into a diagnostic failure,
/// so it is never part of [`chaos_spec`]).
pub const BATCH_STALL: &str = "batch-stall";
/// Fault point: the net front-end abruptly closes an accepted TCP
/// connection before reading — an IoT node vanishing mid-session.
/// Requests already admitted from that connection still complete; their
/// responses are counted as dropped-on-dead-connection, never lost.
pub const CONN_DROP: &str = "conn-drop";
/// Fault point: a connection's outbound stream is cut mid-frame (half a
/// response is written, then the socket dies) — the peer must surface a
/// typed `Truncated` protocol error, not a hang or a panic.
pub const FRAME_TRUNC: &str = "frame-trunc";
/// Fault point: one bit of a freshly read inbound byte is flipped before
/// decoding — wire corruption.  The decoder must return a typed
/// protocol error (or an honestly different valid frame), never panic.
pub const FRAME_CORRUPT: &str = "frame-corrupt";
/// Fault point: an outbound flush writes at most a few bytes — a
/// congested peer — forcing the frame reassembly and write-backpressure
/// paths instead of the common whole-frame write.
pub const WRITE_SPLIT: &str = "write-split";
/// Fault point: the accept path sleeps [`STALL`] before polling the
/// listener — connection setup latency that exercises client
/// reconnect-with-backoff.
pub const ACCEPT_STALL: &str = "accept-stall";
/// Fault point: a staged batch's feature rows are perturbed in place
/// (the affine shift of [`drift_rows`]) just before dispatch — input
/// distribution drift, the environment the control loop's drift
/// monitor and online recalibration exist to absorb
/// (`docs/ROBUSTNESS.md`, "Control loop").
pub const DRIFT_SHIFT: &str = "drift-shift";

/// Every fault point the runtime defines; [`arm_spec`] rejects names
/// outside this list so typos fail loudly instead of arming nothing.
pub const POINTS: &[&str] = &[
    EXEC_ERROR,
    EXEC_PANIC,
    EXEC_DELAY,
    QUEUE_STALL,
    WORKER_DEATH,
    BATCH_STALL,
    CONN_DROP,
    FRAME_TRUNC,
    FRAME_CORRUPT,
    WRITE_SPLIT,
    ACCEPT_STALL,
    DRIFT_SHIFT,
];

/// The in-place perturbation a [`DRIFT_SHIFT`] hit applies to a staged
/// batch's feature rows: a fixed affine shift, strong enough to move
/// reduced-stage margins visibly but not to turn every prediction into
/// noise (the escalation ladder must still be able to rescue accuracy).
pub fn drift_rows(x: &mut [f32]) {
    for v in x {
        *v = *v * 1.15 + 0.1;
    }
}

/// Duration of an injected [`EXEC_DELAY`] / [`QUEUE_STALL`] hiccup.
/// Long enough to back the pipeline up behind a 2-slot staging queue,
/// short enough that a chaos run still terminates promptly.
pub const STALL: Duration = Duration::from_millis(2);

/// Number of armed fault points; 0 keeps [`inject`] on its one-load
/// fast path.
static ARMED: AtomicUsize = AtomicUsize::new(0);

struct Arm {
    point: &'static str,
    prob: f64,
    /// Remaining injections, `None` = unlimited.
    remaining: Option<u64>,
    rng: Pcg64,
}

static REGISTRY: Mutex<Vec<Arm>> = Mutex::new(Vec::new());

/// Serialises [`ArmGuard`] holders: the registry is process-wide state,
/// so concurrently-armed tests would see each other's faults.
static SERIAL: Mutex<()> = Mutex::new(());

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    // The registry holds plain data (no invariants spanning a panic),
    // so a poisoned lock is safe to recover.
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// True when any fault point is armed.  One relaxed atomic load — this
/// is the hot-path gate instrumented code checks before calling
/// [`inject`].
#[inline]
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed) != 0
}

/// Draw the armed fault at `point`: returns `true` when the caller
/// should inject its failure.  Decrements the arm's remaining-shot
/// count on a hit.  Always `false` for unarmed points and after the
/// count is exhausted.
pub fn inject(point: &str) -> bool {
    if !armed() {
        return false;
    }
    let mut reg = lock(&REGISTRY);
    let Some(arm) = reg.iter_mut().find(|a| a.point == point) else {
        return false;
    };
    if arm.remaining == Some(0) {
        return false;
    }
    if arm.rng.next_f64() >= arm.prob {
        return false;
    }
    if let Some(n) = &mut arm.remaining {
        *n -= 1;
    }
    true
}

/// Parse and arm `spec` (grammar in the module docs), replacing any
/// previously armed schedule.  Rejects unknown point names, malformed
/// numbers and probabilities outside `[0, 1]`.
pub fn arm_spec(spec: &str) -> Result<()> {
    let (points, seed) = match spec.rsplit_once('@') {
        Some((p, s)) => {
            let seed = parse_u64(s).map_err(|_| anyhow::anyhow!("bad fault seed {s:?} in spec {spec:?}"))?;
            (p, seed)
        }
        None => (spec, 0),
    };
    let mut arms = Vec::new();
    for (i, part) in points.split(',').enumerate() {
        let part = part.trim();
        ensure!(!part.is_empty(), "empty fault point in spec {spec:?}");
        let mut fields = part.split(':');
        let name = fields.next().unwrap_or_default();
        let Some(&point) = POINTS.iter().find(|&&p| p == name) else {
            bail!("unknown fault point {name:?} (known: {})", POINTS.join(", "));
        };
        let prob = match fields.next() {
            Some(p) => p.parse::<f64>().map_err(|_| anyhow::anyhow!("bad probability {p:?} for {name}"))?,
            None => 1.0,
        };
        ensure!((0.0..=1.0).contains(&prob), "probability {prob} for {name} outside [0, 1]");
        let remaining = match fields.next() {
            Some(c) => Some(parse_u64(c).map_err(|_| anyhow::anyhow!("bad count {c:?} for {name}"))?),
            None => None,
        };
        ensure!(fields.next().is_none(), "too many `:` fields in {part:?}");
        // Independent stream per arm position: same seed, different
        // draws per point, deterministic replay for a given spec.
        arms.push(Arm { point, prob, remaining, rng: Pcg64::new(seed, i as u64 + 1) });
    }
    let mut reg = lock(&REGISTRY);
    ARMED.store(arms.len(), Ordering::Relaxed);
    *reg = arms;
    Ok(())
}

fn parse_u64(s: &str) -> std::result::Result<u64, std::num::ParseIntError> {
    let s = s.trim();
    match s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
        Some(hex) => u64::from_str_radix(hex, 16),
        None => s.parse(),
    }
}

/// Disarm every fault point and clear the registry.
pub fn disarm_all() {
    let mut reg = lock(&REGISTRY);
    ARMED.store(0, Ordering::Relaxed);
    reg.clear();
}

/// The canonical chaos schedule for a given seed: every *recoverable*
/// fault point at a small probability ([`BATCH_STALL`] excluded — a
/// true stall is a watchdog test, not a survivable environment).  Used
/// by the CI `chaos` job via `ARI_FAULTS=<seed>`.
///
/// The network points ride along count-limited: their injection sites
/// live only in `server::net`, so an in-process session never draws
/// them, while the loopback-TCP chaos leg gets a bounded number of
/// drops/truncations/corruptions plus a persistent low-probability
/// write-split — enough to exercise every wire recovery path without
/// turning the session into a reconnect storm.
pub fn chaos_spec(seed: u64) -> String {
    format!(
        "{EXEC_ERROR}:0.02,{EXEC_PANIC}:0.005,{EXEC_DELAY}:0.05,{QUEUE_STALL}:0.02,{WORKER_DEATH}:1.0:2,\
         {CONN_DROP}:1.0:2,{FRAME_TRUNC}:1.0:1,{FRAME_CORRUPT}:1.0:2,{WRITE_SPLIT}:0.05,{ACCEPT_STALL}:1.0:2,\
         {DRIFT_SHIFT}:0.02@{seed}"
    )
}

/// Arm from a user-facing value (`--faults` / `ARI_FAULTS`): a bare
/// integer arms [`chaos_spec`] with that seed, anything else is parsed
/// as a full spec.  Returns the normalised spec that was armed
/// (callers echo it so a failing run can be replayed exactly).
pub fn arm_value(raw: &str) -> Result<String> {
    let raw = raw.trim();
    let spec = match parse_u64(raw) {
        Ok(seed) => chaos_spec(seed),
        Err(_) => raw.to_string(),
    };
    arm_spec(&spec)?;
    Ok(spec)
}

/// Arm from the `ARI_FAULTS` environment variable, if set (see
/// [`arm_value`] for the accepted forms).
pub fn arm_from_env() -> Result<Option<String>> {
    let Ok(raw) = std::env::var("ARI_FAULTS") else {
        return Ok(None);
    };
    if raw.trim().is_empty() {
        return Ok(None);
    }
    arm_value(&raw).map(Some)
}

/// RAII arming for tests: holds a process-wide serial lock (so
/// concurrently-running tests cannot see each other's faults), arms
/// `spec`, and disarms everything on drop.
pub struct ArmGuard {
    _serial: MutexGuard<'static, ()>,
}

impl ArmGuard {
    /// Serialise, then arm `spec`.  Panics on a malformed spec — tests
    /// should fail loudly, not silently run fault-free.
    pub fn arm(spec: &str) -> Self {
        let serial = lock(&SERIAL);
        arm_spec(spec).expect("invalid fault spec");
        ArmGuard { _serial: serial }
    }
}

impl Drop for ArmGuard {
    fn drop(&mut self) {
        disarm_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disarmed_by_default_and_fast_path_false() {
        let _g = ArmGuard::arm(EXEC_DELAY); // serialise with other fault tests
        disarm_all();
        assert!(!armed());
        assert!(!inject(EXEC_ERROR));
    }

    #[test]
    fn certain_fault_fires_and_count_exhausts() {
        let _g = ArmGuard::arm("exec-error:1.0:2");
        assert!(inject(EXEC_ERROR));
        assert!(inject(EXEC_ERROR));
        assert!(!inject(EXEC_ERROR), "count must exhaust after two shots");
        assert!(!inject(EXEC_PANIC), "unarmed points never fire");
    }

    #[test]
    fn zero_probability_never_fires() {
        let _g = ArmGuard::arm("worker-death:0.0");
        for _ in 0..100 {
            assert!(!inject(WORKER_DEATH));
        }
    }

    #[test]
    fn seeded_draws_are_deterministic() {
        let draw = |spec: &str| {
            let _g = ArmGuard::arm(spec);
            (0..64).map(|_| inject(EXEC_DELAY)).collect::<Vec<bool>>()
        };
        let a = draw("exec-delay:0.5@7");
        let b = draw("exec-delay:0.5@7");
        let c = draw("exec-delay:0.5@8");
        assert_eq!(a, b, "same spec must replay identically");
        assert_ne!(a, c, "different seeds must differ");
        assert!(a.iter().any(|&x| x) && a.iter().any(|&x| !x), "p=0.5 should mix over 64 draws");
    }

    #[test]
    fn bad_specs_rejected() {
        let _g = ArmGuard::arm("exec-delay:0.0"); // serialise with other fault tests
        disarm_all();
        for bad in ["nope", "exec-error:2.0", "exec-error:0.5:x", "exec-error:0.5:1:9", "", "exec-error@zz"] {
            assert!(arm_spec(bad).is_err(), "spec {bad:?} must be rejected");
        }
        assert!(!armed(), "failed arming must not leave faults armed");
    }

    #[test]
    fn chaos_spec_round_trips_and_guard_disarms() {
        {
            let _g = ArmGuard::arm(&chaos_spec(42));
            assert!(armed());
        }
        assert!(!armed(), "guard drop must disarm");
    }
}
