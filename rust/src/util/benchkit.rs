//! Minimal benchmarking kit for the `harness = false` bench targets
//! (criterion is not in the sandbox's vendored crate set).
//!
//! Measures wall time over warmup + timed iterations and prints one
//! aligned row per case, criterion-style: mean ± std, plus derived
//! throughput when the caller provides an items-per-iteration count.
//!
//! Machine-readable output: a [`JsonReport`] collects results and, when
//! the `ARI_BENCH_JSON` environment variable names a path, writes the
//! `ari-bench v1` JSON document there (ns/sample and samples/s per
//! case) — `make bench-json` drives this to record the perf trajectory
//! in `BENCH_native.json`.  `ARI_BENCH_SMOKE=1` shrinks iteration
//! counts for CI smoke runs (see [`iters`]).

use std::time::Instant;

/// One benchmark case result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Case name, as printed.
    pub name: String,
    /// Mean wall time per iteration (ns).
    pub mean_ns: f64,
    /// Standard deviation over timed iterations (ns).
    pub std_ns: f64,
    /// Number of timed iterations.
    pub iters: usize,
}

/// Run `f` for `warmup + iters` iterations, timing the last `iters`.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    let mean = samples.iter().sum::<f64>() / iters as f64;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / iters as f64;
    BenchResult { name: name.to_string(), mean_ns: mean, std_ns: var.sqrt(), iters }
}

fn human_time(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.2} s", ns / 1_000_000_000.0)
    }
}

impl BenchResult {
    /// Print `name  mean ± std  [throughput]`.
    pub fn report(&self, items_per_iter: Option<(u64, &str)>) {
        let mut line = format!("{:<44} {:>12} ± {:<10}", self.name, human_time(self.mean_ns), human_time(self.std_ns));
        if let Some((items, unit)) = items_per_iter {
            let per_sec = items as f64 / (self.mean_ns / 1e9);
            line.push_str(&format!("  {per_sec:>12.0} {unit}/s"));
        }
        println!("{line}");
    }
}

/// Print a bench section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// True when `ARI_BENCH_SMOKE` is set (non-empty, not `0`): benches
/// should run short smoke iterations.
pub fn smoke() -> bool {
    std::env::var("ARI_BENCH_SMOKE").map(|v| !v.is_empty() && v != "0").unwrap_or(false)
}

/// `(warmup, iters)` to use: the caller's defaults, shrunk to `(1, 2)`
/// under [`smoke`].
pub fn iters(warmup: usize, iters: usize) -> (usize, usize) {
    if smoke() {
        (1, iters.min(2).max(1))
    } else {
        (warmup, iters)
    }
}

/// One recorded case of a [`JsonReport`].
#[derive(Clone, Debug)]
pub struct JsonEntry {
    /// Case name.
    pub name: String,
    /// Mean wall time per iteration (ns).
    pub mean_ns: f64,
    /// Standard deviation over timed iterations (ns).
    pub std_ns: f64,
    /// Timed iterations.
    pub iters: usize,
    /// Items (samples/elements) processed per iteration, if meaningful.
    pub items_per_iter: Option<u64>,
    /// Extra numeric fields rendered verbatim as additional JSON keys on
    /// the entry (e.g. a serving run's robustness counters).
    pub extras: Vec<(String, f64)>,
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.3}")
    } else {
        "null".to_string()
    }
}

impl JsonEntry {
    fn render(&self) -> String {
        let (per_item, per_sec) = match self.items_per_iter {
            Some(n) if n > 0 && self.mean_ns > 0.0 => (
                json_f64(self.mean_ns / n as f64),
                json_f64(n as f64 / (self.mean_ns / 1e9)),
            ),
            _ => ("null".to_string(), "null".to_string()),
        };
        let items = self.items_per_iter.map_or("null".to_string(), |n| n.to_string());
        let mut extras = String::new();
        for (k, v) in &self.extras {
            extras.push_str(&format!(",\"{}\":{}", json_escape(k), json_f64(*v)));
        }
        format!(
            "{{\"name\":\"{}\",\"mean_ns\":{},\"std_ns\":{},\"iters\":{},\"items_per_iter\":{items},\"ns_per_item\":{per_item},\"items_per_sec\":{per_sec}{extras}}}",
            json_escape(&self.name),
            json_f64(self.mean_ns),
            json_f64(self.std_ns),
            self.iters,
        )
    }
}

/// Machine-readable bench collector: every recorded case becomes one
/// entry of the `ari-bench v1` JSON document.
pub struct JsonReport {
    /// Bench binary name (document header).
    pub bench: String,
    entries: Vec<JsonEntry>,
}

impl JsonReport {
    /// Empty report for one bench binary.
    pub fn new(bench: &str) -> Self {
        Self { bench: bench.to_string(), entries: Vec::new() }
    }

    /// Record one result (items per iteration as in
    /// [`BenchResult::report`]).
    pub fn add(&mut self, r: &BenchResult, items_per_iter: Option<u64>) {
        self.entries.push(JsonEntry {
            name: r.name.clone(),
            mean_ns: r.mean_ns,
            std_ns: r.std_ns,
            iters: r.iters,
            items_per_iter,
            extras: Vec::new(),
        });
    }

    /// Print the human row *and* record it — the one-liner bench mains
    /// use for every case.
    pub fn record(&mut self, r: &BenchResult, items_per_iter: Option<(u64, &'static str)>) {
        r.report(items_per_iter);
        self.add(r, items_per_iter.map(|(n, _)| n));
    }

    /// [`add`](Self::add), plus extra numeric fields appended to the
    /// entry's JSON object — `bench_serve` attaches each session's
    /// robustness counters (degraded/rejected/failed/retries) this way.
    pub fn add_extra(&mut self, r: &BenchResult, items_per_iter: Option<u64>, extras: &[(&str, f64)]) {
        self.add(r, items_per_iter);
        let entry = self.entries.last_mut().expect("add just pushed an entry");
        entry.extras = extras.iter().map(|(k, v)| (k.to_string(), *v)).collect();
    }

    /// The full JSON document.  The header records the active SIMD
    /// dispatch path (`simd`) next to `max_threads`, so paired
    /// default/`ARI_SIMD=0` runs of the same bench are distinguishable
    /// in `BENCH_native.json` and the per-commit SIMD delta can be read
    /// off the artifact.
    pub fn render(&self) -> String {
        let entries: Vec<String> = self.entries.iter().map(|e| e.render()).collect();
        format!(
            "{{\"schema\":\"ari-bench v1\",\"bench\":\"{}\",\"max_threads\":{},\"simd\":\"{}\",\"smoke\":{},\"entries\":[{}]}}\n",
            json_escape(&self.bench),
            crate::util::pool::max_threads(),
            crate::tensor::active_backend().name(),
            smoke(),
            entries.join(",")
        )
    }

    /// Write the document to the path named by `ARI_BENCH_JSON`, if set.
    /// Returns the path written to.  Bench mains call this last.
    ///
    /// # Panics
    ///
    /// Panics (failing the bench run, and with it the CI step) if the
    /// caller asked for JSON output but the write fails — a perf record
    /// silently missing is worse than a loud bench failure.
    pub fn write_if_requested(&self) -> Option<std::path::PathBuf> {
        let path = std::path::PathBuf::from(std::env::var_os("ARI_BENCH_JSON")?);
        match std::fs::write(&path, self.render()) {
            Ok(()) => {
                println!("\n[benchkit] wrote {} entries to {}", self.entries.len(), path.display());
                Some(path)
            }
            Err(e) => panic!("[benchkit] failed to write requested {}: {e}", path.display()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.mean_ns > 0.0);
        assert_eq!(r.iters, 5);
    }

    #[test]
    fn human_time_units() {
        assert!(human_time(500.0).contains("ns"));
        assert!(human_time(5_000.0).contains("µs"));
        assert!(human_time(5_000_000.0).contains("ms"));
        assert!(human_time(5e9).contains(" s"));
    }

    #[test]
    fn json_report_renders_schema() {
        let mut report = JsonReport::new("bench_test");
        report.add(
            &BenchResult { name: "case \"a\"".into(), mean_ns: 1000.0, std_ns: 10.0, iters: 5 },
            Some(32),
        );
        report.add(&BenchResult { name: "plain".into(), mean_ns: 250.0, std_ns: 0.0, iters: 3 }, None);
        report.add_extra(
            &BenchResult { name: "extra".into(), mean_ns: 500.0, std_ns: 0.0, iters: 1 },
            None,
            &[("degraded", 7.0), ("retries", 0.0)],
        );
        let doc = report.render();
        assert!(doc.starts_with("{\"schema\":\"ari-bench v1\""), "{doc}");
        assert!(doc.contains("\"bench\":\"bench_test\""));
        assert!(doc.contains(&format!("\"simd\":\"{}\"", crate::tensor::active_backend().name())), "{doc}");
        assert!(doc.contains("\\\"a\\\""), "quotes escaped: {doc}");
        assert!(doc.contains("\"items_per_iter\":32"));
        assert!(doc.contains("\"ns_per_item\":31.250"));
        assert!(doc.contains("\"items_per_sec\":32000000.000"));
        assert!(doc.contains("\"items_per_iter\":null"));
        assert!(doc.contains("\"degraded\":7.000"), "extras rendered: {doc}");
        assert!(doc.contains("\"retries\":0.000"), "extras rendered: {doc}");
        // Balanced braces/brackets (cheap well-formedness check).
        assert_eq!(doc.matches('{').count(), doc.matches('}').count());
        assert_eq!(doc.matches('[').count(), doc.matches(']').count());
    }

    #[test]
    fn smoke_iters_shrink() {
        // Can't set env vars safely in tests (process-global), but the
        // non-smoke path must pass defaults through.
        if !smoke() {
            assert_eq!(iters(3, 10), (3, 10));
        } else {
            assert_eq!(iters(3, 10), (1, 2));
        }
    }
}
