//! Minimal benchmarking kit for the `harness = false` bench targets
//! (criterion is not in the sandbox's vendored crate set).
//!
//! Measures wall time over warmup + timed iterations and prints one
//! aligned row per case, criterion-style: mean ± std, plus derived
//! throughput when the caller provides an items-per-iteration count.

use std::time::Instant;

/// One benchmark case result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Case name, as printed.
    pub name: String,
    /// Mean wall time per iteration (ns).
    pub mean_ns: f64,
    /// Standard deviation over timed iterations (ns).
    pub std_ns: f64,
    /// Number of timed iterations.
    pub iters: usize,
}

/// Run `f` for `warmup + iters` iterations, timing the last `iters`.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    let mean = samples.iter().sum::<f64>() / iters as f64;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / iters as f64;
    BenchResult { name: name.to_string(), mean_ns: mean, std_ns: var.sqrt(), iters }
}

fn human_time(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.2} s", ns / 1_000_000_000.0)
    }
}

impl BenchResult {
    /// Print `name  mean ± std  [throughput]`.
    pub fn report(&self, items_per_iter: Option<(u64, &str)>) {
        let mut line = format!("{:<44} {:>12} ± {:<10}", self.name, human_time(self.mean_ns), human_time(self.std_ns));
        if let Some((items, unit)) = items_per_iter {
            let per_sec = items as f64 / (self.mean_ns / 1e9);
            line.push_str(&format!("  {per_sec:>12.0} {unit}/s"));
        }
        println!("{line}");
    }
}

/// Print a bench section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", 1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.mean_ns > 0.0);
        assert_eq!(r.iters, 5);
    }

    #[test]
    fn human_time_units() {
        assert!(human_time(500.0).contains("ns"));
        assert!(human_time(5_000.0).contains("µs"));
        assert!(human_time(5_000_000.0).contains("ms"));
        assert!(human_time(5e9).contains(" s"));
    }
}
