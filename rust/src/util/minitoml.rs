//! A deliberately small TOML-subset parser for configuration files.
//!
//! Supported: `[section]` headers, `key = value` pairs with string
//! (`"..."`), integer, float, boolean and flat array (`[1, 2, 3]`)
//! values, `#` comments and blank lines.  This covers everything the ARI
//! configs need; the full `toml`/`serde` stack is not in the sandbox's
//! vendored crate set (DESIGN.md §7).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    /// A quoted string.
    Str(String),
    /// An integer.
    Int(i64),
    /// A float.
    Float(f64),
    /// A boolean.
    Bool(bool),
    /// A flat array of values.
    Array(Vec<Value>),
}

impl Value {
    /// The string payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    /// The integer payload, if this is an integer.
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    /// The numeric payload as f64 (integers promote).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    /// The boolean payload, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// The array payload, if this is an array.
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Str(s) => write!(f, "\"{s}\""),
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => write!(f, "{x}"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Array(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
        }
    }
}

/// Parse error with line information.
#[derive(Debug)]
pub struct ParseError {
    /// 1-based line number of the offending line.
    pub line: usize,
    /// What went wrong.
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "minitoml parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// A parsed document: section name -> key -> value.  Keys outside any
/// section land in the "" section.
#[derive(Clone, Debug, Default)]
pub struct Doc {
    /// Section name -> key -> value.
    pub sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Doc {
    /// Parse a document from text.
    pub fn parse(text: &str) -> Result<Self, ParseError> {
        let mut doc = Doc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let Some((key, val)) = line.split_once('=') else {
                return Err(ParseError { line: lineno + 1, msg: format!("expected key = value, got {line:?}") });
            };
            let value = parse_value(val.trim()).map_err(|msg| ParseError { line: lineno + 1, msg })?;
            doc.sections.entry(section.clone()).or_default().insert(key.trim().to_string(), value);
        }
        Ok(doc)
    }

    /// Look up a value (`""` is the top-level section).
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    /// Typed lookup: string.
    pub fn get_str(&self, section: &str, key: &str) -> Option<&str> {
        self.get(section, key)?.as_str()
    }

    /// Typed lookup: integer.
    pub fn get_int(&self, section: &str, key: &str) -> Option<i64> {
        self.get(section, key)?.as_int()
    }

    /// Typed lookup: float (integers promote).
    pub fn get_float(&self, section: &str, key: &str) -> Option<f64> {
        self.get(section, key)?.as_float()
    }

    /// Typed lookup: boolean.
    pub fn get_bool(&self, section: &str, key: &str) -> Option<bool> {
        self.get(section, key)?.as_bool()
    }
}

fn strip_comment(line: &str) -> &str {
    // A '#' inside a quoted string does not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = s.strip_prefix('"') {
        let Some(inner) = inner.strip_suffix('"') else {
            return Err(format!("unterminated string: {s:?}"));
        };
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let Some(inner) = inner.strip_suffix(']') else {
            return Err(format!("unterminated array: {s:?}"));
        };
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Array(vec![]));
        }
        return inner.split(',').map(|p| parse_value(p.trim())).collect::<Result<Vec<_>, _>>().map(Value::Array);
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("cannot parse value: {s:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic_document() {
        let doc = Doc::parse(
            r#"
# global
name = "ari"
[server]
port = 8080          # inline comment
rate = 2.5
verbose = true
lens = [64, 128, 256]
"#,
        )
        .unwrap();
        assert_eq!(doc.get_str("", "name"), Some("ari"));
        assert_eq!(doc.get_int("server", "port"), Some(8080));
        assert_eq!(doc.get_float("server", "rate"), Some(2.5));
        assert_eq!(doc.get_bool("server", "verbose"), Some(true));
        let lens = doc.get("server", "lens").unwrap().as_array().unwrap();
        assert_eq!(lens.len(), 3);
        assert_eq!(lens[0].as_int(), Some(64));
    }

    #[test]
    fn int_promotes_to_float() {
        let doc = Doc::parse("x = 3").unwrap();
        assert_eq!(doc.get_float("", "x"), Some(3.0));
    }

    #[test]
    fn hash_inside_string_kept() {
        let doc = Doc::parse("s = \"a#b\"").unwrap();
        assert_eq!(doc.get_str("", "s"), Some("a#b"));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = Doc::parse("ok = 1\nbroken").unwrap_err();
        assert_eq!(err.line, 2);
    }

    #[test]
    fn rejects_bad_values() {
        assert!(Doc::parse("x = nope").is_err());
        assert!(Doc::parse("x = \"unterminated").is_err());
        assert!(Doc::parse("x = [1, 2").is_err());
    }

    #[test]
    fn empty_array() {
        let doc = Doc::parse("a = []").unwrap();
        assert_eq!(doc.get("", "a").unwrap().as_array().unwrap().len(), 0);
    }

    #[test]
    fn display_roundtrip() {
        let doc = Doc::parse("a = [1, 2.5, \"x\", true]").unwrap();
        assert_eq!(doc.get("", "a").unwrap().to_string(), "[1, 2.5, \"x\", true]");
    }
}
