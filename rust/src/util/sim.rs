//! Deterministic-interleaving test harness for the serving core's
//! concurrency ("model checking by schedule").
//!
//! The serving runtime's threads synchronise through `Mutex`, `Condvar`,
//! thread spawn/join and timed waits.  This module wraps exactly those
//! primitives behind a compile-time switch:
//!
//! * **Release builds** (`cargo build --release`, no `sim` feature): the
//!   wrappers are literal re-exports of `std::sync` and the hook
//!   functions are empty `#[inline(always)]` stubs — zero overhead, zero
//!   behaviour change (see `docs/PERF.md`).
//! * **Dev/test builds** (`debug_assertions`) or `--features sim`: the
//!   wrappers participate in a **token-passing scheduler**.  All sim
//!   threads are real OS threads, but exactly one holds the run token at
//!   a time; every lock acquisition, condvar wait/notify, spawn, join
//!   and explicit [`yield_point`] is a *scheduling point* where the
//!   harness picks which thread runs next.  The pick sequence is driven
//!   by a [`ChoiceSource`]: exhaustive DFS over all interleavings
//!   ([`check_exhaustive`]), seeded random schedules
//!   ([`check_random`]), or replay of a recorded choice list.
//!
//! Timed waits use **virtual time**: a `u64` nanosecond clock that only
//! advances when no thread is runnable, so batcher deadlines fire
//! deterministically and a model that sleeps five virtual seconds runs
//! in microseconds ([`sleep`], [`vnow`]).
//!
//! Failure handling: an assertion failure in any sim thread, a detected
//! deadlock (no runnable thread, no pending timeout) or a livelock
//! (step bound exceeded) **aborts the schedule**: every parked thread is
//! woken with a private unwind token, the harness reports the failure,
//! and [`check_random`] prints a one-line `ARI_REPLAY=<seed>`
//! reproduction string and a shrunk choice sequence.
//!
//! The module also carries two small test-only side channels used by the
//! model suites: [`probe`] (thread-local event capture, e.g. which SC
//! chunk keys the dispatcher drew) and [`fault`] (named test-only
//! mutations that re-introduce historical bugs so the suites can prove
//! they would catch them).  See `docs/TESTING.md` for the yield-point
//! map and a how-to.

#[cfg(any(debug_assertions, feature = "sim"))]
mod imp {
    use crate::util::prng::Pcg64;
    use std::any::Any;
    use std::cell::RefCell;
    use std::collections::VecDeque;
    use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
    use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
    use std::sync::{Arc, Condvar as StdCondvar, LockResult, Mutex as StdMutex, Once, PoisonError, TryLockError};
    use std::time::Duration;

    /// Default per-schedule scheduler-step bound (livelock guard).
    const DEFAULT_MAX_STEPS: u64 = 200_000;

    /// Whether the sim hooks are compiled in (true in dev/test builds
    /// and under `--features sim`; the release stub returns false).
    pub fn hooks_enabled() -> bool {
        true
    }

    // ------------------------------------------------------------------
    // Scheduler core
    // ------------------------------------------------------------------

    /// Private unwind payload used to tear parked threads out of an
    /// aborted schedule.  Swallowed by the harness, never user-visible.
    struct SimAbort;

    fn unwind_abort() -> ! {
        resume_unwind(Box::new(SimAbort))
    }

    #[derive(Clone, Copy, PartialEq, Eq, Debug)]
    enum RunState {
        Runnable,
        BlockedMutex(usize),
        BlockedCv { addr: usize, deadline: Option<u64> },
        BlockedJoin(usize),
        Finished,
    }

    struct Slot {
        state: RunState,
        timed_out: bool,
        name: String,
    }

    /// How the scheduler resolves each nondeterministic choice.
    enum ChoiceSource {
        /// Seeded random pick at every choice point.
        Random(Pcg64),
        /// DFS: follow `prefix`, then always pick 0 (first enabled).
        Exhaustive { prefix: Vec<u32>, depth: usize },
        /// Replay a recorded choice list (0 / clamped past the end).
        Replay { choices: Vec<u32>, pos: usize },
    }

    impl ChoiceSource {
        fn next(&mut self, n: u32) -> u32 {
            match self {
                ChoiceSource::Random(rng) => rng.below(n as u64) as u32,
                ChoiceSource::Exhaustive { prefix, depth } => {
                    let c = if *depth < prefix.len() { prefix[*depth].min(n - 1) } else { 0 };
                    *depth += 1;
                    c
                }
                ChoiceSource::Replay { choices, pos } => {
                    let c = choices.get(*pos).copied().unwrap_or(0).min(n - 1);
                    *pos += 1;
                    c
                }
            }
        }
    }

    struct Sched {
        slots: Vec<Slot>,
        /// Index of the token holder (`usize::MAX`: none).
        current: usize,
        choices: ChoiceSource,
        /// Every resolved choice with more than one option, as
        /// `(choice, n_options)` — the schedule's replayable identity.
        record: Vec<(u32, u32)>,
        /// Virtual clock, nanoseconds.  Advances only when nothing is
        /// runnable and a timed waiter exists.
        vnow: u64,
        steps: u64,
        max_steps: u64,
        /// Spawned child OS threads that have not exited yet.
        live: usize,
        diag: Option<String>,
        payload: Option<Box<dyn Any + Send>>,
    }

    struct SimShared {
        sched: StdMutex<Sched>,
        cv: StdCondvar,
        abort_flag: AtomicBool,
    }

    #[derive(Clone)]
    struct SimCtx {
        shared: Arc<SimShared>,
        idx: usize,
    }

    thread_local! {
        static CURRENT: RefCell<Option<SimCtx>> = const { RefCell::new(None) };
    }

    fn ctx() -> Option<SimCtx> {
        CURRENT.with(|c| c.borrow().clone())
    }

    /// Context for *parking* operations: `None` means run the plain std
    /// primitive (no sim, or this thread is unwinding); an aborted
    /// schedule unwinds immediately instead of parking.
    fn active_ctx() -> Option<SimCtx> {
        let c = ctx()?;
        if std::thread::panicking() {
            return None;
        }
        if c.shared.abort_flag.load(Ordering::Relaxed) {
            unwind_abort();
        }
        Some(c)
    }

    fn lock_sched(shared: &SimShared) -> std::sync::MutexGuard<'_, Sched> {
        shared.sched.lock().unwrap_or_else(|e| e.into_inner())
    }

    impl SimShared {
        fn abort_locked(&self, s: &mut Sched, diag: String) {
            if s.diag.is_none() {
                s.diag = Some(diag);
            }
            self.abort_flag.store(true, Ordering::Relaxed);
            self.cv.notify_all();
        }

        fn choose(s: &mut Sched, n: usize) -> usize {
            if n <= 1 {
                return 0;
            }
            let c = s.choices.next(n as u32);
            s.record.push((c, n as u32));
            c as usize
        }

        /// Hand the token to some runnable thread, advancing virtual
        /// time if necessary.  Returns false if the schedule aborted
        /// (deadlock).
        fn schedule_next(&self, s: &mut Sched) -> bool {
            loop {
                let runnable: Vec<usize> = s
                    .slots
                    .iter()
                    .enumerate()
                    .filter(|(_, sl)| sl.state == RunState::Runnable)
                    .map(|(i, _)| i)
                    .collect();
                if !runnable.is_empty() {
                    let pick = Self::choose(s, runnable.len());
                    s.current = runnable[pick];
                    self.cv.notify_all();
                    return true;
                }
                let mut min_dl: Option<u64> = None;
                for sl in &s.slots {
                    if let RunState::BlockedCv { deadline: Some(d), .. } = sl.state {
                        min_dl = Some(min_dl.map_or(d, |m: u64| m.min(d)));
                    }
                }
                if let Some(d) = min_dl {
                    s.vnow = s.vnow.max(d);
                    for sl in s.slots.iter_mut() {
                        if let RunState::BlockedCv { deadline: Some(dl), .. } = sl.state {
                            if dl <= s.vnow {
                                sl.state = RunState::Runnable;
                                sl.timed_out = true;
                            }
                        }
                    }
                    continue;
                }
                if s.slots.iter().all(|sl| sl.state == RunState::Finished) {
                    s.current = usize::MAX;
                    self.cv.notify_all();
                    return true;
                }
                let states: Vec<String> =
                    s.slots.iter().map(|sl| format!("  {}: {:?}", sl.name, sl.state)).collect();
                self.abort_locked(
                    s,
                    format!("deadlock: no runnable thread and no pending timeout\n{}", states.join("\n")),
                );
                return false;
            }
        }

        /// Park this thread in `state` until the scheduler hands it the
        /// token again.  Returns whether the wait timed out (only
        /// meaningful for `BlockedCv` with a deadline).
        fn block_on(&self, me: usize, state: RunState) -> bool {
            let mut s = lock_sched(self);
            s.slots[me].state = state;
            s.slots[me].timed_out = false;
            if !self.schedule_next(&mut s) {
                drop(s);
                unwind_abort();
            }
            loop {
                if self.abort_flag.load(Ordering::Relaxed) {
                    drop(s);
                    unwind_abort();
                }
                if s.current == me {
                    return s.slots[me].timed_out;
                }
                s = self.cv.wait(s).unwrap_or_else(|e| e.into_inner());
            }
        }

        /// Scheduling point for a running (token-holding) thread: offer
        /// the token to every runnable thread, including itself.
        fn yield_now(&self, me: usize) {
            let mut s = lock_sched(self);
            if self.abort_flag.load(Ordering::Relaxed) {
                drop(s);
                unwind_abort();
            }
            s.steps += 1;
            if s.steps > s.max_steps {
                let max = s.max_steps;
                self.abort_locked(&mut s, format!("livelock: exceeded {max} scheduler steps"));
                drop(s);
                unwind_abort();
            }
            if !self.schedule_next(&mut s) {
                drop(s);
                unwind_abort();
            }
            loop {
                if self.abort_flag.load(Ordering::Relaxed) {
                    drop(s);
                    unwind_abort();
                }
                if s.current == me {
                    return;
                }
                s = self.cv.wait(s).unwrap_or_else(|e| e.into_inner());
            }
        }

        /// A mutex was unlocked: every thread blocked on it may retry.
        fn mutex_released(&self, addr: usize) {
            let mut s = lock_sched(self);
            for sl in s.slots.iter_mut() {
                if sl.state == RunState::BlockedMutex(addr) {
                    sl.state = RunState::Runnable;
                }
            }
        }

        /// Condvar notify: wake one (scheduler's choice) or all waiters
        /// on `addr`.  No waiters means the notification is lost — real
        /// condvar semantics, which is exactly what the queue models
        /// need to be able to catch.
        fn notify_cv(&self, addr: usize, all: bool) {
            let mut s = lock_sched(self);
            let waiters: Vec<usize> = s
                .slots
                .iter()
                .enumerate()
                .filter(|(_, sl)| matches!(sl.state, RunState::BlockedCv { addr: a, .. } if a == addr))
                .map(|(i, _)| i)
                .collect();
            if waiters.is_empty() {
                return;
            }
            if all {
                for &w in &waiters {
                    s.slots[w].state = RunState::Runnable;
                    s.slots[w].timed_out = false;
                }
            } else {
                let pick = Self::choose(&mut s, waiters.len());
                let w = waiters[pick];
                s.slots[w].state = RunState::Runnable;
                s.slots[w].timed_out = false;
            }
        }

        fn join_slot(&self, me: usize, target: usize) {
            {
                let s = lock_sched(self);
                if s.slots[target].state == RunState::Finished {
                    return;
                }
            }
            let _ = self.block_on(me, RunState::BlockedJoin(target));
        }

        fn thread_exit(&self, me: usize, payload: Option<Box<dyn Any + Send>>) {
            let mut s = lock_sched(self);
            s.slots[me].state = RunState::Finished;
            for sl in s.slots.iter_mut() {
                if sl.state == RunState::BlockedJoin(me) {
                    sl.state = RunState::Runnable;
                }
            }
            if let Some(p) = payload {
                if s.payload.is_none() {
                    s.payload = Some(p);
                }
                self.abort_flag.store(true, Ordering::Relaxed);
                self.cv.notify_all();
                return;
            }
            if self.abort_flag.load(Ordering::Relaxed) {
                self.cv.notify_all();
                return;
            }
            let _ = self.schedule_next(&mut s);
        }

        fn child_exited(&self) {
            let mut s = lock_sched(self);
            s.live -= 1;
            drop(s);
            self.cv.notify_all();
        }

        /// A freshly spawned child's first wait for the token.  Returns
        /// false if the schedule aborted before it ever ran.
        fn wait_for_token_initial(&self, me: usize) -> bool {
            let mut s = lock_sched(self);
            loop {
                if self.abort_flag.load(Ordering::Relaxed) {
                    return false;
                }
                if s.current == me {
                    return true;
                }
                s = self.cv.wait(s).unwrap_or_else(|e| e.into_inner());
            }
        }
    }

    thread_local! {
        /// Depth of [`catching`] regions on this thread: panics raised
        /// inside one are handled by the raiser (e.g. the worker pool's
        /// per-job catch), so the abort hook must not kill the schedule.
        static CATCHING: std::cell::Cell<u32> = const { std::cell::Cell::new(0) };
    }

    /// `catch_unwind` that the sim abort hook knows about: a panic
    /// raised inside `f` does **not** abort the running schedule,
    /// because the caller is about to handle it.  Instrumented code
    /// whose contract is "catch the panic and keep going" (the worker
    /// pool's job runner) must catch through this, or a deliberately
    /// panicking job would tear down the whole model run.
    pub fn catching<R>(f: impl FnOnce() -> R) -> std::thread::Result<R> {
        CATCHING.with(|c| c.set(c.get() + 1));
        let r = catch_unwind(AssertUnwindSafe(f));
        CATCHING.with(|c| c.set(c.get() - 1));
        r
    }

    /// A panic in a sim thread must release every parked peer *before*
    /// the unwinding thread's destructors run (a destructor taking a
    /// lock held by a parked thread would otherwise hang for real).
    /// Installed once per process; delegates to the previous hook.
    /// Panics inside a [`catching`] region are exempt: they are caught
    /// and handled by the raiser, so the schedule keeps running.
    fn install_abort_hook() {
        static HOOK: Once = Once::new();
        HOOK.call_once(|| {
            let prev = std::panic::take_hook();
            std::panic::set_hook(Box::new(move |info| {
                if CATCHING.with(|c| c.get()) == 0 {
                    if let Some(c) = ctx() {
                        c.shared.abort_flag.store(true, Ordering::Relaxed);
                        // Take the sched lock once so no peer can be midway
                        // between its abort check and its wait.
                        drop(lock_sched(&c.shared));
                        c.shared.cv.notify_all();
                    }
                }
                prev(info);
            }));
        });
    }

    // ------------------------------------------------------------------
    // Scheduling hooks used by instrumented code
    // ------------------------------------------------------------------

    /// Explicit scheduling point.  Instrumented lock-free fast paths
    /// (e.g. the worker pool's claim loop) call this so the scheduler
    /// can interleave them; it is a no-op outside a schedule.
    pub fn yield_point() {
        if let Some(c) = active_ctx() {
            c.shared.yield_now(c.idx);
        }
    }

    /// Sleep in virtual time under a schedule (the clock jumps forward
    /// deterministically, no real delay); plain `thread::sleep`
    /// otherwise.
    pub fn sleep(dur: Duration) {
        if let Some(c) = active_ctx() {
            let deadline = {
                let s = lock_sched(&c.shared);
                s.vnow.saturating_add(dur.as_nanos() as u64)
            };
            // A per-thread pseudo-address no real condvar can collide
            // with: nothing ever notifies it, only the clock fires it.
            let addr = usize::MAX - c.idx;
            let _ = c.shared.block_on(c.idx, RunState::BlockedCv { addr, deadline: Some(deadline) });
        } else {
            std::thread::sleep(dur);
        }
    }

    /// Current virtual time in nanoseconds (0 outside a schedule).
    pub fn vnow() -> u64 {
        match ctx() {
            Some(c) => lock_sched(&c.shared).vnow,
            None => 0,
        }
    }

    // ------------------------------------------------------------------
    // Mutex / Condvar wrappers (std-compatible API surface)
    // ------------------------------------------------------------------

    /// Sim-aware mutex.  Same API subset as `std::sync::Mutex`; under a
    /// schedule every `lock` is a scheduling point and contention parks
    /// the thread in the scheduler instead of the OS.
    pub struct Mutex<T> {
        inner: StdMutex<T>,
    }

    /// Guard for [`Mutex`]; releasing it wakes sim threads blocked on
    /// the lock.
    pub struct MutexGuard<'a, T> {
        lock: &'a Mutex<T>,
        inner: Option<std::sync::MutexGuard<'a, T>>,
    }

    impl<T> Mutex<T> {
        /// New unlocked mutex.
        pub fn new(value: T) -> Self {
            Self { inner: StdMutex::new(value) }
        }

        fn addr(&self) -> usize {
            self as *const Self as *const () as usize
        }

        /// Acquire, parking in the scheduler while contended.
        pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
            if let Some(c) = active_ctx() {
                c.shared.yield_now(c.idx);
                loop {
                    match self.inner.try_lock() {
                        Ok(g) => return Ok(MutexGuard { lock: self, inner: Some(g) }),
                        Err(TryLockError::Poisoned(p)) => {
                            return Err(PoisonError::new(MutexGuard { lock: self, inner: Some(p.into_inner()) }))
                        }
                        Err(TryLockError::WouldBlock) => {
                            let _ = c.shared.block_on(c.idx, RunState::BlockedMutex(self.addr()));
                        }
                    }
                }
            }
            match self.inner.lock() {
                Ok(g) => Ok(MutexGuard { lock: self, inner: Some(g) }),
                Err(p) => Err(PoisonError::new(MutexGuard { lock: self, inner: Some(p.into_inner()) })),
            }
        }

        /// Non-blocking acquire (still a scheduling point under a
        /// schedule).
        pub fn try_lock(&self) -> Result<MutexGuard<'_, T>, TryLockError<MutexGuard<'_, T>>> {
            if let Some(c) = active_ctx() {
                c.shared.yield_now(c.idx);
            }
            match self.inner.try_lock() {
                Ok(g) => Ok(MutexGuard { lock: self, inner: Some(g) }),
                Err(TryLockError::WouldBlock) => Err(TryLockError::WouldBlock),
                Err(TryLockError::Poisoned(p)) => Err(TryLockError::Poisoned(PoisonError::new(MutexGuard {
                    lock: self,
                    inner: Some(p.into_inner()),
                }))),
            }
        }
    }

    impl<T> std::ops::Deref for MutexGuard<'_, T> {
        type Target = T;
        fn deref(&self) -> &T {
            self.inner.as_ref().expect("sim mutex guard used after release")
        }
    }

    impl<T> std::ops::DerefMut for MutexGuard<'_, T> {
        fn deref_mut(&mut self) -> &mut T {
            self.inner.as_mut().expect("sim mutex guard used after release")
        }
    }

    impl<T> Drop for MutexGuard<'_, T> {
        fn drop(&mut self) {
            if let Some(g) = self.inner.take() {
                drop(g);
                // Bookkeeping only (never parks), so it also runs while
                // unwinding or aborting — blocked peers must always
                // learn the lock was released.
                if let Some(c) = ctx() {
                    c.shared.mutex_released(self.lock.addr());
                }
            }
        }
    }

    /// Sim-aware condition variable paired with [`Mutex`].  Notify
    /// choices (which waiter wakes) are scheduling choices; a notify
    /// with no waiter is lost, exactly like the real primitive.
    pub struct Condvar {
        inner: StdCondvar,
    }

    impl Condvar {
        /// New condvar.
        pub fn new() -> Self {
            Self { inner: StdCondvar::new() }
        }

        fn addr(&self) -> usize {
            self as *const Self as *const () as usize
        }

        /// Atomically release the guard and park until notified.
        pub fn wait<'a, T>(&self, mut guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
            if let Some(c) = active_ctx() {
                let lock = guard.lock;
                drop(guard.inner.take());
                c.shared.mutex_released(lock.addr());
                drop(guard);
                let _ = c.shared.block_on(c.idx, RunState::BlockedCv { addr: self.addr(), deadline: None });
                return lock.lock();
            }
            let lock = guard.lock;
            let std_guard = guard.inner.take().expect("sim mutex guard used after release");
            match self.inner.wait(std_guard) {
                Ok(g) => Ok(MutexGuard { lock, inner: Some(g) }),
                Err(p) => Err(PoisonError::new(MutexGuard { lock, inner: Some(p.into_inner()) })),
            }
        }

        /// Timed wait; the boolean is true when the wait timed out.
        /// Under a schedule the deadline is virtual-time and fires only
        /// when nothing else is runnable.
        pub fn wait_timeout_sim<'a, T>(&self, mut guard: MutexGuard<'a, T>, dur: Duration) -> (MutexGuard<'a, T>, bool) {
            if let Some(c) = active_ctx() {
                let lock = guard.lock;
                drop(guard.inner.take());
                c.shared.mutex_released(lock.addr());
                drop(guard);
                let deadline = {
                    let s = lock_sched(&c.shared);
                    s.vnow.saturating_add(dur.as_nanos() as u64)
                };
                let timed_out =
                    c.shared.block_on(c.idx, RunState::BlockedCv { addr: self.addr(), deadline: Some(deadline) });
                let g = lock.lock().unwrap_or_else(|e| e.into_inner());
                return (g, timed_out);
            }
            let lock = guard.lock;
            let std_guard = guard.inner.take().expect("sim mutex guard used after release");
            let (g, res) = self.inner.wait_timeout(std_guard, dur).unwrap_or_else(|e| e.into_inner());
            (MutexGuard { lock, inner: Some(g) }, res.timed_out())
        }

        /// Wake one waiter (the scheduler chooses which).
        pub fn notify_one(&self) {
            if let Some(c) = ctx() {
                c.shared.notify_cv(self.addr(), false);
            }
            self.inner.notify_one();
        }

        /// Wake every waiter.
        pub fn notify_all(&self) {
            if let Some(c) = ctx() {
                c.shared.notify_cv(self.addr(), true);
            }
            self.inner.notify_all();
        }
    }

    // ------------------------------------------------------------------
    // Threads
    // ------------------------------------------------------------------

    /// Handle to a (possibly simulated) thread; join-compatible with
    /// `std::thread::JoinHandle<()>`.
    pub struct Thread {
        inner: std::thread::JoinHandle<()>,
        sim: Option<(Arc<SimShared>, usize)>,
    }

    impl Thread {
        /// Wait for the thread to finish (a scheduling point under a
        /// schedule).
        pub fn join(self) -> std::thread::Result<()> {
            if let Some((shared, target)) = &self.sim {
                if let Some(c) = active_ctx() {
                    shared.join_slot(c.idx, *target);
                }
            }
            self.inner.join()
        }
    }

    /// Spawn a named thread.  Inside a schedule the child becomes a sim
    /// thread (runnable immediately, scheduled by choice); outside it is
    /// a plain `std::thread::Builder` spawn.
    pub fn spawn_thread<F: FnOnce() + Send + 'static>(name: String, f: F) -> std::io::Result<Thread> {
        let Some(c) = active_ctx() else {
            let h = std::thread::Builder::new().name(name).spawn(f)?;
            return Ok(Thread { inner: h, sim: None });
        };
        let shared = Arc::clone(&c.shared);
        let idx = {
            let mut s = lock_sched(&shared);
            s.slots.push(Slot { state: RunState::Runnable, timed_out: false, name: name.clone() });
            s.live += 1;
            s.slots.len() - 1
        };
        let sh2 = Arc::clone(&shared);
        let res = std::thread::Builder::new().name(name).spawn(move || {
            CURRENT.with(|cur| *cur.borrow_mut() = Some(SimCtx { shared: Arc::clone(&sh2), idx }));
            let payload = if sh2.wait_for_token_initial(idx) {
                match catch_unwind(AssertUnwindSafe(f)) {
                    Ok(()) => None,
                    Err(p) => {
                        if p.downcast_ref::<SimAbort>().is_some() {
                            None
                        } else {
                            Some(p)
                        }
                    }
                }
            } else {
                None
            };
            sh2.thread_exit(idx, payload);
            sh2.child_exited();
        });
        match res {
            Ok(h) => Ok(Thread { inner: h, sim: Some((shared, idx)) }),
            Err(e) => {
                let mut s = lock_sched(&shared);
                s.slots[idx].state = RunState::Finished;
                s.live -= 1;
                Err(e)
            }
        }
    }

    /// Spawn an anonymous sim thread (model-suite convenience).
    pub fn spawn<F: FnOnce() + Send + 'static>(f: F) -> Thread {
        spawn_thread("sim".to_string(), f).expect("spawn sim thread")
    }

    // ------------------------------------------------------------------
    // Schedule runners
    // ------------------------------------------------------------------

    struct Outcome {
        failure: Option<String>,
        record: Vec<(u32, u32)>,
    }

    fn panic_message(p: &(dyn Any + Send)) -> String {
        p.downcast_ref::<String>()
            .cloned()
            .or_else(|| p.downcast_ref::<&str>().map(|s| s.to_string()))
            .unwrap_or_else(|| "<non-string panic>".to_string())
    }

    fn run_one(choices: ChoiceSource, max_steps: u64, body: &dyn Fn()) -> Outcome {
        install_abort_hook();
        let shared = Arc::new(SimShared {
            sched: StdMutex::new(Sched {
                slots: vec![Slot { state: RunState::Runnable, timed_out: false, name: "root".to_string() }],
                current: 0,
                choices,
                record: Vec::new(),
                vnow: 0,
                steps: 0,
                max_steps,
                live: 0,
                diag: None,
                payload: None,
            }),
            cv: StdCondvar::new(),
            abort_flag: AtomicBool::new(false),
        });
        CURRENT.with(|cur| *cur.borrow_mut() = Some(SimCtx { shared: Arc::clone(&shared), idx: 0 }));
        let r = catch_unwind(AssertUnwindSafe(body));
        CURRENT.with(|cur| *cur.borrow_mut() = None);
        let root_payload = match r {
            Ok(()) => None,
            Err(p) => {
                if p.downcast_ref::<SimAbort>().is_some() {
                    None
                } else {
                    Some(p)
                }
            }
        };
        shared.thread_exit(0, root_payload);
        // Wait for every child OS thread to exit (aborts release parked
        // ones).  The timeout is a harness-bug backstop, not a schedule
        // outcome.
        let mut hung = false;
        {
            let deadline = std::time::Instant::now() + Duration::from_secs(30);
            let mut s = lock_sched(&shared);
            while s.live > 0 {
                let (g, _) = shared.cv.wait_timeout(s, Duration::from_millis(100)).unwrap_or_else(|e| e.into_inner());
                s = g;
                if std::time::Instant::now() > deadline {
                    hung = true;
                    break;
                }
            }
        }
        let mut s = lock_sched(&shared);
        let mut failure = None;
        if let Some(p) = s.payload.take() {
            failure = Some(panic_message(p.as_ref()));
        } else if let Some(d) = s.diag.take() {
            failure = Some(d);
        }
        if hung {
            let base = failure.unwrap_or_else(|| "sim hung: spawned threads did not exit".to_string());
            failure = Some(format!("{base}\n(harness: timed out waiting for sim threads to exit)"));
        }
        Outcome { failure, record: std::mem::take(&mut s.record) }
    }

    /// Result of an exhaustive enumeration.
    #[derive(Clone, Copy, Debug)]
    pub struct SimReport {
        /// Schedules executed.
        pub schedules: u64,
        /// Whether the interleaving space was fully enumerated within
        /// the schedule budget.
        pub complete: bool,
    }

    /// Exhaustively enumerate every interleaving of `body` (DFS over
    /// scheduler choices), up to `max_schedules`.  Panics with the
    /// failing choice sequence on the first schedule that fails.
    pub fn check_exhaustive<F: Fn()>(max_schedules: u64, body: F) -> SimReport {
        let mut prefix: Vec<u32> = Vec::new();
        let mut schedules = 0u64;
        loop {
            let out = run_one(ChoiceSource::Exhaustive { prefix: prefix.clone(), depth: 0 }, DEFAULT_MAX_STEPS, &body);
            schedules += 1;
            if let Some(msg) = out.failure {
                let choices: Vec<u32> = out.record.iter().map(|&(c, _)| c).collect();
                panic!("model failed under exhaustive schedule {schedules} (choices {choices:?}):\n{msg}");
            }
            let mut next = None;
            for i in (0..out.record.len()).rev() {
                let (c, n) = out.record[i];
                if c + 1 < n {
                    let mut p: Vec<u32> = out.record[..i].iter().map(|&(cc, _)| cc).collect();
                    p.push(c + 1);
                    next = Some(p);
                    break;
                }
            }
            match next {
                None => return SimReport { schedules, complete: true },
                Some(_) if schedules >= max_schedules => return SimReport { schedules, complete: false },
                Some(p) => prefix = p,
            }
        }
    }

    /// Run `schedules` seeded-random schedules of `body`.  Honours the
    /// `ARI_REPLAY` environment variable (run exactly one schedule by
    /// seed); on failure prints a one-line `ARI_REPLAY=<seed>`
    /// reproduction string, shrinks the recorded choice sequence, and
    /// panics.  Returns the number of schedules run.
    pub fn check_random<F: Fn()>(schedules: u64, base_seed: u64, body: F) -> u64 {
        if let Some((seed, _)) = crate::util::proptest::replay_env() {
            eprintln!("ARI_REPLAY set: running single schedule seed {seed:#x}");
            let out = run_one(ChoiceSource::Random(Pcg64::new(seed, 0)), DEFAULT_MAX_STEPS, &body);
            if let Some(msg) = out.failure {
                panic!("model failed on replayed schedule (ARI_REPLAY={seed:#x}):\n{msg}");
            }
            return 1;
        }
        for i in 0..schedules {
            let seed = base_seed.wrapping_add(i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
            let out = run_one(ChoiceSource::Random(Pcg64::new(seed, 0)), DEFAULT_MAX_STEPS, &body);
            if let Some(msg) = out.failure {
                eprintln!("ARI_REPLAY={seed:#x}");
                let choices: Vec<u32> = out.record.iter().map(|&(c, _)| c).collect();
                let min = crate::util::proptest::shrink_choices(choices, 128, |cand| {
                    run_one(ChoiceSource::Replay { choices: cand.to_vec(), pos: 0 }, DEFAULT_MAX_STEPS, &body)
                        .failure
                        .is_some()
                });
                panic!(
                    "model failed on random schedule {i} of {schedules}\n\
                     reproduce with ARI_REPLAY={seed:#x} (env var; reruns exactly this schedule)\n\
                     minimised choice sequence: {min:?}\n{msg}"
                );
            }
        }
        schedules
    }

    /// Random-schedule budget for the model suites: `ARI_MODEL_SCHEDULES`
    /// if set (CI raises it), else `default`.
    pub fn schedule_budget(default: u64) -> u64 {
        std::env::var("ARI_MODEL_SCHEDULES").ok().and_then(|s| s.parse::<u64>().ok()).unwrap_or(default)
    }

    // ------------------------------------------------------------------
    // SimChannel: a deterministic mpsc stand-in for model tests
    // ------------------------------------------------------------------

    struct ChanState<T> {
        queue: VecDeque<T>,
        senders: usize,
    }

    struct ChanShared<T> {
        state: Mutex<ChanState<T>>,
        cv: Condvar,
    }

    /// Sending half of [`sim_channel`].
    pub struct SimSender<T> {
        shared: Arc<ChanShared<T>>,
    }

    /// Receiving half of [`sim_channel`].
    pub struct SimReceiver<T> {
        shared: Arc<ChanShared<T>>,
    }

    /// Outcome of [`SimReceiver::recv_timeout`], mirroring
    /// `mpsc::RecvTimeoutError`'s three-way split.
    #[derive(Debug, PartialEq, Eq)]
    pub enum SimRecv<T> {
        /// An item arrived.
        Item(T),
        /// The (virtual-time) timeout elapsed first.
        Timeout,
        /// Every sender is gone and the queue is drained.
        Disconnected,
    }

    /// An unbounded channel built on the sim primitives, so a model can
    /// drive the server's arrival loop under the scheduler with
    /// deterministic, virtual-time `recv_timeout` semantics.
    pub fn sim_channel<T>() -> (SimSender<T>, SimReceiver<T>) {
        let shared = Arc::new(ChanShared {
            state: Mutex::new(ChanState { queue: VecDeque::new(), senders: 1 }),
            cv: Condvar::new(),
        });
        (SimSender { shared: Arc::clone(&shared) }, SimReceiver { shared })
    }

    impl<T> SimSender<T> {
        /// Enqueue an item (never blocks).
        pub fn send(&self, item: T) {
            let mut st = self.shared.state.lock().unwrap_or_else(|e| e.into_inner());
            st.queue.push_back(item);
            drop(st);
            self.shared.cv.notify_one();
        }
    }

    impl<T> Clone for SimSender<T> {
        fn clone(&self) -> Self {
            let mut st = self.shared.state.lock().unwrap_or_else(|e| e.into_inner());
            st.senders += 1;
            drop(st);
            Self { shared: Arc::clone(&self.shared) }
        }
    }

    impl<T> Drop for SimSender<T> {
        fn drop(&mut self) {
            let mut st = self.shared.state.lock().unwrap_or_else(|e| e.into_inner());
            st.senders -= 1;
            let last = st.senders == 0;
            drop(st);
            if last {
                self.shared.cv.notify_all();
            }
        }
    }

    impl<T> SimReceiver<T> {
        /// Blocking receive with a timeout (virtual time under a
        /// schedule).
        pub fn recv_timeout(&self, timeout: Duration) -> SimRecv<T> {
            let mut st = self.shared.state.lock().unwrap_or_else(|e| e.into_inner());
            loop {
                if let Some(x) = st.queue.pop_front() {
                    return SimRecv::Item(x);
                }
                if st.senders == 0 {
                    return SimRecv::Disconnected;
                }
                let (g, timed_out) = self.shared.cv.wait_timeout_sim(st, timeout);
                st = g;
                if timed_out {
                    if let Some(x) = st.queue.pop_front() {
                        return SimRecv::Item(x);
                    }
                    if st.senders == 0 {
                        return SimRecv::Disconnected;
                    }
                    return SimRecv::Timeout;
                }
            }
        }

        /// Non-blocking receive.
        pub fn try_recv(&self) -> Option<T> {
            self.shared.state.lock().unwrap_or_else(|e| e.into_inner()).queue.pop_front()
        }
    }

    // ------------------------------------------------------------------
    // Probes and faults (test-only side channels)
    // ------------------------------------------------------------------

    /// One captured [`probe`] event.
    #[derive(Clone, Copy, Debug, PartialEq, Eq)]
    pub struct ProbeEvent {
        /// Static event tag (e.g. `"sc_key"`).
        pub tag: &'static str,
        /// First payload word.
        pub a: u64,
        /// Second payload word.
        pub b: u64,
    }

    thread_local! {
        static PROBES: RefCell<Option<Vec<ProbeEvent>>> = const { RefCell::new(None) };
    }

    /// Record an event if this thread has probe capture enabled
    /// (a no-op otherwise, and always a no-op in release builds).
    pub fn probe(tag: &'static str, a: u64, b: u64) {
        PROBES.with(|p| {
            if let Some(v) = p.borrow_mut().as_mut() {
                v.push(ProbeEvent { tag, a, b });
            }
        });
    }

    /// Start capturing [`probe`] events on this thread.
    pub fn begin_probes() {
        PROBES.with(|p| *p.borrow_mut() = Some(Vec::new()));
    }

    /// Stop capturing and return the events recorded since
    /// [`begin_probes`].
    pub fn end_probes() -> Vec<ProbeEvent> {
        PROBES.with(|p| p.borrow_mut().take().unwrap_or_default())
    }

    static FAULTS_ON: AtomicUsize = AtomicUsize::new(0);
    static FAULTS: StdMutex<Vec<&'static str>> = StdMutex::new(Vec::new());
    static FAULT_SERIAL: StdMutex<()> = StdMutex::new(());

    /// Whether the named test-only mutation is enabled.  Always false
    /// unless a [`FaultGuard`] for `name` is alive (and always false in
    /// release builds).  The fast path is one relaxed atomic load.
    pub fn fault(name: &str) -> bool {
        if FAULTS_ON.load(Ordering::Relaxed) == 0 {
            return false;
        }
        FAULTS.lock().unwrap_or_else(|e| e.into_inner()).iter().any(|&f| f == name)
    }

    /// RAII enabling of one named fault.  Also holds a process-wide
    /// serialisation lock so fault-injection tests never overlap (the
    /// fault registry is global); a test must hold at most one guard at
    /// a time.
    pub struct FaultGuard {
        name: &'static str,
        _serial: std::sync::MutexGuard<'static, ()>,
    }

    impl FaultGuard {
        /// Enable `name` until the guard drops.
        pub fn enable(name: &'static str) -> Self {
            let serial = FAULT_SERIAL.lock().unwrap_or_else(|e| e.into_inner());
            FAULTS.lock().unwrap_or_else(|e| e.into_inner()).push(name);
            FAULTS_ON.fetch_add(1, Ordering::Relaxed);
            Self { name, _serial: serial }
        }
    }

    impl Drop for FaultGuard {
        fn drop(&mut self) {
            let mut f = FAULTS.lock().unwrap_or_else(|e| e.into_inner());
            if let Some(pos) = f.iter().position(|&n| n == self.name) {
                f.remove(pos);
            }
            FAULTS_ON.fetch_sub(1, Ordering::Relaxed);
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;
        use std::collections::HashSet;
        use std::sync::Mutex as PlainMutex;

        // A plain std mutex is safe inside sim threads as long as it is
        // never held across a scheduling point: between sim ops exactly
        // one thread runs, so it cannot contend.
        fn two_thread_orders() -> (SimReport, HashSet<Vec<u8>>) {
            let seen: PlainMutex<HashSet<Vec<u8>>> = PlainMutex::new(HashSet::new());
            let report = check_exhaustive(10_000, || {
                let order = Arc::new(PlainMutex::new(Vec::new()));
                let m = Arc::new(Mutex::new(()));
                let mut handles = Vec::new();
                for id in 0..2u8 {
                    let order = Arc::clone(&order);
                    let m = Arc::clone(&m);
                    handles.push(spawn(move || {
                        let _g = m.lock().unwrap();
                        order.lock().unwrap().push(id);
                    }));
                }
                for h in handles {
                    h.join().unwrap();
                }
                let o = order.lock().unwrap().clone();
                seen.lock().unwrap().insert(o);
            });
            (report, seen.into_inner().unwrap())
        }

        #[test]
        fn exhaustive_explores_both_orders() {
            let (report, seen) = two_thread_orders();
            assert!(report.complete, "tiny scenario must enumerate fully ({} schedules)", report.schedules);
            assert!(report.schedules >= 2);
            let mut want = HashSet::new();
            want.insert(vec![0u8, 1]);
            want.insert(vec![1u8, 0]);
            assert_eq!(seen, want, "both lock orders must be explored");
        }

        #[test]
        #[should_panic(expected = "deadlock")]
        fn detects_abba_deadlock() {
            check_exhaustive(10_000, || {
                let a = Arc::new(Mutex::new(()));
                let b = Arc::new(Mutex::new(()));
                let (a1, b1) = (Arc::clone(&a), Arc::clone(&b));
                let t1 = spawn(move || {
                    let _x = a1.lock().unwrap();
                    let _y = b1.lock().unwrap();
                });
                let (a2, b2) = (Arc::clone(&a), Arc::clone(&b));
                let t2 = spawn(move || {
                    let _x = b2.lock().unwrap();
                    let _y = a2.lock().unwrap();
                });
                t1.join().unwrap();
                t2.join().unwrap();
            });
        }

        #[test]
        fn virtual_time_advances_without_real_sleep() {
            let t0 = std::time::Instant::now();
            check_random(3, 42, || {
                assert_eq!(vnow(), 0);
                sleep(Duration::from_secs(5));
                assert!(vnow() >= 5_000_000_000);
            });
            assert!(t0.elapsed() < Duration::from_secs(5), "sleep must be virtual");
        }

        fn racy_lost_update() {
            let c = Arc::new(PlainMutex::new(0u64));
            let mut handles = Vec::new();
            for _ in 0..2 {
                let c = Arc::clone(&c);
                handles.push(spawn(move || {
                    let v = *c.lock().unwrap();
                    yield_point();
                    *c.lock().unwrap() = v + 1;
                }));
            }
            for h in handles {
                h.join().unwrap();
            }
            assert_eq!(*c.lock().unwrap(), 2, "lost update");
        }

        #[test]
        fn exhaustive_finds_lost_update() {
            let r = std::panic::catch_unwind(|| check_exhaustive(10_000, racy_lost_update));
            let msg = panic_message(r.expect_err("the race must be found").as_ref());
            assert!(msg.contains("lost update"), "{msg}");
        }

        #[test]
        fn same_seed_reproduces_same_schedule_and_replay_matches() {
            let body = racy_lost_update;
            let mut failing = None;
            for s in 0..200u64 {
                let out = run_one(ChoiceSource::Random(Pcg64::new(s, 0)), DEFAULT_MAX_STEPS, &body);
                if out.failure.is_some() {
                    failing = Some((s, out));
                    break;
                }
            }
            let (seed, first) = failing.expect("some random schedule must hit the race");
            let again = run_one(ChoiceSource::Random(Pcg64::new(seed, 0)), DEFAULT_MAX_STEPS, &body);
            assert_eq!(first.record, again.record, "same seed must replay the same schedule");
            assert!(again.failure.is_some());
            let choices: Vec<u32> = first.record.iter().map(|&(c, _)| c).collect();
            let replay = run_one(ChoiceSource::Replay { choices, pos: 0 }, DEFAULT_MAX_STEPS, &body);
            assert!(replay.failure.is_some(), "recorded choices must reproduce the failure");
        }

        #[test]
        fn channel_timeout_and_disconnect_under_virtual_time() {
            check_random(5, 9, || {
                let (tx, rx) = sim_channel::<u32>();
                assert_eq!(rx.recv_timeout(Duration::from_millis(1)), SimRecv::Timeout);
                tx.send(5);
                assert_eq!(rx.recv_timeout(Duration::from_millis(1)), SimRecv::Item(5));
                drop(tx);
                assert_eq!(rx.recv_timeout(Duration::from_millis(1)), SimRecv::Disconnected);
            });
        }

        #[test]
        fn faults_toggle_and_scope() {
            assert!(!fault("sim-test-fault"));
            {
                let _g = FaultGuard::enable("sim-test-fault");
                assert!(fault("sim-test-fault"));
                assert!(!fault("sim-test-other"));
            }
            assert!(!fault("sim-test-fault"));
        }

        #[test]
        fn probes_capture_only_between_begin_and_end() {
            begin_probes();
            probe("k", 1, 2);
            let v = end_probes();
            assert_eq!(v, vec![ProbeEvent { tag: "k", a: 1, b: 2 }]);
            probe("k", 3, 4); // not capturing: dropped
            begin_probes();
            assert!(end_probes().is_empty());
        }

        #[test]
        fn schedule_budget_default() {
            // Cannot assert the env-var branch without mutating process
            // env; pin the default path.
            if std::env::var("ARI_MODEL_SCHEDULES").is_err() {
                assert_eq!(schedule_budget(123), 123);
            }
        }
    }
}

#[cfg(any(debug_assertions, feature = "sim"))]
pub use imp::*;

#[cfg(not(any(debug_assertions, feature = "sim")))]
mod stub {
    /// Sim-aware mutex (release stub: the real `std::sync::Mutex`).
    pub use std::sync::Mutex;

    /// Sim-aware condvar (release stub: the real `std::sync::Condvar`).
    pub use std::sync::Condvar;

    /// Thread handle (release stub: a plain `JoinHandle<()>`).
    pub type Thread = std::thread::JoinHandle<()>;

    /// Spawn a named thread (release stub: `std::thread::Builder`).
    pub fn spawn_thread<F: FnOnce() + Send + 'static>(name: String, f: F) -> std::io::Result<Thread> {
        std::thread::Builder::new().name(name).spawn(f)
    }

    /// Spawn an anonymous thread (release stub).
    pub fn spawn<F: FnOnce() + Send + 'static>(f: F) -> Thread {
        std::thread::spawn(f)
    }

    /// Scheduling point (release stub: nothing).
    #[inline(always)]
    pub fn yield_point() {}

    /// Harness-aware `catch_unwind` (release stub: the plain one).
    #[inline(always)]
    pub fn catching<R>(f: impl FnOnce() -> R) -> std::thread::Result<R> {
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(f))
    }

    /// Test-only mutation switch (release stub: always disabled, so the
    /// branch folds away).
    #[inline(always)]
    pub fn fault(_name: &str) -> bool {
        false
    }

    /// Test-only event capture (release stub: nothing).
    #[inline(always)]
    pub fn probe(_tag: &'static str, _a: u64, _b: u64) {}

    /// Whether the sim hooks are compiled in (release stub: no).
    #[inline(always)]
    pub fn hooks_enabled() -> bool {
        false
    }
}

#[cfg(not(any(debug_assertions, feature = "sim")))]
pub use stub::*;
