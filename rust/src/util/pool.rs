//! Scoped worker pool for sharding batch rows across cores.
//!
//! The prepared-plan forward passes ([`crate::mlp::plan`]) are
//! embarrassingly parallel over batch rows: every row's computation —
//! kernel accumulation, quantisation epilogue, per-row SC noise stream —
//! is independent of which worker runs it, so outputs are bit-identical
//! for **any** shard count.  This module only decides *how many* workers
//! to use and runs the per-shard jobs on `std::thread::scope` threads
//! (no dependencies, no long-lived pool: scoped threads let jobs borrow
//! the caller's buffers directly).
//!
//! Shards are contiguous row ranges of near-equal size.  Per-row work is
//! uniform (same layer stack for every row), so static partitioning is
//! within noise of work stealing here while staying allocation- and
//! unsafe-free; the `ARI_THREADS` environment variable caps (or raises)
//! the worker count, and `1` forces the serial path.

use std::sync::OnceLock;

/// Rows below which an extra worker is not worth its spawn cost.
const MIN_ROWS_PER_WORKER: usize = 8;

/// Floating-point-op-equivalents of work below which an extra worker is
/// not worth its spawn cost (scoped spawn + join is ~tens of µs; a
/// worker should amortise that many times over).
const MIN_WORK_PER_WORKER: usize = 256 * 1024;

/// Upper bound on worker threads: hardware parallelism (capped at 16),
/// overridable via the `ARI_THREADS` environment variable.  Read once
/// per process.
pub fn max_threads() -> usize {
    static CAP: OnceLock<usize> = OnceLock::new();
    *CAP.get_or_init(|| {
        let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        match std::env::var("ARI_THREADS").ok().and_then(|s| s.parse::<usize>().ok()) {
            Some(n) if n >= 1 => n.min(64),
            _ => hw.min(16),
        }
    })
}

/// Worker count for `rows` rows of roughly uniform per-row work: one
/// worker per [`MIN_ROWS_PER_WORKER`] rows, capped by [`max_threads`],
/// never zero.
pub fn auto_threads(rows: usize) -> usize {
    let by_rows = (rows + MIN_ROWS_PER_WORKER - 1) / MIN_ROWS_PER_WORKER;
    max_threads().min(by_rows).max(1)
}

/// Work-aware worker count: like [`auto_threads`] but also requires
/// each worker to amortise its spawn cost — at least
/// `MIN_WORK_PER_WORKER` flop-equivalents of the `rows *
/// flops_per_row` total per worker, so tiny models stay on the fast
/// serial path (spawn + join would otherwise exceed the compute).
pub fn auto_threads_for(rows: usize, flops_per_row: usize) -> usize {
    let by_work = (rows.saturating_mul(flops_per_row) / MIN_WORK_PER_WORKER).max(1);
    auto_threads(rows).min(by_work)
}

/// Partition `rows` into at most `threads` contiguous `(lo, len)` shards
/// of near-equal size.  Deterministic; empty input gives no shards.
pub fn shards(rows: usize, threads: usize) -> Vec<(usize, usize)> {
    let t = threads.max(1).min(rows.max(1));
    let chunk = (rows + t - 1) / t.max(1);
    let mut out = Vec::with_capacity(t);
    let mut lo = 0;
    while lo < rows {
        let len = chunk.min(rows - lo);
        out.push((lo, len));
        lo += len;
    }
    out
}

/// Run the jobs concurrently on scoped threads.  The first job always
/// runs inline on the caller's thread (the caller is a worker, not an
/// idle joiner), so `n` jobs cost `n - 1` spawns; the call returns once
/// every job has finished.
pub fn run_jobs<F: FnOnce() + Send>(jobs: Vec<F>) {
    let mut jobs = jobs.into_iter();
    let Some(first) = jobs.next() else { return };
    if jobs.len() == 0 {
        first();
        return;
    }
    std::thread::scope(|s| {
        for job in jobs {
            s.spawn(job);
        }
        first();
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shards_cover_exactly() {
        for rows in [0usize, 1, 7, 8, 31, 32, 33, 256] {
            for threads in [1usize, 2, 3, 4, 16] {
                let parts = shards(rows, threads);
                assert!(parts.len() <= threads.max(1));
                let mut expect_lo = 0;
                for &(lo, len) in &parts {
                    assert_eq!(lo, expect_lo);
                    assert!(len > 0);
                    expect_lo += len;
                }
                assert_eq!(expect_lo, rows, "rows={rows} threads={threads}");
            }
        }
    }

    #[test]
    fn run_jobs_executes_every_job() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let hits = AtomicUsize::new(0);
        let jobs: Vec<_> = (0..4)
            .map(|_| {
                let hits = &hits;
                move || {
                    hits.fetch_add(1, Ordering::SeqCst);
                }
            })
            .collect();
        run_jobs(jobs);
        assert_eq!(hits.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn run_jobs_single_runs_inline() {
        let main_id = std::thread::current().id();
        let mut ran_on = None;
        run_jobs(vec![|| {
            ran_on = Some(std::thread::current().id());
        }]);
        assert_eq!(ran_on, Some(main_id));
    }

    #[test]
    fn auto_threads_bounds() {
        assert_eq!(auto_threads(1), 1);
        assert!(auto_threads(256) >= 1);
        assert!(auto_threads(256) <= max_threads());
    }

    #[test]
    fn work_aware_threads_stay_serial_on_tiny_models() {
        // A fixture-sized forward (32 rows × ~3k flops) must not pay
        // thread spawns; heavy per-row work may.
        assert_eq!(auto_threads_for(32, 3_000), 1);
        assert_eq!(auto_threads_for(1, usize::MAX), 1);
        let heavy = auto_threads_for(256, 4_000_000);
        assert_eq!(heavy, auto_threads(256));
        assert!(auto_threads_for(256, 3_000) <= 3);
    }

    #[test]
    fn first_job_runs_on_caller_thread() {
        use std::sync::Mutex;
        let main_id = std::thread::current().id();
        let ids = Mutex::new(Vec::new());
        let jobs: Vec<_> = (0..3)
            .map(|_| {
                let ids = &ids;
                move || ids.lock().unwrap().push(std::thread::current().id())
            })
            .collect();
        run_jobs(jobs);
        let ids = ids.into_inner().unwrap();
        assert_eq!(ids.len(), 3);
        assert!(ids.contains(&main_id), "caller must work, not idle");
    }

    #[test]
    fn jobs_can_write_disjoint_slices() {
        // The plan forward's usage pattern: split one buffer, let each
        // scoped job fill its shard.
        let mut buf = vec![0u32; 32];
        {
            let mut rest: &mut [u32] = &mut buf;
            let mut jobs = Vec::new();
            for (lo, len) in shards(32, 4) {
                let (mine, r) = std::mem::take(&mut rest).split_at_mut(len);
                rest = r;
                jobs.push(move || {
                    for (i, v) in mine.iter_mut().enumerate() {
                        *v = (lo + i) as u32;
                    }
                });
            }
            run_jobs(jobs);
        }
        for (i, &v) in buf.iter().enumerate() {
            assert_eq!(v, i as u32);
        }
    }
}
