//! Persistent, parked worker pool for sharding batch rows across cores.
//!
//! The prepared-plan forward passes ([`crate::mlp::plan`]) are
//! embarrassingly parallel over batch rows: every row's computation —
//! kernel accumulation, quantisation epilogue, per-row SC noise stream —
//! is independent of which worker runs it, so outputs are bit-identical
//! for **any** shard count.  This module decides *how many* workers to
//! use and runs the per-shard jobs on a **persistent pool**: worker
//! threads are spawned once per process (first use), parked on a condvar
//! between batches, and woken per submitted batch.  The old
//! `std::thread::scope` implementation paid a spawn + join (~tens of µs)
//! *per forward call* — comparable to a whole reduced-precision batch on
//! the fixture topologies; waking a parked thread is two orders of
//! magnitude cheaper.
//!
//! Jobs still borrow the caller's buffers directly: [`WorkerPool::run`]
//! publishes the job vector to the workers by raw pointer and does not
//! return until every job has finished (and every worker has detached
//! from the batch), which is the same borrow-safety argument scoped
//! threads make — the borrows outlive the parallel region because the
//! submitting call blocks on it.
//!
//! Shards are contiguous row ranges of near-equal size.  Per-row work is
//! uniform (same layer stack for every row), so static partitioning is
//! within noise of work stealing here; the `ARI_THREADS` environment
//! variable caps (or raises) the worker count, and `1` forces the
//! serial path (the global pool then has zero workers and every job
//! runs inline).

use crate::util::fault;
use crate::util::sim::{self, Condvar, Mutex, Thread};
use std::any::Any;
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
// ari-lint: allow(sim-discipline): `StdMutex` guards only the supervision handle
// list (appended on spawn, drained in `Drop`) — never part of the job protocol
// the sim scheduler model-checks.
use std::sync::{Arc, Mutex as StdMutex, OnceLock, TryLockError};

/// Rows below which an extra worker is not worth waking.
const MIN_ROWS_PER_WORKER: usize = 8;

/// Floating-point-op-equivalents of work below which an extra worker is
/// not worth waking (a condvar wake is ~µs-scale; a worker should still
/// amortise it many times over).
const MIN_WORK_PER_WORKER: usize = 256 * 1024;

/// Upper bound on worker threads: hardware parallelism (capped at 16),
/// overridable via the `ARI_THREADS` environment variable.  Read once
/// per process.
pub fn max_threads() -> usize {
    static CAP: OnceLock<usize> = OnceLock::new();
    *CAP.get_or_init(|| {
        let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        match std::env::var("ARI_THREADS").ok().and_then(|s| s.parse::<usize>().ok()) {
            Some(n) if n >= 1 => n.min(64),
            _ => hw.min(16),
        }
    })
}

/// Worker count for `rows` rows of roughly uniform per-row work: one
/// worker per [`MIN_ROWS_PER_WORKER`] rows, capped by [`max_threads`],
/// never zero.
pub fn auto_threads(rows: usize) -> usize {
    let by_rows = (rows + MIN_ROWS_PER_WORKER - 1) / MIN_ROWS_PER_WORKER;
    max_threads().min(by_rows).max(1)
}

/// Work-aware worker count: like [`auto_threads`] but also requires
/// each worker to amortise its wake cost — at least
/// `MIN_WORK_PER_WORKER` flop-equivalents of the `rows *
/// flops_per_row` total per worker, so tiny models stay on the fast
/// serial path (even a parked-pool dispatch would otherwise exceed the
/// compute).
pub fn auto_threads_for(rows: usize, flops_per_row: usize) -> usize {
    let by_work = (rows.saturating_mul(flops_per_row) / MIN_WORK_PER_WORKER).max(1);
    auto_threads(rows).min(by_work)
}

/// Partition `rows` into at most `threads` contiguous `(lo, len)` shards
/// of near-equal size.  Deterministic; empty input gives no shards.
pub fn shards(rows: usize, threads: usize) -> Vec<(usize, usize)> {
    let t = threads.max(1).min(rows.max(1));
    let chunk = (rows + t - 1) / t.max(1);
    let mut out = Vec::with_capacity(t);
    let mut lo = 0;
    while lo < rows {
        let len = chunk.min(rows - lo);
        out.push((lo, len));
        lo += len;
    }
    out
}

/// Run the jobs concurrently on the process-global persistent pool.
/// The first job always runs inline on the caller's thread (the caller
/// is a worker, not an idle joiner); the call returns once every job
/// has finished.  Semantics are identical to the old scoped-spawn
/// implementation — only the thread lifecycle changed.
pub fn run_jobs<F: FnOnce() + Send>(jobs: Vec<F>) {
    global().run(jobs)
}

/// The process-global pool: `max_threads() - 1` parked workers (the
/// submitting thread is always the remaining worker), created on first
/// use and parked for the life of the process.
pub fn global() -> &'static WorkerPool {
    static POOL: OnceLock<WorkerPool> = OnceLock::new();
    POOL.get_or_init(|| WorkerPool::new(max_threads().saturating_sub(1)))
}

/// Type-erased runner: reads job `idx` out of the submitted vector and
/// runs it, catching panics so the batch always drains (a lost
/// decrement would deadlock the submitter).
type RunOne = unsafe fn(*mut (), usize) -> Option<Box<dyn Any + Send>>;

// SAFETY: callers must pass a `base` obtained from a live `Vec<F>` spine whose
// element type matches this instantiation's `F`; see the body for the full
// per-read contract.
unsafe fn run_erased<F: FnOnce() + Send>(base: *mut (), idx: usize) -> Option<Box<dyn Any + Send>> {
    // SAFETY: the submitter guarantees `base` points at a live `Vec<F>`
    // spine of at least `idx + 1` elements, that every index is claimed
    // exactly once (atomic dispenser), and that the vector's length is
    // set to 0 before the spine is dropped — so this `read` is the one
    // and only move of the job.
    let job: F = unsafe { (base as *mut F).add(idx).read() };
    // `sim::catching`, not a bare catch_unwind: the panic is handled
    // right here (stored, batch keeps draining), so under the model
    // harness it must not abort the running schedule.
    sim::catching(move || job()).err()
}

/// One published batch: an erased view of the submitter's job vector.
/// Lives on the submitter's stack; workers only dereference it between
/// registering in `State::active` and deregistering, and the submitter
/// only returns once `active == 0 && pending == 0`.
struct BatchDesc {
    base: *mut (),
    len: usize,
    /// Next job index to claim.  Index 0 is reserved for the submitter
    /// (the caller always works instead of idling in the join).
    next: AtomicUsize,
    run_one: RunOne,
}

/// Raw pointer to the current batch descriptor, sendable to workers.
#[derive(Clone, Copy)]
struct BatchPtr(*const BatchDesc);
// SAFETY: the pointee outlives every dereference (see `BatchDesc`), and
// the jobs it exposes are `Send` (enforced by `WorkerPool::run`'s
// bound), so handing the pointer to a worker thread is sound.
unsafe impl Send for BatchPtr {}

struct State {
    /// The batch workers should drain, if any.
    batch: Option<BatchPtr>,
    /// Bumped once per published batch so a worker never re-enters a
    /// batch it already drained.
    epoch: u64,
    /// Jobs of the current batch not yet finished.
    pending: usize,
    /// Workers currently inside the current batch's claim loop.
    active: usize,
    /// First panic payload caught in the current batch, if any.
    panic_payload: Option<Box<dyn Any + Send>>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Workers park here between batches.
    work_cv: Condvar,
    /// The submitter parks here until the batch drains.
    done_cv: Condvar,
    /// Serialises submitters; try-locked so nested or concurrent `run`
    /// calls fall back to inline execution instead of deadlocking.
    submit: Mutex<()>,
    /// Live worker threads (for leak tests and introspection).
    live: AtomicUsize,
    /// Total workers ever spawned — names respawned workers uniquely.
    spawned: AtomicUsize,
}

/// A persistent pool of parked worker threads.  See the module docs;
/// most code uses the process-global instance via [`run_jobs`].
///
/// Synchronisation (state mutex, park/done condvars, spawn/join) goes
/// through [`crate::util::sim`], so dedicated pool instances can be
/// driven under the deterministic-interleaving harness
/// (`tests/model_pool.rs`); in release builds the wrappers are the std
/// primitives.  The **global** pool must never be used from inside a
/// schedule — model tests construct their own instances.
///
/// **Supervision**: a worker that dies (its loop unwinds, or the
/// [`fault::WORKER_DEATH`] fault point fires) is replaced at the next
/// [`WorkerPool::run`] submission, under the submit lock, so the pool
/// never serves below capacity for more than one inter-batch gap.
/// This is eventually consistent by design — a death is only *observed*
/// at a submission boundary — and jobs are never lost meanwhile: the
/// claim loop is pull-based, so the submitter drains whatever dead
/// workers don't.
pub struct WorkerPool {
    shared: Arc<Shared>,
    /// Capacity: the worker count the pool was built with and is
    /// supervised back up to.
    workers: usize,
    /// Handles of every spawned worker, including dead ones (joining a
    /// finished thread is immediate); locked because supervision
    /// appends while `Drop` drains.
    handles: StdMutex<Vec<Thread>>,
}

impl WorkerPool {
    /// Spawn `workers` parked worker threads (0 is valid: every job then
    /// runs inline on the submitting thread).
    pub fn new(workers: usize) -> Self {
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                batch: None,
                epoch: 0,
                pending: 0,
                active: 0,
                panic_payload: None,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            submit: Mutex::new(()),
            live: AtomicUsize::new(0),
            spawned: AtomicUsize::new(0),
        });
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            let sh = Arc::clone(&shared);
            sh.live.fetch_add(1, Ordering::SeqCst);
            let i = shared.spawned.fetch_add(1, Ordering::SeqCst);
            let handle = sim::spawn_thread(format!("ari-pool-{i}"), move || worker_loop(sh)).expect("spawn pool worker");
            handles.push(handle);
        }
        Self { shared, workers, handles: StdMutex::new(handles) }
    }

    /// Number of worker threads this pool was built with (its supervised
    /// capacity — see the struct docs).
    pub fn worker_count(&self) -> usize {
        self.workers
    }

    /// Worker threads currently alive (equals [`Self::worker_count`]
    /// until shutdown begins).
    pub fn live_workers(&self) -> usize {
        self.shared.live.load(Ordering::SeqCst)
    }

    /// Run the jobs, first job inline on the caller, the rest drained by
    /// the parked workers (and by the caller once its own job is done).
    /// Returns after every job has finished; panics (re-raising the
    /// first payload) if any job panicked.
    pub fn run<F: FnOnce() + Send>(&self, mut jobs: Vec<F>) {
        let n = jobs.len();
        if n == 0 {
            return;
        }
        if n == 1 || self.workers == 0 {
            for job in jobs {
                job();
            }
            return;
        }
        // A second submitter (or a job submitting from inside the pool)
        // runs inline rather than queueing: the pool's win is parking,
        // not scheduling depth.  A poisoned submit lock is recovered —
        // it protects no data, only mutual exclusion.
        let _submit = match self.shared.submit.try_lock() {
            Ok(g) => g,
            Err(TryLockError::Poisoned(p)) => p.into_inner(),
            Err(TryLockError::WouldBlock) => {
                for job in jobs {
                    job();
                }
                return;
            }
        };
        // Supervision point: replace any workers that died since the
        // last submission, before this batch is published.
        if self.shared.live.load(Ordering::SeqCst) < self.workers {
            self.respawn_missing();
        }
        let desc = BatchDesc {
            base: jobs.as_mut_ptr() as *mut (),
            len: n,
            next: AtomicUsize::new(1),
            run_one: run_erased::<F>,
        };
        {
            let mut st = self.shared.state.lock().unwrap_or_else(|e| e.into_inner());
            st.batch = Some(BatchPtr(&desc));
            st.epoch = st.epoch.wrapping_add(1);
            st.pending = n;
            st.panic_payload = None;
            drop(st);
            self.shared.work_cv.notify_all();
        }
        // Job 0 runs here, then the caller joins the claim loop.
        let mut done = 1usize;
        // SAFETY: index 0 is reserved for the submitter (`next` starts
        // at 1), and `jobs` is live for the whole call.
        let mut first_panic = unsafe { (desc.run_one)(desc.base, 0) };
        loop {
            // Scheduling point: under the sim harness the claim race
            // between the submitter and every worker is enumerable.
            sim::yield_point();
            let i = desc.next.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            // SAFETY: `i` was claimed exactly once by this fetch_add.
            let p = unsafe { (desc.run_one)(desc.base, i) };
            if first_panic.is_none() {
                first_panic = p;
            }
            done += 1;
        }
        let payload = {
            let mut st = self.shared.state.lock().unwrap_or_else(|e| e.into_inner());
            st.pending -= done;
            while st.pending > 0 || st.active > 0 {
                st = self.shared.done_cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }
            // Unpublish before returning: `desc` dies with this frame.
            st.batch = None;
            let worker_panic = st.panic_payload.take();
            if first_panic.is_none() {
                first_panic = worker_panic;
            }
            first_panic
        };
        // Every job was moved out by `run_one`'s `ptr::read`; drop only
        // the spine.
        // SAFETY: all `n` indices were claimed and read exactly once.
        unsafe { jobs.set_len(0) };
        if let Some(payload) = payload {
            // Release the submit lock *before* re-raising: unwinding
            // while holding it would poison the mutex and silently
            // degrade every later `run` to the inline fallback.
            drop(_submit);
            panic::resume_unwind(payload);
        }
    }

    /// Spawn replacements until `live` is back at capacity.  Called
    /// under the submit lock, so respawns never race each other; a
    /// spawn failure leaves the pool short (the claim loop still
    /// completes every batch) and retries at the next submission.
    fn respawn_missing(&self) {
        while self.shared.live.load(Ordering::SeqCst) < self.workers {
            let sh = Arc::clone(&self.shared);
            sh.live.fetch_add(1, Ordering::SeqCst);
            let i = self.shared.spawned.fetch_add(1, Ordering::SeqCst);
            match sim::spawn_thread(format!("ari-pool-{i}"), move || worker_loop(sh)) {
                Ok(handle) => self.handles.lock().unwrap_or_else(|e| e.into_inner()).push(handle),
                Err(_) => {
                    self.shared.live.fetch_sub(1, Ordering::SeqCst);
                    return;
                }
            }
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap_or_else(|e| e.into_inner());
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        let handles = std::mem::take(self.handles.get_mut().unwrap_or_else(|e| e.into_inner()));
        for handle in handles {
            handle.join().ok();
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    // Exactly-once live accounting on *every* exit path — shutdown,
    // injected death, or an unwind out of the loop itself — so the
    // supervisor's capacity check never drifts.
    struct LiveGuard(Arc<Shared>);
    impl Drop for LiveGuard {
        fn drop(&mut self) {
            self.0.live.fetch_sub(1, Ordering::SeqCst);
        }
    }
    let _live = LiveGuard(Arc::clone(&shared));
    let mut seen = 0u64;
    loop {
        // Park until there is a fresh batch (or shutdown).
        let batch = {
            let mut st = shared.state.lock().unwrap_or_else(|e| e.into_inner());
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    seen = st.epoch;
                    // Injected worker death, drawn once per observed
                    // epoch: exit *before* registering in `active`, as
                    // a crashed thread would — no job is lost (claims
                    // are pull-based) and no counter is torn.
                    if fault::inject(fault::WORKER_DEATH) {
                        return;
                    }
                    if let Some(b) = st.batch {
                        st.active += 1;
                        break b;
                    }
                    // Batch already fully drained and unpublished:
                    // nothing to do for this epoch.
                }
                st = shared.work_cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        };
        // Drain jobs.  `desc` stays valid while we are registered in
        // `active` — the submitter cannot return before `active == 0`.
        // SAFETY: see `BatchDesc` / `BatchPtr`.
        let desc = unsafe { &*batch.0 };
        let mut done = 0usize;
        let mut panic_payload: Option<Box<dyn Any + Send>> = None;
        loop {
            // Scheduling point: under the sim harness the claim race
            // between the submitter and every worker is enumerable.
            sim::yield_point();
            let i = desc.next.fetch_add(1, Ordering::Relaxed);
            if i >= desc.len {
                break;
            }
            // SAFETY: `i` was claimed exactly once by this fetch_add.
            let p = unsafe { (desc.run_one)(desc.base, i) };
            if panic_payload.is_none() {
                panic_payload = p;
            }
            done += 1;
        }
        let mut st = shared.state.lock().unwrap_or_else(|e| e.into_inner());
        st.pending -= done;
        st.active -= 1;
        if panic_payload.is_some() && st.panic_payload.is_none() {
            st.panic_payload = panic_payload;
        }
        if st.pending == 0 && st.active == 0 {
            shared.done_cv.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn shards_cover_exactly() {
        for rows in [0usize, 1, 7, 8, 31, 32, 33, 256] {
            for threads in [1usize, 2, 3, 4, 16] {
                let parts = shards(rows, threads);
                assert!(parts.len() <= threads.max(1));
                let mut expect_lo = 0;
                for &(lo, len) in &parts {
                    assert_eq!(lo, expect_lo);
                    assert!(len > 0);
                    expect_lo += len;
                }
                assert_eq!(expect_lo, rows, "rows={rows} threads={threads}");
            }
        }
    }

    #[test]
    fn run_jobs_executes_every_job() {
        let hits = AtomicUsize::new(0);
        let jobs: Vec<_> = (0..4)
            .map(|_| {
                let hits = &hits;
                move || {
                    hits.fetch_add(1, Ordering::SeqCst);
                }
            })
            .collect();
        run_jobs(jobs);
        assert_eq!(hits.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn run_jobs_single_runs_inline() {
        let main_id = std::thread::current().id();
        let mut ran_on = None;
        run_jobs(vec![|| {
            ran_on = Some(std::thread::current().id());
        }]);
        assert_eq!(ran_on, Some(main_id));
    }

    #[test]
    fn auto_threads_bounds() {
        assert_eq!(auto_threads(1), 1);
        assert!(auto_threads(256) >= 1);
        assert!(auto_threads(256) <= max_threads());
    }

    #[test]
    fn work_aware_threads_stay_serial_on_tiny_models() {
        // A fixture-sized forward (32 rows × ~3k flops) must not pay
        // pool dispatch; heavy per-row work may.
        assert_eq!(auto_threads_for(32, 3_000), 1);
        assert_eq!(auto_threads_for(1, usize::MAX), 1);
        let heavy = auto_threads_for(256, 4_000_000);
        assert_eq!(heavy, auto_threads(256));
        assert!(auto_threads_for(256, 3_000) <= 3);
    }

    #[test]
    fn first_job_runs_on_caller_thread() {
        // ari-lint: allow(sim-discipline): plain result collector for a real-thread test.
        use std::sync::Mutex;
        let main_id = std::thread::current().id();
        let ids = Mutex::new(Vec::new());
        let jobs: Vec<_> = (0..3)
            .map(|_| {
                let ids = &ids;
                move || ids.lock().unwrap().push(std::thread::current().id())
            })
            .collect();
        run_jobs(jobs);
        let ids = ids.into_inner().unwrap();
        assert_eq!(ids.len(), 3);
        assert!(ids.contains(&main_id), "caller must work, not idle");
    }

    #[test]
    fn jobs_can_write_disjoint_slices() {
        // The plan forward's usage pattern: split one buffer, let each
        // job fill its shard through a borrowed &mut.
        let mut buf = vec![0u32; 32];
        {
            let mut rest: &mut [u32] = &mut buf;
            let mut jobs = Vec::new();
            for (lo, len) in shards(32, 4) {
                let (mine, r) = std::mem::take(&mut rest).split_at_mut(len);
                rest = r;
                jobs.push(move || {
                    for (i, v) in mine.iter_mut().enumerate() {
                        *v = (lo + i) as u32;
                    }
                });
            }
            run_jobs(jobs);
        }
        for (i, &v) in buf.iter().enumerate() {
            assert_eq!(v, i as u32);
        }
    }

    #[test]
    fn pool_reused_across_many_batches() {
        // The persistent-pool contract: many submissions, zero new
        // threads, every batch complete and correct.
        let pool = WorkerPool::new(3);
        assert_eq!(pool.worker_count(), 3);
        for round in 0..50usize {
            let n_jobs = 1 + round % 6;
            let hits = AtomicUsize::new(0);
            let jobs: Vec<_> = (0..n_jobs)
                .map(|_| {
                    let hits = &hits;
                    move || {
                        hits.fetch_add(1, Ordering::SeqCst);
                    }
                })
                .collect();
            pool.run(jobs);
            assert_eq!(hits.load(Ordering::SeqCst), n_jobs, "round {round}");
            assert_eq!(pool.live_workers(), 3, "round {round}");
        }
    }

    #[test]
    fn drop_joins_all_workers() {
        let pool = WorkerPool::new(4);
        let shared = Arc::clone(&pool.shared);
        pool.run((0..8).map(|_| || ()).collect::<Vec<_>>());
        assert_eq!(shared.live.load(Ordering::SeqCst), 4);
        drop(pool);
        assert_eq!(shared.live.load(Ordering::SeqCst), 0, "drop must join every worker");
    }

    #[test]
    fn repeated_create_drop_does_not_leak_threads() {
        for _ in 0..16 {
            let pool = WorkerPool::new(2);
            let shared = Arc::clone(&pool.shared);
            let hits = AtomicUsize::new(0);
            pool.run(
                (0..4)
                    .map(|_| {
                        let hits = &hits;
                        move || {
                            hits.fetch_add(1, Ordering::SeqCst);
                        }
                    })
                    .collect::<Vec<_>>(),
            );
            assert_eq!(hits.load(Ordering::SeqCst), 4);
            drop(pool);
            assert_eq!(shared.live.load(Ordering::SeqCst), 0);
        }
    }

    #[test]
    fn nested_run_jobs_falls_back_inline() {
        // A job that itself submits must not deadlock: the inner submit
        // sees the submit lock held and runs inline.
        let pool = WorkerPool::new(2);
        let hits = AtomicUsize::new(0);
        let outer: Vec<_> = (0..2)
            .map(|_| {
                let hits = &hits;
                let pool = &pool;
                move || {
                    let inner: Vec<_> = (0..3)
                        .map(|_| {
                            move || {
                                hits.fetch_add(1, Ordering::SeqCst);
                            }
                        })
                        .collect();
                    pool.run(inner);
                }
            })
            .collect();
        pool.run(outer);
        assert_eq!(hits.load(Ordering::SeqCst), 6);
    }

    #[test]
    fn concurrent_submitters_all_complete() {
        let pool = Arc::new(WorkerPool::new(3));
        let total = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let pool = Arc::clone(&pool);
            let total = Arc::clone(&total);
            // ari-lint: allow(sim-discipline): concurrent-submitter stress leg on real
            // OS threads — exercises the global pool under genuine preemption.
            handles.push(std::thread::spawn(move || {
                for _ in 0..20 {
                    let local = AtomicUsize::new(0);
                    let jobs: Vec<_> = (0..4)
                        .map(|_| {
                            let local = &local;
                            move || {
                                local.fetch_add(1, Ordering::SeqCst);
                            }
                        })
                        .collect();
                    pool.run(jobs);
                    total.fetch_add(local.load(Ordering::SeqCst), Ordering::SeqCst);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(total.load(Ordering::SeqCst), 4 * 20 * 4);
    }

    #[test]
    fn job_panic_propagates_and_pool_survives() {
        let pool = WorkerPool::new(2);
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let jobs: Vec<Box<dyn FnOnce() + Send>> = vec![
                Box::new(|| {}),
                Box::new(|| panic!("boom in job")),
                Box::new(|| {}),
            ];
            pool.run(jobs);
        }));
        assert!(caught.is_err(), "job panic must propagate to the submitter");
        // The submit lock must not be poisoned by the re-raise (that
        // would silently degrade every later run to the inline path).
        assert!(pool.shared.submit.try_lock().is_ok(), "submit lock poisoned by propagated panic");
        // The pool is still functional afterwards.
        let hits = AtomicUsize::new(0);
        pool.run(
            (0..4)
                .map(|_| {
                    let hits = &hits;
                    move || {
                        hits.fetch_add(1, Ordering::SeqCst);
                    }
                })
                .collect::<Vec<_>>(),
        );
        assert_eq!(hits.load(Ordering::SeqCst), 4);
        assert_eq!(pool.live_workers(), 2);
    }

    /// Supervision: workers killed by the `worker-death` fault are
    /// respawned at the next submission, every batch still completes,
    /// and the pool returns to full capacity.
    #[test]
    fn dead_workers_are_respawned_to_capacity() {
        let pool = WorkerPool::new(3);
        {
            let _g = fault::ArmGuard::arm("worker-death:1.0:2");
            let hits = AtomicUsize::new(0);
            pool.run(
                (0..6)
                    .map(|_| {
                        let hits = &hits;
                        move || {
                            hits.fetch_add(1, Ordering::SeqCst);
                        }
                    })
                    .collect::<Vec<_>>(),
            );
            assert_eq!(hits.load(Ordering::SeqCst), 6, "batch must complete despite dying workers");
            // Each worker draws the fault when it observes the batch
            // epoch; wait for both shots to be spent.
            for _ in 0..2000 {
                if pool.live_workers() <= 1 {
                    break;
                }
                std::thread::sleep(std::time::Duration::from_millis(1));
            }
            assert_eq!(pool.live_workers(), 1, "two armed deaths must fire");
        }
        // The next submission supervises the pool back to capacity
        // before publishing and still runs every job.
        let hits = AtomicUsize::new(0);
        pool.run(
            (0..8)
                .map(|_| {
                    let hits = &hits;
                    move || {
                        hits.fetch_add(1, Ordering::SeqCst);
                    }
                })
                .collect::<Vec<_>>(),
        );
        assert_eq!(hits.load(Ordering::SeqCst), 8);
        assert_eq!(pool.live_workers(), 3, "pool must respawn to capacity");
        assert_eq!(pool.worker_count(), 3, "capacity itself never changes");
    }

    /// A batch completes and the submitter stays unblocked even when a
    /// worker dies *between* registering batches (pull-based claims
    /// mean the submitter drains whatever dead workers don't).
    #[test]
    fn all_workers_dead_still_completes_inline() {
        let pool = WorkerPool::new(2);
        {
            let _g = fault::ArmGuard::arm("worker-death:1.0");
            let hits = AtomicUsize::new(0);
            for round in 0..4 {
                let jobs: Vec<_> = (0..5)
                    .map(|_| {
                        let hits = &hits;
                        move || {
                            hits.fetch_add(1, Ordering::SeqCst);
                        }
                    })
                    .collect();
                pool.run(jobs);
                assert_eq!(hits.load(Ordering::SeqCst) % 5, 0, "round {round}");
            }
            assert_eq!(hits.load(Ordering::SeqCst), 20, "every job ran every round");
        }
        drop(pool); // joins respawned and dead handles alike
    }

    #[test]
    fn global_pool_sized_by_max_threads() {
        let pool = global();
        assert_eq!(pool.worker_count(), max_threads().saturating_sub(1));
        assert_eq!(pool.live_workers(), pool.worker_count(), "global pool never shuts down");
    }
}
