//! Fixed-bin histogram used for margin densities (paper Figs. 8/10/11)
//! and for latency distributions in the server metrics.

/// A fixed-range, fixed-bin-count histogram over f64 samples.
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    bins: Vec<u64>,
    /// Samples below `lo`.
    pub underflow: u64,
    /// Samples at or above `hi`.
    pub overflow: u64,
    count: u64,
}

impl Histogram {
    /// `n_bins` equal-width bins covering [lo, hi).
    pub fn new(lo: f64, hi: f64, n_bins: usize) -> Self {
        assert!(hi > lo && n_bins > 0);
        Self { lo, hi, bins: vec![0; n_bins], underflow: 0, overflow: 0, count: 0 }
    }

    /// Record one sample.
    pub fn record(&mut self, x: f64) {
        self.count += 1;
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let n = self.bins.len();
            let idx = ((x - self.lo) / (self.hi - self.lo) * n as f64) as usize;
            self.bins[idx.min(n - 1)] += 1;
        }
    }

    /// Record every sample in a slice.
    pub fn record_all(&mut self, xs: &[f64]) {
        for &x in xs {
            self.record(x);
        }
    }

    /// Total samples recorded (including under/overflow).
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Width of one bin.
    pub fn bin_width(&self) -> f64 {
        (self.hi - self.lo) / self.bins.len() as f64
    }

    /// (bin_center, count) pairs.
    pub fn bins(&self) -> Vec<(f64, u64)> {
        let w = self.bin_width();
        self.bins
            .iter()
            .enumerate()
            .map(|(i, &c)| (self.lo + (i as f64 + 0.5) * w, c))
            .collect()
    }

    /// Density per the paper's Fig. 8 definition: count in the bin divided
    /// by the bin width (and by the total count, to make it a pdf).
    pub fn densities(&self) -> Vec<(f64, f64)> {
        let w = self.bin_width();
        let n = self.count.max(1) as f64;
        self.bins().into_iter().map(|(c, cnt)| (c, cnt as f64 / (n * w))).collect()
    }

    /// Quantile from the binned data (approximate, bin-resolution).
    pub fn quantile(&self, q: f64) -> f64 {
        let in_range: u64 = self.bins.iter().sum();
        if in_range == 0 {
            return self.lo;
        }
        let target = (q.clamp(0.0, 1.0) * in_range as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, &c) in self.bins.iter().enumerate() {
            acc += c;
            if acc >= target.max(1) {
                return self.lo + (i as f64 + 1.0) * self.bin_width();
            }
        }
        self.hi
    }

    /// Render a compact ASCII bar chart (used by the experiment drivers to
    /// print figure panels into EXPERIMENTS.md).
    pub fn ascii(&self, width: usize) -> String {
        let max = self.bins.iter().copied().max().unwrap_or(1).max(1);
        let rows = self.bins();
        let mut out = String::new();
        for (center, cnt) in rows {
            let bar = "#".repeat((cnt as usize * width).div_ceil(max as usize).min(width));
            out.push_str(&format!("{center:8.4} |{bar:<width$}| {cnt}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_binning() {
        let mut h = Histogram::new(0.0, 1.0, 10);
        h.record(0.05);
        h.record(0.15);
        h.record(0.151);
        assert_eq!(h.bins()[0].1, 1);
        assert_eq!(h.bins()[1].1, 2);
        assert_eq!(h.count(), 3);
    }

    #[test]
    fn under_overflow() {
        let mut h = Histogram::new(0.0, 1.0, 4);
        h.record(-0.1);
        h.record(1.0); // hi is exclusive
        h.record(2.0);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.overflow, 2);
    }

    #[test]
    fn densities_integrate_to_one() {
        let mut h = Histogram::new(0.0, 1.0, 20);
        let mut p = crate::util::Pcg64::seeded(3);
        for _ in 0..5000 {
            h.record(p.next_f64());
        }
        let integral: f64 = h.densities().iter().map(|(_, d)| d * h.bin_width()).sum();
        assert!((integral - 1.0).abs() < 1e-9);
    }

    #[test]
    fn quantile_monotone() {
        let mut h = Histogram::new(0.0, 1.0, 100);
        let mut p = crate::util::Pcg64::seeded(4);
        for _ in 0..10_000 {
            h.record(p.next_f64());
        }
        let q50 = h.quantile(0.5);
        let q95 = h.quantile(0.95);
        assert!(q50 < q95);
        assert!((q50 - 0.5).abs() < 0.05);
        assert!((q95 - 0.95).abs() < 0.05);
    }

    #[test]
    fn ascii_renders() {
        let mut h = Histogram::new(0.0, 1.0, 4);
        h.record(0.1);
        let s = h.ascii(10);
        assert_eq!(s.lines().count(), 4);
        assert!(s.contains('#'));
    }
}
