//! PCG64 (XSL-RR 128/64) pseudo-random generator.
//!
//! Deterministic, seedable and fast; used by workload generators, the SC
//! simulator's auxiliary seeding and the property-test harness.  Not a
//! cryptographic RNG.

/// PCG XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
    /// Cached sine half of the last Box–Muller pair — see [`Self::normal`].
    spare_normal: Option<f64>,
}

const MUL: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Seed with a stream id; different `(seed, stream)` pairs give
    /// independent sequences.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut p = Self { state: 0, inc, spare_normal: None };
        p.step();
        p.state = p.state.wrapping_add(seed as u128);
        p.step();
        p
    }

    /// Seed with stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    fn step(&mut self) {
        self.state = self.state.wrapping_mul(MUL).wrapping_add(self.inc);
    }

    /// Next u64.
    pub fn next_u64(&mut self) -> u64 {
        self.step();
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Next u32.
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).  `n` must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire-style rejection-free-enough for our uses: widen-multiply.
        let m = (self.next_u64() as u128) * (n as u128);
        (m >> 64) as u64
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller.  Each underlying transform yields
    /// an **independent pair** (cosine and sine halves); the sine half is
    /// cached so consecutive draws pay the `ln`/`sqrt`/trig cost once per
    /// two values — this is what keeps the SC noise epilogue cheap.
    /// Deterministic: same seed, same call sequence, same values (the
    /// cache is part of [`Clone`]d state).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.spare_normal.take() {
            return v;
        }
        let (c, s) = self.normal_pair();
        self.spare_normal = Some(s);
        c
    }

    /// Both halves of one Box–Muller transform — two independent
    /// standard normals from two uniform draws: `(r·cos θ, r·sin θ)`.
    pub fn normal_pair(&mut self) -> (f64, f64) {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let (sin, cos) = (std::f64::consts::TAU * u2).sin_cos();
        (r * cos, r * sin)
    }

    /// Single-draw normal that runs one fresh Box–Muller transform per
    /// call, discards its sine half, and never touches the pair cache —
    /// the historical [`Self::normal`] behaviour.  The fixture generator
    /// ([`crate::runtime::fixture`]) pins its draw pattern to this so
    /// every synthetic dataset stays byte-identical across releases; new
    /// code should prefer [`Self::normal`].
    pub fn normal_unpaired(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Exponential with rate `lambda` (mean 1/lambda).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.next_f64().max(1e-300).ln() / lambda
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(42, 0);
        let mut b = Pcg64::new(42, 1);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_range() {
        let mut p = Pcg64::seeded(7);
        let mut mean = 0.0;
        for _ in 0..10_000 {
            let v = p.next_f64();
            assert!((0.0..1.0).contains(&v));
            mean += v;
        }
        mean /= 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "{mean}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut p = Pcg64::seeded(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = p.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut p = Pcg64::seeded(11);
        let xs: Vec<f64> = (0..20_000).map(|_| p.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.03, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn normal_caches_the_sine_half() {
        // Two draws consume exactly one uniform pair; the second comes
        // from the cache and must equal the pair's sine half.
        let mut a = Pcg64::seeded(17);
        let mut b = Pcg64::seeded(17);
        let (c, s) = b.normal_pair();
        assert_eq!(a.normal(), c);
        assert_eq!(a.normal(), s);
        // After an even number of draws both generators are aligned.
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn normal_deterministic_and_clone_carries_spare() {
        let mut a = Pcg64::seeded(19);
        let _ = a.normal(); // spare now cached
        let mut b = a.clone();
        for _ in 0..10 {
            assert_eq!(a.normal(), b.normal());
        }
    }

    #[test]
    fn normal_pair_halves_are_standard_normal() {
        let mut p = Pcg64::seeded(21);
        let mut sines = Vec::with_capacity(10_000);
        for _ in 0..10_000 {
            sines.push(p.normal_pair().1);
        }
        let mean = sines.iter().sum::<f64>() / sines.len() as f64;
        let var = sines.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / sines.len() as f64;
        assert!(mean.abs() < 0.04, "{mean}");
        assert!((var - 1.0).abs() < 0.06, "{var}");
    }

    #[test]
    fn normal_unpaired_matches_historical_sequence() {
        // One transform per call, cosine half only, no cache: calling it
        // interleaved with normal() must not disturb either stream's
        // uniform consumption beyond its own two draws.
        let mut a = Pcg64::seeded(23);
        let mut b = Pcg64::seeded(23);
        let x = a.normal_unpaired();
        let (c, _) = b.normal_pair();
        // Same uniforms, and the cosine halves may differ only by the
        // sin_cos-vs-cos implementation; both must be finite and close.
        assert!((x - c).abs() < 1e-12, "{x} vs {c}");
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn exponential_mean() {
        let mut p = Pcg64::seeded(13);
        let mean: f64 = (0..20_000).map(|_| p.exponential(2.0)).sum::<f64>() / 20_000.0;
        assert!((mean - 0.5).abs() < 0.02, "{mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut p = Pcg64::seeded(15);
        let mut xs: Vec<u32> = (0..50).collect();
        p.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }
}
