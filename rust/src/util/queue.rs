//! A small bounded blocking queue (Mutex + Condvar over a preallocated
//! ring) for the pipelined serving runtime.
//!
//! `std::sync::mpsc` allocates a node per message; this queue never
//! allocates after construction (the `VecDeque` is sized up front and
//! pushes are rejected-by-blocking at capacity), which is what lets the
//! server's staged-batch pipeline claim zero steady-state allocation —
//! the same fixed set of [`crate::server`] staging buffers circulates
//! through a pair of these queues for the whole session.
//!
//! Semantics: `push` blocks while full and fails only once the queue is
//! closed; `pop` blocks while empty and returns `None` only once the
//! queue is closed **and** drained (close never discards queued items).
//!
//! The close contract, pinned by the model suite
//! (`tests/model_queue.rs`) under every small-bound interleaving:
//!
//! * items enqueued before `close` are always delivered, FIFO;
//! * a `push` that observes the queue closed — including a pusher that
//!   was blocked on a full queue when `close` arrived — returns
//!   `Err(item)`, handing the exact item back; an item is never both
//!   returned **and** delivered;
//! * a blocked `pop` always wakes on `close` (drain, then `None`);
//!   a blocked `push` always wakes on `close` (`Err`).  No wakeup is
//!   lost under any schedule.
//!
//! The synchronisation goes through [`crate::util::sim`]: in release
//! builds those wrappers *are* `std::sync::{Mutex, Condvar}`; in
//! dev/test builds every lock and wait is a scheduling point the
//! deterministic-interleaving harness can enumerate.
//!
//! **Poison tolerance**: the protected state is a plain ring + closed
//! flag with no invariant that can be torn mid-panic (every mutation is
//! a single `push_back`/`pop_front`/flag store), so a panic elsewhere
//! in a holder's thread must not cascade into `PoisonError` unwinds in
//! every other pipeline thread — all lock/wait sites recover the guard.
//! The [`crate::util::fault::QUEUE_STALL`] fault point injects a
//! bounded delay ahead of `push`/`pop` to exercise backpressure paths.

use crate::util::fault;
use crate::util::sim::{Condvar, Mutex};
use std::collections::VecDeque;

struct Inner<T> {
    buf: VecDeque<T>,
    closed: bool,
}

/// Bounded multi-producer multi-consumer blocking queue.
pub struct BoundedQueue<T> {
    inner: Mutex<Inner<T>>,
    cap: usize,
    not_empty: Condvar,
    not_full: Condvar,
}

impl<T> BoundedQueue<T> {
    /// Queue holding at most `cap` items (`cap >= 1`).  The backing
    /// storage is allocated here, once.
    pub fn new(cap: usize) -> Self {
        assert!(cap >= 1, "queue capacity must be >= 1");
        Self {
            inner: Mutex::new(Inner { buf: VecDeque::with_capacity(cap), closed: false }),
            cap,
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
        }
    }

    /// Enqueue, blocking while the queue is full.  Returns the item back
    /// as `Err(item)` if the queue is (or, while blocked, becomes)
    /// closed — the item is then guaranteed **not** to have been
    /// enqueued, so the caller still owns it exclusively.  On `Ok(())`
    /// the item will be delivered by exactly one `pop` (close never
    /// discards accepted items).
    pub fn push(&self, item: T) -> Result<(), T> {
        if fault::inject(fault::QUEUE_STALL) {
            std::thread::sleep(fault::STALL);
        }
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if inner.closed {
                return Err(item);
            }
            if inner.buf.len() < self.cap {
                inner.buf.push_back(item);
                drop(inner);
                self.not_empty.notify_one();
                return Ok(());
            }
            inner = self.not_full.wait(inner).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Dequeue, blocking while the queue is empty and open.  `None`
    /// means closed *and* fully drained — items queued before `close`
    /// are always delivered, in FIFO order, each to exactly one popper.
    pub fn pop(&self) -> Option<T> {
        if fault::inject(fault::QUEUE_STALL) {
            std::thread::sleep(fault::STALL);
        }
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        loop {
            if let Some(item) = inner.buf.pop_front() {
                drop(inner);
                self.not_full.notify_one();
                return Some(item);
            }
            if inner.closed {
                return None;
            }
            inner = self.not_empty.wait(inner).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Non-blocking enqueue for callers that must never park (the net
    /// front-end's readiness loop).  `Ok(())` delivers exactly once,
    /// `Err(item)` hands the item back when the queue is full *or*
    /// closed — the caller distinguishes via [`Self::is_closed`] if it
    /// matters.  No fault injection here: the blocking twins already
    /// exercise [`crate::util::fault::QUEUE_STALL`], and a stall inside
    /// a readiness loop would be a busy-spin, not backpressure.
    pub fn try_push(&self, item: T) -> Result<(), T> {
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        if inner.closed || inner.buf.len() >= self.cap {
            return Err(item);
        }
        inner.buf.push_back(item);
        drop(inner);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Non-blocking dequeue: `None` when the queue is currently empty
    /// (open or closed — callers polling a closing pipeline check
    /// [`Self::is_closed`] to tell "drained for now" from "drained for
    /// good").  Wakes one blocked pusher on success, like [`Self::pop`].
    pub fn try_pop(&self) -> Option<T> {
        let mut inner = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        let item = inner.buf.pop_front()?;
        drop(inner);
        self.not_full.notify_one();
        Some(item)
    }

    /// Close the queue: every blocked pusher wakes and gets its item
    /// back as `Err`, every blocked popper wakes and drains the
    /// remaining items (which are never discarded) before `None`.
    /// Idempotent.
    pub fn close(&self) {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }

    /// Whether [`Self::close`] has been called.
    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).closed
    }

    /// Items currently queued.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).buf.len()
    }

    /// Whether the queue is currently empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn fifo_order() {
        let q = BoundedQueue::new(4);
        for i in 0..4 {
            q.push(i).unwrap();
        }
        assert_eq!(q.len(), 4);
        for i in 0..4 {
            assert_eq!(q.pop(), Some(i));
        }
        assert!(q.is_empty());
    }

    #[test]
    fn push_blocks_until_pop() {
        let q = Arc::new(BoundedQueue::new(1));
        q.push(0usize).unwrap();
        let q2 = Arc::clone(&q);
        let pushed = Arc::new(AtomicUsize::new(0));
        let pushed2 = Arc::clone(&pushed);
        // ari-lint: allow(sim-discipline): real-thread blocking leg — exercises the
        // actual OS condvar wakeup, which the sim scheduler abstracts away.
        let h = std::thread::spawn(move || {
            q2.push(1).unwrap(); // blocks: capacity 1, slot taken
            pushed2.store(1, Ordering::SeqCst);
        });
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(pushed.load(Ordering::SeqCst), 0, "push must block while full");
        assert_eq!(q.pop(), Some(0));
        h.join().unwrap();
        assert_eq!(pushed.load(Ordering::SeqCst), 1);
        assert_eq!(q.pop(), Some(1));
    }

    #[test]
    fn close_drains_then_none() {
        let q = BoundedQueue::new(4);
        q.push(1).unwrap();
        q.push(2).unwrap();
        q.close();
        assert!(q.is_closed());
        assert_eq!(q.push(3), Err(3), "push after close must fail");
        assert_eq!(q.pop(), Some(1), "close must not discard queued items");
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn close_wakes_blocked_popper() {
        let q = Arc::new(BoundedQueue::<u32>::new(2));
        let q2 = Arc::clone(&q);
        // ari-lint: allow(sim-discipline): real-thread blocking leg (see above).
        let h = std::thread::spawn(move || q2.pop());
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        assert_eq!(h.join().unwrap(), None);
    }

    #[test]
    fn close_wakes_blocked_pusher() {
        let q = Arc::new(BoundedQueue::new(1));
        q.push(7u32).unwrap();
        let q2 = Arc::clone(&q);
        // ari-lint: allow(sim-discipline): real-thread blocking leg (see above).
        let h = std::thread::spawn(move || q2.push(8));
        std::thread::sleep(Duration::from_millis(20));
        q.close();
        assert_eq!(h.join().unwrap(), Err(8));
    }

    /// A panic while holding the ring's lock must not take the queue
    /// down with it: the state is a plain ring, so later operations
    /// recover the guard and keep serving.
    #[test]
    fn operations_survive_a_poisoned_lock() {
        let q = Arc::new(BoundedQueue::new(2));
        q.push(1u32).unwrap();
        let q2 = Arc::clone(&q);
        // ari-lint: allow(sim-discipline): poisoning requires a real panicking thread;
        // sim threads abort the whole schedule on panic instead of poisoning locks.
        let _ = std::thread::spawn(move || {
            let _guard = q2.inner.lock();
            panic!("poison the queue lock");
        })
        .join();
        q.push(2).unwrap();
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        q.close();
        assert!(q.is_closed());
        assert_eq!(q.pop(), None);
    }

    /// An injected queue stall delays but never drops or reorders:
    /// FIFO delivery is unchanged with `queue-stall` armed at p=1.
    #[test]
    fn queue_stall_fault_delays_but_conserves() {
        let _g = fault::ArmGuard::arm("queue-stall:1.0:4");
        let q = BoundedQueue::new(4);
        for i in 0..4 {
            q.push(i).unwrap();
        }
        for i in 0..4 {
            assert_eq!(q.pop(), Some(i));
        }
    }

    /// The non-blocking twins: full/empty/closed all report via the
    /// return value without parking, and a `try_pop` success wakes a
    /// blocked pusher exactly like `pop` does.
    #[test]
    fn try_ops_never_block() {
        let q = BoundedQueue::new(1);
        assert_eq!(q.try_pop(), None, "empty queue: try_pop is None, not a hang");
        q.try_push(1u32).unwrap();
        assert_eq!(q.try_push(2), Err(2), "full queue: try_push hands the item back");
        assert_eq!(q.try_pop(), Some(1));
        q.close();
        assert_eq!(q.try_push(3), Err(3), "closed queue: try_push hands the item back");
        assert_eq!(q.try_pop(), None);
    }

    #[test]
    fn try_pop_wakes_blocked_pusher() {
        let q = Arc::new(BoundedQueue::new(1));
        q.push(0usize).unwrap();
        let q2 = Arc::clone(&q);
        // ari-lint: allow(sim-discipline): real-thread blocking leg (see above).
        let h = std::thread::spawn(move || q2.push(1));
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(q.try_pop(), Some(0));
        assert_eq!(h.join().unwrap(), Ok(()), "try_pop must notify not_full");
        assert_eq!(q.pop(), Some(1));
    }

    #[test]
    fn try_and_blocking_ops_interleave_fifo() {
        let q = BoundedQueue::new(4);
        q.push(1).unwrap();
        q.try_push(2).unwrap();
        q.push(3).unwrap();
        assert_eq!(q.try_pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.try_pop(), Some(3));
    }

    #[test]
    fn buffers_circulate_without_growth() {
        // The serving pipeline's usage: a fixed set of buffers bouncing
        // between two queues.
        let fwd = BoundedQueue::new(2);
        let back = BoundedQueue::new(2);
        back.push(Vec::<f32>::with_capacity(64)).unwrap();
        back.push(Vec::<f32>::with_capacity(64)).unwrap();
        for round in 0..100 {
            let mut buf = back.pop().unwrap();
            buf.clear();
            buf.push(round as f32);
            fwd.push(buf).unwrap();
            let buf = fwd.pop().unwrap();
            assert_eq!(buf[0], round as f32);
            assert!(buf.capacity() >= 64);
            back.push(buf).unwrap();
        }
    }
}
