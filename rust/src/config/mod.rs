//! Configuration: CLI-facing structures parsed from mini-TOML files
//! and/or command-line `key=value` overrides.
//!
//! Example config (see `examples/configs/serving.toml`):
//!
//! ```toml
//! [ari]
//! dataset = "fashion_syn"
//! mode = "fp"            # fp | sc
//! reduced_level = 10     # FP bits or SC sequence length
//! threshold = "mmax"     # mmax | m99 | m95 | a float
//!
//! [server]
//! batch_size = 32
//! batch_timeout_us = 2000
//! requests = 2048
//! arrival_rate = 4000.0  # req/s, open-loop poisson
//! ```

use crate::util::minitoml::Doc;
use std::path::PathBuf;

/// Threshold selection policy (paper §III-C).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ThresholdPolicy {
    /// Cover every element that changed class on the calibration set.
    MMax,
    /// Cover 99% of changed elements.
    M99,
    /// Cover 95% of changed elements.
    M95,
    /// Fixed user-supplied threshold.
    Fixed(f64),
}

impl ThresholdPolicy {
    /// Parse `mmax | m99 | m95 | <float>`.
    pub fn parse(s: &str) -> crate::Result<Self> {
        Ok(match s {
            "mmax" => ThresholdPolicy::MMax,
            "m99" => ThresholdPolicy::M99,
            "m95" => ThresholdPolicy::M95,
            other => ThresholdPolicy::Fixed(
                other.parse().map_err(|_| anyhow::anyhow!("bad threshold {other:?} (mmax|m99|m95|<float>)"))?,
            ),
        })
    }

    /// Coverage fraction for percentile policies.
    pub fn coverage(&self) -> Option<f64> {
        match self {
            ThresholdPolicy::MMax => Some(1.0),
            ThresholdPolicy::M99 => Some(0.99),
            ThresholdPolicy::M95 => Some(0.95),
            ThresholdPolicy::Fixed(_) => None,
        }
    }
}

impl std::fmt::Display for ThresholdPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ThresholdPolicy::MMax => write!(f, "Mmax"),
            ThresholdPolicy::M99 => write!(f, "M99"),
            ThresholdPolicy::M95 => write!(f, "M95"),
            ThresholdPolicy::Fixed(t) => write!(f, "T={t}"),
        }
    }
}

/// Resolution family.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    /// Truncated-mantissa floating point (levels are bit widths).
    Fp,
    /// Stochastic computing (levels are sequence lengths).
    Sc,
}

impl Mode {
    /// Parse `fp | sc`.
    pub fn parse(s: &str) -> crate::Result<Self> {
        match s {
            "fp" => Ok(Mode::Fp),
            "sc" => Ok(Mode::Sc),
            other => anyhow::bail!("bad mode {other:?} (fp|sc)"),
        }
    }

    /// The manifest [`crate::data::VariantKind`] of this mode.
    pub fn kind(&self) -> crate::data::VariantKind {
        match self {
            Mode::Fp => crate::data::VariantKind::Fp,
            Mode::Sc => crate::data::VariantKind::Sc,
        }
    }
}

/// Full server/cascade configuration.
#[derive(Clone, Debug)]
pub struct AriConfig {
    /// Artifacts directory (manifest + datasets).
    pub artifacts: PathBuf,
    /// Dataset to serve.
    pub dataset: String,
    /// Resolution family of the cascade.
    pub mode: Mode,
    /// FP bit width or SC sequence length of the reduced model.
    pub reduced_level: usize,
    /// Level of the full model (FP16 / L=4096 by default).
    pub full_level: usize,
    /// Explicit N-level resolution ladder (strictly ascending; the last
    /// entry is the full model).  Empty means the 2-level
    /// `[reduced_level, full_level]` cascade — see
    /// [`AriConfig::ladder_levels`].
    pub levels: Vec<usize>,
    /// Threshold selection policy.
    pub threshold: ThresholdPolicy,
    /// Fraction of the eval split used for threshold calibration.
    pub calib_fraction: f64,
    /// Serving batch size (must match a compiled variant batch).
    pub batch_size: usize,
    /// Batcher deadline: max microseconds a request waits for a batch.
    pub batch_timeout_us: u64,
    /// Number of requests a serving session generates.
    pub requests: usize,
    /// Open-loop Poisson arrival rate (req/s); 0 = closed loop.
    pub arrival_rate: f64,
    /// Workload / SC-key seed.
    pub seed: u64,
    /// Per-request deadline in µs from submission; requests already
    /// past it at dispatch are rejected unserved.  0 disables deadlines.
    pub deadline_us: u64,
    /// Max retries per batch after a transient backend error/panic
    /// before the batch's requests are marked failed.
    pub retries: u32,
    /// Base backoff between backend retries in µs (attempt `k` waits
    /// `k * retry_backoff_us`).
    pub retry_backoff_us: u64,
    /// Overload threshold on pipeline depth: when staged + escalation
    /// backlog reaches this many requests, the dispatcher stops
    /// escalating and serves reduced-stage answers flagged degraded.
    /// 0 disables the depth trigger.
    pub overload_queue: usize,
    /// Overload threshold on observed p95 latency in µs (same
    /// degraded-mode response).  0 disables the latency trigger.
    pub overload_p95_us: u64,
    /// Batching-thread watchdog: a heartbeat stalled longer than this
    /// many µs fails the session diagnostically instead of hanging.
    /// 0 disables the watchdog.
    pub watchdog_stall_us: u64,
    /// TCP listen address for the network serving tier (`[net] listen`,
    /// e.g. `"127.0.0.1:7070"`).  Empty (the default) disables the
    /// front-end entirely: serving runs the in-process generator path,
    /// bit-identical to a build without the net module.
    pub listen: String,
    /// Accepted-connection cap; excess accepts are refused immediately.
    pub net_max_conns: usize,
    /// Slow-loris read deadline in µs: a connection dangling a partial
    /// frame longer than this is closed with a typed `Stalled` error.
    /// 0 disables.
    pub net_read_deadline_us: u64,
    /// Per-connection admitted-but-unanswered request cap; excess
    /// requests are shed with typed `Rejected` responses.
    pub net_max_in_flight: usize,
    /// Per-connection encoded-but-unflushed response byte cap; past it
    /// new requests are shed until the socket drains.
    pub net_write_buf_cap: usize,
    /// Grace period in µs: a peer accepting no bytes for this long is
    /// dropped, and an idle listener with no connections left begins
    /// shutdown after it.
    pub net_linger_us: u64,
    /// Serve with per-class stage thresholds (`T_i[c]` keyed by the
    /// stage's predicted class) instead of one global `T_i` per stage.
    /// Off by default: global thresholds, bit-identical serving.
    pub control_per_class: bool,
    /// Enable the load-adaptive controller: queue depth and
    /// sliding-window p95 tighten/relax thresholds with hysteresis.
    /// Off by default.
    pub control_load_adaptive: bool,
    /// Enable drift detection + bounded online recalibration of the
    /// stage-0 threshold from a sliding margin window.  Off by default.
    pub control_drift: bool,
    /// Sliding latency window length (samples) used for the control
    /// loop's p95 signal *and* the `server.overload_p95_us` trigger.
    pub control_window: usize,
    /// Sliding-window p95 (µs) above which the controller tightens one
    /// step.  0 disables the latency signal.
    pub control_p95_high_us: u64,
    /// Sliding-window p95 (µs) below which the controller may relax one
    /// step (together with a drained queue).
    pub control_p95_low_us: u64,
    /// Queue depth (requests) at or above which the controller tightens
    /// one step.  0 disables the depth signal.
    pub control_queue_high: usize,
    /// Queue depth at or below which the controller may relax one step.
    pub control_queue_low: usize,
    /// Hysteresis hold: a signal must persist for this many consecutive
    /// dispatched batches before the controller moves one step.
    pub control_hold: u32,
    /// Threshold delta per tighten step (thresholds move down by
    /// `step` per level, clamped at 0 — fewer escalations).
    pub control_step: f64,
    /// Maximum tighten level (`max_steps * step` is the largest
    /// threshold reduction the load controller may apply).
    pub control_max_steps: u32,
    /// Sliding window length (stage-0 margin samples) for the drift
    /// monitor.
    pub control_drift_window: usize,
    /// Drift tolerance: absolute deviation of the windowed stage-0
    /// escalation fraction from the calibration-time baseline that
    /// flags drift and triggers recalibration.
    pub control_drift_tolerance: f64,
    /// Minimum fresh margin samples between recalibrations (bounds the
    /// recalibration rate).
    pub control_recal_min: usize,
    /// Clamp on recalibration: the refreshed threshold may move at most
    /// this far from the offline-calibrated value.
    pub control_recal_clamp: f64,
}

impl Default for AriConfig {
    fn default() -> Self {
        Self {
            artifacts: PathBuf::from("artifacts"),
            dataset: "fashion_syn".into(),
            mode: Mode::Fp,
            reduced_level: 10,
            full_level: 16,
            levels: Vec::new(),
            threshold: ThresholdPolicy::MMax,
            calib_fraction: 0.5,
            batch_size: 32,
            batch_timeout_us: 2000,
            requests: 2048,
            arrival_rate: 0.0,
            seed: 0xA41,
            deadline_us: 0,
            retries: 2,
            retry_backoff_us: 200,
            overload_queue: 0,
            overload_p95_us: 0,
            watchdog_stall_us: 3_000_000,
            listen: String::new(),
            net_max_conns: 64,
            net_read_deadline_us: 2_000_000,
            net_max_in_flight: 256,
            net_write_buf_cap: 65_536,
            net_linger_us: 1_000_000,
            control_per_class: false,
            control_load_adaptive: false,
            control_drift: false,
            control_window: 64,
            control_p95_high_us: 20_000,
            control_p95_low_us: 5_000,
            control_queue_high: 64,
            control_queue_low: 8,
            control_hold: 3,
            control_step: 0.1,
            control_max_steps: 4,
            control_drift_window: 256,
            control_drift_tolerance: 0.2,
            control_recal_min: 64,
            control_recal_clamp: 0.5,
        }
    }
}

impl AriConfig {
    /// Load from a mini-TOML file.
    pub fn from_file(path: &std::path::Path) -> crate::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let doc = Doc::parse(&text)?;
        let mut cfg = Self::default();
        cfg.apply_doc(&doc)?;
        Ok(cfg)
    }

    /// Apply a parsed document on top of the current values.
    pub fn apply_doc(&mut self, doc: &Doc) -> crate::Result<()> {
        if let Some(v) = doc.get_str("ari", "dataset") {
            self.dataset = v.to_string();
        }
        if let Some(v) = doc.get_str("ari", "artifacts") {
            self.artifacts = PathBuf::from(v);
        }
        if let Some(v) = doc.get_str("ari", "mode") {
            self.mode = Mode::parse(v)?;
            // keep full_level consistent with the family default — but
            // never behind an explicit ladder's back: with `levels` set,
            // full_level must keep mirroring its last rung (switching
            // family then requires supplying matching levels).
            if self.mode == Mode::Sc && self.full_level == 16 && self.levels.is_empty() {
                self.full_level = 4096;
            }
        }
        // `levels` is applied before the endpoint keys so that a
        // document (or one batch of CLI overrides) carrying both a
        // ladder and a reduced_level/full_level composes: the ladder is
        // installed first, then the endpoint updates its rung.
        if let Some(v) = doc.get("ari", "levels") {
            let arr = v.as_array().ok_or_else(|| anyhow::anyhow!("ari.levels must be an array, got {v}"))?;
            let mut levels = Vec::with_capacity(arr.len());
            for item in arr {
                let l = item.as_int().ok_or_else(|| anyhow::anyhow!("ari.levels entries must be integers, got {item}"))?;
                anyhow::ensure!(l > 0, "ari.levels entries must be positive, got {l}");
                levels.push(l as usize);
            }
            anyhow::ensure!(levels.len() >= 2, "ari.levels needs at least 2 stages, got {levels:?}");
            anyhow::ensure!(
                levels.windows(2).all(|w| w[0] < w[1]),
                "ari.levels must be strictly increasing (reduced -> full), got {levels:?}"
            );
            self.reduced_level = levels[0];
            self.full_level = *levels.last().unwrap();
            self.levels = levels;
        }
        if let Some(v) = doc.get_int("ari", "reduced_level") {
            let v = v as usize;
            // keep an explicit ladder's first rung in sync — validated
            // on a candidate so a rejected override leaves the config
            // untouched.
            if !self.levels.is_empty() {
                let mut candidate = self.levels.clone();
                candidate[0] = v;
                anyhow::ensure!(
                    candidate.windows(2).all(|w| w[0] < w[1]),
                    "reduced_level {v} breaks the configured ladder {:?} (must stay strictly increasing)",
                    self.levels
                );
                self.levels = candidate;
            }
            self.reduced_level = v;
        }
        if let Some(v) = doc.get_int("ari", "full_level") {
            let v = v as usize;
            if !self.levels.is_empty() {
                let mut candidate = self.levels.clone();
                *candidate.last_mut().unwrap() = v;
                anyhow::ensure!(
                    candidate.windows(2).all(|w| w[0] < w[1]),
                    "full_level {v} breaks the configured ladder {:?} (must stay strictly increasing)",
                    self.levels
                );
                self.levels = candidate;
            }
            self.full_level = v;
        }
        if let Some(v) = doc.get_str("ari", "threshold") {
            self.threshold = ThresholdPolicy::parse(v)?;
        } else if let Some(v) = doc.get_float("ari", "threshold") {
            self.threshold = ThresholdPolicy::Fixed(v);
        }
        if let Some(v) = doc.get_float("ari", "calib_fraction") {
            anyhow::ensure!(v > 0.0 && v < 1.0, "calib_fraction must be in (0,1)");
            self.calib_fraction = v;
        }
        if let Some(v) = doc.get_int("server", "batch_size") {
            self.batch_size = v as usize;
        }
        if let Some(v) = doc.get_int("server", "batch_timeout_us") {
            self.batch_timeout_us = v as u64;
        }
        if let Some(v) = doc.get_int("server", "requests") {
            self.requests = v as usize;
        }
        if let Some(v) = doc.get_float("server", "arrival_rate") {
            self.arrival_rate = v;
        }
        if let Some(v) = doc.get_int("server", "seed") {
            self.seed = v as u64;
        }
        if let Some(v) = doc.get_int("server", "deadline_us") {
            anyhow::ensure!(v >= 0, "server.deadline_us must be >= 0, got {v}");
            self.deadline_us = v as u64;
        }
        if let Some(v) = doc.get_int("server", "retries") {
            anyhow::ensure!((0..=64).contains(&v), "server.retries must be in 0..=64, got {v}");
            self.retries = v as u32;
        }
        if let Some(v) = doc.get_int("server", "retry_backoff_us") {
            anyhow::ensure!(v >= 0, "server.retry_backoff_us must be >= 0, got {v}");
            self.retry_backoff_us = v as u64;
        }
        if let Some(v) = doc.get_int("server", "overload_queue") {
            anyhow::ensure!(v >= 0, "server.overload_queue must be >= 0, got {v}");
            self.overload_queue = v as usize;
        }
        if let Some(v) = doc.get_int("server", "overload_p95_us") {
            anyhow::ensure!(v >= 0, "server.overload_p95_us must be >= 0, got {v}");
            self.overload_p95_us = v as u64;
        }
        if let Some(v) = doc.get_int("server", "watchdog_stall_us") {
            anyhow::ensure!(v >= 0, "server.watchdog_stall_us must be >= 0, got {v}");
            self.watchdog_stall_us = v as u64;
        }
        if let Some(v) = doc.get_str("net", "listen") {
            self.listen = v.to_string();
        }
        if let Some(v) = doc.get_int("net", "max_conns") {
            anyhow::ensure!(v > 0, "net.max_conns must be > 0, got {v}");
            self.net_max_conns = v as usize;
        }
        if let Some(v) = doc.get_int("net", "read_deadline_us") {
            anyhow::ensure!(v >= 0, "net.read_deadline_us must be >= 0, got {v}");
            self.net_read_deadline_us = v as u64;
        }
        if let Some(v) = doc.get_int("net", "max_in_flight") {
            anyhow::ensure!(v > 0, "net.max_in_flight must be > 0, got {v}");
            self.net_max_in_flight = v as usize;
        }
        if let Some(v) = doc.get_int("net", "write_buf_cap") {
            anyhow::ensure!(v > 0, "net.write_buf_cap must be > 0, got {v}");
            self.net_write_buf_cap = v as usize;
        }
        if let Some(v) = doc.get_int("net", "linger_us") {
            anyhow::ensure!(v >= 0, "net.linger_us must be >= 0, got {v}");
            self.net_linger_us = v as u64;
        }
        if let Some(v) = doc.get_bool("control", "per_class") {
            self.control_per_class = v;
        }
        if let Some(v) = doc.get_bool("control", "load_adaptive") {
            self.control_load_adaptive = v;
        }
        if let Some(v) = doc.get_bool("control", "drift") {
            self.control_drift = v;
        }
        if let Some(v) = doc.get_int("control", "window") {
            anyhow::ensure!(v >= 16, "control.window must be >= 16 samples, got {v}");
            self.control_window = v as usize;
        }
        if let Some(v) = doc.get_int("control", "p95_high_us") {
            anyhow::ensure!(v >= 0, "control.p95_high_us must be >= 0, got {v}");
            self.control_p95_high_us = v as u64;
        }
        if let Some(v) = doc.get_int("control", "p95_low_us") {
            anyhow::ensure!(v >= 0, "control.p95_low_us must be >= 0, got {v}");
            self.control_p95_low_us = v as u64;
        }
        if let Some(v) = doc.get_int("control", "queue_high") {
            anyhow::ensure!(v >= 0, "control.queue_high must be >= 0, got {v}");
            self.control_queue_high = v as usize;
        }
        if let Some(v) = doc.get_int("control", "queue_low") {
            anyhow::ensure!(v >= 0, "control.queue_low must be >= 0, got {v}");
            self.control_queue_low = v as usize;
        }
        if let Some(v) = doc.get_int("control", "hold") {
            anyhow::ensure!(v >= 1, "control.hold must be >= 1 batch, got {v}");
            self.control_hold = v as u32;
        }
        if let Some(v) = doc.get_float("control", "step") {
            anyhow::ensure!(v > 0.0, "control.step must be > 0, got {v}");
            self.control_step = v;
        }
        if let Some(v) = doc.get_int("control", "max_steps") {
            anyhow::ensure!(v >= 1, "control.max_steps must be >= 1, got {v}");
            self.control_max_steps = v as u32;
        }
        if let Some(v) = doc.get_int("control", "drift_window") {
            anyhow::ensure!(v >= 16, "control.drift_window must be >= 16 samples, got {v}");
            self.control_drift_window = v as usize;
        }
        if let Some(v) = doc.get_float("control", "drift_tolerance") {
            anyhow::ensure!(v > 0.0 && v <= 1.0, "control.drift_tolerance must be in (0,1], got {v}");
            self.control_drift_tolerance = v;
        }
        if let Some(v) = doc.get_int("control", "recal_min") {
            anyhow::ensure!(v >= 1, "control.recal_min must be >= 1 sample, got {v}");
            self.control_recal_min = v as usize;
        }
        if let Some(v) = doc.get_float("control", "recal_clamp") {
            anyhow::ensure!(v >= 0.0, "control.recal_clamp must be >= 0, got {v}");
            self.control_recal_clamp = v;
        }
        // Hysteresis sanity: the relax band must sit strictly below the
        // tighten band or the controller could oscillate on one signal.
        anyhow::ensure!(
            self.control_queue_high == 0 || self.control_queue_low < self.control_queue_high,
            "control.queue_low ({}) must be < control.queue_high ({})",
            self.control_queue_low,
            self.control_queue_high
        );
        anyhow::ensure!(
            self.control_p95_high_us == 0 || self.control_p95_low_us < self.control_p95_high_us,
            "control.p95_low_us ({}) must be < control.p95_high_us ({})",
            self.control_p95_low_us,
            self.control_p95_high_us
        );
        Ok(())
    }

    /// The resolution ladder this configuration describes: the explicit
    /// `levels` when set, else the paper's 2-level
    /// `[reduced_level, full_level]` cascade.
    pub fn ladder_levels(&self) -> Vec<usize> {
        if self.levels.is_empty() {
            vec![self.reduced_level, self.full_level]
        } else {
            self.levels.clone()
        }
    }

    /// Apply `section.key=value` command-line overrides.
    pub fn apply_overrides(&mut self, overrides: &[String]) -> crate::Result<()> {
        if overrides.is_empty() {
            return Ok(());
        }
        // Reuse the TOML value parser by synthesising a document.
        let mut by_section: std::collections::BTreeMap<&str, Vec<(&str, &str)>> = Default::default();
        for ov in overrides {
            let (path, value) = ov
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("override must be section.key=value: {ov:?}"))?;
            let (section, key) = path.split_once('.').unwrap_or(("ari", path));
            by_section.entry(section).or_default().push((key, value));
        }
        let mut text = String::new();
        for (section, kvs) in by_section {
            text.push_str(&format!("[{section}]\n"));
            for (k, v) in kvs {
                // values that don't parse as numbers/bools/arrays are strings
                let quoted = if v.parse::<f64>().is_ok() || v == "true" || v == "false" || v.starts_with('[') {
                    v.to_string()
                } else {
                    format!("\"{v}\"")
                };
                text.push_str(&format!("{k} = {quoted}\n"));
            }
        }
        self.apply_doc(&Doc::parse(&text)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = AriConfig::default();
        assert_eq!(c.full_level, 16);
        assert_eq!(c.threshold, ThresholdPolicy::MMax);
    }

    #[test]
    fn parse_threshold_policies() {
        assert_eq!(ThresholdPolicy::parse("mmax").unwrap(), ThresholdPolicy::MMax);
        assert_eq!(ThresholdPolicy::parse("m99").unwrap(), ThresholdPolicy::M99);
        assert_eq!(ThresholdPolicy::parse("0.25").unwrap(), ThresholdPolicy::Fixed(0.25));
        assert!(ThresholdPolicy::parse("nope").is_err());
        assert_eq!(ThresholdPolicy::M95.coverage(), Some(0.95));
        assert_eq!(ThresholdPolicy::Fixed(0.1).coverage(), None);
    }

    #[test]
    fn apply_doc_full() {
        let doc = Doc::parse(
            r#"
[ari]
dataset = "svhn_syn"
mode = "sc"
reduced_level = 512
threshold = "m99"
[server]
batch_size = 64
arrival_rate = 1000.5
"#,
        )
        .unwrap();
        let mut c = AriConfig::default();
        c.apply_doc(&doc).unwrap();
        assert_eq!(c.dataset, "svhn_syn");
        assert_eq!(c.mode, Mode::Sc);
        assert_eq!(c.full_level, 4096); // switched with mode
        assert_eq!(c.reduced_level, 512);
        assert_eq!(c.threshold, ThresholdPolicy::M99);
        assert_eq!(c.batch_size, 64);
        assert!((c.arrival_rate - 1000.5).abs() < 1e-9);
    }

    #[test]
    fn overrides_parse() {
        let mut c = AriConfig::default();
        c.apply_overrides(&[
            "dataset=cifar10_syn".into(),
            "server.batch_size=128".into(),
            "threshold=m95".into(),
        ])
        .unwrap();
        assert_eq!(c.dataset, "cifar10_syn");
        assert_eq!(c.batch_size, 128);
        assert_eq!(c.threshold, ThresholdPolicy::M95);
    }

    #[test]
    fn bad_overrides_rejected() {
        let mut c = AriConfig::default();
        assert!(c.apply_overrides(&["no-equals".into()]).is_err());
        assert!(c.apply_overrides(&["ari.mode=xyz".into()]).is_err());
        assert!(c.apply_overrides(&["ari.calib_fraction=1.5".into()]).is_err());
    }

    #[test]
    fn ladder_levels_defaults_to_reduced_full_pair() {
        let c = AriConfig::default();
        assert!(c.levels.is_empty());
        assert_eq!(c.ladder_levels(), vec![10, 16]);
    }

    #[test]
    fn levels_parse_and_sync_endpoints() {
        let mut c = AriConfig::default();
        c.apply_overrides(&["levels=[8,12,16]".into()]).unwrap();
        assert_eq!(c.levels, vec![8, 12, 16]);
        assert_eq!(c.reduced_level, 8);
        assert_eq!(c.full_level, 16);
        assert_eq!(c.ladder_levels(), vec![8, 12, 16]);
        // A later reduced_level override updates the first rung.
        c.apply_overrides(&["reduced_level=10".into()]).unwrap();
        assert_eq!(c.levels, vec![10, 12, 16]);
        // Both keys in ONE batch compose too: the ladder is installed
        // first, then the endpoint updates its rung.
        let mut c = AriConfig::default();
        c.apply_overrides(&["levels=[8,12,16]".into(), "reduced_level=10".into()]).unwrap();
        assert_eq!(c.levels, vec![10, 12, 16]);
        assert_eq!(c.reduced_level, 10);
    }

    /// Switching the resolution family must not re-default full_level
    /// behind an explicit ladder's back.
    #[test]
    fn mode_switch_does_not_desync_explicit_ladder() {
        let mut c = AriConfig::default();
        c.apply_overrides(&["levels=[8,12,16]".into()]).unwrap();
        c.apply_overrides(&["mode=sc".into()]).unwrap();
        assert_eq!(c.full_level, 16, "full_level must keep mirroring the ladder's last rung");
        assert_eq!(c.levels, vec![8, 12, 16]);
        // Without a ladder the family default still applies.
        let mut c = AriConfig::default();
        c.apply_overrides(&["mode=sc".into()]).unwrap();
        assert_eq!(c.full_level, 4096);
    }

    /// An endpoint override may not corrupt an explicit ladder's ascent —
    /// and a rejected override must leave the config untouched.
    #[test]
    fn endpoint_overrides_cannot_break_ladder() {
        for bad in ["reduced_level=14", "reduced_level=12", "full_level=11"] {
            let mut c = AriConfig::default();
            c.apply_overrides(&["levels=[8,12,16]".into()]).unwrap();
            assert!(c.apply_overrides(&[bad.into()]).is_err(), "{bad} must be rejected");
            assert_eq!(c.levels, vec![8, 12, 16], "{bad} must not corrupt the ladder");
            assert_eq!(c.reduced_level, 8);
            assert_eq!(c.full_level, 16);
        }
    }

    /// The robustness keys default OFF (bit-identical serving) and
    /// parse from the `[server]` section with range validation.
    #[test]
    fn robustness_keys_parse_and_validate() {
        let c = AriConfig::default();
        assert_eq!(c.deadline_us, 0, "deadlines default off");
        assert_eq!(c.overload_queue, 0, "depth trigger defaults off");
        assert_eq!(c.overload_p95_us, 0, "latency trigger defaults off");
        assert_eq!(c.retries, 2);
        assert_eq!(c.retry_backoff_us, 200);
        assert_eq!(c.watchdog_stall_us, 3_000_000);
        let mut c = AriConfig::default();
        c.apply_overrides(&[
            "server.deadline_us=5000".into(),
            "server.retries=4".into(),
            "server.retry_backoff_us=50".into(),
            "server.overload_queue=96".into(),
            "server.overload_p95_us=20000".into(),
            "server.watchdog_stall_us=1000000".into(),
        ])
        .unwrap();
        assert_eq!(c.deadline_us, 5000);
        assert_eq!(c.retries, 4);
        assert_eq!(c.retry_backoff_us, 50);
        assert_eq!(c.overload_queue, 96);
        assert_eq!(c.overload_p95_us, 20000);
        assert_eq!(c.watchdog_stall_us, 1_000_000);
        let mut c = AriConfig::default();
        assert!(c.apply_overrides(&["server.retries=65".into()]).is_err(), "retry cap");
        assert!(c.apply_overrides(&["server.deadline_us=-1".into()]).is_err(), "negative deadline");
    }

    /// The `[net]` keys: listen defaults empty (front-end off, serving
    /// bit-identical to the in-process path), supervision knobs parse
    /// with range validation, and a rejected value leaves the config
    /// untouched.
    #[test]
    fn net_keys_parse_and_validate() {
        let c = AriConfig::default();
        assert!(c.listen.is_empty(), "net front-end defaults off");
        assert_eq!(c.net_max_conns, 64);
        assert_eq!(c.net_read_deadline_us, 2_000_000);
        assert_eq!(c.net_max_in_flight, 256);
        assert_eq!(c.net_write_buf_cap, 65_536);
        assert_eq!(c.net_linger_us, 1_000_000);
        let mut c = AriConfig::default();
        c.apply_overrides(&[
            "net.listen=127.0.0.1:7070".into(),
            "net.max_conns=8".into(),
            "net.read_deadline_us=500000".into(),
            "net.max_in_flight=32".into(),
            "net.write_buf_cap=4096".into(),
            "net.linger_us=250000".into(),
        ])
        .unwrap();
        assert_eq!(c.listen, "127.0.0.1:7070");
        assert_eq!(c.net_max_conns, 8);
        assert_eq!(c.net_read_deadline_us, 500_000);
        assert_eq!(c.net_max_in_flight, 32);
        assert_eq!(c.net_write_buf_cap, 4096);
        assert_eq!(c.net_linger_us, 250_000);
        let mut c = AriConfig::default();
        assert!(c.apply_overrides(&["net.max_conns=0".into()]).is_err(), "zero conn cap");
        assert!(c.apply_overrides(&["net.max_in_flight=0".into()]).is_err(), "zero in-flight cap");
        assert!(c.apply_overrides(&["net.read_deadline_us=-1".into()]).is_err(), "negative deadline");
        assert_eq!(c.net_max_conns, 64, "rejected override must not corrupt the config");
    }

    /// The `[control]` keys: every adaptive mode defaults OFF (serving
    /// bit-identical to a static-threshold build), tuning knobs parse
    /// with range validation, and inverted hysteresis bands are
    /// rejected.
    #[test]
    fn control_keys_parse_and_validate() {
        let c = AriConfig::default();
        assert!(!c.control_per_class, "per-class mode defaults off");
        assert!(!c.control_load_adaptive, "load controller defaults off");
        assert!(!c.control_drift, "drift monitor defaults off");
        assert_eq!(c.control_window, 64);
        assert_eq!(c.control_p95_high_us, 20_000);
        assert_eq!(c.control_p95_low_us, 5_000);
        assert_eq!(c.control_queue_high, 64);
        assert_eq!(c.control_queue_low, 8);
        assert_eq!(c.control_hold, 3);
        assert!((c.control_step - 0.1).abs() < 1e-12);
        assert_eq!(c.control_max_steps, 4);
        assert_eq!(c.control_drift_window, 256);
        assert!((c.control_drift_tolerance - 0.2).abs() < 1e-12);
        assert_eq!(c.control_recal_min, 64);
        assert!((c.control_recal_clamp - 0.5).abs() < 1e-12);
        let mut c = AriConfig::default();
        c.apply_overrides(&[
            "control.per_class=true".into(),
            "control.load_adaptive=true".into(),
            "control.drift=true".into(),
            "control.window=32".into(),
            "control.p95_high_us=10000".into(),
            "control.p95_low_us=2000".into(),
            "control.queue_high=128".into(),
            "control.queue_low=16".into(),
            "control.hold=2".into(),
            "control.step=0.05".into(),
            "control.max_steps=6".into(),
            "control.drift_window=128".into(),
            "control.drift_tolerance=0.15".into(),
            "control.recal_min=32".into(),
            "control.recal_clamp=0.25".into(),
        ])
        .unwrap();
        assert!(c.control_per_class && c.control_load_adaptive && c.control_drift);
        assert_eq!(c.control_window, 32);
        assert_eq!(c.control_p95_high_us, 10_000);
        assert_eq!(c.control_p95_low_us, 2_000);
        assert_eq!(c.control_queue_high, 128);
        assert_eq!(c.control_queue_low, 16);
        assert_eq!(c.control_hold, 2);
        assert!((c.control_step - 0.05).abs() < 1e-12);
        assert_eq!(c.control_max_steps, 6);
        assert_eq!(c.control_drift_window, 128);
        assert!((c.control_drift_tolerance - 0.15).abs() < 1e-12);
        assert_eq!(c.control_recal_min, 32);
        assert!((c.control_recal_clamp - 0.25).abs() < 1e-12);
        let mut c = AriConfig::default();
        assert!(c.apply_overrides(&["control.window=8".into()]).is_err(), "window floor");
        assert!(c.apply_overrides(&["control.hold=0".into()]).is_err(), "zero hold");
        assert!(c.apply_overrides(&["control.step=0".into()]).is_err(), "zero step");
        assert!(c.apply_overrides(&["control.drift_tolerance=1.5".into()]).is_err(), "tolerance range");
        assert!(
            c.apply_overrides(&["control.queue_low=200".into()]).is_err(),
            "relax band above tighten band must be rejected"
        );
        assert!(
            c.apply_overrides(&["control.p95_low_us=30000".into()]).is_err(),
            "p95 relax band above tighten band must be rejected"
        );
        assert_eq!(c.control_window, 64, "rejected override must not corrupt the config");
    }

    #[test]
    fn bad_levels_rejected() {
        let mut c = AriConfig::default();
        assert!(c.apply_overrides(&["levels=[16]".into()]).is_err(), "single-level ladder");
        assert!(c.apply_overrides(&["levels=[16,8]".into()]).is_err(), "descending ladder");
        assert!(c.apply_overrides(&["levels=[8,8,16]".into()]).is_err(), "duplicate level");
        assert_eq!(c.levels, Vec::<usize>::new(), "rejected levels must not stick");
    }
}
