//! Stochastic number generators: value -> bitstream.
//!
//! A bipolar SNG encodes v in [-1, 1] as a Bernoulli stream with
//! P(bit = 1) = (v + 1) / 2, by comparing the probability threshold
//! against successive LFSR states — exactly the comparator circuit of the
//! paper's Fig. 4, and bit-identical to the python twin
//! (`ref.sng_bipolar`): bit = (state < floor(p * 2^width)).

use super::lfsr::Lfsr;

/// Generator of one bipolar stochastic stream.
#[derive(Clone, Debug)]
pub struct Sng {
    lfsr: Lfsr,
    threshold: u32,
}

impl Sng {
    /// Encode `value` (clamped into [-1, 1]) using an LFSR of `width`
    /// bits seeded with `seed`.
    pub fn bipolar(value: f64, width: u32, seed: u64) -> Self {
        let v = value.clamp(-1.0, 1.0);
        let p = (v + 1.0) / 2.0;
        let denom = (1u64 << width) as f64;
        let threshold = (p * denom).floor() as u32;
        Self { lfsr: Lfsr::new(width, seed), threshold }
    }

    /// Next bit of the stream.
    #[inline]
    pub fn next_bit(&mut self) -> bool {
        self.lfsr.next_state() < self.threshold
    }

    /// Generate `n` bits packed into u64 words (LSB-first within a word).
    pub fn bits_packed(&mut self, n: usize) -> Vec<u64> {
        let mut words = vec![0u64; n.div_ceil(64)];
        for t in 0..n {
            if self.next_bit() {
                words[t / 64] |= 1u64 << (t % 64);
            }
        }
        words
    }

    /// Decode a packed stream of `n` bits back to a bipolar value.
    pub fn decode_bipolar(words: &[u64], n: usize) -> f64 {
        let ones: u32 = count_ones(words, n);
        2.0 * ones as f64 / n as f64 - 1.0
    }
}

/// Popcount over the first `n` bits of a packed stream.
pub fn count_ones(words: &[u64], n: usize) -> u32 {
    let full = n / 64;
    let mut ones: u32 = words[..full].iter().map(|w| w.count_ones()).sum();
    let rem = n % 64;
    if rem > 0 {
        ones += (words[full] & ((1u64 << rem) - 1)).count_ones();
    }
    ones
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_mean_tracks_value() {
        for (value, seed) in [(0.0, 3u64), (0.5, 5), (-0.7, 7), (0.97, 11)] {
            let width = 12;
            let n = (1usize << width) - 1; // full period
            let mut sng = Sng::bipolar(value, width, seed);
            let words = sng.bits_packed(n);
            let decoded = Sng::decode_bipolar(&words, n);
            assert!((decoded - value).abs() < 3.5 / (1 << width) as f64 + 1e-9, "{value} -> {decoded}");
        }
    }

    #[test]
    fn extreme_values() {
        let mut all_ones = Sng::bipolar(1.0, 8, 1);
        let w = all_ones.bits_packed(255);
        assert_eq!(count_ones(&w, 255), 255);
        let mut all_zeros = Sng::bipolar(-1.0, 8, 1);
        let w = all_zeros.bits_packed(255);
        assert_eq!(count_ones(&w, 255), 0);
    }

    #[test]
    fn clamps_out_of_range() {
        let mut s = Sng::bipolar(5.0, 8, 1);
        let w = s.bits_packed(64);
        assert_eq!(count_ones(&w, 64), 64);
    }

    #[test]
    fn packing_roundtrip() {
        let mut s = Sng::bipolar(0.3, 10, 9);
        let packed = s.bits_packed(130);
        let mut s2 = Sng::bipolar(0.3, 10, 9);
        for t in 0..130 {
            let bit = (packed[t / 64] >> (t % 64)) & 1 == 1;
            assert_eq!(bit, s2.next_bit(), "bit {t}");
        }
    }

    #[test]
    fn count_ones_partial_word() {
        let words = vec![u64::MAX, u64::MAX];
        assert_eq!(count_ones(&words, 64), 64);
        assert_eq!(count_ones(&words, 65), 65);
        assert_eq!(count_ones(&words, 128), 128);
        assert_eq!(count_ones(&words, 3), 3);
    }

    #[test]
    fn matches_python_semantics() {
        // bit = state < floor(p * 2^w); v=0 -> threshold = 2^(w-1).
        let mut s = Sng::bipolar(0.0, 8, 1);
        // states: 1,2,4,8,17,35,71,142 -> threshold 128 -> bits: all < 128
        // except 142.
        let bits: Vec<bool> = (0..8).map(|_| s.next_bit()).collect();
        assert_eq!(bits, vec![true, true, true, true, true, true, true, false]);
    }
}
