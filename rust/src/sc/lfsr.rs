//! Maximal-length Fibonacci LFSRs — the randomness source of every SNG.
//!
//! Taps are identical to the python twin (`ref.lfsr_sequence`); the golden
//! vectors below are pinned on both sides of the language boundary, so any
//! drift fails one of the two test suites.

/// Maximal XOR-form taps, indexed by register width.
fn taps(width: u32) -> &'static [u32] {
    match width {
        8 => &[8, 6, 5, 4],
        10 => &[10, 7],
        12 => &[12, 11, 10, 4],
        16 => &[16, 15, 13, 4],
        _ => panic!("unsupported LFSR width {width} (supported: 8, 10, 12, 16)"),
    }
}

/// A Fibonacci LFSR over `width` bits.  Seed 0 is remapped to 1 (the
/// all-zero state is absorbing).
#[derive(Clone, Debug)]
pub struct Lfsr {
    state: u32,
    width: u32,
    mask: u32,
}

impl Lfsr {
    /// `seed` may be any u64 (e.g. a hashed stream id); only the low
    /// `width` bits are kept, matching the python twin exactly.
    pub fn new(width: u32, seed: u64) -> Self {
        let _ = taps(width); // validate width eagerly
        let mask = (1u32 << width) - 1;
        let state = (seed as u32) & mask;
        Self { state: if state == 0 { 1 } else { state }, width, mask }
    }

    /// Current state, then advance.  States are in [1, 2^width - 1].
    #[inline]
    pub fn next_state(&mut self) -> u32 {
        let out = self.state;
        let mut fb = 0u32;
        for &t in taps(self.width) {
            fb ^= self.state >> (t - 1);
        }
        self.state = ((self.state << 1) | (fb & 1)) & self.mask;
        out
    }

    /// Register width in bits.
    pub fn width(&self) -> u32 {
        self.width
    }

    /// Full period of a maximal LFSR of this width: 2^width - 1.
    pub fn period(&self) -> usize {
        (1usize << self.width) - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sequence(width: u32, seed: u64, n: usize) -> Vec<u32> {
        let mut l = Lfsr::new(width, seed);
        (0..n).map(|_| l.next_state()).collect()
    }

    #[test]
    fn golden_vectors_match_python() {
        // Pinned in python/tests/test_sc_exact.py::test_lfsr_golden_vectors.
        assert_eq!(sequence(8, 1, 8), vec![1, 2, 4, 8, 17, 35, 71, 142]);
        assert_eq!(sequence(10, 1, 8), vec![1, 2, 4, 8, 16, 32, 64, 129]);
        assert_eq!(sequence(16, 0xACE1, 4), vec![44257, 22979, 45958, 26380]);
    }

    #[test]
    fn maximal_period_8() {
        let seq = sequence(8, 1, 255);
        let mut seen = [false; 256];
        for s in seq {
            assert!(s > 0 && s < 256);
            assert!(!seen[s as usize], "state {s} repeated early");
            seen[s as usize] = true;
        }
    }

    #[test]
    fn maximal_period_10() {
        let seq = sequence(10, 7, 1023);
        let distinct: std::collections::HashSet<u32> = seq.into_iter().collect();
        assert_eq!(distinct.len(), 1023);
    }

    #[test]
    fn maximal_period_16() {
        let seq = sequence(16, 0xACE1, 65535);
        let distinct: std::collections::HashSet<u32> = seq.into_iter().collect();
        assert_eq!(distinct.len(), 65535);
    }

    #[test]
    fn zero_seed_remapped() {
        assert_eq!(sequence(8, 0, 1)[0], 1);
    }

    #[test]
    fn seed_masked_to_width() {
        // python: state = seed & mask -> identical truncation semantics
        assert_eq!(sequence(8, 0x1_02, 1)[0], 0x02);
    }

    #[test]
    #[should_panic(expected = "unsupported LFSR width")]
    fn unsupported_width_panics() {
        Lfsr::new(9, 1);
    }
}
