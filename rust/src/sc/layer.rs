//! Bitstream-exact SC dot product and MLP layer.
//!
//! Stream seeding is bit-identical to the python twin
//! (`ref.sc_exact_dot`): input stream `i` uses seed
//! `seed * 2654435761 + i + 1`; weight stream `(i, j)` uses
//! `(seed + 7919) * 40503 + i * n_out + j + 1`.  Python computes these in
//! arbitrary precision and masks to the LFSR width; wrapping u64
//! arithmetic preserves exactly the low bits the mask keeps.

use super::sng::Sng;
use super::ScConfig;

/// LFSR width used by the exact simulator (same as the python twin).
pub const STREAM_WIDTH: u32 = 16;

/// Bitstream-exact bipolar SC dot product.
///
/// `x`: fan_in values in [-1, 1]; `w`: row-major (fan_in, n_out) values in
/// [-1, 1].  Returns the n_out estimates of `x @ w`.
pub fn sc_dot(x: &[f32], w: &[f32], n_out: usize, cfg: ScConfig, seed: u64) -> Vec<f64> {
    let fan_in = x.len();
    assert_eq!(w.len(), fan_in * n_out, "weight shape mismatch");
    let l = cfg.seq_len;
    // Pre-generate packed input streams (reused across all outputs).
    let x_bits: Vec<Vec<u64>> = (0..fan_in)
        .map(|i| {
            let s = seed.wrapping_mul(2654435761).wrapping_add(i as u64 + 1);
            Sng::bipolar(x[i] as f64, STREAM_WIDTH, s).bits_packed(l)
        })
        .collect();
    let wseed = seed.wrapping_add(7919).wrapping_mul(40503);
    let mut out = Vec::with_capacity(n_out);
    for j in 0..n_out {
        let mut total_ones = 0u64;
        for i in 0..fan_in {
            let s = wseed.wrapping_add((i * n_out + j) as u64 + 1);
            let w_bits = Sng::bipolar(w[i * n_out + j] as f64, STREAM_WIDTH, s).bits_packed(l);
            total_ones += super::ops::product_ones(&x_bits[i], &w_bits, l) as u64;
        }
        out.push(super::ops::apc_decode(total_ones, fan_in, l));
    }
    out
}

/// Bitstream-exact SC layer: SC dot + exact bias + PReLU on the counter
/// readout (the paper's LFSM applies the activation in the stochastic
/// domain; [`super::fsm`] provides that variant — the readout-domain
/// activation here matches the python twin used for calibration).
pub fn sc_layer(
    x: &[f32],
    w: &[f32],
    b: &[f32],
    n_out: usize,
    alpha: f32,
    cfg: ScConfig,
    seed: u64,
    activate: bool,
) -> Vec<f64> {
    let mut pre = sc_dot(x, w, n_out, cfg, seed);
    assert_eq!(b.len(), n_out);
    for (p, &bi) in pre.iter_mut().zip(b) {
        *p += bi as f64;
        if activate && *p < 0.0 {
            *p *= alpha as f64;
        }
    }
    pre
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_converges_to_true_value() {
        let mut rng = crate::util::Pcg64::seeded(21);
        let fan_in = 32;
        let n_out = 4;
        let x: Vec<f32> = (0..fan_in).map(|_| (rng.next_f32() * 2.0 - 1.0) * 0.8).collect();
        let w: Vec<f32> = (0..fan_in * n_out).map(|_| (rng.next_f32() * 2.0 - 1.0) * 0.8).collect();
        let mut truth = vec![0.0f64; n_out];
        for i in 0..fan_in {
            for j in 0..n_out {
                truth[j] += x[i] as f64 * w[i * n_out + j] as f64;
            }
        }
        let short = sc_dot(&x, &w, n_out, ScConfig::new(256), 9);
        let long = sc_dot(&x, &w, n_out, ScConfig::new(8192), 9);
        let err_short: f64 = short.iter().zip(&truth).map(|(a, b)| (a - b).abs()).sum::<f64>() / n_out as f64;
        let err_long: f64 = long.iter().zip(&truth).map(|(a, b)| (a - b).abs()).sum::<f64>() / n_out as f64;
        assert!(err_long < err_short, "short {err_short} long {err_long}");
        assert!(err_long < 0.35, "{err_long}");
    }

    #[test]
    fn error_scales_with_model() {
        // Empirical MAC std within [0.5, 2] x the c*sqrt(fan_in/L) noise
        // model — the same calibration contract as the python twin.
        let mut rng = crate::util::Pcg64::seeded(22);
        let fan_in = 24;
        let l = 512;
        let x: Vec<f32> = (0..fan_in).map(|_| rng.next_f32() * 1.6 - 0.8).collect();
        let w: Vec<f32> = (0..fan_in * 3).map(|_| rng.next_f32() * 1.6 - 0.8).collect();
        let mut truth = vec![0.0f64; 3];
        for i in 0..fan_in {
            for j in 0..3 {
                truth[j] += x[i] as f64 * w[i * 3 + j] as f64;
            }
        }
        let mut errs = Vec::new();
        for seed in 0..12u64 {
            let est = sc_dot(&x, &w, 3, ScConfig::new(l), seed * 131 + 7);
            errs.extend(est.iter().zip(&truth).map(|(a, b)| a - b));
        }
        let std = crate::util::Summary::of(&errs).std;
        let model = 0.72 * ((fan_in as f64) / l as f64).sqrt();
        assert!(std > 0.5 * model && std < 2.0 * model, "std {std} model {model}");
    }

    #[test]
    fn layer_bias_and_activation() {
        let x = [0.5f32, -0.5];
        let w = [0.5f32, -0.5, 0.25, 0.25];
        let b = [0.1f32, -0.6];
        let cfg = ScConfig::new(4096);
        let no_act = sc_layer(&x, &w, &b, 2, 0.25, cfg, 3, false);
        let act = sc_layer(&x, &w, &b, 2, 0.25, cfg, 3, true);
        assert!((no_act[0] - act[0]).abs() < 1e-12); // positive: unchanged
        assert!(no_act[1] < 0.0);
        assert!((act[1] - no_act[1] * 0.25).abs() < 1e-12);
    }

    #[test]
    fn golden_parity_with_python() {
        // Values produced by python's ref.sc_exact_dot on the same inputs
        // (see python/tests/test_sc_exact.py) — the cross-language
        // contract for the whole exact simulator.
        let x = [0.5f32, -0.25, 0.75, -0.875];
        let w = [0.5f32, -0.5, 0.25, 0.125, -0.75, 0.375, 0.0625, -0.9375];
        let got = sc_dot(&x, &w, 2, ScConfig::new(256), 3);
        assert_eq!(got, vec![-0.3359375, 0.578125]);
        let got = sc_dot(&x, &w, 2, ScConfig::new(1024), 11);
        assert_eq!(got, vec![-0.361328125, 0.744140625]);
    }

    #[test]
    fn deterministic_in_seed() {
        let x = [0.3f32, 0.7];
        let w = [0.2f32, -0.1, 0.4, 0.9];
        let a = sc_dot(&x, &w, 2, ScConfig::new(1024), 5);
        let b = sc_dot(&x, &w, 2, ScConfig::new(1024), 5);
        let c = sc_dot(&x, &w, 2, ScConfig::new(1024), 6);
        assert_eq!(a, b);
        assert_ne!(a, c);
    }
}
