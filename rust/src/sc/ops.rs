//! Bitstream arithmetic: XNOR multiply and APC accumulate.
//!
//! Bipolar SC multiplication is a single XNOR gate per bit pair:
//! decode(a XNOR b) = decode(a) * decode(b) when the streams are
//! uncorrelated.  The accurate parallel counter (APC) replaces the
//! classic (lossy) mux-tree scaled adder with an exact popcount over all
//! product streams — the design the paper's MLP uses.

use super::sng::count_ones;

/// XNOR of two packed streams (bipolar multiply).  Both must cover `n`
/// bits; trailing bits of the last word are left dirty and must be masked
/// by the consumer (count_ones does).
pub fn xnor_mul(a: &[u64], b: &[u64]) -> Vec<u64> {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| !(x ^ y)).collect()
}

/// Popcount of the first `n` bits of an XNOR product stream.
pub fn product_ones(a: &[u64], b: &[u64], n: usize) -> u32 {
    let prod = xnor_mul(a, b);
    count_ones(&prod, n)
}

/// APC accumulation of `fan_in` product streams over `n` bits: the exact
/// sum of all product bits.  Decoded: each product stream contributes
/// 2*ones/n - 1; summing over streams gives the dot-product estimate.
pub fn apc_decode(total_ones: u64, fan_in: usize, n: usize) -> f64 {
    2.0 * total_ones as f64 / n as f64 - fan_in as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sc::sng::Sng;

    #[test]
    fn xnor_identity() {
        let a = vec![0b1100u64];
        assert_eq!(xnor_mul(&a, &a), vec![!0u64]);
    }

    #[test]
    fn xnor_is_bipolar_multiply() {
        // Uncorrelated streams (different LFSR seeds): decode(a xnor b)
        // ~= decode(a) * decode(b).
        let n = 4095;
        let (va, vb) = (0.6, -0.4);
        let mut a = Sng::bipolar(va, 12, 17);
        let mut b = Sng::bipolar(vb, 12, 7919 * 41 + 3);
        let pa = a.bits_packed(n);
        let pb = b.bits_packed(n);
        let ones = product_ones(&pa, &pb, n);
        let decoded = 2.0 * ones as f64 / n as f64 - 1.0;
        assert!((decoded - va * vb).abs() < 0.05, "decoded {decoded} expected {}", va * vb);
    }

    #[test]
    fn correlated_streams_bias() {
        // Same LFSR seed => maximally correlated => decode(a xnor a) = 1,
        // NOT va*va.  This is the classic SC correlation hazard; the test
        // documents why every SNG gets an independent seed.
        let n = 1023;
        let mut a1 = Sng::bipolar(0.5, 10, 5);
        let mut a2 = Sng::bipolar(0.5, 10, 5);
        let ones = product_ones(&a1.bits_packed(n), &a2.bits_packed(n), n);
        assert_eq!(ones as usize, n);
    }

    #[test]
    fn apc_decode_bounds() {
        assert_eq!(apc_decode(0, 4, 100), -4.0);
        assert_eq!(apc_decode(400, 4, 100), 4.0);
        assert_eq!(apc_decode(200, 4, 100), 0.0);
    }
}
