//! Linear finite-state-machine activation (stochastic tanh).
//!
//! The paper's neuron (Fig. 4) applies its activation with a linear FSM
//! operating directly on the bitstream: a saturating up/down counter with
//! `n_states` states whose output bit is 1 in the upper half.  For an
//! input stream encoding x, the output stream approximates
//! `tanh(n_states/2 * x)` (Brown & Card's classic stanh construction).
//!
//! The exact-simulator layers apply PReLU on the counter readout instead
//! (matching the calibration twin); this module provides the
//! fully-stochastic activation for the ablation bench
//! (`bench_sc` --fsm) and for completeness of the substrate.

/// Saturating up/down counter FSM producing a stochastic tanh.
#[derive(Clone, Debug)]
pub struct StanhFsm {
    n_states: u32,
    state: u32,
}

impl StanhFsm {
    /// `n_states` must be even and >= 2; the FSM starts at the midpoint.
    pub fn new(n_states: u32) -> Self {
        assert!(n_states >= 2 && n_states % 2 == 0, "n_states must be even >= 2");
        Self { n_states, state: n_states / 2 }
    }

    /// Consume one input bit, emit one output bit.
    #[inline]
    pub fn step(&mut self, input: bool) -> bool {
        if input {
            if self.state < self.n_states - 1 {
                self.state += 1;
            }
        } else if self.state > 0 {
            self.state -= 1;
        }
        self.state >= self.n_states / 2
    }

    /// Run over a packed stream, returning the packed output stream.
    pub fn run_packed(&mut self, words: &[u64], n: usize) -> Vec<u64> {
        let mut out = vec![0u64; words.len()];
        for t in 0..n {
            let bit = (words[t / 64] >> (t % 64)) & 1 == 1;
            if self.step(bit) {
                out[t / 64] |= 1u64 << (t % 64);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sc::sng::{count_ones, Sng};

    fn stanh_decode(value: f64, n_states: u32, l: usize, seed: u64) -> f64 {
        let mut sng = Sng::bipolar(value, 16, seed);
        let bits = sng.bits_packed(l);
        let mut fsm = StanhFsm::new(n_states);
        let out = fsm.run_packed(&bits, l);
        2.0 * count_ones(&out, l) as f64 / l as f64 - 1.0
    }

    #[test]
    fn approximates_tanh() {
        // Ideal stanh(n, x) = tanh(n/2 * x) assumes i.i.d. input bits; an
        // LFSR comparator's serial correlation softens the effective gain
        // (a known SC effect), so the structural contract is: odd-symmetric
        // sigmoid bracketed between tanh(x) and tanh(n/2 * x).
        let l = 65535;
        assert!(stanh_decode(0.0, 8, l, 42).abs() < 0.1);
        for &v in &[-0.8, -0.3, 0.3, 0.8] {
            let got = stanh_decode(v, 8, l, 42);
            let lo = (v as f64).tanh();
            let hi = (4.0 * v as f64).tanh();
            let (lo, hi) = if lo < hi { (lo, hi) } else { (hi, lo) };
            assert!(got >= lo - 0.1 && got <= hi + 0.1, "v={v} got={got} range [{lo},{hi}]");
            assert_eq!(got.signum(), (v as f64).signum(), "sign mismatch at {v}");
        }
    }

    #[test]
    fn saturates_at_extremes() {
        let l = 16384;
        assert!(stanh_decode(0.9, 8, l, 1) > 0.95);
        assert!(stanh_decode(-0.9, 8, l, 1) < -0.95);
    }

    #[test]
    fn monotone_in_input() {
        let l = 32768;
        let vals: Vec<f64> = [-0.6, -0.2, 0.2, 0.6].iter().map(|&v| stanh_decode(v, 8, l, 3)).collect();
        for w in vals.windows(2) {
            assert!(w[0] < w[1], "{vals:?}");
        }
    }

    #[test]
    #[should_panic(expected = "even")]
    fn odd_states_rejected() {
        StanhFsm::new(5);
    }

    #[test]
    fn counter_saturates_not_wraps() {
        let mut fsm = StanhFsm::new(4);
        for _ in 0..10 {
            fsm.step(true);
        }
        assert_eq!(fsm.state, 3);
        for _ in 0..10 {
            fsm.step(false);
        }
        assert_eq!(fsm.state, 0);
    }
}
