//! Margin statistics and threshold calibration — the heart of ARI
//! (paper §III-B/C).
//!
//! Given paired outputs of the full and reduced models over a calibration
//! set, [`Calibration`] collects the reduced-model margins of exactly the
//! elements whose predicted class differs, and derives the threshold
//! `T` for a [`ThresholdPolicy`]: `T = Mmax` reproduces the full model's
//! predictions on the calibration set exactly; `M99`/`M95` trade a
//! bounded sliver of coverage for lower T (and hence fewer escalations).

use crate::config::ThresholdPolicy;
use crate::util::stats::margin_threshold;

/// Paired full/reduced predictions over a calibration set.
#[derive(Clone, Debug)]
pub struct Calibration {
    /// Margins (reduced model) of elements whose class changed.
    pub changed_margins: Vec<f64>,
    /// Total calibration elements.
    pub n: usize,
    /// Count with identical predictions.
    pub agree: usize,
    /// Per-class mode (Daghero et al., 2204.03431): `class_margins[c]`
    /// holds the changed-element margins of calibration elements the
    /// *reduced* model predicted as class `c`.  Empty (the
    /// [`Calibration::from_pairs`] default) means global-only — the
    /// bit-identical single-`T` mode.
    pub class_margins: Vec<Vec<f64>>,
}

impl Calibration {
    /// Build from paired predictions and the reduced model's margins.
    pub fn from_pairs(full_pred: &[i32], reduced_pred: &[i32], reduced_margin: &[f32]) -> Self {
        assert_eq!(full_pred.len(), reduced_pred.len());
        assert_eq!(full_pred.len(), reduced_margin.len());
        let mut changed = Vec::new();
        let mut agree = 0;
        for i in 0..full_pred.len() {
            if full_pred[i] == reduced_pred[i] {
                agree += 1;
            } else {
                changed.push(reduced_margin[i] as f64);
            }
        }
        Self { changed_margins: changed, n: full_pred.len(), agree, class_margins: Vec::new() }
    }

    /// Build the per-class mode: like [`Calibration::from_pairs`] but the
    /// changed-element margins are additionally bucketed by the reduced
    /// model's predicted class, enabling one `T[c]` per class from the
    /// same split.  Out-of-range predictions fall into the global pool
    /// only.
    pub fn from_pairs_classed(
        full_pred: &[i32],
        reduced_pred: &[i32],
        reduced_margin: &[f32],
        n_classes: usize,
    ) -> Self {
        let mut cal = Self::from_pairs(full_pred, reduced_pred, reduced_margin);
        let mut buckets = vec![Vec::new(); n_classes];
        for i in 0..full_pred.len() {
            if full_pred[i] != reduced_pred[i] {
                let c = reduced_pred[i];
                if c >= 0 && (c as usize) < n_classes {
                    buckets[c as usize].push(reduced_margin[i] as f64);
                }
            }
        }
        cal.class_margins = buckets;
        cal
    }

    /// Per-class thresholds for a policy.  A class whose bucket is empty
    /// (the reduced model never disagreed with the full model on it in
    /// calibration — or it was never predicted) falls back to
    /// `fallback`, normally the global threshold: unseen classes must
    /// not silently accept everything.  With [`ThresholdPolicy::MMax`]
    /// every per-class threshold is <= the global one, so per-class mode
    /// preserves calibration-set parity while escalating no more (and
    /// usually fewer) elements.
    pub fn class_thresholds(&self, policy: ThresholdPolicy, fallback: f64) -> Vec<f64> {
        self.class_margins
            .iter()
            .map(|bucket| {
                if bucket.is_empty() {
                    fallback
                } else {
                    match policy {
                        ThresholdPolicy::Fixed(t) => t,
                        p => margin_threshold(bucket, p.coverage().unwrap()),
                    }
                }
            })
            .collect()
    }

    /// Fraction of elements whose class changed under quantisation.
    pub fn change_rate(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.changed_margins.len() as f64 / self.n as f64
        }
    }

    /// The calibrated threshold for a policy.
    pub fn threshold(&self, policy: ThresholdPolicy) -> f64 {
        match policy {
            ThresholdPolicy::Fixed(t) => t,
            p => margin_threshold(&self.changed_margins, p.coverage().unwrap()),
        }
    }

    /// One-line summary of this calibration at a chosen threshold —
    /// used for the per-stage report of an N-level ladder
    /// ([`crate::coordinator::Ladder::calibration_report`]).
    pub fn summary(&self, threshold: f64) -> String {
        format!(
            "{} changed of {} ({:.2}%), T = {:.4}",
            self.changed_margins.len(),
            self.n,
            100.0 * self.change_rate(),
            threshold
        )
    }

    /// Fraction of (calibration) elements that would escalate at T, given
    /// all reduced-model margins.  This is the paper's F (Fig. 13).
    pub fn escalation_fraction(all_reduced_margins: &[f32], t: f64) -> f64 {
        if all_reduced_margins.is_empty() {
            return 0.0;
        }
        let k = all_reduced_margins.iter().filter(|&&m| (m as f64) <= t).count();
        k as f64 / all_reduced_margins.len() as f64
    }
}

/// The runtime decision (paper Fig. 7b): accept the reduced result when
/// its margin clears the threshold, otherwise escalate.
#[inline]
pub fn accepts(margin: f32, threshold: f64) -> bool {
    (margin as f64) > threshold
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_counts() {
        let full = [0, 1, 2, 3];
        let red = [0, 1, 9, 3];
        let marg = [0.9f32, 0.8, 0.1, 0.7];
        let c = Calibration::from_pairs(&full, &red, &marg);
        assert_eq!(c.n, 4);
        assert_eq!(c.agree, 3);
        assert_eq!(c.changed_margins.len(), 1);
        assert!((c.changed_margins[0] - 0.1f32 as f64).abs() < 1e-9);
        assert!((c.change_rate() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn mmax_threshold_covers_all_changes() {
        let full = [0, 0, 0, 0, 0];
        let red = [1, 1, 0, 1, 0];
        let marg = [0.30f32, 0.10, 0.9, 0.20, 0.8];
        let c = Calibration::from_pairs(&full, &red, &marg);
        let t = c.threshold(ThresholdPolicy::MMax);
        assert!((t - 0.30).abs() < 1e-7);
        // Every changed element must fail the accept test at T.
        for (i, &m) in marg.iter().enumerate() {
            if full[i] != red[i] {
                assert!(!accepts(m, t), "changed element {i} accepted");
            }
        }
    }

    #[test]
    fn percentile_thresholds_below_mmax() {
        let full: Vec<i32> = vec![0; 1000];
        let red: Vec<i32> = (0..1000).map(|i| if i < 100 { 1 } else { 0 }).collect();
        let marg: Vec<f32> = (0..1000).map(|i| if i < 100 { i as f32 / 100.0 } else { 0.99 }).collect();
        let c = Calibration::from_pairs(&full, &red, &marg);
        let mmax = c.threshold(ThresholdPolicy::MMax);
        let m99 = c.threshold(ThresholdPolicy::M99);
        let m95 = c.threshold(ThresholdPolicy::M95);
        assert!(m95 < m99 && m99 < mmax);
    }

    #[test]
    fn summary_reports_counts_and_threshold() {
        let c = Calibration::from_pairs(&[0, 1, 2, 3], &[0, 1, 9, 3], &[0.9f32, 0.8, 0.1, 0.7]);
        let s = c.summary(0.1);
        assert!(s.contains("1 changed of 4"), "{s}");
        assert!(s.contains("25.00%"), "{s}");
        assert!(s.contains("T = 0.1000"), "{s}");
    }

    #[test]
    fn fixed_threshold_passthrough() {
        let c = Calibration::from_pairs(&[0], &[0], &[0.5]);
        assert_eq!(c.threshold(ThresholdPolicy::Fixed(0.123)), 0.123);
    }

    #[test]
    fn no_changes_means_zero_threshold() {
        let c = Calibration::from_pairs(&[1, 2], &[1, 2], &[0.4, 0.6]);
        assert_eq!(c.threshold(ThresholdPolicy::MMax), 0.0);
        // and nothing escalates except exact-zero margins
        assert!(accepts(0.4, 0.0));
    }

    /// Per-class MMax thresholds cover every changed element of their
    /// class (calibration-set parity) while never exceeding the global
    /// threshold — per-class mode can only reduce escalations.
    #[test]
    fn per_class_thresholds_cover_changes_below_global() {
        let full = [0, 0, 1, 1, 1, 0, 1, 0];
        let red = [0, 1, 1, 0, 1, 1, 1, 0]; // changes at 1 (pred 1), 3 (pred 0), 5 (pred 1)
        let marg = [0.9f32, 0.15, 0.8, 0.40, 0.7, 0.25, 0.6, 0.5];
        let c = Calibration::from_pairs_classed(&full, &red, &marg, 2);
        let global = c.threshold(ThresholdPolicy::MMax);
        assert!((global - 0.40).abs() < 1e-7);
        let per = c.class_thresholds(ThresholdPolicy::MMax, global);
        assert_eq!(per.len(), 2);
        assert!((per[0] - 0.40).abs() < 1e-7, "class 0 covers its one change");
        assert!((per[1] - 0.25).abs() < 1e-7, "class 1 tighter than global");
        for (i, &m) in marg.iter().enumerate() {
            if full[i] != red[i] {
                assert!(!accepts(m, per[red[i] as usize]), "changed element {i} accepted");
            }
        }
        for t in &per {
            assert!(*t <= global + 1e-12);
        }
        // The plain constructor stays global-only.
        let plain = Calibration::from_pairs(&full, &red, &marg);
        assert!(plain.class_margins.is_empty());
    }

    /// Classes the calibration never saw a disagreement for fall back to
    /// the supplied (global) threshold instead of accepting everything.
    #[test]
    fn per_class_empty_bucket_falls_back() {
        let c = Calibration::from_pairs_classed(&[0, 1], &[0, 1], &[0.4, 0.6], 3);
        let per = c.class_thresholds(ThresholdPolicy::MMax, 0.33);
        assert_eq!(per, vec![0.33, 0.33, 0.33]);
    }

    #[test]
    fn escalation_fraction_matches_definition() {
        let margins = [0.1f32, 0.2, 0.3, 0.4, 0.5];
        assert!((Calibration::escalation_fraction(&margins, 0.25) - 0.4).abs() < 1e-12);
        assert_eq!(Calibration::escalation_fraction(&[], 0.5), 0.0);
        // boundary: margin == T escalates (strict >); note the f32->f64
        // widening must match the accept path's
        assert!((Calibration::escalation_fraction(&margins, 0.3f32 as f64) - 0.6).abs() < 1e-12);
    }
}
