//! Pure-rust MLP inference engines — the cross-check baseline for the
//! PJRT path and the host of the exact-SC backend.
//!
//! Three engines share the [`Weights`] loaded from artifacts:
//!
//! * [`FpEngine`] — truncated-mantissa forward, mirroring the L1
//!   `quant_matmul` kernel (same quantisation points), used to validate
//!   the PJRT executables and as the fallback when artifacts lack a
//!   precision level.
//! * [`ScNoiseEngine`] — the SC noise model on the rust substrate (same
//!   maths as the `sc_matmul` kernel, seeded Gaussians + grid snap).
//! * [`sc_exact_forward`] — bitstream-exact single-sample forward on the
//!   [`crate::sc`] simulator (slow; case studies and validation only).
//!
//! The FP and SC-noise forward passes run on prepared execution plans
//! ([`plan`]): weights quantised/packed once into a padded
//! kernel-friendly layout, reusable ping-pong activation scratch, and
//! batch rows sharded across a scoped worker pool with bit-identical
//! results for any thread count.  The engines above are thin wrappers;
//! the serving backend caches [`FpPlan`]/[`ScPlan`] per variant.

pub mod plan;

use crate::data::Weights;
use crate::quant::FpFormat;
use crate::sc::ScConfig;
use crate::tensor::{top2_margin, Matrix};

pub use plan::{FpPlan, OutBufs, ScPlan, Scratch};

/// Output of a forward pass over a batch.
#[derive(Clone, Debug)]
pub struct Outputs {
    /// (batch, n_classes) L2-normalised scores, row-major.
    pub scores: Matrix,
    /// Predicted class per row.
    pub pred: Vec<i32>,
    /// Top-1 minus top-2 score gap per row.
    pub margin: Vec<f32>,
}

impl Outputs {
    /// Scores = L2-normalised logits — mirrors the L2 jax model's
    /// `_normalize` (see `python/compile/model.py`): the paper's scores
    /// are raw bounded outputs, not softmax, which is what gives changed
    /// elements their small margins.
    fn from_logits(logits: Matrix) -> Self {
        Self::from_logits_reuse(logits, Vec::new(), Vec::new())
    }

    /// [`Self::from_logits`] writing into recycled `pred`/`margin`
    /// buffers (cleared, then filled) — with the logits matrix itself
    /// built over a recycled score buffer this makes a steady-state
    /// forward allocation-free (see [`plan::OutBufs`]).
    fn from_logits_reuse(mut logits: Matrix, mut pred: Vec<i32>, mut margin: Vec<f32>) -> Self {
        logits.l2_normalize_rows();
        pred.clear();
        margin.clear();
        pred.reserve(logits.rows);
        margin.reserve(logits.rows);
        for r in 0..logits.rows {
            let (p, m) = top2_margin(logits.row(r));
            pred.push(p as i32);
            margin.push(m);
        }
        Self { scores: logits, pred, margin }
    }

    /// Bipolar counter readout: snap to the 2/L grid on the normalised
    /// range (mirrors the SC entry in the jax model).
    fn snap_scores_to_grid(&mut self, l: usize) {
        let half = l as f32 / 2.0;
        self.scores.map_inplace(|v| (v * half).round() / half);
        for r in 0..self.scores.rows {
            let (p, m) = top2_margin(self.scores.row(r));
            self.pred[r] = p as i32;
            self.margin[r] = m;
        }
    }
}

/// Truncated-mantissa floating-point engine — a convenience wrapper
/// that builds a prepared [`FpPlan`] at construction (weights quantised
/// once, padded kernel layout) and forwards through it.  The serving
/// path ([`crate::runtime::NativeBackend`]) caches plans and scratch
/// directly; this wrapper allocates fresh scratch per call.
pub struct FpEngine {
    plan: FpPlan,
    /// The reduced-precision format this engine emulates.
    pub fmt: FpFormat,
}

impl FpEngine {
    /// Engine over `weights` at a fixed format (quantises and packs the
    /// weights once, here).  The plan owns packed copies, so the engine
    /// does not borrow `weights`.
    pub fn new(weights: &Weights, fmt: FpFormat) -> Self {
        Self { plan: FpPlan::new(weights, fmt), fmt }
    }

    /// Forward a (batch, input_dim) row-major slice.
    pub fn forward(&self, x: &[f32], batch: usize) -> Outputs {
        let mut scratch = Scratch::new();
        self.plan.forward(x, batch, &mut scratch, self.plan.auto_threads(batch))
    }
}

/// SC noise-model engine (rust twin of the `sc_matmul` kernel maths) —
/// a convenience wrapper over a prepared [`ScPlan`] (raw padded
/// weights, per-layer `max|w|` precomputed at construction).
pub struct ScNoiseEngine {
    plan: ScPlan,
    /// The SC configuration (sequence length) being modelled.
    pub cfg: ScConfig,
}

/// Bernoulli-regime noise constant shared with the python kernel
/// (`SC_NOISE_C`) — validated against the exact bitstream simulator.
pub const SC_NOISE_C: f64 = 0.72;

/// LFSR low-discrepancy variance-reduction factor (python twin:
/// `SC_LFSR_LOW_DISCREPANCY_K`).  Full-period LFSR-driven SNGs behave
/// like stratified samplers, not i.i.d. Bernoulli draws; calibrated to
/// the paper's §III-B anchor (~1.3% class changes, SVHN 4096→512).
pub const SC_LFSR_K: f64 = 48.0;

impl ScNoiseEngine {
    /// Engine over `weights` at a fixed sequence length (packs the
    /// weights and precomputes per-layer `max|w|` once, here).  The plan
    /// owns packed copies, so the engine does not borrow `weights`.
    pub fn new(weights: &Weights, cfg: ScConfig) -> Self {
        Self { plan: ScPlan::new(weights, cfg), cfg }
    }

    /// Forward with explicit noise seed (deterministic).  Row `r` draws
    /// noise from its own `(seed, SC_ROW_STREAM + r)` PCG stream (see
    /// [`plan::SC_ROW_STREAM`]) — per-row keying that makes results
    /// independent of batch sharding across worker threads.  The operand
    /// scale `max|x|` is
    /// per row (as the exact bitstream simulator normalises per sample),
    /// and the APC readout error converts back by `max|x| * max|w|`.
    pub fn forward(&self, x: &[f32], batch: usize, seed: u64) -> Outputs {
        let mut scratch = Scratch::new();
        self.plan.forward(x, batch, seed, &mut scratch, self.plan.auto_threads(batch))
    }
}

/// Bitstream-exact SC forward of ONE sample (values normalised per layer
/// into the bipolar range, like the paper's hardware).  Slow — case
/// studies, validation and benches only.
pub fn sc_exact_forward(weights: &Weights, x: &[f32], cfg: ScConfig, seed: u64) -> Outputs {
    let n = weights.layers.len();
    let mut h: Vec<f32> = x.to_vec();
    for (i, l) in weights.layers.iter().enumerate() {
        // Normalise inputs and weights into [-1, 1] (per-layer scales, as
        // the SC hardware does), run the bitstream dot, then undo scales.
        let xmax = h.iter().fold(1e-6f32, |a, &v| a.max(v.abs()));
        let wmax = l.w.iter().fold(1e-6f32, |a, &v| a.max(v.abs()));
        let xn: Vec<f32> = h.iter().map(|&v| v / xmax).collect();
        let wn: Vec<f32> = l.w.iter().map(|&v| v / wmax).collect();
        let est = crate::sc::sc_dot(&xn, &wn, l.out_dim, cfg, seed.wrapping_add(i as u64 * 7919));
        let scale = (xmax * wmax) as f64;
        let mut out: Vec<f32> = est
            .iter()
            .zip(&l.b)
            .map(|(&e, &b)| (e * scale) as f32 + b)
            .collect();
        if i + 1 < n {
            for v in &mut out {
                if *v < 0.0 {
                    *v *= l.alpha;
                }
            }
        }
        h = out;
    }
    Outputs::from_logits(Matrix::from_vec(1, h.len(), h))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::LayerWeights;

    fn tiny_weights() -> Weights {
        // 4 -> 3 -> 2, hand-set so class 0 wins for positive inputs.
        Weights {
            layers: vec![
                LayerWeights {
                    in_dim: 4,
                    out_dim: 3,
                    w: vec![0.5, -0.2, 0.1, 0.3, 0.4, -0.1, -0.3, 0.2, 0.5, 0.1, -0.4, 0.2],
                    b: vec![0.05, -0.05, 0.0],
                    alpha: 0.25,
                },
                LayerWeights {
                    in_dim: 3,
                    out_dim: 2,
                    w: vec![0.8, -0.8, 0.5, -0.5, 0.3, -0.3],
                    b: vec![0.1, -0.1],
                    alpha: 0.25,
                },
            ],
        }
    }

    #[test]
    fn fp_engine_full_vs_coarse() {
        let w = tiny_weights();
        let x = vec![1.0f32, 0.5, -0.5, 0.25, -1.0, 0.7, 0.2, -0.3];
        let full = FpEngine::new(&w, FpFormat::FP16).forward(&x, 2);
        let coarse = FpEngine::new(&w, FpFormat::fp(8)).forward(&x, 2);
        assert_eq!(full.pred.len(), 2);
        // scores are L2-normalised rows
        for out in [&full, &coarse] {
            for r in 0..2 {
                let n: f32 = out.scores.row(r).iter().map(|v| v * v).sum();
                assert!((n - 1.0).abs() < 1e-4, "{n}");
            }
        }
    }

    #[test]
    fn fp_engine_margin_consistent() {
        let w = tiny_weights();
        let x = vec![0.3f32, -0.2, 0.8, 0.1];
        let out = FpEngine::new(&w, FpFormat::FP16).forward(&x, 1);
        let row = out.scores.row(0);
        let mut sorted: Vec<f32> = row.to_vec();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        assert!((out.margin[0] - (sorted[0] - sorted[1])).abs() < 1e-6);
    }

    #[test]
    fn sc_noise_engine_deterministic_and_grid() {
        let w = tiny_weights();
        let x = vec![0.3f32, -0.2, 0.8, 0.1];
        let eng = ScNoiseEngine::new(&w, ScConfig::new(256));
        let a = eng.forward(&x, 1, 42);
        let b = eng.forward(&x, 1, 42);
        assert_eq!(a.scores.data, b.scores.data);
        // (note: with the low-discrepancy noise constant and this tiny
        // fan-in the per-layer noise is far below the counter grid, so
        // different seeds may legitimately snap to identical scores —
        // determinism is the contract here, seed-sensitivity is exercised
        // at realistic fan-in by the PJRT golden tests.)
        // scores on the bipolar 2/L grid
        for &s in &a.scores.data {
            assert!((s * 128.0 - (s * 128.0).round()).abs() < 1e-4);
        }
    }

    #[test]
    fn sc_noise_converges_to_fp_with_length() {
        let w = tiny_weights();
        let x = vec![0.9f32, -0.4, 0.6, 0.2];
        let fp = FpEngine::new(&w, FpFormat::FP16).forward(&x, 1);
        let long = ScNoiseEngine::new(&w, ScConfig::new(1 << 20)).forward(&x, 1, 7);
        for (a, b) in long.scores.data.iter().zip(&fp.scores.data) {
            assert!((a - b).abs() < 0.02, "{a} vs {b}");
        }
    }

    #[test]
    fn sc_exact_forward_reasonable() {
        let w = tiny_weights();
        let x = vec![0.9f32, -0.4, 0.6, 0.2];
        let fp = FpEngine::new(&w, FpFormat::FP16).forward(&x, 1);
        let exact = sc_exact_forward(&w, &x, ScConfig::new(8192), 3);
        // Long streams: prediction should agree with the exact engine.
        assert_eq!(exact.pred[0], fp.pred[0]);
    }
}
