//! Pure-rust MLP inference engines — the cross-check baseline for the
//! PJRT path and the host of the exact-SC backend.
//!
//! Three engines share the [`Weights`] loaded from artifacts:
//!
//! * [`FpEngine`] — truncated-mantissa forward, mirroring the L1
//!   `quant_matmul` kernel (same quantisation points), used to validate
//!   the PJRT executables and as the fallback when artifacts lack a
//!   precision level.
//! * [`ScNoiseEngine`] — the SC noise model on the rust substrate (same
//!   maths as the `sc_matmul` kernel, seeded Gaussians + grid snap).
//! * [`sc_exact_forward`] — bitstream-exact single-sample forward on the
//!   [`crate::sc`] simulator (slow; case studies and validation only).

use crate::data::Weights;
use crate::quant::FpFormat;
use crate::sc::ScConfig;
use crate::tensor::{top2_margin, Matrix};
use crate::util::Pcg64;

/// Output of a forward pass over a batch.
#[derive(Clone, Debug)]
pub struct Outputs {
    /// (batch, n_classes) L2-normalised scores, row-major.
    pub scores: Matrix,
    /// Predicted class per row.
    pub pred: Vec<i32>,
    /// Top-1 minus top-2 score gap per row.
    pub margin: Vec<f32>,
}

impl Outputs {
    /// Scores = L2-normalised logits — mirrors the L2 jax model's
    /// `_normalize` (see `python/compile/model.py`): the paper's scores
    /// are raw bounded outputs, not softmax, which is what gives changed
    /// elements their small margins.
    fn from_logits(mut logits: Matrix) -> Self {
        logits.l2_normalize_rows();
        let mut pred = Vec::with_capacity(logits.rows);
        let mut margin = Vec::with_capacity(logits.rows);
        for r in 0..logits.rows {
            let (p, m) = top2_margin(logits.row(r));
            pred.push(p as i32);
            margin.push(m);
        }
        Self { scores: logits, pred, margin }
    }

    /// Bipolar counter readout: snap to the 2/L grid on the normalised
    /// range (mirrors the SC entry in the jax model).
    fn snap_scores_to_grid(&mut self, l: usize) {
        let half = l as f32 / 2.0;
        self.scores.map_inplace(|v| (v * half).round() / half);
        for r in 0..self.scores.rows {
            let (p, m) = top2_margin(self.scores.row(r));
            self.pred[r] = p as i32;
            self.margin[r] = m;
        }
    }
}

/// Truncated-mantissa floating-point engine.
pub struct FpEngine<'w> {
    weights: &'w Weights,
    /// The reduced-precision format this engine emulates.
    pub fmt: FpFormat,
}

impl<'w> FpEngine<'w> {
    /// Engine over borrowed weights at a fixed format.
    pub fn new(weights: &'w Weights, fmt: FpFormat) -> Self {
        Self { weights, fmt }
    }

    /// Forward a (batch, input_dim) row-major slice.
    pub fn forward(&self, x: &[f32], batch: usize) -> Outputs {
        let input_dim = self.weights.layers[0].in_dim;
        assert_eq!(x.len(), batch * input_dim, "input shape mismatch");
        let mut h = Matrix::from_vec(batch, input_dim, x.to_vec());
        let n = self.weights.layers.len();
        for (i, l) in self.weights.layers.iter().enumerate() {
            let w = Matrix::from_vec(l.in_dim, l.out_dim, l.w.clone());
            h = crate::quant::quant_layer(&h, &w, &l.b, l.alpha, self.fmt, i + 1 < n);
        }
        Outputs::from_logits(h)
    }
}

/// SC noise-model engine (rust twin of the `sc_matmul` kernel maths).
pub struct ScNoiseEngine<'w> {
    weights: &'w Weights,
    /// The SC configuration (sequence length) being modelled.
    pub cfg: ScConfig,
}

/// Bernoulli-regime noise constant shared with the python kernel
/// (`SC_NOISE_C`) — validated against the exact bitstream simulator.
pub const SC_NOISE_C: f64 = 0.72;

/// LFSR low-discrepancy variance-reduction factor (python twin:
/// `SC_LFSR_LOW_DISCREPANCY_K`).  Full-period LFSR-driven SNGs behave
/// like stratified samplers, not i.i.d. Bernoulli draws; calibrated to
/// the paper's §III-B anchor (~1.3% class changes, SVHN 4096→512).
pub const SC_LFSR_K: f64 = 48.0;

impl<'w> ScNoiseEngine<'w> {
    /// Engine over borrowed weights at a fixed sequence length.
    pub fn new(weights: &'w Weights, cfg: ScConfig) -> Self {
        Self { weights, cfg }
    }

    /// Forward with explicit noise seed (deterministic).
    pub fn forward(&self, x: &[f32], batch: usize, seed: u64) -> Outputs {
        let input_dim = self.weights.layers[0].in_dim;
        assert_eq!(x.len(), batch * input_dim, "input shape mismatch");
        let mut h = Matrix::from_vec(batch, input_dim, x.to_vec());
        let n = self.weights.layers.len();
        let mut rng = Pcg64::new(seed, 17);
        for (i, l) in self.weights.layers.iter().enumerate() {
            let w = Matrix::from_vec(l.in_dim, l.out_dim, l.w.clone());
            let mut pre = h.matmul(&w);
            pre.add_row(&l.b);
            // Same scale as the kernel: the SC hardware encodes x/max|x|
            // and w/max|w|, so the APC readout error converts back by
            // max|x| * max|w|.
            let xmax = h.data.iter().fold(1e-6f32, |a, &v| a.max(v.abs())) as f64;
            let wmax = l.w.iter().fold(1e-6f32, |a, &v| a.max(v.abs())) as f64;
            let scale = xmax * wmax;
            let sigma = SC_NOISE_C / SC_LFSR_K * (l.in_dim as f64 / self.cfg.seq_len as f64).sqrt() * scale;
            let step = self.cfg.grid_step() * scale;
            for v in &mut pre.data {
                let noisy = *v as f64 + sigma * rng.normal();
                *v = ((noisy / step).round() * step) as f32;
            }
            if i + 1 < n {
                pre.prelu(l.alpha);
            }
            h = pre;
        }
        let mut out = Outputs::from_logits(h);
        out.snap_scores_to_grid(self.cfg.seq_len);
        out
    }
}

/// Bitstream-exact SC forward of ONE sample (values normalised per layer
/// into the bipolar range, like the paper's hardware).  Slow — case
/// studies, validation and benches only.
pub fn sc_exact_forward(weights: &Weights, x: &[f32], cfg: ScConfig, seed: u64) -> Outputs {
    let n = weights.layers.len();
    let mut h: Vec<f32> = x.to_vec();
    for (i, l) in weights.layers.iter().enumerate() {
        // Normalise inputs and weights into [-1, 1] (per-layer scales, as
        // the SC hardware does), run the bitstream dot, then undo scales.
        let xmax = h.iter().fold(1e-6f32, |a, &v| a.max(v.abs()));
        let wmax = l.w.iter().fold(1e-6f32, |a, &v| a.max(v.abs()));
        let xn: Vec<f32> = h.iter().map(|&v| v / xmax).collect();
        let wn: Vec<f32> = l.w.iter().map(|&v| v / wmax).collect();
        let est = crate::sc::sc_dot(&xn, &wn, l.out_dim, cfg, seed.wrapping_add(i as u64 * 7919));
        let scale = (xmax * wmax) as f64;
        let mut out: Vec<f32> = est
            .iter()
            .zip(&l.b)
            .map(|(&e, &b)| (e * scale) as f32 + b)
            .collect();
        if i + 1 < n {
            for v in &mut out {
                if *v < 0.0 {
                    *v *= l.alpha;
                }
            }
        }
        h = out;
    }
    Outputs::from_logits(Matrix::from_vec(1, h.len(), h))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::LayerWeights;

    fn tiny_weights() -> Weights {
        // 4 -> 3 -> 2, hand-set so class 0 wins for positive inputs.
        Weights {
            layers: vec![
                LayerWeights {
                    in_dim: 4,
                    out_dim: 3,
                    w: vec![0.5, -0.2, 0.1, 0.3, 0.4, -0.1, -0.3, 0.2, 0.5, 0.1, -0.4, 0.2],
                    b: vec![0.05, -0.05, 0.0],
                    alpha: 0.25,
                },
                LayerWeights {
                    in_dim: 3,
                    out_dim: 2,
                    w: vec![0.8, -0.8, 0.5, -0.5, 0.3, -0.3],
                    b: vec![0.1, -0.1],
                    alpha: 0.25,
                },
            ],
        }
    }

    #[test]
    fn fp_engine_full_vs_coarse() {
        let w = tiny_weights();
        let x = vec![1.0f32, 0.5, -0.5, 0.25, -1.0, 0.7, 0.2, -0.3];
        let full = FpEngine::new(&w, FpFormat::FP16).forward(&x, 2);
        let coarse = FpEngine::new(&w, FpFormat::fp(8)).forward(&x, 2);
        assert_eq!(full.pred.len(), 2);
        // scores are L2-normalised rows
        for out in [&full, &coarse] {
            for r in 0..2 {
                let n: f32 = out.scores.row(r).iter().map(|v| v * v).sum();
                assert!((n - 1.0).abs() < 1e-4, "{n}");
            }
        }
    }

    #[test]
    fn fp_engine_margin_consistent() {
        let w = tiny_weights();
        let x = vec![0.3f32, -0.2, 0.8, 0.1];
        let out = FpEngine::new(&w, FpFormat::FP16).forward(&x, 1);
        let row = out.scores.row(0);
        let mut sorted: Vec<f32> = row.to_vec();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        assert!((out.margin[0] - (sorted[0] - sorted[1])).abs() < 1e-6);
    }

    #[test]
    fn sc_noise_engine_deterministic_and_grid() {
        let w = tiny_weights();
        let x = vec![0.3f32, -0.2, 0.8, 0.1];
        let eng = ScNoiseEngine::new(&w, ScConfig::new(256));
        let a = eng.forward(&x, 1, 42);
        let b = eng.forward(&x, 1, 42);
        assert_eq!(a.scores.data, b.scores.data);
        // (note: with the low-discrepancy noise constant and this tiny
        // fan-in the per-layer noise is far below the counter grid, so
        // different seeds may legitimately snap to identical scores —
        // determinism is the contract here, seed-sensitivity is exercised
        // at realistic fan-in by the PJRT golden tests.)
        // scores on the bipolar 2/L grid
        for &s in &a.scores.data {
            assert!((s * 128.0 - (s * 128.0).round()).abs() < 1e-4);
        }
    }

    #[test]
    fn sc_noise_converges_to_fp_with_length() {
        let w = tiny_weights();
        let x = vec![0.9f32, -0.4, 0.6, 0.2];
        let fp = FpEngine::new(&w, FpFormat::FP16).forward(&x, 1);
        let long = ScNoiseEngine::new(&w, ScConfig::new(1 << 20)).forward(&x, 1, 7);
        for (a, b) in long.scores.data.iter().zip(&fp.scores.data) {
            assert!((a - b).abs() < 0.02, "{a} vs {b}");
        }
    }

    #[test]
    fn sc_exact_forward_reasonable() {
        let w = tiny_weights();
        let x = vec![0.9f32, -0.4, 0.6, 0.2];
        let fp = FpEngine::new(&w, FpFormat::FP16).forward(&x, 1);
        let exact = sc_exact_forward(&w, &x, ScConfig::new(8192), 3);
        // Long streams: prediction should agree with the exact engine.
        assert_eq!(exact.pred[0], fp.pred[0]);
    }
}
