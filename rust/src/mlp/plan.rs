//! Prepared execution plans: the per-variant state the native backend
//! caches so a steady-state forward pass does no per-call weight work.
//!
//! The unprepared engines pay three per-batch costs the paper's hardware
//! never would: every layer's weight matrix is cloned and re-quantised
//! on every call, the matmul allocates a fresh output per layer, and the
//! whole pass is single-threaded.  A plan hoists all of it to
//! construction time:
//!
//! * weights are quantised **once** per [`FpFormat`] (FP) or copied raw
//!   with the per-layer `max|w|` precomputed (SC noise model),
//! * each layer's weight matrix is stored in a padded, kernel-friendly
//!   layout — output width rounded up to [`KERNEL_NR`] with zero
//!   columns, input rows extended with zero rows to the previous layer's
//!   padded width — so the tiled kernel's full-register path runs edge
//!   handling exactly never,
//! * activations ping-pong through a reusable [`Scratch`] (two
//!   `batch × stride` buffers plus the SC path's per-row noise
//!   streams), and output storage can be recycled through [`OutBufs`]
//!   (`forward_reuse`), so a steady-state serving forward that returns
//!   its outputs to the backend's recycle pool allocates **nothing**
//!   on the serial path (the threaded path allocates only the two
//!   small per-call shard/job vectors).
//!
//! Forwards shard batch rows across the persistent parked worker pool
//! ([`crate::util::pool`]).  Everything per-row — kernel accumulation
//! order, the quantisation epilogue, and the SC noise stream, which is
//! keyed per row as `Pcg64::new(seed, SC_ROW_STREAM + row)` — is
//! independent of the shard layout, so outputs are **bit-identical for
//! any worker count** (pinned by `tests/kernel_parity.rs`).
//!
//! All per-element quantisation on the FP hot path (input staging, bias
//! epilogue, PReLU epilogue, and the pack-time weight quantisation) runs
//! through a [`PreparedQuantizer`] — the format's round/clamp/flush
//! constants precomputed once per plan, bit-identical to the scalar
//! [`FpFormat::quantize`].  The SC forward is **layer-major**: one
//! `rows × np` matmul per layer over the whole shard (instead of an
//! `m = 1` matmul per row per layer, which wasted 3 of the kernel's 4
//! register rows), with one persistent [`Pcg64`] per row carrying the
//! noise stream across layers so every draw lands in the same order —
//! and therefore every score in the same bits — as the row-major walk.
//!
//! Zero padding is invisible to the numbers: padded columns carry zero
//! weights and zero bias (so their activations are exactly `0.0`, which
//! PReLU and quantisation both fix), and padded input rows are zero
//! rows, so every extra kernel term is `0.0 * 0.0` appended *after* the
//! real accumulation.

use crate::data::Weights;
use crate::quant::{FpFormat, PreparedQuantizer};
use crate::sc::ScConfig;
use crate::tensor::{matmul_strided, Matrix, KERNEL_NR};
use crate::util::{pool, Pcg64};

use super::{Outputs, SC_LFSR_K, SC_NOISE_C};

/// Stream-id base for per-row SC noise: row `r` of a batch draws from
/// `Pcg64::new(seed, SC_ROW_STREAM + r)`, independent of every other
/// row and of how rows are sharded across workers.
pub const SC_ROW_STREAM: u64 = 17;

/// One layer in packed, kernel-ready form.
struct PlanLayer {
    /// `(k, np)` row-major weights — quantised for FP plans, raw for SC.
    w: Vec<f32>,
    /// Bias, `np` long (padded with zeros; pre-quantised for FP plans).
    b: Vec<f32>,
    /// PReLU negative slope.
    alpha: f32,
    /// Kernel reduction depth: the real input width for the first layer,
    /// the previous layer's padded width after that.
    k: usize,
    /// Padded output width (multiple of [`KERNEL_NR`]).
    np: usize,
    /// Real (unpadded) input width — the SC noise model's fan-in.
    in_real: usize,
    /// Real (unpadded) output width.
    out_real: usize,
    /// `max|w|` over the real weights (SC noise scale), `>= 1e-6`.
    wmax: f64,
}

/// Packed layers plus the shared layout facts.
struct Packed {
    layers: Vec<PlanLayer>,
    /// Row stride of the ping-pong buffers: `max(input_dim, max np)`.
    stride: usize,
    input_dim: usize,
    n_classes: usize,
    /// Kernel flops (2·k·np summed over layers) per batch row — the
    /// work estimate behind [`pool::auto_threads_for`].
    flops_per_row: usize,
}

fn pad_to(n: usize, q: usize) -> usize {
    (n + q - 1) / q * q
}

fn pack(weights: &Weights, quant: Option<FpFormat>) -> Packed {
    let pq = quant.map(PreparedQuantizer::new);
    let mut layers = Vec::with_capacity(weights.layers.len());
    let input_dim = weights.layers[0].in_dim;
    let mut prev_np = input_dim; // kernel depth consumed by the next layer
    let mut stride = input_dim;
    for (li, l) in weights.layers.iter().enumerate() {
        let k = if li == 0 { input_dim } else { prev_np };
        let np = pad_to(l.out_dim, KERNEL_NR);
        let mut w = vec![0.0f32; k * np];
        for i in 0..l.in_dim {
            for j in 0..l.out_dim {
                let v = l.w[i * l.out_dim + j];
                w[i * np + j] = match pq {
                    Some(pq) => pq.quantize(v),
                    None => v,
                };
            }
        }
        let mut b = vec![0.0f32; np];
        for (bq, &bv) in b.iter_mut().zip(&l.b) {
            *bq = match pq {
                Some(pq) => pq.quantize(bv),
                None => bv,
            };
        }
        let wmax = l.w.iter().fold(1e-6f32, |a, &v| a.max(v.abs())) as f64;
        layers.push(PlanLayer { w, b, alpha: l.alpha, k, np, in_real: l.in_dim, out_real: l.out_dim, wmax });
        stride = stride.max(np);
        prev_np = np;
    }
    let n_classes = layers.last().expect("weights have at least one layer").out_real;
    let flops_per_row = layers.iter().map(|l| 2 * l.k * l.np).sum();
    Packed { layers, stride, input_dim, n_classes, flops_per_row }
}

/// Reusable ping-pong activation buffers (plus, for SC plans, the
/// per-row noise streams).  Grows to the largest `batch × stride` seen
/// and never shrinks, so the steady state of a serving loop allocates
/// nothing per forward.
#[derive(Default)]
pub struct Scratch {
    ping: Vec<f32>,
    pong: Vec<f32>,
    /// Per-row SC noise streams, re-seeded every forward (FP plans
    /// leave this empty).
    rngs: Vec<Pcg64>,
}

impl Scratch {
    /// Empty scratch (buffers grow on first use).
    pub fn new() -> Self {
        Self::default()
    }

    fn ensure(&mut self, len: usize) {
        if self.ping.len() < len {
            self.ping.resize(len, 0.0);
            self.pong.resize(len, 0.0);
        }
    }

    fn ensure_rngs(&mut self, rows: usize) {
        if self.rngs.len() < rows {
            self.rngs.resize_with(rows, || Pcg64::new(0, 0));
        }
    }
}

/// Recyclable output buffers for [`FpPlan::forward_reuse`] /
/// [`ScPlan::forward_reuse`]: score/pred/margin storage whose
/// capacities persist across forwards.  The native backend circulates
/// these through its recycle pool (`Backend::recycle_outputs`), which
/// is what makes the steady-state serving dispatch allocation-free.
#[derive(Default)]
pub struct OutBufs {
    /// Raw score storage (becomes `Outputs::scores.data`).
    pub scores: Vec<f32>,
    /// Predicted-class storage.
    pub pred: Vec<i32>,
    /// Margin storage.
    pub margin: Vec<f32>,
}

/// Shared shard scaffolding of both plan forwards: size the scratch,
/// split ping/pong/rngs/scores into per-shard slices, run `run(lo,
/// rows, ping, pong, rngs, scores)` for every shard on the persistent
/// worker pool, and leave the assembled scores in `scores`.  Keeping
/// this in one place keeps the bit-identical-across-threads contract
/// uniform across engines.  The serial path (`threads <= 1`, which
/// includes every fixture-sized batch thanks to the work gate in
/// [`pool::auto_threads_for`]) runs inline with no per-call
/// allocation; the threaded path allocates the shard and job vectors
/// (two small Vecs) per call.
fn shard_forward<F>(
    packed: &Packed,
    batch: usize,
    scratch: &mut Scratch,
    threads: usize,
    scores: &mut Vec<f32>,
    use_rngs: bool,
    run: F,
) where
    F: Fn(usize, usize, &mut [f32], &mut [f32], &mut [Pcg64], &mut [f32]) + Sync,
{
    scratch.ensure(batch * packed.stride);
    if use_rngs {
        scratch.ensure_rngs(batch);
    }
    scores.clear();
    scores.resize(batch * packed.n_classes, 0.0);
    if batch == 0 {
        return;
    }
    if threads <= 1 {
        let rngs: &mut [Pcg64] = if use_rngs { &mut scratch.rngs[..batch] } else { &mut [] };
        run(
            0,
            batch,
            &mut scratch.ping[..batch * packed.stride],
            &mut scratch.pong[..batch * packed.stride],
            rngs,
            &mut scores[..],
        );
        return;
    }
    {
        let mut ping: &mut [f32] = &mut scratch.ping[..batch * packed.stride];
        let mut pong: &mut [f32] = &mut scratch.pong[..batch * packed.stride];
        let mut rngs: &mut [Pcg64] = if use_rngs { &mut scratch.rngs[..batch] } else { &mut [] };
        let mut out: &mut [f32] = scores;
        let run = &run;
        let mut jobs = Vec::new();
        for (lo, rows) in pool::shards(batch, threads) {
            let (a, rest) = std::mem::take(&mut ping).split_at_mut(rows * packed.stride);
            ping = rest;
            let (b, rest) = std::mem::take(&mut pong).split_at_mut(rows * packed.stride);
            pong = rest;
            let rg: &mut [Pcg64] = if use_rngs {
                let (rg, rest) = std::mem::take(&mut rngs).split_at_mut(rows);
                rngs = rest;
                rg
            } else {
                &mut []
            };
            let (o, rest) = std::mem::take(&mut out).split_at_mut(rows * packed.n_classes);
            out = rest;
            jobs.push(move || run(lo, rows, a, b, rg, o));
        }
        pool::run_jobs(jobs);
    }
}

/// Prepared truncated-mantissa FP forward: weights and biases quantised
/// once at construction, padded kernel layout, threaded forward, and a
/// [`PreparedQuantizer`] driving every epilogue element (no per-element
/// format math).
pub struct FpPlan {
    packed: Packed,
    /// The format's precomputed round/clamp/flush constants.
    pq: PreparedQuantizer,
    /// The format this plan was quantised for.
    pub fmt: FpFormat,
}

impl FpPlan {
    /// Quantise + pack `weights` for `fmt`.
    pub fn new(weights: &Weights, fmt: FpFormat) -> Self {
        Self { packed: pack(weights, Some(fmt)), pq: fmt.prepare(), fmt }
    }

    /// Input feature width this plan consumes.
    pub fn input_dim(&self) -> usize {
        self.packed.input_dim
    }

    /// Classes per output row.
    pub fn n_classes(&self) -> usize {
        self.packed.n_classes
    }

    /// Work-aware worker count for a batch of `rows`: stays serial when
    /// the whole forward is cheaper than thread spawns (tiny models),
    /// scales toward [`pool::max_threads`] as per-row kernel work grows.
    pub fn auto_threads(&self, rows: usize) -> usize {
        pool::auto_threads_for(rows, self.packed.flops_per_row)
    }

    /// Forward a `(batch, input_dim)` row-major slice on up to `threads`
    /// workers.  Outputs are bit-identical for every `threads` value.
    pub fn forward(&self, x: &[f32], batch: usize, scratch: &mut Scratch, threads: usize) -> Outputs {
        self.forward_reuse(x, batch, scratch, threads, OutBufs::default())
    }

    /// [`Self::forward`] with recycled output storage: `bufs` provides
    /// the score/pred/margin buffers (any content is overwritten), so a
    /// caller that hands back the previous call's outputs makes the
    /// steady-state forward allocation-free.  Bit-identical to
    /// [`Self::forward`].
    pub fn forward_reuse(
        &self,
        x: &[f32],
        batch: usize,
        scratch: &mut Scratch,
        threads: usize,
        bufs: OutBufs,
    ) -> Outputs {
        let p = &self.packed;
        assert_eq!(x.len(), batch * p.input_dim, "input shape mismatch");
        let OutBufs { mut scores, pred, margin } = bufs;
        shard_forward(p, batch, scratch, threads, &mut scores, false, |lo, rows, ping, pong, _rngs, out| {
            self.run_rows(x, lo, rows, ping, pong, out)
        });
        Outputs::from_logits_reuse(Matrix::from_vec(batch, p.n_classes, scores), pred, margin)
    }

    /// One shard: rows `[lo, lo + rows)` of the batch, start to finish.
    /// Every per-element quantisation goes through the prepared
    /// branchless kernel (`self.pq`), bit-identical to the scalar path.
    fn run_rows(&self, x: &[f32], lo: usize, rows: usize, ping: &mut [f32], pong: &mut [f32], scores: &mut [f32]) {
        let p = &self.packed;
        let pq = &self.pq;
        let stride = p.stride;
        // Stage + quantise the input rows (the first layer's operand
        // quantisation, hoisted out of the layer loop).
        for r in 0..rows {
            let src = &x[(lo + r) * p.input_dim..(lo + r + 1) * p.input_dim];
            let dst = &mut ping[r * stride..r * stride + p.input_dim];
            dst.copy_from_slice(src);
            pq.quantize_slice(dst);
        }
        let (mut cur, mut nxt) = (ping, pong);
        let n_layers = p.layers.len();
        for (li, l) in p.layers.iter().enumerate() {
            matmul_strided(cur, stride, &l.w, l.k, nxt, stride, rows, l.np);
            let last = li + 1 == n_layers;
            for r in 0..rows {
                // Padded columns are skipped: the kernel already left
                // exact zeros there (zero weight columns), and they only
                // ever feed zero weight rows downstream.
                let row = &mut nxt[r * stride..r * stride + l.out_real];
                // Epilogue order matches `quant::quant_layer`: + bias,
                // quantise, PReLU, quantise.  Non-negative values are
                // already on the format grid after the first quantise,
                // so the post-activation pass only touches negatives.
                for (v, &b) in row.iter_mut().zip(&l.b) {
                    *v = pq.quantize(*v + b);
                }
                if !last {
                    for v in row.iter_mut() {
                        if *v < 0.0 {
                            *v = pq.quantize(l.alpha * *v);
                        }
                    }
                }
            }
            std::mem::swap(&mut cur, &mut nxt);
        }
        for r in 0..rows {
            scores[r * p.n_classes..(r + 1) * p.n_classes]
                .copy_from_slice(&cur[r * stride..r * stride + p.n_classes]);
        }
    }
}

/// Prepared SC noise-model forward: raw padded weights, per-layer
/// `max|w|` precomputed, per-row noise streams, threaded **layer-major**
/// forward (one whole-shard matmul per layer + per-row noise epilogue).
pub struct ScPlan {
    packed: Packed,
    /// The SC configuration (sequence length) being modelled.
    pub cfg: ScConfig,
}

impl ScPlan {
    /// Pack `weights` for the SC noise model at `cfg`.
    pub fn new(weights: &Weights, cfg: ScConfig) -> Self {
        Self { packed: pack(weights, None), cfg }
    }

    /// Input feature width this plan consumes.
    pub fn input_dim(&self) -> usize {
        self.packed.input_dim
    }

    /// Classes per output row.
    pub fn n_classes(&self) -> usize {
        self.packed.n_classes
    }

    /// Work-aware worker count for a batch of `rows`.  SC rows carry the
    /// kernel flops plus a Box–Muller normal draw and grid snap per
    /// output (`ln`/`cos`-heavy — weighted at 256 flop-equivalents
    /// each), so SC parallelises earlier than FP at equal topology.
    pub fn auto_threads(&self, rows: usize) -> usize {
        let noise: usize = self.packed.layers.iter().map(|l| 256 * l.out_real).sum();
        pool::auto_threads_for(rows, self.packed.flops_per_row + noise)
    }

    /// Forward with an explicit noise seed on up to `threads` workers.
    /// Row `r` draws noise from its own `(seed, SC_ROW_STREAM + r)`
    /// stream, so outputs are bit-identical for every `threads` value.
    pub fn forward(&self, x: &[f32], batch: usize, seed: u64, scratch: &mut Scratch, threads: usize) -> Outputs {
        self.forward_reuse(x, batch, seed, scratch, threads, OutBufs::default())
    }

    /// [`Self::forward`] with recycled output storage (see
    /// [`FpPlan::forward_reuse`]).  The per-row noise streams live in
    /// the scratch and are re-seeded per call, so this is bit-identical
    /// to [`Self::forward`] at equal seed.
    pub fn forward_reuse(
        &self,
        x: &[f32],
        batch: usize,
        seed: u64,
        scratch: &mut Scratch,
        threads: usize,
        bufs: OutBufs,
    ) -> Outputs {
        let p = &self.packed;
        assert_eq!(x.len(), batch * p.input_dim, "input shape mismatch");
        let OutBufs { mut scores, pred, margin } = bufs;
        shard_forward(p, batch, scratch, threads, &mut scores, true, |lo, rows, ping, pong, rngs, out| {
            self.run_rows(x, lo, rows, seed, rngs, ping, pong, out)
        });
        let mut out = Outputs::from_logits_reuse(Matrix::from_vec(batch, p.n_classes, scores), pred, margin);
        out.snap_scores_to_grid(self.cfg.seq_len);
        out
    }

    /// One shard, processed **layer-major**: one `rows × np` matmul per
    /// layer over the whole shard (full register tiles, unlike the old
    /// row-major walk's `m = 1` matmuls, which wasted 3 of the kernel's
    /// 4 register rows), then the per-row noise epilogue.  One [`Pcg64`]
    /// per row persists across layers, so each row's draw order — and
    /// therefore every SC score — is bit-identical to the row-major
    /// walk (pinned against an inline row-major reference in
    /// `tests/kernel_parity.rs`).
    fn run_rows(
        &self,
        x: &[f32],
        lo: usize,
        rows: usize,
        seed: u64,
        rngs: &mut [Pcg64],
        ping: &mut [f32],
        pong: &mut [f32],
        scores: &mut [f32],
    ) {
        let p = &self.packed;
        let stride = p.stride;
        let n_layers = p.layers.len();
        // Re-seed the shard's recycled per-row streams: identical draws
        // to a freshly allocated `Pcg64` per row (`new` also clears the
        // cached Box–Muller half).
        for (r, rng) in rngs.iter_mut().enumerate() {
            *rng = Pcg64::new(seed, SC_ROW_STREAM + (lo + r) as u64);
        }
        for r in 0..rows {
            ping[r * stride..r * stride + p.input_dim]
                .copy_from_slice(&x[(lo + r) * p.input_dim..(lo + r + 1) * p.input_dim]);
        }
        let (mut cur, mut nxt) = (ping, pong);
        for (li, l) in p.layers.iter().enumerate() {
            let last = li + 1 == n_layers;
            let sigma_base = SC_NOISE_C / SC_LFSR_K * (l.in_real as f64 / self.cfg.seq_len as f64).sqrt();
            matmul_strided(cur, stride, &l.w, l.k, nxt, stride, rows, l.np);
            for (r, rng) in rngs.iter_mut().enumerate() {
                // Per-row operand scale, matching the exact bitstream
                // simulator's per-sample normalisation (the hardware
                // encodes x / max|x| per input vector).
                let xmax = cur[r * stride..r * stride + l.k].iter().fold(1e-6f32, |a, &v| a.max(v.abs())) as f64;
                let scale = xmax * l.wmax;
                let sigma = sigma_base * scale;
                let step = self.cfg.grid_step() * scale;
                let orow = &mut nxt[r * stride..r * stride + l.np];
                for (j, &b) in l.b.iter().enumerate().take(l.out_real) {
                    let v = orow[j] + b;
                    let noisy = v as f64 + sigma * rng.normal();
                    let mut v = ((noisy / step).round() * step) as f32;
                    if !last && v < 0.0 {
                        v *= l.alpha;
                    }
                    orow[j] = v;
                }
                // Padded outputs stay exactly zero (zero weights, zero
                // bias, no noise): they feed zero rows downstream.
                for v in &mut orow[l.out_real..l.np] {
                    *v = 0.0;
                }
            }
            std::mem::swap(&mut cur, &mut nxt);
        }
        for r in 0..rows {
            scores[r * p.n_classes..(r + 1) * p.n_classes]
                .copy_from_slice(&cur[r * stride..r * stride + p.n_classes]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::LayerWeights;

    fn weights(in_dim: usize, hidden: usize, classes: usize, seed: u64) -> Weights {
        let mut rng = Pcg64::seeded(seed);
        let mut mk = |i: usize, o: usize| LayerWeights {
            w: (0..i * o).map(|_| (rng.next_f32() - 0.5) * 0.4).collect(),
            in_dim: i,
            out_dim: o,
            b: (0..o).map(|_| (rng.next_f32() - 0.5) * 0.1).collect(),
            alpha: 0.25,
        };
        Weights { layers: vec![mk(in_dim, hidden), mk(hidden, classes)] }
    }

    #[test]
    fn fp_plan_matches_unprepared_reference() {
        let w = weights(11, 13, 5, 1);
        let mut rng = Pcg64::seeded(2);
        let batch = 9;
        let x: Vec<f32> = (0..batch * 11).map(|_| rng.next_f32() - 0.5).collect();
        for fmt in [FpFormat::fp(16), FpFormat::fp(8)] {
            // Reference: the unprepared per-call path (clone + requantise
            // per layer) straight through quant_layer.
            let mut h = Matrix::from_vec(batch, 11, x.clone());
            let n = w.layers.len();
            for (i, l) in w.layers.iter().enumerate() {
                let wm = Matrix::from_vec(l.in_dim, l.out_dim, l.w.clone());
                h = crate::quant::quant_layer(&h, &wm, &l.b, l.alpha, fmt, i + 1 < n);
            }
            let want = Outputs::from_logits(h);
            let plan = FpPlan::new(&w, fmt);
            for threads in [1usize, 2, 4] {
                let mut scratch = Scratch::new();
                let got = plan.forward(&x, batch, &mut scratch, threads);
                assert_eq!(got.scores.data, want.scores.data, "threads={threads}");
                assert_eq!(got.pred, want.pred);
                assert_eq!(got.margin, want.margin);
            }
        }
    }

    #[test]
    fn sc_plan_invariant_to_thread_count() {
        let w = weights(12, 16, 6, 3);
        let mut rng = Pcg64::seeded(4);
        let batch = 11;
        let x: Vec<f32> = (0..batch * 12).map(|_| rng.next_f32() - 0.5).collect();
        let plan = ScPlan::new(&w, ScConfig::new(256));
        let mut scratch = Scratch::new();
        let base = plan.forward(&x, batch, 42, &mut scratch, 1);
        for threads in [2usize, 3, 4] {
            let got = plan.forward(&x, batch, 42, &mut scratch, threads);
            assert_eq!(got.scores.data, base.scores.data, "threads={threads}");
            assert_eq!(got.pred, base.pred);
        }
        // Different seeds give different streams (statistically).
        let other = plan.forward(&x, batch, 43, &mut scratch, 2);
        assert_eq!(other.pred.len(), batch);
    }

    #[test]
    fn scratch_reuse_is_clean() {
        // A big batch followed by a small one must not see stale data.
        let w = weights(10, 12, 4, 5);
        let plan = FpPlan::new(&w, FpFormat::fp(10));
        let mut rng = Pcg64::seeded(6);
        let big: Vec<f32> = (0..32 * 10).map(|_| rng.next_f32() - 0.5).collect();
        let small: Vec<f32> = big[..4 * 10].to_vec();
        let mut scratch = Scratch::new();
        let _ = plan.forward(&big, 32, &mut scratch, 2);
        let a = plan.forward(&small, 4, &mut scratch, 2);
        let b = plan.forward(&small, 4, &mut Scratch::new(), 1);
        assert_eq!(a.scores.data, b.scores.data);
    }

    #[test]
    fn plan_reports_topology() {
        let w = weights(10, 12, 4, 7);
        let plan = FpPlan::new(&w, FpFormat::FP16);
        assert_eq!(plan.input_dim(), 10);
        assert_eq!(plan.n_classes(), 4);
        let sc = ScPlan::new(&w, ScConfig::new(64));
        assert_eq!(sc.input_dim(), 10);
        assert_eq!(sc.n_classes(), 4);
    }
}
