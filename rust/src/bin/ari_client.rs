//! `ari-client` — load generator for the ARI TCP serving tier.
//!
//! ```text
//! ari-client --connect 127.0.0.1:7070 [--mode open|partial|closed] [--rate R]
//!            [--requests N] [--seed S] [--concurrency K] [--outstanding M]
//!            [--dataset NAME] [--timeout-ms T] [--reconnects R] [--json NAME]
//! ```
//!
//! Drives a `ari serve --listen ADDR` server over the length-prefixed
//! wire protocol (`docs/PROTOCOL.md`) in one of three load shapes
//! (open, partial-open, closed loop), reconnecting with exponential
//! backoff — which also absorbs the server's startup race in the smoke
//! targets.  Rows come from the same dataset and RNG stream as the
//! server's in-process generator, so a fixed seed is row-for-row
//! comparable with an in-process session.
//!
//! Prints the client report (sent/received/lost, outcome mix, wire
//! p50/p95/p99); with `ARI_BENCH_JSON` set, also records the wire
//! latency quantiles as `ari-bench v1` entries (`make bench-serve`
//! routes them into `BENCH_serve.json`).

use std::time::Duration;

use ari::runtime::{Backend, NativeBackend};
use ari::server::net::client::{run_client, ClientConfig, LoadMode};
use ari::util::benchkit::{BenchResult, JsonReport};

const HELP: &str = "ari-client — load generator for the ARI TCP serving tier\n\
flags:\n  --connect ADDR      server address (required), e.g. 127.0.0.1:7070\n  \
--mode M            open | partial | closed (default closed)\n  \
--rate R            Poisson req/s for open/partial (0 = back-to-back)\n  \
--requests N        requests to send (default 256)\n  \
--seed S            workload seed (match the server's for parity)\n  \
--concurrency K     closed-loop window (default 8)\n  \
--outstanding M     partial-open outstanding cap (default 32)\n  \
--dataset NAME      synthetic dataset to draw rows from (default fashion_syn)\n  \
--timeout-ms T      idle timeout before outstanding requests count lost (default 5000)\n  \
--reconnects R      max (re)connect attempts (default 8)\n  \
--json NAME         ARI_BENCH_JSON entry prefix (default ari-client)\n  \
--stats             fetch and print the server's live stats snapshot, then exit\n\
see docs/PROTOCOL.md for the wire format.";

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn parse_flag<'a>(it: &mut std::iter::Peekable<std::slice::Iter<'a, String>>, flag: &str) -> ari::Result<&'a str> {
    it.next().map(|s| s.as_str()).ok_or_else(|| anyhow::anyhow!("{flag} needs a value"))
}

fn run() -> ari::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut cfg = ClientConfig::default();
    let mut addr: Option<String> = None;
    let mut mode_name = String::from("closed");
    let mut concurrency = 8usize;
    let mut outstanding = 32usize;
    let mut dataset = String::from("fashion_syn");
    let mut json_name = String::from("ari-client");
    let mut stats_only = false;
    let mut it = args.iter().peekable();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--stats" => stats_only = true,
            "--connect" => addr = Some(parse_flag(&mut it, "--connect")?.to_string()),
            "--mode" => mode_name = parse_flag(&mut it, "--mode")?.to_string(),
            "--rate" => cfg.rate = parse_flag(&mut it, "--rate")?.parse()?,
            "--requests" => cfg.requests = parse_flag(&mut it, "--requests")?.parse()?,
            "--seed" => cfg.seed = parse_flag(&mut it, "--seed")?.parse()?,
            "--concurrency" => concurrency = parse_flag(&mut it, "--concurrency")?.parse()?,
            "--outstanding" => outstanding = parse_flag(&mut it, "--outstanding")?.parse()?,
            "--dataset" => dataset = parse_flag(&mut it, "--dataset")?.to_string(),
            "--timeout-ms" => cfg.timeout = Duration::from_millis(parse_flag(&mut it, "--timeout-ms")?.parse()?),
            "--reconnects" => cfg.max_reconnects = parse_flag(&mut it, "--reconnects")?.parse()?,
            "--json" => json_name = parse_flag(&mut it, "--json")?.to_string(),
            "--help" | "-h" => {
                println!("{HELP}");
                return Ok(());
            }
            other => anyhow::bail!("unknown flag {other:?}\n{HELP}"),
        }
    }
    cfg.addr = addr.ok_or_else(|| anyhow::anyhow!("--connect ADDR is required\n{HELP}"))?;
    if stats_only {
        let s = ari::server::net::client::fetch_stats(&cfg.addr, cfg.timeout)?;
        println!("stats from {}:", cfg.addr);
        println!(
            "  requests: {} admitted + {} shed -> {} responses sent ({} completed)",
            s.admitted, s.shed, s.responses_sent, s.completed
        );
        println!("  outcomes: degraded {} rejected {} failed {}", s.degraded, s.rejected, s.failed);
        println!(
            "  control: tighten level {} drifted {} recalibrations {}",
            s.level,
            if s.drifted { "yes" } else { "no" },
            s.recals
        );
        for (i, st) in s.stages.iter().enumerate() {
            println!("  stage {i}: served {} threshold {:.6}", st.served, st.threshold);
        }
        return Ok(());
    }
    cfg.mode = match mode_name.as_str() {
        "open" => LoadMode::Open,
        "partial" => LoadMode::PartialOpen { max_outstanding: outstanding },
        "closed" => LoadMode::Closed { concurrency },
        other => anyhow::bail!("unknown --mode {other:?} (open | partial | closed)"),
    };

    // Rows come from the same synthetic fixture suite the native
    // backend serves, so client and server agree on dimensions and
    // content without sharing artifacts over the wire.
    let engine = NativeBackend::synthetic();
    let data = engine.eval_data(&dataset)?;
    println!(
        "ari-client -> {} ({} x {} req, mode {}, rate {}, seed {})",
        cfg.addr, dataset, cfg.requests, mode_name, cfg.rate, cfg.seed
    );
    let report = run_client(&cfg, &data)?;
    println!("{}", report.summary());

    let mut json = JsonReport::new(&json_name);
    json.add_extra(
        &BenchResult {
            name: format!("{json_name} wall"),
            mean_ns: report.wall.as_nanos() as f64,
            std_ns: 0.0,
            iters: 1,
        },
        Some(report.received),
        &[
            ("sent", report.sent as f64),
            ("lost", report.lost as f64),
            ("wire_errors", report.wire_errors as f64),
            ("reconnects", report.reconnects as f64),
        ],
    );
    for (suffix, d) in [
        ("wire p50", report.p50),
        ("wire p95", report.p95),
        ("wire p99", report.p99),
        ("wire mean", report.mean_latency),
    ] {
        json.add(
            &BenchResult {
                name: format!("{json_name} {suffix}"),
                mean_ns: d.as_nanos() as f64,
                std_ns: 0.0,
                iters: 1,
            },
            None,
        );
    }
    if let Some(p) = json.write_if_requested() {
        println!("wrote {p:?}");
    }
    Ok(())
}
