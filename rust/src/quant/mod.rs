//! Truncated-mantissa floating-point emulation — the rust twin of the L1
//! `quant_matmul` Pallas kernel.
//!
//! The paper derives every reduced FP model from the FP16 full model by
//! removing mantissa LSBs (Fig. 2).  `FpFormat` mirrors
//! `python/compile/kernels/quant_matmul.QuantSpec` exactly: the python
//! tests and `rust/tests/quant_parity.rs` pin both implementations to the
//! same golden values, so the pure-rust [`crate::mlp`] baseline and the
//! PJRT executables agree bit-for-bit on quantisation.

/// An FP16-family format: 1 sign bit, `e_bits` exponent bits, `m_bits`
/// mantissa bits.  The paper's "FPk" is `FpFormat::fp(k)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct FpFormat {
    /// Mantissa bits kept (1..=23).
    pub m_bits: u32,
    /// Exponent bits (2..=8; the paper's family uses 5).
    pub e_bits: u32,
}

impl FpFormat {
    /// Build a format from explicit mantissa/exponent widths.
    pub const fn new(m_bits: u32, e_bits: u32) -> Self {
        assert!(m_bits >= 1 && m_bits <= 23);
        assert!(e_bits >= 2 && e_bits <= 8);
        Self { m_bits, e_bits }
    }

    /// Paper notation: FP16 = full model, FP10 = 6 mantissa bits removed…
    /// (total = 1 sign + 5 exponent + mantissa).
    pub const fn fp(total_bits: u32) -> Self {
        Self::new(total_bits - 6, 5)
    }

    /// The full model's format (IEEE half precision).
    pub const FP16: FpFormat = FpFormat::fp(16);

    /// Total storage bits: 1 sign + exponent + mantissa.
    pub fn total_bits(&self) -> u32 {
        1 + self.e_bits + self.m_bits
    }

    /// Largest finite magnitude: (2 - 2^-m) * 2^emax.
    pub fn max_value(&self) -> f32 {
        let emax = ((1u32 << (self.e_bits - 1)) - 1) as i32;
        (2.0 - (-(self.m_bits as f32)).exp2()) * (emax as f32).exp2()
    }

    /// Smallest normal magnitude: 2^emin.
    pub fn min_normal(&self) -> f32 {
        let emin = 2 - (1i32 << (self.e_bits - 1));
        (emin as f32).exp2()
    }

    /// Quantise one f32 (round-to-nearest-even on the mantissa, clamp to
    /// the format range, flush subnormals to zero, NaN passes through).
    /// Bit-identical to the python `quantize_fp`.
    pub fn quantize(&self, x: f32) -> f32 {
        if x.is_nan() {
            return x;
        }
        let shift = 23 - self.m_bits;
        let q = if shift == 0 {
            // m_bits == 23 keeps the full f32 mantissa: rounding is the
            // identity, and the bit trick below would underflow
            // (`1 << (shift - 1)` with shift = 0).  Range clamp and
            // subnormal flush still apply.
            x
        } else {
            let i = x.to_bits();
            let lsb = (i >> shift) & 1;
            let bias = lsb + ((1u32 << (shift - 1)) - 1);
            let i = i.wrapping_add(bias) & !((1u32 << shift) - 1);
            f32::from_bits(i)
        };
        let q = q.clamp(-self.max_value(), self.max_value());
        if q.abs() < self.min_normal() {
            0.0
        } else {
            q
        }
    }

    /// Quantise a slice in place.
    pub fn quantize_slice(&self, xs: &mut [f32]) {
        for x in xs {
            *x = self.quantize(*x);
        }
    }

    /// Precompute this format's quantisation constants — see
    /// [`PreparedQuantizer`].
    pub fn prepare(&self) -> PreparedQuantizer {
        PreparedQuantizer::new(*self)
    }
}

/// A quantiser prepared once per [`FpFormat`]: the mantissa round bias
/// and keep mask plus the clamp/flush bounds precomputed as `u32` bit
/// patterns, driving a **branchless** per-element kernel the compiler
/// can vectorise.  The scalar [`FpFormat::quantize`] recomputes
/// `max_value()`/`min_normal()` — four `exp2` calls — on every element;
/// this does all of that exactly once at construction.
///
/// Bit-identical to [`FpFormat::quantize`] for **every** `f32` bit
/// pattern (NaN passthrough, ±0, subnormals, halfway-RNE cases, ±max,
/// infinities) — pinned by the `tests/quantizer_equivalence.rs` suite
/// over all constructible `(m_bits, e_bits)` formats.
#[derive(Clone, Copy, Debug)]
pub struct PreparedQuantizer {
    fmt: FpFormat,
    /// Mantissa bits dropped: `23 - m_bits`.
    shift: u32,
    /// 1 when RNE applies (`shift > 0`), 0 for the identity (`m = 23`) —
    /// gates the round-to-even LSB term without a branch.
    lsb_gate: u32,
    /// `(1 << (shift - 1)) - 1`, or 0 when `shift == 0`.
    half_bias: u32,
    /// `!((1 << shift) - 1)`: mask keeping the surviving mantissa bits.
    keep_mask: u32,
    /// `max_value().to_bits()`: clamp bound on the magnitude bits (for
    /// positive finite floats, bit order == value order).
    max_bits: u32,
    /// `min_normal().to_bits()`: flush-to-zero bound on the magnitude.
    min_bits: u32,
}

impl PreparedQuantizer {
    /// Precompute the round/clamp/flush constants for `fmt`.
    pub fn new(fmt: FpFormat) -> Self {
        let shift = 23 - fmt.m_bits;
        Self {
            fmt,
            shift,
            lsb_gate: u32::from(shift != 0),
            half_bias: if shift == 0 { 0 } else { (1u32 << (shift - 1)) - 1 },
            keep_mask: if shift == 0 { !0 } else { !((1u32 << shift) - 1) },
            max_bits: fmt.max_value().to_bits(),
            min_bits: fmt.min_normal().to_bits(),
        }
    }

    /// The format this quantiser was prepared for.
    pub fn format(&self) -> FpFormat {
        self.fmt
    }

    /// Quantise one value — branchless bit-pattern twin of
    /// [`FpFormat::quantize`] (same RNE, clamp, subnormal flush and NaN
    /// passthrough; flushed values come back as `+0.0` either way).
    #[inline(always)]
    pub fn quantize(&self, x: f32) -> f32 {
        let bits = x.to_bits();
        let sign = bits & 0x8000_0000;
        let mag = bits & 0x7FFF_FFFF;
        // Round-to-nearest-even on the magnitude (identity when m = 23):
        // add the tie-to-even bias, clear the dropped mantissa bits.
        // Carries propagate into the exponent, which is exactly how the
        // scalar bit trick rounds across binades.
        let lsb = (mag >> self.shift) & self.lsb_gate;
        let r = (mag + lsb + self.half_bias) & self.keep_mask;
        // Clamp to the largest finite magnitude (also catches inf and
        // rounding carries past the top), then flush subnormals to +0.
        let r = if r > self.max_bits { self.max_bits } else { r };
        let q = if r < self.min_bits { 0 } else { r | sign };
        // NaN passes through with its payload, like the scalar path.
        f32::from_bits(if mag > 0x7F80_0000 { bits } else { q })
    }

    /// Quantise a slice in place — the hot-path form: one branchless
    /// kernel per element, no per-element format math, vectorisable.
    pub fn quantize_slice(&self, xs: &mut [f32]) {
        for x in xs {
            *x = self.quantize(*x);
        }
    }
}

/// Reduced-precision MLP layer on the pure-rust substrate — mirrors the
/// pallas kernel: quantised operands, f32 accumulator, quantised epilogue.
pub fn quant_layer(
    x: &crate::tensor::Matrix,
    w: &crate::tensor::Matrix,
    b: &[f32],
    alpha: f32,
    fmt: FpFormat,
    activate: bool,
) -> crate::tensor::Matrix {
    let mut xq = x.clone();
    fmt.quantize_slice(&mut xq.data);
    let mut wq = w.clone();
    fmt.quantize_slice(&mut wq.data);
    let mut out = xq.matmul(&wq);
    let bq: Vec<f32> = b.iter().map(|&v| fmt.quantize(v)).collect();
    out.add_row(&bq);
    fmt.quantize_slice(&mut out.data);
    if activate {
        out.prelu(alpha);
        fmt.quantize_slice(&mut out.data);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fp16_constants() {
        let f = FpFormat::FP16;
        assert_eq!(f.m_bits, 10);
        assert_eq!(f.e_bits, 5);
        assert_eq!(f.total_bits(), 16);
        assert!((f.max_value() - 65504.0).abs() < 1.0);
        assert!((f.min_normal() - 2f32.powi(-14)).abs() < 1e-12);
    }

    #[test]
    fn quantize_exact_values_fixed() {
        // FP16 can represent 1.0, 1.5, 0.25 exactly.
        let f = FpFormat::FP16;
        for v in [0.0f32, 1.0, -1.0, 1.5, 0.25, 2048.0] {
            assert_eq!(f.quantize(v), v, "{v}");
        }
    }

    #[test]
    fn quantize_idempotent_random() {
        let mut rng = crate::util::Pcg64::seeded(5);
        for fmt in [FpFormat::fp(8), FpFormat::fp(10), FpFormat::fp(12), FpFormat::fp(16)] {
            for _ in 0..1000 {
                let x = (rng.next_f32() - 0.5) * rng.range_f64(1e-3, 1e3) as f32;
                let q = fmt.quantize(x);
                assert_eq!(fmt.quantize(q), q);
            }
        }
    }

    #[test]
    fn quantize_error_bound() {
        let mut rng = crate::util::Pcg64::seeded(6);
        for m in [2u32, 4, 6, 8, 10] {
            let fmt = FpFormat::new(m, 5);
            for _ in 0..1000 {
                let x = (rng.next_f32() - 0.5) * 100.0;
                if x.abs() < fmt.min_normal() * 2.0 || x.abs() > fmt.max_value() / 2.0 {
                    continue;
                }
                let rel = ((fmt.quantize(x) - x) / x).abs();
                assert!(rel <= 0.5f32.powi(m as i32 + 1) + 1e-7, "m={m} x={x} rel={rel}");
            }
        }
    }

    #[test]
    fn quantize_every_constructible_mantissa_width() {
        // Regression: m_bits = 23 gives shift = 0 and used to panic in
        // debug (`1 << (shift - 1)`) / wrap in release even though
        // `FpFormat::new(23, _)` is a legal constructor.  Sweep the full
        // constructible range.
        let mut rng = crate::util::Pcg64::seeded(23);
        for m in 1..=23u32 {
            for e in [2u32, 5, 8] {
                let fmt = FpFormat::new(m, e);
                for _ in 0..200 {
                    let x = (rng.next_f32() - 0.5) * rng.range_f64(1e-4, 1e4) as f32;
                    let q = fmt.quantize(x);
                    assert!(q.is_finite(), "m={m} e={e} x={x}");
                    assert_eq!(fmt.quantize(q), q, "idempotency m={m} e={e} x={x}");
                    assert!(q.abs() <= fmt.max_value());
                }
            }
        }
    }

    #[test]
    fn full_mantissa_is_identity_in_range() {
        // m_bits = 23, e_bits = 8 covers the whole normal f32 range:
        // quantisation must be the identity there.
        let fmt = FpFormat::new(23, 8);
        let mut rng = crate::util::Pcg64::seeded(29);
        for _ in 0..500 {
            let x = (rng.next_f32() - 0.5) * 1e6;
            assert_eq!(fmt.quantize(x), x, "{x}");
        }
        assert_eq!(fmt.quantize(0.0), 0.0);
        assert_eq!(fmt.quantize(f32::MAX), f32::MAX);
        // Narrower exponent still clamps/flushes with the full mantissa.
        let half_range = FpFormat::new(23, 5);
        assert_eq!(half_range.quantize(1e9), half_range.max_value());
        assert_eq!(half_range.quantize(1e-9), 0.0);
        assert_eq!(half_range.quantize(1.5), 1.5);
    }

    #[test]
    fn clamp_and_flush() {
        let f = FpFormat::fp(10); // max 2^15*(2-2^-4)=~63488? (m=4)
        assert_eq!(f.quantize(1e9), f.max_value());
        assert_eq!(f.quantize(-1e9), -f.max_value());
        assert_eq!(f.quantize(1e-9), 0.0);
    }

    #[test]
    fn rne_halfway_rounds_to_even() {
        // FP16: 1 + 2^-11 is halfway between 1 and 1 + 2^-10 -> 1 (even).
        let f = FpFormat::FP16;
        assert_eq!(f.quantize(1.0 + 2f32.powi(-11)), 1.0);
        assert_eq!(f.quantize(1.0 + 2f32.powi(-11) + 2f32.powi(-20)), 1.0 + 2f32.powi(-10));
    }

    #[test]
    fn nan_passthrough() {
        assert!(FpFormat::FP16.quantize(f32::NAN).is_nan());
    }

    #[test]
    fn coarser_format_never_more_accurate() {
        let mut rng = crate::util::Pcg64::seeded(8);
        for _ in 0..200 {
            let x = (rng.next_f32() - 0.5) * 10.0;
            let mut last = f32::INFINITY;
            for m in [2u32, 4, 6, 8, 10] {
                let err = (FpFormat::new(m, 5).quantize(x) - x).abs();
                assert!(err <= last + 1e-9);
                last = err;
            }
        }
    }

    #[test]
    fn prepared_quantizer_constants_smoke() {
        // One representative check per precomputed constant; the
        // exhaustive scalar-vs-prepared equivalence (all constructible
        // formats, full-range bit patterns, NaN/tie/bound edges) lives
        // in `tests/quantizer_equivalence.rs` — keep that the single
        // source of truth for the contract.
        let pq = FpFormat::FP16.prepare();
        assert_eq!(pq.shift, 13);
        assert_eq!(pq.lsb_gate, 1);
        assert_eq!(pq.half_bias, (1 << 12) - 1);
        assert_eq!(pq.keep_mask, !((1u32 << 13) - 1));
        assert_eq!(pq.max_bits, 65504.0f32.to_bits());
        assert_eq!(pq.min_bits, 2f32.powi(-14).to_bits());
        // m = 23: rounding must be the identity (no underflowing shift).
        let full = FpFormat::new(23, 8).prepare();
        assert_eq!(full.lsb_gate, 0);
        assert_eq!(full.half_bias, 0);
        assert_eq!(full.keep_mask, !0);
    }

    #[test]
    fn prepared_quantizer_slice_matches_elementwise() {
        let fmt = FpFormat::fp(10);
        let pq = fmt.prepare();
        assert_eq!(pq.format(), fmt);
        let mut rng = crate::util::Pcg64::seeded(31);
        let mut xs: Vec<f32> = (0..4096).map(|_| (rng.next_f32() - 0.5) * rng.range_f64(1e-6, 1e6) as f32).collect();
        let mut want = xs.clone();
        fmt.quantize_slice(&mut want);
        pq.quantize_slice(&mut xs);
        assert_eq!(
            xs.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            want.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn quant_layer_shapes_and_effect() {
        use crate::tensor::Matrix;
        let mut rng = crate::util::Pcg64::seeded(9);
        let x = Matrix::from_fn(4, 8, |_, _| rng.next_f32() - 0.5);
        let w = Matrix::from_fn(8, 3, |_, _| (rng.next_f32() - 0.5) * 0.2);
        let b = vec![0.01f32, -0.02, 0.03];
        let full = quant_layer(&x, &w, &b, 0.25, FpFormat::fp(16), true);
        let coarse = quant_layer(&x, &w, &b, 0.25, FpFormat::fp(8), true);
        assert_eq!(full.rows, 4);
        assert_eq!(full.cols, 3);
        // coarse output must be on a coarser grid: every value q(q)=q at fp8
        for &v in &coarse.data {
            assert_eq!(FpFormat::fp(8).quantize(v), v);
        }
        // and differ somewhere from the fp16 result
        assert_ne!(full.data, coarse.data);
    }
}
