//! Minimal f32 matrix substrate for the pure-rust inference engine.
//!
//! This is deliberately small: row-major storage, a register-blocked
//! tiled matmul kernel, and the handful of elementwise ops the MLP
//! needs — no external BLAS.  The hot path is [`matmul_strided`]: an
//! `MR`×`NR` register-tile kernel that accumulates each output element
//! over `k` in ascending order, which makes it **bit-identical to the
//! naive triple loop** ([`Matrix::matmul_naive`]) — the property
//! `tests/kernel_parity.rs` pins, and what lets the prepared-plan
//! forward pass shard batch rows across threads without changing a
//! single bit of output.

/// Row-register width of the tiled kernel (i-block).
pub const KERNEL_MR: usize = 4;

/// Column-register width of the tiled kernel (j-block).  Prepared plans
/// pad weight matrices' output dimension to a multiple of this so the
/// steady-state kernel never takes the ragged-edge path.
pub const KERNEL_NR: usize = 8;

/// Tiled matmul with explicit row strides: `out[i][j] = sum_p a[i][p] *
/// b[p][j]` for `i < m`, `j < n`, `p < k`, where row `i` of `a` lives at
/// `a[i*lda..i*lda+k]`, `b` is packed `(k, n)` row-major, and row `i` of
/// `out` lives at `out[i*ldo..i*ldo+n]`.
///
/// Each output element accumulates over `p` in ascending order (register
/// tiling only changes *which* elements are in flight, never the
/// per-element summation order), so results are bit-identical to
/// [`Matrix::matmul_naive`] and independent of the `MR`/`NR` blocking.
pub fn matmul_strided(a: &[f32], lda: usize, b: &[f32], k: usize, out: &mut [f32], ldo: usize, m: usize, n: usize) {
    debug_assert!(m == 0 || (m - 1) * lda + k <= a.len(), "a too short");
    debug_assert!(k * n <= b.len(), "b too short");
    debug_assert!(m == 0 || (m - 1) * ldo + n <= out.len(), "out too short");
    let mut i = 0;
    while i < m {
        let ib = KERNEL_MR.min(m - i);
        let mut j = 0;
        while j < n {
            let jb = KERNEL_NR.min(n - j);
            let mut acc = [[0.0f32; KERNEL_NR]; KERNEL_MR];
            for p in 0..k {
                let brow = &b[p * n + j..p * n + j + jb];
                for (mi, accr) in acc.iter_mut().enumerate().take(ib) {
                    let av = a[(i + mi) * lda + p];
                    if jb == KERNEL_NR {
                        // Full tile: fixed trip count so the compiler can
                        // unroll/vectorise with no bounds checks.
                        for nj in 0..KERNEL_NR {
                            accr[nj] += av * brow[nj];
                        }
                    } else {
                        for (nj, &bv) in brow.iter().enumerate() {
                            accr[nj] += av * bv;
                        }
                    }
                }
            }
            for (mi, accr) in acc.iter().enumerate().take(ib) {
                out[(i + mi) * ldo + j..(i + mi) * ldo + j + jb].copy_from_slice(&accr[..jb]);
            }
            j += jb;
        }
        i += ib;
    }
}

/// Row-major 2-D f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    /// Row count.
    pub rows: usize,
    /// Column count.
    pub cols: usize,
    /// Row-major storage, `rows * cols` long.
    pub data: Vec<f32>,
}

impl Matrix {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Wrap row-major data (must be exactly `rows * cols` long).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Self { rows, cols, data }
    }

    /// Build element-wise from `f(row, col)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Self { rows, cols, data }
    }

    /// One element.
    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    /// Overwrite one element.
    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    /// One row as a slice.
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// One row as a mutable slice.
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// `self (m,k) @ other (k,n)` via the tiled [`matmul_strided`]
    /// kernel (dense, branch-free, register-blocked).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(m, n);
        matmul_strided(&self.data, k, &other.data, k, &mut out.data, n, m, n);
        out
    }

    /// Reference triple-loop matmul (`for i { for j { for p } }`).  Slow;
    /// exists as the golden the tiled kernel is pinned against in
    /// `tests/kernel_parity.rs`.
    pub fn matmul_naive(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for p in 0..k {
                    acc += self.data[i * k + p] * other.data[p * n + j];
                }
                out.data[i * n + j] = acc;
            }
        }
        out
    }

    /// Add a row vector to every row.
    pub fn add_row(&mut self, bias: &[f32]) {
        assert_eq!(bias.len(), self.cols);
        for r in 0..self.rows {
            for (v, b) in self.row_mut(r).iter_mut().zip(bias) {
                *v += b;
            }
        }
    }

    /// In-place elementwise map.
    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for v in &mut self.data {
            *v = f(*v);
        }
    }

    /// PReLU with slope `alpha`.
    pub fn prelu(&mut self, alpha: f32) {
        self.map_inplace(|v| if v >= 0.0 { v } else { alpha * v });
    }

    /// Row-wise L2 normalisation (the score mapping of the ARI models).
    pub fn l2_normalize_rows(&mut self) {
        for r in 0..self.rows {
            let row = self.row_mut(r);
            let norm = (row.iter().map(|v| v * v).sum::<f32>() + 1e-12).sqrt();
            for v in row.iter_mut() {
                *v /= norm;
            }
        }
    }

    /// Row-wise softmax (numerically stable).
    pub fn softmax_rows(&mut self) {
        for r in 0..self.rows {
            let row = self.row_mut(r);
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0f32;
            for v in row.iter_mut() {
                *v = (*v - max).exp();
                sum += *v;
            }
            for v in row.iter_mut() {
                *v /= sum;
            }
        }
    }
}

/// (pred, margin) of one score row: argmax class and top1 - top2 gap.
pub fn top2_margin(scores: &[f32]) -> (usize, f32) {
    assert!(scores.len() >= 2);
    let (mut i1, mut s1, mut s2) = (0usize, f32::NEG_INFINITY, f32::NEG_INFINITY);
    for (i, &s) in scores.iter().enumerate() {
        if s > s1 {
            s2 = s1;
            s1 = s;
            i1 = i;
        } else if s > s2 {
            s2 = s;
        }
    }
    (i1, s1 - s2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let eye = Matrix::from_fn(2, 2, |r, c| if r == c { 1.0 } else { 0.0 });
        assert_eq!(a.matmul(&eye), a);
    }

    #[test]
    fn matmul_known_values() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Matrix::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    #[should_panic(expected = "matmul shape mismatch")]
    fn matmul_rejects_bad_shapes() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 3);
        let _ = a.matmul(&b);
    }

    #[test]
    fn bias_and_prelu() {
        let mut m = Matrix::from_vec(1, 3, vec![-2.0, 0.0, 2.0]);
        m.add_row(&[1.0, 1.0, 1.0]);
        m.prelu(0.25);
        assert_eq!(m.data, vec![-0.25, 1.0, 3.0]);
    }

    #[test]
    fn softmax_rows_normalised() {
        let mut m = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0]);
        m.softmax_rows();
        for r in 0..2 {
            let s: f32 = m.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
        assert!(m.get(0, 2) > m.get(0, 1));
    }

    #[test]
    fn softmax_handles_large_logits() {
        let mut m = Matrix::from_vec(1, 2, vec![1000.0, 999.0]);
        m.softmax_rows();
        assert!(m.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn top2_margin_basic() {
        let (pred, margin) = top2_margin(&[0.1, 0.6, 0.3]);
        assert_eq!(pred, 1);
        assert!((margin - 0.3).abs() < 1e-6);
    }

    #[test]
    fn top2_margin_ties() {
        let (pred, margin) = top2_margin(&[0.5, 0.5]);
        assert_eq!(pred, 0);
        assert_eq!(margin, 0.0);
    }

    #[test]
    fn tiled_kernel_bit_identical_to_naive() {
        // Shapes straddling the MR/NR tile edges, including ragged ones.
        let mut rng = crate::util::Pcg64::seeded(21);
        for (m, k, n) in [(1, 1, 1), (3, 5, 7), (4, 8, 8), (5, 9, 17), (32, 24, 32), (2, 100, 3)] {
            let a = Matrix::from_fn(m, k, |_, _| rng.next_f32() - 0.5);
            let b = Matrix::from_fn(k, n, |_, _| rng.next_f32() - 0.5);
            let tiled = a.matmul(&b);
            let naive = a.matmul_naive(&b);
            assert_eq!(tiled.data, naive.data, "m={m} k={k} n={n}");
        }
    }

    #[test]
    fn strided_kernel_respects_strides() {
        // Rows of a and out embedded in wider buffers; the gap bytes
        // must never be read or written.
        let (m, k, n, lda, ldo) = (3usize, 4usize, 5usize, 7usize, 9usize);
        let mut rng = crate::util::Pcg64::seeded(22);
        let mut a = vec![f32::NAN; (m - 1) * lda + k];
        for i in 0..m {
            for p in 0..k {
                a[i * lda + p] = rng.next_f32() - 0.5;
            }
        }
        let b = Matrix::from_fn(k, n, |_, _| rng.next_f32() - 0.5);
        let sentinel = -123.0f32;
        let mut out = vec![sentinel; (m - 1) * ldo + n];
        matmul_strided(&a, lda, &b.data, k, &mut out, ldo, m, n);
        let at = Matrix::from_fn(m, k, |i, p| a[i * lda + p]);
        let want = at.matmul_naive(&b);
        for i in 0..m {
            for j in 0..n {
                assert_eq!(out[i * ldo + j], want.get(i, j), "({i},{j})");
            }
            // Stride gap untouched.
            if i + 1 < m {
                for g in n..ldo {
                    assert_eq!(out[i * ldo + g], sentinel);
                }
            }
        }
    }

    #[test]
    fn matmul_matches_naive_random() {
        let mut rng = crate::util::Pcg64::seeded(77);
        for _ in 0..10 {
            let (m, k, n) = (1 + rng.below(8) as usize, 1 + rng.below(8) as usize, 1 + rng.below(8) as usize);
            let a = Matrix::from_fn(m, k, |_, _| rng.next_f32() - 0.5);
            let b = Matrix::from_fn(k, n, |_, _| rng.next_f32() - 0.5);
            let c = a.matmul(&b);
            for i in 0..m {
                for j in 0..n {
                    let naive: f32 = (0..k).map(|p| a.get(i, p) * b.get(p, j)).sum();
                    assert!((c.get(i, j) - naive).abs() < 1e-4);
                }
            }
        }
    }
}
