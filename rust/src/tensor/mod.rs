//! Minimal f32 matrix substrate for the pure-rust inference engine.
//!
//! This is deliberately small: row-major storage, a register-blocked
//! tiled matmul kernel, and the handful of elementwise ops the MLP
//! needs — no external BLAS.  The hot path is [`matmul_strided`]: an
//! `MR`×`NR` register-tile kernel that accumulates each output element
//! over `k` in ascending order, which makes it **bit-identical to the
//! naive triple loop** ([`Matrix::matmul_naive`]) — the property
//! `tests/kernel_parity.rs` pins, and what lets the prepared-plan
//! forward pass shard batch rows across threads without changing a
//! single bit of output.
//!
//! The full-width tile of the kernel is **runtime-dispatched** to an
//! explicit SIMD path ([`SimdBackend`]): AVX2 on x86_64 hosts that have
//! it, SSE2 as the x86_64 baseline, and a portable scalar fallback
//! everywhere.  Every path uses separate multiply and add only (no FMA)
//! with the same per-lane, k-ascending accumulation, so **all dispatch
//! paths produce bit-identical outputs** — SIMD changes how many output
//! elements are in flight, never a single element's summation order.
//! `ARI_SIMD=0` (or `scalar`/`sse2`/`avx2`) overrides the dispatch for
//! forced-scalar runs; see [`active_backend`].

use std::sync::OnceLock;

/// Row-register width of the tiled kernel (i-block).
pub const KERNEL_MR: usize = 4;

/// Column-register width of the tiled kernel (j-block): two 256-bit
/// vectors on the AVX2 path.  Prepared plans pad weight matrices'
/// output dimension to a multiple of this so the steady-state kernel
/// never takes the ragged-edge path.
pub const KERNEL_NR: usize = 16;

/// One instruction-set flavour of the full-tile matmul microkernel.
/// All variants exist on every architecture (so code can name them
/// portably); [`SimdBackend::is_available`] says which ones this host
/// can actually run.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SimdBackend {
    /// Portable scalar loop (autovectorisable, no `std::arch`).
    Scalar,
    /// x86_64 SSE2 (`__m128`, baseline on every x86_64).
    Sse2,
    /// x86_64 AVX2 (`__m256`, runtime-detected).
    Avx2,
}

impl SimdBackend {
    /// Lower-case stable name (`scalar` / `sse2` / `avx2`) — used in the
    /// `ari-bench v1` JSON header and the `ARI_SIMD` override.
    pub fn name(self) -> &'static str {
        match self {
            SimdBackend::Scalar => "scalar",
            SimdBackend::Sse2 => "sse2",
            SimdBackend::Avx2 => "avx2",
        }
    }

    /// Whether this host can execute the path.
    pub fn is_available(self) -> bool {
        match self {
            SimdBackend::Scalar => true,
            #[cfg(target_arch = "x86_64")]
            SimdBackend::Sse2 => true,
            #[cfg(target_arch = "x86_64")]
            SimdBackend::Avx2 => std::arch::is_x86_feature_detected!("avx2"),
            #[cfg(not(target_arch = "x86_64"))]
            _ => false,
        }
    }
}

/// Every dispatch path this host can run, scalar first.  Test suites
/// iterate this to pin all paths against the naive reference.
pub fn available_backends() -> Vec<SimdBackend> {
    [SimdBackend::Scalar, SimdBackend::Sse2, SimdBackend::Avx2].into_iter().filter(|b| b.is_available()).collect()
}

fn best_available() -> SimdBackend {
    if SimdBackend::Avx2.is_available() {
        SimdBackend::Avx2
    } else if SimdBackend::Sse2.is_available() {
        SimdBackend::Sse2
    } else {
        SimdBackend::Scalar
    }
}

/// The dispatch path [`matmul_strided`] uses, decided once per process:
/// the `ARI_SIMD` environment variable (`0`/`scalar`/`off`, `sse2`,
/// `avx2`) when set and available on this host, else the best detected
/// path (AVX2 > SSE2 > scalar).  An unavailable request falls back to
/// auto-detection with a warning rather than failing — outputs are
/// bit-identical on every path, so the choice only affects speed.
pub fn active_backend() -> SimdBackend {
    static ACTIVE: OnceLock<SimdBackend> = OnceLock::new();
    *ACTIVE.get_or_init(|| {
        let requested = match std::env::var("ARI_SIMD").ok().as_deref().map(str::trim) {
            Some("0") | Some("scalar") | Some("off") => Some(SimdBackend::Scalar),
            Some("sse2") => Some(SimdBackend::Sse2),
            Some("avx2") => Some(SimdBackend::Avx2),
            Some("") | None => None,
            Some(other) => {
                eprintln!("[ari] unknown ARI_SIMD={other:?} (expected 0|scalar|sse2|avx2); auto-detecting");
                None
            }
        };
        match requested {
            Some(b) if b.is_available() => b,
            Some(b) => {
                let fallback = best_available();
                eprintln!("[ari] ARI_SIMD asked for {} but this host cannot run it; using {}", b.name(), fallback.name());
                fallback
            }
            None => best_available(),
        }
    })
}

/// Tiled matmul with explicit row strides: `out[i][j] = sum_p a[i][p] *
/// b[p][j]` for `i < m`, `j < n`, `p < k`, where row `i` of `a` lives at
/// `a[i*lda..i*lda+k]`, `b` is packed `(k, n)` row-major, and row `i` of
/// `out` lives at `out[i*ldo..i*ldo+n]`.
///
/// Each output element accumulates over `p` in ascending order (register
/// tiling and SIMD only change *which* elements are in flight, never the
/// per-element summation order, and no path contracts mul+add into FMA),
/// so results are bit-identical to [`Matrix::matmul_naive`], independent
/// of the `MR`/`NR` blocking **and** of the dispatched instruction set.
/// Dispatches to [`active_backend`]; use [`matmul_strided_with`] to pin
/// a specific path.
pub fn matmul_strided(a: &[f32], lda: usize, b: &[f32], k: usize, out: &mut [f32], ldo: usize, m: usize, n: usize) {
    matmul_strided_with(active_backend(), a, lda, b, k, out, ldo, m, n);
}

/// [`matmul_strided`] on an explicit dispatch path.  Panics if `backend`
/// is not available on this host (see [`available_backends`]).
#[allow(clippy::too_many_arguments)]
pub fn matmul_strided_with(
    backend: SimdBackend,
    a: &[f32],
    lda: usize,
    b: &[f32],
    k: usize,
    out: &mut [f32],
    ldo: usize,
    m: usize,
    n: usize,
) {
    assert!(backend.is_available(), "SIMD backend {} unavailable on this host", backend.name());
    // Hard asserts, not debug: the SIMD paths below use raw-pointer
    // loads/stores, so an undersized slice must panic here (as the old
    // slice-indexed kernel did) rather than read or write out of bounds
    // in release builds.  Three integer compares, negligible vs the
    // matmul itself.
    assert!(m == 0 || (m - 1) * lda + k <= a.len(), "a too short");
    assert!(k * n <= b.len(), "b too short");
    assert!(m == 0 || (m - 1) * ldo + n <= out.len(), "out too short");
    let mut i = 0;
    while i < m {
        let ib = KERNEL_MR.min(m - i);
        let mut j = 0;
        while j < n {
            let jb = KERNEL_NR.min(n - j);
            if jb == KERNEL_NR {
                match backend {
                    #[cfg(target_arch = "x86_64")]
                    // SAFETY: availability asserted above; the tile is in
                    // bounds (j + KERNEL_NR <= n checked here, row bounds
                    // by the debug asserts / slice invariants).
                    SimdBackend::Avx2 => unsafe { full_tile_avx2(a, lda, b, n, out, ldo, k, i, j, ib) },
                    #[cfg(target_arch = "x86_64")]
                    // SAFETY: SSE2 is baseline on x86_64; bounds as above.
                    SimdBackend::Sse2 => unsafe {
                        full_tile_sse2_half(a, lda, b, n, out, ldo, k, i, j, ib);
                        full_tile_sse2_half(a, lda, b, n, out, ldo, k, i, j + KERNEL_NR / 2, ib);
                    },
                    _ => full_tile_scalar(a, lda, b, n, out, ldo, k, i, j, ib),
                }
            } else {
                ragged_tile_scalar(a, lda, b, n, out, ldo, k, i, j, ib, jb);
            }
            j += jb;
        }
        i += ib;
    }
}

/// Full-width tile, portable scalar path: fixed trip count so the
/// compiler can unroll/autovectorise with no bounds checks.
#[allow(clippy::too_many_arguments)]
fn full_tile_scalar(
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    out: &mut [f32],
    ldo: usize,
    k: usize,
    i: usize,
    j: usize,
    ib: usize,
) {
    let mut acc = [[0.0f32; KERNEL_NR]; KERNEL_MR];
    for p in 0..k {
        let brow = &b[p * ldb + j..p * ldb + j + KERNEL_NR];
        for (mi, accr) in acc.iter_mut().enumerate().take(ib) {
            let av = a[(i + mi) * lda + p];
            for nj in 0..KERNEL_NR {
                accr[nj] += av * brow[nj];
            }
        }
    }
    for (mi, accr) in acc.iter().enumerate().take(ib) {
        out[(i + mi) * ldo + j..(i + mi) * ldo + j + KERNEL_NR].copy_from_slice(accr);
    }
}

/// Ragged-edge tile (`jb < KERNEL_NR`), scalar on every dispatch path —
/// prepared plans pad their layouts so serving never comes here.
#[allow(clippy::too_many_arguments)]
fn ragged_tile_scalar(
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    out: &mut [f32],
    ldo: usize,
    k: usize,
    i: usize,
    j: usize,
    ib: usize,
    jb: usize,
) {
    let mut acc = [[0.0f32; KERNEL_NR]; KERNEL_MR];
    for p in 0..k {
        let brow = &b[p * ldb + j..p * ldb + j + jb];
        for (mi, accr) in acc.iter_mut().enumerate().take(ib) {
            let av = a[(i + mi) * lda + p];
            for (nj, &bv) in brow.iter().enumerate() {
                accr[nj] += av * bv;
            }
        }
    }
    for (mi, accr) in acc.iter().enumerate().take(ib) {
        out[(i + mi) * ldo + j..(i + mi) * ldo + j + jb].copy_from_slice(&accr[..jb]);
    }
}

/// Full-width tile on AVX2: `ib` rows × two `__m256` column registers.
/// Separate `_mm256_mul_ps` + `_mm256_add_ps` per lane, `p` ascending —
/// rustc never contracts these into FMA, so lanes compute exactly the
/// scalar `acc += a * b` sequence and outputs stay bit-identical.
///
/// # Safety
///
/// Caller must ensure AVX2 is available, `j + KERNEL_NR <= ldb` with
/// `b.len() >= k * ldb`, `(i + ib - 1) * lda + k <= a.len()`, and
/// `(i + ib - 1) * ldo + j + KERNEL_NR <= out.len()`.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
unsafe fn full_tile_avx2(
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    out: &mut [f32],
    ldo: usize,
    k: usize,
    i: usize,
    j: usize,
    ib: usize,
) {
    use std::arch::x86_64::*;
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    let op = out.as_mut_ptr();
    let mut acc = [[_mm256_setzero_ps(); 2]; KERNEL_MR];
    for p in 0..k {
        let base = bp.add(p * ldb + j);
        let b0 = _mm256_loadu_ps(base);
        let b1 = _mm256_loadu_ps(base.add(8));
        for (mi, accr) in acc.iter_mut().enumerate().take(ib) {
            let av = _mm256_set1_ps(*ap.add((i + mi) * lda + p));
            accr[0] = _mm256_add_ps(accr[0], _mm256_mul_ps(av, b0));
            accr[1] = _mm256_add_ps(accr[1], _mm256_mul_ps(av, b1));
        }
    }
    for (mi, accr) in acc.iter().enumerate().take(ib) {
        let dst = op.add((i + mi) * ldo + j);
        _mm256_storeu_ps(dst, accr[0]);
        _mm256_storeu_ps(dst.add(8), accr[1]);
    }
}

/// Half of a full-width tile on SSE2: `ib` rows × two `__m128` column
/// registers covering columns `j..j + 8`.  Called twice per full tile so
/// the accumulators fit the 16 xmm registers without spilling; columns
/// are independent, so the split cannot change any output bit.  Mul+add
/// only, `p` ascending — bit-identical to the scalar path.
///
/// # Safety
///
/// Caller must ensure `j + 8 <= ldb` with `b.len() >= k * ldb`,
/// `(i + ib - 1) * lda + k <= a.len()`, and `(i + ib - 1) * ldo + j + 8
/// <= out.len()`.  SSE2 itself is baseline on x86_64.
#[cfg(target_arch = "x86_64")]
#[allow(clippy::too_many_arguments)]
unsafe fn full_tile_sse2_half(
    a: &[f32],
    lda: usize,
    b: &[f32],
    ldb: usize,
    out: &mut [f32],
    ldo: usize,
    k: usize,
    i: usize,
    j: usize,
    ib: usize,
) {
    use std::arch::x86_64::*;
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    let op = out.as_mut_ptr();
    let mut acc = [[_mm_setzero_ps(); 2]; KERNEL_MR];
    for p in 0..k {
        let base = bp.add(p * ldb + j);
        let b0 = _mm_loadu_ps(base);
        let b1 = _mm_loadu_ps(base.add(4));
        for (mi, accr) in acc.iter_mut().enumerate().take(ib) {
            let av = _mm_set1_ps(*ap.add((i + mi) * lda + p));
            accr[0] = _mm_add_ps(accr[0], _mm_mul_ps(av, b0));
            accr[1] = _mm_add_ps(accr[1], _mm_mul_ps(av, b1));
        }
    }
    for (mi, accr) in acc.iter().enumerate().take(ib) {
        let dst = op.add((i + mi) * ldo + j);
        _mm_storeu_ps(dst, accr[0]);
        _mm_storeu_ps(dst.add(4), accr[1]);
    }
}

/// Row-major 2-D f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    /// Row count.
    pub rows: usize,
    /// Column count.
    pub cols: usize,
    /// Row-major storage, `rows * cols` long.
    pub data: Vec<f32>,
}

impl Matrix {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Wrap row-major data (must be exactly `rows * cols` long).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Self { rows, cols, data }
    }

    /// Build element-wise from `f(row, col)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Self { rows, cols, data }
    }

    /// One element.
    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    /// Overwrite one element.
    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    /// One row as a slice.
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// One row as a mutable slice.
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// `self (m,k) @ other (k,n)` via the tiled [`matmul_strided`]
    /// kernel (dense, branch-free, register-blocked).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(m, n);
        matmul_strided(&self.data, k, &other.data, k, &mut out.data, n, m, n);
        out
    }

    /// Reference triple-loop matmul (`for i { for j { for p } }`).  Slow;
    /// exists as the golden the tiled kernel is pinned against in
    /// `tests/kernel_parity.rs`.
    pub fn matmul_naive(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Matrix::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for p in 0..k {
                    acc += self.data[i * k + p] * other.data[p * n + j];
                }
                out.data[i * n + j] = acc;
            }
        }
        out
    }

    /// Add a row vector to every row.
    pub fn add_row(&mut self, bias: &[f32]) {
        assert_eq!(bias.len(), self.cols);
        for r in 0..self.rows {
            for (v, b) in self.row_mut(r).iter_mut().zip(bias) {
                *v += b;
            }
        }
    }

    /// In-place elementwise map.
    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for v in &mut self.data {
            *v = f(*v);
        }
    }

    /// PReLU with slope `alpha`.
    pub fn prelu(&mut self, alpha: f32) {
        self.map_inplace(|v| if v >= 0.0 { v } else { alpha * v });
    }

    /// Row-wise L2 normalisation (the score mapping of the ARI models).
    pub fn l2_normalize_rows(&mut self) {
        for r in 0..self.rows {
            let row = self.row_mut(r);
            let norm = (row.iter().map(|v| v * v).sum::<f32>() + 1e-12).sqrt();
            for v in row.iter_mut() {
                *v /= norm;
            }
        }
    }

    /// Row-wise softmax (numerically stable).
    pub fn softmax_rows(&mut self) {
        for r in 0..self.rows {
            let row = self.row_mut(r);
            let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0f32;
            for v in row.iter_mut() {
                *v = (*v - max).exp();
                sum += *v;
            }
            for v in row.iter_mut() {
                *v /= sum;
            }
        }
    }
}

/// (pred, margin) of one score row: argmax class and top1 - top2 gap.
pub fn top2_margin(scores: &[f32]) -> (usize, f32) {
    assert!(scores.len() >= 2);
    let (mut i1, mut s1, mut s2) = (0usize, f32::NEG_INFINITY, f32::NEG_INFINITY);
    for (i, &s) in scores.iter().enumerate() {
        if s > s1 {
            s2 = s1;
            s1 = s;
            i1 = i;
        } else if s > s2 {
            s2 = s;
        }
    }
    (i1, s1 - s2)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let eye = Matrix::from_fn(2, 2, |r, c| if r == c { 1.0 } else { 0.0 });
        assert_eq!(a.matmul(&eye), a);
    }

    #[test]
    fn matmul_known_values() {
        let a = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Matrix::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    #[should_panic(expected = "matmul shape mismatch")]
    fn matmul_rejects_bad_shapes() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 3);
        let _ = a.matmul(&b);
    }

    #[test]
    fn bias_and_prelu() {
        let mut m = Matrix::from_vec(1, 3, vec![-2.0, 0.0, 2.0]);
        m.add_row(&[1.0, 1.0, 1.0]);
        m.prelu(0.25);
        assert_eq!(m.data, vec![-0.25, 1.0, 3.0]);
    }

    #[test]
    fn softmax_rows_normalised() {
        let mut m = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, -1.0, 0.0, 1.0]);
        m.softmax_rows();
        for r in 0..2 {
            let s: f32 = m.row(r).iter().sum();
            assert!((s - 1.0).abs() < 1e-6);
        }
        assert!(m.get(0, 2) > m.get(0, 1));
    }

    #[test]
    fn softmax_handles_large_logits() {
        let mut m = Matrix::from_vec(1, 2, vec![1000.0, 999.0]);
        m.softmax_rows();
        assert!(m.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn top2_margin_basic() {
        let (pred, margin) = top2_margin(&[0.1, 0.6, 0.3]);
        assert_eq!(pred, 1);
        assert!((margin - 0.3).abs() < 1e-6);
    }

    #[test]
    fn top2_margin_ties() {
        let (pred, margin) = top2_margin(&[0.5, 0.5]);
        assert_eq!(pred, 0);
        assert_eq!(margin, 0.0);
    }

    #[test]
    fn tiled_kernel_bit_identical_to_naive() {
        // Shapes straddling the MR/NR tile edges, including ragged ones,
        // on every dispatch path this host can run.
        let mut rng = crate::util::Pcg64::seeded(21);
        for (m, k, n) in [(1, 1, 1), (3, 5, 7), (4, 8, 8), (5, 9, 17), (5, 9, 16), (32, 24, 32), (2, 100, 3)] {
            let a = Matrix::from_fn(m, k, |_, _| rng.next_f32() - 0.5);
            let b = Matrix::from_fn(k, n, |_, _| rng.next_f32() - 0.5);
            let naive = a.matmul_naive(&b);
            let tiled = a.matmul(&b);
            assert_eq!(tiled.data, naive.data, "active m={m} k={k} n={n}");
            for backend in available_backends() {
                let mut out = Matrix::zeros(m, n);
                matmul_strided_with(backend, &a.data, k, &b.data, k, &mut out.data, n, m, n);
                assert_eq!(out.data, naive.data, "{} m={m} k={k} n={n}", backend.name());
            }
        }
    }

    #[test]
    fn dispatch_reports_a_runnable_backend() {
        let active = active_backend();
        assert!(active.is_available());
        assert!(available_backends().contains(&active));
        assert!(available_backends().contains(&SimdBackend::Scalar));
        assert_eq!(SimdBackend::Scalar.name(), "scalar");
        assert_eq!(SimdBackend::Sse2.name(), "sse2");
        assert_eq!(SimdBackend::Avx2.name(), "avx2");
    }

    #[test]
    #[should_panic(expected = "unavailable")]
    #[cfg(not(target_arch = "x86_64"))]
    fn unavailable_backend_rejected() {
        let a = [1.0f32];
        let mut out = [0.0f32];
        matmul_strided_with(SimdBackend::Avx2, &a, 1, &a, 1, &mut out, 1, 1, 1);
    }

    #[test]
    fn strided_kernel_respects_strides() {
        // Rows of a and out embedded in wider buffers; the gap bytes
        // must never be read or written — on every dispatch path.  n is
        // a full KERNEL_NR multiple plus a ragged tail so SIMD stores
        // and the scalar edge both run.
        let (m, k, n, lda, ldo) = (3usize, 4usize, KERNEL_NR + 5, KERNEL_NR + 7, KERNEL_NR + 9);
        let mut rng = crate::util::Pcg64::seeded(22);
        let mut a = vec![f32::NAN; (m - 1) * lda + k];
        for i in 0..m {
            for p in 0..k {
                a[i * lda + p] = rng.next_f32() - 0.5;
            }
        }
        let b = Matrix::from_fn(k, n, |_, _| rng.next_f32() - 0.5);
        let at = Matrix::from_fn(m, k, |i, p| a[i * lda + p]);
        let want = at.matmul_naive(&b);
        let sentinel = -123.0f32;
        for backend in available_backends() {
            let mut out = vec![sentinel; (m - 1) * ldo + n];
            matmul_strided_with(backend, &a, lda, &b.data, k, &mut out, ldo, m, n);
            for i in 0..m {
                for j in 0..n {
                    assert_eq!(out[i * ldo + j], want.get(i, j), "{} ({i},{j})", backend.name());
                }
                // Stride gap untouched.
                if i + 1 < m {
                    for g in n..ldo {
                        assert_eq!(out[i * ldo + g], sentinel, "{} gap", backend.name());
                    }
                }
            }
        }
    }

    #[test]
    fn matmul_matches_naive_random() {
        let mut rng = crate::util::Pcg64::seeded(77);
        for _ in 0..10 {
            let (m, k, n) = (1 + rng.below(8) as usize, 1 + rng.below(8) as usize, 1 + rng.below(8) as usize);
            let a = Matrix::from_fn(m, k, |_, _| rng.next_f32() - 0.5);
            let b = Matrix::from_fn(k, n, |_, _| rng.next_f32() - 0.5);
            let c = a.matmul(&b);
            for i in 0..m {
                for j in 0..n {
                    let naive: f32 = (0..k).map(|p| a.get(i, p) * b.get(p, j)).sum();
                    assert!((c.get(i, j) - naive).abs() < 1e-4);
                }
            }
        }
    }
}
