//! Regeneration drivers for every table and figure in the paper's
//! evaluation (§IV) — see DESIGN.md §4 for the index.
//!
//! Each driver returns a plain-text report (the "figure" as data series /
//! ASCII panels); `ari experiment <id>` prints it and `ari experiment all
//! --out <dir>` writes one file per artifact.  EXPERIMENTS.md is curated
//! from these outputs.

pub mod case_study;
pub mod figures;
pub mod sweep;
pub mod tables;

use crate::runtime::Backend;

/// All experiment ids, in paper order.
pub const ALL: &[&str] = &[
    "table1", "table2", "fig5", "fig6", "fig8", "fig10", "fig11", "fig12", "fig13", "fig14", "fig15",
    "table3", "table4", "ladder",
];

/// Run one experiment by id.
pub fn run_experiment(engine: &mut dyn Backend, id: &str) -> crate::Result<String> {
    match id {
        "table1" => tables::table1(),
        "table2" => tables::table2(),
        "fig5" => figures::fig5(engine),
        "fig6" => figures::fig6(engine),
        "fig8" => figures::fig8(engine),
        "fig10" => figures::fig10(engine),
        "fig11" => figures::fig11(engine),
        "fig12" => figures::fig12(engine),
        "fig13" => figures::fig13(engine),
        "fig14" => figures::fig14(engine),
        "fig15" => figures::fig15(engine),
        "table3" => case_study::table3(engine),
        "table4" => case_study::table4(engine),
        "ladder" => sweep::ladder_report(engine),
        other => anyhow::bail!("unknown experiment {other:?} (known: {ALL:?})"),
    }
}
