//! Tables III & IV — the case study (§IV-E): energy savings with ZERO
//! accuracy loss on the dataset (threshold = Mmax).
//!
//! The paper fixes FP10 for all datasets (Table III) and picks the
//! best sequence length per dataset (Table IV: 1024/1024/512).  We report
//! the paper's chosen operating points AND the argmax over our sweep, so
//! drift in where the optimum falls is visible rather than hidden.

use crate::config::ThresholdPolicy;
use crate::data::VariantKind;
use crate::energy::EnergyModel;
use crate::margin::Calibration;
use crate::quant::FpFormat;
use crate::runtime::Backend;
use crate::sc::ScConfig;

use super::sweep::Sweep;

struct Row {
    level: usize,
    savings: f64,
}

fn savings_at_mmax(
    engine: &mut dyn Backend,
    sweep: &mut Sweep,
    ds: &str,
    kind: VariantKind,
    level: usize,
) -> crate::Result<f64> {
    let full = Sweep::full_level(kind);
    let cal = sweep.calibration(engine, ds, kind, full, level)?;
    let t = cal.threshold(ThresholdPolicy::MMax);
    let margins = sweep.outputs(engine, ds, kind, level)?.margin.clone();
    let f = Calibration::escalation_fraction(&margins, t);
    engine.load_dataset(ds)?;
    let dims = engine.weights(ds)?.dims();
    let m = EnergyModel::for_dims(&dims);
    let (e_r, e_f) = match kind {
        VariantKind::Fp => (m.fp_energy(FpFormat::fp(level as u32)), m.fp_energy(FpFormat::fp(full as u32))),
        VariantKind::Sc => (m.sc_energy(ScConfig::new(level)), m.sc_energy(ScConfig::new(full))),
    };
    Ok(EnergyModel::ari_savings(e_r, e_f, f))
}

fn case_study(engine: &mut dyn Backend, kind: VariantKind, paper_rows: &[(&str, usize, f64)]) -> crate::Result<String> {
    let mut s = String::new();
    s.push_str("dataset        paper_point      paper_savings  ours_at_paper_point  best_point  best_savings\n");
    for &(ds, paper_level, paper_savings) in paper_rows {
        let mut sweep = Sweep::new();
        let at_paper = savings_at_mmax(engine, &mut sweep, ds, kind, paper_level)?;
        let mut best = Row { level: paper_level, savings: at_paper };
        for level in Sweep::reduced_levels(engine, ds, kind) {
            let sav = savings_at_mmax(engine, &mut sweep, ds, kind, level)?;
            if sav > best.savings {
                best = Row { level, savings: sav };
            }
        }
        let unit = match kind {
            VariantKind::Fp => format!("FP{paper_level}"),
            VariantKind::Sc => format!("L={paper_level}"),
        };
        let best_unit = match kind {
            VariantKind::Fp => format!("FP{}", best.level),
            VariantKind::Sc => format!("L={}", best.level),
        };
        s.push_str(&format!(
            "{ds:<14} {unit:<16} {:<14.2} {:<20.2} {best_unit:<11} {:.2}\n",
            100.0 * paper_savings,
            100.0 * at_paper,
            100.0 * best.savings
        ));
    }
    s.push_str("\nthreshold = Mmax everywhere: zero accuracy loss on the dataset by construction\n");
    Ok(s)
}

/// Table III — floating point, no accuracy loss.
pub fn table3(engine: &mut dyn Backend) -> crate::Result<String> {
    let mut s = String::from("TABLE III — FP energy savings with no dataset accuracy loss (T = Mmax)\n");
    s.push_str(&case_study(
        engine,
        VariantKind::Fp,
        &[("svhn_syn", 10, 0.4118), ("cifar10_syn", 10, 0.3927), ("fashion_syn", 10, 0.4172)],
    )?);
    Ok(s)
}

/// Table IV — stochastic computing, no accuracy loss.
pub fn table4(engine: &mut dyn Backend) -> crate::Result<String> {
    let mut s = String::from("TABLE IV — SC energy savings with no dataset accuracy loss (T = Mmax)\n");
    s.push_str(&case_study(
        engine,
        VariantKind::Sc,
        &[("svhn_syn", 1024, 0.5576), ("cifar10_syn", 1024, 0.4770), ("fashion_syn", 512, 0.7913)],
    )?);
    Ok(s)
}
