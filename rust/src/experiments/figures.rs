//! Figure drivers (Figs. 5, 6, 8, 10–15).  Each returns the figure's
//! data series as text (plus ASCII histogram panels where the paper
//! shows densities).

use crate::config::ThresholdPolicy;
use crate::data::VariantKind;
use crate::energy::EnergyModel;
use crate::margin::Calibration;
use crate::quant::FpFormat;
use crate::runtime::Backend;
use crate::sc::ScConfig;
use crate::util::Histogram;

use super::sweep::{level_label, Sweep};

const POLICIES: [ThresholdPolicy; 3] = [ThresholdPolicy::MMax, ThresholdPolicy::M99, ThresholdPolicy::M95];

fn dataset_names(engine: &dyn Backend) -> Vec<String> {
    engine.manifest().dataset_names().iter().map(|s| s.to_string()).collect()
}

fn energy_for(engine: &mut dyn Backend, ds: &str, kind: VariantKind, level: usize) -> crate::Result<f64> {
    engine.load_dataset(ds)?;
    let dims = engine.weights(ds)?.dims();
    let m = EnergyModel::for_dims(&dims);
    Ok(match kind {
        VariantKind::Fp => m.fp_energy(FpFormat::fp(level as u32)),
        VariantKind::Sc => m.sc_energy(ScConfig::new(level)),
    })
}

/// Fig. 5 — accuracy (top) and relative energy per inference (bottom) of
/// the SC MLP vs sequence length, SVHN.
pub fn fig5(engine: &mut dyn Backend) -> crate::Result<String> {
    let ds = "svhn_syn";
    let mut sweep = Sweep::new();
    let mut s = String::from("FIG 5 — SC accuracy & relative energy vs sequence length (SVHN-like)\n");
    s.push_str("seq_len  accuracy  rel_energy_vs_L128\n");
    let levels = engine.manifest().levels(ds, VariantKind::Sc);
    let e128 = energy_for(engine, ds, VariantKind::Sc, 128)?;
    for &l in levels.iter().rev() {
        let y = sweep.eval(engine, ds)?.y.clone();
        let out = sweep.outputs(engine, ds, VariantKind::Sc, l)?;
        let acc = out.accuracy(&y);
        let rel = energy_for(engine, ds, VariantKind::Sc, l)? / e128 * 100.0;
        s.push_str(&format!("{l:<8} {acc:<9.4} {rel:.0}%\n"));
    }
    s.push_str("\npaper shape: accuracy gains flatten with L while energy grows linearly\n");
    Ok(s)
}

/// Fig. 6 — classification scores of one element at L=4096 vs L=512.
pub fn fig6(engine: &mut dyn Backend) -> crate::Result<String> {
    let ds = "svhn_syn";
    let mut sweep = Sweep::new();
    let full = sweep.outputs(engine, ds, VariantKind::Sc, 4096)?.clone();
    let red = sweep.outputs(engine, ds, VariantKind::Sc, 512)?.clone();
    // The paper's example: an element with a large full-model margin whose
    // class is preserved (though the margin shrinks) at L=512.
    let mut pick = 0;
    let mut best = f32::NEG_INFINITY;
    for i in 0..full.pred.len() {
        // the paper's example: large full-model margin, class preserved,
        // margin shrunk at L=512
        if full.pred[i] == red.pred[i] && red.margin[i] < full.margin[i] && full.margin[i] > best {
            best = full.margin[i];
            pick = i;
        }
    }
    let mut s = format!("FIG 6 — scores of element #{pick} (SVHN-like, stochastic computing)\n");
    s.push_str(&format!(
        "L=4096: pred={} margin={:.4}\nL=512 : pred={} margin={:.4}\n\nclass  score@4096  score@512\n",
        full.pred[pick], full.margin[pick], red.pred[pick], red.margin[pick]
    ));
    for c in 0..full.n_classes {
        let a = full.score_row(pick)[c];
        let b = red.score_row(pick)[c];
        let bar_a = "#".repeat((a * 40.0) as usize);
        let bar_b = "+".repeat((b * 40.0) as usize);
        s.push_str(&format!("{c:<6} {a:<11.4} {b:<10.4} |{bar_a}\n                               |{bar_b}\n"));
    }
    s.push_str("\npaper shape: classification (and sign of the margin) unchanged; margin shrinks\n");
    Ok(s)
}

fn margin_panel(cal: &Calibration, title: &str) -> String {
    let mut s = format!("{title}: changed={} / {} ({:.2}%)\n", cal.changed_margins.len(), cal.n, 100.0 * cal.change_rate());
    if cal.changed_margins.is_empty() {
        s.push_str("  (no elements change class at this resolution)\n");
        return s;
    }
    let mmax = cal.threshold(ThresholdPolicy::MMax);
    let m99 = cal.threshold(ThresholdPolicy::M99);
    let m95 = cal.threshold(ThresholdPolicy::M95);
    s.push_str(&format!("  Mmax={mmax:.4}  M99={m99:.4}  M95={m95:.4}\n"));
    let hi = (mmax * 1.05).max(1e-3);
    let mut h = Histogram::new(0.0, hi, 12);
    h.record_all(&cal.changed_margins);
    for (center, d) in h.densities() {
        let bar = "#".repeat((d * hi * 30.0).min(60.0) as usize);
        s.push_str(&format!("  {center:7.4} {bar}\n"));
    }
    s
}

/// Fig. 8 — distribution of reduced-model margins over elements that
/// change class (the paper's SVHN SC L=512 example), with thresholds.
pub fn fig8(engine: &mut dyn Backend) -> crate::Result<String> {
    let mut sweep = Sweep::new();
    let cal = sweep.calibration(engine, "svhn_syn", VariantKind::Sc, 4096, 512)?;
    let mut s = String::from("FIG 8 — margin density of class-changing elements (SVHN-like, SC 4096->512)\n");
    s.push_str(&margin_panel(&cal, "SC L=512"));
    s.push_str("\npaper shape: right-skewed density; M95 < M99 << Mmax\n");
    Ok(s)
}

fn margin_grid(engine: &mut dyn Backend, kind: VariantKind, levels: &[usize], title: &str) -> crate::Result<String> {
    let mut sweep = Sweep::new();
    let full = Sweep::full_level(kind);
    let mut s = format!("{title}\n");
    for ds in dataset_names(engine) {
        s.push_str(&format!("\n== {ds} ==\n"));
        for &level in levels {
            let cal = sweep.calibration(engine, &ds, kind, full, level)?;
            s.push_str(&margin_panel(&cal, &level_label(kind, level)));
        }
    }
    Ok(s)
}

/// Fig. 10 — margin distributions, floating point, removing 4/6/8 bits.
pub fn fig10(engine: &mut dyn Backend) -> crate::Result<String> {
    margin_grid(
        engine,
        VariantKind::Fp,
        &[12, 10, 8],
        "FIG 10 — margins of class-changing elements, FP (remove 4/6/8 mantissa bits)",
    )
}

/// Fig. 11 — margin distributions, stochastic computing, L=1024/256/64.
pub fn fig11(engine: &mut dyn Backend) -> crate::Result<String> {
    margin_grid(
        engine,
        VariantKind::Sc,
        &[1024, 256, 64],
        "FIG 11 — margins of class-changing elements, SC (L = 1024/256/64)",
    )
}

/// Threshold/F/savings/accuracy sweeps share this walk.
fn sweep_rows(
    engine: &mut dyn Backend,
    mut row: impl FnMut(&mut dyn Backend, &mut Sweep, &str, VariantKind, usize, &Calibration) -> crate::Result<String>,
) -> crate::Result<String> {
    let mut s = String::new();
    for kind in [VariantKind::Fp, VariantKind::Sc] {
        for ds in dataset_names(engine) {
            s.push_str(&format!("\n== {ds} ({kind:?}) ==\n"));
            let mut sweep = Sweep::new();
            let full = Sweep::full_level(kind);
            for level in Sweep::reduced_levels(engine, &ds, kind) {
                let cal = sweep.calibration(engine, &ds, kind, full, level)?;
                s.push_str(&row(engine, &mut sweep, &ds, kind, level, &cal)?);
            }
        }
    }
    Ok(s)
}

/// Fig. 12 — thresholds Mmax/M99/M95 vs quantisation level.
pub fn fig12(engine: &mut dyn Backend) -> crate::Result<String> {
    let mut s = String::from("FIG 12 — margin thresholds vs quantisation level\nlevel  Mmax  M99  M95\n");
    s.push_str(&sweep_rows(engine, |_, _, _, kind, level, cal| {
        Ok(format!(
            "{:<26} {:.4} {:.4} {:.4}\n",
            level_label(kind, level),
            cal.threshold(ThresholdPolicy::MMax),
            cal.threshold(ThresholdPolicy::M99),
            cal.threshold(ThresholdPolicy::M95),
        ))
    })?);
    s.push_str("\npaper shape: thresholds grow as resolution drops; percentile thresholds sit below Mmax\n");
    Ok(s)
}

/// Fig. 13 — fraction F of inferences that must run the full model.
pub fn fig13(engine: &mut dyn Backend) -> crate::Result<String> {
    let mut s = String::from("FIG 13 — escalation fraction F vs quantisation level\nlevel  F@Mmax  F@M99  F@M95\n");
    s.push_str(&sweep_rows(engine, |engine, sweep, ds, kind, level, cal| {
        let margins = sweep.outputs(engine, ds, kind, level)?.margin.clone();
        let mut cells = String::new();
        for p in POLICIES {
            let f = Calibration::escalation_fraction(&margins, cal.threshold(p));
            cells.push_str(&format!(" {f:<7.4}"));
        }
        Ok(format!("{:<26}{cells}\n", level_label(kind, level)))
    })?);
    s.push_str("\npaper shape: F below ~20% for moderate quantisation, rising steeply at aggressive levels\n");
    Ok(s)
}

/// Fig. 14 — energy savings (eq. 2) vs quantisation level.
pub fn fig14(engine: &mut dyn Backend) -> crate::Result<String> {
    let mut s = String::from("FIG 14 — ARI energy savings vs quantisation level (eq. 2)\nlevel  savings@Mmax  savings@M99  savings@M95\n");
    s.push_str(&sweep_rows(engine, |engine, sweep, ds, kind, level, cal| {
        let margins = sweep.outputs(engine, ds, kind, level)?.margin.clone();
        let e_r = energy_for(engine, ds, kind, level)?;
        let e_f = energy_for(engine, ds, kind, Sweep::full_level(kind))?;
        let mut cells = String::new();
        for p in POLICIES {
            let f = Calibration::escalation_fraction(&margins, cal.threshold(p));
            let sav = EnergyModel::ari_savings(e_r, e_f, f);
            cells.push_str(&format!(" {:<12.4}", sav));
        }
        Ok(format!("{:<26}{cells}\n", level_label(kind, level)))
    })?);
    s.push_str("\npaper shape: savings rise, peak at an intermediate resolution, then fall as F explodes\n");
    Ok(s)
}

/// Fig. 15 — accuracy drop of ARI vs the plain quantised model.
pub fn fig15(engine: &mut dyn Backend) -> crate::Result<String> {
    let mut s = String::from(
        "FIG 15 — accuracy drop (percentage points vs full model)\nlevel  ari@Mmax  ari@M99  ari@M95  plain_quantised\n",
    );
    s.push_str(&sweep_rows(engine, |engine, sweep, ds, kind, level, cal| {
        let y = sweep.eval(engine, ds)?.y.clone();
        let full = sweep.outputs(engine, ds, kind, Sweep::full_level(kind))?.clone();
        let red = sweep.outputs(engine, ds, kind, level)?.clone();
        let acc_full = full.accuracy(&y);
        let acc_plain = red.accuracy(&y);
        let mut cells = String::new();
        for p in POLICIES {
            let t = cal.threshold(p);
            // Simulated ARI: accept reduced when margin clears T, else full.
            let mut ok = 0usize;
            for i in 0..y.len() {
                let pred = if crate::margin::accepts(red.margin[i], t) { red.pred[i] } else { full.pred[i] };
                if pred == y[i] {
                    ok += 1;
                }
            }
            let acc_ari = ok as f64 / y.len() as f64;
            cells.push_str(&format!(" {:<8.4}", 100.0 * (acc_full - acc_ari)));
        }
        Ok(format!(
            "{:<26}{cells} {:<8.4}\n",
            level_label(kind, level),
            100.0 * (acc_full - acc_plain)
        ))
    })?);
    s.push_str("\npaper shape: ARI drop ~0 (exactly 0 at Mmax); plain quantisation drops sharply at low resolution\n");
    Ok(s)
}
