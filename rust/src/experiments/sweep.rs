//! Shared sweep cache: every figure in §IV needs (dataset × variant)
//! outputs over the whole eval split; this runs each combination once
//! per process and memoises the result.

use std::collections::HashMap;

use crate::data::{EvalData, VariantKind};
use crate::margin::Calibration;
use crate::runtime::{Backend, BatchOutputs};

/// Batch size used for dataset sweeps (the larger compiled batch).
pub const SWEEP_BATCH: usize = 256;

/// Memoised sweep runner.
pub struct Sweep {
    outputs: HashMap<(String, VariantKind, usize), BatchOutputs>,
    eval: HashMap<String, EvalData>,
}

impl Default for Sweep {
    fn default() -> Self {
        Self::new()
    }
}

impl Sweep {
    /// Empty cache.
    pub fn new() -> Self {
        Self { outputs: HashMap::new(), eval: HashMap::new() }
    }

    /// Eval split of a dataset (cached).
    pub fn eval<'a>(&'a mut self, engine: &dyn Backend, ds: &str) -> crate::Result<&'a EvalData> {
        if !self.eval.contains_key(ds) {
            self.eval.insert(ds.to_string(), engine.eval_data(ds)?);
        }
        Ok(&self.eval[ds])
    }

    /// Outputs of (ds, kind, level) over the whole eval split (cached).
    pub fn outputs<'a>(
        &'a mut self,
        engine: &mut dyn Backend,
        ds: &str,
        kind: VariantKind,
        level: usize,
    ) -> crate::Result<&'a BatchOutputs> {
        let key = (ds.to_string(), kind, level);
        if !self.outputs.contains_key(&key) {
            if !self.eval.contains_key(ds) {
                self.eval.insert(ds.to_string(), engine.eval_data(ds)?);
            }
            let data = &self.eval[ds];
            let v = engine.manifest().variant(ds, kind, level, SWEEP_BATCH)?.clone();
            // Seed depends on the level so different SC lengths get
            // independent streams (as independent hardware runs would).
            let out = engine.run_dataset(&v, data, level as u32)?;
            self.outputs.insert(key.clone(), out);
        }
        Ok(&self.outputs[&key])
    }

    /// Calibration of (reduced vs full) over the whole eval split — the
    /// paper's protocol (margins of changed elements over "the dataset").
    pub fn calibration(
        &mut self,
        engine: &mut dyn Backend,
        ds: &str,
        kind: VariantKind,
        full_level: usize,
        reduced_level: usize,
    ) -> crate::Result<Calibration> {
        let full = self.outputs(engine, ds, kind, full_level)?.pred.clone();
        let red = self.outputs(engine, ds, kind, reduced_level)?;
        Ok(Calibration::from_pairs(&full, &red.pred, &red.margin))
    }

    /// The full-model level of a kind (paper: FP16 / L=4096).
    pub fn full_level(kind: VariantKind) -> usize {
        match kind {
            VariantKind::Fp => 16,
            VariantKind::Sc => 4096,
        }
    }

    /// Reduced levels available in the manifest, descending, excluding
    /// the full model.
    pub fn reduced_levels(engine: &dyn Backend, ds: &str, kind: VariantKind) -> Vec<usize> {
        engine
            .manifest()
            .levels(ds, kind)
            .into_iter()
            .filter(|&l| l != Self::full_level(kind))
            .collect()
    }
}

/// Quantisation-level axis label (paper's x-axes).
pub fn level_label(kind: VariantKind, level: usize) -> String {
    match kind {
        VariantKind::Fp => format!("FP{level} ({} bits removed)", 16 - level),
        VariantKind::Sc => format!("L={level} ({}x reduction)", 4096 / level.max(1)),
    }
}
