//! Shared sweep cache: every figure in §IV needs (dataset × variant)
//! outputs over the whole eval split; this runs each combination once
//! per process and memoises the result.
//!
//! Also home of the **ladder sweep** (`ari sweep [--ladder]`): the
//! N-level generalisation turns the paper's single reduced/full
//! operating point into a family of energy/accuracy tradeoff curves —
//! every 2-level pair plus multi-level ladders assembled from the
//! manifest's level grid, each reported with per-stage escalation
//! fractions and the `E = Σ_i f_i · E_i` energy accounting.

use std::collections::HashMap;

use crate::config::{Mode, ThresholdPolicy};
use crate::coordinator::{Ladder, LadderSpec};
use crate::data::{EvalData, VariantKind};
use crate::margin::Calibration;
use crate::runtime::{Backend, BatchOutputs};

/// Batch size used for dataset sweeps (the larger compiled batch).
pub const SWEEP_BATCH: usize = 256;

/// Memoised sweep runner.
pub struct Sweep {
    outputs: HashMap<(String, VariantKind, usize), BatchOutputs>,
    eval: HashMap<String, EvalData>,
}

impl Default for Sweep {
    fn default() -> Self {
        Self::new()
    }
}

impl Sweep {
    /// Empty cache.
    pub fn new() -> Self {
        Self { outputs: HashMap::new(), eval: HashMap::new() }
    }

    /// Eval split of a dataset (cached).
    pub fn eval<'a>(&'a mut self, engine: &dyn Backend, ds: &str) -> crate::Result<&'a EvalData> {
        if !self.eval.contains_key(ds) {
            self.eval.insert(ds.to_string(), engine.eval_data(ds)?);
        }
        Ok(&self.eval[ds])
    }

    /// Outputs of (ds, kind, level) over the whole eval split (cached).
    pub fn outputs<'a>(
        &'a mut self,
        engine: &mut dyn Backend,
        ds: &str,
        kind: VariantKind,
        level: usize,
    ) -> crate::Result<&'a BatchOutputs> {
        let key = (ds.to_string(), kind, level);
        if !self.outputs.contains_key(&key) {
            if !self.eval.contains_key(ds) {
                self.eval.insert(ds.to_string(), engine.eval_data(ds)?);
            }
            let data = &self.eval[ds];
            let v = engine.manifest().variant(ds, kind, level, SWEEP_BATCH)?.clone();
            // Seed depends on the level so different SC lengths get
            // independent streams (as independent hardware runs would).
            let out = engine.run_dataset(&v, data, level as u32)?;
            self.outputs.insert(key.clone(), out);
        }
        Ok(&self.outputs[&key])
    }

    /// Calibration of (reduced vs full) over the whole eval split — the
    /// paper's protocol (margins of changed elements over "the dataset").
    pub fn calibration(
        &mut self,
        engine: &mut dyn Backend,
        ds: &str,
        kind: VariantKind,
        full_level: usize,
        reduced_level: usize,
    ) -> crate::Result<Calibration> {
        let full = self.outputs(engine, ds, kind, full_level)?.pred.clone();
        let red = self.outputs(engine, ds, kind, reduced_level)?;
        Ok(Calibration::from_pairs(&full, &red.pred, &red.margin))
    }

    /// The full-model level of a kind (paper: FP16 / L=4096).
    pub fn full_level(kind: VariantKind) -> usize {
        match kind {
            VariantKind::Fp => 16,
            VariantKind::Sc => 4096,
        }
    }

    /// Reduced levels available in the manifest, descending, excluding
    /// the full model.
    pub fn reduced_levels(engine: &dyn Backend, ds: &str, kind: VariantKind) -> Vec<usize> {
        engine
            .manifest()
            .levels(ds, kind)
            .into_iter()
            .filter(|&l| l != Self::full_level(kind))
            .collect()
    }
}

/// Candidate ladders over a dataset's manifest levels: every 2-level
/// `[reduced, full]` pair, plus — when `multi` — a 3-level
/// low→mid→full ladder and the whole level chain.
pub fn candidate_ladders(engine: &dyn Backend, ds: &str, kind: VariantKind, multi: bool) -> Vec<Vec<usize>> {
    let full = Sweep::full_level(kind);
    let mut reduced = Sweep::reduced_levels(engine, ds, kind);
    reduced.sort_unstable(); // ascending
    let mut out: Vec<Vec<usize>> = reduced.iter().map(|&r| vec![r, full]).collect();
    if multi && reduced.len() >= 2 {
        let lo = reduced[0];
        let mid = reduced[reduced.len() / 2];
        if mid != lo {
            out.push(vec![lo, mid, full]);
        }
        let mut chain = reduced.clone();
        chain.push(full);
        if chain.len() > 3 {
            out.push(chain);
        }
    }
    out
}

/// Run every candidate ladder end to end (calibrate on the calibration
/// split, infer the whole eval split) and tabulate per-stage fractions,
/// energy per inference, realised savings vs always-full, and accuracy.
#[allow(clippy::too_many_arguments)]
pub fn ladder_table(
    engine: &mut dyn Backend,
    ds: &str,
    mode: Mode,
    ladders: &[Vec<usize>],
    threshold: ThresholdPolicy,
    calib_fraction: f64,
    batch: usize,
    seed: u32,
) -> crate::Result<String> {
    let data = engine.eval_data(ds)?;
    let n_calib = (((data.n as f64) * calib_fraction) as usize).clamp(1, data.n);
    let mut s = format!(
        "ladder sweep: {ds} {mode:?} threshold={threshold} calib_rows={n_calib} eval_rows={}\n",
        data.n
    );
    s.push_str("levels | stage fractions f_i | E/inf µJ | savings | accuracy\n");
    for levels in ladders {
        let spec = LadderSpec { dataset: ds.to_string(), mode, levels: levels.clone(), batch, threshold, seed };
        let ladder = Ladder::calibrate(engine, spec, &data, n_calib)?;
        let (out, _) = ladder.infer_dataset(engine, &data)?;
        let acc = out.pred.iter().zip(&data.y).filter(|(a, b)| a == b).count() as f64 / data.n.max(1) as f64;
        let fracs =
            out.stage_fractions().iter().map(|f| format!("{f:.3}")).collect::<Vec<_>>().join("/");
        let e_per = out.energy_uj / data.n.max(1) as f64;
        s.push_str(&format!(
            "{levels:?} | {fracs} | {e_per:.5} | {:.3} | {acc:.4}\n",
            ladder.realised_savings(&out)
        ));
    }
    Ok(s)
}

/// The `ari sweep --drift` table: one calibrated ladder evaluated on
/// progressively drifted copies of the eval split (the fixture suite's
/// [`DriftSpec`](crate::runtime::fixture::DriftSpec) transform, scaled
/// by an intensity factor).  Thresholds are calibrated once on the
/// undrifted stream and held static, so the table shows exactly the
/// failure mode the control loop's drift monitor exists for: early-stage
/// margins collapse, acceptance decisions go stale, and ladder accuracy
/// falls away from the full model's on the same drifted rows.
#[allow(clippy::too_many_arguments)]
pub fn drift_table(
    engine: &mut dyn Backend,
    ds: &str,
    mode: Mode,
    levels: &[usize],
    threshold: ThresholdPolicy,
    calib_fraction: f64,
    batch: usize,
    seed: u32,
) -> crate::Result<String> {
    use crate::runtime::fixture::{drift_eval, DriftSpec};
    let data = engine.eval_data(ds)?;
    let n_calib = (((data.n as f64) * calib_fraction) as usize).clamp(1, data.n);
    let spec = LadderSpec { dataset: ds.to_string(), mode, levels: levels.to_vec(), batch, threshold, seed };
    let ladder = Ladder::calibrate(engine, spec, &data, n_calib)?;
    let kind = mode.kind();
    let full_level = *levels.last().unwrap();
    let full_v = engine.manifest().variant(ds, kind, full_level, batch)?.clone();
    let mut s = format!(
        "drift sweep: {ds} {mode:?} levels={levels:?} threshold={threshold} calib_rows={n_calib} eval_rows={}\n",
        data.n
    );
    s.push_str("(thresholds calibrated on the undrifted stream and held static; `[control] drift = true` recalibrates online)\n");
    s.push_str("drift | stage fractions f_i | E/inf µJ | ladder acc | full acc\n");
    let base = DriftSpec::default();
    for intensity in [0.0f32, 0.25, 0.5, 1.0, 1.5, 2.0] {
        let drift = DriftSpec {
            scale: 1.0 + intensity * (base.scale - 1.0),
            shift: intensity * base.shift,
            noise: intensity * base.noise,
            seed: base.seed,
        };
        let mut drifted = data.clone();
        drift_eval(&mut drifted, &drift);
        let (out, _) = ladder.infer_dataset(engine, &drifted)?;
        let n = drifted.n.max(1) as f64;
        let acc = out.pred.iter().zip(&drifted.y).filter(|(a, b)| a == b).count() as f64 / n;
        let full_out = engine.run_dataset(&full_v, &drifted, seed)?;
        let full_acc = full_out.pred.iter().zip(&drifted.y).filter(|(a, b)| a == b).count() as f64 / n;
        let fracs =
            out.stage_fractions().iter().map(|f| format!("{f:.3}")).collect::<Vec<_>>().join("/");
        s.push_str(&format!(
            "{intensity:4.2}x | {fracs} | {:.5} | {acc:.4} | {full_acc:.4}\n",
            out.energy_uj / n
        ));
    }
    Ok(s)
}

/// The `ladder` experiment: FP candidate ladders (pairs + multi-level)
/// on the first manifest dataset at the sweep batch size.
pub fn ladder_report(engine: &mut dyn Backend) -> crate::Result<String> {
    let ds = engine.manifest().datasets[0].name.clone();
    let ladders = candidate_ladders(engine, &ds, VariantKind::Fp, true);
    ladder_table(engine, &ds, Mode::Fp, &ladders, ThresholdPolicy::MMax, 0.5, SWEEP_BATCH, 0xA41)
}

/// Quantisation-level axis label (paper's x-axes).
pub fn level_label(kind: VariantKind, level: usize) -> String {
    match kind {
        VariantKind::Fp => format!("FP{level} ({} bits removed)", 16 - level),
        VariantKind::Sc => format!("L={level} ({}x reduction)", 4096 / level.max(1)),
    }
}
