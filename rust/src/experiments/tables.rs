//! Tables I & II: the hardware cost model, regenerated from the
//! calibrated [`crate::energy::EnergyModel`] next to the paper's
//! published synthesis numbers.

use crate::energy::{self, EnergyModel};
use crate::quant::FpFormat;
use crate::sc::ScConfig;

/// Table I — area/energy of the FP MLP vs precision (Fashion-MNIST
/// topology).  Area is reported via the paper's own column (the model
/// reproduces energy; area follows the same linear law and is shown from
/// the paper's calibration points).
pub fn table1() -> crate::Result<String> {
    let model = EnergyModel::for_input_dim(784);
    let paper_area: [(u32, f64); 5] = [(16, 0.41), (14, 0.34), (12, 0.28), (10, 0.21), (8, 0.14)];
    let mut s = String::new();
    s.push_str("TABLE I — floating-point MLP, Fashion-MNIST topology (784-1024-512-256-256-10)\n");
    s.push_str("precision  paper_area_mm2  paper_energy_uJ  model_energy_uJ  rel_err\n");
    for (bits, uj) in energy::TABLE_I {
        let got = model.fp_energy(FpFormat::fp(bits));
        let area = paper_area.iter().find(|(b, _)| *b == bits).unwrap().1;
        s.push_str(&format!(
            "FP{bits:<8} {area:<15.2} {uj:<16.2} {got:<16.3} {:.2}%\n",
            100.0 * (got - uj).abs() / uj
        ));
    }
    s.push_str("\nmodel: E(bits) = (-0.198 + 0.0555*bits) * macs/macs_ref  [least-squares over the paper's Table I]\n");
    Ok(s)
}

/// Table II — latency/energy of the SC MLP vs sequence length
/// (784-100-200-10 topology).
pub fn table2() -> crate::Result<String> {
    let model = EnergyModel { macs: energy::table_ii_reference_macs() };
    let mut s = String::new();
    s.push_str("TABLE II — stochastic-computing MLP (784-100-200-10)\n");
    s.push_str("seq_len  paper_latency_us  model_latency_us  paper_energy_uJ  model_energy_uJ  rel_err\n");
    for ((l, uj), (_, us)) in energy::TABLE_II.iter().zip(energy::TABLE_II_LATENCY.iter()) {
        let cfg = ScConfig::new(*l);
        let got = model.sc_energy(cfg);
        let got_us = model.sc_latency_us(cfg);
        s.push_str(&format!(
            "{l:<8} {us:<17.2} {got_us:<17.3} {uj:<16.2} {got:<16.3} {:.2}%\n",
            100.0 * (got - uj).abs() / uj
        ));
    }
    s.push_str("\nmodel: E(L) = (2.15/4096)*L * macs/macs_ref;  latency(L) = (4.10/4096)*L\n");
    Ok(s)
}

#[cfg(test)]
mod tests {
    #[test]
    fn tables_render() {
        let t1 = super::table1().unwrap();
        assert!(t1.contains("FP16") && t1.contains("0.70"));
        let t2 = super::table2().unwrap();
        assert!(t2.contains("4096") && t2.contains("2.15"));
    }
}
