//! PJRT runtime (the `pjrt` cargo feature): load AOT-lowered HLO text,
//! compile once, execute from the serving hot path.
//!
//! Wraps the `xla` crate (PJRT C API, CPU client).  Weights are uploaded
//! to device buffers **once per dataset** at startup; each inference call
//! only uploads the activation batch (and, for SC variants, the 8-byte
//! threefry key).  Executables are compiled lazily and cached by variant
//! key.
//!
//! `PjRtClient` is `Rc`-based (not `Send`), so an [`Engine`] must stay on
//! the thread that created it — the server keeps all PJRT work on the
//! coordinator thread and feeds it through channels (see
//! [`crate::server`]).
//!
//! The default (offline) build links the compile-only stub in
//! `rust/vendor/xla`; see that crate's docs for swapping in the real
//! PJRT bindings.

use std::collections::HashMap;
use std::path::Path;
use std::time::Instant;

use crate::data::{EvalData, Manifest, VariantKind, VariantRef, Weights};
use crate::runtime::{Backend, BatchOutputs, EngineStats, EngineStatsAccum};

struct DatasetState {
    weights: Weights,
    /// Device-resident raw (f32) weight buffers, exporter order — used by
    /// SC variants (which never quantise weights).
    bufs: Vec<xla::PjRtBuffer>,
    /// Per-FP-level pre-quantised weight buffers.  The L1 kernel contract
    /// is that FP weights arrive already quantised (quantisation is
    /// idempotent and batch-independent, so it is hoisted off the
    /// per-call hot path — §Perf in EXPERIMENTS.md).
    fp_bufs: HashMap<u32, Vec<xla::PjRtBuffer>>,
    input_dim: usize,
}

/// The PJRT engine: one per process/thread.
pub struct Engine {
    client: xla::PjRtClient,
    /// The artifact manifest this engine serves.
    pub manifest: Manifest,
    datasets: HashMap<String, DatasetState>,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Compile/execute statistics (perf accounting, exact ns).
    pub stats: EngineStatsAccum,
}

impl Engine {
    /// Create a CPU PJRT client and parse the artifact manifest.
    /// Weights/eval data load lazily per dataset.
    pub fn new(artifacts: &Path) -> crate::Result<Self> {
        let manifest = Manifest::load(artifacts)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PJRT CPU client: {e}"))?;
        Ok(Self { client, manifest, datasets: HashMap::new(), executables: HashMap::new(), stats: EngineStatsAccum::default() })
    }

    /// Ensure a dataset's weights are loaded and device-resident.
    pub fn load_dataset(&mut self, name: &str) -> crate::Result<()> {
        if self.datasets.contains_key(name) {
            return Ok(());
        }
        let entry = self.manifest.dataset(name)?.clone();
        let dir = self.manifest.dataset_dir(name);
        let weights = Weights::load(&dir)?;
        anyhow::ensure!(
            weights.layers[0].in_dim == entry.input_dim,
            "weights/manifest input_dim mismatch for {name}"
        );
        let mut bufs = Vec::new();
        for (_, dims, data) in weights.flat() {
            let buf = self
                .client
                .buffer_from_host_buffer::<f32>(data, &dims, None)
                .map_err(|e| anyhow::anyhow!("uploading weights for {name}: {e}"))?;
            self.stats.h2d_bytes += (data.len() * 4) as u64;
            bufs.push(buf);
        }
        self.datasets.insert(
            name.to_string(),
            DatasetState { weights, bufs, fp_bufs: HashMap::new(), input_dim: entry.input_dim },
        );
        Ok(())
    }

    /// Ensure pre-quantised weight buffers exist for an FP level.
    /// Quantises w tensors host-side (bit-identical to the L1 kernel's
    /// `quantize_fp`); b/alpha stay raw (the kernel quantises the bias in
    /// its epilogue).
    fn ensure_fp_weights(&mut self, name: &str, level: u32) -> crate::Result<()> {
        let ds = self.datasets.get(name).ok_or_else(|| anyhow::anyhow!("dataset {name} not loaded"))?;
        if ds.fp_bufs.contains_key(&level) {
            return Ok(());
        }
        let fmt = crate::quant::FpFormat::fp(level);
        let mut bufs = Vec::new();
        let mut h2d = 0u64;
        for (i, (_, dims, data)) in ds.weights.flat().into_iter().enumerate() {
            // flat() order is (w, b, alpha) per layer: quantise only w.
            let owned: Vec<f32> = if i % 3 == 0 {
                data.iter().map(|&v| fmt.quantize(v)).collect()
            } else {
                data.to_vec()
            };
            let buf = self
                .client
                .buffer_from_host_buffer::<f32>(&owned, &dims, None)
                .map_err(|e| anyhow::anyhow!("uploading FP{level} weights for {name}: {e}"))?;
            h2d += (owned.len() * 4) as u64;
            bufs.push(buf);
        }
        self.stats.h2d_bytes += h2d;
        self.datasets.get_mut(name).unwrap().fp_bufs.insert(level, bufs);
        Ok(())
    }

    /// Loaded weights of a dataset (for the pure-rust cross-check engines).
    pub fn weights(&self, name: &str) -> crate::Result<&Weights> {
        Ok(&self.datasets.get(name).ok_or_else(|| anyhow::anyhow!("dataset {name} not loaded"))?.weights)
    }

    /// Load the eval split of a dataset.
    pub fn eval_data(&self, name: &str) -> crate::Result<EvalData> {
        EvalData::load(&self.manifest.dataset_dir(name))
    }

    /// Compile (or fetch from cache) a variant's executable.
    pub fn ensure_compiled(&mut self, v: &VariantRef) -> crate::Result<()> {
        let key = v.key();
        if self.executables.contains_key(&key) {
            return Ok(());
        }
        let path = self.manifest.hlo_path(v);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .map_err(|e| anyhow::anyhow!("parsing {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| anyhow::anyhow!("compiling {key}: {e}"))?;
        self.stats.compiles += 1;
        self.stats.compile_ns += t0.elapsed().as_nanos();
        self.executables.insert(key, exe);
        Ok(())
    }

    /// Execute one batch on a variant.  `x` must be exactly
    /// `v.batch * input_dim` long (use [`Backend::run_padded`] for
    /// partial batches).  `sc_key` is required for SC variants.
    pub fn execute(&mut self, v: &VariantRef, x: &[f32], sc_key: Option<[u32; 2]>) -> crate::Result<BatchOutputs> {
        self.ensure_compiled(v)?;
        self.load_dataset(&v.dataset)?;
        if v.kind == VariantKind::Fp {
            self.ensure_fp_weights(&v.dataset, v.level as u32)?;
        }
        let ds = &self.datasets[&v.dataset];
        anyhow::ensure!(
            x.len() == v.batch * ds.input_dim,
            "input length {} != batch {} * input_dim {}",
            x.len(),
            v.batch,
            ds.input_dim
        );
        let t0 = Instant::now();
        let xbuf = self
            .client
            .buffer_from_host_buffer::<f32>(x, &[v.batch, ds.input_dim], None)
            .map_err(|e| anyhow::anyhow!("uploading batch: {e}"))?;
        self.stats.h2d_bytes += (x.len() * 4) as u64;
        let kbuf = match (v.kind, sc_key) {
            (VariantKind::Sc, Some(k)) => Some(
                self.client
                    .buffer_from_host_buffer::<u32>(&k, &[2], None)
                    .map_err(|e| anyhow::anyhow!("uploading key: {e}"))?,
            ),
            (VariantKind::Sc, None) => anyhow::bail!("SC variant requires a key"),
            (VariantKind::Fp, _) => None,
        };
        let wbufs: &Vec<xla::PjRtBuffer> = match v.kind {
            VariantKind::Fp => &ds.fp_bufs[&(v.level as u32)],
            VariantKind::Sc => &ds.bufs,
        };
        let mut inputs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(2 + wbufs.len());
        inputs.push(&xbuf);
        if let Some(ref k) = kbuf {
            inputs.push(k);
        }
        inputs.extend(wbufs.iter());
        let exe = &self.executables[&v.key()];
        let result = exe
            .execute_b::<&xla::PjRtBuffer>(&inputs)
            .map_err(|e| anyhow::anyhow!("executing {}: {e}", v.key()))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("download: {e}"))?;
        self.stats.executes += 1;
        self.stats.execute_ns += t0.elapsed().as_nanos();
        let parts = result.to_tuple().map_err(|e| anyhow::anyhow!("untuple: {e}"))?;
        anyhow::ensure!(parts.len() == 3, "expected 3 outputs, got {}", parts.len());
        let scores = parts[0].to_vec::<f32>().map_err(|e| anyhow::anyhow!("scores: {e}"))?;
        let pred = parts[1].to_vec::<i32>().map_err(|e| anyhow::anyhow!("pred: {e}"))?;
        let margin = parts[2].to_vec::<f32>().map_err(|e| anyhow::anyhow!("margin: {e}"))?;
        let n_classes = scores.len() / v.batch;
        Ok(BatchOutputs { scores, pred, margin, batch: v.batch, n_classes })
    }
}

impl Backend for Engine {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn load_dataset(&mut self, name: &str) -> crate::Result<()> {
        Engine::load_dataset(self, name)
    }

    fn weights(&self, name: &str) -> crate::Result<&Weights> {
        Engine::weights(self, name)
    }

    fn eval_data(&self, name: &str) -> crate::Result<EvalData> {
        Engine::eval_data(self, name)
    }

    fn ensure_compiled(&mut self, v: &VariantRef) -> crate::Result<()> {
        Engine::ensure_compiled(self, v)
    }

    fn execute(&mut self, v: &VariantRef, x: &[f32], sc_key: Option<[u32; 2]>) -> crate::Result<BatchOutputs> {
        Engine::execute(self, v, x, sc_key)
    }

    fn stats(&self) -> EngineStats {
        self.stats.report()
    }
}
