//! The pure-rust inference backend: the [`crate::mlp`] engines behind
//! the [`Backend`] trait.
//!
//! `NativeBackend` is fully self-contained — no PJRT library, no
//! `artifacts/` directory required.  It serves either a real artifacts
//! directory (same `.bin`/`.meta` contract as the PJRT engine) or the
//! deterministic in-memory fixture suite from
//! [`crate::runtime::fixture`], which is what makes `cargo test -q`
//! green on a fresh offline checkout.
//!
//! FP variants run a prepared [`FpPlan`] (bit-identical quantisation to
//! the L1 Pallas kernel, pre-quantised at compile time); SC variants run
//! a prepared [`ScPlan`] of the calibrated noise model, seeded from the
//! caller's `[u32; 2]` key exactly like the PJRT path's threefry key —
//! same key, same stream.
//!
//! "Compilation" ([`Backend::ensure_compiled`]) builds a prepared
//! variant: per-layer weights quantised once per format,
//! packed into the padded kernel layout, per-layer `max|w|` precomputed
//! for the SC noise model, plus reusable ping-pong activation scratch —
//! cached by `(dataset, kind, level)` and shared across batch sizes, so
//! steady-state execution does no per-call weight work — and, when the
//! caller returns consumed outputs via [`Backend::recycle_outputs`],
//! no per-call allocation either (output storage circulates through a
//! small recycle pool).  Batch rows shard across the persistent parked
//! worker pool ([`crate::util::pool`]) with bit-identical results for
//! any thread count.
//!
//! Unlike the PJRT client (`Rc`-based, thread-pinned), `NativeBackend`
//! owns plain host memory and is `Send`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::data::{EvalData, Manifest, VariantKind, VariantRef, Weights};
use crate::mlp::{FpPlan, OutBufs, ScPlan, Scratch};
use crate::quant::FpFormat;
use crate::runtime::fixture::{self, FixtureSpec};
use crate::runtime::{Backend, BatchOutputs, EngineStats, EngineStatsAccum, VariantStats};
use crate::sc::ScConfig;
use crate::util::fault;

/// Max recycled output-buffer sets kept by [`Backend::recycle_outputs`].
/// The serving path keeps at most a couple in flight; the cap just
/// bounds memory if a caller recycles more than it executes.
const FREE_OUTPUT_POOL: usize = 8;

struct LoadedDataset {
    weights: Weights,
    eval: EvalData,
}

/// A compiled-for-native variant: the prepared plan plus its reusable
/// scratch and per-variant timings.  One per `(dataset, kind, level)` —
/// batch size only affects how much of the scratch is used.
struct PreparedVariant {
    dataset: String,
    kind: VariantKind,
    level: usize,
    kernel: PreparedKernel,
    scratch: Scratch,
    stats: VariantStats,
}

impl PreparedVariant {
    /// Cache identity: batch size deliberately excluded (plans are
    /// batch-agnostic).
    fn matches(&self, v: &VariantRef) -> bool {
        self.kind == v.kind && self.level == v.level && self.dataset == v.dataset
    }
}

enum PreparedKernel {
    Fp(FpPlan),
    Sc(ScPlan),
}

/// Stable per-variant stats key (batch size excluded, like the cache).
fn plan_key(v: &VariantRef) -> String {
    format!("{}/{:?}{}", v.dataset, v.kind, v.level)
}

/// Pure-rust [`Backend`] over the `mlp`/`quant`/`sc` modules.
///
/// ```
/// use ari::runtime::{Backend, NativeBackend};
/// let backend = NativeBackend::synthetic();
/// assert_eq!(backend.name(), "native");
/// assert_eq!(backend.manifest().datasets.len(), 3);
/// ```
pub struct NativeBackend {
    manifest: Manifest,
    /// Artifacts root for lazily loaded datasets (None = synthetic).
    root: Option<PathBuf>,
    datasets: HashMap<String, LoadedDataset>,
    /// The single compilation cache: one prepared plan (+ scratch +
    /// timings) per `(dataset, kind, level)`.  A linear scan, not a
    /// map: variant counts are tiny and matching on fields keeps the
    /// steady-state execute path free of per-call key formatting.
    plans: Vec<PreparedVariant>,
    /// Recycled output buffers ([`Backend::recycle_outputs`]) handed
    /// back to the next execute, shared across variants.
    free: Vec<OutBufs>,
    stats: EngineStatsAccum,
}

impl NativeBackend {
    /// Open an artifacts directory (as written by `make artifacts` or by
    /// [`fixture::write_artifacts`]).  Weights/eval data load lazily per
    /// dataset, mirroring the PJRT engine's lifecycle.
    pub fn from_artifacts(artifacts: &Path) -> crate::Result<Self> {
        let manifest = Manifest::load(artifacts)?;
        Ok(Self {
            manifest,
            root: Some(artifacts.to_path_buf()),
            datasets: HashMap::new(),
            plans: Vec::new(),
            free: Vec::new(),
            stats: EngineStatsAccum::default(),
        })
    }

    /// The default deterministic fixture suite
    /// ([`fixture::default_specs`]) — three miniature datasets with the
    /// full FP/SC variant grid, entirely in memory.
    pub fn synthetic() -> Self {
        Self::from_fixtures(&fixture::default_specs())
    }

    /// Build from explicit fixture specs (generated eagerly, in memory).
    pub fn from_fixtures(specs: &[FixtureSpec]) -> Self {
        let manifest = fixture::manifest(specs);
        let mut datasets = HashMap::new();
        for spec in specs {
            let fx = fixture::generate(spec);
            datasets.insert(spec.name.clone(), LoadedDataset { weights: fx.weights, eval: fx.eval });
        }
        Self { manifest, root: None, datasets, plans: Vec::new(), free: Vec::new(), stats: EngineStatsAccum::default() }
    }

    /// The prepared variant for `v`, building and caching it on first
    /// use ("compilation"): validate against the manifest, load the
    /// dataset, pre-quantise/pack the weights into the kernel layout.
    /// One plan per `(dataset, kind, level)` — batch sizes share it.
    fn prepared(&mut self, v: &VariantRef) -> crate::Result<&mut PreparedVariant> {
        if let Some(idx) = self.plans.iter().position(|p| p.matches(v)) {
            return Ok(&mut self.plans[idx]);
        }
        self.manifest.dataset(&v.dataset)?;
        if v.kind == VariantKind::Sc {
            // Fails loudly on non-power-of-two lengths, like the
            // exporter would at lowering time.
            anyhow::ensure!(
                v.level >= 2 && v.level.is_power_of_two(),
                "SC sequence length {} must be a power of two >= 2",
                v.level
            );
        }
        self.load_dataset(&v.dataset)?;
        let weights = &self.datasets[&v.dataset].weights;
        let t0 = Instant::now();
        let kernel = match v.kind {
            VariantKind::Fp => PreparedKernel::Fp(FpPlan::new(weights, FpFormat::fp(v.level as u32))),
            VariantKind::Sc => PreparedKernel::Sc(ScPlan::new(weights, ScConfig::new(v.level))),
        };
        let prepare_ns = t0.elapsed().as_nanos();
        self.stats.compiles += 1;
        self.stats.compile_ns += prepare_ns;
        let stats = VariantStats { key: plan_key(v), prepare_ns, ..Default::default() };
        self.plans.push(PreparedVariant {
            dataset: v.dataset.clone(),
            kind: v.kind,
            level: v.level,
            kernel,
            scratch: Scratch::new(),
            stats,
        });
        Ok(self.plans.last_mut().expect("just prepared"))
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn load_dataset(&mut self, name: &str) -> crate::Result<()> {
        if self.datasets.contains_key(name) {
            return Ok(());
        }
        let entry = self.manifest.dataset(name)?.clone();
        if self.root.is_none() {
            anyhow::bail!("dataset {name} not in this synthetic backend");
        }
        let dir = self.manifest.dataset_dir(name);
        // Every load error names the offending file: a corrupt artifact
        // directory must produce a typed, actionable `Err`, never a
        // panic (pinned by `tests/failure_injection.rs`).
        let weights = Weights::load(&dir)
            .map_err(|e| e.context(format!("dataset {name}: {}", dir.join("weights.bin/.meta").display())))?;
        anyhow::ensure!(
            weights.layers[0].in_dim == entry.input_dim,
            "weights/manifest input_dim mismatch for {name} in {}",
            dir.join("weights.meta").display()
        );
        let eval = EvalData::load(&dir)
            .map_err(|e| e.context(format!("dataset {name}: {}", dir.join("eval.bin/.meta").display())))?;
        self.datasets.insert(name.to_string(), LoadedDataset { weights, eval });
        Ok(())
    }

    fn weights(&self, name: &str) -> crate::Result<&Weights> {
        Ok(&self.datasets.get(name).ok_or_else(|| anyhow::anyhow!("dataset {name} not loaded"))?.weights)
    }

    fn eval_data(&self, name: &str) -> crate::Result<EvalData> {
        if let Some(ds) = self.datasets.get(name) {
            return Ok(ds.eval.clone());
        }
        match &self.root {
            Some(_) => EvalData::load(&self.manifest.dataset_dir(name)),
            None => anyhow::bail!("dataset {name} not in this synthetic backend"),
        }
    }

    fn ensure_compiled(&mut self, v: &VariantRef) -> crate::Result<()> {
        self.prepared(v).map(|_| ())
    }

    fn execute(&mut self, v: &VariantRef, x: &[f32], sc_key: Option<[u32; 2]>) -> crate::Result<BatchOutputs> {
        // Injected environmental faults (one relaxed load when
        // disarmed): a latency spike, a transient typed error, or a
        // mid-batch panic — in escalating order of violence so one
        // chaos schedule can arm all three.
        if fault::armed() {
            if fault::inject(fault::EXEC_DELAY) {
                std::thread::sleep(fault::STALL);
            }
            if fault::inject(fault::EXEC_ERROR) {
                anyhow::bail!("injected transient execute fault ({})", plan_key(v));
            }
            if fault::inject(fault::EXEC_PANIC) {
                panic!("injected execute panic ({})", plan_key(v));
            }
        }
        // Output storage comes from the recycle pool when the caller
        // returns consumed outputs (`recycle_outputs`): the steady-state
        // serving dispatch then allocates nothing here.
        let bufs = self.free.pop().unwrap_or_default();
        let (out, batch, elapsed) = {
            let plan = self.prepared(v)?;
            // Work-aware worker count: tiny models stay serial (even a
            // parked-pool dispatch would out-cost the kernel), big ones
            // scale with cores.
            let (input_dim, threads) = match &plan.kernel {
                PreparedKernel::Fp(p) => (p.input_dim(), p.auto_threads(v.batch)),
                PreparedKernel::Sc(p) => (p.input_dim(), p.auto_threads(v.batch)),
            };
            anyhow::ensure!(
                x.len() == v.batch * input_dim,
                "input length {} != batch {} * input_dim {}",
                x.len(),
                v.batch,
                input_dim
            );
            let t0 = Instant::now();
            let out = match &plan.kernel {
                PreparedKernel::Fp(p) => p.forward_reuse(x, v.batch, &mut plan.scratch, threads, bufs),
                PreparedKernel::Sc(p) => {
                    let Some(key) = sc_key else {
                        anyhow::bail!("SC variant requires a key");
                    };
                    let seed = ((key[0] as u64) << 32) | key[1] as u64;
                    p.forward_reuse(x, v.batch, seed, &mut plan.scratch, threads, bufs)
                }
            };
            let elapsed = t0.elapsed();
            plan.stats.executes += 1;
            plan.stats.execute_ns += elapsed.as_nanos();
            plan.stats.samples += v.batch as u64;
            (out, v.batch, elapsed)
        };
        self.stats.executes += 1;
        self.stats.execute_ns += elapsed.as_nanos();
        let n_classes = out.scores.cols;
        Ok(BatchOutputs { scores: out.scores.data, pred: out.pred, margin: out.margin, batch, n_classes })
    }

    fn recycle_outputs(&mut self, out: BatchOutputs) {
        if self.free.len() < FREE_OUTPUT_POOL {
            self.free.push(OutBufs { scores: out.scores, pred: out.pred, margin: out.margin });
        }
    }

    fn stats(&self) -> EngineStats {
        self.stats.report()
    }

    fn variant_stats(&self) -> Vec<VariantStats> {
        let mut out: Vec<VariantStats> = self.plans.iter().map(|p| p.stats.clone()).collect();
        out.sort_by(|a, b| a.key.cmp(&b.key));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backend() -> NativeBackend {
        NativeBackend::from_fixtures(&[FixtureSpec::small("d", "D", 16, 11)])
    }

    fn fp_variant(b: &NativeBackend, level: usize, batch: usize) -> VariantRef {
        b.manifest().variant("d", VariantKind::Fp, level, batch).unwrap().clone()
    }

    #[test]
    fn executes_fp_batch() {
        let mut b = backend();
        let v = fp_variant(&b, 16, 32);
        let eval = b.eval_data("d").unwrap();
        let out = b.execute(&v, eval.rows(0, 32), None).unwrap();
        assert_eq!(out.batch, 32);
        assert_eq!(out.pred.len(), 32);
        assert_eq!(out.n_classes, 10);
        assert_eq!(out.scores.len(), 320);
        assert!(b.stats().executes == 1 && b.stats().compiles == 1);
    }

    #[test]
    fn fp_is_deterministic() {
        let mut b = backend();
        let v = fp_variant(&b, 10, 32);
        let eval = b.eval_data("d").unwrap();
        let a = b.execute(&v, eval.rows(0, 32), None).unwrap();
        let c = b.execute(&v, eval.rows(0, 32), None).unwrap();
        assert_eq!(a.pred, c.pred);
        assert_eq!(a.scores, c.scores);
    }

    #[test]
    fn sc_same_key_same_stream() {
        let mut b = backend();
        let v = b.manifest().variant("d", VariantKind::Sc, 512, 32).unwrap().clone();
        let eval = b.eval_data("d").unwrap();
        let a = b.execute(&v, eval.rows(0, 32), Some([3, 4])).unwrap();
        let c = b.execute(&v, eval.rows(0, 32), Some([3, 4])).unwrap();
        assert_eq!(a.scores, c.scores);
    }

    #[test]
    fn recycled_outputs_do_not_change_results() {
        // The recycle pool only reuses capacity: executing through
        // recycled buffers must be bit-identical to fresh allocation,
        // for FP and (same key) SC alike.
        let mut b = backend();
        let eval = b.eval_data("d").unwrap();
        let v = fp_variant(&b, 10, 32);
        let first = b.execute(&v, eval.rows(0, 32), None).unwrap();
        let want = (first.scores.clone(), first.pred.clone(), first.margin.clone());
        b.recycle_outputs(first);
        let again = b.execute(&v, eval.rows(0, 32), None).unwrap();
        assert_eq!((again.scores.clone(), again.pred.clone(), again.margin.clone()), want);
        b.recycle_outputs(again);

        let sv = b.manifest().variant("d", VariantKind::Sc, 512, 32).unwrap().clone();
        let sa = b.execute(&sv, eval.rows(0, 32), Some([3, 4])).unwrap();
        let swant = sa.scores.clone();
        b.recycle_outputs(sa);
        let sb = b.execute(&sv, eval.rows(0, 32), Some([3, 4])).unwrap();
        assert_eq!(sb.scores, swant, "SC through recycled buffers must keep the stream");
    }

    #[test]
    fn recycle_pool_is_bounded() {
        let mut b = backend();
        let eval = b.eval_data("d").unwrap();
        let v = fp_variant(&b, 16, 32);
        for _ in 0..2 * FREE_OUTPUT_POOL {
            let out = b.execute(&v, eval.rows(0, 32), None).unwrap();
            b.recycle_outputs(out.clone());
            b.recycle_outputs(out); // over-recycling must not grow the pool unboundedly
        }
        assert!(b.free.len() <= FREE_OUTPUT_POOL);
    }

    #[test]
    fn sc_without_key_rejected() {
        let mut b = backend();
        let v = b.manifest().variant("d", VariantKind::Sc, 512, 32).unwrap().clone();
        let eval = b.eval_data("d").unwrap();
        let err = b.execute(&v, eval.rows(0, 32), None).unwrap_err().to_string();
        assert!(err.contains("key"), "{err}");
    }

    #[test]
    fn wrong_input_length_rejected() {
        let mut b = backend();
        let v = fp_variant(&b, 16, 32);
        let err = b.execute(&v, &[0.0; 10], None).unwrap_err().to_string();
        assert!(err.contains("input length"), "{err}");
    }

    #[test]
    fn plan_cache_shared_across_batch_sizes() {
        // (dataset, kind, level) keys the prepared plan: executing the
        // same level at two compiled batch sizes builds it once.
        let mut b = backend();
        let eval = b.eval_data("d").unwrap();
        let v32 = b.manifest().variant("d", VariantKind::Fp, 16, 32).unwrap().clone();
        let v256 = b.manifest().variant("d", VariantKind::Fp, 16, 256).unwrap().clone();
        b.execute(&v32, eval.rows(0, 32), None).unwrap();
        b.execute(&v256, eval.rows(0, 256), None).unwrap();
        assert_eq!(b.stats().compiles, 1, "one plan for both batch sizes");
        assert_eq!(b.stats().executes, 2);
        let vs = b.variant_stats();
        assert_eq!(vs.len(), 1);
        assert_eq!(vs[0].key, "d/Fp16");
        assert_eq!(vs[0].executes, 2);
        assert_eq!(vs[0].samples, 32 + 256);
        assert!(vs[0].ns_per_sample() >= 0.0);
    }

    #[test]
    fn variant_stats_sorted_and_per_level() {
        let mut b = backend();
        let eval = b.eval_data("d").unwrap();
        for level in [16usize, 8] {
            let v = fp_variant(&b, level, 32);
            b.execute(&v, eval.rows(0, 32), None).unwrap();
        }
        let keys: Vec<String> = b.variant_stats().into_iter().map(|s| s.key).collect();
        assert_eq!(keys, vec!["d/Fp16".to_string(), "d/Fp8".to_string()]);
    }

    #[test]
    fn unknown_dataset_rejected() {
        let mut b = backend();
        assert!(b.load_dataset("nope").is_err());
        assert!(b.weights("nope").is_err());
        assert!(b.eval_data("nope").is_err());
    }

    /// The `exec-error` fault point turns executes into typed errors
    /// naming the plan, without corrupting the backend: once the armed
    /// count is spent the same variant executes normally again.
    #[test]
    fn injected_exec_error_is_typed_and_transient() {
        let mut b = backend();
        let v = fp_variant(&b, 16, 32);
        let eval = b.eval_data("d").unwrap();
        b.execute(&v, eval.rows(0, 32), None).unwrap(); // compile clean
        let _g = fault::ArmGuard::arm("exec-error:1.0:2");
        for _ in 0..2 {
            let err = b.execute(&v, eval.rows(0, 32), None).unwrap_err().to_string();
            assert!(err.contains("injected transient execute fault"), "{err}");
            assert!(err.contains("d/Fp16"), "error must name the plan: {err}");
        }
        let out = b.execute(&v, eval.rows(0, 32), None).unwrap();
        assert_eq!(out.batch, 32, "backend must recover once the fault count is spent");
    }

    /// The `exec-panic` fault point panics mid-batch; the backend (and
    /// its plan cache) survives a caught panic.
    #[test]
    fn injected_exec_panic_leaves_backend_usable() {
        let mut b = backend();
        let v = fp_variant(&b, 16, 32);
        let eval = b.eval_data("d").unwrap();
        b.execute(&v, eval.rows(0, 32), None).unwrap();
        let _g = fault::ArmGuard::arm("exec-panic:1.0:1");
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = b.execute(&v, eval.rows(0, 32), None);
        }));
        assert!(caught.is_err(), "armed exec-panic must fire");
        let out = b.execute(&v, eval.rows(0, 32), None).unwrap();
        assert_eq!(out.batch, 32, "backend must stay usable after a caught panic");
    }
}
