//! The pure-rust inference backend: the [`crate::mlp`] engines behind
//! the [`Backend`] trait.
//!
//! `NativeBackend` is fully self-contained — no PJRT library, no
//! `artifacts/` directory required.  It serves either a real artifacts
//! directory (same `.bin`/`.meta` contract as the PJRT engine) or the
//! deterministic in-memory fixture suite from
//! [`crate::runtime::fixture`], which is what makes `cargo test -q`
//! green on a fresh offline checkout.
//!
//! FP variants run the truncated-mantissa [`crate::mlp::FpEngine`]
//! (bit-identical quantisation to the L1 Pallas kernel); SC variants run
//! the calibrated [`crate::mlp::ScNoiseEngine`], seeded from the
//! caller's `[u32; 2]` key exactly like the PJRT path's threefry key —
//! same key, same stream.
//!
//! Unlike the PJRT client (`Rc`-based, thread-pinned), `NativeBackend`
//! owns plain host memory and is `Send`.

use std::collections::{HashMap, HashSet};
use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::data::{EvalData, Manifest, VariantKind, VariantRef, Weights};
use crate::mlp::{FpEngine, ScNoiseEngine};
use crate::quant::FpFormat;
use crate::runtime::fixture::{self, FixtureSpec};
use crate::runtime::{Backend, BatchOutputs, EngineStats};
use crate::sc::ScConfig;

struct LoadedDataset {
    weights: Weights,
    eval: EvalData,
}

/// Pure-rust [`Backend`] over the `mlp`/`quant`/`sc` modules.
///
/// ```
/// use ari::runtime::{Backend, NativeBackend};
/// let backend = NativeBackend::synthetic();
/// assert_eq!(backend.name(), "native");
/// assert_eq!(backend.manifest().datasets.len(), 3);
/// ```
pub struct NativeBackend {
    manifest: Manifest,
    /// Artifacts root for lazily loaded datasets (None = synthetic).
    root: Option<PathBuf>,
    datasets: HashMap<String, LoadedDataset>,
    compiled: HashSet<String>,
    stats: EngineStats,
}

impl NativeBackend {
    /// Open an artifacts directory (as written by `make artifacts` or by
    /// [`fixture::write_artifacts`]).  Weights/eval data load lazily per
    /// dataset, mirroring the PJRT engine's lifecycle.
    pub fn from_artifacts(artifacts: &Path) -> crate::Result<Self> {
        let manifest = Manifest::load(artifacts)?;
        Ok(Self {
            manifest,
            root: Some(artifacts.to_path_buf()),
            datasets: HashMap::new(),
            compiled: HashSet::new(),
            stats: EngineStats::default(),
        })
    }

    /// The default deterministic fixture suite
    /// ([`fixture::default_specs`]) — three miniature datasets with the
    /// full FP/SC variant grid, entirely in memory.
    pub fn synthetic() -> Self {
        Self::from_fixtures(&fixture::default_specs())
    }

    /// Build from explicit fixture specs (generated eagerly, in memory).
    pub fn from_fixtures(specs: &[FixtureSpec]) -> Self {
        let manifest = fixture::manifest(specs);
        let mut datasets = HashMap::new();
        for spec in specs {
            let fx = fixture::generate(spec);
            datasets.insert(spec.name.clone(), LoadedDataset { weights: fx.weights, eval: fx.eval });
        }
        Self { manifest, root: None, datasets, compiled: HashSet::new(), stats: EngineStats::default() }
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn load_dataset(&mut self, name: &str) -> crate::Result<()> {
        if self.datasets.contains_key(name) {
            return Ok(());
        }
        let entry = self.manifest.dataset(name)?.clone();
        if self.root.is_none() {
            anyhow::bail!("dataset {name} not in this synthetic backend");
        }
        let dir = self.manifest.dataset_dir(name);
        let weights = Weights::load(&dir)?;
        anyhow::ensure!(
            weights.layers[0].in_dim == entry.input_dim,
            "weights/manifest input_dim mismatch for {name}"
        );
        let eval = EvalData::load(&dir)?;
        self.datasets.insert(name.to_string(), LoadedDataset { weights, eval });
        Ok(())
    }

    fn weights(&self, name: &str) -> crate::Result<&Weights> {
        Ok(&self.datasets.get(name).ok_or_else(|| anyhow::anyhow!("dataset {name} not loaded"))?.weights)
    }

    fn eval_data(&self, name: &str) -> crate::Result<EvalData> {
        if let Some(ds) = self.datasets.get(name) {
            return Ok(ds.eval.clone());
        }
        match &self.root {
            Some(_) => EvalData::load(&self.manifest.dataset_dir(name)),
            None => anyhow::bail!("dataset {name} not in this synthetic backend"),
        }
    }

    fn ensure_compiled(&mut self, v: &VariantRef) -> crate::Result<()> {
        // Nothing to compile natively; validate the variant and account
        // it once so stats stay comparable across backends.
        if self.compiled.contains(&v.key()) {
            return Ok(());
        }
        self.manifest.dataset(&v.dataset)?;
        if v.kind == VariantKind::Sc {
            // Fails loudly on non-power-of-two lengths, like the
            // exporter would at lowering time.
            anyhow::ensure!(
                v.level >= 2 && v.level.is_power_of_two(),
                "SC sequence length {} must be a power of two >= 2",
                v.level
            );
        }
        self.compiled.insert(v.key());
        self.stats.compiles += 1;
        Ok(())
    }

    fn execute(&mut self, v: &VariantRef, x: &[f32], sc_key: Option<[u32; 2]>) -> crate::Result<BatchOutputs> {
        self.ensure_compiled(v)?;
        self.load_dataset(&v.dataset)?;
        let ds = &self.datasets[&v.dataset];
        let input_dim = ds.weights.layers[0].in_dim;
        anyhow::ensure!(
            x.len() == v.batch * input_dim,
            "input length {} != batch {} * input_dim {}",
            x.len(),
            v.batch,
            input_dim
        );
        let t0 = Instant::now();
        let out = match v.kind {
            VariantKind::Fp => FpEngine::new(&ds.weights, FpFormat::fp(v.level as u32)).forward(x, v.batch),
            VariantKind::Sc => {
                let Some(key) = sc_key else {
                    anyhow::bail!("SC variant requires a key");
                };
                let seed = ((key[0] as u64) << 32) | key[1] as u64;
                ScNoiseEngine::new(&ds.weights, ScConfig::new(v.level)).forward(x, v.batch, seed)
            }
        };
        self.stats.executes += 1;
        self.stats.execute_us += t0.elapsed().as_micros();
        let n_classes = out.scores.cols;
        Ok(BatchOutputs { scores: out.scores.data, pred: out.pred, margin: out.margin, batch: v.batch, n_classes })
    }

    fn stats(&self) -> EngineStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backend() -> NativeBackend {
        NativeBackend::from_fixtures(&[FixtureSpec::small("d", "D", 16, 11)])
    }

    fn fp_variant(b: &NativeBackend, level: usize, batch: usize) -> VariantRef {
        b.manifest().variant("d", VariantKind::Fp, level, batch).unwrap().clone()
    }

    #[test]
    fn executes_fp_batch() {
        let mut b = backend();
        let v = fp_variant(&b, 16, 32);
        let eval = b.eval_data("d").unwrap();
        let out = b.execute(&v, eval.rows(0, 32), None).unwrap();
        assert_eq!(out.batch, 32);
        assert_eq!(out.pred.len(), 32);
        assert_eq!(out.n_classes, 10);
        assert_eq!(out.scores.len(), 320);
        assert!(b.stats().executes == 1 && b.stats().compiles == 1);
    }

    #[test]
    fn fp_is_deterministic() {
        let mut b = backend();
        let v = fp_variant(&b, 10, 32);
        let eval = b.eval_data("d").unwrap();
        let a = b.execute(&v, eval.rows(0, 32), None).unwrap();
        let c = b.execute(&v, eval.rows(0, 32), None).unwrap();
        assert_eq!(a.pred, c.pred);
        assert_eq!(a.scores, c.scores);
    }

    #[test]
    fn sc_same_key_same_stream() {
        let mut b = backend();
        let v = b.manifest().variant("d", VariantKind::Sc, 512, 32).unwrap().clone();
        let eval = b.eval_data("d").unwrap();
        let a = b.execute(&v, eval.rows(0, 32), Some([3, 4])).unwrap();
        let c = b.execute(&v, eval.rows(0, 32), Some([3, 4])).unwrap();
        assert_eq!(a.scores, c.scores);
    }

    #[test]
    fn sc_without_key_rejected() {
        let mut b = backend();
        let v = b.manifest().variant("d", VariantKind::Sc, 512, 32).unwrap().clone();
        let eval = b.eval_data("d").unwrap();
        let err = b.execute(&v, eval.rows(0, 32), None).unwrap_err().to_string();
        assert!(err.contains("key"), "{err}");
    }

    #[test]
    fn wrong_input_length_rejected() {
        let mut b = backend();
        let v = fp_variant(&b, 16, 32);
        let err = b.execute(&v, &[0.0; 10], None).unwrap_err().to_string();
        assert!(err.contains("input length"), "{err}");
    }

    #[test]
    fn unknown_dataset_rejected() {
        let mut b = backend();
        assert!(b.load_dataset("nope").is_err());
        assert!(b.weights("nope").is_err());
        assert!(b.eval_data("nope").is_err());
    }
}
