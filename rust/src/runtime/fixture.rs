//! Deterministic synthetic fixtures: datasets + weights + manifest that
//! make the whole stack (backend, cascade, server, experiments) runnable
//! with **no** `artifacts/` directory and no build-time python step.
//!
//! The generator is seeded ([`crate::util::Pcg64`]) and uses no wall
//! clock, so every run — test, doctest, CI — sees bit-identical data.
//! Gaussian draws go through `Pcg64::normal_unpaired` (one Box–Muller
//! transform per call, sine half discarded): the draw pattern is pinned
//! so fixture bytes stay identical even as `Pcg64::normal` gains
//! optimisations like the spare-half cache.
//!
//! The construction mirrors the paper's setting at miniature scale:
//! class prototypes are unit-norm gaussian directions; the first layer's
//! leading columns embed the prototypes (so the network is a working
//! classifier out of the box); deeper layers are near-identity with
//! small gaussian mixing.  Eval rows are scaled prototypes plus noise,
//! with a configurable fraction of "hard" rows (prototype mixtures)
//! whose margins sit near zero — exactly the elements that change class
//! under resolution reduction and drive the ARI escalation machinery.

use std::io::Write as _;
use std::path::{Path, PathBuf};

use crate::data::{DatasetEntry, EvalData, LayerWeights, Manifest, VariantKind, VariantRef, Weights};
use crate::util::Pcg64;

/// FP bit widths every fixture manifest exposes (paper Table I axis).
pub const FP_LEVELS: [usize; 5] = [16, 14, 12, 10, 8];

/// SC sequence lengths every fixture manifest exposes (Table II axis).
pub const SC_LEVELS: [usize; 7] = [4096, 2048, 1024, 512, 256, 128, 64];

/// Compiled batch sizes every fixture manifest exposes.
pub const BATCHES: [usize; 2] = [32, 256];

/// Description of one synthetic dataset.
#[derive(Clone, Debug)]
pub struct FixtureSpec {
    /// Dataset name (manifest key, e.g. `fashion_syn`).
    pub name: String,
    /// Paper dataset this stands in for (underscores become spaces).
    pub paper_name: String,
    /// Input feature dimension.
    pub input_dim: usize,
    /// Number of classes.
    pub n_classes: usize,
    /// Hidden layer widths (each must be >= `n_classes`).
    pub hidden: Vec<usize>,
    /// Eval split size.
    pub n_eval: usize,
    /// Fraction of eval rows built as two-prototype mixtures (the
    /// near-zero-margin tail that escalates under ARI).
    pub hard_fraction: f64,
    /// PRNG seed; same seed, same bytes.
    pub seed: u64,
}

impl FixtureSpec {
    /// A small (fast even in debug builds) spec with sane defaults.
    pub fn small(name: &str, paper_name: &str, input_dim: usize, seed: u64) -> Self {
        Self {
            name: name.to_string(),
            paper_name: paper_name.to_string(),
            input_dim,
            n_classes: 10,
            hidden: vec![32, 16],
            n_eval: 512,
            hard_fraction: 0.12,
            seed,
        }
    }
}

/// The default three-dataset suite mirroring the paper's evaluation
/// (Fashion-MNIST / SVHN / CIFAR-10 stand-ins, miniature topologies).
pub fn default_specs() -> Vec<FixtureSpec> {
    vec![
        FixtureSpec::small("fashion_syn", "Fashion-MNIST", 24, 0xF517_0001),
        FixtureSpec::small("svhn_syn", "SVHN", 28, 0xF517_0002),
        FixtureSpec::small("cifar10_syn", "CIFAR-10", 32, 0xF517_0003),
    ]
}

/// One generated dataset: weights + eval split.
#[derive(Clone, Debug)]
pub struct Fixture {
    /// The spec this was generated from.
    pub spec: FixtureSpec,
    /// Trained-looking MLP weights.
    pub weights: Weights,
    /// Eval inputs and labels.
    pub eval: EvalData,
}

/// Generate the weights and eval split for a spec (deterministic).
pub fn generate(spec: &FixtureSpec) -> Fixture {
    let mut rng = Pcg64::new(spec.seed, 7);
    let n_classes = spec.n_classes;

    // Unit-norm class prototypes.
    let mut prototypes: Vec<Vec<f32>> = Vec::with_capacity(n_classes);
    for _ in 0..n_classes {
        let mut p: Vec<f32> = (0..spec.input_dim).map(|_| rng.normal_unpaired() as f32).collect();
        let norm = (p.iter().map(|v| v * v).sum::<f32>()).sqrt().max(1e-6);
        for v in &mut p {
            *v /= norm;
        }
        prototypes.push(p);
    }

    // Layer widths: input -> hidden... -> classes.
    let mut dims = vec![spec.input_dim];
    dims.extend(spec.hidden.iter().copied());
    dims.push(n_classes);

    let mut layers = Vec::with_capacity(dims.len() - 1);
    for li in 0..dims.len() - 1 {
        let (in_dim, out_dim) = (dims[li], dims[li + 1]);
        // Background mixing weights.
        let mut w: Vec<f32> = (0..in_dim * out_dim).map(|_| (rng.normal_unpaired() as f32) * 0.05).collect();
        if li == 0 {
            // Leading columns carry the class prototypes.
            for (j, proto) in prototypes.iter().enumerate().take(out_dim.min(n_classes)) {
                for i in 0..in_dim {
                    w[i * out_dim + j] = proto[i] + (rng.normal_unpaired() as f32) * 0.01;
                }
            }
        } else {
            // Near-identity on the class coordinates.
            for j in 0..in_dim.min(out_dim).min(n_classes) {
                w[j * out_dim + j] += 1.0;
            }
        }
        let b: Vec<f32> = (0..out_dim).map(|_| rng.range_f64(-0.05, 0.05) as f32).collect();
        layers.push(LayerWeights { w, in_dim, out_dim, b, alpha: 0.25 });
    }
    let weights = Weights { layers };

    // Eval split: scaled prototypes + noise, with a hard-row tail.
    let mut x = Vec::with_capacity(spec.n_eval * spec.input_dim);
    let mut y = Vec::with_capacity(spec.n_eval);
    for _ in 0..spec.n_eval {
        let c = rng.below(n_classes as u64) as usize;
        let scale = rng.range_f64(0.6, 1.4) as f32;
        let difficulty = rng.range_f64(0.02, 0.25) as f32;
        let hard = rng.next_f64() < spec.hard_fraction;
        let c2 = (c + 1 + rng.below(n_classes as u64 - 1) as usize) % n_classes;
        for i in 0..spec.input_dim {
            let base = if hard {
                0.5 * prototypes[c][i] + 0.5 * prototypes[c2][i]
            } else {
                prototypes[c][i]
            };
            x.push(scale * base + difficulty * rng.normal_unpaired() as f32);
        }
        y.push(c as i32);
    }
    let eval = EvalData { x, y, n: spec.n_eval, input_dim: spec.input_dim };

    Fixture { spec: spec.clone(), weights, eval }
}

/// A deterministic input-drift transform for a fixture's eval split:
/// the drifted stream is the original stream under an affine
/// feature-space shift plus seeded gaussian noise — the "sensor aged /
/// environment moved" setting the control loop's drift monitor targets
/// (`docs/ROBUSTNESS.md`, "Control loop").  Labels are untouched: drift
/// moves the inputs, not the task.
#[derive(Clone, Copy, Debug)]
pub struct DriftSpec {
    /// Multiplicative feature scale (1.0 = none).
    pub scale: f32,
    /// Additive feature shift (0.0 = none).
    pub shift: f32,
    /// Std-dev of the extra seeded gaussian noise (0.0 = none).
    pub noise: f32,
    /// PRNG seed for the noise stream; same spec, same bytes.
    pub seed: u64,
}

impl Default for DriftSpec {
    fn default() -> Self {
        Self { scale: 1.15, shift: 0.1, noise: 0.05, seed: 0xD21F }
    }
}

/// [`generate`] followed by an in-place [`DriftSpec`] perturbation of
/// the eval split.  Additive on purpose: `generate` itself is untouched,
/// so undrifted fixture bytes (and everything calibrated on them) stay
/// bit-identical.  Deterministic: same `(spec, drift)`, same bytes.
pub fn generate_drifted(spec: &FixtureSpec, drift: &DriftSpec) -> Fixture {
    let mut fx = generate(spec);
    drift_eval(&mut fx.eval, drift);
    fx
}

/// Apply a [`DriftSpec`] to an eval split in place.  Deterministic for a
/// fixed `(data, drift)` pair; labels stay untouched.  This is the one
/// drift transform in the repo — the fixture generator, `ari sweep
/// --drift`, and the control-loop tests all go through it so their
/// notion of "drifted stream" agrees bit for bit.
pub fn drift_eval(data: &mut EvalData, drift: &DriftSpec) {
    let mut rng = Pcg64::new(drift.seed, 11);
    for v in &mut data.x {
        *v = *v * drift.scale + drift.shift + drift.noise * rng.normal_unpaired() as f32;
    }
}

/// The manifest entry for a spec.
pub fn dataset_entry(spec: &FixtureSpec) -> DatasetEntry {
    DatasetEntry {
        name: spec.name.clone(),
        paper_name: spec.paper_name.clone(),
        input_dim: spec.input_dim,
        n_classes: spec.n_classes,
        n_eval: spec.n_eval,
        train_acc: 0.9,
    }
}

/// All variant records for a spec (full FP/SC level × batch grid).
pub fn variants(spec: &FixtureSpec) -> Vec<VariantRef> {
    let mut out = Vec::new();
    for &batch in &BATCHES {
        for &level in &FP_LEVELS {
            out.push(VariantRef {
                dataset: spec.name.clone(),
                kind: VariantKind::Fp,
                level,
                batch,
                file: format!("fp{level}_b{batch}.hlo.txt"),
            });
        }
        for &level in &SC_LEVELS {
            out.push(VariantRef {
                dataset: spec.name.clone(),
                kind: VariantKind::Sc,
                level,
                batch,
                file: format!("sc{level}_b{batch}.hlo.txt"),
            });
        }
    }
    out
}

/// Build an in-memory manifest over a fixture suite.
pub fn manifest(specs: &[FixtureSpec]) -> Manifest {
    Manifest {
        root: PathBuf::from("<synthetic>"),
        datasets: specs.iter().map(dataset_entry).collect(),
        variants: specs.iter().flat_map(|s| variants(s)).collect(),
    }
}

/// Serialise tensors in the exporter's `.bin`/`.meta` container format
/// (the rust twin of `python/compile/aot.py::BinWriter`).
struct BinWriter {
    bin: Vec<u8>,
    meta: String,
}

impl BinWriter {
    fn new() -> Self {
        Self { bin: Vec::new(), meta: String::from("ari-meta v1\n") }
    }

    fn add_f32(&mut self, name: &str, dims: &[usize], vals: &[f32]) {
        let off = self.bin.len();
        for v in vals {
            self.bin.extend_from_slice(&v.to_le_bytes());
        }
        self.push_meta(name, "f32", dims, off, vals.len() * 4);
    }

    fn add_i32(&mut self, name: &str, dims: &[usize], vals: &[i32]) {
        let off = self.bin.len();
        for v in vals {
            self.bin.extend_from_slice(&v.to_le_bytes());
        }
        self.push_meta(name, "i32", dims, off, vals.len() * 4);
    }

    fn push_meta(&mut self, name: &str, dtype: &str, dims: &[usize], off: usize, len: usize) {
        let dimstr = dims.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(" ");
        self.meta.push_str(&format!("tensor {name} {dtype} {} {dimstr} {off} {len}\n", dims.len()));
    }

    fn write(&self, base: &Path) -> crate::Result<()> {
        let mut f = std::fs::File::create(base.with_extension("bin"))?;
        f.write_all(&self.bin)?;
        let mut f = std::fs::File::create(base.with_extension("meta"))?;
        f.write_all(self.meta.as_bytes())?;
        Ok(())
    }
}

/// Write a fixture suite to disk as a real artifacts directory
/// (`manifest.txt` + per-dataset `weights.*` / `eval.*`), loadable by
/// [`crate::data::Manifest::load`], [`crate::data::Weights::load`] and
/// [`crate::data::EvalData::load`] — used by loader/failure tests and by
/// `ari fixture --out DIR`.
pub fn write_artifacts(dir: &Path, specs: &[FixtureSpec]) -> crate::Result<()> {
    std::fs::create_dir_all(dir)?;
    let mut manifest_text = String::from("ari-manifest v1\n");
    for spec in specs {
        let fx = generate(spec);
        let ds_dir = dir.join(&spec.name);
        std::fs::create_dir_all(&ds_dir)?;

        let mut w = BinWriter::new();
        for (i, l) in fx.weights.layers.iter().enumerate() {
            w.add_f32(&format!("layer{i}.w"), &[l.in_dim, l.out_dim], &l.w);
            w.add_f32(&format!("layer{i}.b"), &[l.out_dim], &l.b);
            w.add_f32(&format!("layer{i}.alpha"), &[1], &[l.alpha]);
        }
        w.write(&ds_dir.join("weights"))?;

        let mut e = BinWriter::new();
        e.add_f32("x", &[fx.eval.n, fx.eval.input_dim], &fx.eval.x);
        e.add_i32("y", &[fx.eval.n], &fx.eval.y);
        e.write(&ds_dir.join("eval"))?;

        manifest_text.push_str(&format!(
            "dataset {} paper={} input_dim={} n_classes={} n_eval={} train_acc=0.9\n",
            spec.name,
            spec.paper_name.replace(' ', "_"),
            spec.input_dim,
            spec.n_classes,
            spec.n_eval
        ));
        for v in variants(spec) {
            let kind = match v.kind {
                VariantKind::Fp => "fp",
                VariantKind::Sc => "sc",
            };
            manifest_text.push_str(&format!(
                "variant {} kind={kind} level={} batch={} file={}\n",
                v.dataset, v.level, v.batch, v.file
            ));
        }
    }
    std::fs::write(dir.join("manifest.txt"), manifest_text)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_is_deterministic() {
        let spec = FixtureSpec::small("d", "D", 16, 42);
        let a = generate(&spec);
        let b = generate(&spec);
        assert_eq!(a.weights.layers[0].w, b.weights.layers[0].w);
        assert_eq!(a.eval.x, b.eval.x);
        assert_eq!(a.eval.y, b.eval.y);
    }

    #[test]
    fn drifted_generation_is_deterministic_and_differs() {
        let spec = FixtureSpec::small("d", "D", 16, 42);
        let drift = DriftSpec::default();
        let a = generate_drifted(&spec, &drift);
        let b = generate_drifted(&spec, &drift);
        // Byte-identical per (spec, drift) pair: the drift stream is as
        // reproducible as the base fixture.
        assert_eq!(a.eval.x, b.eval.x);
        let base = generate(&spec);
        // Weights and labels untouched; inputs moved.
        assert_eq!(a.weights.layers[0].w, base.weights.layers[0].w);
        assert_eq!(a.eval.y, base.eval.y);
        assert_ne!(a.eval.x, base.eval.x);
        // A different drift seed gives a different (still valid) stream.
        let c = generate_drifted(&spec, &DriftSpec { seed: 99, ..drift });
        assert_ne!(a.eval.x, c.eval.x);
    }

    #[test]
    fn dims_chain_and_labels_in_range() {
        let spec = FixtureSpec::small("d", "D", 16, 1);
        let fx = generate(&spec);
        assert_eq!(fx.weights.dims(), vec![16, 32, 16, 10]);
        assert_eq!(fx.eval.n, spec.n_eval);
        assert!(fx.eval.y.iter().all(|&y| (0..10).contains(&y)));
    }

    #[test]
    fn classifier_is_better_than_chance() {
        // The embedded-prototype construction must give a working
        // classifier (the numpy design study puts full-model accuracy
        // around 0.9; assert a generous floor).
        let spec = FixtureSpec::small("d", "D", 24, 3);
        let fx = generate(&spec);
        let eng = crate::mlp::FpEngine::new(&fx.weights, crate::quant::FpFormat::FP16);
        let out = eng.forward(&fx.eval.x, fx.eval.n);
        let ok = out.pred.iter().zip(&fx.eval.y).filter(|(a, b)| a == b).count();
        let acc = ok as f64 / fx.eval.n as f64;
        assert!(acc > 0.6, "synthetic full-model accuracy {acc} too low");
    }

    #[test]
    fn manifest_covers_grid() {
        let specs = default_specs();
        let m = manifest(&specs);
        assert_eq!(m.datasets.len(), 3);
        for spec in &specs {
            for &b in &BATCHES {
                assert!(m.variant(&spec.name, VariantKind::Fp, 16, b).is_ok());
                assert!(m.variant(&spec.name, VariantKind::Sc, 4096, b).is_ok());
            }
            assert_eq!(m.levels(&spec.name, VariantKind::Fp), FP_LEVELS.to_vec());
            assert_eq!(m.levels(&spec.name, VariantKind::Sc), SC_LEVELS.to_vec());
        }
    }

    #[test]
    fn written_artifacts_roundtrip() {
        let dir = std::env::temp_dir().join(format!("ari-fixture-rt-{}", std::process::id()));
        let specs = vec![FixtureSpec::small("tiny", "Tiny", 12, 9)];
        write_artifacts(&dir, &specs).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.datasets[0].name, "tiny");
        let w = Weights::load(&dir.join("tiny")).unwrap();
        let fx = generate(&specs[0]);
        assert_eq!(w.layers[0].w, fx.weights.layers[0].w);
        let e = EvalData::load(&dir.join("tiny")).unwrap();
        assert_eq!(e.x, fx.eval.x);
        assert_eq!(e.y, fx.eval.y);
        std::fs::remove_dir_all(dir).ok();
    }
}
